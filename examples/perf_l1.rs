//! §Perf L1/L2 iteration harness: times every "perf"-experiment artifact
//! against the shipped default on the scaled Table-1 baseline.
use std::path::Path;
use streamk::bench;
use streamk::prop::Rng;
use streamk::runtime::{Engine, Manifest};

fn main() -> anyhow::Result<()> {
    let engine = Engine::new(Manifest::load(Path::new("artifacts"))?)?;
    let mut rng = Rng::new(31);
    let a = rng.normal_f32_vec(960 * 1024);
    let b = rng.normal_f32_vec(1024 * 1024);
    let mut names: Vec<String> = engine
        .manifest()
        .artifacts
        .iter()
        .filter(|x| x.experiment == "perf")
        .map(|x| x.name.clone())
        .collect();
    names.insert(0, "gemm_streamk_nopad_f32_960x1024x1024".into());
    names.push("gemm_ref_nopad_f32_960x1024x1024".into());
    names.push("gemm_tile_nopad_f32_960x1024x1024".into());
    for name in names {
        engine.warmup(&[&name])?;
        let stats = bench::bench(1, 5, || {
            bench::keep(engine.run_f32(&name, &[&a, &b]).unwrap());
        });
        println!("{name:<60} min {:>8.2} ms  ({:.3} TFLOP/s)",
                 stats.min * 1e3,
                 2.0 * 960.0 * 1024.0 * 1024.0 / stats.min / 1e12);
    }
    Ok(())
}
