//! Quickstart: run one Stream-K GEMM through the full stack.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```
//!
//! Loads the AOT-compiled Stream-K artifact (Pallas kernel → HLO text),
//! executes it on the PJRT CPU client, and cross-checks the result
//! against (a) the AOT reference-oracle artifact and (b) the pure-rust
//! naive GEMM — the same three-way check the integration tests enforce.

use std::path::Path;

use streamk::faults::{error_rate, naive_gemm, Matrix};
use streamk::prop::Rng;
use streamk::runtime::{Engine, Manifest};

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let engine = Engine::new(Manifest::load(&dir)?)?;
    println!("PJRT platform: {}", engine.platform());

    // 1. Make a random 128x128x128 problem.
    let mut rng = Rng::new(1);
    let a = Matrix::random(128, 128, &mut rng);
    let b = Matrix::random(128, 128, &mut rng);

    // 2. Run it through the Stream-K artifact (8 simulated CUs).
    let name = "gemm_streamk_nopad_f32_128x128x128_cu8";
    let (outs, stats) = engine.run_f32(name, &[&a.data, &b.data])?;
    println!(
        "{name}\n  compile {:.3}s (cached afterwards), execute {:.6}s, {:.3} TFLOP/s",
        stats.compile_s,
        stats.execute_s,
        stats.tflops()
    );

    // 3. Cross-check vs the jnp oracle artifact and naive rust GEMM.
    let (oracle, _) =
        engine.run_f32("gemm_ref_nopad_f32_128x128x128", &[&a.data, &b.data])?;
    let vs_oracle = error_rate(&outs[0], &oracle[0], 1e-3);
    let vs_naive = error_rate(&outs[0], &naive_gemm(&a, &b).data, 1e-2);
    println!(
        "  vs jnp oracle:  {} ({} / {} elements off)",
        if vs_oracle.passed() { "OK" } else { "MISMATCH" },
        vs_oracle.bad,
        vs_oracle.total
    );
    println!(
        "  vs naive rust:  {} (max rel err {:.2e})",
        if vs_naive.passed() { "OK" } else { "MISMATCH" },
        vs_naive.max_rel_err
    );
    anyhow::ensure!(vs_oracle.passed() && vs_naive.passed(), "numerics");

    // 4. Show the schedule that artifact baked in.
    let sched = streamk::decomp::build_schedule(
        streamk::decomp::GemmShape::new(128, 128, 128),
        streamk::decomp::BlockShape::default(),
        8,
    )?;
    println!(
        "\nschedule: {} tile(s) × {} k-iters on 8 CUs → dp_tiles={} \
         sk_tiles={} split_tiles={}",
        sched.grid.num_tiles(),
        sched.grid.iters_per_tile,
        sched.dp_tiles,
        sched.sk_tiles,
        sched.split_tiles.len()
    );
    println!("quickstart OK");
    Ok(())
}
