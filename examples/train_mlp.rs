//! End-to-end TRAINING driver: a rust-owned SGD loop over the
//! AOT-compiled training step, in which every matmul — forward and
//! backward — is the Stream-K Pallas kernel.
//!
//! ```sh
//! make artifacts && cargo run --release --example train_mlp -- --steps 200
//! ```
//!
//! The artifact is `(w1, b1, w2, b2, x, y) → (w1', b1', w2', b2', loss)`:
//! rust holds the parameters as plain f32 buffers, feeds synthetic
//! teacher-generated batches, iterates the step, and logs the loss
//! curve. Python is involved zero times after `make artifacts`.

use std::path::Path;

use streamk::cli::{Command, Opt};
use streamk::exec::Stopwatch;
use streamk::prop::Rng;
use streamk::runtime::{Engine, Manifest};

const ARTIFACT: &str = "train_mlp_streamk_f32_b32_64x128x32";
const D_IN: usize = 64;
const D_HIDDEN: usize = 128;
const D_OUT: usize = 32;
const BATCH: usize = 32;

/// The synthetic regression task (mirror of `compile.train.synthetic_batch`
/// up to RNG): targets from a fixed random teacher, so the loss has
/// structure and must fall under SGD.
struct Teacher {
    w: Vec<f32>,
}

impl Teacher {
    fn new(rng: &mut Rng) -> Self {
        Self { w: rng.normal_f32_vec(D_IN * D_OUT) }
    }

    fn batch(&self, rng: &mut Rng) -> (Vec<f32>, Vec<f32>) {
        let x = rng.normal_f32_vec(BATCH * D_IN);
        let scale = 1.0 / (D_IN as f32).sqrt();
        let mut y = vec![0.0f32; BATCH * D_OUT];
        for r in 0..BATCH {
            for c in 0..D_OUT {
                let mut acc = 0.0f32;
                for i in 0..D_IN {
                    acc += x[r * D_IN + i] * self.w[i * D_OUT + c];
                }
                y[r * D_OUT + c] = acc * scale;
            }
        }
        (x, y)
    }
}

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("train_mlp", "rust-driven Stream-K training loop")
        .opt(Opt::value("artifacts", Some("artifacts"), "artifact dir"))
        .opt(Opt::value("steps", Some("200"), "SGD steps"))
        .opt(Opt::value("batches", Some("8"), "dataset size (cycled)"))
        .opt(Opt::value("log-every", Some("20"), "loss log cadence"))
        .opt(Opt::value("loss-out", None, "CSV path for the loss curve"));
    let args = cmd.parse_or_exit();
    let steps = args.usize("steps")?;
    let n_batches = args.usize("batches")?.max(1);
    let log_every = args.usize("log-every")?.max(1);

    let engine = Engine::new(Manifest::load(Path::new(args.str("artifacts")))?)?;
    let meta = engine.manifest().get(ARTIFACT)?.clone();
    println!(
        "training step artifact: {} ({} GEMM-FLOPs/step, fwd+bwd all \
         Stream-K)",
        meta.name, meta.flops
    );
    let compile = engine.warmup(&[ARTIFACT])?;
    println!("compiled in {compile:.2}s\n");

    // He-style init at the scale the convergence tests validated.
    let mut rng = Rng::new(0x7EAC4);
    let scale = 0.3f32;
    let mut w1: Vec<f32> =
        rng.normal_f32_vec(D_IN * D_HIDDEN).iter().map(|v| v * scale).collect();
    let mut b1 = vec![0.0f32; D_HIDDEN];
    let mut w2: Vec<f32> =
        rng.normal_f32_vec(D_HIDDEN * D_OUT).iter().map(|v| v * scale).collect();
    let mut b2 = vec![0.0f32; D_OUT];

    let teacher = Teacher::new(&mut rng);
    let data: Vec<(Vec<f32>, Vec<f32>)> =
        (0..n_batches).map(|_| teacher.batch(&mut rng)).collect();

    let mut curve: Vec<(usize, f32)> = Vec::new();
    let sw = Stopwatch::start();
    let mut first_loss = None;
    let mut last_loss = 0.0f32;
    for step in 0..steps {
        let (x, y) = &data[step % n_batches];
        let (mut outs, _) =
            engine.run_f32(ARTIFACT, &[&w1, &b1, &w2, &b2, x, y])?;
        last_loss = outs[4][0];
        b2 = outs.swap_remove(3);
        w2 = outs.swap_remove(2);
        b1 = outs.swap_remove(1);
        w1 = outs.swap_remove(0);
        first_loss.get_or_insert(last_loss);
        if step % log_every == 0 || step + 1 == steps {
            println!("step {step:>5}  loss {last_loss:.5}");
            curve.push((step, last_loss));
        }
    }
    let wall = sw.elapsed_secs();
    let first = first_loss.unwrap();
    println!(
        "\ntrained {steps} steps in {wall:.2}s ({:.1} steps/s, {:.3} \
         GFLOP/s of Stream-K GEMMs)",
        steps as f64 / wall,
        meta.flops as f64 * steps as f64 / wall / 1e9
    );
    println!("loss: {first:.4} → {last_loss:.4} ({:.1}% of start)",
             last_loss / first * 100.0);
    if let Some(path) = args.get("loss-out") {
        let mut csv = String::from("step,loss\n");
        for (s, l) in &curve {
            csv.push_str(&format!("{s},{l}\n"));
        }
        std::fs::write(path, csv)?;
        println!("loss curve written to {path}");
    }
    anyhow::ensure!(
        last_loss < 0.5 * first,
        "loss must at least halve over {steps} steps"
    );
    println!("train_mlp OK");
    Ok(())
}
