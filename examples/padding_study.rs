//! The report's padding experiment as a runnable example: execute the
//! padded and no-padding Stream-K artifacts on the same data and show
//! (a) identical numerics and (b) the timing difference, alongside the
//! analytical padding-overhead model. The full Table-1 regeneration
//! lives in `cargo bench --bench table1_padding`; this is the
//! single-shape interactive version.
//!
//! ```sh
//! make artifacts && cargo run --release --example padding_study -- --shape t1_irregular
//! ```

use std::path::Path;

use streamk::bench;
use streamk::cli::{Command, Opt};
use streamk::decomp::{BlockShape, GemmShape};
use streamk::faults::error_rate;
use streamk::prop::Rng;
use streamk::runtime::{Engine, Manifest};

const SHAPES: &[(&str, usize, usize, usize)] = &[
    ("t1_base", 960, 1024, 1024),
    ("t1_small", 3, 9, 9),
    ("t1_irregular", 480, 500, 500),
    ("t1_medium", 480, 512, 512),
];

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("padding_study", "padded vs no-padding, one shape")
        .opt(Opt::value("artifacts", Some("artifacts"), "artifact dir"))
        .opt(Opt::value("shape", Some("t1_irregular"),
                        "t1_base|t1_small|t1_irregular|t1_medium"))
        .opt(Opt::value("iters", Some("5"), "timed iterations"));
    let args = cmd.parse_or_exit();
    let &(tag, m, n, k) = SHAPES
        .iter()
        .find(|(t, ..)| *t == args.str("shape"))
        .ok_or_else(|| anyhow::anyhow!("unknown shape tag"))?;
    let iters = args.usize("iters")?;

    let dir = Path::new(args.str("artifacts"));
    let engine = Engine::new(Manifest::load(dir)?)?;

    let mut rng = Rng::new(11);
    let a = rng.normal_f32_vec(m * k);
    let b = rng.normal_f32_vec(k * n);

    let pad_name = format!("gemm_streamk_pad_f32_{m}x{n}x{k}");
    let nopad_name = format!("gemm_streamk_nopad_f32_{m}x{n}x{k}");
    engine.warmup(&[pad_name.as_str(), nopad_name.as_str()])?;

    println!("== {tag}: {m}x{n}x{k} ==");
    let shape = GemmShape::new(m, n, k);
    let overhead = {
        // analytical inflation of A/B traffic from physical padding
        let block = BlockShape::default().effective(shape);
        let mp = m.div_ceil(block.bm) * block.bm;
        let np = n.div_ceil(block.bn) * block.bn;
        let kp = k.div_ceil(block.bk) * block.bk;
        (mp * kp + kp * np) as f64 / (m * k + k * n) as f64 - 1.0
    };
    println!("analytical padded-operand inflation: {:.1}%\n", overhead * 100.0);

    let mut results = Vec::new();
    for (label, name) in [("padded", &pad_name), ("no padding", &nopad_name)] {
        let stats = bench::bench(1, iters, || {
            let out = engine.run_f32(name, &[&a, &b]).expect("run");
            bench::keep(out);
        });
        let flops = shape.flops();
        println!(
            "{label:>11}: {:>8.3} ms  {:>6.3} TFLOP/s  (min {:.3} ms over {iters} iters)",
            stats.mean_ms(),
            flops as f64 / stats.mean / 1e12,
            stats.min * 1e3
        );
        results.push((label, stats));
    }
    let improvement =
        results[0].1.mean / results[1].1.mean - 1.0;
    println!(
        "\nno-padding improvement: {:.1}%  (report measured 0.2%–3% on MI200)",
        improvement * 100.0
    );

    // numerics must agree between the two policies
    let (p, _) = engine.run_f32(&pad_name, &[&a, &b])?;
    let (np_, _) = engine.run_f32(&nopad_name, &[&a, &b])?;
    let rep = error_rate(&p[0], &np_[0], 1e-3);
    anyhow::ensure!(rep.passed(), "pad policies disagree: {rep:?}");
    println!("numerics: padded == no-padding ({} elements checked)", rep.total);
    Ok(())
}
