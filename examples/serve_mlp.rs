//! End-to-end serving driver (DESIGN.md §5 E2E): load the AOT-compiled
//! MLP (both matmuls are the Stream-K Pallas kernel), start the
//! coordinator, fire a batched synthetic request stream, and report
//! latency/throughput — the run recorded in EXPERIMENTS.md.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_mlp -- --requests 200
//! ```

use streamk::cli::{Command, Opt};
use streamk::config::Settings;
use streamk::coordinator::Coordinator;
use streamk::exec::Stopwatch;
use streamk::prop::Rng;
use streamk::runtime::{spawn_engine, Manifest};

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("serve_mlp", "end-to-end MLP serving demo")
        .opt(Opt::value("artifacts", Some("artifacts"), "artifact dir"))
        .opt(Opt::value("requests", Some("200"), "requests to send"))
        .opt(Opt::value("workers", Some("2"), "coordinator workers"))
        .opt(Opt::value("max-batch", Some("32"), "dynamic batch limit"))
        .opt(Opt::value("batch-window-us", Some("500"), "batch window µs"))
        .opt(Opt::value("metrics-out", None, "metrics JSON path"));
    let args = cmd.parse_or_exit();
    let settings = Settings::default().apply_cli(&args)?;
    let requests = args.usize("requests")?;

    let manifest = Manifest::load(&settings.artifacts_dir)?;
    let (engine, _join) = spawn_engine(manifest)?;
    let warm = engine.warmup(&[
        "mlp_streamk_f32_b8_256x512x256",
        "mlp_streamk_f32_b32_256x512x256",
        "mlp_streamk_f32_b128_256x512x256",
    ])?;
    println!("compiled 3 MLP batch variants in {warm:.2}s (one Stream-K \
              kernel config serves all of them)");

    let coord = Coordinator::start(engine, &settings);
    let handle = coord.handle.clone();

    // Mixed open-loop workload: mostly single-row requests with bursts.
    let mut rng = Rng::new(0xE2E);
    let sw = Stopwatch::start();
    let mut waiters = Vec::with_capacity(requests);
    for i in 0..requests {
        let rows = if i % 17 == 0 { 8 } else { *rng.choose(&[1usize, 1, 2, 4]) };
        waiters.push(handle.submit_mlp(rows, rng.normal_f32_vec(rows * 256)));
    }
    let mut ok = 0usize;
    let mut rows_served = 0usize;
    for w in waiters {
        let resp = w.recv().expect("response");
        if let Ok(y) = &resp.result {
            ok += 1;
            rows_served += y.len() / 256;
        }
    }
    let wall = sw.elapsed_secs();

    let snap = handle.metrics().snapshot();
    println!("\n== serve_mlp results ==");
    println!("requests      : {ok}/{requests} ok, {rows_served} rows");
    println!("wall time     : {wall:.3}s  ({:.1} req/s, {:.1} rows/s)",
             ok as f64 / wall, rows_served as f64 / wall);
    println!("batches       : {} (mean {:.2} rows — dynamic batching at work)",
             snap.batches, snap.mean_batch_rows);
    println!("latency e2e   : p50 {:.2}ms  p95 {:.2}ms  p99 {:.2}ms",
             snap.e2e.quantile_us(0.50) / 1e3,
             snap.e2e.quantile_us(0.95) / 1e3,
             snap.e2e.quantile_us(0.99) / 1e3);
    println!("model compute : {:.3} TFLOP/s sustained", snap.tflops);
    if let Some(path) = args.get("metrics-out") {
        std::fs::write(path, streamk::json::to_string_pretty(&snap.to_json()))?;
        println!("metrics JSON  : {path}");
    }
    coord.shutdown();
    anyhow::ensure!(ok == requests, "{} requests failed", requests - ok);
    println!("serve_mlp OK");
    Ok(())
}
