//! Interactive-ish exploration of the Stream-K schedule and its
//! simulated behaviour — the tool the report's authors needed when they
//! were reverse-engineering CK ("it would take extensive learning the
//! library or testing to even know what parameters are permissible").
//!
//! ```sh
//! cargo run --release --example streamk_explorer -- --m 3840 --n 4096 --k 4096
//! ```
//!
//! Prints the decomposition (DP/SK regions, per-CU segments, fixup
//! schedule), the parameter-legality verdict for the chosen block, and
//! the simulated MI200 comparison of all three decompositions.

use streamk::cli::{Command, Opt};
use streamk::decomp::{
    build_schedule, occupancy, params, splitk, swizzle::Swizzle, tile,
    BlockShape, GemmShape, TileGrid,
};
use streamk::gpu_sim::{gemm, Device, DeviceKind};

fn main() -> anyhow::Result<()> {
    let cmd = Command::new("streamk_explorer", "inspect a Stream-K schedule")
        .opt(Opt::value("m", Some("3840"), "M"))
        .opt(Opt::value("n", Some("4096"), "N"))
        .opt(Opt::value("k", Some("4096"), "K"))
        .opt(Opt::value("cus", Some("120"), "compute units"))
        .opt(Opt::value("bm", Some("128"), "block M"))
        .opt(Opt::value("bn", Some("128"), "block N"))
        .opt(Opt::value("bk", Some("64"), "block K"))
        .opt(Opt::flag("segments", "dump every CU's segment list"));
    let args = cmd.parse_or_exit();
    let shape = GemmShape::new(
        args.usize("m")?,
        args.usize("n")?,
        args.usize("k")?,
    );
    let block = BlockShape::new(
        args.usize("bm")?,
        args.usize("bn")?,
        args.usize("bk")?,
    );
    let cus = args.usize("cus")?;

    // --- parameter legality (the BLK experiment's single-point view) ---
    let kp = params::KernelParams::new(block, 4);
    println!("== kernel parameters ==");
    println!("block {}x{}x{}  VMEM {:.1} KiB  MXU util {:.0}%",
             block.bm, block.bn, block.bk,
             kp.vmem_bytes() as f64 / 1024.0,
             kp.mxu_utilization() * 100.0);
    match params::check(&kp) {
        Ok(()) => println!("legal: yes"),
        Err(reasons) => {
            println!("legal: NO");
            for r in &reasons {
                println!("  - {r}");
            }
        }
    }

    // --- the schedule --------------------------------------------------
    let sched = build_schedule(shape, block, cus)?;
    let g = sched.grid;
    println!("\n== stream-k schedule: {}x{}x{} on {cus} CUs ==",
             shape.m, shape.n, shape.k);
    println!("tiles {}x{} = {}  ({} k-iters each, {} total MAC iters)",
             g.tiles_m, g.tiles_n, g.num_tiles(), g.iters_per_tile,
             g.total_iters());
    println!("data-parallel region : {} tiles ({} waves of {cus})",
             sched.dp_tiles, sched.dp_tiles_per_cu);
    println!("stream-k region      : {} tiles, {} iters split across {cus} CUs",
             sched.sk_tiles, sched.sk_iters);
    println!("split tiles (fixup)  : {} (max {} contributors)",
             sched.split_tiles.len(), sched.max_contributors);
    println!("partials workspace   : {} KiB (vs split-k's O(S·M·N))",
             sched.partials_bytes() / 1024);
    println!("utilization          : dp {:.1}%  stream-k {:.1}%",
             sched.quantization_efficiency_dp() * 100.0,
             sched.quantization_efficiency_sk() * 100.0);

    if args.flag("segments") {
        println!("\nper-CU segments (tile, k_start, k_len, kind):");
        for cu in 0..sched.p {
            let segs: Vec<String> = sched.segments[cu]
                .iter()
                .map(|s| {
                    format!(
                        "({}, {}, {}, {})",
                        s.tile,
                        s.k_start,
                        s.k_len,
                        if s.direct { "direct" } else { "partial" }
                    )
                })
                .collect();
            if !segs.is_empty() || sched.dp_tiles_per_cu > 0 {
                println!("  cu{cu:>3}: {} dp tiles + {}",
                         sched.dp_tiles_per_cu, segs.join(" "));
            }
        }
    }

    // --- simulated device comparison -----------------------------------
    let dev = Device::preset(DeviceKind::Mi200).with_cus(cus.min(120));
    let grid = TileGrid::new(shape, block.effective(shape));
    let dp = gemm::simulate(
        &dev, shape, grid,
        tile::dp_assignment(grid, dev.num_cus, Swizzle::RowMajor),
        block.effective(shape), 4,
    );
    let sk = gemm::simulate_streamk(&dev, &build_schedule(shape, block, dev.num_cus)?, 4);
    let s4 = gemm::simulate(
        &dev, shape, grid,
        splitk::splitk_assignment(grid, dev.num_cus, 4),
        block.effective(shape), 4,
    );
    println!("\n== simulated MI200 ({} CUs) ==", dev.num_cus);
    println!("{:<14} {:>10} {:>10} {:>8}", "decomposition", "ms", "TFLOP/s", "util");
    for (name, r) in [("tile (dp)", &dp), ("split-k s=4", &s4), ("stream-k", &sk)] {
        println!("{:<14} {:>10.4} {:>10.2} {:>7.1}%",
                 name, r.total_s * 1e3, r.tflops, r.utilization * 100.0);
    }
    println!("\nstream-k speedup vs tile: {:.3}x", dp.total_s / sk.total_s);

    // --- quantization landscape around this problem ---------------------
    println!("\n== utilization vs tiles (the Figure-1 sawtooth) ==");
    let pts = occupancy::utilization_sweep(
        block, cus, shape.n, shape.k,
        (1..=24).map(|i| i * block.bm * (g.tiles_m / 12).max(1)),
    );
    for p in pts.iter().step_by(2) {
        let bar = "#".repeat((p.dp_efficiency * 32.0) as usize);
        println!("{:>6} tiles  dp {:>5.1}%  sk {:>5.1}%  |{bar}",
                 p.num_tiles, p.dp_efficiency * 100.0, p.sk_efficiency * 100.0);
    }
    Ok(())
}
