//! Integration e2e for the TCP serving tier: spawns REAL `streamk
//! serve --listen` daemon processes (cargo builds the binary for us —
//! `CARGO_BIN_EXE_streamk`) and drives them over loopback.
//!
//! The full gate matrix lives in [`streamk::net::e2e`]; this test runs
//! the two profile-independent pieces — the smoke (1 daemon + 1 client
//! process, graceful drain, conservation, >90% plan hit rate) and the
//! tentpole kill-one-of-two failover run. The live adversarial
//! scenario replays execute big GEMMs for real and stay in the
//! optimized `e2e_net` driver (`cargo run --release --bin e2e_net`).

use std::path::Path;

use streamk::net::e2e;

fn bin() -> &'static Path {
    Path::new(env!("CARGO_BIN_EXE_streamk"))
}

#[test]
fn serve_daemon_smoke_over_tcp() {
    let msg = e2e::run_smoke(bin()).expect("net smoke must pass");
    println!("{msg}");
}

#[test]
fn kill_one_of_two_servers_mid_run() {
    let msg = e2e::run_kill_one(bin()).expect("kill-one e2e must pass");
    println!("{msg}");
}
