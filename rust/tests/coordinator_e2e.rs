//! End-to-end integration: engine thread + coordinator + artifacts.
//! Checks numerics against the pure-rust naive GEMM, batching
//! behaviour, load shedding, and metrics accounting.
//!
//! Runs against `rust/artifacts` when `make artifacts` has produced it;
//! otherwise (interpreter backend only) falls back to the checked-in
//! minimal manifest under `examples/minimal_artifacts`, which the
//! interpreter serves from metadata alone — so these tests activate
//! everywhere. Under `--features pjrt` real HLO files are required and
//! the tests still skip without `make artifacts`.

use std::path::Path;

use streamk::config::Settings;
use streamk::coordinator::Coordinator;
use streamk::faults::{error_rate, naive_gemm, Matrix};
use streamk::prop::Rng;
use streamk::runtime::{pjrt_test_lock, spawn_engine, Manifest};

fn manifest() -> Option<Manifest> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        return Some(Manifest::load(&dir).unwrap());
    }
    #[cfg(not(feature = "pjrt"))]
    {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
            .parent()
            .expect("crate lives under the repo root")
            .join("examples")
            .join("minimal_artifacts");
        if dir.join("manifest.json").exists() {
            return Some(Manifest::load(&dir).unwrap());
        }
    }
    eprintln!("skipped: run `make artifacts` first");
    None
}

#[test]
fn gemm_requests_roundtrip_with_correct_numerics() {
    let _guard = pjrt_test_lock();
    let Some(manifest) = manifest() else { return };
    let (engine, _join) = spawn_engine(manifest).unwrap();
    let settings = Settings { workers: 2, ..Settings::default() };
    let coord = Coordinator::start(engine, &settings);

    let mut rng = Rng::new(2024);
    let (m, n, k) = (128, 128, 128);
    let mut waiters = Vec::new();
    let mut expected = Vec::new();
    for _ in 0..6 {
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        expected.push(naive_gemm(&a, &b));
        waiters.push(coord.handle.submit_gemm(
            m,
            n,
            k,
            a.data.clone(),
            b.data.clone(),
        ));
    }
    for (w, want) in waiters.into_iter().zip(&expected) {
        let resp = w.recv().expect("response");
        let got = resp.result.expect("gemm ok");
        let rep = error_rate(&got, &want.data, 1e-2);
        assert!(rep.passed(), "artifact {}: {rep:?}", resp.artifact);
        assert_eq!(resp.artifact, "gemm_streamk_nopad_f32_128x128x128_cu8");
    }

    let snap = coord.handle.metrics().snapshot();
    assert_eq!(snap.completed, 6);
    assert_eq!(snap.failed, 0);
    assert!(snap.throughput_rps > 0.0);
    coord.shutdown();
}

#[test]
fn unroutable_shape_fails_gracefully() {
    let _guard = pjrt_test_lock();
    let Some(manifest) = manifest() else { return };
    let (engine, _join) = spawn_engine(manifest).unwrap();
    let coord = Coordinator::start(engine, &Settings::default());
    let w = coord.handle.submit_gemm(7, 7, 7, vec![0.0; 49], vec![0.0; 49]);
    let resp = w.recv().unwrap();
    let err = resp.result.unwrap_err();
    assert!(err.contains("make artifacts"), "{err}");
    let snap = coord.handle.metrics().snapshot();
    assert_eq!(snap.failed, 1);
    coord.shutdown();
}

#[test]
fn mlp_requests_batch_and_match_direct_execution() {
    let _guard = pjrt_test_lock();
    let Some(manifest) = manifest() else { return };
    let (engine, _join) = spawn_engine(manifest).unwrap();
    engine
        .warmup(&[
            "mlp_streamk_f32_b8_256x512x256",
            "mlp_streamk_f32_b32_256x512x256",
        ])
        .unwrap();
    let settings = Settings {
        workers: 2,
        max_batch: 16,
        batch_window_us: 3000,
        ..Settings::default()
    };
    let coord = Coordinator::start(engine.clone(), &settings);

    let mut rng = Rng::new(7);
    let reqs: Vec<(usize, Vec<f32>)> = (0..8)
        .map(|i| {
            let rows = 1 + (i % 3);
            (rows, rng.normal_f32_vec(rows * 256))
        })
        .collect();
    let waiters: Vec<_> = reqs
        .iter()
        .map(|(rows, x)| coord.handle.submit_mlp(*rows, x.clone()))
        .collect();

    // Direct single-request execution through the same artifact as oracle.
    let params = streamk::coordinator::mlp_params();
    for ((rows, x), w) in reqs.iter().zip(waiters) {
        let resp = w.recv().unwrap();
        let got = resp.result.expect("mlp ok");
        assert_eq!(got.len(), rows * 256);
        assert!(resp.batched_as >= *rows);

        let mut padded = vec![0.0f32; 8 * 256];
        padded[..x.len()].copy_from_slice(x);
        let (outs, _) = engine
            .run_slices(
                "mlp_streamk_f32_b8_256x512x256",
                &[&padded, &params.w1, &params.b1, &params.w2, &params.b2],
            )
            .unwrap();
        let rep = error_rate(&got, &outs[0][..rows * 256], 1e-2);
        assert!(rep.passed(), "{rep:?}");
    }
    let snap = coord.handle.metrics().snapshot();
    assert_eq!(snap.completed, 8);
    assert!(snap.batches >= 1);
    // the window should have folded at least two requests somewhere
    assert!(snap.mean_batch_rows > 1.0, "{}", snap.mean_batch_rows);
    coord.shutdown();
}

#[test]
fn try_submit_sheds_load_when_saturated() {
    let _guard = pjrt_test_lock();
    let Some(manifest) = manifest() else { return };
    let (engine, _join) = spawn_engine(manifest).unwrap();
    let settings = Settings {
        workers: 1,
        queue_cap: 2,
        ..Settings::default()
    };
    let coord = Coordinator::start(engine, &settings);
    let mut shed = 0;
    let mut accepted = Vec::new();
    for _ in 0..50 {
        match coord.handle.try_submit_gemm(
            128,
            128,
            128,
            vec![1.0; 128 * 128],
            vec![1.0; 128 * 128],
        ) {
            Some(w) => accepted.push(w),
            None => shed += 1,
        }
    }
    for w in accepted {
        let resp = w.recv().unwrap();
        assert!(resp.result.is_ok());
    }
    let snap = coord.handle.metrics().snapshot();
    assert_eq!(snap.shed as usize, shed);
    assert_eq!(snap.completed + snap.shed, 50);
    coord.shutdown();
}
