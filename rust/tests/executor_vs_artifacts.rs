//! Three-way semantic cross-check of the Stream-K implementation:
//!
//!   1. the Pallas kernel, AOT-lowered and executed through PJRT
//!      (what production serves);
//!   2. the pure-rust schedule executor (`faults::exec`), driven by the
//!      rust schedule;
//!   3. naive triple-loop GEMM (ground truth).
//!
//! If (1) and (2) both match (3) on the same problems, the Python and
//! Rust halves of the system agree on Stream-K's semantics end to end.

use std::path::Path;

use streamk::decomp::{build_schedule, BlockShape, GemmShape};
use streamk::faults::{error_rate, execute_schedule, naive_gemm, Matrix};
use streamk::prop::Rng;
use streamk::runtime::{pjrt_test_lock, Engine, Manifest};

fn engine() -> Option<Engine> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("skipped: run `make artifacts` first");
        return None;
    }
    Some(Engine::new(Manifest::load(&dir).unwrap()).unwrap())
}

#[test]
fn all_three_implementations_agree() {
    let _guard = pjrt_test_lock();
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(77);

    // Every streamk artifact with a CU count is a distinct schedule
    // regime; check them all (table1 shapes + the cubug sweep set).
    let names: Vec<String> = engine
        .manifest()
        .artifacts
        .iter()
        .filter(|a| {
            a.algo == "streamk"
                && a.dtype == "f32"
                && a.kind == "gemm"
                && a.epilogue == "none"
                && a.flops < 400_000_000 // keep debug-profile CPU time sane
        })
        .map(|a| a.name.clone())
        .collect();
    assert!(names.len() >= 6, "expected several streamk artifacts");

    for name in names {
        let meta = engine.manifest().get(&name).unwrap().clone();
        let (m, n, k) = (meta.m, meta.n, meta.k);
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);

        let want = naive_gemm(&a, &b);

        // (1) PJRT artifact
        let (outs, _) = engine.run_f32(&name, &[&a.data, &b.data]).unwrap();
        let rep = error_rate(&outs[0], &want.data, 1e-2);
        assert!(rep.passed(), "{name} PJRT: {rep:?}");

        // (2) rust schedule executor on the same schedule parameters
        let sched = build_schedule(
            GemmShape::new(m, n, k),
            BlockShape::new(128, 128, 64),
            meta.cus,
        )
        .unwrap();
        let got = execute_schedule(&a, &b, &sched);
        let rep = error_rate(&got.data, &want.data, 1e-2);
        assert!(rep.passed(), "{name} rust executor: {rep:?}");
    }
}

#[test]
fn bf16_artifact_matches_its_ref_within_precision() {
    let _guard = pjrt_test_lock();
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(3);
    let a = rng.normal_f32_vec(256 * 256);
    let b = rng.normal_f32_vec(256 * 256);
    let (sk, _) = engine
        .run_f32("gemm_streamk_nopad_bf16_256x256x256", &[&a, &b])
        .unwrap();
    let (rf, _) = engine
        .run_f32("gemm_ref_nopad_bf16_256x256x256", &[&a, &b])
        .unwrap();
    // both sides quantize to bf16; agree to bf16 tolerance
    let rep = error_rate(&sk[0], &rf[0], 3e-2);
    assert!(rep.passed(), "{rep:?}");
}

#[test]
fn fused_gelu_epilogue_matches_ref() {
    let _guard = pjrt_test_lock();
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(4);
    let a = rng.normal_f32_vec(256 * 256);
    let b = rng.normal_f32_vec(256 * 256);
    let (sk, _) = engine
        .run_f32("gemm_streamk_nopad_f32_256x256x256_gelu", &[&a, &b])
        .unwrap();
    let (rf, _) = engine
        .run_f32("gemm_ref_nopad_f32_256x256x256_gelu", &[&a, &b])
        .unwrap();
    let rep = error_rate(&sk[0], &rf[0], 1e-3);
    assert!(rep.passed(), "{rep:?}");
}
