//! Cross-language parity: the rust Stream-K schedule must be
//! bit-identical to the python one (`python/compile/partition.py`) over
//! the golden cases in `testdata/partition_cases.json`.
//!
//! The Pallas kernels bake the *python* schedule into the HLO artifacts
//! while the simulator/coordinator reason with the *rust* schedule — any
//! divergence here means the two halves of the system disagree about who
//! computes what.

use std::path::Path;

use streamk::decomp::{build_schedule, BlockShape, GemmShape};
use streamk::json::{self, Value};

fn golden() -> Option<Vec<Value>> {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("testdata/partition_cases.json");
    let text = std::fs::read_to_string(path).ok()?;
    match json::parse(&text).expect("golden file parses") {
        Value::Arr(cases) => Some(cases),
        _ => panic!("golden root must be an array"),
    }
}

#[test]
fn schedules_match_python_bit_for_bit() {
    let Some(cases) = golden() else {
        eprintln!("skipped: run `make artifacts` to generate the golden file");
        return;
    };
    assert!(cases.len() >= 10, "expected the full parity case set");
    for case in &cases {
        let (m, n, k) = (
            case.u("m").unwrap(),
            case.u("n").unwrap(),
            case.u("k").unwrap(),
        );
        let block = BlockShape::new(
            case.u("bm").unwrap(),
            case.u("bn").unwrap(),
            case.u("bk").unwrap(),
        );
        let p = case.u("p").unwrap();
        let ctx = format!("{m}x{n}x{k} block {block:?} p={p}");
        let s = build_schedule(GemmShape::new(m, n, k), block, p)
            .unwrap_or_else(|e| panic!("{ctx}: {e}"));

        assert_eq!(s.grid.tiles_m, case.u("tiles_m").unwrap(), "{ctx}");
        assert_eq!(s.grid.tiles_n, case.u("tiles_n").unwrap(), "{ctx}");
        assert_eq!(s.grid.num_tiles(), case.u("num_tiles").unwrap(), "{ctx}");
        assert_eq!(
            s.grid.iters_per_tile,
            case.u("iters_per_tile").unwrap(),
            "{ctx}"
        );
        assert_eq!(s.grid.total_iters(), case.u("total_iters").unwrap(), "{ctx}");
        assert_eq!(s.dp_tiles, case.u("dp_tiles").unwrap(), "{ctx}");
        assert_eq!(s.sk_tiles, case.u("sk_tiles").unwrap(), "{ctx}");
        assert_eq!(
            s.dp_tiles_per_cu,
            case.u("dp_tiles_per_cu").unwrap(),
            "{ctx}"
        );
        assert_eq!(s.max_segments, case.u("max_segments").unwrap(), "{ctx}");
        assert_eq!(
            s.max_contributors,
            case.u("max_contributors").unwrap(),
            "{ctx}"
        );

        let starts = case.arr("cu_sk_start").unwrap();
        let ends = case.arr("cu_sk_end").unwrap();
        assert_eq!(starts.len(), s.p, "{ctx}");
        for cu in 0..s.p {
            assert_eq!(
                s.cu_sk_start[cu],
                starts[cu].as_usize().unwrap(),
                "{ctx} cu={cu}"
            );
            assert_eq!(
                s.cu_sk_end[cu],
                ends[cu].as_usize().unwrap(),
                "{ctx} cu={cu}"
            );
        }

        let segs = case.arr("segments").unwrap();
        for cu in 0..s.p {
            let py_segs = segs[cu].as_arr().unwrap();
            assert_eq!(py_segs.len(), s.segments[cu].len(), "{ctx} cu={cu}");
            for (g, pg) in s.segments[cu].iter().zip(py_segs) {
                assert_eq!(g.tile, pg.u("tile").unwrap(), "{ctx} cu={cu}");
                assert_eq!(g.k_start, pg.u("k_start").unwrap(), "{ctx}");
                assert_eq!(g.k_len, pg.u("k_len").unwrap(), "{ctx}");
                assert_eq!(g.direct, pg.b("direct").unwrap(), "{ctx}");
                // python encodes direct slots as -1; rust keeps 0
                if !g.direct {
                    assert_eq!(
                        g.slot as i64,
                        pg.i("slot").unwrap(),
                        "{ctx} cu={cu}"
                    );
                }
            }
        }

        let splits = case.arr("split_tiles").unwrap();
        assert_eq!(splits.len(), s.split_tiles.len(), "{ctx}");
        for (st, ps) in s.split_tiles.iter().zip(splits) {
            assert_eq!(st.tile, ps.u("tile").unwrap(), "{ctx}");
            let pcs = ps.arr("contributors").unwrap();
            assert_eq!(pcs.len(), st.contributors.len(), "{ctx}");
            for (c, pc) in st.contributors.iter().zip(pcs) {
                assert_eq!(c.cu, pc.u("cu").unwrap(), "{ctx}");
                assert_eq!(c.slot, pc.u("slot").unwrap(), "{ctx}");
                assert_eq!(c.k_start, pc.u("k_start").unwrap(), "{ctx}");
                assert_eq!(c.k_len, pc.u("k_len").unwrap(), "{ctx}");
            }
        }
    }
}
