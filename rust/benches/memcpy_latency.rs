//! MEMCPY — the hipMemcpy latency study (the report's future-work item:
//! "take a deeper look into different strategies to reduce the latency
//! in hipMemcpy").
//!
//! Sections: (1) modeled PCIe transfer curves (pageable vs pinned),
//! (2) the chunked-overlap strategy crossover, (3) measured host↔device
//! marshalling on the real CPU-PJRT path (literal creation + readback —
//! this testbed's analogue of hipMemcpy).
//!
//! Run: `cargo bench --bench memcpy_latency`

use std::path::Path;

use streamk::bench::{self, Table};
use streamk::gpu_sim::xfer::{
    gemm_d2h_bytes, gemm_h2d_bytes, PCIE4_PAGEABLE, PCIE4_PINNED,
};
use streamk::prop::Rng;
use streamk::runtime::{Engine, Manifest};

fn main() {
    println!("== 1. modeled transfer curves ==\n");
    let mut t = Table::new(&[
        "bytes", "pageable ms", "pinned ms", "pageable GB/s", "pinned GB/s",
    ]);
    for shift in [10usize, 14, 18, 22, 26, 28, 30] {
        let bytes = 1usize << shift;
        t.row(&[
            format!("2^{shift}"),
            format!("{:.4}", PCIE4_PAGEABLE.time(bytes) * 1e3),
            format!("{:.4}", PCIE4_PINNED.time(bytes) * 1e3),
            format!("{:.2}", PCIE4_PAGEABLE.effective_bw(bytes) / 1e9),
            format!("{:.2}", PCIE4_PINNED.effective_bw(bytes) / 1e9),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape: latency-limited below ~1 MiB (effective \
         bandwidth collapses), pinned ≈ 2x pageable at size.\n"
    );

    println!("== 2. chunked overlap strategy (Table-1 baseline operands) ==\n");
    let bytes = gemm_h2d_bytes(3840, 4096, 4096, 2);
    let compute_s = 1.446e-3; // the paper's measured kernel time
    let mut t = Table::new(&["chunks", "total ms", "vs serial"]);
    let serial = PCIE4_PAGEABLE.time(bytes) + compute_s;
    for chunks in [1usize, 2, 4, 8, 16, 64, 256] {
        let ov = PCIE4_PAGEABLE.overlapped_time(bytes, chunks, compute_s);
        t.row(&[
            chunks.to_string(),
            format!("{:.3}", ov * 1e3),
            format!("{:.2}x", serial / ov),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape: overlap wins until per-chunk latency dominates \
         (the U-curve) — the strategy the report proposed to explore.\n"
    );

    println!("== 3. measured PJRT host↔device marshalling ==\n");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Err(_) => println!("(skipped: run `make artifacts`)"),
        Ok(manifest) => {
            let engine = Engine::new(manifest).expect("pjrt");
            let name = "gemm_streamk_nopad_f32_128x128x128_cu8";
            engine.warmup(&[name]).unwrap();
            let mut rng = Rng::new(9);
            let a = rng.normal_f32_vec(128 * 128);
            let b = rng.normal_f32_vec(128 * 128);
            // Full request = h2d + execute + d2h; the artifact's
            // execute_s isolates device time, the difference is the
            // marshalling cost this bench tracks.
            let stats = bench::bench(2, 10, || {
                bench::keep(engine.run_f32(name, &[&a, &b]).unwrap());
            });
            let (_, exec_stats) = engine.run_f32(name, &[&a, &b]).unwrap();
            let h2d = gemm_h2d_bytes(128, 128, 128, 4);
            let d2h = gemm_d2h_bytes(128, 128, 4);
            println!(
                "request {:.3} ms total; execute {:.3} ms; marshalling \
                 ≈ {:.3} ms for {} B h2d + {} B d2h",
                stats.mean * 1e3,
                exec_stats.execute_s * 1e3,
                (stats.mean - exec_stats.execute_s).max(0.0) * 1e3,
                h2d,
                d2h
            );
            println!(
                "modeled PCIe pageable for the same traffic: {:.3} ms",
                (PCIE4_PAGEABLE.time(h2d) + PCIE4_PAGEABLE.time(d2h)) * 1e3
            );
        }
    }
}
