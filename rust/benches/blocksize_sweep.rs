//! BLK — the block-size exploration the report could not complete.
//!
//! "We could not get the vast majority of block/hyperparameter
//! adjustments to compile … ~15 interdependent parameters … we did
//! successfully compile a block size to 1024, with M and N per XDL = 16,
//! but threw floating point errors during a run."
//!
//! Sections: (1) the legality matrix over the exploration grid with
//! *named* rejection reasons; (2) the report's 16x16 configuration,
//! rejected statically with the exact failure mode it hit at runtime;
//! (3) simulated performance of every legal point on the Table-1
//! baseline, showing why 128x128x64 is the single shipped config.
//!
//! Run: `cargo bench --bench blocksize_sweep`

use std::collections::BTreeMap;
use std::time::Duration;

use streamk::bench::Table;
use streamk::decomp::params::{check, exploration_grid, KernelParams};
use streamk::decomp::{build_schedule, BlockShape, GemmShape};
use streamk::exec::Stopwatch;
use streamk::gpu_sim::{gemm, Device, DeviceKind};

/// Wall budget for the whole section-3 sweep — the paper's sweep "got
/// stuck" on pathological parameter points; ours checks the clock
/// *before* each point, so one slow point can overshoot by at most its
/// own runtime and everything after it is skipped with a diagnostic
/// instead of hanging the sweep. Completed measurements are always
/// kept (a slow host must not change which configs get ranked).
const SWEEP_BUDGET: Duration = Duration::from_secs(60);
/// A single point slower than this gets called out by name — the
/// diagnostic the paper's runs never produced.
const SLOW_POINT: Duration = Duration::from_secs(5);

fn main() {
    println!("== 1. legality over the exploration grid ==\n");
    let grid = exploration_grid();
    let mut reasons: BTreeMap<String, usize> = BTreeMap::new();
    let mut legal: Vec<KernelParams> = Vec::new();
    for p in &grid {
        match check(p) {
            Ok(()) => legal.push(*p),
            Err(errs) => {
                for e in errs {
                    *reasons.entry(e.label().to_string()).or_default() += 1;
                }
            }
        }
    }
    println!(
        "{} / {} parameter points legal ({:.0}% rejected — the report: \
         'the vast majority … fail to compile')\n",
        legal.len(),
        grid.len(),
        100.0 * (grid.len() - legal.len()) as f64 / grid.len() as f64
    );
    let mut t = Table::new(&["rejection reason", "points"]);
    for (reason, count) in &reasons {
        t.row(&[reason.clone(), count.to_string()]);
    }
    t.print();

    println!("\n== 2. the report's 1024-thread / 16x16-per-XDL config ==\n");
    let report_cfg = KernelParams::new(BlockShape::new(16, 16, 64), 4);
    match check(&report_cfg) {
        Ok(()) => panic!("must be rejected"),
        Err(errs) => {
            println!("block 16x16x64 → rejected statically:");
            for e in errs {
                println!("  - {e}");
            }
            println!(
                "\n(CK accepted this template and crashed with floating \
                 point errors at runtime — the legality model turns that \
                 runtime failure into a compile-time reason)"
            );
        }
    }

    println!("\n== 3. simulated perf of every legal point (Table-1 baseline) ==\n");
    let dev = Device::preset(DeviceKind::Mi200);
    let shape = GemmShape::new(3840, 4096, 4096);
    let sweep_sw = Stopwatch::start();
    let mut skipped = 0usize;
    let mut rows: Vec<(f64, KernelParams, f64, f64)> = Vec::new();
    for p in &legal {
        // Budget guard *before* each point: once the sweep budget is
        // spent, remaining points print a diagnostic and are skipped —
        // the paper's "process getting stuck" symptom, made impossible.
        if sweep_sw.elapsed() > SWEEP_BUDGET {
            skipped += 1;
            continue;
        }
        let point_sw = Stopwatch::start();
        let sched = build_schedule(shape, p.block, dev.num_cus).unwrap();
        let r = gemm::simulate_streamk(&dev, &sched, p.bytes_per_elem());
        if point_sw.elapsed() > SLOW_POINT {
            eprintln!(
                "  [slow] point {}x{}x{} dbuf={} took {:.2}s (> {:?}) — \
                 result kept, but this point is pathological",
                p.block.bm,
                p.block.bn,
                p.block.bk,
                p.double_buffer,
                point_sw.elapsed_secs(),
                SLOW_POINT,
            );
        }
        rows.push((r.total_s, *p, r.tflops, r.utilization));
    }
    if skipped > 0 {
        println!(
            "({skipped} of {} legal points skipped: sweep exceeded its \
             {SWEEP_BUDGET:?} budget after {:.2}s — diagnostic instead of \
             a hang)\n",
            legal.len(),
            sweep_sw.elapsed_secs()
        );
    }
    assert!(
        !rows.is_empty(),
        "the sweep budget expired before the first point — raise SWEEP_BUDGET"
    );
    rows.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut t = Table::new(&[
        "block", "dbuf", "VMEM KiB", "MXU util", "sim ms", "sim TFLOP/s",
    ]);
    for (time, p, tflops, _util) in rows.iter().take(12) {
        t.row(&[
            format!("{}x{}x{}", p.block.bm, p.block.bn, p.block.bk),
            p.double_buffer.to_string(),
            format!("{:.0}", p.vmem_bytes() as f64 / 1024.0),
            format!("{:.0}%", p.mxu_utilization() * 100.0),
            format!("{:.3}", time * 1e3),
            format!("{tflops:.1}"),
        ]);
    }
    t.print();
    let best = rows.first().unwrap();
    println!(
        "\nbest legal point: {}x{}x{} — the shipped single config \
         (128x128x64) is within {:.1}% of it; one configuration per \
         precision is the Stream-K storage claim.",
        best.1.block.bm,
        best.1.block.bn,
        best.1.block.bk,
        {
            let shipped = rows
                .iter()
                .find(|(_, p, ..)| {
                    p.block == BlockShape::new(128, 128, 64) && p.double_buffer
                })
                .expect("shipped config is legal");
            (shipped.0 / best.0 - 1.0) * 100.0
        }
    );
}
