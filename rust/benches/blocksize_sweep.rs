//! BLK — the block-size exploration the report could not complete.
//!
//! "We could not get the vast majority of block/hyperparameter
//! adjustments to compile … ~15 interdependent parameters … we did
//! successfully compile a block size to 1024, with M and N per XDL = 16,
//! but threw floating point errors during a run."
//!
//! Sections: (1) the legality matrix over the exploration grid with
//! *named* rejection reasons; (2) the report's 16x16 configuration,
//! rejected statically with the exact failure mode it hit at runtime;
//! (3) simulated performance of every legal point on the Table-1
//! baseline, showing why 128x128x64 is the single shipped config.
//!
//! Run: `cargo bench --bench blocksize_sweep`

use std::collections::BTreeMap;

use streamk::bench::Table;
use streamk::decomp::params::{check, exploration_grid, Illegal, KernelParams};
use streamk::decomp::{build_schedule, BlockShape, GemmShape};
use streamk::gpu_sim::{gemm, Device, DeviceKind};

fn main() {
    println!("== 1. legality over the exploration grid ==\n");
    let grid = exploration_grid();
    let mut reasons: BTreeMap<String, usize> = BTreeMap::new();
    let mut legal: Vec<KernelParams> = Vec::new();
    for p in &grid {
        match check(p) {
            Ok(()) => legal.push(*p),
            Err(errs) => {
                for e in errs {
                    let key = match e {
                        Illegal::ZeroDim => "zero block dimension",
                        Illegal::VmemOverflow { .. } => "VMEM overflow",
                        Illegal::LaneMisaligned { .. } => {
                            "minor dim not lane-aligned (128)"
                        }
                        Illegal::SublaneMisaligned { .. } => {
                            "second-minor dim not sublane-aligned (8)"
                        }
                        Illegal::KpackMisaligned { .. } => "kpack misaligned",
                        Illegal::MxuUnderfilled { .. } => {
                            "MXU utilization below 25% floor"
                        }
                        Illegal::MxuTileMismatch { .. } => {
                            "block smaller than MXU tile (CK 16x16-per-XDL FP-error mode)"
                        }
                    };
                    *reasons.entry(key.to_string()).or_default() += 1;
                }
            }
        }
    }
    println!(
        "{} / {} parameter points legal ({:.0}% rejected — the report: \
         'the vast majority … fail to compile')\n",
        legal.len(),
        grid.len(),
        100.0 * (grid.len() - legal.len()) as f64 / grid.len() as f64
    );
    let mut t = Table::new(&["rejection reason", "points"]);
    for (reason, count) in &reasons {
        t.row(&[reason.clone(), count.to_string()]);
    }
    t.print();

    println!("\n== 2. the report's 1024-thread / 16x16-per-XDL config ==\n");
    let report_cfg = KernelParams::new(BlockShape::new(16, 16, 64), 4);
    match check(&report_cfg) {
        Ok(()) => panic!("must be rejected"),
        Err(errs) => {
            println!("block 16x16x64 → rejected statically:");
            for e in errs {
                println!("  - {e}");
            }
            println!(
                "\n(CK accepted this template and crashed with floating \
                 point errors at runtime — the legality model turns that \
                 runtime failure into a compile-time reason)"
            );
        }
    }

    println!("\n== 3. simulated perf of every legal point (Table-1 baseline) ==\n");
    let dev = Device::preset(DeviceKind::Mi200);
    let shape = GemmShape::new(3840, 4096, 4096);
    let mut rows: Vec<(f64, KernelParams, f64, f64)> = legal
        .iter()
        .map(|p| {
            let sched =
                build_schedule(shape, p.block, dev.num_cus).unwrap();
            let r = gemm::simulate_streamk(&dev, &sched, p.bytes_per_elem);
            (r.total_s, *p, r.tflops, r.utilization)
        })
        .collect();
    rows.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut t = Table::new(&[
        "block", "dbuf", "VMEM KiB", "MXU util", "sim ms", "sim TFLOP/s",
    ]);
    for (time, p, tflops, _util) in rows.iter().take(12) {
        t.row(&[
            format!("{}x{}x{}", p.block.bm, p.block.bn, p.block.bk),
            p.double_buffer.to_string(),
            format!("{:.0}", p.vmem_bytes() as f64 / 1024.0),
            format!("{:.0}%", p.mxu_utilization() * 100.0),
            format!("{:.3}", time * 1e3),
            format!("{tflops:.1}"),
        ]);
    }
    t.print();
    let best = rows.first().unwrap();
    println!(
        "\nbest legal point: {}x{}x{} — the shipped single config \
         (128x128x64) is within {:.1}% of it; one configuration per \
         precision is the Stream-K storage claim.",
        best.1.block.bm,
        best.1.block.bn,
        best.1.block.bk,
        {
            let shipped = rows
                .iter()
                .find(|(_, p, ..)| {
                    p.block == BlockShape::new(128, 128, 64) && p.double_buffer
                })
                .expect("shipped config is legal");
            (shipped.0 / best.0 - 1.0) * 100.0
        }
    );
}
