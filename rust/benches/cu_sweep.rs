//! CUBUG + MEDBUG — the compute-unit bug study.
//!
//! The report: `./bin/example_gemm_xdl_streamk 1 2 1 ... 120` worked, but
//! any explicit sub-maximal CU count corrupted output; the cause was
//! traced as far as the Block2CTile mapping but never isolated. And
//! 480x512x512 produced "99% errors" at every setting.
//!
//! Three sections:
//!  1. injected CK-style bug vs our fixed mapping, error rate per CU
//!     count (rust schedule executor, real numerics);
//!  2. the medium-matrix (fixup-overflow) bug class;
//!  3. PJRT validation of the real Stream-K artifacts at every compiled
//!     CU count + simulated scaling curve.
//!
//! Run: `cargo bench --bench cu_sweep`

use std::path::Path;

use streamk::bench::Table;
use streamk::decomp::{build_schedule, BlockShape, GemmShape};
use streamk::faults::{
    bugs::{shape_triggers_fixup_overflow, Fault, FaultyExecutor},
    error_rate, naive_gemm, Matrix,
};
use streamk::gpu_sim::{gemm, Device, DeviceKind};
use streamk::prop::Rng;
use streamk::runtime::{Engine, Manifest};

fn main() {
    let mut rng = Rng::new(42);

    println!("== 1. the compute-unit bug (Block2CTile mis-mapping) ==\n");
    // 144 tiles (> 120) so the affine mis-mapping walks off the raster
    // at every sub-maximal CU count, like the report observed.
    let (m, n, k) = (192, 192, 64);
    let blk = BlockShape::new(16, 16, 8);
    let a = Matrix::random(m, k, &mut rng);
    let b = Matrix::random(k, n, &mut rng);
    let want = naive_gemm(&a, &b);
    let mut t = Table::new(&["CUs", "buggy errors", "fixed errors", "paper"]);
    for cus in [1usize, 15, 30, 60, 90, 119, 120] {
        let sched =
            build_schedule(GemmShape::new(m, n, k), blk, cus).unwrap();
        let buggy = FaultyExecutor::new(Fault::CuMapping { hw_cus: 120 })
            .run(&a, &b, &sched);
        let fixed = FaultyExecutor::new(Fault::None).run(&a, &b, &sched);
        let eb = error_rate(&buggy.data, &want.data, 1e-3);
        let ef = error_rate(&fixed.data, &want.data, 1e-3);
        assert_eq!(ef.bad, 0, "fixed path must be exact at cus={cus}");
        if cus == 120 {
            assert_eq!(eb.bad, 0, "full-CU run must be clean (the report)");
        } else {
            assert!(eb.bad > 0, "sub-maximal cus={cus} must corrupt");
        }
        t.row(&[
            cus.to_string(),
            format!("{:.1}%", eb.rate * 100.0),
            format!("{:.1}%", ef.rate * 100.0),
            if cus == 120 { "works".into() } else { "errors".to_string() },
        ]);
    }
    t.print();
    println!(
        "\nreproduced: the injected CK-style mapping is clean ONLY at the \
         full 120 CUs; our schedule is exact at every CU count.\n"
    );

    println!("== 2. the medium-matrix bug (480x512x512 → 99% errors) ==\n");
    // Scaled 1:8 in every dimension incl. blocks → same schedule shape.
    let (m, n, k) = (60, 64, 64);
    let blk2 = BlockShape::new(16, 16, 2);
    let a = Matrix::random(m, k, &mut rng);
    let b = Matrix::random(k, n, &mut rng);
    let want = naive_gemm(&a, &b);
    let mut t = Table::new(&["shape", "variant", "element errors", "paper"]);
    let sched = build_schedule(GemmShape::new(m, n, k), blk2, 120).unwrap();
    assert!(shape_triggers_fixup_overflow(&sched));
    for (variant, fault) in
        [("CK-style fixup", Fault::FixupOverflow), ("ours", Fault::None)]
    {
        let got = FaultyExecutor::new(fault).run(&a, &b, &sched);
        let e = error_rate(&got.data, &want.data, 1e-3);
        t.row(&[
            "480x512x512 (1:8)".into(),
            variant.into(),
            format!("{:.1}%", e.rate * 100.0),
            if matches!(fault, Fault::FixupOverflow) {
                "99% errors".into()
            } else {
                "n/a (fixed)".to_string()
            },
        ]);
    }
    // A Table-1 shape whose split tiles never exceed 2 contributors
    // stays silent under the same bug — why CK's other sizes "worked".
    let quiet = build_schedule(
        GemmShape::new(96, 96, 64),
        BlockShape::new(16, 16, 8),
        4,
    )
    .unwrap();
    if !shape_triggers_fixup_overflow(&quiet) {
        let a2 = Matrix::random(96, 64, &mut rng);
        let b2 = Matrix::random(64, 96, &mut rng);
        let got = FaultyExecutor::new(Fault::FixupOverflow).run(
            &a2, &b2, &quiet,
        );
        let e = error_rate(&got.data, &naive_gemm(&a2, &b2).data, 1e-3);
        t.row(&[
            "96x96x64 p=4".into(),
            "CK-style fixup".into(),
            format!("{:.1}%", e.rate * 100.0),
            "silent on other shapes".into(),
        ]);
    }
    t.print();

    println!("\n== 3. real artifacts across CU counts (PJRT) ==\n");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Err(_) => println!("(skipped: run `make artifacts`)"),
        Ok(manifest) => {
            let engine = Engine::new(manifest).expect("pjrt");
            let (m, n, k) = (480, 512, 512);
            let a = rng.normal_f32_vec(m * k);
            let b = rng.normal_f32_vec(k * n);
            let (rv, _) = engine
                .run_f32(&format!("gemm_ref_nopad_f32_{m}x{n}x{k}"), &[&a, &b])
                .unwrap();
            let mut t =
                Table::new(&["CUs", "errors", "exec ms", "sim MI200 ms"]);
            let dev120 = Device::preset(DeviceKind::Mi200);
            for cus in [1usize, 30, 60, 119, 120] {
                let name = if cus == 120 {
                    format!("gemm_streamk_nopad_f32_{m}x{n}x{k}")
                } else {
                    format!("gemm_streamk_nopad_f32_{m}x{n}x{k}_cu{cus}")
                };
                let (sv, stats) = engine.run_f32(&name, &[&a, &b]).unwrap();
                let e = error_rate(&sv[0], &rv[0], 1e-3);
                assert_eq!(e.bad, 0, "cus={cus}: {e:?}");
                let sched = build_schedule(
                    GemmShape::new(m, n, k),
                    BlockShape::default(),
                    cus,
                )
                .unwrap();
                let sim = gemm::simulate_streamk(
                    &dev120.clone().with_cus(cus),
                    &sched,
                    4,
                );
                t.row(&[
                    cus.to_string(),
                    format!("{:.1}%", e.rate * 100.0),
                    format!("{:.2}", stats.execute_s * 1e3),
                    format!("{:.4}", sim.total_s * 1e3),
                ]);
            }
            t.print();
            println!(
                "\nreproduced: correct output at EVERY CU count (the CK \
                 branch only worked at the default/full count), and the \
                 simulated MI200 time scales down with CUs."
            );
        }
    }
}
