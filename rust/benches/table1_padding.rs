//! TAB1 — regenerates Table 1: padded vs no-padding Stream-K across the
//! paper's matrix sizes, reporting ms / TFLOPs / GB/s and the no-padding
//! improvement, exactly the paper's rows.
//!
//! Two sections:
//!  1. **measured** — the AOT Pallas artifacts on CPU PJRT, scaled shapes
//!     (the default artifact set keeps XLA-CPU time laptop-scale; the
//!     `--full` artifacts add the exact 3840x4096x4096 rows when built
//!     with `python -m compile.aot --full`).
//!  2. **simulated MI200** — the analytical padding cost on the modeled
//!     device at the paper's exact shapes, for direct comparison with
//!     Table 1's absolute numbers.
//!
//! Run: `cargo bench --bench table1_padding`

use std::path::Path;

use streamk::bench::{self, Table};
use streamk::decomp::{BlockShape, GemmShape};
use streamk::faults::error_rate;
use streamk::gpu_sim::{gemm, Device, DeviceKind};
use streamk::prop::Rng;
use streamk::runtime::{Engine, Manifest};

const ITERS: usize = 7;

fn main() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(manifest) = Manifest::load(&dir) else {
        eprintln!("run `make artifacts` first");
        std::process::exit(1);
    };
    let engine = Engine::new(manifest).expect("pjrt");
    let mut rng = Rng::new(1337);

    println!("== Table 1 (measured, CPU PJRT, scaled shapes) ==\n");
    let mut t = Table::new(&[
        "Test", "ms", "TFLOPs", "GB/s", "M", "N", "K",
    ]);
    let mut improvements = Vec::new();

    // Every table1 streamk shape present in the manifest, nopad+pad pairs.
    let shapes: Vec<(usize, usize, usize)> = {
        let mut v: Vec<_> = engine
            .manifest()
            .artifacts
            .iter()
            .filter(|a| a.experiment == "table1" && a.algo == "streamk")
            .map(|a| (a.m, a.n, a.k))
            .collect();
        v.sort();
        v.dedup();
        v
    };

    for (m, n, k) in shapes {
        let shape = GemmShape::new(m, n, k);
        let a = rng.normal_f32_vec(m * k);
        let b = rng.normal_f32_vec(k * n);
        let mut row_times = Vec::new();
        for (label, pad) in [("", "physical"), (" (NP)", "none")] {
            let name = format!(
                "gemm_streamk_{}_f32_{m}x{n}x{k}",
                if pad == "none" { "nopad" } else { "pad" }
            );
            engine.warmup(&[&name]).expect("warmup");
            let stats = bench::bench(1, ITERS, || {
                bench::keep(engine.run_f32(&name, &[&a, &b]).expect("run"));
            });
            let bytes = 4.0 * (m * k + k * n + m * n) as f64;
            t.row(&[
                format!("{m}x{n}x{k}{label}"),
                bench::fmt_ms(stats.min),
                bench::fmt_tflops(shape.flops(), stats.min),
                bench::fmt_gbps(bytes, stats.min),
                m.to_string(),
                n.to_string(),
                k.to_string(),
            ]);
            // min-of-N: the report disregarded "suspicious results
            // during times of heavy shared use of the cluster"; min is
            // the principled version of that on a noisy shared box.
            row_times.push(stats.min);
        }
        let imp = row_times[0] / row_times[1] - 1.0;
        improvements.push(imp);
        t.row(&[
            "No Padding Improvement".into(),
            format!("{:.1}%", imp * 100.0),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
            String::new(),
        ]);

        // Correctness gate per shape — the paper's medium matrix showed
        // 99% errors in CK; ours must be exact under both policies.
        let pad_name = format!("gemm_streamk_pad_f32_{m}x{n}x{k}");
        let nopad_name = format!("gemm_streamk_nopad_f32_{m}x{n}x{k}");
        let ref_name = format!("gemm_ref_nopad_f32_{m}x{n}x{k}");
        let (pv, _) = engine.run_f32(&pad_name, &[&a, &b]).unwrap();
        let (nv, _) = engine.run_f32(&nopad_name, &[&a, &b]).unwrap();
        let (rv, _) = engine.run_f32(&ref_name, &[&a, &b]).unwrap();
        let ep = error_rate(&pv[0], &rv[0], 1e-3);
        let en = error_rate(&nv[0], &rv[0], 1e-3);
        assert!(
            ep.passed() && en.passed(),
            "{m}x{n}x{k}: pad {:.1}% / nopad {:.1}% errors (paper's \
             medium-matrix bug class — must be 0 here)",
            ep.rate * 100.0,
            en.rate * 100.0
        );
    }
    let avg = improvements.iter().sum::<f64>() / improvements.len() as f64;
    t.row(&[
        "Average No Padding Improvement".into(),
        format!("{:.1}%", avg * 100.0),
        String::new(), String::new(), String::new(), String::new(), String::new(),
    ]);
    t.print();
    println!(
        "\n(paper: 0.2%–3% per shape, 0.6% average on MI200; CPU-PJRT \
         magnifies the padding memcpy so larger percentages are expected, \
         the *sign and ordering* are the reproduced result)\n"
    );
    println!("correctness: all shapes 0% element errors under both \
              policies (CK's 480x512x512 showed 99% errors)\n");

    println!("== Table 1 (simulated MI200, paper's exact shapes) ==\n");
    let dev = Device::preset(DeviceKind::Mi200);
    let mut t = Table::new(&["Test", "ms", "TFLOPs", "M", "N", "K"]);
    for (m, n, k) in [
        (3840usize, 4096usize, 4096usize),
        (3, 9, 9),
        (1920, 2000, 2000),
        (480, 512, 512),
    ] {
        let shape = GemmShape::new(m, n, k);
        let block = BlockShape::default().effective(shape);
        for (label, padded) in [("", true), (" (NP)", false)] {
            let sched =
                streamk::decomp::build_schedule(shape, block, dev.num_cus)
                    .unwrap();
            let mut r = gemm::simulate_streamk(&dev, &sched, 4);
            if padded {
                // physical padding adds the pad memcpy of A and B plus
                // inflated streaming reads — model as extra HBM time.
                let mp = m.div_ceil(block.bm) * block.bm;
                let np_ = n.div_ceil(block.bn) * block.bn;
                let kp = k.div_ceil(block.bk) * block.bk;
                let extra_bytes =
                    4.0 * ((mp * kp + kp * np_) + (mp * kp - m * k) + (kp * np_ - k * n)) as f64;
                r.total_s += extra_bytes / dev.hbm_bw;
            }
            t.row(&[
                format!("{m}x{n}x{k}{label}"),
                format!("{:.3}", r.total_s * 1e3),
                format!("{:.2}", shape.flops() as f64 / r.total_s / 1e12),
                m.to_string(),
                n.to_string(),
                k.to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "\npaper's measured row (baseline): 1.446 ms / 89.07 TFLOPs padded, \
         1.443 ms / 89.26 TFLOPs unpadded (0.2%)"
    );
}
