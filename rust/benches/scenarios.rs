//! SCENARIOS — the adversarial-scenario fleet as CI gates.
//!
//! Every scenario in [`streamk::bench::workload::catalogue`] runs
//! open-loop through the churn-capable simulator and must hold its SLO
//! rules while conserving every request (served + shed + dropped =
//! offered) and never serving a corrupted result. The sections:
//!
//! 1. flash-crowd      — diurnal load with a 10× mid-trace spike
//! 2. drifting-hotset  — power-law shape popularity, rotating hot set
//! 3. device-churn     — fastest device leaves; warm joiner replaces it
//! 4. slow-node        — silent 0.3× decay the re-tune loop must chase
//! 5. fault-injection  — corrupted results detected and re-placed
//! 6. warm-vs-cold     — churn control arm: cold joiner converges later
//!
//! Run: `cargo bench --bench scenarios`
//! CI smoke: `cargo bench --bench scenarios -- --test`
//! Rows append to `BENCH_scenarios.json` (one JSON object per run).

use streamk::bench::workload::{catalogue, scenario, Scenario};
use streamk::bench::Table;
use streamk::fleet::{run_scenario, ScenarioReport, ScenarioRunOptions};

/// The gates every scenario must clear regardless of its script.
fn gate(sc: &Scenario, r: &ScenarioReport) {
    assert!(
        r.conserved(),
        "{}: request conservation violated: served {} + shed {} + \
         dropped {} != offered {}",
        sc.name,
        r.served,
        r.shed,
        r.dropped,
        r.requests,
    );
    assert_eq!(
        r.wrong_results, 0,
        "{}: {} corrupted result(s) reached a client",
        sc.name, r.wrong_results
    );
    assert!(r.served > 0, "{}: nothing served", sc.name);
    assert!(
        r.breaches.is_empty(),
        "{}: SLO breached ({}): {:?}",
        sc.name,
        sc.slo,
        r.breaches
    );
    assert!(
        r.shed_rate().is_finite() && r.throughput_tflops().is_finite(),
        "{}: non-finite report rates",
        sc.name
    );
}

fn main() {
    // `cargo bench --bench scenarios -- --test` forwards `--test`;
    // cargo itself may inject `--bench`, ignored (harness = false).
    let quick = std::env::args().skip(1).any(|a| a == "--test");
    println!(
        "== adversarial scenario fleet ({} mode) ==",
        if quick { "smoke" } else { "full" }
    );

    let mut table = Table::new(&[
        "scenario", "req", "served", "shed %", "requeued", "faults",
        "quar", "p99 ms", "TFLOP/s", "slo",
    ]);
    let mut churn_warm: Option<ScenarioReport> = None;

    for (i, sc) in catalogue().iter().enumerate() {
        // Smoke mode offers ~40% of the scripted load (floored so every
        // scripted event still lands inside the trace with room to
        // observe its aftermath).
        let requests =
            if quick { Some((sc.requests * 2 / 5).max(140)) } else { None };
        println!("\n== {}. {} ==\n   {}", i + 1, sc.name, sc.about);
        let r = run_scenario(
            sc,
            &ScenarioRunOptions { requests, cold_joins: false },
        );
        println!("   {}", r.summary());
        gate(sc, &r);

        match sc.name {
            "flash-crowd" => {
                // The spike must actually stress admission: either the
                // bounded queues shed or everything still completed.
                assert!(
                    r.shed > 0 || r.served == r.requests as u64,
                    "flash-crowd: spike left requests unaccounted"
                );
            }
            "drifting-hotset" => {
                // Rotations force misses on the new hot bucket; the
                // inline tune path must have fired.
                assert!(
                    r.tunes_on_miss > 0,
                    "drifting-hotset: hot-set rotation never missed \
                     the cache"
                );
            }
            "device-churn" => {
                assert_eq!(r.leaves, 1, "device-churn: scripted leave");
                assert!(
                    r.requeued > 0,
                    "device-churn: in-flight work was not re-placed"
                );
                let j = r
                    .joins
                    .first()
                    .expect("device-churn: scripted join missing");
                assert!(j.warm && j.seeded > 0,
                        "device-churn: joiner must be warm-seeded");
                assert!(
                    j.requests_to_converge.is_some(),
                    "device-churn: warm joiner never converged"
                );
                churn_warm = Some(r.clone());
            }
            "slow-node" => {
                assert!(
                    r.retune_convergence_s.is_some(),
                    "slow-node: drift re-tune loop never recovered \
                     the degraded device"
                );
                assert!(
                    r.revalidations > 0,
                    "slow-node: degradation tripped no re-validation"
                );
            }
            "fault-injection" => {
                assert!(
                    r.faults_detected > 0,
                    "fault-injection: no fault was ever detected"
                );
                assert!(
                    r.quarantined >= 1,
                    "fault-injection: no faulty device was quarantined"
                );
                assert!(
                    r.requeued > 0,
                    "fault-injection: detected faults must re-place"
                );
            }
            other => panic!("unknown catalogue scenario '{other}'"),
        }

        table.row(&[
            r.name.clone(),
            r.requests.to_string(),
            r.served.to_string(),
            format!("{:.1}", r.shed_rate() * 100.0),
            r.requeued.to_string(),
            r.faults_detected.to_string(),
            r.quarantined.to_string(),
            format!("{:.3}", r.latency_p99_ms),
            format!("{:.2}", r.throughput_tflops()),
            "pass".into(),
        ]);
        streamk::bench::dump_json("BENCH_scenarios.json", r.to_json());
    }

    // 6. Control arm: re-run device-churn with the cache transfer
    // disabled. The cold joiner must tune more and converge later than
    // the warm one — the cross-device cache-transfer acceptance gate.
    println!("\n== 6. warm-vs-cold joiner (cache-transfer control) ==");
    let sc = scenario("device-churn").expect("catalogue has device-churn");
    let requests =
        if quick { Some((sc.requests * 2 / 5).max(140)) } else { None };
    let cold = run_scenario(
        &sc,
        &ScenarioRunOptions { requests, cold_joins: true },
    );
    println!("   cold: {}", cold.summary());
    gate(&sc, &cold);
    let warm = churn_warm.expect("device-churn ran above");
    let cj = cold.joins.first().expect("cold joiner missing");
    let wj = warm.joins.first().expect("warm joiner missing");
    assert!(!cj.warm && cj.seeded == 0, "control arm must join cold");
    assert!(
        cold.tunes_on_miss > warm.tunes_on_miss,
        "cold joiner must tune from scratch: cold {} vs warm {} misses",
        cold.tunes_on_miss,
        warm.tunes_on_miss
    );
    let w = wj.requests_to_converge.expect("warm joiner converged above");
    match cj.requests_to_converge {
        // Cold converging strictly later (or never) is the acceptance
        // criterion for seeding the joiner from a peer's fingerprint.
        Some(c) => assert!(
            w < c,
            "warm joiner must converge first: warm {w} vs cold {c}"
        ),
        None => {}
    }
    println!(
        "   warm converged after {w} requests ({} seeded entries); \
         cold after {} ({} extra inline tunes)",
        wj.seeded,
        cj.requests_to_converge
            .map(|c| c.to_string())
            .unwrap_or_else(|| "never".into()),
        cold.tunes_on_miss - warm.tunes_on_miss,
    );

    println!();
    table.print();
    println!(
        "\nscenarios OK: {} catalogue scenarios + warm-vs-cold control \
         held their SLOs with zero wrong results",
        catalogue().len()
    );
}
