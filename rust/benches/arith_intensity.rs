//! AI — reproduces the report's arithmetic-intensity measurement
//! ("we measured the arithmetic intensity of 1337, indicating a large
//! compute bottleneck") and generalizes it into the roofline table.
//!
//! Run: `cargo bench --bench arith_intensity`

use streamk::bench::Table;
use streamk::decomp::intensity::{
    arithmetic_intensity, operand_intensity, MI200,
};
use streamk::decomp::GemmShape;

fn main() {
    println!("== the report's 1337 ==\n");
    let shape = GemmShape::new(3840, 4096, 4096);
    let ai_fp16 = arithmetic_intensity(shape, 2);
    println!(
        "Table-1 baseline 3840x4096x4096 @ fp16, full A+B+C traffic: \
         AI = {ai_fp16:.1} FLOP/byte"
    );
    println!("report measured: 1337 (matches within {:.2}%)\n",
             ((ai_fp16 - 1337.0) / 1337.0 * 100.0).abs());
    assert!((ai_fp16 - 1337.0).abs() / 1337.0 < 0.01);

    println!("== AI / roofline across the experiment shapes ==\n");
    let mut t = Table::new(&[
        "shape", "bytes/elem", "AI", "AI (A+B only)", "ridge", "verdict",
    ]);
    for (m, n, k, bpe) in [
        (3840usize, 4096usize, 4096usize, 2usize),
        (3840, 4096, 4096, 4),
        (30840, 4096, 4096, 2), // the CK example CLI shape
        (3, 9, 9, 4),
        (1920, 2000, 2000, 4),
        (480, 512, 512, 4),
        (960, 1024, 1024, 4),
        (128, 128, 128, 4),
        (256, 256, 8192, 4),   // deep-K
        (4096, 4096, 64, 4),   // shallow-K
    ] {
        let s = GemmShape::new(m, n, k);
        let ai = arithmetic_intensity(s, bpe);
        t.row(&[
            format!("{m}x{n}x{k}"),
            bpe.to_string(),
            format!("{ai:.1}"),
            format!("{:.1}", operand_intensity(s, bpe)),
            format!("{:.1}", MI200.ridge_point()),
            if MI200.compute_bound(ai) {
                format!(
                    "compute-bound ({:.0} TFLOP/s attainable)",
                    MI200.attainable(ai) / 1e12
                )
            } else {
                format!(
                    "memory-bound ({:.2} TFLOP/s attainable)",
                    MI200.attainable(ai) / 1e12
                )
            },
        ]);
    }
    t.print();
    println!(
        "\nexpected shape (paper): the large Table-1 GEMMs sit far right \
         of the MI200 ridge point ({:.1} FLOP/byte) — a 'large compute \
         bottleneck' — while the 3x9x9 row is deeply memory-bound.",
        MI200.ridge_point()
    );
}
