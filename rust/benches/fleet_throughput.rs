//! FLEET — Block2Time-guided placement vs round-robin on a
//! heterogeneous 4-device fleet, plus the online re-tuning loop.
//!
//! The acceptance demonstration for the fleet subsystem:
//! (1) on a skewed shape mix over four devices spanning a ~4× speed
//! range, completion-time-predicted placement beats round-robin
//! makespan by a wide margin; (2) the feedback loop measurably
//! tightens at least one cache entry's predicted-vs-measured drift
//! over the simulated traffic run.
//!
//! Run: `cargo bench --bench fleet_throughput`
//! CI smoke: `cargo bench --bench fleet_throughput -- --test`

use streamk::bench::workload::Arrival;
use streamk::bench::Table;
use streamk::fleet::{
    demo_fleet_devices, gen_open_trace, gen_trace, run_trace, run_trace_open,
    warm, Fleet, PlacementPolicy, ShapeMix,
};
use streamk::tuner::{Budget, StalenessPolicy, TuneOptions};

fn main() {
    // `cargo bench --bench fleet_throughput -- --test` forwards
    // `--test`; cargo itself may inject `--bench`, which is ignored
    // like every other unknown flag (harness = false).
    let quick = std::env::args().skip(1).any(|a| a == "--test");
    let (budget_ms, requests) = if quick { (50u64, 80usize) } else { (250, 400) };

    let opts = TuneOptions {
        top_k: 8,
        budget: Budget::from_millis(budget_ms),
        ..TuneOptions::default()
    };
    // High drift threshold: this bench demonstrates the *blending* half
    // of the loop, so re-tunes must not reset predictions mid-series
    // (`streamk fleet --drift-pct` exercises the re-validation half).
    let staleness = StalenessPolicy { max_drift: 10.0, ..Default::default() };
    let fleet = Fleet::new(demo_fleet_devices(), opts, staleness, 256);

    println!("== 1. the fleet ==\n");
    let mut t = Table::new(&["device", "cus", "peak TF/s", "hbm GB/s"]);
    for d in fleet.devices() {
        t.row(&[
            d.name.clone(),
            d.device().num_cus.to_string(),
            format!("{:.1}", d.device().peak_flops() / 1e12),
            format!("{:.0}", d.device().hbm_bw / 1e9),
        ]);
    }
    t.print();

    let mix = ShapeMix::skewed_default();
    let tuned = warm(&fleet, &mix.shapes());
    println!(
        "\nwarmed {tuned} (device × bucket) cache entries under a \
         {budget_ms}ms budget each\n"
    );

    let trace = gen_trace(42, requests, &mix);
    let rr = run_trace(&fleet, &trace, PlacementPolicy::RoundRobin, false);
    let b2t = run_trace(&fleet, &trace, PlacementPolicy::Block2Time, true);

    println!("== 2. placement: round-robin vs Block2Time-guided ==\n");
    let mut t = Table::new(&[
        "device", "rr reqs", "rr busy ms", "fleet reqs", "fleet busy ms",
    ]);
    for (i, d) in fleet.devices().iter().enumerate() {
        t.row(&[
            d.name.clone(),
            rr.device_requests[i].to_string(),
            format!("{:.3}", rr.device_busy_s[i] * 1e3),
            b2t.device_requests[i].to_string(),
            format!("{:.3}", b2t.device_busy_s[i] * 1e3),
        ]);
    }
    t.print();
    let speedup = rr.makespan_s / b2t.makespan_s.max(1e-12);
    println!(
        "\nmakespan: rr {:.3} ms | fleet {:.3} ms | speedup {speedup:.3}x",
        rr.makespan_s * 1e3,
        b2t.makespan_s * 1e3,
    );
    println!(
        "throughput: rr {:.2} TFLOP/s | fleet {:.2} TFLOP/s",
        rr.throughput_tflops(),
        b2t.throughput_tflops(),
    );

    // Acceptance 1: predicted placement must beat round-robin clearly.
    assert!(
        b2t.makespan_s < rr.makespan_s * 0.95,
        "fleet placement must beat round-robin: {} vs {}",
        b2t.makespan_s,
        rr.makespan_s
    );
    // Every device pulled its weight under both policies.
    assert!(
        b2t.device_requests.iter().all(|&c| c > 0),
        "a fleet member starved: {:?}",
        b2t.device_requests
    );
    assert_eq!(b2t.fallback_placements, 0, "warm caches: no fallbacks");

    println!("\n== 3. the online feedback loop ==\n");
    let mut series: Vec<_> =
        b2t.drift.iter().filter(|s| s.drifts.len() >= 3).collect();
    series.sort_by(|a, b| b.drifts[0].total_cmp(&a.drifts[0]));
    let mut t = Table::new(&[
        "device", "bucket", "obs", "first drift", "last drift",
    ]);
    for s in series.iter().take(6) {
        t.row(&[
            s.device.to_string(),
            s.bucket.clone(),
            s.drifts.len().to_string(),
            format!("{:.1}%", s.drifts[0] * 100.0),
            format!("{:.1}%", s.drifts.last().unwrap() * 100.0),
        ]);
    }
    t.print();

    // Acceptance 2: the loop measurably tightens at least one entry's
    // predicted-vs-measured drift over the run.
    let best = series
        .first()
        .expect("a repeated (device, bucket) series must exist");
    let (first, last) = (best.drifts[0], *best.drifts.last().unwrap());
    assert!(
        last < first,
        "online feedback must tighten drift: {first} -> {last}"
    );
    println!(
        "\nfeedback tightened device {} bucket {} from {:.1}% to {:.1}% \
         drift over {} observations",
        best.device,
        best.bucket,
        first * 100.0,
        last * 100.0,
        best.drifts.len(),
    );

    println!("\n== 4. open-loop arrivals (queueing delay visible) ==\n");
    // Offered load at ~1.5× round-robin's sustained closed-loop rate:
    // rr's slow devices queue throughout the run, completion-time
    // placement drains strictly faster — the queueing delay the
    // closed-loop burst comparison could never show.
    let rate = 1.5 * requests as f64 / rr.makespan_s.max(1e-12);
    let open = gen_open_trace(
        7,
        requests,
        &mix,
        Arrival::Poisson { rate },
    );
    let rr_o = run_trace_open(&fleet, &open, PlacementPolicy::RoundRobin, false);
    let b2t_o = run_trace_open(&fleet, &open, PlacementPolicy::Block2Time, false);
    let mut t = Table::new(&[
        "policy", "makespan ms", "queue mean ms", "queue p95 ms", "TFLOP/s",
    ]);
    for r in [&rr_o, &b2t_o] {
        t.row(&[
            format!("{:?}", r.policy),
            format!("{:.3}", r.makespan_s * 1e3),
            format!("{:.3}", r.queue_delay_mean_s * 1e3),
            format!("{:.3}", r.queue_delay_p95_s * 1e3),
            format!("{:.2}", r.throughput_tflops()),
        ]);
    }
    t.print();
    println!(
        "\n(Poisson {rate:.0} req/s over {requests} requests; arrivals via \
         bench::workload::Arrival)"
    );
    // Acceptance 3: with arrival times in play, placement must cut both
    // the makespan and the queueing delay.
    assert!(
        b2t_o.makespan_s < rr_o.makespan_s,
        "open loop: fleet placement must beat round-robin: {} vs {}",
        b2t_o.makespan_s,
        rr_o.makespan_s
    );
    assert!(
        b2t_o.queue_delay_mean_s < rr_o.queue_delay_mean_s,
        "open loop: placement must cut queueing delay: {} vs {}",
        b2t_o.queue_delay_mean_s,
        rr_o.queue_delay_mean_s
    );
    assert!(rr_o.queue_delay_p95_s > 0.0, "overloaded rr must queue");

    let plan = streamk::plan::global().stats();
    println!(
        "\nplan cache: {} hits / {} misses ({:.1}% hit rate) | {} builds \
         ({:.2} ms total build time)",
        plan.hits,
        plan.misses,
        plan.hit_rate() * 100.0,
        plan.builds,
        plan.build_time_s * 1e3,
    );

    println!("\nfleet_throughput OK ({speedup:.3}x over round-robin)");
}
