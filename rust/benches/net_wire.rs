//! net_wire — frame codec micro-benchmark: encode/decode throughput of
//! the TCP serving tier's wire protocol over a spread of GEMM payload
//! sizes, so protocol overhead is a measured number instead of a guess.
//!
//! Run: `cargo bench --bench net_wire`
//! CI smoke: `cargo bench --bench net_wire -- --test` — round-trips a
//! spread of shapes bit-exactly and asserts single-bit corruption
//! anywhere in a frame body yields a typed decode error (never a panic,
//! the satellite guarantee the daemon's framing layer leans on).
//! Bench rows append to `BENCH_net_wire.json`.

use streamk::bench::Table;
use streamk::exec::Stopwatch;
use streamk::net::{decode_frame, encode_request, Message, Request};
use streamk::prop::Rng;

fn gemm_frame(m: usize, n: usize, k: usize, rng: &mut Rng) -> Vec<u8> {
    encode_request(&Request::Gemm {
        id: 7,
        deadline_us: 250_000,
        m: m as u32,
        n: n as u32,
        k: k as u32,
        a: rng.normal_f32_vec(m * k),
        b: rng.normal_f32_vec(k * n),
    })
}

fn run_test() {
    let mut rng = Rng::new(0xC0DEC);
    for &(m, n, k) in
        &[(1usize, 1, 1), (8, 8, 8), (64, 64, 64), (128, 96, 32)]
    {
        let frame = gemm_frame(m, n, k, &mut rng);
        // encode_request returns the full frame; the body starts after
        // the 4-byte length prefix.
        match decode_frame(&frame[4..]).expect("roundtrip decodes") {
            Message::Request(Request::Gemm {
                m: dm, n: dn, k: dk, a, b, ..
            }) => {
                assert_eq!((dm, dn, dk), (m as u32, n as u32, k as u32));
                assert_eq!(a.len(), m * k);
                assert_eq!(b.len(), k * n);
            }
            other => panic!("decoded the wrong message: {other:?}"),
        }
    }
    // Single-bit corruption anywhere in the body must surface as a
    // typed error: header flips trip magic/version/kind checks, the
    // rest trips the FNV-1a checksum.
    let frame = gemm_frame(32, 32, 32, &mut rng);
    let body = &frame[4..];
    for i in 0..256 {
        let mut flipped = body.to_vec();
        let at = (i * 131) % flipped.len();
        flipped[at] ^= 1 << (i % 8);
        assert!(
            decode_frame(&flipped).is_err(),
            "bit flip at byte {at} went undetected"
        );
    }
    println!("net_wire codec smoke OK");
}

fn main() {
    if std::env::args().skip(1).any(|a| a == "--test") {
        run_test();
        return;
    }
    let mut rng = Rng::new(0xC0DEC);
    let mut t = Table::new(&[
        "shape", "frame KiB", "encode GB/s", "decode GB/s", "decode/s",
    ]);
    for &(m, n, k) in
        &[(16usize, 16, 16), (64, 64, 64), (128, 128, 128), (256, 256, 256)]
    {
        let frame = gemm_frame(m, n, k, &mut rng);
        let bytes = frame.len() as f64;
        let reps = ((256 << 20) as f64 / bytes).ceil() as usize;
        let reps = reps.clamp(64, 20_000);

        let a = rng.normal_f32_vec(m * k);
        let b = rng.normal_f32_vec(k * n);
        let sw = Stopwatch::start();
        for i in 0..reps {
            let f = encode_request(&Request::Gemm {
                id: i as u64,
                deadline_us: 0,
                m: m as u32,
                n: n as u32,
                k: k as u32,
                a: a.clone(),
                b: b.clone(),
            });
            std::hint::black_box(&f);
        }
        let enc_s = sw.elapsed_secs();

        let body = frame[4..].to_vec();
        let sw = Stopwatch::start();
        for _ in 0..reps {
            let msg = decode_frame(&body).expect("bench frame decodes");
            std::hint::black_box(&msg);
        }
        let dec_s = sw.elapsed_secs();

        let enc_gbs = bytes * reps as f64 / enc_s / 1e9;
        let dec_gbs = bytes * reps as f64 / dec_s / 1e9;
        t.row(&[
            format!("{m}x{n}x{k}"),
            format!("{:.1}", bytes / 1024.0),
            format!("{enc_gbs:.2}"),
            format!("{dec_gbs:.2}"),
            format!("{:.0}", reps as f64 / dec_s),
        ]);
        streamk::bench::dump_json(
            "BENCH_net_wire.json",
            streamk::json::obj(vec![
                ("bench", "net_wire".into()),
                ("shape", format!("{m}x{n}x{k}").into()),
                ("frame_bytes", (bytes as usize).into()),
                ("encode_gbs", enc_gbs.into()),
                ("decode_gbs", dec_gbs.into()),
            ]),
        );
    }
    t.print();
    println!(
        "\nexpected shape: both directions are memcpy-bound — the codec \
         adds one FNV-1a pass and bounds checks, so GB/s should sit \
         within small factors of memory bandwidth and grow with frame \
         size as fixed header costs amortize.\n"
    );
}
