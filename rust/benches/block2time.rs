//! B2T — Block2Time predictive load balancing (the report's headline
//! future-work item, implemented).
//!
//! On a heterogeneous device (thermal throttling / shared-cluster noise —
//! the report explicitly disregarded "suspicious results … during times
//! of heavy shared use of the cluster"), the even Stream-K split waits on
//! the slowest CU. Block2Time: (1) fit a per-iteration cost model from
//! probe timings, (2) estimate per-CU speeds, (3) cut the iteration
//! space proportionally to speed.
//!
//! Run: `cargo bench --bench block2time`

use streamk::bench::Table;
use streamk::decomp::{build_schedule, BlockShape, GemmShape};
use streamk::gpu_sim::{gemm, Device, DeviceKind};
use streamk::predict::{
    balance_plan, fit, predicted_makespan_plan, SpeedEstimator,
};
use streamk::prop::Rng;

fn simulate_makespan(dev: &Device, sched: &streamk::decomp::StreamKSchedule) -> f64 {
    gemm::simulate_streamk(dev, sched, 4).total_s
}

fn main() {
    let shape = GemmShape::new(2048, 2048, 2048);
    let block = BlockShape::default();
    let base = Device::preset(DeviceKind::Mi200);
    let mut rng = Rng::new(0xB27);

    println!("== 1. cost-model fit from probe launches ==\n");
    // Probe: time per-CU work of increasing depth on the simulator,
    // with multiplicative noise — the data Block2Time would collect
    // from rocprof counters.
    let samples: Vec<(usize, f64)> = (1..=24)
        .map(|i| {
            let iters = i * 64;
            let per_iter = block.flops_per_iter() as f64 / base.flops_per_cu;
            let noisy = per_iter * iters as f64 * (1.0 + 0.02 * rng.normal());
            (iters, noisy + 6.0e-6)
        })
        .collect();
    let model = fit(&samples).expect("fit");
    println!(
        "fitted seconds = {:.3e}·iters + {:.2e}   (true slope {:.3e}, \
         launch overhead 6.0e-6)",
        model.a,
        model.b,
        block.flops_per_iter() as f64 / base.flops_per_cu
    );
    let slope_err = (model.a * base.flops_per_cu
        / block.flops_per_iter() as f64
        - 1.0)
        .abs();
    assert!(slope_err < 0.05, "cost model fit off by {slope_err:.2}");

    println!("\n== 2. even vs Block2Time-balanced split, heterogeneous CUs ==\n");
    let mut t = Table::new(&[
        "device condition", "even ms", "balanced ms", "speedup", "predicted",
    ]);
    for (label, dev) in [
        ("homogeneous", base.clone()),
        ("1/4 CUs at 0.5x", base.clone().with_throttled(4, 0.5)),
        ("1/2 CUs at 0.5x", base.clone().with_throttled(2, 0.5)),
        ("1/8 CUs at 0.25x", base.clone().with_throttled(8, 0.25)),
        ("every 2nd at 0.75x", base.clone().with_throttled(2, 0.75)),
    ] {
        // Block2Time's speed estimation from noisy probe observations.
        let mut est = SpeedEstimator::new(dev.num_cus);
        for cu in 0..dev.num_cus {
            for _ in 0..5 {
                let true_t = 1.0 / dev.cu_speed[cu];
                est.record(cu, true_t * (1.0 + 0.03 * rng.normal().abs()));
            }
        }
        let speeds = est.speeds().expect("speeds");

        let even = build_schedule(shape, block, dev.num_cus).unwrap();
        // The weighted split comes from the plan cache (quantized
        // per-CU weight key) — the dispatch path Block2Time uses.
        let balanced = balance_plan(shape, block, &speeds, 4).unwrap();
        // A re-scaled estimate of the same speeds must *reuse* the
        // cached plan, not re-run the weighted decomposition.
        let rescaled: Vec<f64> = speeds.iter().map(|s| s * 0.5).collect();
        let again = balance_plan(shape, block, &rescaled, 4).unwrap();
        assert!(
            std::sync::Arc::ptr_eq(&balanced, &again),
            "{label}: rescaled estimate must hit the weighted plan cache"
        );
        let t_even = simulate_makespan(&dev, &even);
        let t_bal = balanced.simulate(&dev).total_s;
        let pred =
            predicted_makespan_plan(&balanced, model, &dev.cu_speed) * 1e3;
        t.row(&[
            label.into(),
            format!("{:.3}", t_even * 1e3),
            format!("{:.3}", t_bal * 1e3),
            format!("{:.2}x", t_even / t_bal),
            format!("{pred:.3} ms"),
        ]);
        if label == "homogeneous" {
            assert!((t_even / t_bal - 1.0).abs() < 0.05, "must tie");
        } else {
            assert!(t_even / t_bal > 1.1, "{label}: balancing must win");
        }
    }
    t.print();

    println!("\n== 3. speedup vs throttle severity (1/4 of CUs slowed) ==\n");
    let mut t = Table::new(&["slow-CU speed", "even ms", "balanced ms", "speedup"]);
    for factor in [0.9, 0.75, 0.5, 0.25, 0.1] {
        let dev = base.clone().with_throttled(4, factor);
        let even = build_schedule(shape, block, dev.num_cus).unwrap();
        let balanced = balance_plan(shape, block, &dev.cu_speed, 4).unwrap();
        let t_even = simulate_makespan(&dev, &even);
        let t_bal = balanced.simulate(&dev).total_s;
        t.row(&[
            format!("{factor:.2}x"),
            format!("{:.3}", t_even * 1e3),
            format!("{:.3}", t_bal * 1e3),
            format!("{:.2}x", t_even / t_bal),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape: speedup grows as heterogeneity deepens \
         (even split is gated by the slowest CU; Block2Time shifts work \
         to fast CUs), and exactly 1.0x on a homogeneous device."
    );
    let stats = streamk::plan::global().stats();
    println!(
        "\nweighted-plan cache: {} hits / {} misses | {} builds \
         ({} entries)",
        stats.hits, stats.misses, stats.builds, stats.entries
    );
    assert!(
        stats.hits >= 5,
        "each condition's rescaled estimate must hit the cached plan"
    );
}
