//! SK-VS-DP — Stream-K vs data-parallel vs Split-K, the speedup
//! landscape from Osama et al. that the report's whole exploration rests
//! on. Two sections:
//!
//!  1. simulated MI200 sweep across tile counts (the quantization
//!     sawtooth): speedup of stream-k and split-k over tile-based, with
//!     the crossovers the paper describes;
//!  2. measured CPU-PJRT comparison of the three algorithms' artifacts
//!     on the scaled Table-1 baseline.
//!
//! Run: `cargo bench --bench streamk_vs_baselines`

use std::path::Path;

use streamk::bench::{self, Table};
use streamk::decomp::{
    build_schedule, splitk, swizzle::Swizzle, tile, BlockShape, GemmShape,
    TileGrid,
};
use streamk::gpu_sim::{gemm, Device, DeviceKind};
use streamk::prop::Rng;
use streamk::runtime::{Engine, Manifest};

fn main() {
    let dev = Device::preset(DeviceKind::Mi200);
    let block = BlockShape::default();

    println!("== 1. simulated MI200: speedup vs tile count ==\n");
    let mut t = Table::new(&[
        "tiles", "waves", "tile ms", "sk speedup", "splitk2", "splitk4", "splitk8",
    ]);
    let mut sk_wins = 0usize;
    let mut points = 0usize;
    for tiles_m in (6..=126).step_by(8) {
        let shape = GemmShape::new(tiles_m * 128, 4096, 1024);
        let grid = TileGrid::new(shape, block);
        let dp = gemm::simulate(
            &dev,
            shape,
            grid,
            tile::dp_assignment(grid, dev.num_cus, Swizzle::RowMajor),
            block,
            4,
        );
        let sk = gemm::simulate_streamk(
            &dev,
            &build_schedule(shape, block, dev.num_cus).unwrap(),
            4,
        );
        let mut split_speedups = Vec::new();
        for s in [2usize, 4, 8] {
            let r = gemm::simulate(
                &dev,
                shape,
                grid,
                splitk::splitk_assignment(grid, dev.num_cus, s),
                block,
                4,
            );
            split_speedups.push(dp.total_s / r.total_s);
        }
        points += 1;
        if sk.total_s <= dp.total_s * 1.001 {
            sk_wins += 1;
        }
        t.row(&[
            grid.num_tiles().to_string(),
            format!("{:.2}", grid.num_tiles() as f64 / 120.0),
            format!("{:.3}", dp.total_s * 1e3),
            format!("{:.2}x", dp.total_s / sk.total_s),
            format!("{:.2}x", split_speedups[0]),
            format!("{:.2}x", split_speedups[1]),
            format!("{:.2}x", split_speedups[2]),
        ]);
    }
    t.print();
    println!(
        "\nstream-k ≥ tile-based at {sk_wins}/{points} points (paper: \
         never loses); split-k helps only where its fixed factor happens \
         to fill the last wave — the kernel-selection-heuristic problem \
         stream-k removes.\n"
    );

    println!("== 2. measured CPU PJRT, scaled Table-1 baseline ==\n");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Manifest::load(&dir) {
        Err(_) => println!("(skipped: run `make artifacts`)"),
        Ok(manifest) => {
            let engine = Engine::new(manifest).expect("pjrt");
            let (m, n, k) = (960usize, 1024usize, 1024usize);
            let shape = GemmShape::new(m, n, k);
            let mut rng = Rng::new(5);
            let a = rng.normal_f32_vec(m * k);
            let b = rng.normal_f32_vec(k * n);
            let mut t =
                Table::new(&["algorithm", "ms", "TFLOP/s", "vs ref"]);
            let (rv, _) = engine
                .run_f32(&format!("gemm_ref_nopad_f32_{m}x{n}x{k}"), &[&a, &b])
                .unwrap();
            for algo in ["ref", "streamk", "tile", "splitk"] {
                let name = if algo == "splitk" {
                    format!("gemm_splitk_nopad_f32_{m}x{n}x{k}_s4")
                } else {
                    format!("gemm_{algo}_nopad_f32_{m}x{n}x{k}")
                };
                engine.warmup(&[&name]).unwrap();
                let stats = bench::bench(1, 5, || {
                    bench::keep(engine.run_f32(&name, &[&a, &b]).unwrap());
                });
                let (v, _) = engine.run_f32(&name, &[&a, &b]).unwrap();
                let err = streamk::faults::error_rate(&v[0], &rv[0], 1e-3);
                assert!(err.passed(), "{name}: {err:?}");
                t.row(&[
                    algo.into(),
                    bench::fmt_ms(stats.mean),
                    bench::fmt_tflops(shape.flops(), stats.mean),
                    format!("{} elements off", err.bad),
                ]);
            }
            t.print();
            println!(
                "\n(on one XLA-CPU core the grid-loop overhead dominates; \
                 the *relative* algorithm ordering and exactness are the \
                 portable result — device-time ordering is section 1)"
            );
        }
    }
}
