//! KERNEL — blocked packed-tile executor vs the per-element reference.
//!
//! Acceptance demonstration for the microkernel execution layer:
//! (1) the blocked executor is bit-identical to the per-element
//! reference (spot-checked here; property-tested in `kernel::exec`),
//! (2) it beats the per-element path on Table-1 shapes — ≥ 3× in the
//! full run (serial microkernel gains × work-item parallelism), and
//! strictly faster even in the CI smoke on a constrained runner.
//!
//! Run: `cargo bench --bench kernel_exec`
//! CI smoke: `cargo bench --bench kernel_exec -- --test`

use streamk::bench::{bench, keep, Table};
use streamk::decomp::{build_schedule, BlockShape, FlatSchedule, GemmShape};
use streamk::faults::{execute_flat_ref, Matrix};
use streamk::kernel::{execute_threads, Epilogue, ExecDesc};
use streamk::prop::Rng;

fn main() {
    let quick = std::env::args().skip(1).any(|a| a == "--test");
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let par_threads = cores.min(8);

    println!("== 1. bit-identity gate (ragged shape, NaN/Inf seeded) ==\n");
    {
        let (m, n, k, p) = (96usize, 102usize, 100usize, 12usize);
        let mut rng = Rng::new(42);
        let mut a = Matrix::random(m, k, &mut rng);
        a.data[0] = f32::INFINITY;
        a.data[m * k / 2] = f32::NAN;
        let b = Matrix::random(k, n, &mut rng);
        let sched =
            build_schedule(GemmShape::new(m, n, k), BlockShape::new(16, 16, 8), p)
                .unwrap();
        let flat = FlatSchedule::from_schedule(&sched);
        let want =
            execute_flat_ref(&a.data, &b.data, sched.shape, &flat, sched.block);
        let desc = ExecDesc::new(sched.shape, sched.block, &flat);
        for threads in [1usize, par_threads] {
            let got = execute_threads(
                &a.data,
                &b.data,
                &desc,
                Epilogue::None,
                threads,
            );
            let identical = got
                .iter()
                .zip(&want)
                .all(|(g, w)| g.to_bits() == w.to_bits());
            assert!(identical, "threads={threads}: blocked != reference");
        }
        println!(
            "blocked == per-element reference, bit for bit \
             (threads 1 and {par_threads}, non-finite inputs included)\n"
        );
    }

    println!("== 2. Table-1 shapes: per-element vs blocked ==\n");
    // (480, 512, 512) is the paper's medium shape — the 99%-error
    // regime, pure-SK on 120 CUs with deep split tiles; the baseline
    // shape joins in the full run (several seconds per per-element
    // iteration in debug-profile CI, so the smoke skips it).
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(480, 512, 512)]
    } else {
        &[(480, 512, 512), (1920, 2000, 2000)]
    };
    let iters = if quick { 2 } else { 3 };
    let par_header = format!("blocked-{par_threads}t ms");
    let mut t = Table::new(&[
        "shape",
        "per-elem ms",
        "blocked-1t ms",
        par_header.as_str(),
        "serial speedup",
        "parallel speedup",
    ]);
    let mut best_speedup = 0.0f64;
    for &(m, n, k) in shapes {
        let mut rng = Rng::new((m + n + k) as u64);
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let shape = GemmShape::new(m, n, k);
        let sched = build_schedule(shape, BlockShape::default(), 120).unwrap();
        let flat = FlatSchedule::from_schedule(&sched);
        let desc = ExecDesc::new(shape, sched.block, &flat);

        let reference = bench(1, iters, || {
            keep(execute_flat_ref(&a.data, &b.data, shape, &flat, sched.block));
        });
        let serial = bench(1, iters, || {
            keep(execute_threads(&a.data, &b.data, &desc, Epilogue::None, 1));
        });
        let parallel = bench(1, iters, || {
            keep(execute_threads(
                &a.data,
                &b.data,
                &desc,
                Epilogue::None,
                par_threads,
            ));
        });
        let s_serial = reference.median / serial.median.max(1e-12);
        let s_parallel = reference.median / parallel.median.max(1e-12);
        best_speedup = best_speedup.max(s_parallel);
        t.row(&[
            format!("{m}x{n}x{k}"),
            format!("{:.2}", reference.median * 1e3),
            format!("{:.2}", serial.median * 1e3),
            format!("{:.2}", parallel.median * 1e3),
            format!("{s_serial:.2}x"),
            format!("{s_parallel:.2}x"),
        ]);
    }
    t.print();
    println!(
        "\nbest blocked speedup over the per-element path: \
         {best_speedup:.2}x ({cores} cores available)"
    );

    if quick {
        // CI runners are small and noisy: the smoke asserts a strict
        // win; the full run asserts the 3x acceptance bar.
        assert!(
            best_speedup > 1.05,
            "blocked executor must beat the per-element path: {best_speedup:.2}x"
        );
    } else {
        assert!(
            best_speedup >= 3.0,
            "blocked executor must be >= 3x the per-element path on a \
             Table-1 shape: {best_speedup:.2}x"
        );
    }

    println!("\nkernel_exec OK");
}
