//! KERNEL — SIMD-laned, ownership-streaming executor vs the PR-4
//! blocked baseline and the per-element reference.
//!
//! Acceptance demonstration for the kernel execution layer:
//! (1) bit-identity — every runnable lane backend × dispatcher mode
//! reproduces the per-element reference exactly (NaN/∞ seeded;
//! property-tested further in `kernel::exec` / `kernel::micro`);
//! (2) ownership — direct-store streaming engages on *all* fully
//! aligned work items (per-class counts reported per shape);
//! (3) speed — the new executor (detected SIMD lanes + streaming)
//! beats the per-element path ≥ 3× and the PR-4 blocked baseline
//! (scalar lanes, everything windowed) ≥ 1.5× on Table-1 shapes in the
//! full run; the CI smoke asserts a strict win on a constrained
//! runner. `STREAMK_KERNEL_LANES=scalar` gates the forced-scalar path
//! through the same bit-identity checks (CI runs both).
//!
//! Run: `cargo bench --bench kernel_exec`
//! CI smoke: `cargo bench --bench kernel_exec -- --test`

use streamk::bench::{bench, keep, Table};
use streamk::decomp::{build_schedule, BlockShape, FlatSchedule, GemmShape};
use streamk::faults::{execute_flat_ref, Matrix};
use streamk::kernel::{
    execute_opts, lane, Epilogue, ExecDesc, ExecOpts, LaneBackend,
};
use streamk::prop::Rng;

fn main() {
    let quick = std::env::args().skip(1).any(|a| a == "--test");
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    let par_threads = cores.min(8);
    let active = lane::active();
    println!(
        "lane backend: {} (available: {}) | {cores} cores\n",
        active.name(),
        lane::available()
            .iter()
            .map(|b| b.name())
            .collect::<Vec<_>>()
            .join(","),
    );

    println!("== 1. bit-identity gate (ragged shape, NaN/Inf seeded) ==\n");
    {
        let (m, n, k, p) = (96usize, 102usize, 100usize, 12usize);
        let mut rng = Rng::new(42);
        let mut a = Matrix::random(m, k, &mut rng);
        a.data[0] = f32::INFINITY;
        a.data[m * k / 2] = f32::NAN;
        let b = Matrix::random(k, n, &mut rng);
        let sched =
            build_schedule(GemmShape::new(m, n, k), BlockShape::new(16, 16, 8), p)
                .unwrap();
        let flat = FlatSchedule::from_schedule(&sched);
        let want =
            execute_flat_ref(&a.data, &b.data, sched.shape, &flat, sched.block);
        let desc = ExecDesc::new(sched.shape, sched.block, &flat);
        let mut combos = 0;
        for backend in lane::available() {
            for direct_store in [false, true] {
                for threads in [1usize, par_threads] {
                    let got = execute_opts(
                        &a.data,
                        &b.data,
                        &desc,
                        Epilogue::None,
                        &ExecOpts { backend, direct_store, threads, kc: None, reg: None },
                    );
                    let identical = got
                        .iter()
                        .zip(&want)
                        .all(|(g, w)| g.to_bits() == w.to_bits());
                    assert!(
                        identical,
                        "{backend:?} direct={direct_store} threads={threads}: \
                         executor != reference"
                    );
                    combos += 1;
                }
            }
        }
        println!(
            "all {combos} (backend x dispatch x threads) combinations == \
             per-element reference, bit for bit (non-finite inputs included)\n"
        );
    }

    println!("== 2. tile-ownership classes (Table-1 shapes, 120 CUs) ==\n");
    let mut t = Table::new(&[
        "shape", "streamed", "ordered", "partial", "aligned",
    ]);
    for &(m, n, k) in &[
        (3840usize, 4096usize, 4096usize), // baseline: fully grid-aligned
        (1920, 2000, 2000),                // ragged N/K
        (480, 512, 512),                   // ragged M, pure-SK regime
        (3, 9, 9),                         // tiny
    ] {
        let shape = GemmShape::new(m, n, k);
        let sched = build_schedule(shape, BlockShape::default(), 120).unwrap();
        let flat = FlatSchedule::from_schedule(&sched);
        let desc = ExecDesc::new(shape, sched.block, &flat);
        let (streamed, ordered, partial) = desc.class_counts();
        let aligned = m % sched.block.bm == 0 && n % sched.block.bn == 0;
        if aligned {
            assert_eq!(
                ordered, 0,
                "{m}x{n}x{k}: every store on an aligned grid must stream"
            );
        }
        t.row(&[
            format!("{m}x{n}x{k}"),
            streamed.to_string(),
            ordered.to_string(),
            partial.to_string(),
            if aligned { "yes (all streamed)" } else { "no" }.to_string(),
        ]);
    }
    t.print();
    println!(
        "\n(direct-store streaming engages on every fully-aligned work \
         item; clamped-edge and multi-writer tiles keep the ordered \
         windowed path)\n"
    );

    println!("== 3. Table-1 shapes: per-element vs PR-4 baseline vs new ==\n");
    // (480, 512, 512) is the paper's medium shape — the 99%-error
    // regime, pure-SK on 120 CUs with deep split tiles; the baseline
    // shape joins in the full run (several seconds per per-element
    // iteration in debug-profile CI, so the smoke skips it).
    let shapes: &[(usize, usize, usize)] = if quick {
        &[(480, 512, 512)]
    } else {
        &[(480, 512, 512), (1920, 2000, 2000)]
    };
    let iters = if quick { 2 } else { 3 };
    let mut t = Table::new(&[
        "shape",
        "per-elem ms",
        "pr4-base ms",
        "new-1t ms",
        "new-par ms",
        "vs per-elem",
        "vs pr4",
    ]);
    let mut best_vs_ref = 0.0f64;
    let mut best_vs_pr4 = 0.0f64;
    for &(m, n, k) in shapes {
        let mut rng = Rng::new((m + n + k) as u64);
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let shape = GemmShape::new(m, n, k);
        let sched = build_schedule(shape, BlockShape::default(), 120).unwrap();
        let flat = FlatSchedule::from_schedule(&sched);
        let desc = ExecDesc::new(shape, sched.block, &flat);

        let reference = bench(1, iters, || {
            keep(execute_flat_ref(&a.data, &b.data, shape, &flat, sched.block));
        });
        // The PR-4 configuration: scalar (auto-vectorized) lanes, every
        // store staged through the windowed arena + serial drain.
        let pr4 = ExecOpts {
            backend: LaneBackend::Scalar,
            direct_store: false,
            threads: par_threads,
            kc: None,
            reg: None,
        };
        let baseline = bench(1, iters, || {
            keep(execute_opts(&a.data, &b.data, &desc, Epilogue::None, &pr4));
        });
        let new1 = ExecOpts {
            backend: active,
            direct_store: true,
            threads: 1,
            kc: None,
            reg: None,
        };
        let serial = bench(1, iters, || {
            keep(execute_opts(&a.data, &b.data, &desc, Epilogue::None, &new1));
        });
        let newp = ExecOpts { threads: par_threads, ..new1 };
        let parallel = bench(1, iters, || {
            keep(execute_opts(&a.data, &b.data, &desc, Epilogue::None, &newp));
        });
        let vs_ref = reference.median / parallel.median.max(1e-12);
        let vs_pr4 = baseline.median / parallel.median.max(1e-12);
        best_vs_ref = best_vs_ref.max(vs_ref);
        best_vs_pr4 = best_vs_pr4.max(vs_pr4);
        t.row(&[
            format!("{m}x{n}x{k}"),
            format!("{:.2}", reference.median * 1e3),
            format!("{:.2}", baseline.median * 1e3),
            format!("{:.2}", serial.median * 1e3),
            format!("{:.2}", parallel.median * 1e3),
            format!("{vs_ref:.2}x"),
            format!("{vs_pr4:.2}x"),
        ]);
        let flops = 2.0 * (m * n * k) as f64;
        let bytes = 4.0 * (m * k + k * n + m * n) as f64;
        let s = parallel.median.max(1e-12);
        let roofline = streamk::trace::profile::host_roofline(par_threads);
        streamk::bench::dump_json(
            "BENCH_kernel_exec.json",
            streamk::json::obj(vec![
                ("bench", "kernel_exec".into()),
                ("shape", format!("{m}x{n}x{k}").into()),
                ("width", "f32".into()),
                ("ms", (parallel.median * 1e3).into()),
                ("gflops", (flops / s / 1e9).into()),
                ("gbps", (bytes / s / 1e9).into()),
                ("efficiency", (flops / s / roofline.peak_flops).into()),
                ("vs_per_elem", vs_ref.into()),
                ("vs_pr4", vs_pr4.into()),
            ]),
        );
    }
    t.print();
    println!(
        "\nbest speedups: {best_vs_ref:.2}x over per-element, \
         {best_vs_pr4:.2}x over the PR-4 blocked baseline \
         (lanes: {})",
        active.name()
    );

    if quick {
        // CI runners are small and noisy: the smoke asserts a strict
        // win; the full run asserts the acceptance bars.
        assert!(
            best_vs_ref > 1.05,
            "executor must beat the per-element path: {best_vs_ref:.2}x"
        );
    } else {
        assert!(
            best_vs_ref >= 3.0,
            "executor must be >= 3x the per-element path on a Table-1 \
             shape: {best_vs_ref:.2}x"
        );
        if active != LaneBackend::Scalar {
            assert!(
                best_vs_pr4 >= 1.5,
                "SIMD lanes + ownership streaming must be >= 1.5x the \
                 PR-4 blocked baseline: {best_vs_pr4:.2}x"
            );
        }
    }

    println!("\n== 4. tracing overhead gate (disabled path) ==\n");
    {
        assert!(
            !streamk::trace::enabled(),
            "tracing must be off for the overhead gate"
        );
        let (m, n, k) = (480usize, 512usize, 512usize);
        let mut rng = Rng::new(9);
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let shape = GemmShape::new(m, n, k);
        let sched = build_schedule(shape, BlockShape::default(), 120).unwrap();
        let flat = FlatSchedule::from_schedule(&sched);
        let desc = ExecDesc::new(shape, sched.block, &flat);
        let opts = ExecOpts {
            backend: active,
            direct_store: true,
            threads: par_threads,
            kc: None,
            reg: None,
        };
        let dispatch = bench(1, if quick { 3 } else { 5 }, || {
            keep(execute_opts(&a.data, &b.data, &desc, Epilogue::None, &opts));
        });
        // Cost of one disabled span hook: a single relaxed atomic load.
        const SPANS_PER_SAMPLE: usize = 1_000_000;
        let hook = bench(1, 3, || {
            for _ in 0..SPANS_PER_SAMPLE {
                drop(keep(streamk::trace::span("bench.noop")));
            }
        });
        let per_span_s = hook.median / SPANS_PER_SAMPLE as f64;
        // Upper bound on hooks one dispatch executes: one accumulate +
        // one store span per job, the pass/window/fixup spans on top.
        let hooks = desc.jobs.len() * 3 + 64;
        let overhead = per_span_s * hooks as f64 / dispatch.median.max(1e-12);
        println!(
            "disabled span: {:.1} ns | {} hooks/dispatch (bound) | \
             dispatch {:.2} ms | overhead {:.4}%",
            per_span_s * 1e9,
            hooks,
            dispatch.median * 1e3,
            overhead * 100.0,
        );
        assert!(
            overhead <= 0.01,
            "disabled tracing must stay within 1% of dispatch time: \
             {:.4}%",
            overhead * 100.0
        );

        // The roofline profiler rides the same pattern: its hooks
        // collapse to one relaxed atomic load while disabled.
        assert!(
            !streamk::trace::profile::enabled(),
            "profiler must be off for the overhead gate"
        );
        let phook = bench(1, 3, || {
            for _ in 0..SPANS_PER_SAMPLE {
                keep(streamk::trace::profile::enabled());
            }
        });
        let per_hook_s = phook.median / SPANS_PER_SAMPLE as f64;
        // Bound: one gate check in each of the two passes per job,
        // plus the per-dispatch aggregation bookkeeping.
        let phooks = desc.jobs.len() * 2 + 64;
        let poverhead =
            per_hook_s * phooks as f64 / dispatch.median.max(1e-12);
        println!(
            "disabled profiler hook: {:.1} ns | {} hooks/dispatch \
             (bound) | overhead {:.4}%",
            per_hook_s * 1e9,
            phooks,
            poverhead * 100.0,
        );
        assert!(
            poverhead <= 0.01,
            "disabled profiling must stay within 1% of dispatch time: \
             {:.4}%",
            poverhead * 100.0
        );

        println!("\n== 5. roofline attribution (enabled path) ==\n");
        streamk::trace::profile::set_enabled(true);
        let _ = streamk::trace::profile::drain();
        let attributed = bench(1, if quick { 2 } else { 3 }, || {
            keep(execute_opts(&a.data, &b.data, &desc, Epilogue::None, &opts));
        });
        streamk::trace::profile::set_enabled(false);
        let profiles = streamk::trace::profile::drain();
        let roofline = streamk::trace::profile::host_roofline(par_threads);
        let bucket = profiles
            .iter()
            .find(|p| p.bucket == "512x512x512")
            .expect("dispatch must land in the 512x512x512 bucket");
        println!("{}", bucket.summary(&roofline));
        println!(
            "enabled-profiler dispatch {:.2} ms (disabled {:.2} ms)",
            attributed.median * 1e3,
            dispatch.median * 1e3,
        );
        // Debug-profile CI timers are coarse; the full release run
        // holds the paper-grade attribution bar.
        let floor = if quick { 0.90 } else { 0.95 };
        assert!(
            bucket.accounted() >= floor,
            "attributed phases must cover >= {:.0}% of dispatch wall \
             time: {:.1}%",
            floor * 100.0,
            bucket.accounted() * 100.0
        );
    }

    println!(
        "\n== 6. mixed-precision lanes (16-bit streaming, f32 accumulate) ==\n"
    );
    {
        use streamk::gpu_sim::{Device, DeviceKind};
        use streamk::kernel::Width;

        // (a) Per-width bit-identity, every runnable backend: a 16-bit
        // descriptor must reproduce the f32 per-element reference over
        // width-quantized inputs *exactly* — pack→widen→accumulate is
        // the oracle, NaN/∞ seeded. Runs in smoke and full mode.
        let (m, n, k, p) = (96usize, 102usize, 100usize, 12usize);
        let mut rng = Rng::new(7);
        let mut a = Matrix::random(m, k, &mut rng);
        a.data[1] = f32::NEG_INFINITY;
        a.data[m * k / 3] = f32::NAN;
        let b = Matrix::random(k, n, &mut rng);
        let shape = GemmShape::new(m, n, k);
        let sched =
            build_schedule(shape, BlockShape::new(16, 16, 8), p).unwrap();
        let flat = FlatSchedule::from_schedule(&sched);
        let mut combos = 0;
        for width in Width::all() {
            let desc =
                ExecDesc::new(shape, sched.block, &flat).with_width(width);
            let qa = width.quantize_slice(&a.data);
            let qb = width.quantize_slice(&b.data);
            let want =
                execute_flat_ref(&qa, &qb, shape, &flat, sched.block);
            for backend in lane::available() {
                let got = execute_opts(
                    &a.data,
                    &b.data,
                    &desc,
                    Epilogue::None,
                    &ExecOpts {
                        backend,
                        direct_store: true,
                        threads: par_threads,
                        kc: None,
                        reg: None,
                    },
                );
                assert!(
                    got.iter()
                        .zip(&want)
                        .all(|(g, w)| g.to_bits() == w.to_bits()),
                    "{width} on {backend:?}: widening lanes != \
                     width-quantized per-element oracle"
                );
                combos += 1;
            }
        }
        println!(
            "all {combos} (width x backend) combinations == the \
             width-quantized per-element oracle, bit for bit\n"
        );

        // (b) Predicted speedup where halved panel bytes must pay: a
        // compute-rich mi200 variant (4x the matrix throughput, same
        // 1.6 TB/s of HBM) puts the big Table-1 shapes squarely in the
        // memory-bound regime — exactly the deployment that reaches
        // for 16-bit streaming. Gated >= 1.3x in the full run; the
        // smoke still prints the table and checks monotonicity.
        let dev = Device::preset(DeviceKind::Mi200).with_flops_scale(4.0);
        let mut t = Table::new(&[
            "shape", "f32 ms", "bf16 ms", "f16 ms", "bf16 gain",
        ]);
        let mut best_gain = 0.0f64;
        for &(m, n, k) in
            &[(1920usize, 2000usize, 2000usize), (3840, 4096, 4096)]
        {
            let shape = GemmShape::new(m, n, k);
            let times: Vec<f64> = Width::all()
                .iter()
                .map(|&w| {
                    streamk::plan::global()
                        .get_or_build_w(
                            shape,
                            BlockShape::default(),
                            w,
                            120,
                        )
                        .expect("plan builds at every width")
                        .time_on(&dev)
                })
                .collect();
            let gain = times[0] / times[1].max(1e-12);
            best_gain = best_gain.max(gain);
            for (w, time) in Width::all().iter().zip(&times) {
                assert!(
                    *time <= times[0] * (1.0 + 1e-12),
                    "{w}: halved panel bytes must never predict slower \
                     than f32"
                );
                streamk::bench::dump_json(
                    "BENCH_kernel_exec.json",
                    streamk::json::obj(vec![
                        ("bench", "kernel_exec_precision".into()),
                        ("shape", format!("{m}x{n}x{k}").into()),
                        ("width", w.name().into()),
                        ("predicted_ms", (time * 1e3).into()),
                        ("gain_vs_f32", (times[0] / time.max(1e-12)).into()),
                    ]),
                );
            }
            t.row(&[
                format!("{m}x{n}x{k}"),
                format!("{:.3}", times[0] * 1e3),
                format!("{:.3}", times[1] * 1e3),
                format!("{:.3}", times[2] * 1e3),
                format!("{gain:.2}x"),
            ]);
        }
        t.print();
        println!(
            "\n(memory-bound regime: mi200 x4 matrix throughput, HBM \
             unchanged; best bf16 gain {best_gain:.2}x)"
        );
        if !quick {
            assert!(
                best_gain >= 1.3,
                "bf16 streaming must buy >= 1.3x over f32 on a \
                 memory-bound Table-1 shape: {best_gain:.2}x"
            );
        }
    }

    println!("\nkernel_exec OK");
}
