//! E2E — coordinator serving benchmark: throughput/latency of the full
//! stack (router → dynamic batcher → engine thread → PJRT) under a
//! synthetic MLP request stream, swept over batching policies, plus the
//! overload/shedding behaviour. This regenerates the serving-side
//! numbers recorded in EXPERIMENTS.md §E2E.
//!
//! Run: `cargo bench --bench e2e_serve` (needs `make artifacts`)
//! CI smoke: `cargo bench --bench e2e_serve -- --test` — runs a
//! repeated-shape GEMM trace through the full coordinator over the
//! checked-in `examples/minimal_artifacts` manifest and asserts the
//! plan cache's zero-rebuild hot path (>90% hit rate and zero schedule
//! builds once warm), then repeats the stream with structured tracing
//! sampled on and asserts the exported Chrome trace parses, carries the
//! full request span chain, and populated finite Block2Time residuals,
//! and finally serves under a deliberately impossible `--slo` target
//! and asserts the watchdog trips: forced re-validation fires, the
//! metrics flight recorder fills, and `slo.breach` / `slo.retune`
//! events land in the trace ring. Bench rows append to
//! `BENCH_e2e_serve.json` for EXPERIMENTS.md bookkeeping.

use std::path::Path;

use streamk::bench::Table;
use streamk::config::Settings;
use streamk::coordinator::Coordinator;
use streamk::exec::Stopwatch;
use streamk::prop::Rng;
use streamk::runtime::{spawn_engine, Manifest};

const REQUESTS: usize = 120;

/// Plan-cache smoke over the interpreter-backend coordinator: no
/// `make artifacts` needed, so this runs in CI.
fn run_smoke() {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("minimal_artifacts");
    let manifest = Manifest::load(&dir).expect("checked-in minimal manifest");
    let (engine, _join) = spawn_engine(manifest).expect("engine");
    // tune-on-miss off: the smoke isolates the plan cache's serving
    // counters from background tuner traffic.
    let settings = Settings {
        workers: 2,
        tune_on_miss: false,
        ..Settings::default()
    };
    let coord = Coordinator::start(engine, &settings);
    let handle = coord.handle.clone();

    let gemm = |handle: &streamk::coordinator::CoordinatorHandle| {
        let w = handle.submit_gemm(
            128,
            128,
            128,
            vec![1.0; 128 * 128],
            vec![1.0; 128 * 128],
        );
        let resp = w.recv().expect("gemm reply");
        let out = resp.result.expect("gemm ok");
        assert!(
            out.iter().all(|&v| (v - 128.0).abs() < 1e-2),
            "ones x ones must give k"
        );
    };

    // Warm touch: the first request builds the shape's plans (one for
    // the placement prior's grid, one for the artifact's CU grid).
    gemm(&handle);
    let warm = handle.metrics().snapshot().plan;
    assert!(warm.builds > 0, "cold request must build plans");

    // Repeated-shape trace: every subsequent request must be pure hits.
    let repeats = 49usize;
    for _ in 0..repeats {
        gemm(&handle);
    }
    let snap = handle.metrics().snapshot();
    let plan = snap.plan;
    println!(
        "smoke: {} requests | plan cache {} hits / {} misses \
         ({:.1}% hit rate) | {} builds ({:.2} ms total) | {} entries",
        repeats + 1,
        plan.hits,
        plan.misses,
        plan.hit_rate() * 100.0,
        plan.builds,
        plan.build_time_s * 1e3,
        plan.entries,
    );
    assert_eq!(
        plan.builds, warm.builds,
        "hit path must not rebuild schedules"
    );
    assert!(
        plan.hits >= warm.hits + repeats as u64,
        "every repeated request must hit the plan cache"
    );
    assert!(
        plan.hit_rate() > 0.9,
        "repeated-shape trace must exceed 90% hit rate: {:.3}",
        plan.hit_rate()
    );
    assert_eq!(snap.completed, repeats as u64 + 1);
    coord.shutdown();
    println!("e2e_serve smoke OK ({:.1}% plan hit rate)", plan.hit_rate() * 100.0);
}

/// Tracing + Block2Time smoke: serve a short GEMM stream with tracing
/// sampled on, assert the exported Chrome trace file re-parses through
/// the in-tree JSON parser with the full request span chain present,
/// and that measured residual stats landed in the metrics snapshot.
fn run_traced_smoke() {
    let _guard = streamk::trace::test_lock();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("minimal_artifacts");
    let manifest = Manifest::load(&dir).expect("checked-in minimal manifest");
    let (engine, _join) = spawn_engine(manifest).expect("engine");
    let settings = Settings {
        workers: 2,
        tune_on_miss: false,
        ..Settings::default()
    };

    streamk::trace::set_sample_every(1);
    streamk::trace::set_enabled(true);
    let _ = streamk::trace::drain(); // start from an empty ring

    let coord = Coordinator::start(engine, &settings);
    let handle = coord.handle.clone();
    for _ in 0..8 {
        let w = handle.submit_gemm(
            128,
            128,
            128,
            vec![1.0; 128 * 128],
            vec![1.0; 128 * 128],
        );
        let resp = w.recv().expect("gemm reply");
        assert!(resp.result.is_ok(), "traced gemm must succeed");
    }
    let snap = handle.metrics().snapshot();
    coord.shutdown();
    streamk::trace::set_enabled(false);

    // Block2Time residuals: every completed GEMM paired the scheduler's
    // prediction with its measured execution span.
    assert!(
        !snap.residuals.is_empty(),
        "residual stats must populate under load"
    );
    for r in &snap.residuals {
        assert!(r.count > 0, "{}: empty residual bucket", r.bucket);
        assert!(
            r.ewma_bias.is_finite()
                && r.mean_ape.is_finite()
                && r.p50_ape.is_finite()
                && r.p95_ape.is_finite(),
            "{}: residual stats must be finite",
            r.bucket
        );
    }

    let (events, threads, _dropped) = streamk::trace::drain();
    for want in [
        "request.gemm",
        "coord.place",
        "fleet.place",
        "coord.tuner",
        "coord.route",
        "coord.execute",
        "engine.execute",
        "plan.lookup",
        "kernel.execute",
    ] {
        assert!(
            events.iter().any(|e| e.name == want),
            "request span chain is missing {want:?}"
        );
    }
    assert!(
        events
            .iter()
            .any(|e| e.name.starts_with("kernel.") && e.name != "kernel.execute"),
        "dispatcher pass spans (accumulate/store/fixup) must record"
    );

    // Export → file → re-parse through the in-tree JSON parser.
    let doc = streamk::trace::chrome_trace_json(&events, &threads);
    let path = std::env::temp_dir().join("streamk_e2e_trace.json");
    std::fs::write(&path, streamk::json::to_string_pretty(&doc))
        .expect("write trace file");
    let text = std::fs::read_to_string(&path).expect("read trace back");
    let parsed = streamk::json::parse(&text).expect("trace file must parse");
    let records = parsed.arr("traceEvents").expect("traceEvents array");
    assert!(
        records.len() > events.len(),
        "trace file must hold every span plus thread-name metadata"
    );
    let _ = std::fs::remove_file(&path);
    println!(
        "traced smoke OK: {} spans across {} threads, {} residual bucket(s)",
        events.len(),
        threads.len(),
        snap.residuals.len()
    );
}

/// SLO watchdog smoke: serve with a deliberately impossible p99 target
/// and assert the watchdog trips within one sampling window — forced
/// re-validation fires, the flight recorder captures the timeline, and
/// the breach / re-tune events land in the trace ring.
fn run_slo_smoke() {
    let _guard = streamk::trace::test_lock();
    let dir = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("..")
        .join("examples")
        .join("minimal_artifacts");
    let manifest = Manifest::load(&dir).expect("checked-in minimal manifest");
    let (engine, _join) = spawn_engine(manifest).expect("engine");
    let settings = Settings {
        workers: 2,
        tune_on_miss: false,
        metrics_interval_ms: 5,
        metrics_window: 64,
        slo: Some("p99_ms<=0.0001".into()),
        ..Settings::default()
    };

    streamk::trace::set_sample_every(1);
    streamk::trace::set_enabled(true);
    let _ = streamk::trace::drain(); // start from an empty ring

    let coord = Coordinator::start(engine, &settings);
    let handle = coord.handle.clone();
    for _ in 0..8 {
        let w = handle.submit_gemm(
            128,
            128,
            128,
            vec![1.0; 128 * 128],
            vec![1.0; 128 * 128],
        );
        let resp = w.recv().expect("gemm reply");
        assert!(resp.result.is_ok(), "slo smoke gemm must succeed");
    }
    // Any completed request breaches a 0.1 µs p99 budget; the watchdog
    // samples every 5 ms, so the forced re-tune lands promptly.
    let sw = Stopwatch::start();
    while handle.metrics().snapshot().drift_revalidations == 0 {
        assert!(
            sw.elapsed_secs() < 30.0,
            "watchdog must trip the p99 rule within 30 s"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let sw = Stopwatch::start();
    while coord.recorder().is_empty() {
        assert!(
            sw.elapsed_secs() < 30.0,
            "flight recorder must capture a sample within 30 s"
        );
        std::thread::sleep(std::time::Duration::from_millis(5));
    }
    let samples = coord.recorder().len();
    let snap = handle.metrics().snapshot();
    coord.shutdown();
    streamk::trace::set_enabled(false);

    let (events, _threads, _dropped) = streamk::trace::drain();
    assert!(
        events.iter().any(|e| e.name == "slo.breach"),
        "watchdog must emit an slo.breach event"
    );
    assert!(
        events.iter().any(|e| e.name == "slo.retune"),
        "watchdog must force a re-tune on the breached bucket"
    );

    streamk::bench::dump_json(
        "BENCH_e2e_serve.json",
        streamk::json::obj(vec![
            ("bench", "e2e_serve_slo_smoke".into()),
            ("requests", (snap.requests as usize).into()),
            ("p99_ms", (snap.e2e.quantile_us(0.99) / 1e3).into()),
            (
                "drift_revalidations",
                (snap.drift_revalidations as usize).into(),
            ),
            ("recorder_samples", samples.into()),
        ]),
    );
    println!(
        "slo smoke OK: p99 rule tripped ({} forced re-validation(s), \
         {} recorder sample(s))",
        snap.drift_revalidations, samples
    );
}

fn run_stream(settings: &Settings, requests: usize) -> (f64, u64, f64, f64, f64) {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Manifest::load(&dir).expect("run `make artifacts`");
    let (engine, _join) = spawn_engine(manifest).expect("engine");
    engine
        .warmup(&[
            "mlp_streamk_f32_b8_256x512x256",
            "mlp_streamk_f32_b32_256x512x256",
            "mlp_streamk_f32_b128_256x512x256",
        ])
        .expect("warmup");
    let coord = Coordinator::start(engine, settings);
    let handle = coord.handle.clone();
    let mut rng = Rng::new(0xBEEF);
    let sw = Stopwatch::start();
    let waiters: Vec<_> = (0..requests)
        .map(|i| {
            let rows = if i % 13 == 0 { 8 } else { *rng.choose(&[1usize, 2, 4]) };
            handle.submit_mlp(rows, rng.normal_f32_vec(rows * 256))
        })
        .collect();
    let mut ok = 0usize;
    for w in waiters {
        if w.recv().map(|r| r.result.is_ok()).unwrap_or(false) {
            ok += 1;
        }
    }
    let wall = sw.elapsed_secs();
    assert_eq!(ok, requests, "all requests must succeed");
    let snap = handle.metrics().snapshot();
    coord.shutdown();
    (
        requests as f64 / wall,
        snap.batches,
        snap.mean_batch_rows,
        snap.e2e.quantile_us(0.50) / 1e3,
        snap.e2e.quantile_us(0.95) / 1e3,
    )
}

fn main() {
    // `cargo bench --bench e2e_serve -- --test` forwards `--test`;
    // cargo itself may inject `--bench`, ignored like every other
    // unknown flag (harness = false).
    if std::env::args().skip(1).any(|a| a == "--test") {
        run_smoke();
        run_traced_smoke();
        run_slo_smoke();
        return;
    }
    println!("== 1. batching policy sweep ({REQUESTS} MLP requests) ==\n");
    let mut t = Table::new(&[
        "max_batch", "window µs", "req/s", "batches", "mean rows",
        "p50 ms", "p95 ms",
    ]);
    for (max_batch, window_us) in [
        (1usize, 0u64),      // no batching (batch size 1)
        (8, 200),
        (32, 200),
        (32, 2000),
        (128, 2000),
    ] {
        let settings = Settings {
            workers: 2,
            max_batch,
            batch_window_us: window_us,
            ..Settings::default()
        };
        let (rps, batches, rows, p50, p95) = run_stream(&settings, REQUESTS);
        t.row(&[
            max_batch.to_string(),
            window_us.to_string(),
            format!("{rps:.1}"),
            batches.to_string(),
            format!("{rows:.1}"),
            format!("{p50:.2}"),
            format!("{p95:.2}"),
        ]);
        streamk::bench::dump_json(
            "BENCH_e2e_serve.json",
            streamk::json::obj(vec![
                ("bench", "e2e_serve".into()),
                ("max_batch", max_batch.into()),
                ("window_us", (window_us as usize).into()),
                ("rps", rps.into()),
                ("batches", (batches as usize).into()),
                ("mean_rows", rows.into()),
                ("p50_ms", p50.into()),
                ("p95_ms", p95.into()),
            ]),
        );
    }
    t.print();
    println!(
        "\nexpected shape: throughput rises with batch size (one \
         executable launch amortized over more rows), p95 rises with the \
         window — the classic batching latency/throughput trade.\n"
    );

    println!("== 2. overload / load-shedding ==\n");
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = Manifest::load(&dir).expect("artifacts");
    let (engine, _join) = spawn_engine(manifest).expect("engine");
    engine
        .warmup(&["gemm_streamk_nopad_f32_128x128x128_cu8"])
        .unwrap();
    let settings = Settings { workers: 1, queue_cap: 4, ..Settings::default() };
    let coord = Coordinator::start(engine, &settings);
    let mut shed = 0usize;
    let mut accepted = Vec::new();
    for _ in 0..200 {
        match coord.handle.try_submit_gemm(
            128,
            128,
            128,
            vec![1.0; 128 * 128],
            vec![1.0; 128 * 128],
        ) {
            Some(w) => accepted.push(w),
            None => shed += 1,
        }
    }
    for w in accepted {
        let _ = w.recv();
    }
    let snap = coord.handle.metrics().snapshot();
    println!(
        "200 burst submissions, queue_cap=4: {} accepted+done, {shed} shed \
         (metrics agree: {})",
        snap.completed, snap.shed
    );
    assert_eq!(snap.shed as usize, shed);

    println!("\n== 3. tuner cache effectiveness (GEMM path) ==\n");
    let total = snap.tuner_hits + snap.tuner_misses;
    println!(
        "tuner consults {total}: {} hits / {} misses ({:.1}% hit rate) | \
         background tunes {} (mean {:.1} ms, p95 {:.1} ms)",
        snap.tuner_hits,
        snap.tuner_misses,
        if total > 0 {
            snap.tuner_hits as f64 / total as f64 * 100.0
        } else {
            0.0
        },
        snap.tunes,
        snap.tune.mean_us() / 1e3,
        snap.tune.quantile_us(0.95) / 1e3,
    );
    // every accepted GEMM consulted the cache exactly once
    assert_eq!(total, snap.completed + snap.failed);
    coord.shutdown();
    println!("\ne2e_serve OK");
}
