//! PLAN — plan-cache effectiveness: the cached hit path vs a full
//! schedule rebuild, on a repeated-shape serving trace.
//!
//! Acceptance demonstration for the zero-rebuild hot path: (1) pricing
//! a request through the cached `FlatSchedule` plan is strictly faster
//! than rebuilding the `StreamKSchedule` + nested work lists per
//! request; (2) on a repeated-shape trace the cache's hit rate exceeds
//! 90% and the hit path performs zero schedule builds.
//!
//! Run: `cargo bench --bench plan_cache`
//! CI smoke: `cargo bench --bench plan_cache -- --test`

use std::sync::Arc;

use streamk::bench::{bench, keep, Table};
use streamk::decomp::{build_schedule, BlockShape, GemmShape};
use streamk::fleet::{gen_trace, ShapeMix};
use streamk::gpu_sim::{simulate_streamk, Device, DeviceKind};
use streamk::plan::{warm_parallel, PlanCache, PlanKey};

fn main() {
    let quick = std::env::args().skip(1).any(|a| a == "--test");
    let (iters, requests) = if quick { (40usize, 200usize) } else { (400, 2000) };
    let dev = Device::preset(DeviceKind::Mi200);

    println!("== 1. hit path vs rebuild path (per-request pricing) ==\n");
    let mut t = Table::new(&[
        "shape", "rebuild µs", "hit µs", "speedup", "items",
    ]);
    let shapes = [
        GemmShape::new(3840, 4096, 4096),
        GemmShape::new(1920, 2000, 2000),
        GemmShape::new(1000, 1000, 1000), // ragged: fixup launch
        GemmShape::new(480, 512, 512),
    ];
    // Cold plan construction fans out over the worker pool.
    let cache = Arc::new(PlanCache::new(64, 4));
    let keys: Vec<PlanKey> = shapes
        .iter()
        .map(|&s| PlanKey::new(s, BlockShape::default(), 4, dev.num_cus))
        .collect();
    let built = warm_parallel(&cache, &keys, 4);
    assert_eq!(built, shapes.len(), "parallel warm builds every cold key");

    let mut all_faster = true;
    for &shape in &shapes {
        // Rebuild path: what every request used to pay — construct the
        // schedule, materialize nested work lists, simulate.
        let rebuild = bench(2, iters, || {
            let sched =
                build_schedule(shape, BlockShape::default(), dev.num_cus)
                    .unwrap();
            keep(simulate_streamk(&dev, &sched, 4).total_s);
        });
        // Hit path: the shared warm cache, plan replayed per request.
        let hit = bench(2, iters, || {
            let plan = cache
                .get_or_build(shape, BlockShape::default(), 4, dev.num_cus)
                .unwrap();
            keep(plan.time_on(&dev));
        });
        let speedup = rebuild.median / hit.median.max(1e-12);
        all_faster &= hit.median < rebuild.median;
        let items = cache
            .peek(shape, BlockShape::default(), 4, dev.num_cus)
            .unwrap()
            .flat
            .num_items();
        t.row(&[
            format!("{}x{}x{}", shape.m, shape.n, shape.k),
            format!("{:.2}", rebuild.median * 1e6),
            format!("{:.3}", hit.median * 1e6),
            format!("{speedup:.0}x"),
            items.to_string(),
        ]);
    }
    t.print();
    // Acceptance: the cached hit path is strictly faster than the
    // rebuild path on every shape.
    assert!(
        all_faster,
        "cached hit path must beat the rebuild path on every shape"
    );

    println!("\n== 2. repeated-shape serving trace ==\n");
    let cache = Arc::new(PlanCache::new(256, 8));
    let mix = ShapeMix::skewed_default();
    let trace = gen_trace(11, requests, &mix);
    for &shape in &trace {
        cache
            .get_or_build(shape, BlockShape::default(), 4, dev.num_cus)
            .unwrap();
    }
    let s = cache.stats();
    println!(
        "{} requests over {} distinct shapes: {} hits / {} misses \
         ({:.1}% hit rate) | {} builds | {:.2} ms total build time",
        requests,
        mix.shapes().len(),
        s.hits,
        s.misses,
        s.hit_rate() * 100.0,
        s.builds,
        s.build_time_s * 1e3,
    );
    // Acceptance: >90% hit rate, and the number of schedule builds is
    // the number of distinct shapes — the hit path never rebuilds.
    assert!(
        s.hit_rate() > 0.9,
        "repeated-shape trace must hit >90%: {:.3}",
        s.hit_rate()
    );
    assert_eq!(
        s.builds as usize,
        mix.shapes().len(),
        "hit path must not rebuild schedules"
    );

    println!("\nplan_cache OK");
}
