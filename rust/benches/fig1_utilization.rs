//! FIG1 — regenerates Figure 1: CU utilization of the conventional
//! tile-based decomposition vs Stream-K.
//!
//! The paper's figure shows a partial final wave leaving 25% of the
//! device idle (75% utilization). We print (a) that canonical example
//! with per-CU bars, (b) the utilization sweep over tile counts (the
//! sawtooth), and (c) simulated-device utilization for the Table-1
//! shapes. Run: `cargo bench --bench fig1_utilization`.

use streamk::bench::{fmt_pct, Table};
use streamk::decomp::{occupancy, swizzle::Swizzle, tile, BlockShape, GemmShape, TileGrid};
use streamk::gpu_sim::{gemm, Device, DeviceKind};

fn main() {
    println!("== FIG1(a): the canonical example — 3 tiles on 4 CUs ==\n");
    let load = occupancy::dp_cu_load(3, 4);
    for (cu, l) in load.iter().enumerate() {
        let bar = "█".repeat((l * 30.0) as usize);
        println!("  CU{cu}: {bar:<30} {:.0}%", l * 100.0);
    }
    let dp = occupancy::dp_efficiency(3, 4);
    let sk = occupancy::sk_efficiency(
        GemmShape::new(3 * 128, 128, 4096),
        BlockShape::default(),
        4,
    );
    println!("\n  conventional tile output utilization: {}", fmt_pct(dp));
    println!("  stream-k utilization (same problem):  {}", fmt_pct(sk));
    println!("  paper reports: 75% for the conventional example\n");
    assert!((dp - 0.75).abs() < 1e-9, "Figure-1 anchor point must be 75%");

    println!("== FIG1(b): utilization vs tile count, 120 CUs (sawtooth) ==\n");
    let mut t = Table::new(&["tiles", "waves", "dp util", "sk util"]);
    let pts = occupancy::utilization_sweep(
        BlockShape::default(),
        120,
        4096,
        4096,
        (1..=16).map(|i| i * 30 * 128 / 8), // tiles_m sweep → 30..480 tiles... m values
    );
    for p in &pts {
        t.row(&[
            p.num_tiles.to_string(),
            format!("{:.2}", p.waves),
            fmt_pct(p.dp_efficiency),
            fmt_pct(p.sk_efficiency),
        ]);
    }
    t.print();
    let worst = pts
        .iter()
        .min_by(|a, b| a.dp_efficiency.total_cmp(&b.dp_efficiency))
        .unwrap();
    println!(
        "\n  worst dp point: {} tiles at {} — stream-k holds {}\n",
        worst.num_tiles,
        fmt_pct(worst.dp_efficiency),
        fmt_pct(worst.sk_efficiency)
    );

    println!("== FIG1(c): simulated MI200 utilization, Table-1 shapes ==\n");
    let dev = Device::preset(DeviceKind::Mi200);
    let mut t = Table::new(&["shape", "tiles", "dp util", "sk util", "sk speedup"]);
    for (m, n, k) in [
        (3840usize, 4096usize, 4096usize),
        (3968, 4096, 4096), // +1 tile row: the quantization cliff
        (3, 9, 9),
        (1920, 2000, 2000),
        (480, 512, 512),
    ] {
        let shape = GemmShape::new(m, n, k);
        let block = BlockShape::default().effective(shape);
        let grid = TileGrid::new(shape, block);
        let dp = gemm::simulate(
            &dev,
            shape,
            grid,
            tile::dp_assignment(grid, dev.num_cus, Swizzle::RowMajor),
            block,
            4,
        );
        let sched =
            streamk::decomp::build_schedule(shape, block, dev.num_cus).unwrap();
        let sk = gemm::simulate_streamk(&dev, &sched, 4);
        t.row(&[
            format!("{m}x{n}x{k}"),
            grid.num_tiles().to_string(),
            fmt_pct(dp.utilization),
            fmt_pct(sk.utilization),
            format!("{:.3}x", dp.total_s / sk.total_s),
        ]);
    }
    t.print();
    println!(
        "\nexpected shape (paper): dp sawtooths and dips below 80% off \
         full waves; stream-k stays ~flat near 100% and never loses."
    );
}
