//! TUNE — tuned-vs-default across the Table-1 shape suite.
//!
//! The report's parameter exploration ended with the process "getting
//! stuck"; this bench demonstrates the tuner subsystem closing that
//! loop: (1) the legality-pruned space statistics, (2) per-shape
//! tuned-vs-default simulated times with the winning configuration,
//! (3) a cold-cache `tune` of every suite shape completing inside its
//! budget, and (4) persistent-cache round-trip (store, reload, hit).
//!
//! Run: `cargo bench --bench tuner_gain`
//! CI smoke: `cargo bench --bench tuner_gain -- --test` (tight budget)

use streamk::bench::Table;
use streamk::decomp::GemmShape;
use streamk::exec::Stopwatch;
use streamk::gpu_sim::{Device, DeviceKind};
use streamk::tuner::{
    Budget, ShapeBucket, TuneOptions, Tuner, TABLE1_SUITE,
};

fn main() {
    // `cargo bench --bench tuner_gain -- --test` forwards `--test`;
    // cargo itself may inject `--bench`, which is ignored like every
    // other unknown flag (harness = false).
    let quick = std::env::args().skip(1).any(|a| a == "--test");
    let budget_ms: u64 = if quick { 250 } else { 1000 };

    let dev = Device::preset(DeviceKind::Mi200);
    let opts = TuneOptions {
        top_k: 8,
        budget: Budget::from_millis(budget_ms),
        ..TuneOptions::default()
    };
    let tuner = Tuner::new(dev, opts, 64);

    println!("== 1. tuned vs default (simulated MI200, Table-1 suite) ==\n");
    // "tuned at" = the pow2 bucket representative the times were
    // actually simulated at (what the cache entry serves), not the
    // requested shape.
    let mut t = Table::new(&[
        "shape", "tuned at", "default ms", "tuned ms", "speedup", "block",
        "pad", "cus", "legal/total", "tune ms",
    ]);
    let mut strict_wins = 0usize;
    let mut reports = Vec::new();
    for &(m, n, k) in TABLE1_SUITE {
        let shape = GemmShape::new(m, n, k);
        let sw = Stopwatch::start();
        let r = tuner.tune_and_insert(shape).expect("tune");
        let wall = sw.elapsed_secs();

        // The budget guarantee — the "stuck" failure mode is impossible:
        // one tune never runs longer than budget + bounded slack.
        assert!(
            wall < (budget_ms as f64 / 1e3) * 4.0 + 2.0,
            "{m}x{n}x{k}: tune took {wall}s against a {budget_ms}ms budget"
        );
        // Tuned must never lose to the default config.
        assert!(
            r.best.measured_s <= r.default_s * (1.0 + 1e-9),
            "{m}x{n}x{k}: tuned {} worse than default {}",
            r.best.measured_s,
            r.default_s
        );
        if r.best.measured_s < r.default_s * (1.0 - 1e-6) {
            strict_wins += 1;
        }
        let blk = r.best.params.block;
        t.row(&[
            format!("{m}x{n}x{k}"),
            format!("{}x{}x{}", r.shape.m, r.shape.n, r.shape.k),
            format!("{:.4}", r.default_s * 1e3),
            format!("{:.4}", r.best.measured_s * 1e3),
            format!("{:.3}x", r.speedup()),
            format!("{}x{}x{}", blk.bm, blk.bn, blk.bk),
            r.best.pad.as_str().to_string(),
            r.best.cus.to_string(),
            format!("{}/{}", r.space.legal, r.space.total),
            format!("{:.1}", r.elapsed_s * 1e3),
        ]);
        reports.push(r);
    }
    t.print();

    // Acceptance: the tuned config beats the default on at least half
    // of the suite (the tiny 3x9x9 shape collapses every candidate to
    // the same point, so it legitimately ties).
    assert!(
        strict_wins * 2 >= TABLE1_SUITE.len(),
        "only {strict_wins}/{} strict wins",
        TABLE1_SUITE.len()
    );
    println!(
        "\nstrict wins: {strict_wins}/{} (ties are shapes whose effective \
         block collapses the space)\n",
        TABLE1_SUITE.len()
    );

    println!("== 2. legality pruning (what the report hit as opaque failures) ==\n");
    let space = &reports[0].space;
    let mut t = Table::new(&["rejection reason", "points"]);
    for (reason, count) in &space.pruned {
        t.row(&[reason.to_string(), count.to_string()]);
    }
    t.print();
    println!(
        "\n{} of {} block configurations rejected by the legality \
         predicate (never measured); the survivors expand to {} \
         candidates ({} kept, {} collapsed by effective-block dedup).\n",
        space.illegal_blocks,
        space.block_points,
        space.total,
        space.legal,
        space.deduped
    );

    println!("== 3. persistent cache round-trip ==\n");
    let path = std::env::temp_dir().join(format!(
        "streamk-tuner-gain-{}.json",
        std::process::id()
    ));
    tuner.store_cache(&path).expect("store");
    let fresh = Tuner::new(
        Device::preset(DeviceKind::Mi200),
        TuneOptions::default(),
        64,
    );
    let n = fresh.load_cache(&path).expect("load");
    assert_eq!(n, {
        // suite shapes may share pow2 buckets; count distinct buckets
        let mut buckets: Vec<String> = TABLE1_SUITE
            .iter()
            .map(|&(m, n, k)| ShapeBucket::of(GemmShape::new(m, n, k)).key())
            .collect();
        buckets.sort();
        buckets.dedup();
        buckets.len()
    });
    for &(m, n, k) in TABLE1_SUITE {
        assert!(
            fresh.lookup(GemmShape::new(m, n, k)).is_some(),
            "warm cache must hit {m}x{n}x{k}"
        );
    }
    std::fs::remove_file(&path).expect("cleanup");
    println!(
        "stored {n} bucket entries, reloaded cold, every suite shape hits.\n"
    );
    println!("tuner_gain OK");
}
