//! Block2Time residual accounting: predicted vs. measured latency.
//!
//! The paper closes on Block2Time being promising "in enhancing runtime
//! predictions and optimizing load balancing" — which is only actionable
//! if prediction error is *measured* (the multi-precision DMM tuning
//! line of work tracks exactly this residual). Every executed request
//! pairs the scheduler's predicted latency ([`crate::fleet::Placement`]
//! `predicted_s`, itself `Plan::time_on` / tuner-cache backed) with the
//! measured execute span, bucketed by [`crate::tuner::ShapeBucket`]:
//!
//! - **EWMA bias** — signed exponentially-weighted mean of
//!   `(predicted − measured) / measured`; positive means the model is
//!   optimistic about this bucket being slow (over-predicts), negative
//!   means it under-predicts.
//! - **APE distribution** — absolute percentage error per request in a
//!   log₂ [`Histogram`] (recorded as fraction-seconds, so `p95/1e6` is
//!   the p95 APE fraction), with linear in-bucket interpolation from
//!   the quantile fix in this PR.
//!
//! The tracker lives in [`crate::coordinator::Metrics`] (serialized in
//! the snapshot JSON under `"residuals"`), and the measured residual —
//! not the blended tuner observation — is what trips drift re-tunes via
//! `Fleet::observe_residual`.

use crate::coordinator::Histogram;
use crate::json::{obj, Value};

/// Default EWMA smoothing for the signed bias (matches the tuner's
/// observation alpha so the two feedback loops settle at comparable
/// speed). Overridable per tracker ([`ResidualTracker::with_alpha`])
/// and process-wide via `STREAMK_OBSERVE_ALPHA` — the same knob that
/// steers [`crate::tuner::BlendConfig`], keeping the two loops in sync.
const BIAS_ALPHA: f64 = 0.3;

fn default_alpha() -> f64 {
    std::env::var("STREAMK_OBSERVE_ALPHA")
        .ok()
        .and_then(|v| v.trim().parse::<f64>().ok())
        .filter(|v| v.is_finite() && *v > 0.0 && *v <= 1.0)
        .unwrap_or(BIAS_ALPHA)
}

#[derive(Debug, Clone)]
struct Bucket {
    key: String,
    count: u64,
    ewma_bias: f64,
    /// APE fractions recorded as "seconds" (fraction 0.25 → 250_000µs).
    ape: Histogram,
}

/// Per-shape-bucket prediction residual statistics.
#[derive(Debug)]
pub struct ResidualTracker {
    buckets: Vec<Bucket>,
    alpha: f64,
}

impl Default for ResidualTracker {
    fn default() -> Self {
        Self { buckets: Vec::new(), alpha: default_alpha() }
    }
}

/// Point-in-time view of one bucket, for snapshots/serialization.
#[derive(Debug, Clone, PartialEq)]
pub struct ResidualSnapshot {
    pub bucket: String,
    pub count: u64,
    /// Signed EWMA of (predicted − measured) / measured.
    pub ewma_bias: f64,
    pub mean_ape: f64,
    pub p50_ape: f64,
    pub p95_ape: f64,
}

impl ResidualTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Override the bias-EWMA smoothing weight (must be in (0, 1];
    /// out-of-range values keep the current weight).
    pub fn with_alpha(mut self, alpha: f64) -> Self {
        if alpha.is_finite() && alpha > 0.0 && alpha <= 1.0 {
            self.alpha = alpha;
        }
        self
    }

    /// Record one (predicted, measured) pair for `bucket_key`. Returns
    /// the absolute percentage error, or `None` when the pair is
    /// degenerate (non-finite or non-positive measurement) and was
    /// dropped.
    pub fn observe(
        &mut self,
        bucket_key: &str,
        predicted_s: f64,
        measured_s: f64,
    ) -> Option<f64> {
        if !predicted_s.is_finite()
            || !measured_s.is_finite()
            || measured_s <= 0.0
            || predicted_s < 0.0
        {
            return None;
        }
        let rel = (predicted_s - measured_s) / measured_s;
        let ape = rel.abs();
        let b = match self.buckets.iter_mut().find(|b| b.key == bucket_key) {
            Some(b) => b,
            None => {
                self.buckets.push(Bucket {
                    key: bucket_key.to_string(),
                    count: 0,
                    ewma_bias: 0.0,
                    ape: Histogram::default(),
                });
                self.buckets.last_mut().expect("just pushed")
            }
        };
        b.ewma_bias = if b.count == 0 {
            rel
        } else {
            self.alpha * rel + (1.0 - self.alpha) * b.ewma_bias
        };
        b.count += 1;
        b.ape.record_secs(ape);
        Some(ape)
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }

    /// Per-bucket snapshot, insertion-ordered (first-seen bucket first).
    pub fn snapshot(&self) -> Vec<ResidualSnapshot> {
        self.buckets
            .iter()
            .map(|b| ResidualSnapshot {
                bucket: b.key.clone(),
                count: b.count,
                ewma_bias: b.ewma_bias,
                mean_ape: b.ape.mean_us() / 1e6,
                p50_ape: b.ape.quantile_us(0.5) / 1e6,
                p95_ape: b.ape.quantile_us(0.95) / 1e6,
            })
            .collect()
    }
}

/// Bucket key carrying a fleet device dimension: `dev{idx}|{bucket}`.
/// Without it, residuals from a heterogeneous fleet (a 4×-speed-range
/// device set) collapse into one shape bucket and skew the EWMA bias
/// that drives re-tunes. Single-device serving keeps the bare shape
/// key so existing dashboards (and tests) are unchanged.
pub fn device_key(device: usize, bucket: &str) -> String {
    format!("dev{device}|{bucket}")
}

/// Split a bucket key back into its optional device index and the
/// shape-bucket part. Keys without a `dev<idx>|` prefix return
/// `(None, key)` unchanged.
pub fn split_device_key(key: &str) -> (Option<usize>, &str) {
    if let Some(rest) = key.strip_prefix("dev") {
        if let Some((idx, bucket)) = rest.split_once('|') {
            if let Ok(idx) = idx.parse::<usize>() {
                return (Some(idx), bucket);
            }
        }
    }
    (None, key)
}

impl ResidualSnapshot {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("bucket", self.bucket.as_str().into()),
            ("count", (self.count as usize).into()),
            ("ewma_bias", self.ewma_bias.into()),
            ("mean_ape", self.mean_ape.into()),
            ("p50_ape", self.p50_ape.into()),
            ("p95_ape", self.p95_ape.into()),
        ])
    }

    /// One-line human form for `streamk serve` / `streamk fleet`.
    pub fn summary(&self) -> String {
        format!(
            "{}: n={} bias={:+.1}% p50_ape={:.1}% p95_ape={:.1}%",
            self.bucket,
            self.count,
            self.ewma_bias * 100.0,
            self.p50_ape * 100.0,
            self.p95_ape * 100.0,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bias_is_signed_and_ape_is_not() {
        let mut t = ResidualTracker::new();
        // prediction consistently 20% low
        for _ in 0..50 {
            t.observe("128x128x128", 0.8e-3, 1.0e-3);
        }
        let snap = t.snapshot();
        assert_eq!(snap.len(), 1);
        let s = &snap[0];
        assert_eq!(s.bucket, "128x128x128");
        assert_eq!(s.count, 50);
        assert!(
            (s.ewma_bias + 0.2).abs() < 1e-9,
            "bias {}",
            s.ewma_bias
        );
        // APE ~0.2; in-bucket interpolation keeps quantiles near truth
        assert!((s.p50_ape - 0.2).abs() < 0.05, "p50 {}", s.p50_ape);
        assert!((s.p95_ape - 0.2).abs() < 0.07, "p95 {}", s.p95_ape);
        assert!((s.mean_ape - 0.2).abs() < 1e-6);
    }

    #[test]
    fn ewma_tracks_regime_change() {
        let mut t = ResidualTracker::new();
        for _ in 0..30 {
            t.observe("b", 1.0, 1.0); // perfect
        }
        assert!(t.snapshot()[0].ewma_bias.abs() < 1e-12);
        for _ in 0..30 {
            t.observe("b", 2.0, 1.0); // +100% over-prediction
        }
        let bias = t.snapshot()[0].ewma_bias;
        assert!(bias > 0.99, "bias should converge up: {bias}");
    }

    #[test]
    fn alpha_override_changes_settling_speed() {
        // alpha = 1.0: the bias IS the last relative error.
        let mut fast = ResidualTracker::new().with_alpha(1.0);
        fast.observe("b", 1.0, 1.0);
        fast.observe("b", 2.0, 1.0);
        assert!((fast.snapshot()[0].ewma_bias - 1.0).abs() < 1e-12);
        // a tiny alpha barely moves off the first sample
        let mut slow = ResidualTracker::new().with_alpha(0.01);
        slow.observe("b", 1.0, 1.0);
        slow.observe("b", 2.0, 1.0);
        assert!(slow.snapshot()[0].ewma_bias < 0.05);
        // invalid overrides are ignored
        let t = ResidualTracker::new().with_alpha(f64::NAN).with_alpha(2.0);
        assert!((t.alpha - default_alpha()).abs() < 1e-12);
    }

    #[test]
    fn degenerate_pairs_are_dropped() {
        let mut t = ResidualTracker::new();
        assert!(t.observe("b", 1.0, 0.0).is_none());
        assert!(t.observe("b", f64::NAN, 1.0).is_none());
        assert!(t.observe("b", 1.0, f64::INFINITY).is_none());
        assert!(t.observe("b", -1.0, 1.0).is_none());
        assert!(t.is_empty());
        assert!(t.observe("b", 1.0, 1.0).is_some());
        assert_eq!(t.snapshot()[0].count, 1);
    }

    #[test]
    fn device_keys_round_trip() {
        let k = device_key(3, "128x128x128");
        assert_eq!(k, "dev3|128x128x128");
        assert_eq!(split_device_key(&k), (Some(3), "128x128x128"));
        // bare shape keys pass through untouched
        assert_eq!(split_device_key("64x64x64"), (None, "64x64x64"));
        // malformed prefixes are not device keys
        assert_eq!(split_device_key("devx|64"), (None, "devx|64"));
        assert_eq!(split_device_key("dev12"), (None, "dev12"));
        // device-keyed buckets track independently
        let mut t = ResidualTracker::new();
        t.observe(&device_key(0, "64x64x64"), 1.1, 1.0);
        t.observe(&device_key(1, "64x64x64"), 0.5, 1.0);
        let snap = t.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0].bucket, "dev0|64x64x64");
        assert_eq!(snap[1].bucket, "dev1|64x64x64");
    }

    #[test]
    fn buckets_are_independent_and_serialize() {
        let mut t = ResidualTracker::new();
        t.observe("a", 1.1, 1.0);
        t.observe("b", 0.5, 1.0);
        let snap = t.snapshot();
        assert_eq!(snap.len(), 2);
        assert!(snap[0].ewma_bias > 0.0 && snap[1].ewma_bias < 0.0);
        let j = snap[0].to_json();
        assert_eq!(j.s("bucket").unwrap(), "a");
        assert_eq!(j.u("count").unwrap(), 1);
        assert!(j.f("ewma_bias").unwrap() > 0.0);
        assert!(j.f("p95_ape").unwrap().is_finite());
        assert!(snap[1].summary().contains("p95_ape"));
    }
}
