//! Roofline attribution profiler.
//!
//! The source paper reports aggregate numbers (89.37 TFlops, 66.91
//! GB/s) but could not say *where* the time went — its block-mapping
//! bug survived precisely because per-phase attribution was missing.
//! This module is the quantitative layer on top of the PR-6 spans:
//! per-dispatch counters (flops executed, bytes packed, bytes stored,
//! tiles per ownership class, wall time per dispatcher pass)
//! accumulated behind the same one-atomic-load gate as the span
//! recorder, folded into per-shape-bucket totals, and reported as
//! achieved GFLOPS / GB/s against the roofline ceiling with a
//! pack/compute/store/fixup breakdown.
//!
//! Hot-path contract: when disabled, the dispatcher pays one relaxed
//! atomic load plus a handful of `Option` branches per dispatch — the
//! `kernel_exec -- --test` smoke gates this at ≤ 1% of dispatch time,
//! same harness as the span gate. When enabled, workers bump shared
//! `AtomicU64`s (relaxed; the counters are commutative sums) and the
//! dispatching thread times each pass; the global registry lock is
//! taken once per dispatch, never inside the worker loop.

use crate::decomp::intensity::{Roofline, CPU_1CORE};
use crate::decomp::GemmShape;
use crate::json::{obj, Value};
use crate::kernel::Width;
use crate::tuner::ShapeBucket;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Turn the attribution profiler on or off (process-wide).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Release);
}

/// The dispatcher's gate — one relaxed atomic load.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Per-dispatch shared counters, bumped by compute workers.
///
/// All fields are commutative sums, so relaxed ordering is sufficient;
/// the dispatcher reads them only after the worker scope has joined.
#[derive(Debug, Default)]
pub struct DispatchCounters {
    /// Nanoseconds spent inside panel packing (summed across workers;
    /// workers overlap, so this can exceed pass wall time).
    pub pack_ns: AtomicU64,
    /// Bytes copied into packed A/B panels.
    pub pack_bytes: AtomicU64,
    /// FLOPs executed (2 per multiply-accumulate).
    pub flops: AtomicU64,
    /// Bytes stored into C (direct, windowed, and fixup stores).
    pub store_bytes: AtomicU64,
}

/// Wall time per dispatcher pass, measured on the dispatching thread.
/// The passes run sequentially there, so their sum approximates the
/// dispatch wall time — that closure is the ≥95%-accounted criterion.
#[derive(Debug, Default, Clone, Copy)]
pub struct PassTimes {
    pub direct_ns: u64,
    pub windowed_ns: u64,
    pub store_ns: u64,
    pub fixup_ns: u64,
}

#[derive(Debug, Default, Clone)]
struct BucketTotals {
    key: String,
    width: Width,
    dispatches: u64,
    flops: u64,
    pack_bytes: u64,
    store_bytes: u64,
    owned: u64,
    ordered: u64,
    partial: u64,
    fixup_tiles: u64,
    pack_ns: u64,
    direct_ns: u64,
    windowed_ns: u64,
    store_ns: u64,
    fixup_ns: u64,
    total_ns: u64,
}

fn registry() -> &'static Mutex<Vec<BucketTotals>> {
    static REG: OnceLock<Mutex<Vec<BucketTotals>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

/// Bucket key for one (shape bucket, element width) attribution slot.
/// f32 keeps the bare bucket key (back-compatible with existing lookups
/// and the bench's headline rows); 16-bit widths get an `@width` suffix
/// so per-width GB/s and residual APE never mix — streamed bytes halve
/// at bf16/f16 and averaging across widths would hide exactly the
/// accounting drift this profiler exists to expose.
pub fn width_key(bucket: &str, width: Width) -> String {
    match width {
        Width::F32 => bucket.to_string(),
        w => format!("{bucket}@{w}"),
    }
}

/// Inverse of [`width_key`]: split a registry key back into the bare
/// bucket key and the element width (f32 when no suffix is present).
pub fn split_width_key(key: &str) -> (&str, Width) {
    if let Some((bucket, tag)) = key.rsplit_once('@') {
        if let Some(w) = Width::parse(tag) {
            return (bucket, w);
        }
    }
    (key, Width::F32)
}

/// Fold one finished dispatch into the per-bucket registry.
/// `classes` is the descriptor's (owned, ordered, partial) tile-store
/// class counts; `total_ns` is the dispatch wall time. `width` is the
/// dispatch's element width — it selects the attribution slot (see
/// [`width_key`]) and is echoed in the JSON report.
pub fn record_dispatch(
    shape: GemmShape,
    width: Width,
    classes: (usize, usize, usize),
    fixup_tiles: usize,
    ctr: &DispatchCounters,
    times: &PassTimes,
    total_ns: u64,
) {
    let key = width_key(&ShapeBucket::of(shape).key(), width);
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let slot = match reg.iter_mut().find(|b| b.key == key) {
        Some(b) => b,
        None => {
            reg.push(BucketTotals { key, width, ..BucketTotals::default() });
            reg.last_mut().expect("just pushed")
        }
    };
    slot.dispatches += 1;
    slot.flops += ctr.flops.load(Ordering::Relaxed);
    slot.pack_bytes += ctr.pack_bytes.load(Ordering::Relaxed);
    slot.store_bytes += ctr.store_bytes.load(Ordering::Relaxed);
    slot.owned += classes.0 as u64;
    slot.ordered += classes.1 as u64;
    slot.partial += classes.2 as u64;
    slot.fixup_tiles += fixup_tiles as u64;
    slot.pack_ns += ctr.pack_ns.load(Ordering::Relaxed);
    slot.direct_ns += times.direct_ns;
    slot.windowed_ns += times.windowed_ns;
    slot.store_ns += times.store_ns;
    slot.fixup_ns += times.fixup_ns;
    slot.total_ns += total_ns;
}

/// Aggregated attribution for one shape bucket.
#[derive(Debug, Clone)]
pub struct BucketProfile {
    pub bucket: String,
    pub width: Width,
    pub dispatches: u64,
    pub flops: u64,
    pub pack_bytes: u64,
    pub store_bytes: u64,
    pub owned: u64,
    pub ordered: u64,
    pub partial: u64,
    pub fixup_tiles: u64,
    pub pack_ns: u64,
    pub direct_ns: u64,
    pub windowed_ns: u64,
    pub store_ns: u64,
    pub fixup_ns: u64,
    pub total_ns: u64,
}

impl BucketProfile {
    fn from_totals(t: &BucketTotals) -> Self {
        Self {
            bucket: t.key.clone(),
            width: t.width,
            dispatches: t.dispatches,
            flops: t.flops,
            pack_bytes: t.pack_bytes,
            store_bytes: t.store_bytes,
            owned: t.owned,
            ordered: t.ordered,
            partial: t.partial,
            fixup_tiles: t.fixup_tiles,
            pack_ns: t.pack_ns,
            direct_ns: t.direct_ns,
            windowed_ns: t.windowed_ns,
            store_ns: t.store_ns,
            fixup_ns: t.fixup_ns,
            total_ns: t.total_ns,
        }
    }

    pub fn total_s(&self) -> f64 {
        self.total_ns as f64 / 1e9
    }

    /// Achieved compute throughput over the dispatch wall time.
    pub fn achieved_gflops(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        self.flops as f64 / self.total_s() / 1e9
    }

    /// Achieved memory throughput (packed + stored bytes; operands are
    /// read through the pack, C is written through the stores).
    pub fn achieved_gbps(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        (self.pack_bytes + self.store_bytes) as f64 / self.total_s() / 1e9
    }

    /// Measured arithmetic intensity (flops per byte actually moved).
    pub fn ai(&self) -> f64 {
        let bytes = (self.pack_bytes + self.store_bytes) as f64;
        if bytes == 0.0 {
            return 0.0;
        }
        self.flops as f64 / bytes
    }

    /// Achieved fraction of the roofline-attainable FLOP/s at this
    /// bucket's measured arithmetic intensity.
    pub fn efficiency(&self, roofline: &Roofline) -> f64 {
        let attainable = roofline.attainable(self.ai());
        if attainable == 0.0 || self.total_ns == 0 {
            return 0.0;
        }
        (self.flops as f64 / self.total_s()) / attainable
    }

    /// Fraction of the dispatch wall time attributed to a pass. The
    /// passes run sequentially on the dispatching thread, so this
    /// should be ≥ 0.95 on real shapes (the acceptance gate).
    pub fn accounted(&self) -> f64 {
        if self.total_ns == 0 {
            return 0.0;
        }
        (self.direct_ns + self.windowed_ns + self.store_ns + self.fixup_ns)
            as f64
            / self.total_ns as f64
    }

    /// The dispatch element width this bucket aggregates.
    pub fn width(&self) -> Width {
        self.width
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("bucket", self.bucket.clone().into()),
            ("width", self.width.name().into()),
            ("dispatches", (self.dispatches as usize).into()),
            ("flops", (self.flops as usize).into()),
            ("pack_bytes", (self.pack_bytes as usize).into()),
            ("store_bytes", (self.store_bytes as usize).into()),
            ("owned", (self.owned as usize).into()),
            ("ordered", (self.ordered as usize).into()),
            ("partial", (self.partial as usize).into()),
            ("fixup_tiles", (self.fixup_tiles as usize).into()),
            ("pack_ms", (self.pack_ns as f64 / 1e6).into()),
            ("direct_ms", (self.direct_ns as f64 / 1e6).into()),
            ("windowed_ms", (self.windowed_ns as f64 / 1e6).into()),
            ("store_ms", (self.store_ns as f64 / 1e6).into()),
            ("fixup_ms", (self.fixup_ns as f64 / 1e6).into()),
            ("total_ms", (self.total_ns as f64 / 1e6).into()),
            ("gflops", self.achieved_gflops().into()),
            ("gbps", self.achieved_gbps().into()),
            ("ai", self.ai().into()),
            ("accounted", self.accounted().into()),
        ])
    }

    /// One human-readable attribution line.
    pub fn summary(&self, roofline: &Roofline) -> String {
        format!(
            "{}: n={} {:.2} ms | {:.2} GFLOPS {:.2} GB/s ai={:.1} \
             eff={:.1}% | direct={:.0}% windowed={:.0}% store={:.0}% \
             fixup={:.0}% (pack {:.2} ms) acct={:.0}%",
            self.bucket,
            self.dispatches,
            self.total_ns as f64 / 1e6,
            self.achieved_gflops(),
            self.achieved_gbps(),
            self.ai(),
            self.efficiency(roofline) * 100.0,
            self.pct(self.direct_ns),
            self.pct(self.windowed_ns),
            self.pct(self.store_ns),
            self.pct(self.fixup_ns),
            self.pack_ns as f64 / 1e6,
            self.accounted() * 100.0,
        )
    }

    fn pct(&self, ns: u64) -> f64 {
        if self.total_ns == 0 {
            0.0
        } else {
            ns as f64 / self.total_ns as f64 * 100.0
        }
    }
}

/// Copy the current per-bucket totals (sorted by total time, hottest
/// first) without clearing them.
pub fn snapshot() -> Vec<BucketProfile> {
    let reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let mut out: Vec<_> =
        reg.iter().map(BucketProfile::from_totals).collect();
    out.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
    out
}

/// Take and clear the per-bucket totals.
pub fn drain() -> Vec<BucketProfile> {
    let mut reg = registry().lock().unwrap_or_else(|e| e.into_inner());
    let mut out: Vec<_> =
        reg.iter().map(BucketProfile::from_totals).collect();
    reg.clear();
    out.sort_by(|a, b| b.total_ns.cmp(&a.total_ns));
    out
}

/// Host roofline for the interpreter backend: the documented
/// single-core envelope scaled by the dispatcher's thread count
/// (memory bandwidth is shared, not scaled).
pub fn host_roofline(threads: usize) -> Roofline {
    Roofline {
        peak_flops: CPU_1CORE.peak_flops * threads.max(1) as f64,
        mem_bw: CPU_1CORE.mem_bw,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn counters(
        flops: u64,
        pack_bytes: u64,
        store_bytes: u64,
        pack_ns: u64,
    ) -> DispatchCounters {
        let c = DispatchCounters::default();
        c.flops.store(flops, Ordering::Relaxed);
        c.pack_bytes.store(pack_bytes, Ordering::Relaxed);
        c.store_bytes.store(store_bytes, Ordering::Relaxed);
        c.pack_ns.store(pack_ns, Ordering::Relaxed);
        c
    }

    #[test]
    fn gate_defaults_off_and_toggles() {
        let _g = crate::trace::test_lock();
        set_enabled(false);
        assert!(!enabled());
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
    }

    #[test]
    fn record_accumulates_per_bucket_and_drains() {
        let _g = crate::trace::test_lock();
        drain();
        let shape = GemmShape::new(100, 100, 100);
        let times = PassTimes {
            direct_ns: 40,
            windowed_ns: 30,
            store_ns: 20,
            fixup_ns: 5,
        };
        record_dispatch(
            shape,
            Width::F32,
            (3, 2, 1),
            4,
            &counters(2_000_000, 1000, 500, 17),
            &times,
            100,
        );
        record_dispatch(
            shape,
            Width::F32,
            (3, 2, 1),
            4,
            &counters(2_000_000, 1000, 500, 17),
            &times,
            100,
        );
        // a different bucket stays separate
        record_dispatch(
            GemmShape::new(300, 300, 300),
            Width::F32,
            (1, 0, 0),
            0,
            &counters(1, 1, 1, 1),
            &PassTimes::default(),
            10,
        );
        let snap = snapshot();
        assert_eq!(snap.len(), 2);
        let p = snap
            .iter()
            .find(|p| p.bucket == ShapeBucket::of(shape).key())
            .expect("bucket present");
        assert_eq!(p.dispatches, 2);
        assert_eq!(p.flops, 4_000_000);
        assert_eq!(p.pack_bytes, 2000);
        assert_eq!(p.store_bytes, 1000);
        assert_eq!((p.owned, p.ordered, p.partial), (6, 4, 2));
        assert_eq!(p.fixup_tiles, 8);
        assert_eq!(p.total_ns, 200);
        assert!((p.accounted() - 0.95).abs() < 1e-12);
        // 4e6 flops over 200ns = 2e13 flop/s = 2e4 GFLOPS
        assert!((p.achieved_gflops() - 2e4).abs() / 2e4 < 1e-9);
        // 3000 bytes over 200ns = 1.5e10 B/s = 15 GB/s
        assert!((p.achieved_gbps() - 15.0).abs() < 1e-9);
        assert!((p.ai() - 4_000_000.0 / 3000.0).abs() < 1e-9);
        // json keys present
        let j = p.to_json();
        assert_eq!(j.s("bucket").unwrap(), p.bucket);
        assert!(j.f("gflops").unwrap() > 0.0);
        assert!(j.f("accounted").unwrap() > 0.9);
        // drain clears
        let drained = drain();
        assert_eq!(drained.len(), 2);
        assert!(snapshot().is_empty());
    }

    #[test]
    fn efficiency_is_bounded_by_roofline() {
        let _g = crate::trace::test_lock();
        drain();
        // 1 GFLOP in 1 second at high AI → 1 GFLOPS achieved
        record_dispatch(
            GemmShape::new(64, 64, 64),
            Width::F32,
            (1, 0, 0),
            0,
            &counters(1_000_000_000, 1000, 1000, 0),
            &PassTimes { direct_ns: 1_000_000_000, ..Default::default() },
            1_000_000_000,
        );
        let p = drain().remove(0);
        let r = host_roofline(1);
        let eff = p.efficiency(&r);
        assert!(eff > 0.0 && eff < 1.0, "eff={eff}");
        // achieved 1e9 flop/s vs 5e9 peak = 20%
        assert!((eff - 0.2).abs() < 1e-6, "eff={eff}");
    }

    #[test]
    fn host_roofline_scales_with_threads() {
        let r1 = host_roofline(1);
        let r8 = host_roofline(8);
        assert!((r8.peak_flops / r1.peak_flops - 8.0).abs() < 1e-12);
        assert_eq!(r1.mem_bw, r8.mem_bw);
        // zero threads clamps to one core
        assert_eq!(host_roofline(0).peak_flops, r1.peak_flops);
    }

    #[test]
    fn empty_profile_is_all_zeroes() {
        let p = BucketProfile::from_totals(&BucketTotals::default());
        assert_eq!(p.achieved_gflops(), 0.0);
        assert_eq!(p.achieved_gbps(), 0.0);
        assert_eq!(p.ai(), 0.0);
        assert_eq!(p.accounted(), 0.0);
        assert_eq!(p.efficiency(&host_roofline(4)), 0.0);
    }

    /// Satellite zero-guard: a bucket can legitimately record bytes and
    /// pass time with zero wall time (sub-nanosecond dispatch rounded
    /// down by the clock). Every derived rate must return 0, never
    /// NaN/∞ — these feed the metrics JSON and the SLO watchdog.
    #[test]
    fn bytes_with_zero_wall_time_yield_zero_rates_not_nan() {
        let _g = crate::trace::test_lock();
        drain();
        record_dispatch(
            GemmShape::new(8, 8, 8),
            Width::Bf16,
            (1, 0, 0),
            0,
            &counters(1024, 4096, 256, 9),
            &PassTimes { direct_ns: 3, ..Default::default() },
            0,
        );
        let p = drain().remove(0);
        assert!(p.pack_bytes > 0 && p.total_ns == 0);
        assert_eq!(p.accounted(), 0.0);
        assert_eq!(p.achieved_gflops(), 0.0);
        assert_eq!(p.achieved_gbps(), 0.0);
        assert_eq!(p.efficiency(&host_roofline(4)), 0.0);
        for key in ["accounted", "gflops", "gbps"] {
            let v = p.to_json().f(key).unwrap();
            assert!(v.is_finite(), "{key} must stay finite, got {v}");
        }
    }

    /// Width-suffixed bucket keys: f32 stays bare (back-compat with
    /// every existing lookup), 16-bit widths append `@width`, and the
    /// split is the exact inverse for every bucket key shape.
    #[test]
    fn width_keys_round_trip_and_keep_f32_bare() {
        assert_eq!(width_key("512x512x512", Width::F32), "512x512x512");
        assert_eq!(width_key("512x512x512", Width::Bf16), "512x512x512@bf16");
        assert_eq!(width_key("3x9x9", Width::F16), "3x9x9@f16");
        for bucket in ["512x512x512", "3840x4096x4096", "3x9x9"] {
            for w in Width::all() {
                let key = width_key(bucket, w);
                assert_eq!(split_width_key(&key), (bucket, w));
            }
        }
        // An unknown suffix is not a width tag — the whole key is the
        // bucket and the width defaults to f32.
        assert_eq!(split_width_key("odd@tag"), ("odd@tag", Width::F32));
    }

    /// Same shape at two widths lands in two separate slots; per-width
    /// byte totals never mix.
    #[test]
    fn widths_get_separate_attribution_slots() {
        let _g = crate::trace::test_lock();
        drain();
        let shape = GemmShape::new(200, 200, 200);
        for (w, bytes) in [(Width::F32, 4000u64), (Width::Bf16, 2000u64)] {
            record_dispatch(
                shape,
                w,
                (1, 0, 0),
                0,
                &counters(100, bytes, 16, 1),
                &PassTimes { direct_ns: 10, ..Default::default() },
                10,
            );
        }
        let snap = drain();
        assert_eq!(snap.len(), 2);
        let bucket = ShapeBucket::of(shape).key();
        let f32p = snap.iter().find(|p| p.bucket == bucket).unwrap();
        let bf = snap
            .iter()
            .find(|p| p.bucket == width_key(&bucket, Width::Bf16))
            .unwrap();
        assert_eq!(f32p.pack_bytes, 4000);
        assert_eq!(bf.pack_bytes, 2000);
        assert_eq!(f32p.width(), Width::F32);
        assert_eq!(bf.width(), Width::Bf16);
        assert_eq!(bf.to_json().s("width").unwrap(), "bf16");
    }
}
