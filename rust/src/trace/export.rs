//! Drain/export: Chrome trace-event JSON and a terminal span tree.
//!
//! The JSON form is the Trace Event Format's "X" (complete) events —
//! load the file at <https://ui.perfetto.dev> (or chrome://tracing).
//! Nesting is reconstructed by the viewer from time containment per
//! track, which the recorder's RAII stack discipline guarantees; no
//! parent ids are serialized.

use super::{ThreadMeta, TraceEvent};
use crate::json::{obj, Value};

/// Build the `{"traceEvents": [...]}` document for a drained trace.
/// Timestamps are microseconds (fractional) since the trace epoch.
pub fn chrome_trace_json(
    events: &[TraceEvent],
    threads: &[ThreadMeta],
) -> Value {
    let mut arr = Vec::with_capacity(events.len() + threads.len());
    for t in threads {
        arr.push(obj(vec![
            ("name", "thread_name".into()),
            ("ph", "M".into()),
            ("pid", 1usize.into()),
            ("tid", (t.tid as usize).into()),
            ("args", obj(vec![("name", t.name.as_str().into())])),
        ]));
    }
    for e in events {
        let args: Vec<(&str, Value)> = e
            .args()
            .iter()
            .map(|&(k, v)| (k, (v as usize).into()))
            .collect();
        arr.push(obj(vec![
            ("name", e.name.into()),
            ("ph", "X".into()),
            ("pid", 1usize.into()),
            ("tid", (e.tid as usize).into()),
            ("ts", Value::Num(e.start_ns as f64 / 1e3)),
            ("dur", Value::Num(e.dur_ns as f64 / 1e3)),
            ("args", obj(args)),
        ]));
    }
    obj(vec![("traceEvents", Value::Arr(arr))])
}

/// Render the span forest as an indented text tree with per-stage
/// times — the `streamk trace` subcommand's output. Events must be the
/// sorted result of [`super::drain`] (by thread, then start, longest
/// first at equal starts).
pub fn render_tree(events: &[TraceEvent], threads: &[ThreadMeta]) -> String {
    let mut out = String::new();
    let name_of = |tid: u64| {
        threads
            .iter()
            .find(|t| t.tid == tid)
            .map(|t| t.name.as_str())
            .unwrap_or("?")
    };
    let mut i = 0;
    while i < events.len() {
        let tid = events[i].tid;
        out.push_str(&format!("thread {} ({})\n", tid, name_of(tid)));
        // (end_ns) stack of currently-open ancestors on this track
        let mut stack: Vec<u64> = Vec::new();
        while i < events.len() && events[i].tid == tid {
            let e = &events[i];
            while let Some(&end) = stack.last() {
                if e.start_ns >= end {
                    stack.pop();
                } else {
                    break;
                }
            }
            out.push_str(&"  ".repeat(stack.len() + 1));
            out.push_str(&format!(
                "{}  {:.3} ms",
                e.name,
                e.dur_ns as f64 / 1e6
            ));
            for (k, v) in e.args() {
                out.push_str(&format!("  {k}={v}"));
            }
            out.push('\n');
            stack.push(e.start_ns + e.dur_ns);
            i += 1;
        }
    }
    out
}

/// Flat summary of the hottest spans by *self* time (span duration
/// minus the time covered by its direct children on the same track).
/// Returns `(name, count, total_ns, self_ns)` rows sorted hottest-self
/// first. Events must be the sorted result of [`super::drain`].
pub fn top_spans(
    events: &[TraceEvent],
) -> Vec<(&'static str, u64, u64, u64)> {
    struct Frame {
        name: &'static str,
        end_ns: u64,
        dur_ns: u64,
        child_ns: u64,
    }
    let mut totals: Vec<(&'static str, u64, u64, u64)> = Vec::new();
    let mut credit = |name: &'static str, dur: u64, self_ns: u64| {
        match totals.iter_mut().find(|t| t.0 == name) {
            Some(t) => {
                t.1 += 1;
                t.2 += dur;
                t.3 += self_ns;
            }
            None => totals.push((name, 1, dur, self_ns)),
        }
    };
    let mut stack: Vec<Frame> = Vec::new();
    let mut pop = |stack: &mut Vec<Frame>,
                   credit: &mut dyn FnMut(&'static str, u64, u64)| {
        let f = stack.pop().expect("pop on empty span stack");
        credit(f.name, f.dur_ns, f.dur_ns.saturating_sub(f.child_ns));
        if let Some(parent) = stack.last_mut() {
            parent.child_ns += f.dur_ns;
        }
    };
    let mut prev_tid: Option<u64> = None;
    for e in events {
        // track switch or sibling start: close finished frames
        while let Some(top) = stack.last() {
            if prev_tid != Some(e.tid) || e.start_ns >= top.end_ns {
                pop(&mut stack, &mut credit);
            } else {
                break;
            }
        }
        prev_tid = Some(e.tid);
        stack.push(Frame {
            name: e.name,
            end_ns: e.start_ns + e.dur_ns,
            dur_ns: e.dur_ns,
            child_ns: 0,
        });
    }
    while !stack.is_empty() {
        pop(&mut stack, &mut credit);
    }
    totals.sort_by(|a, b| b.3.cmp(&a.3));
    totals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;
    use crate::prop;
    use crate::trace;

    #[test]
    fn chrome_json_round_trips_and_is_well_formed() {
        let _g = trace::test_lock();
        trace::set_enabled(true);
        let _ = trace::drain();
        {
            let _a = trace::span1("test.export.root", "req", 3);
            let _b = trace::span("test.export.child");
        }
        trace::set_enabled(false);
        let (events, threads, _) = trace::drain();
        let events: Vec<_> = events
            .into_iter()
            .filter(|e| e.name.starts_with("test.export"))
            .collect();
        assert_eq!(events.len(), 2);
        let doc = chrome_trace_json(&events, &threads);
        let text = crate::json::to_string_pretty(&doc);
        let back = parse(&text).expect("chrome trace json parses");
        let evs = back.arr("traceEvents").unwrap();
        // one metadata record per thread + one X record per span
        let xs: Vec<_> = evs
            .iter()
            .filter(|e| e.s("ph").unwrap() == "X")
            .collect();
        assert_eq!(xs.len(), 2);
        for x in &xs {
            assert!(!x.s("name").unwrap().is_empty());
            assert!(x.f("ts").unwrap() >= 0.0);
            assert!(x.f("dur").unwrap() >= 0.0);
            assert_eq!(x.u("pid").unwrap(), 1);
        }
        assert!(evs
            .iter()
            .any(|e| e.s("ph").map(|p| p == "M").unwrap_or(false)));
        let tree = render_tree(&events, &threads);
        assert!(tree.contains("test.export.root"));
        assert!(tree.contains("  test.export.child") || tree.contains("test.export.child"));
    }

    /// Satellite: randomly nested/interleaved spans across `exec::pool`
    /// workers drain to well-formed, properly parented Chrome trace
    /// JSON that round-trips through the in-tree parser.
    #[test]
    fn prop_interleaved_worker_spans_export_well_formed() {
        let _g = trace::test_lock();
        // fixed name pool: span names must be &'static str
        const NAMES: [&str; 4] = [
            "test.prop.a",
            "test.prop.b",
            "test.prop.c",
            "test.prop.d",
        ];
        prop::check("trace-export-well-formed", 8, |rng| {
            trace::set_enabled(true);
            let _ = trace::drain();
            let seeds: Vec<u64> = (0..rng.usize_in(2, 5))
                .map(|_| rng.next_u64())
                .collect();
            // each pool worker opens a random nested span tree
            crate::exec::scope_map_with(
                seeds.len(),
                &seeds,
                || (),
                |_, idx, &seed| {
                    let mut r = prop::Rng::new(seed);
                    nest(&mut r, &NAMES, idx as u64, 3);
                },
            );
            trace::set_enabled(false);
            let (events, threads, _) = trace::drain();
            let events: Vec<_> = events
                .into_iter()
                .filter(|e| e.name.starts_with("test.prop"))
                .collect();
            prop::ensure(!events.is_empty(), "no events recorded")?;
            // proper parenting: on each track, every span is either
            // disjoint from or fully contained in the one before it on
            // the open stack (drain order is start-sorted per tid)
            let mut stack: Vec<(u64, u64)> = Vec::new(); // (tid, end)
            for e in &events {
                while let Some(&(tid, end)) = stack.last() {
                    if tid != e.tid || e.start_ns >= end {
                        stack.pop();
                    } else {
                        break;
                    }
                }
                if let Some(&(tid, end)) = stack.last() {
                    if tid == e.tid {
                        prop::ensure(
                            e.start_ns + e.dur_ns <= end,
                            format!(
                                "span {} overlaps parent boundary",
                                e.name
                            ),
                        )?;
                    }
                }
                stack.push((e.tid, e.start_ns + e.dur_ns));
            }
            // round-trip through the in-tree json parser
            let doc = chrome_trace_json(&events, &threads);
            let text = doc.to_string();
            let back = parse(&text).map_err(|e| e.to_string())?;
            let evs = back.arr("traceEvents").map_err(|e| e.to_string())?;
            let xs = evs
                .iter()
                .filter(|e| e.s("ph").map(|p| p == "X").unwrap_or(false))
                .count();
            prop::ensure_eq(xs, events.len(), "X event count")?;
            for e in evs {
                prop::ensure(
                    e.s("ph").is_ok() && e.get("args").is_some(),
                    "event missing ph/args",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn top_spans_attributes_self_time() {
        let _g = trace::test_lock();
        trace::set_enabled(true);
        let _ = trace::drain();
        {
            let _root = trace::span("test.top.root");
            for _ in 0..3 {
                let _child = trace::span("test.top.child");
                std::hint::black_box(
                    (0..2000u64).fold(0u64, |a, b| a.wrapping_add(b)),
                );
            }
        }
        trace::set_enabled(false);
        let (events, _, _) = trace::drain();
        let events: Vec<_> = events
            .into_iter()
            .filter(|e| e.name.starts_with("test.top"))
            .collect();
        let top = top_spans(&events);
        assert_eq!(top.len(), 2);
        let root = top.iter().find(|t| t.0 == "test.top.root").unwrap();
        let child = top.iter().find(|t| t.0 == "test.top.child").unwrap();
        assert_eq!(root.1, 1);
        assert_eq!(child.1, 3);
        // leaf spans: self == total; parent: self = total − children
        assert_eq!(child.2, child.3);
        assert!(root.3 < root.2, "root self {} total {}", root.3, root.2);
        assert!(root.2 >= child.2, "root contains children");
        assert_eq!(root.3, root.2 - child.2);
        // total time is conserved: Σself == Σroot durations
        let self_sum: u64 = top.iter().map(|t| t.3).sum();
        assert_eq!(self_sum, root.2);
    }

    /// Recursive random span tree: each level opens a span, maybe
    /// recurses (nested children), maybe opens siblings.
    fn nest(rng: &mut prop::Rng, names: &[&'static str; 4], worker: u64, depth: usize) {
        let name = names[rng.usize_in(0, names.len() - 1)];
        let _s = trace::span1(name, "worker", worker);
        if depth > 0 {
            for _ in 0..rng.usize_in(0, 2) {
                nest(rng, names, worker, depth - 1);
            }
        }
        if rng.bool() {
            std::thread::yield_now();
        }
    }
}
