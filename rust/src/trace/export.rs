//! Drain/export: Chrome trace-event JSON and a terminal span tree.
//!
//! The JSON form is the Trace Event Format's "X" (complete) events —
//! load the file at <https://ui.perfetto.dev> (or chrome://tracing).
//! Nesting is reconstructed by the viewer from time containment per
//! track, which the recorder's RAII stack discipline guarantees; no
//! parent ids are serialized.

use super::{ThreadMeta, TraceEvent};
use crate::json::{obj, Value};

/// Build the `{"traceEvents": [...]}` document for a drained trace.
/// Timestamps are microseconds (fractional) since the trace epoch.
pub fn chrome_trace_json(
    events: &[TraceEvent],
    threads: &[ThreadMeta],
) -> Value {
    let mut arr = Vec::with_capacity(events.len() + threads.len());
    for t in threads {
        arr.push(obj(vec![
            ("name", "thread_name".into()),
            ("ph", "M".into()),
            ("pid", 1usize.into()),
            ("tid", (t.tid as usize).into()),
            ("args", obj(vec![("name", t.name.as_str().into())])),
        ]));
    }
    for e in events {
        let args: Vec<(&str, Value)> = e
            .args()
            .iter()
            .map(|&(k, v)| (k, (v as usize).into()))
            .collect();
        arr.push(obj(vec![
            ("name", e.name.into()),
            ("ph", "X".into()),
            ("pid", 1usize.into()),
            ("tid", (e.tid as usize).into()),
            ("ts", Value::Num(e.start_ns as f64 / 1e3)),
            ("dur", Value::Num(e.dur_ns as f64 / 1e3)),
            ("args", obj(args)),
        ]));
    }
    obj(vec![("traceEvents", Value::Arr(arr))])
}

/// Render the span forest as an indented text tree with per-stage
/// times — the `streamk trace` subcommand's output. Events must be the
/// sorted result of [`super::drain`] (by thread, then start, longest
/// first at equal starts).
pub fn render_tree(events: &[TraceEvent], threads: &[ThreadMeta]) -> String {
    let mut out = String::new();
    let name_of = |tid: u64| {
        threads
            .iter()
            .find(|t| t.tid == tid)
            .map(|t| t.name.as_str())
            .unwrap_or("?")
    };
    let mut i = 0;
    while i < events.len() {
        let tid = events[i].tid;
        out.push_str(&format!("thread {} ({})\n", tid, name_of(tid)));
        // (end_ns) stack of currently-open ancestors on this track
        let mut stack: Vec<u64> = Vec::new();
        while i < events.len() && events[i].tid == tid {
            let e = &events[i];
            while let Some(&end) = stack.last() {
                if e.start_ns >= end {
                    stack.pop();
                } else {
                    break;
                }
            }
            out.push_str(&"  ".repeat(stack.len() + 1));
            out.push_str(&format!(
                "{}  {:.3} ms",
                e.name,
                e.dur_ns as f64 / 1e6
            ));
            for (k, v) in e.args() {
                out.push_str(&format!("  {k}={v}"));
            }
            out.push('\n');
            stack.push(e.start_ns + e.dur_ns);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::parse;
    use crate::prop;
    use crate::trace;

    #[test]
    fn chrome_json_round_trips_and_is_well_formed() {
        let _g = trace::test_lock();
        trace::set_enabled(true);
        let _ = trace::drain();
        {
            let _a = trace::span1("test.export.root", "req", 3);
            let _b = trace::span("test.export.child");
        }
        trace::set_enabled(false);
        let (events, threads, _) = trace::drain();
        let events: Vec<_> = events
            .into_iter()
            .filter(|e| e.name.starts_with("test.export"))
            .collect();
        assert_eq!(events.len(), 2);
        let doc = chrome_trace_json(&events, &threads);
        let text = crate::json::to_string_pretty(&doc);
        let back = parse(&text).expect("chrome trace json parses");
        let evs = back.arr("traceEvents").unwrap();
        // one metadata record per thread + one X record per span
        let xs: Vec<_> = evs
            .iter()
            .filter(|e| e.s("ph").unwrap() == "X")
            .collect();
        assert_eq!(xs.len(), 2);
        for x in &xs {
            assert!(!x.s("name").unwrap().is_empty());
            assert!(x.f("ts").unwrap() >= 0.0);
            assert!(x.f("dur").unwrap() >= 0.0);
            assert_eq!(x.u("pid").unwrap(), 1);
        }
        assert!(evs
            .iter()
            .any(|e| e.s("ph").map(|p| p == "M").unwrap_or(false)));
        let tree = render_tree(&events, &threads);
        assert!(tree.contains("test.export.root"));
        assert!(tree.contains("  test.export.child") || tree.contains("test.export.child"));
    }

    /// Satellite: randomly nested/interleaved spans across `exec::pool`
    /// workers drain to well-formed, properly parented Chrome trace
    /// JSON that round-trips through the in-tree parser.
    #[test]
    fn prop_interleaved_worker_spans_export_well_formed() {
        let _g = trace::test_lock();
        // fixed name pool: span names must be &'static str
        const NAMES: [&str; 4] = [
            "test.prop.a",
            "test.prop.b",
            "test.prop.c",
            "test.prop.d",
        ];
        prop::check("trace-export-well-formed", 8, |rng| {
            trace::set_enabled(true);
            let _ = trace::drain();
            let seeds: Vec<u64> = (0..rng.usize_in(2, 5))
                .map(|_| rng.next_u64())
                .collect();
            // each pool worker opens a random nested span tree
            crate::exec::scope_map_with(
                seeds.len(),
                &seeds,
                || (),
                |_, idx, &seed| {
                    let mut r = prop::Rng::new(seed);
                    nest(&mut r, &NAMES, idx as u64, 3);
                },
            );
            trace::set_enabled(false);
            let (events, threads, _) = trace::drain();
            let events: Vec<_> = events
                .into_iter()
                .filter(|e| e.name.starts_with("test.prop"))
                .collect();
            prop::ensure(!events.is_empty(), "no events recorded")?;
            // proper parenting: on each track, every span is either
            // disjoint from or fully contained in the one before it on
            // the open stack (drain order is start-sorted per tid)
            let mut stack: Vec<(u64, u64)> = Vec::new(); // (tid, end)
            for e in &events {
                while let Some(&(tid, end)) = stack.last() {
                    if tid != e.tid || e.start_ns >= end {
                        stack.pop();
                    } else {
                        break;
                    }
                }
                if let Some(&(tid, end)) = stack.last() {
                    if tid == e.tid {
                        prop::ensure(
                            e.start_ns + e.dur_ns <= end,
                            format!(
                                "span {} overlaps parent boundary",
                                e.name
                            ),
                        )?;
                    }
                }
                stack.push((e.tid, e.start_ns + e.dur_ns));
            }
            // round-trip through the in-tree json parser
            let doc = chrome_trace_json(&events, &threads);
            let text = doc.to_string();
            let back = parse(&text).map_err(|e| e.to_string())?;
            let evs = back.arr("traceEvents").map_err(|e| e.to_string())?;
            let xs = evs
                .iter()
                .filter(|e| e.s("ph").map(|p| p == "X").unwrap_or(false))
                .count();
            prop::ensure_eq(xs, events.len(), "X event count")?;
            for e in evs {
                prop::ensure(
                    e.s("ph").is_ok() && e.get("args").is_some(),
                    "event missing ph/args",
                )?;
            }
            Ok(())
        });
    }

    /// Recursive random span tree: each level opens a span, maybe
    /// recurses (nested children), maybe opens siblings.
    fn nest(rng: &mut prop::Rng, names: &[&'static str; 4], worker: u64, depth: usize) {
        let name = names[rng.usize_in(0, names.len() - 1)];
        let _s = trace::span1(name, "worker", worker);
        if depth > 0 {
            for _ in 0..rng.usize_in(0, 2) {
                nest(rng, names, worker, depth - 1);
            }
        }
        if rng.bool() {
            std::thread::yield_now();
        }
    }
}
