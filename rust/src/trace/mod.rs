//! Structured tracing: per-thread ring-buffer span recorder.
//!
//! The paper's block-mapping bug ("could not fully resolve") and its
//! Block2Time bet are both observability gaps: the runtime predicts
//! everywhere but records nothing about what actually happened per stage
//! or per CU. This module closes the recording half; [`residual`] closes
//! the prediction-error half.
//!
//! Design constraints, in priority order:
//!
//! 1. **Disabled is free.** Tracing is compiled in everywhere (no
//!    feature flag to bit-rot) but runtime-gated: a disabled
//!    [`span`] is one relaxed atomic load and a trivially-copyable
//!    struct return. The kernel dispatcher calls it per tile job, so
//!    this path is held to the ≤1% overhead gate in
//!    `benches/kernel_exec.rs`.
//! 2. **Zero heap on the hot path.** Span names are `&'static str`,
//!    args are two fixed `(&'static str, u64)` slots, and events land
//!    in a preallocated per-thread ring. The only allocation is the
//!    one-time ring registration per thread.
//! 3. **Threads die, events survive.** The kernel dispatcher spawns
//!    scoped workers per window; their rings are `Arc`-shared with a
//!    global registry so a drain after the scope closes still sees
//!    their spans. Rings whose thread is gone are pruned after draining.
//!
//! Span identity is (thread, start, duration): export emits Chrome
//! trace-event "X" (complete) events, and Perfetto reconstructs
//! parent/child nesting from time containment on each track — RAII
//! stack discipline guarantees spans on one thread properly nest, so no
//! explicit parent ids are recorded.

pub mod export;
pub mod profile;
pub mod residual;

pub use export::{chrome_trace_json, render_tree, top_spans};
pub use residual::{ResidualSnapshot, ResidualTracker};

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

/// Events kept per thread; the ring overwrites the oldest beyond this.
const RING_CAP: usize = 4096;

/// Registry cap: rings registered beyond this are thread-local only
/// (their events are recorded but never drained) so a pathological
/// thread-spawn loop cannot grow the registry without bound.
const MAX_RINGS: usize = 1024;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TID: AtomicU64 = AtomicU64::new(1);
static SAMPLE_EVERY: AtomicU64 = AtomicU64::new(1);
static REQ_COUNTER: AtomicU64 = AtomicU64::new(0);

/// Turn span recording on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Release);
}

/// The one-load gate every span constructor checks first.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Record request-lifecycle spans for every `n`-th request only
/// (`streamk serve --trace-sample n`). Kernel/engine spans are not
/// request-scoped and follow the global gate alone.
pub fn set_sample_every(n: u64) {
    SAMPLE_EVERY.store(n.max(1), Ordering::Relaxed);
}

/// Per-request sampling decision: true when this request's lifecycle
/// spans should be emitted. Approximate under concurrency (the counter
/// is global), exact for any window of `n` consecutive requests.
pub fn request_sampled() -> bool {
    if !enabled() {
        return false;
    }
    let n = SAMPLE_EVERY.load(Ordering::Relaxed);
    REQ_COUNTER.fetch_add(1, Ordering::Relaxed) % n == 0
}

/// One completed span, as drained from a ring.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TraceEvent {
    pub name: &'static str,
    /// Trace-local thread id (registration order, not OS tid).
    pub tid: u64,
    /// Nanoseconds since the process trace epoch.
    pub start_ns: u64,
    pub dur_ns: u64,
    pub args: [(&'static str, u64); 2],
    pub nargs: u8,
}

impl TraceEvent {
    /// The populated arg slots.
    pub fn args(&self) -> &[(&'static str, u64)] {
        &self.args[..self.nargs as usize]
    }
}

/// Thread metadata for export (one Chrome "M" record each).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ThreadMeta {
    pub tid: u64,
    pub name: String,
}

struct RingInner {
    meta: ThreadMeta,
    events: Vec<TraceEvent>,
    /// Overwrite cursor once `events` reaches [`RING_CAP`].
    head: usize,
    dropped: u64,
}

type Ring = Arc<Mutex<RingInner>>;

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn registry() -> &'static Mutex<Vec<Ring>> {
    static REG: OnceLock<Mutex<Vec<Ring>>> = OnceLock::new();
    REG.get_or_init(|| Mutex::new(Vec::new()))
}

thread_local! {
    static LOCAL_RING: std::cell::RefCell<Option<Ring>> =
        const { std::cell::RefCell::new(None) };
}

fn register_ring() -> Ring {
    let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
    let name = std::thread::current()
        .name()
        .map(str::to_string)
        .unwrap_or_else(|| format!("thread-{tid}"));
    let ring = Arc::new(Mutex::new(RingInner {
        meta: ThreadMeta { tid, name },
        events: Vec::with_capacity(64),
        head: 0,
        dropped: 0,
    }));
    let mut reg = registry().lock().expect("trace registry");
    if reg.len() < MAX_RINGS {
        reg.push(ring.clone());
    }
    ring
}

fn record(mut ev: TraceEvent) {
    LOCAL_RING.with(|slot| {
        let mut slot = slot.borrow_mut();
        let ring = slot.get_or_insert_with(register_ring);
        let mut inner = ring.lock().expect("trace ring");
        ev.tid = inner.meta.tid;
        if inner.events.len() < RING_CAP {
            inner.events.push(ev);
        } else {
            let h = inner.head;
            inner.events[h] = ev;
            inner.head = (h + 1) % RING_CAP;
            inner.dropped += 1;
        }
    });
}

/// RAII span guard: records one event on drop. Construct via [`span`],
/// [`span1`], [`span2`] or [`span_if`]; bind it (`let _s = ...`) so it
/// lives to the end of the scope it measures.
#[must_use = "a span measures its guard's lifetime; bind it with `let`"]
pub struct Span {
    name: &'static str,
    start_ns: u64,
    args: [(&'static str, u64); 2],
    nargs: u8,
    live: bool,
}

impl Span {
    const DEAD: Span = Span {
        name: "",
        start_ns: 0,
        args: [("", 0), ("", 0)],
        nargs: 0,
        live: false,
    };
}

impl Drop for Span {
    fn drop(&mut self) {
        if !self.live {
            return;
        }
        let end = now_ns();
        record(TraceEvent {
            name: self.name,
            tid: 0, // filled from the ring in record()
            start_ns: self.start_ns,
            dur_ns: end.saturating_sub(self.start_ns),
            args: self.args,
            nargs: self.nargs,
        });
    }
}

/// Open a span; the event is recorded when the guard drops.
#[inline]
pub fn span(name: &'static str) -> Span {
    if !enabled() {
        return Span::DEAD;
    }
    Span { name, start_ns: now_ns(), args: [("", 0), ("", 0)], nargs: 0, live: true }
}

/// Span with one numeric arg (CU id, request id, tile count, ...).
#[inline]
pub fn span1(name: &'static str, k: &'static str, v: u64) -> Span {
    if !enabled() {
        return Span::DEAD;
    }
    Span { name, start_ns: now_ns(), args: [(k, v), ("", 0)], nargs: 1, live: true }
}

/// Span with two numeric args.
#[inline]
pub fn span2(
    name: &'static str,
    k1: &'static str,
    v1: u64,
    k2: &'static str,
    v2: u64,
) -> Span {
    if !enabled() {
        return Span::DEAD;
    }
    Span { name, start_ns: now_ns(), args: [(k1, v1), (k2, v2)], nargs: 2, live: true }
}

/// Conditionally-open span — the request-sampling hook: callers gate a
/// whole lifecycle on one [`request_sampled`] draw and thread the bool
/// through their child spans.
#[inline]
pub fn span_if(on: bool, name: &'static str) -> Span {
    if on {
        span(name)
    } else {
        Span::DEAD
    }
}

/// Like [`span_if`] with two args.
#[inline]
pub fn span2_if(
    on: bool,
    name: &'static str,
    k1: &'static str,
    v1: u64,
    k2: &'static str,
    v2: u64,
) -> Span {
    if on {
        span2(name, k1, v1, k2, v2)
    } else {
        Span::DEAD
    }
}

/// Drain every registered ring: returns all recorded events (sorted by
/// thread then start time) plus per-thread metadata, and empties the
/// rings. Rings whose thread has exited (registry holds the only
/// remaining reference) are pruned after draining, so scoped kernel
/// workers don't accumulate. Total events dropped to ring overflow
/// since the last drain are returned as the third element.
pub fn drain() -> (Vec<TraceEvent>, Vec<ThreadMeta>, u64) {
    let mut events = Vec::new();
    let mut threads = Vec::new();
    let mut dropped = 0u64;
    let mut reg = registry().lock().expect("trace registry");
    reg.retain(|ring| {
        {
            let mut inner = ring.lock().expect("trace ring");
            if !inner.events.is_empty() {
                threads.push(inner.meta.clone());
            }
            events.append(&mut inner.events);
            inner.head = 0;
            dropped += inner.dropped;
            inner.dropped = 0;
        }
        Arc::strong_count(ring) > 1
    });
    drop(reg);
    events.sort_by_key(|e| (e.tid, e.start_ns, std::cmp::Reverse(e.dur_ns)));
    threads.sort_by_key(|t| t.tid);
    (events, threads, dropped)
}

/// Serialized test access: tracing state (gate, rings, sample counter)
/// is process-global, so tests that enable tracing and drain must not
/// interleave. Library tests and the bench harness both use this.
pub fn test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Events from this test process only — concurrent tests in other
    /// modules may record spans while tracing is enabled here, so every
    /// assertion filters to the names this module emits.
    fn drain_named(prefix: &str) -> Vec<TraceEvent> {
        let (events, _, _) = drain();
        events.into_iter().filter(|e| e.name.starts_with(prefix)).collect()
    }

    #[test]
    fn disabled_records_nothing() {
        let _g = test_lock();
        set_enabled(false);
        let _ = drain(); // clear leftovers
        {
            let _s = span("test.disabled");
            let _t = span2("test.disabled.child", "a", 1, "b", 2);
        }
        assert!(drain_named("test.disabled").is_empty());
    }

    #[test]
    fn spans_nest_by_stack_discipline() {
        let _g = test_lock();
        set_enabled(true);
        let _ = drain();
        {
            let _outer = span1("test.nest.outer", "req", 7);
            std::thread::sleep(std::time::Duration::from_micros(200));
            {
                let _inner = span("test.nest.inner");
                std::thread::sleep(std::time::Duration::from_micros(100));
            }
        }
        set_enabled(false);
        let evs = drain_named("test.nest");
        assert_eq!(evs.len(), 2, "{evs:?}");
        let outer = evs.iter().find(|e| e.name == "test.nest.outer").unwrap();
        let inner = evs.iter().find(|e| e.name == "test.nest.inner").unwrap();
        assert_eq!(outer.tid, inner.tid);
        assert_eq!(outer.args(), &[("req", 7)]);
        // containment: inner starts after outer and ends before it
        assert!(inner.start_ns >= outer.start_ns);
        assert!(
            inner.start_ns + inner.dur_ns <= outer.start_ns + outer.dur_ns,
            "inner escapes outer: {inner:?} vs {outer:?}"
        );
        assert!(outer.dur_ns > inner.dur_ns);
    }

    #[test]
    fn ring_overflow_drops_oldest_but_keeps_cap() {
        let _g = test_lock();
        set_enabled(true);
        let _ = drain();
        for i in 0..(RING_CAP + 500) {
            let _s = span1("test.overflow", "i", i as u64);
        }
        set_enabled(false);
        let (events, _, dropped) = drain();
        let ours: Vec<_> =
            events.into_iter().filter(|e| e.name == "test.overflow").collect();
        assert_eq!(ours.len(), RING_CAP);
        assert!(dropped >= 500);
        // the survivors are the newest 4096 (oldest were overwritten)
        let min_i = ours.iter().map(|e| e.args[0].1).min().unwrap();
        assert!(min_i >= 500 - 1, "oldest surviving index {min_i}");
    }

    #[test]
    fn dead_thread_events_survive_until_drained() {
        let _g = test_lock();
        set_enabled(true);
        let _ = drain();
        std::thread::spawn(|| {
            let _s = span("test.deadthread");
        })
        .join()
        .unwrap();
        set_enabled(false);
        let evs = drain_named("test.deadthread");
        assert_eq!(evs.len(), 1);
        // its ring was pruned: a second drain finds nothing
        assert!(drain_named("test.deadthread").is_empty());
    }

    #[test]
    fn sampling_selects_every_nth_request() {
        let _g = test_lock();
        set_enabled(true);
        set_sample_every(3);
        let hits =
            (0..9).filter(|_| request_sampled()).count();
        assert_eq!(hits, 3);
        set_sample_every(1);
        set_enabled(false);
        // disabled: never sampled
        assert!(!(0..5).any(|_| request_sampled()));
        let _ = drain();
    }
}
