//! The serving coordinator — L3's request path.
//!
//! vLLM-router-shaped pipeline, with GEMM/MLP computations instead of
//! LLM decoding:
//!
//! ```text
//! client → [bounded queue] → router (shape→artifact, tuner-cache
//!        consult) → dynamic batcher → worker pool → PJRT engine
//!        → reply channels → metrics
//!                                  ↘ tuner miss → background tune
//! ```
//!
//! Python never appears here: the engine executes AOT artifacts only.
//! The per-shape tuner ([`crate::tuner`]) sits beside the router: a
//! cache hit steers the routing policy, a miss falls back to defaults
//! and schedules a background tune so the next request in that shape
//! bucket is served tuned.

mod batcher;
mod metrics;
mod request;
mod router;
mod service;

pub use batcher::{BatchPlan, Batcher};
pub use metrics::{Histogram, Metrics, MetricsSnapshot};
pub use request::{GemmRequest, GemmResponse, MlpRequest, MlpResponse, ReplyTo};
pub use router::{RouteError, Router};
pub use service::{mlp_params, Coordinator, CoordinatorHandle, MlpParams};
