//! The serving coordinator — L3's request path.
//!
//! vLLM-router-shaped pipeline, with GEMM/MLP computations instead of
//! LLM decoding. Since the fleet refactor the coordinator serves N
//! devices, not one:
//!
//! ```text
//! client → [bounded queue] → fleet scheduler (lowest Block2Time-
//!        predicted completion; least-loaded fallback)
//!        → router (shape→artifact, per-device tuner-cache consult,
//!          nearest-CU build) → dynamic batcher (MLP) → worker pool
//!        → engine[device]  ── one engine per fleet device
//!        → reply channels → metrics (per-device placements)
//!             ↘ measured latency → fleet.observe()
//!                 ├ blends the cached prediction toward reality
//!                 ├ tuner miss       → background tune (Miss)
//!                 └ drift > policy   → background re-tune (Revalidate)
//! ```
//!
//! Python never appears here: the engines execute AOT artifacts only.
//! Each fleet device owns a per-shape tuner ([`crate::tuner`]): a
//! cache hit steers the routing policy, a miss falls back to defaults
//! and schedules a background tune so the next request in that shape
//! bucket is served tuned — and the measured latency of every
//! completion feeds the online Block2Time loop ([`crate::fleet`]).

mod batcher;
mod metrics;
pub mod recorder;
mod request;
mod router;
mod service;
pub mod slo;

pub use batcher::{BatchPlan, Batcher};
pub use metrics::{Histogram, Metrics, MetricsSnapshot};
pub use recorder::{FlightRecorder, TimedSnapshot};
pub use request::{GemmRequest, GemmResponse, MlpRequest, MlpResponse, ReplyTo};
pub use router::{RouteError, Router};
pub use service::{mlp_params, Coordinator, CoordinatorHandle, MlpParams};
pub use slo::{parse_rules, Breach, SloRule};
