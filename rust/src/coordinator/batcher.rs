//! Dynamic batching of MLP requests.
//!
//! Requests arriving within `window` are folded into one executable
//! launch (up to `max_batch` rows), padded to the smallest compiled
//! batch size. The Stream-K connection: because one kernel config serves
//! every shape, the batcher only needs the *batch* dimension menu, not a
//! per-shape kernel zoo.

use super::request::MlpRequest;
use crate::exec::{Receiver, Stopwatch};
use std::time::Duration;

/// A group of requests to run as one launch.
pub struct BatchPlan {
    pub requests: Vec<MlpRequest>,
    pub total_rows: usize,
}

/// Collects requests from a channel into batch plans.
pub struct Batcher {
    pub max_batch: usize,
    pub window: Duration,
    /// Request that did not fit in the previous batch.
    pending: Option<MlpRequest>,
}

impl Batcher {
    pub fn new(max_batch: usize, window: Duration) -> Self {
        assert!(max_batch > 0);
        Self { max_batch, window, pending: None }
    }

    /// Block for the next batch: waits for one request, then keeps
    /// draining until the window closes, the batch is full, or the
    /// channel empties at window end. A batch never exceeds `max_batch`
    /// rows (unless a single oversized request arrives, which is passed
    /// through alone for the router to reject). Returns `None` when the
    /// channel is disconnected and fully drained.
    pub fn next_batch(&mut self, rx: &Receiver<MlpRequest>) -> Option<BatchPlan> {
        let first = match self.pending.take() {
            Some(req) => req,
            None => rx.recv().ok()?,
        };
        // Span opens after the blocking recv: it measures the batching
        // window (coalescing time), not idle queue waiting.
        let _collect =
            crate::trace::span1("batch.collect", "first", first.id);
        let mut rows = first.rows;
        let mut requests = vec![first];
        let sw = Stopwatch::start();
        while rows < self.max_batch {
            let remaining = self
                .window
                .checked_sub(sw.elapsed())
                .unwrap_or(Duration::ZERO);
            if remaining.is_zero() {
                break;
            }
            match rx.try_recv() {
                Ok(req) => {
                    if rows + req.rows > self.max_batch {
                        // Doesn't fit: hold it for the next batch.
                        self.pending = Some(req);
                        break;
                    }
                    rows += req.rows;
                    requests.push(req);
                }
                Err(_) => std::thread::sleep(Duration::from_micros(20)),
            }
        }
        Some(BatchPlan { requests, total_rows: rows })
    }
}

impl BatchPlan {
    /// Pack all requests' rows into one contiguous activation buffer of
    /// `batch` rows (zero-padded tail). Returns the buffer and each
    /// request's row offset.
    pub fn pack(&self, d_in: usize, batch: usize) -> (Vec<f32>, Vec<usize>) {
        assert!(batch >= self.total_rows, "batch too small for plan");
        let mut x = vec![0.0f32; batch * d_in];
        let mut offsets = Vec::with_capacity(self.requests.len());
        let mut row = 0usize;
        for req in &self.requests {
            assert_eq!(req.x.len(), req.rows * d_in, "request row width");
            x[row * d_in..(row + req.rows) * d_in].copy_from_slice(&req.x);
            offsets.push(row);
            row += req.rows;
        }
        (x, offsets)
    }

    /// Split a packed output buffer back into per-request slices.
    pub fn unpack(
        &self,
        y: &[f32],
        d_out: usize,
        offsets: &[usize],
    ) -> Vec<Vec<f32>> {
        self.requests
            .iter()
            .zip(offsets)
            .map(|(req, &off)| {
                y[off * d_out..(off + req.rows) * d_out].to_vec()
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::ReplyTo;
    use crate::exec::bounded;

    fn req(id: u64, rows: usize, d_in: usize, fill: f32) -> MlpRequest {
        let (reply, _rx) = ReplyTo::pair();
        MlpRequest { id, rows, x: vec![fill; rows * d_in], reply }
    }

    #[test]
    fn batches_waiting_requests_together() {
        let (tx, rx) = bounded(16);
        assert!(tx.send(req(1, 2, 4, 1.0)).is_ok());
        assert!(tx.send(req(2, 3, 4, 2.0)).is_ok());
        let mut b = Batcher::new(16, Duration::from_millis(5));
        let plan = b.next_batch(&rx).unwrap();
        assert_eq!(plan.requests.len(), 2);
        assert_eq!(plan.total_rows, 5);
    }

    #[test]
    fn overflow_request_deferred_to_next_batch() {
        let (tx, rx) = bounded(16);
        assert!(tx.send(req(1, 3, 1, 1.0)).is_ok());
        assert!(tx.send(req(2, 3, 1, 2.0)).is_ok()); // 3+3 > max_batch=4
        let mut b = Batcher::new(4, Duration::from_millis(5));
        let plan = b.next_batch(&rx).unwrap();
        assert_eq!(plan.total_rows, 3);
        assert_eq!(plan.requests[0].id, 1);
        let plan2 = b.next_batch(&rx).unwrap();
        assert_eq!(plan2.requests[0].id, 2);
        drop(tx);
    }

    #[test]
    fn disconnected_returns_none() {
        let (tx, rx) = bounded::<MlpRequest>(4);
        drop(tx);
        let mut b = Batcher::new(8, Duration::from_millis(1));
        assert!(b.next_batch(&rx).is_none());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let (tx, rx) = bounded(16);
        assert!(tx.send(req(1, 2, 3, 1.0)).is_ok());
        assert!(tx.send(req(2, 1, 3, 2.0)).is_ok());
        let mut b = Batcher::new(8, Duration::from_millis(5));
        let plan = b.next_batch(&rx).unwrap();
        let (x, offsets) = plan.pack(3, 8);
        assert_eq!(x.len(), 24);
        assert_eq!(&x[0..6], &[1.0; 6]);
        assert_eq!(&x[6..9], &[2.0; 3]);
        assert_eq!(&x[9..], &[0.0; 15]); // padding
        // fake output: row r filled with r
        let y: Vec<f32> = (0..8).flat_map(|r| vec![r as f32; 2]).collect();
        let outs = plan.unpack(&y, 2, &offsets);
        assert_eq!(outs[0], vec![0.0, 0.0, 1.0, 1.0]);
        assert_eq!(outs[1], vec![2.0, 2.0]);
    }

    #[test]
    fn respects_window_even_when_starved() {
        let (tx, rx) = bounded(4);
        assert!(tx.send(req(1, 1, 2, 0.5)).is_ok());
        let mut b = Batcher::new(64, Duration::from_millis(2));
        let sw = crate::exec::Stopwatch::start();
        let plan = b.next_batch(&rx).unwrap();
        assert_eq!(plan.requests.len(), 1);
        assert!(sw.elapsed_secs() < 0.5, "window not honored");
        drop(tx);
    }
}
