//! Metrics flight recorder: a fixed-capacity ring of periodic
//! `MetricsSnapshot`s sampled inside `serve`, giving the SLO watchdog
//! a sliding window to evaluate over and `--metrics-out` a JSON
//! timeline instead of a single final snapshot.

use super::metrics::MetricsSnapshot;
use crate::json::{obj, Value};
use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

/// One recorded sample: a monotonically increasing sequence number,
/// seconds since the recorder started, and the snapshot itself.
#[derive(Debug, Clone)]
pub struct TimedSnapshot {
    pub seq: u64,
    pub t_s: f64,
    pub snap: MetricsSnapshot,
}

/// Overwrite-oldest ring of timed metrics snapshots.
#[derive(Debug)]
pub struct FlightRecorder {
    cap: usize,
    start: Instant,
    inner: Mutex<(u64, VecDeque<TimedSnapshot>)>,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            cap,
            start: Instant::now(),
            inner: Mutex::new((0, VecDeque::with_capacity(cap))),
        }
    }

    /// Append a sample, evicting the oldest past capacity.
    pub fn record(&self, snap: MetricsSnapshot) {
        let t_s = self.start.elapsed().as_secs_f64();
        let mut g = self.inner.lock().expect("flight recorder");
        let seq = g.0;
        g.0 += 1;
        g.1.push_back(TimedSnapshot { seq, t_s, snap });
        while g.1.len() > self.cap {
            g.1.pop_front();
        }
    }

    /// Current window, oldest first.
    pub fn window(&self) -> Vec<TimedSnapshot> {
        self.inner
            .lock()
            .expect("flight recorder")
            .1
            .iter()
            .cloned()
            .collect()
    }

    pub fn len(&self) -> usize {
        self.inner.lock().expect("flight recorder").1.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    /// Total samples ever recorded (including evicted ones).
    pub fn recorded(&self) -> u64 {
        self.inner.lock().expect("flight recorder").0
    }

    /// JSON timeline: `{"cap": N, "samples": [{seq, t_s, metrics}...]}`.
    pub fn to_json(&self) -> Value {
        let samples = self
            .window()
            .iter()
            .map(|s| {
                obj(vec![
                    ("seq", (s.seq as usize).into()),
                    ("t_s", s.t_s.into()),
                    ("metrics", s.snap.to_json()),
                ])
            })
            .collect();
        obj(vec![("cap", self.cap.into()), ("samples", Value::Arr(samples))])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;
    use crate::prop;

    #[test]
    fn ring_wraparound_preserves_order_and_monotonic_timestamps() {
        // Property: for random capacities and overfill counts, the
        // window holds exactly the last `cap` samples with strictly
        // increasing seq and non-decreasing timestamps.
        prop::check("flight-recorder ring wraparound", 30, |rng| {
            let cap = 1 + (rng.next_u64() % 16) as usize;
            let extra = (rng.next_u64() % 24) as usize;
            let total = cap + extra;
            let rec = FlightRecorder::new(cap);
            let m = Metrics::new();
            for i in 0..total {
                if i % 3 == 0 {
                    m.on_submit();
                }
                rec.record(m.snapshot());
            }
            prop::ensure_eq(rec.len(), cap, "window is full")?;
            prop::ensure_eq(
                rec.recorded(),
                total as u64,
                "all records counted",
            )?;
            let w = rec.window();
            for (i, s) in w.iter().enumerate() {
                prop::ensure_eq(
                    s.seq,
                    (total - cap + i) as u64,
                    "seq is the last cap values in order",
                )?;
            }
            for pair in w.windows(2) {
                prop::ensure(
                    pair[1].t_s >= pair[0].t_s,
                    "timestamps monotonic",
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn capacity_floor_and_json_shape() {
        let rec = FlightRecorder::new(0); // clamped to 1
        assert_eq!(rec.cap(), 1);
        assert!(rec.is_empty());
        let m = Metrics::new();
        m.on_submit();
        m.on_complete(1e-4, 2e-4, 1000);
        rec.record(m.snapshot());
        rec.record(m.snapshot());
        assert_eq!(rec.len(), 1);
        let j = rec.to_json();
        assert_eq!(j.u("cap").unwrap(), 1);
        let samples = j.arr("samples").unwrap();
        assert_eq!(samples.len(), 1);
        assert_eq!(samples[0].u("seq").unwrap(), 1);
        assert!(samples[0].f("t_s").unwrap() >= 0.0);
        assert_eq!(
            samples[0].get("metrics").unwrap().u("completed").unwrap(),
            1
        );
    }
}
