//! Declarative SLO rules and their evaluation over metrics snapshots.
//!
//! Rules are written as a comma-separated spec (the `--slo` flag /
//! `"slo"` settings key):
//!
//! ```text
//! p99_ms<=5,shed<=0.05,ape<=0.5,eff>=0.3
//! ```
//!
//! - `p99_ms<=X` — end-to-end p99 latency ceiling in milliseconds;
//! - `shed<=X`   — shed-rate ceiling (shed / submitted, 0..1);
//! - `ape<=X`    — Block2Time residual p95 absolute-percentage-error
//!   ceiling (fraction, 0.5 = 50%) per shape bucket;
//! - `eff>=X`    — roofline-efficiency floor (only evaluated when the
//!   caller supplies a measured efficiency, e.g. from the attribution
//!   profiler).
//!
//! The watchdog in `coordinator::service` evaluates these over the
//! flight-recorder sampling interval and wires breaches to actions:
//! latency/APE breaches force a background re-tune of the offending
//! bucket; shed breaches tighten the open-loop admission bound in the
//! fleet sim (`fleet::sim::run_trace_open_adaptive`).

use super::metrics::MetricsSnapshot;

/// One declarative service-level objective.
#[derive(Debug, Clone, PartialEq)]
pub enum SloRule {
    /// End-to-end p99 latency ceiling, milliseconds.
    P99Ms(f64),
    /// Shed-rate ceiling, fraction of submitted requests.
    ShedRate(f64),
    /// Residual p95-APE ceiling, fraction.
    ApeCeil(f64),
    /// Roofline-efficiency floor, fraction.
    EffFloor(f64),
}

impl SloRule {
    /// Short stable name used in breach events and trace spans.
    pub fn name(&self) -> &'static str {
        match self {
            SloRule::P99Ms(_) => "p99_ms",
            SloRule::ShedRate(_) => "shed",
            SloRule::ApeCeil(_) => "ape",
            SloRule::EffFloor(_) => "eff",
        }
    }

    pub fn limit(&self) -> f64 {
        match self {
            SloRule::P99Ms(v)
            | SloRule::ShedRate(v)
            | SloRule::ApeCeil(v)
            | SloRule::EffFloor(v) => *v,
        }
    }
}

/// Parse a comma-separated rule spec. Whitespace around rules is
/// ignored; unknown rules and malformed thresholds are errors.
pub fn parse_rules(spec: &str) -> Result<Vec<SloRule>, String> {
    let mut rules = Vec::new();
    for part in spec.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (rule, op, value) = if let Some((l, r)) = part.split_once("<=") {
            (l.trim(), "<=", r.trim())
        } else if let Some((l, r)) = part.split_once(">=") {
            (l.trim(), ">=", r.trim())
        } else {
            return Err(format!(
                "SLO rule {part:?}: expected `name<=value` or `name>=value`"
            ));
        };
        let v: f64 = value
            .parse()
            .map_err(|_| format!("SLO rule {part:?}: bad threshold {value:?}"))?;
        if !v.is_finite() || v < 0.0 {
            return Err(format!(
                "SLO rule {part:?}: threshold must be finite and >= 0"
            ));
        }
        let parsed = match (rule, op) {
            ("p99_ms", "<=") => SloRule::P99Ms(v),
            ("shed", "<=") => SloRule::ShedRate(v),
            ("ape", "<=") => SloRule::ApeCeil(v),
            ("eff", ">=") => SloRule::EffFloor(v),
            _ => {
                return Err(format!(
                    "SLO rule {part:?}: unknown rule/operator (expected \
                     p99_ms<=, shed<=, ape<=, eff>=)"
                ))
            }
        };
        rules.push(parsed);
    }
    if rules.is_empty() {
        return Err("empty SLO spec".into());
    }
    Ok(rules)
}

/// A rule violation observed on one snapshot.
#[derive(Debug, Clone)]
pub struct Breach {
    /// Rule name (`p99_ms`, `shed`, `ape`, `eff`).
    pub rule: String,
    /// Index of the rule in the evaluated slice.
    pub index: usize,
    /// Observed value.
    pub value: f64,
    /// Configured threshold.
    pub limit: f64,
    /// Offending shape bucket, when the rule is bucket-scoped (APE).
    pub bucket: Option<String>,
}

/// Evaluate `rules` against a snapshot. `min_eff` is the measured
/// roofline efficiency when the caller has one (the profiler must be
/// enabled for it to exist); `EffFloor` rules are skipped otherwise.
pub fn evaluate(
    rules: &[SloRule],
    snap: &MetricsSnapshot,
    min_eff: Option<f64>,
) -> Vec<Breach> {
    let mut out = Vec::new();
    for (index, rule) in rules.iter().enumerate() {
        let breach = match rule {
            SloRule::P99Ms(limit) => {
                if snap.e2e.count() == 0 {
                    None
                } else {
                    let p99_ms = snap.e2e.quantile_us(0.99) / 1e3;
                    (p99_ms > *limit).then(|| (p99_ms, *limit, None))
                }
            }
            SloRule::ShedRate(limit) => {
                if snap.requests == 0 {
                    None
                } else {
                    let rate = snap.shed as f64 / snap.requests as f64;
                    (rate > *limit).then(|| (rate, *limit, None))
                }
            }
            SloRule::ApeCeil(limit) => snap
                .residuals
                .iter()
                .filter(|r| r.p95_ape.is_finite())
                .max_by(|a, b| {
                    a.p95_ape
                        .partial_cmp(&b.p95_ape)
                        .unwrap_or(std::cmp::Ordering::Equal)
                })
                .and_then(|worst| {
                    (worst.p95_ape > *limit).then(|| {
                        (worst.p95_ape, *limit, Some(worst.bucket.clone()))
                    })
                }),
            SloRule::EffFloor(limit) => min_eff
                .and_then(|eff| (eff < *limit).then(|| (eff, *limit, None))),
        };
        if let Some((value, limit, bucket)) = breach {
            out.push(Breach {
                rule: rule.name().to_string(),
                index,
                value,
                limit,
                bucket,
            });
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Metrics;

    #[test]
    fn parse_round_trip_and_errors() {
        let rules =
            parse_rules(" p99_ms<=5 , shed<=0.05, ape<=0.5, eff>=0.3 ")
                .unwrap();
        assert_eq!(
            rules,
            vec![
                SloRule::P99Ms(5.0),
                SloRule::ShedRate(0.05),
                SloRule::ApeCeil(0.5),
                SloRule::EffFloor(0.3),
            ]
        );
        assert_eq!(rules[0].name(), "p99_ms");
        assert_eq!(rules[3].limit(), 0.3);
        assert!(parse_rules("").is_err());
        assert!(parse_rules("p99_ms<=nope").is_err());
        assert!(parse_rules("latency<=5").is_err());
        // wrong operator direction is rejected, not silently flipped
        assert!(parse_rules("eff<=0.3").is_err());
        assert!(parse_rules("p99_ms>=5").is_err());
        assert!(parse_rules("p99_ms<=-1").is_err());
        assert!(parse_rules("p99_ms<=inf").is_err());
    }

    #[test]
    fn quiet_snapshot_never_breaches() {
        let rules = parse_rules("p99_ms<=0.001,shed<=0.0,ape<=0.0").unwrap();
        let snap = Metrics::new().snapshot();
        // zero requests / no residuals: every rule is skipped
        assert!(evaluate(&rules, &snap, None).is_empty());
    }

    #[test]
    fn p99_and_shed_breach_on_real_metrics() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.on_submit();
        }
        for _ in 0..8 {
            // ~10ms e2e latency
            m.on_complete(2e-3, 8e-3, 1000);
        }
        m.on_shed();
        m.on_shed();
        let snap = m.snapshot();
        let rules = parse_rules("p99_ms<=5,shed<=0.1").unwrap();
        let breaches = evaluate(&rules, &snap, None);
        assert_eq!(breaches.len(), 2);
        let p99 = &breaches[0];
        assert_eq!(p99.rule, "p99_ms");
        assert_eq!(p99.index, 0);
        assert!(p99.value > 5.0, "p99 {}", p99.value);
        assert!(p99.bucket.is_none());
        let shed = &breaches[1];
        assert_eq!(shed.rule, "shed");
        assert!((shed.value - 0.2).abs() < 1e-12);
        // generous limits: no breach
        let ok = parse_rules("p99_ms<=1000,shed<=0.5").unwrap();
        assert!(evaluate(&ok, &snap, None).is_empty());
    }

    #[test]
    fn ape_breach_carries_worst_bucket() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.on_residual("64x64x64", Some(1.05e-3), 1e-3); // 5% APE
            m.on_residual("128x128x128", Some(2e-3), 1e-3); // 100% APE
        }
        let snap = m.snapshot();
        let rules = parse_rules("ape<=0.5").unwrap();
        let breaches = evaluate(&rules, &snap, None);
        assert_eq!(breaches.len(), 1);
        assert_eq!(breaches[0].rule, "ape");
        assert_eq!(breaches[0].bucket.as_deref(), Some("128x128x128"));
        assert!(breaches[0].value > 0.5);
        // the tight bucket alone would pass
        let loose = parse_rules("ape<=1.5").unwrap();
        assert!(evaluate(&loose, &snap, None).is_empty());
    }

    #[test]
    fn eff_floor_requires_a_measurement() {
        let rules = parse_rules("eff>=0.5").unwrap();
        let snap = Metrics::new().snapshot();
        assert!(evaluate(&rules, &snap, None).is_empty());
        let breaches = evaluate(&rules, &snap, Some(0.2));
        assert_eq!(breaches.len(), 1);
        assert_eq!(breaches[0].rule, "eff");
        assert!((breaches[0].value - 0.2).abs() < 1e-12);
        assert!(evaluate(&rules, &snap, Some(0.8)).is_empty());
    }
}
