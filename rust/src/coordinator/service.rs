//! The coordinator service: queue → place (fleet) → route → (batch) →
//! execute → observe → reply.
//!
//! Since the fleet refactor the coordinator no longer assumes a single
//! engine: [`Coordinator::start_fleet`] takes one engine per fleet
//! device, every GEMM/MLP is placed by the fleet scheduler (lowest
//! Block2Time-predicted completion time), and each measured latency is
//! folded back into the owning device's tuner cache — drift past the
//! staleness policy schedules a background re-tune.
//! [`Coordinator::start`] is the single-device special case.

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::recorder::FlightRecorder;
use super::request::{
    GemmRequest, GemmResponse, MlpRequest, MlpResponse, ReplyTo,
};
use super::router::Router;
use super::slo::{self, SloRule};
use crate::config::Settings;
use crate::decomp::GemmShape;
use crate::exec::{bounded, CancelToken, Receiver, Sender, Stopwatch};
use crate::fleet::Fleet;
use crate::gpu_sim::{Device, DeviceKind};
use crate::runtime::EngineHandle;
use crate::trace;
use crate::tuner::{
    Budget, DeviceFingerprint, Observation, ShapeBucket, StalenessPolicy,
    TuneOptions, Tuner,
};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// In-memory LRU entries each device's serving tuner cache holds.
const TUNER_CACHE_CAPACITY: usize = 256;
/// Pending background tune requests beyond which misses are dropped
/// (tuning is best-effort; the request path never waits on it).
const TUNE_QUEUE_CAP: usize = 32;

enum Work {
    Gemm(GemmRequest, Instant),
    Mlp(MlpRequest, Instant),
    /// Sentinel: the receiving worker exits its loop. `shutdown` sends
    /// one per worker so teardown never depends on every cloned
    /// [`CoordinatorHandle`] being dropped first.
    Shutdown,
}

/// One background tuning job for a specific fleet device.
enum TuneJob {
    /// Cache miss: tune unless a queued duplicate already landed.
    Miss { device: usize, shape: GemmShape },
    /// Staleness: measured latency drifted past policy — re-tune even
    /// though an entry exists.
    Revalidate { device: usize, shape: GemmShape },
}

/// Client handle: submit requests, read metrics. Cloneable; the service
/// shuts down when all handles are dropped and the queue drains.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: Sender<Work>,
    metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
}

/// The running coordinator.
pub struct Coordinator {
    pub handle: CoordinatorHandle,
    cancel: CancelToken,
    workers: Vec<JoinHandle<()>>,
    worker_count: usize,
    fleet: Arc<Fleet>,
    tune_tx: Option<Sender<TuneJob>>,
    /// Tells the tuner thread to fast-drain (skip queued tunes) at
    /// shutdown — background tuning is speculative and must never
    /// extend process exit by queue-depth × budget.
    tune_stop: CancelToken,
    tuner_cache_path: Option<PathBuf>,
    /// Periodic metrics-snapshot ring (the flight recorder); filled by
    /// the sampler thread, exported by `streamk serve --metrics-out`.
    recorder: Arc<FlightRecorder>,
    /// Stops the metrics sampler / SLO watchdog thread at shutdown.
    watch_stop: CancelToken,
}

impl Coordinator {
    /// Start the service over one warmed engine — the single-device
    /// fleet special case (device preset/CU count from `settings`).
    pub fn start(engine: EngineHandle, settings: &Settings) -> Self {
        let dev = Device::preset(DeviceKind::Mi200)
            .with_cus(settings.cus.min(120));
        Self::start_fleet(vec![engine], vec![dev], settings)
    }

    /// Start the service over a heterogeneous fleet: one engine per
    /// device. `settings.workers` threads consume the queue; GEMMs are
    /// placed per request, MLP requests flow through one dynamic
    /// batcher whose batches are placed as a unit.
    pub fn start_fleet(
        engines: Vec<EngineHandle>,
        devices: Vec<Device>,
        settings: &Settings,
    ) -> Self {
        assert!(!engines.is_empty(), "fleet needs at least one engine");
        assert_eq!(
            engines.len(),
            devices.len(),
            "one engine per fleet device"
        );
        let (tx, rx) = bounded::<Work>(settings.queue_cap);
        let metrics = Arc::new(Metrics::new());
        let cancel = CancelToken::new();
        let router =
            Router::new(&settings.algo, &settings.pad_policy, &settings.dtype);

        // Per-device tuners under the fleet: the scheduler consults the
        // caches on every GEMM; misses fall back to defaults and (when
        // enabled) enqueue a background tune so the *next* request in
        // that bucket hits; measured latencies feed the staleness loop.
        let opts = TuneOptions {
            top_k: settings.tune_top_k,
            budget: Budget::from_millis(settings.tune_budget_ms),
            width: settings.width(),
            ..TuneOptions::default()
        };
        let staleness = StalenessPolicy {
            max_age_s: settings.cache_max_age_s,
            max_drift: settings.tune_drift_pct as f64 / 100.0,
            ..StalenessPolicy::default()
        };
        let fleet = Arc::new(Fleet::new_with_blend(
            devices,
            opts,
            staleness,
            TUNER_CACHE_CAPACITY,
            settings.blend(),
        ));
        if let Some(path) = &settings.tuner_cache {
            match fleet.load_cache(path) {
                Ok((usable, total)) if total > 0 => {
                    if usable == 0 {
                        eprintln!(
                            "tuner: WARNING: {} holds {total} entries but \
                             none match any fleet device fingerprint \
                             (e.g. {}) — cache was tuned for different \
                             devices/cus; serving will re-tune from scratch",
                            path.display(),
                            DeviceFingerprint::of(
                                fleet.device(0).tuner.device()
                            )
                            .as_str(),
                        );
                    } else {
                        eprintln!(
                            "tuner: warmed {usable}/{total} entries from {}",
                            path.display()
                        );
                    }
                }
                Ok(_) => {}
                Err(e) => eprintln!("tuner: starting cold ({e})"),
            }
        }
        let (tune_tx, tune_rx) = bounded::<TuneJob>(TUNE_QUEUE_CAP);

        // MLP requests are funneled to a single batching thread so
        // concurrent small requests coalesce; GEMM work fans out across
        // the remaining workers.
        let (mlp_tx, mlp_rx) = bounded::<MlpRequest>(settings.queue_cap);
        let mut workers = Vec::new();
        {
            let engines = engines.clone();
            let metrics = metrics.clone();
            let router = router.clone();
            let fleet = fleet.clone();
            let tune_tx = tune_tx.clone();
            let batcher = Batcher::new(
                settings.max_batch,
                Duration::from_micros(settings.batch_window_us),
            );
            workers.push(
                std::thread::Builder::new()
                    .name("streamk-mlp-batcher".into())
                    .spawn(move || {
                        mlp_batch_loop(
                            engines, metrics, router, fleet, batcher, mlp_rx,
                            tune_tx,
                        )
                    })
                    .expect("spawn batcher"),
            );
        }
        // Background tune worker: drains miss/re-validate jobs, tunes
        // on the owning device's tuner, and inserts into that device's
        // cache. Exits when every sender (the workers + the
        // coordinator) is gone.
        let tune_stop = CancelToken::new();
        if settings.tune_on_miss {
            let fleet = fleet.clone();
            let metrics = metrics.clone();
            let stop = tune_stop.clone();
            workers.push(
                std::thread::Builder::new()
                    .name("streamk-tuner".into())
                    .spawn(move || tune_loop(fleet, metrics, tune_rx, stop))
                    .expect("spawn tuner"),
            );
        } else {
            drop(tune_rx); // workers' try_send sheds harmlessly
        }
        for i in 0..settings.workers {
            let rx = rx.clone();
            let engines = engines.clone();
            let metrics = metrics.clone();
            let router = router.clone();
            let mlp_tx = mlp_tx.clone();
            let cancel = cancel.clone();
            let fleet = fleet.clone();
            let tune_tx = tune_tx.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("streamk-coord-{i}"))
                    .spawn(move || {
                        worker_loop(
                            engines, metrics, router, rx, mlp_tx, cancel,
                            fleet, tune_tx,
                        )
                    })
                    .expect("spawn worker"),
            );
        }
        drop(mlp_tx); // batcher exits when all workers are gone

        // Metrics flight recorder + SLO watchdog: one sampler thread
        // snapshots `Metrics` into a fixed ring every
        // `metrics_interval_ms`, evaluates the declarative SLO rules
        // against each sample, and wires breaches to actions — a
        // latency/APE breach forces a re-validation tune of the worst
        // bucket, visible as `slo.breach`/`slo.retune` trace spans.
        let recorder = Arc::new(FlightRecorder::new(settings.metrics_window));
        let watch_stop = CancelToken::new();
        let slo_rules: Vec<SloRule> = settings
            .slo
            .as_deref()
            .and_then(|spec| slo::parse_rules(spec).ok())
            .unwrap_or_default();
        {
            let metrics = metrics.clone();
            let recorder = recorder.clone();
            let tune_tx = tune_tx.clone();
            let stop = watch_stop.clone();
            let interval = Duration::from_millis(settings.metrics_interval_ms);
            workers.push(
                std::thread::Builder::new()
                    .name("streamk-metrics".into())
                    .spawn(move || {
                        watch_loop(
                            metrics, recorder, slo_rules, tune_tx, stop,
                            interval,
                        )
                    })
                    .expect("spawn metrics sampler"),
            );
        }

        Coordinator {
            handle: CoordinatorHandle {
                tx,
                metrics,
                next_id: Arc::new(AtomicU64::new(1)),
            },
            cancel,
            workers,
            worker_count: settings.workers,
            fleet,
            tune_tx: Some(tune_tx),
            tune_stop,
            tuner_cache_path: settings.tuner_cache.clone(),
            recorder,
            watch_stop,
        }
    }

    /// Device 0's tuner (single-device observability / tests).
    pub fn tuner(&self) -> &Arc<Tuner> {
        &self.fleet.device(0).tuner
    }

    /// The fleet behind this coordinator.
    pub fn fleet(&self) -> &Arc<Fleet> {
        &self.fleet
    }

    /// The metrics flight recorder (periodic snapshot ring).
    pub fn recorder(&self) -> &Arc<FlightRecorder> {
        &self.recorder
    }

    /// Graceful shutdown: drain queued work, then join all threads.
    /// Safe even when clones of [`Coordinator::handle`] are still alive:
    /// one shutdown sentinel per worker ends each loop after the queue
    /// ahead of it has been processed.
    pub fn shutdown(mut self) {
        for _ in 0..self.worker_count {
            let _ = self.handle.tx.send(Work::Shutdown);
        }
        drop(self.handle);
        // Queued tunes are speculative: tell the tuner thread to
        // fast-drain instead of spending queue-depth × budget on shapes
        // no request will ever use, then release the coordinator's tune
        // sender so its channel disconnects once the workers exit.
        self.tune_stop.cancel();
        self.watch_stop.cancel();
        drop(self.tune_tx.take());
        for w in self.workers.drain(..) {
            w.join().expect("coordinator worker panicked");
        }
        if let Some(path) = &self.tuner_cache_path {
            if let Err(e) = self.fleet.store_cache(path) {
                eprintln!("tuner: cache not persisted: {e}");
            }
        }
    }

    /// Abort: cancel in-flight batching loops (queue is not drained).
    pub fn abort(self) {
        self.cancel.cancel();
        self.shutdown();
    }
}

impl CoordinatorHandle {
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit a GEMM; blocks on a full queue (backpressure).
    pub fn submit_gemm(
        &self,
        m: usize,
        n: usize,
        k: usize,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> Receiver<GemmResponse> {
        let (reply, waiter) = ReplyTo::pair();
        let req = GemmRequest { id: self.id(), m, n, k, a, b, reply };
        self.metrics.on_submit();
        if self.tx.send(Work::Gemm(req, Instant::now())).is_err() {
            self.metrics.on_fail();
        }
        waiter
    }

    /// Submit a GEMM without blocking; sheds load when the queue is full
    /// (returns `None`).
    pub fn try_submit_gemm(
        &self,
        m: usize,
        n: usize,
        k: usize,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> Option<Receiver<GemmResponse>> {
        let (reply, waiter) = ReplyTo::pair();
        let req = GemmRequest { id: self.id(), m, n, k, a, b, reply };
        match self.tx.try_send(Work::Gemm(req, Instant::now())) {
            Ok(()) => {
                self.metrics.on_submit();
                Some(waiter)
            }
            Err(_) => {
                self.metrics.on_shed();
                None
            }
        }
    }

    /// Submit MLP activations without blocking; sheds load when the
    /// queue is full (returns `None`) — the serving tier's SHED path.
    pub fn try_submit_mlp(
        &self,
        rows: usize,
        x: Vec<f32>,
    ) -> Option<Receiver<MlpResponse>> {
        let (reply, waiter) = ReplyTo::pair();
        let req = MlpRequest { id: self.id(), rows, x, reply };
        match self.tx.try_send(Work::Mlp(req, Instant::now())) {
            Ok(()) => {
                self.metrics.on_submit();
                Some(waiter)
            }
            Err(_) => {
                self.metrics.on_shed();
                None
            }
        }
    }

    /// Submit `rows` MLP activations of width d_in.
    pub fn submit_mlp(&self, rows: usize, x: Vec<f32>) -> Receiver<MlpResponse> {
        let (reply, waiter) = ReplyTo::pair();
        let req = MlpRequest { id: self.id(), rows, x, reply };
        self.metrics.on_submit();
        if self.tx.send(Work::Mlp(req, Instant::now())).is_err() {
            self.metrics.on_fail();
        }
        waiter
    }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    engines: Vec<EngineHandle>,
    metrics: Arc<Metrics>,
    router: Router,
    rx: Receiver<Work>,
    mlp_tx: Sender<MlpRequest>,
    cancel: CancelToken,
    fleet: Arc<Fleet>,
    tune_tx: Sender<TuneJob>,
) {
    while let Ok(work) = rx.recv() {
        if cancel.is_cancelled() {
            break;
        }
        match work {
            Work::Gemm(req, enqueued) => {
                let queue_s = enqueued.elapsed().as_secs_f64();
                handle_gemm(
                    &engines, &metrics, &router, &fleet, &tune_tx, req,
                    queue_s,
                );
            }
            Work::Mlp(req, _enqueued) => {
                // Forward to the batching thread; it owns timing.
                if mlp_tx.send(req).is_err() {
                    metrics.on_fail();
                }
            }
            Work::Shutdown => break,
        }
    }
}

fn handle_gemm(
    engines: &[EngineHandle],
    metrics: &Metrics,
    router: &Router,
    fleet: &Arc<Fleet>,
    tune_tx: &Sender<TuneJob>,
    req: GemmRequest,
    queue_s: f64,
) {
    let GemmRequest { id, m, n, k, a, b, reply } = req;
    let shape = GemmShape::new(m, n, k);
    // Sampled request-lifecycle tracing: every Nth request (see
    // `trace::set_sample_every`) records the admit→execute span chain.
    // Kernel/engine spans below this level follow the global gate alone.
    let sampled = trace::request_sampled();
    let _req_span =
        trace::span2_if(sampled, "request.gemm", "id", id, "m", m as u64);
    // Fleet placement: lowest Block2Time-predicted completion time
    // given predicted work in flight; least-loaded fallback. Never
    // blocks, never panics on poisoned predictions.
    let placement = {
        let _s = trace::span_if(sampled, "coord.place");
        fleet.place_gemm(shape)
    };
    let device = placement.device;
    let fdev = fleet.device(device);
    metrics.on_place(device, placement.fallback);
    // Consult the owning device's tuning cache for this shape's
    // bucket. A hit steers routing (tuned pad policy first); a miss
    // enqueues a background tune without ever blocking the request.
    let tuned = {
        let _s = trace::span_if(sampled, "coord.tuner");
        if shape.is_degenerate() { None } else { fdev.tuner.lookup(shape) }
    };
    let pad_override = match &tuned {
        Some(cfg) => {
            metrics.on_tuner_hit();
            Some(cfg.pad.as_str())
        }
        None => {
            metrics.on_tuner_miss();
            if !shape.is_degenerate() {
                // best-effort; shed on full
                let _ = tune_tx.try_send(TuneJob::Miss { device, shape });
            }
            None
        }
    };
    // Tuned-KC serving wiring: a cache hit's K-chunk rides the request
    // into the engine so the kernel packs at the tuned chunk length
    // (bit-neutral — `kc` only changes packing locality).
    let kc_hint = tuned.as_ref().map(|cfg| cfg.params.kc);
    let engine = &engines[device];
    let routed = {
        let _s = trace::span_if(sampled, "coord.route");
        router.route_gemm_fleet(
            engine.manifest(),
            m,
            n,
            k,
            pad_override,
            fdev.device().num_cus,
        )
    };
    match routed {
        Ok(artifact) => {
            let exec_span = trace::span2_if(
                sampled,
                "coord.execute",
                "device",
                device as u64,
                "kc",
                kc_hint.unwrap_or(0) as u64,
            );
            let sw = Stopwatch::start();
            match engine.run_f32_kc(
                &artifact,
                vec![Arc::new(a), Arc::new(b)],
                kc_hint,
            ) {
                Ok((mut outs, stats)) => {
                    let execute_s = sw.elapsed_secs();
                    drop(exec_span);
                    fleet.complete(&placement);
                    // Block2Time residual accounting: pair the
                    // scheduler's prediction with the measured latency,
                    // per shape bucket. The residual also drives the
                    // drift loop below, so mis-predictions re-tune even
                    // when the bucket has no cache entry yet. Fleets of
                    // more than one device key per-device so a slow
                    // outlier doesn't hide inside the shape's average.
                    metrics.on_residual(
                        &residual_key(fleet, device, shape),
                        placement.predicted_s,
                        execute_s,
                    );
                    // Online Block2Time loop: fold the measured latency
                    // into the owning device's cache; drift past policy
                    // schedules a background re-tune.
                    if let Observation::Drifted { .. } = fleet
                        .observe_residual(
                            device,
                            shape,
                            placement.predicted_s,
                            execute_s,
                        )
                    {
                        metrics.on_drift_revalidate();
                        let _ = tune_tx
                            .try_send(TuneJob::Revalidate { device, shape });
                    }
                    metrics.on_complete(queue_s, execute_s, stats.flops);
                    reply.send(GemmResponse {
                        id,
                        result: Ok(outs.swap_remove(0)),
                        artifact,
                        device,
                        queue_s,
                        execute_s,
                    });
                }
                Err(e) => {
                    fleet.complete(&placement);
                    metrics.on_fail();
                    reply.send(GemmResponse {
                        id,
                        result: Err(e.to_string()),
                        artifact,
                        device,
                        queue_s,
                        execute_s: 0.0,
                    });
                }
            }
        }
        Err(e) => {
            fleet.complete(&placement);
            metrics.on_fail();
            reply.send(GemmResponse {
                id,
                result: Err(e.to_string()),
                artifact: String::new(),
                device,
                queue_s,
                execute_s: 0.0,
            });
        }
    }
}

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;
    use crate::faults::{error_rate, naive_gemm, Matrix};
    use crate::prop::Rng;
    use crate::runtime::{spawn_engine, Manifest};
    use std::path::PathBuf;

    /// Minimal manifest the interpreter backend can serve — no HLO files
    /// needed, so the coordinator+tuner path is testable without
    /// `make artifacts`.
    fn test_manifest(tag: &str) -> (Manifest, PathBuf) {
        let dir = std::env::temp_dir().join(format!(
            "streamk-service-test-{tag}-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "version": 2,
              "artifacts": [
                {"name": "gemm_streamk_nopad_f32_64x64x64",
                 "file": "unused.hlo.txt", "experiment": "test",
                 "kind": "gemm", "flops": 524288,
                 "inputs": [{"shape": [64, 64], "dtype": "f32"},
                             {"shape": [64, 64], "dtype": "f32"}],
                 "outputs": [{"shape": [64, 64], "dtype": "f32"}],
                 "m": 64, "n": 64, "k": 64, "algo": "streamk",
                 "pad": "none", "dtype": "f32", "cus": 8},
                {"name": "mlp_streamk_f32_b8_256x512x256",
                 "file": "unused.hlo.txt", "experiment": "test",
                 "kind": "mlp", "flops": 4194304,
                 "inputs": [{"shape": [8, 256], "dtype": "f32"},
                             {"shape": [256, 512], "dtype": "f32"},
                             {"shape": [512], "dtype": "f32"},
                             {"shape": [512, 256], "dtype": "f32"},
                             {"shape": [256], "dtype": "f32"}],
                 "outputs": [{"shape": [8, 256], "dtype": "f32"}],
                 "dtype": "f32", "batch": 8}
              ]
            }"#,
        )
        .unwrap();
        (Manifest::load(&dir).unwrap(), dir)
    }

    #[test]
    fn gemm_path_consults_tuner_and_tunes_in_background() {
        let (manifest, dir) = test_manifest("tuner");
        let (engine, _join) = spawn_engine(manifest).unwrap();
        let cache_path = dir.join("tuner_cache.json");
        let settings = Settings {
            workers: 2,
            tuner_cache: Some(cache_path.clone()),
            ..Settings::default()
        };
        let coord = Coordinator::start(engine, &settings);

        let mut rng = Rng::new(99);
        let a = Matrix::random(64, 64, &mut rng);
        let b = Matrix::random(64, 64, &mut rng);
        let want = naive_gemm(&a, &b);
        let w = coord.handle.submit_gemm(
            64,
            64,
            64,
            a.data.clone(),
            b.data.clone(),
        );
        let resp = w.recv().unwrap();
        let got = resp.result.expect("gemm ok");
        assert!(error_rate(&got, &want.data, 1e-3).passed());
        assert_eq!(resp.artifact, "gemm_streamk_nopad_f32_64x64x64");

        // first request missed the cold cache
        let snap = coord.handle.metrics().snapshot();
        assert_eq!(snap.tuner_misses, 1);
        assert_eq!(snap.tuner_hits, 0);
        // single-device fleet: everything placed on device 0
        assert_eq!(snap.placements, vec![1]);
        // residual accounting: the plan-backed placement prediction was
        // paired with the measured latency under the shape's bucket
        assert_eq!(snap.residuals.len(), 1, "{:?}", snap.residuals);
        assert_eq!(snap.residuals[0].bucket, "64x64x64");
        assert_eq!(snap.residuals[0].count, 1);
        assert!(snap.residuals[0].ewma_bias.is_finite());
        assert!(snap.residuals[0].p95_ape.is_finite());

        // the background worker tunes the bucket; wait for it
        let sw = Stopwatch::start();
        while coord.tuner().is_empty() && sw.elapsed_secs() < 30.0 {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!coord.tuner().is_empty(), "background tune never landed");

        // the next request in the same bucket hits
        let w = coord.handle.submit_gemm(
            64,
            64,
            64,
            a.data.clone(),
            b.data.clone(),
        );
        assert!(w.recv().unwrap().result.is_ok());
        let snap = coord.handle.metrics().snapshot();
        assert_eq!(snap.tuner_hits, 1);
        assert!(snap.tunes >= 1);
        assert!(snap.tune.mean_us() > 0.0);
        // the hit's measured latency was folded into the cache
        assert!(coord.tuner().lookup(GemmShape::new(64, 64, 64)).is_some());

        // shutdown persists the cache...
        coord.shutdown();
        assert!(cache_path.exists(), "cache must persist on shutdown");

        // ...and a fresh coordinator warms from it: first request hits.
        let (manifest, _) = test_manifest("tuner");
        let (engine, _join) = spawn_engine(manifest).unwrap();
        let coord = Coordinator::start(engine, &settings);
        let w = coord.handle.submit_gemm(
            64,
            64,
            64,
            a.data.clone(),
            b.data.clone(),
        );
        assert!(w.recv().unwrap().result.is_ok());
        let snap = coord.handle.metrics().snapshot();
        assert_eq!(snap.tuner_hits, 1);
        assert_eq!(snap.tuner_misses, 0);
        coord.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mlp_batches_fold_into_the_tune_on_miss_queue() {
        // The batcher's GEMM-equivalent bucket must flow through the
        // same background tune queue as GEMM misses (the PR-2 ROADMAP
        // gap: MLP observations used to be fire-and-forget NoEntry).
        let (manifest, dir) = test_manifest("mlp-tune");
        let (engine, _join) = spawn_engine(manifest).unwrap();
        let settings = Settings { workers: 1, ..Settings::default() };
        let coord = Coordinator::start(engine, &settings);

        let rows = 2usize;
        let w = coord.handle.submit_mlp(rows, vec![0.1; rows * 256]);
        assert!(w.recv().unwrap().result.is_ok());

        // The MLP-equivalent GEMM shape the batch was priced as.
        let params = mlp_params();
        let eq_shape = GemmShape::new(
            rows,
            params.d_hidden,
            params.d_in + params.d_out,
        );
        // The background worker tunes that bucket; wait for it.
        let sw = Stopwatch::start();
        while coord.tuner().lookup(eq_shape).is_none()
            && sw.elapsed_secs() < 30.0
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(
            coord.tuner().lookup(eq_shape).is_some(),
            "MLP bucket never reached the tune queue"
        );
        // A second batch of the same size now observes a live entry.
        let w = coord.handle.submit_mlp(rows, vec![0.2; rows * 256]);
        assert!(w.recv().unwrap().result.is_ok());
        let sw = Stopwatch::start();
        loop {
            let cfg = coord.tuner().lookup(eq_shape).expect("entry stays");
            if cfg.observed_n >= 1 || sw.elapsed_secs() > 30.0 {
                assert!(
                    cfg.observed_n >= 1,
                    "second batch must fold an observation into the entry"
                );
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        coord.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tune_on_miss_disabled_still_serves() {
        let (manifest, dir) = test_manifest("no-tune");
        let (engine, _join) = spawn_engine(manifest).unwrap();
        let settings = Settings {
            workers: 1,
            tune_on_miss: false,
            ..Settings::default()
        };
        let coord = Coordinator::start(engine, &settings);
        let w = coord.handle.submit_gemm(
            64,
            64,
            64,
            vec![1.0; 64 * 64],
            vec![1.0; 64 * 64],
        );
        let resp = w.recv().unwrap();
        let out = resp.result.unwrap();
        assert!(out.iter().all(|&v| (v - 64.0).abs() < 1e-3));
        let snap = coord.handle.metrics().snapshot();
        assert_eq!(snap.tuner_misses, 1);
        assert_eq!(snap.tunes, 0);
        coord.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fleet_places_on_the_predicted_fastest_engine() {
        // Two engines over the same manifest behind a 2-device fleet
        // where device 1 (MI200) is strictly faster than device 0
        // (MI100). With one worker (no requests in flight at placement
        // time), every GEMM must deterministically land on device 1 —
        // the non-zero engine index, which also proves the multi-engine
        // path actually routes off engine 0.
        let (manifest, dir) = test_manifest("fleet");
        let (engine_a, _ja) = spawn_engine(manifest.clone()).unwrap();
        let (engine_b, _jb) = spawn_engine(manifest).unwrap();
        let settings = Settings {
            workers: 1,
            tune_on_miss: false,
            ..Settings::default()
        };
        let devices = vec![
            Device::preset(DeviceKind::Mi100),
            Device::preset(DeviceKind::Mi200),
        ];
        let coord = Coordinator::start_fleet(
            vec![engine_a, engine_b],
            devices,
            &settings,
        );

        let requests = 12u64;
        let waiters: Vec<_> = (0..requests)
            .map(|_| {
                coord.handle.submit_gemm(
                    64,
                    64,
                    64,
                    vec![1.0; 64 * 64],
                    vec![1.0; 64 * 64],
                )
            })
            .collect();
        for w in waiters {
            let resp = w.recv().unwrap();
            let out = resp.result.expect("gemm ok");
            assert!(out.iter().all(|&v| (v - 64.0).abs() < 1e-3));
            assert_eq!(resp.artifact, "gemm_streamk_nopad_f32_64x64x64");
        }
        let snap = coord.handle.metrics().snapshot();
        assert_eq!(snap.completed, requests);
        assert_eq!(
            snap.placements,
            vec![0, requests],
            "every placement goes to the faster device"
        );
        assert_eq!(snap.placement_fallbacks, 0);
        // queue accounting drained
        for i in 0..2 {
            assert_eq!(coord.fleet().device(i).queue_depth(), 0);
        }
        coord.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slo_watchdog_trips_p99_and_forces_retune() {
        // An un-meetable p99 ceiling (0.1µs) must breach within one
        // flight-recorder sampling window of the first completed
        // request and force a re-validation tune, observable as
        // `slo.breach`/`slo.retune` trace events plus the
        // drift_revalidations counter.
        let _g = trace::test_lock();
        trace::set_enabled(true);
        let _ = trace::drain();
        let (manifest, dir) = test_manifest("slo");
        let (engine, _join) = spawn_engine(manifest).unwrap();
        let settings = Settings {
            workers: 2,
            metrics_interval_ms: 5,
            metrics_window: 64,
            slo: Some("p99_ms<=0.0001".into()),
            ..Settings::default()
        };
        let coord = Coordinator::start(engine, &settings);
        for _ in 0..4 {
            let w = coord.handle.submit_gemm(
                64,
                64,
                64,
                vec![1.0; 64 * 64],
                vec![1.0; 64 * 64],
            );
            assert!(w.recv().unwrap().result.is_ok());
        }
        // the watchdog increments drift_revalidations on every forced
        // re-tune; wait for the first firing
        let sw = Stopwatch::start();
        while coord.handle.metrics().snapshot().drift_revalidations == 0
            && sw.elapsed_secs() < 30.0
        {
            std::thread::sleep(Duration::from_millis(2));
        }
        let snap = coord.handle.metrics().snapshot();
        assert!(
            snap.drift_revalidations >= 1,
            "SLO watchdog never forced a re-tune"
        );
        // give the sampler one more window so the recorder has samples
        let sw = Stopwatch::start();
        while coord.recorder().is_empty() && sw.elapsed_secs() < 30.0 {
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!coord.recorder().is_empty(), "flight recorder stayed empty");
        let timeline = coord.recorder().to_json();
        assert!(!timeline.arr("samples").unwrap().is_empty());
        coord.shutdown();
        trace::set_enabled(false);
        let (events, _, _) = trace::drain();
        assert!(
            events.iter().any(|e| e.name == "slo.breach"),
            "no slo.breach trace event"
        );
        assert!(
            events.iter().any(|e| e.name == "slo.retune"),
            "no slo.retune trace event"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
}

/// MLP weights are baked into the artifact? No — the MLP artifacts take
/// (x, w1, b1, w2, b2); the service holds one parameter set, uploaded at
/// start via [`MlpParams`]. Defaults to a deterministic pseudo-random
/// init so examples/benches run out of the box.
pub struct MlpParams {
    pub d_in: usize,
    pub d_hidden: usize,
    pub d_out: usize,
    pub w1: Arc<Vec<f32>>,
    pub b1: Arc<Vec<f32>>,
    pub w2: Arc<Vec<f32>>,
    pub b2: Arc<Vec<f32>>,
}

impl MlpParams {
    pub fn deterministic(d_in: usize, d_hidden: usize, d_out: usize) -> Self {
        let mut rng = crate::prop::Rng::new(0x5EED);
        let scale_1 = (2.0 / d_in as f64).sqrt() as f32;
        let scale_2 = (2.0 / d_hidden as f64).sqrt() as f32;
        Self {
            d_in,
            d_hidden,
            d_out,
            w1: Arc::new(
                rng.normal_f32_vec(d_in * d_hidden)
                    .iter()
                    .map(|v| v * scale_1)
                    .collect(),
            ),
            b1: Arc::new(vec![0.01; d_hidden]),
            w2: Arc::new(
                rng.normal_f32_vec(d_hidden * d_out)
                    .iter()
                    .map(|v| v * scale_2)
                    .collect(),
            ),
            b2: Arc::new(vec![0.01; d_out]),
        }
    }
}

static MLP_PARAMS: std::sync::OnceLock<MlpParams> = std::sync::OnceLock::new();

/// The MLP parameter set served by every coordinator in this process.
pub fn mlp_params() -> &'static MlpParams {
    MLP_PARAMS.get_or_init(|| MlpParams::deterministic(256, 512, 256))
}

/// Background tune worker, fleet-aware: a `Miss` tunes once per bucket
/// per device (re-checked against that device's cache so a burst of
/// misses tunes once); a `Revalidate` always re-tunes — the entry
/// exists but its measurements drifted past the staleness policy.
/// On `stop` it keeps draining the channel but skips the tuning work,
/// so shutdown latency is bounded by at most one in-flight tune.
fn tune_loop(
    fleet: Arc<Fleet>,
    metrics: Arc<Metrics>,
    rx: Receiver<TuneJob>,
    stop: CancelToken,
) {
    while let Ok(job) = rx.recv() {
        if stop.is_cancelled() {
            continue; // fast-drain: shutting down
        }
        let (device, shape, revalidate) = match job {
            TuneJob::Miss { device, shape } => (device, shape, false),
            TuneJob::Revalidate { device, shape } => (device, shape, true),
        };
        let tuner = &fleet.device(device).tuner;
        if !revalidate && tuner.lookup(shape).is_some() {
            continue; // raced: an earlier queued miss already tuned this
        }
        let sw = Stopwatch::start();
        // Re-validation carries the serving observations over so the
        // refreshed entry's prediction stays in measured-latency terms
        // and the drift that triggered it does not immediately recur.
        let result = if revalidate {
            tuner.retune_keeping_observations(shape)
        } else {
            tuner.tune_and_insert(shape)
        };
        match result {
            Ok(_) => metrics.on_tune(sw.elapsed_secs()),
            Err(e) => eprintln!("tuner: {shape:?}: {e}"),
        }
    }
}

/// Residual bucket key for a placement: bare shape bucket on a
/// single-device f32 fleet (existing dashboards/tests unchanged),
/// `{bucket}@{width}` at 16-bit widths so a bf16 bucket's residuals
/// never average into f32's, and `dev{idx}|{bucket}` once a real fleet
/// is behind the coordinator.
fn residual_key(fleet: &Arc<Fleet>, device: usize, shape: GemmShape) -> String {
    let bucket = crate::trace::profile::width_key(
        &ShapeBucket::of(shape).key(),
        fleet.width(),
    );
    if fleet.len() > 1 {
        crate::trace::residual::device_key(device, &bucket)
    } else {
        bucket
    }
}

/// Metrics sampler + SLO watchdog. Every `interval` it snapshots
/// `metrics` into the flight recorder and evaluates the SLO rules over
/// the sample. Breaches emit `slo.breach` trace events; latency and
/// prediction-error breaches additionally force a re-validation tune
/// of the worst-offending bucket's representative shape on its device
/// (`slo.retune`) — closing the loop the per-request drift policy only
/// covers for shapes that keep arriving. A per-rule cooldown keeps a
/// persistent breach from flooding the tune queue faster than tuning
/// can help.
fn watch_loop(
    metrics: Arc<Metrics>,
    recorder: Arc<FlightRecorder>,
    rules: Vec<SloRule>,
    tune_tx: Sender<TuneJob>,
    stop: CancelToken,
    interval: Duration,
) {
    /// Samples a breached rule stays quiet after firing its action.
    const COOLDOWN_SAMPLES: u64 = 4;
    let mut last_fired: Vec<Option<u64>> = vec![None; rules.len()];
    let mut sample: u64 = 0;
    loop {
        // Sleep in short slices so shutdown never waits out a long
        // sampling interval.
        let t0 = Instant::now();
        while t0.elapsed() < interval {
            if stop.is_cancelled() {
                return;
            }
            std::thread::sleep(interval.min(Duration::from_millis(5)));
        }
        if stop.is_cancelled() {
            return;
        }
        let snap = metrics.snapshot();
        for b in slo::evaluate(&rules, &snap, None) {
            let cooling = matches!(
                last_fired[b.index],
                Some(at) if sample.saturating_sub(at) < COOLDOWN_SAMPLES
            );
            if cooling {
                continue;
            }
            last_fired[b.index] = Some(sample);
            // Alert: zero-duration span carrying the rule index and
            // the breaching value in per-mille (integer args only).
            drop(trace::span2(
                "slo.breach",
                "rule",
                b.index as u64,
                "pm",
                (b.value * 1e3) as u64,
            ));
            eprintln!(
                "slo: BREACH {}={:.4} limit={:.4}{}",
                b.rule,
                b.value,
                b.limit,
                b.bucket
                    .as_deref()
                    .map(|bk| format!(" bucket={bk}"))
                    .unwrap_or_default(),
            );
            if !matches!(
                rules[b.index],
                SloRule::P99Ms(_) | SloRule::ApeCeil(_)
            ) {
                continue;
            }
            // Pick the bucket to re-tune: the breach's own (APE rules)
            // or the worst-predicted residual bucket (latency rules
            // carry none).
            let target = b.bucket.or_else(|| {
                snap.residuals
                    .iter()
                    .filter(|r| r.p95_ape.is_finite())
                    .max_by(|a, b| {
                        a.p95_ape
                            .partial_cmp(&b.p95_ape)
                            .unwrap_or(std::cmp::Ordering::Equal)
                    })
                    .map(|r| r.bucket.clone())
            });
            let Some(key) = target else { continue };
            let (device, bucket_part) =
                crate::trace::residual::split_device_key(&key);
            let Some(bucket) = ShapeBucket::parse(bucket_part) else {
                continue;
            };
            let device = device.unwrap_or(0);
            metrics.on_drift_revalidate();
            drop(trace::span1("slo.retune", "device", device as u64));
            let _ = tune_tx.try_send(TuneJob::Revalidate {
                device,
                shape: bucket.representative(),
            });
        }
        recorder.record(snap);
        sample += 1;
    }
}

fn mlp_batch_loop(
    engines: Vec<EngineHandle>,
    metrics: Arc<Metrics>,
    router: Router,
    fleet: Arc<Fleet>,
    mut batcher: Batcher,
    rx: Receiver<MlpRequest>,
    tune_tx: Sender<TuneJob>,
) {
    let params = mlp_params();
    while let Some(plan) = batcher.next_batch(&rx) {
        // One sampling draw covers the whole batch: batches are the
        // request unit on this path.
        let sampled = trace::request_sampled();
        let _batch_span = trace::span2_if(
            sampled,
            "request.mlp_batch",
            "rows",
            plan.total_rows as u64,
            "requests",
            plan.requests.len() as u64,
        );
        let sw = Stopwatch::start();
        metrics.on_batch(plan.total_rows);
        // Place the whole batch as one unit, priced as its equivalent
        // GEMM: the two layers cost 2·rows·d_hidden·(d_in + d_out)
        // FLOPs, which is exactly the GEMM
        // (rows × d_hidden × (d_in+d_out)) — pricing only one layer
        // would under-count in-flight work 2× at the default square
        // 256×512×256 MLP and skew placement.
        let eq_shape = GemmShape::new(
            plan.total_rows.max(1),
            params.d_hidden,
            params.d_in + params.d_out,
        );
        let placement = fleet.place_gemm(eq_shape);
        metrics.on_place(placement.device, placement.fallback);
        let engine = &engines[placement.device];
        let routed = router.route_mlp(engine.manifest(), plan.total_rows);
        let (artifact, batch) = match routed {
            Ok(v) => v,
            Err(e) => {
                fleet.complete(&placement);
                for req in plan.requests {
                    metrics.on_fail();
                    req.reply.send(MlpResponse {
                        id: req.id,
                        result: Err(e.to_string()),
                        batched_as: 0,
                        queue_s: 0.0,
                        execute_s: 0.0,
                    });
                }
                continue;
            }
        };
        let (x, offsets) = {
            let _s = trace::span_if(sampled, "batch.pack");
            plan.pack(params.d_in, batch)
        };
        let run = {
            let _s = trace::span2_if(
                sampled,
                "batch.execute",
                "device",
                placement.device as u64,
                "batch",
                batch as u64,
            );
            engine.run_f32(
                &artifact,
                vec![
                    Arc::new(x),
                    params.w1.clone(),
                    params.b1.clone(),
                    params.w2.clone(),
                    params.b2.clone(),
                ],
            )
        };
        let execute_s = sw.elapsed_secs();
        fleet.complete(&placement);
        match run {
            Ok((outs, stats)) => {
                // Residual accounting for the batch's GEMM-equivalent
                // bucket, same as the GEMM path (per-device keyed in
                // multi-device fleets).
                metrics.on_residual(
                    &residual_key(&fleet, placement.device, eq_shape),
                    placement.predicted_s,
                    execute_s,
                );
                // Feed the feedback loop with the batch's GEMM-equivalent
                // bucket. The batcher participates in the same
                // tune-on-miss / drift-revalidation queue as the GEMM
                // path: an untuned MLP bucket schedules a background
                // tune so future placements of that batch size are
                // priced from a real entry, and a drifted one re-tunes.
                match fleet.observe_residual(
                    placement.device,
                    eq_shape,
                    placement.predicted_s,
                    execute_s,
                ) {
                    Observation::NoEntry => {
                        // best-effort; shed on full
                        let _ = tune_tx.try_send(TuneJob::Miss {
                            device: placement.device,
                            shape: eq_shape,
                        });
                    }
                    Observation::Drifted { .. } => {
                        metrics.on_drift_revalidate();
                        let _ = tune_tx.try_send(TuneJob::Revalidate {
                            device: placement.device,
                            shape: eq_shape,
                        });
                    }
                    Observation::Updated { .. } | Observation::Rejected => {}
                }
                let split = {
                    let _s = trace::span_if(sampled, "batch.unpack");
                    plan.unpack(&outs[0], params.d_out, &offsets)
                };
                for (req, y) in plan.requests.into_iter().zip(split) {
                    metrics.on_complete(0.0, execute_s, stats.flops);
                    req.reply.send(MlpResponse {
                        id: req.id,
                        result: Ok(y),
                        batched_as: batch,
                        queue_s: 0.0,
                        execute_s,
                    });
                }
            }
            Err(e) => {
                for req in plan.requests {
                    metrics.on_fail();
                    req.reply.send(MlpResponse {
                        id: req.id,
                        result: Err(e.to_string()),
                        batched_as: batch,
                        queue_s: 0.0,
                        execute_s,
                    });
                }
            }
        }
    }
}
