//! The coordinator service: queue → route → (batch) → execute → reply.

use super::batcher::Batcher;
use super::metrics::Metrics;
use super::request::{
    GemmRequest, GemmResponse, MlpRequest, MlpResponse, ReplyTo,
};
use super::router::Router;
use crate::config::Settings;
use crate::exec::{bounded, CancelToken, Receiver, Sender, Stopwatch};
use crate::runtime::EngineHandle;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

enum Work {
    Gemm(GemmRequest, Instant),
    Mlp(MlpRequest, Instant),
    /// Sentinel: the receiving worker exits its loop. `shutdown` sends
    /// one per worker so teardown never depends on every cloned
    /// [`CoordinatorHandle`] being dropped first.
    Shutdown,
}

/// Client handle: submit requests, read metrics. Cloneable; the service
/// shuts down when all handles are dropped and the queue drains.
#[derive(Clone)]
pub struct CoordinatorHandle {
    tx: Sender<Work>,
    metrics: Arc<Metrics>,
    next_id: Arc<AtomicU64>,
}

/// The running coordinator.
pub struct Coordinator {
    pub handle: CoordinatorHandle,
    cancel: CancelToken,
    workers: Vec<JoinHandle<()>>,
    worker_count: usize,
}

impl Coordinator {
    /// Start the service over a warmed engine. `settings.workers` threads
    /// consume the queue; GEMMs execute directly, MLP requests flow
    /// through a per-worker dynamic batcher.
    pub fn start(engine: EngineHandle, settings: &Settings) -> Self {
        let (tx, rx) = bounded::<Work>(settings.queue_cap);
        let metrics = Arc::new(Metrics::new());
        let cancel = CancelToken::new();
        let router = Router::new(&settings.algo, &settings.pad_policy, "f32");

        // MLP requests are funneled to a single batching thread so
        // concurrent small requests coalesce; GEMM work fans out across
        // the remaining workers.
        let (mlp_tx, mlp_rx) = bounded::<MlpRequest>(settings.queue_cap);
        let mut workers = Vec::new();
        {
            let engine = engine.clone();
            let metrics = metrics.clone();
            let router = router.clone();
            let batcher = Batcher::new(
                settings.max_batch,
                Duration::from_micros(settings.batch_window_us),
            );
            workers.push(
                std::thread::Builder::new()
                    .name("streamk-mlp-batcher".into())
                    .spawn(move || {
                        mlp_batch_loop(engine, metrics, router, batcher, mlp_rx)
                    })
                    .expect("spawn batcher"),
            );
        }
        for i in 0..settings.workers {
            let rx = rx.clone();
            let engine = engine.clone();
            let metrics = metrics.clone();
            let router = router.clone();
            let mlp_tx = mlp_tx.clone();
            let cancel = cancel.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("streamk-coord-{i}"))
                    .spawn(move || {
                        worker_loop(engine, metrics, router, rx, mlp_tx, cancel)
                    })
                    .expect("spawn worker"),
            );
        }
        drop(mlp_tx); // batcher exits when all workers are gone

        Coordinator {
            handle: CoordinatorHandle {
                tx,
                metrics,
                next_id: Arc::new(AtomicU64::new(1)),
            },
            cancel,
            workers,
            worker_count: settings.workers,
        }
    }

    /// Graceful shutdown: drain queued work, then join all threads.
    /// Safe even when clones of [`Coordinator::handle`] are still alive:
    /// one shutdown sentinel per worker ends each loop after the queue
    /// ahead of it has been processed.
    pub fn shutdown(mut self) {
        for _ in 0..self.worker_count {
            let _ = self.handle.tx.send(Work::Shutdown);
        }
        drop(self.handle);
        for w in self.workers.drain(..) {
            w.join().expect("coordinator worker panicked");
        }
    }

    /// Abort: cancel in-flight batching loops (queue is not drained).
    pub fn abort(self) {
        self.cancel.cancel();
        self.shutdown();
    }
}

impl CoordinatorHandle {
    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }

    fn id(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Submit a GEMM; blocks on a full queue (backpressure).
    pub fn submit_gemm(
        &self,
        m: usize,
        n: usize,
        k: usize,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> Receiver<GemmResponse> {
        let (reply, waiter) = ReplyTo::pair();
        let req = GemmRequest { id: self.id(), m, n, k, a, b, reply };
        self.metrics.on_submit();
        if self.tx.send(Work::Gemm(req, Instant::now())).is_err() {
            self.metrics.on_fail();
        }
        waiter
    }

    /// Submit a GEMM without blocking; sheds load when the queue is full
    /// (returns `None`).
    pub fn try_submit_gemm(
        &self,
        m: usize,
        n: usize,
        k: usize,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> Option<Receiver<GemmResponse>> {
        let (reply, waiter) = ReplyTo::pair();
        let req = GemmRequest { id: self.id(), m, n, k, a, b, reply };
        match self.tx.try_send(Work::Gemm(req, Instant::now())) {
            Ok(()) => {
                self.metrics.on_submit();
                Some(waiter)
            }
            Err(_) => {
                self.metrics.on_shed();
                None
            }
        }
    }

    /// Submit `rows` MLP activations of width d_in.
    pub fn submit_mlp(&self, rows: usize, x: Vec<f32>) -> Receiver<MlpResponse> {
        let (reply, waiter) = ReplyTo::pair();
        let req = MlpRequest { id: self.id(), rows, x, reply };
        self.metrics.on_submit();
        if self.tx.send(Work::Mlp(req, Instant::now())).is_err() {
            self.metrics.on_fail();
        }
        waiter
    }
}

fn worker_loop(
    engine: EngineHandle,
    metrics: Arc<Metrics>,
    router: Router,
    rx: Receiver<Work>,
    mlp_tx: Sender<MlpRequest>,
    cancel: CancelToken,
) {
    while let Ok(work) = rx.recv() {
        if cancel.is_cancelled() {
            break;
        }
        match work {
            Work::Gemm(req, enqueued) => {
                let queue_s = enqueued.elapsed().as_secs_f64();
                handle_gemm(&engine, &metrics, &router, req, queue_s);
            }
            Work::Mlp(req, _enqueued) => {
                // Forward to the batching thread; it owns timing.
                if mlp_tx.send(req).is_err() {
                    metrics.on_fail();
                }
            }
            Work::Shutdown => break,
        }
    }
}

fn handle_gemm(
    engine: &EngineHandle,
    metrics: &Metrics,
    router: &Router,
    req: GemmRequest,
    queue_s: f64,
) {
    let GemmRequest { id, m, n, k, a, b, reply } = req;
    let routed = router.route_gemm(engine.manifest(), m, n, k);
    match routed {
        Ok(artifact) => {
            let sw = Stopwatch::start();
            match engine.run_f32(&artifact, vec![Arc::new(a), Arc::new(b)]) {
                Ok((mut outs, stats)) => {
                    let execute_s = sw.elapsed_secs();
                    metrics.on_complete(queue_s, execute_s, stats.flops);
                    reply.send(GemmResponse {
                        id,
                        result: Ok(outs.swap_remove(0)),
                        artifact,
                        queue_s,
                        execute_s,
                    });
                }
                Err(e) => {
                    metrics.on_fail();
                    reply.send(GemmResponse {
                        id,
                        result: Err(e.to_string()),
                        artifact,
                        queue_s,
                        execute_s: 0.0,
                    });
                }
            }
        }
        Err(e) => {
            metrics.on_fail();
            reply.send(GemmResponse {
                id,
                result: Err(e.to_string()),
                artifact: String::new(),
                queue_s,
                execute_s: 0.0,
            });
        }
    }
}

/// MLP weights are baked into the artifact? No — the MLP artifacts take
/// (x, w1, b1, w2, b2); the service holds one parameter set, uploaded at
/// start via [`MlpParams`]. Defaults to a deterministic pseudo-random
/// init so examples/benches run out of the box.
pub struct MlpParams {
    pub d_in: usize,
    pub d_hidden: usize,
    pub d_out: usize,
    pub w1: Arc<Vec<f32>>,
    pub b1: Arc<Vec<f32>>,
    pub w2: Arc<Vec<f32>>,
    pub b2: Arc<Vec<f32>>,
}

impl MlpParams {
    pub fn deterministic(d_in: usize, d_hidden: usize, d_out: usize) -> Self {
        let mut rng = crate::prop::Rng::new(0x5EED);
        let scale_1 = (2.0 / d_in as f64).sqrt() as f32;
        let scale_2 = (2.0 / d_hidden as f64).sqrt() as f32;
        Self {
            d_in,
            d_hidden,
            d_out,
            w1: Arc::new(
                rng.normal_f32_vec(d_in * d_hidden)
                    .iter()
                    .map(|v| v * scale_1)
                    .collect(),
            ),
            b1: Arc::new(vec![0.01; d_hidden]),
            w2: Arc::new(
                rng.normal_f32_vec(d_hidden * d_out)
                    .iter()
                    .map(|v| v * scale_2)
                    .collect(),
            ),
            b2: Arc::new(vec![0.01; d_out]),
        }
    }
}

static MLP_PARAMS: std::sync::OnceLock<MlpParams> = std::sync::OnceLock::new();

/// The MLP parameter set served by every coordinator in this process.
pub fn mlp_params() -> &'static MlpParams {
    MLP_PARAMS.get_or_init(|| MlpParams::deterministic(256, 512, 256))
}

fn mlp_batch_loop(
    engine: EngineHandle,
    metrics: Arc<Metrics>,
    router: Router,
    mut batcher: Batcher,
    rx: Receiver<MlpRequest>,
) {
    let params = mlp_params();
    while let Some(plan) = batcher.next_batch(&rx) {
        let sw = Stopwatch::start();
        metrics.on_batch(plan.total_rows);
        let routed = router.route_mlp(engine.manifest(), plan.total_rows);
        let (artifact, batch) = match routed {
            Ok(v) => v,
            Err(e) => {
                for req in plan.requests {
                    metrics.on_fail();
                    req.reply.send(MlpResponse {
                        id: req.id,
                        result: Err(e.to_string()),
                        batched_as: 0,
                        queue_s: 0.0,
                        execute_s: 0.0,
                    });
                }
                continue;
            }
        };
        let (x, offsets) = plan.pack(params.d_in, batch);
        let run = engine.run_f32(
            &artifact,
            vec![
                Arc::new(x),
                params.w1.clone(),
                params.b1.clone(),
                params.w2.clone(),
                params.b2.clone(),
            ],
        );
        let execute_s = sw.elapsed_secs();
        match run {
            Ok((outs, stats)) => {
                let split = plan.unpack(&outs[0], params.d_out, &offsets);
                for (req, y) in plan.requests.into_iter().zip(split) {
                    metrics.on_complete(0.0, execute_s, stats.flops);
                    req.reply.send(MlpResponse {
                        id: req.id,
                        result: Ok(y),
                        batched_as: batch,
                        queue_s: 0.0,
                        execute_s,
                    });
                }
            }
            Err(e) => {
                for req in plan.requests {
                    metrics.on_fail();
                    req.reply.send(MlpResponse {
                        id: req.id,
                        result: Err(e.to_string()),
                        batched_as: batch,
                        queue_s: 0.0,
                        execute_s,
                    });
                }
            }
        }
    }
}
