//! Shape→artifact routing.
//!
//! Stream-K's library-size claim lives here: with the work-centric
//! kernel, *one* configuration per precision serves every shape, so the
//! routing table is the artifact manifest itself — no kernel-selection
//! heuristics (the report's "complex kernel selection heuristics"
//! problem) beyond exact shape lookup + policy fallbacks.

use crate::runtime::Manifest;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteError {
    NoArtifact {
        m: usize,
        n: usize,
        k: usize,
        algo: String,
        pad: String,
        dtype: String,
    },
    BatchTooLarge { rows: usize, largest: usize },
}

impl std::fmt::Display for RouteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RouteError::NoArtifact { m, n, k, algo, pad, dtype } => write!(
                f,
                "no artifact for gemm {m}x{n}x{k} algo={algo} pad={pad} \
                 dtype={dtype}; add the shape to python/compile/aot.py and \
                 re-run `make artifacts`"
            ),
            RouteError::BatchTooLarge { rows, largest } => write!(
                f,
                "no MLP artifact with batch >= {rows} (largest is {largest})"
            ),
        }
    }
}

impl std::error::Error for RouteError {}

/// The routing policy: preferred algorithm + padding, with fallbacks.
#[derive(Debug, Clone)]
pub struct Router {
    pub algo: String,
    pub pad: String,
    pub dtype: String,
}

impl Router {
    pub fn new(algo: &str, pad: &str, dtype: &str) -> Self {
        Self { algo: algo.into(), pad: pad.into(), dtype: dtype.into() }
    }

    /// Route a GEMM shape to an artifact name.
    ///
    /// Fallback chain: exact (algo, pad) → other pad policy → the `ref`
    /// oracle artifact (always correct, never fast) → error.
    pub fn route_gemm(
        &self,
        manifest: &Manifest,
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<String, RouteError> {
        self.route_gemm_with(manifest, m, n, k, None)
    }

    /// Like [`Router::route_gemm`], but a tuner-cache hit can override
    /// the preferred padding policy: the tuned pad is tried first, then
    /// the normal fallback chain. A tuned preference never *removes*
    /// fallbacks — a cache entry for a shape whose tuned artifact was
    /// never compiled still routes somewhere correct.
    pub fn route_gemm_with(
        &self,
        manifest: &Manifest,
        m: usize,
        n: usize,
        k: usize,
        pad_override: Option<&str>,
    ) -> Result<String, RouteError> {
        self.route_gemm_chain(manifest, m, n, k, pad_override, None)
    }

    /// Fleet-aware routing: like [`Router::route_gemm_with`], but when
    /// several artifacts serve the same routing key the one compiled
    /// for the CU count nearest the placed device wins — a 60-CU
    /// device should not launch a 120-CU grid when a closer build
    /// exists. With one artifact per key this is exactly
    /// [`Router::route_gemm_with`].
    pub fn route_gemm_fleet(
        &self,
        manifest: &Manifest,
        m: usize,
        n: usize,
        k: usize,
        pad_override: Option<&str>,
        device_cus: usize,
    ) -> Result<String, RouteError> {
        self.route_gemm_chain(manifest, m, n, k, pad_override, Some(device_cus))
    }

    /// The one fallback chain both GEMM routes share: exact
    /// (algo, pad) → other pad policy → the `ref` oracle → error.
    /// `device_cus` switches the per-key lookup between first-match
    /// and nearest-CU selection.
    fn route_gemm_chain(
        &self,
        manifest: &Manifest,
        m: usize,
        n: usize,
        k: usize,
        pad_override: Option<&str>,
        device_cus: Option<usize>,
    ) -> Result<String, RouteError> {
        let preferred = pad_override.unwrap_or(self.pad.as_str());
        let other_pad = if preferred == "none" { "physical" } else { "none" };
        for (algo, pad) in [
            (self.algo.as_str(), preferred),
            (self.algo.as_str(), other_pad),
            ("ref", "none"),
        ] {
            let found = match device_cus {
                Some(cus) => manifest
                    .find_gemm_for_cus(m, n, k, algo, pad, &self.dtype, cus),
                None => manifest.find_gemm(m, n, k, algo, pad, &self.dtype),
            };
            if let Some(a) = found {
                return Ok(a.name.clone());
            }
        }
        Err(RouteError::NoArtifact {
            m,
            n,
            k,
            algo: self.algo.clone(),
            pad: self.pad.clone(),
            dtype: self.dtype.clone(),
        })
    }

    /// Route an MLP batch: the smallest compiled batch ≥ `rows`
    /// (requests are padded up to it by the batcher).
    pub fn route_mlp(
        &self,
        manifest: &Manifest,
        rows: usize,
    ) -> Result<(String, usize), RouteError> {
        let mut candidates: Vec<(usize, &str)> = manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == "mlp" && a.dtype == self.dtype)
            .map(|a| (a.batch, a.name.as_str()))
            .collect();
        candidates.sort();
        let largest = candidates.last().map(|&(b, _)| b).unwrap_or(0);
        candidates
            .into_iter()
            .find(|&(b, _)| b >= rows)
            .map(|(b, name)| (name.to_string(), b))
            .ok_or(RouteError::BatchTooLarge { rows, largest })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn manifest() -> Option<Manifest> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return None;
        }
        Some(Manifest::load(&dir).unwrap())
    }

    #[test]
    fn routes_table1_shapes() {
        let Some(m) = manifest() else { return };
        let r = Router::new("streamk", "none", "f32");
        let name = r.route_gemm(&m, 960, 1024, 1024).unwrap();
        assert_eq!(name, "gemm_streamk_nopad_f32_960x1024x1024");
        // padded policy routes to the padded artifact
        let r = Router::new("tile", "physical", "f32");
        let name = r.route_gemm(&m, 960, 1024, 1024).unwrap();
        assert_eq!(name, "gemm_tile_pad_f32_960x1024x1024");
    }

    #[test]
    fn falls_back_to_ref_then_errors() {
        let Some(m) = manifest() else { return };
        // 256x256x256 gelu exists only as streamk+ref; splitk falls back.
        let r = Router::new("splitk", "none", "bf16");
        let name = r.route_gemm(&m, 256, 256, 256).unwrap();
        assert_eq!(name, "gemm_ref_nopad_bf16_256x256x256");
        // a shape with no artifact at all errors with guidance
        let err = r.route_gemm(&m, 7, 7, 7).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn tuned_pad_override_flips_preference() {
        let Some(m) = manifest() else { return };
        let r = Router::new("streamk", "none", "f32");
        // tuner said "physical" for this bucket → the padded artifact wins
        let name = r
            .route_gemm_with(&m, 960, 1024, 1024, Some("physical"))
            .unwrap();
        assert_eq!(name, "gemm_streamk_pad_f32_960x1024x1024");
        // override matching the default changes nothing
        let name =
            r.route_gemm_with(&m, 960, 1024, 1024, Some("none")).unwrap();
        assert_eq!(name, "gemm_streamk_nopad_f32_960x1024x1024");
    }

    #[test]
    fn fleet_route_prefers_nearest_cus_build() {
        // Inline manifest with the same routing key at two CU counts.
        let dir = std::env::temp_dir().join(format!(
            "streamk-router-fleet-{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{
              "version": 2,
              "artifacts": [
                {"name": "gemm_streamk_nopad_f32_64x64x64_cu8",
                 "file": "a.hlo.txt", "experiment": "t", "kind": "gemm",
                 "flops": 524288,
                 "inputs": [{"shape": [64, 64], "dtype": "f32"},
                             {"shape": [64, 64], "dtype": "f32"}],
                 "outputs": [{"shape": [64, 64], "dtype": "f32"}],
                 "m": 64, "n": 64, "k": 64, "algo": "streamk",
                 "pad": "none", "dtype": "f32", "cus": 8},
                {"name": "gemm_streamk_nopad_f32_64x64x64_cu120",
                 "file": "b.hlo.txt", "experiment": "t", "kind": "gemm",
                 "flops": 524288,
                 "inputs": [{"shape": [64, 64], "dtype": "f32"},
                             {"shape": [64, 64], "dtype": "f32"}],
                 "outputs": [{"shape": [64, 64], "dtype": "f32"}],
                 "m": 64, "n": 64, "k": 64, "algo": "streamk",
                 "pad": "none", "dtype": "f32", "cus": 120}
              ]
            }"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let r = Router::new("streamk", "none", "f32");
        assert_eq!(
            r.route_gemm_fleet(&m, 64, 64, 64, None, 120).unwrap(),
            "gemm_streamk_nopad_f32_64x64x64_cu120"
        );
        assert_eq!(
            r.route_gemm_fleet(&m, 64, 64, 64, None, 16).unwrap(),
            "gemm_streamk_nopad_f32_64x64x64_cu8"
        );
        // single-artifact keys behave exactly like route_gemm_with
        assert!(r.route_gemm_fleet(&m, 7, 7, 7, None, 120).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn mlp_smallest_fitting_batch() {
        let Some(m) = manifest() else { return };
        let r = Router::new("streamk", "none", "f32");
        assert_eq!(r.route_mlp(&m, 1).unwrap().1, 8);
        assert_eq!(r.route_mlp(&m, 8).unwrap().1, 8);
        assert_eq!(r.route_mlp(&m, 9).unwrap().1, 32);
        assert_eq!(r.route_mlp(&m, 100).unwrap().1, 128);
        assert_eq!(
            r.route_mlp(&m, 1000),
            Err(RouteError::BatchTooLarge { rows: 1000, largest: 128 })
        );
    }
}
