//! Serving metrics: counters, latency histograms, throughput.
//!
//! The report's future-work item on "integrating automated benchmarking
//! tools … integrated and continuous performance monitoring" — these are
//! the hooks. Snapshots serialize to JSON for the bench harness and the
//! `streamk serve --metrics-out` flag.

use crate::json::{obj, Value};
use std::sync::Mutex;

/// Log₂-bucketed latency histogram (µs buckets from 1µs to ~17min).
#[derive(Debug, Clone, PartialEq)]
pub struct Histogram {
    buckets: Vec<u64>, // bucket i: [2^i, 2^{i+1}) µs
    count: u64,
    sum_us: f64,
    max_us: f64,
}

const BUCKETS: usize = 30;

impl Default for Histogram {
    fn default() -> Self {
        Self { buckets: vec![0; BUCKETS], count: 0, sum_us: 0.0, max_us: 0.0 }
    }
}

impl Histogram {
    pub fn record_secs(&mut self, secs: f64) {
        let us = (secs * 1e6).max(0.0);
        let idx = (us.max(1.0).log2() as usize).min(BUCKETS - 1);
        self.buckets[idx] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us / self.count as f64
        }
    }

    /// Approximate quantile, interpolated linearly within the winning
    /// log₂ bucket (assumes a uniform in-bucket distribution). The old
    /// bucket-upper-bound answer overstated p50 by up to 2× — on
    /// uniform 1..=1000µs samples it returned 512 for a true p50 of
    /// 500; interpolation lands within a few percent.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64)
            .ceil()
            .max(1.0) as u64;
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                let lo = (1u64 << i) as f64;
                let hi = (1u64 << (i + 1)) as f64;
                let frac = (target - seen) as f64 / c as f64;
                return lo + frac * (hi - lo);
            }
            seen += c;
        }
        self.max_us
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("count", (self.count as usize).into()),
            ("mean_us", self.mean_us().into()),
            ("p50_us", self.quantile_us(0.5).into()),
            ("p95_us", self.quantile_us(0.95).into()),
            ("p99_us", self.quantile_us(0.99).into()),
            ("max_us", self.max_us.into()),
        ])
    }
}

/// Shared coordinator metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
    /// Block2Time prediction residuals per shape bucket — separate lock
    /// so residual recording never contends with counter updates.
    residuals: Mutex<crate::trace::ResidualTracker>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    completed: u64,
    failed: u64,
    shed: u64,
    batches: u64,
    batched_rows: u64,
    tuner_hits: u64,
    tuner_misses: u64,
    /// Fleet placements per device index (grown on demand).
    placements: Vec<u64>,
    /// Placements that took the least-loaded fallback (no prediction).
    placement_fallbacks: u64,
    /// Entries whose measured latency drifted past the staleness
    /// policy and were sent back for re-tuning.
    drift_revalidations: u64,
    queue: Histogram,
    execute: Histogram,
    e2e: Histogram,
    tune: Histogram,
    flops: f64,
    started: Option<std::time::Instant>,
}

/// Point-in-time copy for reporting.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub completed: u64,
    pub failed: u64,
    pub shed: u64,
    pub batches: u64,
    pub mean_batch_rows: f64,
    /// Process-wide plan-cache counters (hits/misses/builds/build time)
    /// at snapshot time — the zero-rebuild hot path's effectiveness.
    /// Shared by the fleet scheduler, the tuner and the interpreter
    /// runtime, so this reflects every schedule the process priced or
    /// executed.
    pub plan: crate::plan::PlanCacheStats,
    /// Tuner-cache effectiveness on the GEMM request path.
    pub tuner_hits: u64,
    pub tuner_misses: u64,
    /// Completed background tunes (count + duration distribution).
    pub tunes: u64,
    /// Fleet placements per device index (empty until the first
    /// placement lands).
    pub placements: Vec<u64>,
    pub placement_fallbacks: u64,
    pub drift_revalidations: u64,
    pub queue: Histogram,
    pub execute: Histogram,
    pub e2e: Histogram,
    pub tune: Histogram,
    /// Block2Time residuals (predicted vs. measured latency) per shape
    /// bucket — empty until the first placement carries a prediction.
    pub residuals: Vec<crate::trace::ResidualSnapshot>,
    pub elapsed_s: f64,
    pub throughput_rps: f64,
    pub tflops: f64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn on_submit(&self) {
        let mut m = self.inner.lock().expect("metrics");
        m.requests += 1;
        m.started.get_or_insert_with(std::time::Instant::now);
    }

    pub fn on_shed(&self) {
        self.inner.lock().expect("metrics").shed += 1;
    }

    pub fn on_complete(&self, queue_s: f64, execute_s: f64, flops: u64) {
        let mut m = self.inner.lock().expect("metrics");
        m.completed += 1;
        m.queue.record_secs(queue_s);
        m.execute.record_secs(execute_s);
        m.e2e.record_secs(queue_s + execute_s);
        m.flops += flops as f64;
    }

    pub fn on_fail(&self) {
        self.inner.lock().expect("metrics").failed += 1;
    }

    pub fn on_batch(&self, rows: usize) {
        let mut m = self.inner.lock().expect("metrics");
        m.batches += 1;
        m.batched_rows += rows as u64;
    }

    pub fn on_tuner_hit(&self) {
        self.inner.lock().expect("metrics").tuner_hits += 1;
    }

    pub fn on_tuner_miss(&self) {
        self.inner.lock().expect("metrics").tuner_misses += 1;
    }

    /// A request was placed on fleet device `device`.
    pub fn on_place(&self, device: usize, fallback: bool) {
        let mut m = self.inner.lock().expect("metrics");
        if m.placements.len() <= device {
            m.placements.resize(device + 1, 0);
        }
        m.placements[device] += 1;
        if fallback {
            m.placement_fallbacks += 1;
        }
    }

    /// A cache entry drifted past the staleness policy and was sent
    /// back for background re-tuning.
    pub fn on_drift_revalidate(&self) {
        self.inner.lock().expect("metrics").drift_revalidations += 1;
    }

    /// A background tune finished in `secs`.
    pub fn on_tune(&self, secs: f64) {
        self.inner.lock().expect("metrics").tune.record_secs(secs);
    }

    /// Pair a Block2Time prediction with the measured execute latency.
    /// No-op when the placement carried no prediction (fallback path).
    /// Returns the absolute percentage error when recorded.
    pub fn on_residual(
        &self,
        bucket: &str,
        predicted_s: Option<f64>,
        measured_s: f64,
    ) -> Option<f64> {
        let predicted_s = predicted_s?;
        self.residuals
            .lock()
            .expect("metrics residuals")
            .observe(bucket, predicted_s, measured_s)
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.inner.lock().expect("metrics");
        let elapsed_s = m
            .started
            .map(|t| t.elapsed().as_secs_f64())
            .unwrap_or(0.0);
        MetricsSnapshot {
            requests: m.requests,
            completed: m.completed,
            failed: m.failed,
            shed: m.shed,
            batches: m.batches,
            mean_batch_rows: if m.batches == 0 {
                0.0
            } else {
                m.batched_rows as f64 / m.batches as f64
            },
            plan: crate::plan::global().stats(),
            tuner_hits: m.tuner_hits,
            tuner_misses: m.tuner_misses,
            tunes: m.tune.count(),
            placements: m.placements.clone(),
            placement_fallbacks: m.placement_fallbacks,
            drift_revalidations: m.drift_revalidations,
            queue: m.queue.clone(),
            execute: m.execute.clone(),
            e2e: m.e2e.clone(),
            tune: m.tune.clone(),
            residuals: self
                .residuals
                .lock()
                .expect("metrics residuals")
                .snapshot(),
            elapsed_s,
            throughput_rps: if elapsed_s > 0.0 {
                m.completed as f64 / elapsed_s
            } else {
                0.0
            },
            tflops: if elapsed_s > 0.0 {
                m.flops / elapsed_s / 1e12
            } else {
                0.0
            },
        }
    }
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> Value {
        obj(vec![
            ("requests", (self.requests as usize).into()),
            ("completed", (self.completed as usize).into()),
            ("failed", (self.failed as usize).into()),
            ("shed", (self.shed as usize).into()),
            ("batches", (self.batches as usize).into()),
            ("mean_batch_rows", self.mean_batch_rows.into()),
            ("tuner_hits", (self.tuner_hits as usize).into()),
            ("tuner_misses", (self.tuner_misses as usize).into()),
            ("tunes", (self.tunes as usize).into()),
            (
                "placements",
                Value::Arr(
                    self.placements
                        .iter()
                        .map(|&c| (c as usize).into())
                        .collect(),
                ),
            ),
            (
                "placement_fallbacks",
                (self.placement_fallbacks as usize).into(),
            ),
            (
                "drift_revalidations",
                (self.drift_revalidations as usize).into(),
            ),
            ("plan", self.plan.to_json()),
            ("elapsed_s", self.elapsed_s.into()),
            ("throughput_rps", self.throughput_rps.into()),
            ("tflops", self.tflops.into()),
            ("queue", self.queue.to_json()),
            ("execute", self.execute.to_json()),
            ("e2e", self.e2e.to_json()),
            ("tune", self.tune.to_json()),
            (
                "residuals",
                Value::Arr(
                    self.residuals.iter().map(|r| r.to_json()).collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_are_monotone() {
        let mut h = Histogram::default();
        for i in 1..=1000 {
            h.record_secs(i as f64 * 1e-6);
        }
        assert_eq!(h.count(), 1000);
        let p50 = h.quantile_us(0.5);
        let p95 = h.quantile_us(0.95);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p95 && p95 <= p99, "{p50} {p95} {p99}");
        assert!((h.mean_us() - 500.5).abs() < 1.0);
    }

    #[test]
    fn quantiles_interpolate_within_buckets() {
        // 100 identical 3µs samples land in bucket [2,4): every
        // quantile interpolates inside that bucket instead of snapping
        // to the upper bound 4.
        let mut h = Histogram::default();
        for _ in 0..100 {
            h.record_secs(3e-6);
        }
        let p50 = h.quantile_us(0.5);
        assert!((p50 - 3.0).abs() < 1e-9, "p50 {p50}");
        assert!(h.quantile_us(0.95) < 4.0);
        // uniform 1..=1000µs: exact p50 = 500, p90 = 900; the old
        // upper-bound answer was 512 / 1024
        let mut u = Histogram::default();
        for i in 1..=1000 {
            u.record_secs(i as f64 * 1e-6);
        }
        let p50 = u.quantile_us(0.5);
        let p90 = u.quantile_us(0.9);
        assert!((p50 - 500.0).abs() / 500.0 < 0.05, "p50 {p50}");
        assert!((p90 - 900.0).abs() / 900.0 < 0.10, "p90 {p90}");
        // extremes stay sane
        assert!(u.quantile_us(0.0) >= 1.0);
        assert!(u.quantile_us(1.0) <= 1024.0);
    }

    #[test]
    fn empty_histogram() {
        let h = Histogram::default();
        assert_eq!(h.quantile_us(0.99), 0.0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn lifecycle_counting() {
        let m = Metrics::new();
        for _ in 0..10 {
            m.on_submit();
        }
        for _ in 0..8 {
            m.on_complete(1e-4, 2e-4, 1000);
        }
        m.on_fail();
        m.on_shed();
        m.on_batch(4);
        m.on_batch(8);
        let s = m.snapshot();
        assert_eq!(s.requests, 10);
        assert_eq!(s.completed, 8);
        assert_eq!(s.failed, 1);
        assert_eq!(s.shed, 1);
        assert!((s.mean_batch_rows - 6.0).abs() < 1e-12);
        assert_eq!(s.e2e.count(), 8);
        // json serializes without panicking and with the right keys
        let j = s.to_json();
        assert_eq!(j.u("completed").unwrap(), 8);
        assert!(j.get("e2e").unwrap().get("p95_us").is_some());
        // plan-cache counters are surfaced (values are process-global,
        // so only their presence is asserted here)
        assert!(j.get("plan").unwrap().get("hit_rate").is_some());
        assert!(j.get("plan").unwrap().get("builds").is_some());
    }

    #[test]
    fn fleet_placement_counters() {
        let m = Metrics::new();
        m.on_place(2, false); // device index seen first grows the vec
        m.on_place(0, false);
        m.on_place(2, true);
        m.on_drift_revalidate();
        let s = m.snapshot();
        assert_eq!(s.placements, vec![1, 0, 2]);
        assert_eq!(s.placement_fallbacks, 1);
        assert_eq!(s.drift_revalidations, 1);
        let j = s.to_json();
        let arr = j.get("placements").unwrap();
        assert_eq!(arr.as_arr().unwrap().len(), 3);
        assert_eq!(j.u("placement_fallbacks").unwrap(), 1);
        assert_eq!(j.u("drift_revalidations").unwrap(), 1);
    }

    #[test]
    fn residual_accounting_surfaces_in_snapshot_json() {
        let m = Metrics::new();
        // fallback placements carry no prediction: dropped
        assert!(m.on_residual("128x128x128", None, 1e-3).is_none());
        assert!(m.snapshot().residuals.is_empty());
        for _ in 0..20 {
            let ape = m.on_residual("128x128x128", Some(1.2e-3), 1e-3);
            assert!((ape.unwrap() - 0.2).abs() < 1e-12);
        }
        m.on_residual("256x256x256", Some(0.9e-3), 1e-3);
        let s = m.snapshot();
        assert_eq!(s.residuals.len(), 2);
        let r = &s.residuals[0];
        assert_eq!(r.bucket, "128x128x128");
        assert_eq!(r.count, 20);
        assert!(r.ewma_bias > 0.19 && r.ewma_bias < 0.21);
        assert!(r.p95_ape.is_finite() && r.p95_ape > 0.0);
        let j = s.to_json();
        let arr = j.arr("residuals").unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].s("bucket").unwrap(), "128x128x128");
        assert!(arr[0].f("ewma_bias").unwrap() > 0.0);
        assert!(arr[1].f("ewma_bias").unwrap() < 0.0);
        assert!(arr[0].f("p95_ape").unwrap().is_finite());
    }

    #[test]
    fn tuner_counters() {
        let m = Metrics::new();
        m.on_tuner_hit();
        m.on_tuner_hit();
        m.on_tuner_miss();
        m.on_tune(0.05);
        let s = m.snapshot();
        assert_eq!(s.tuner_hits, 2);
        assert_eq!(s.tuner_misses, 1);
        assert_eq!(s.tunes, 1);
        assert_eq!(s.tune.count(), 1);
        assert!(s.tune.mean_us() > 0.0);
        let j = s.to_json();
        assert_eq!(j.u("tuner_hits").unwrap(), 2);
        assert_eq!(j.u("tuner_misses").unwrap(), 1);
        assert_eq!(j.u("tunes").unwrap(), 1);
        assert!(j.get("tune").unwrap().get("p95_us").is_some());
    }
}
