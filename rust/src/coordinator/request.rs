//! Request/response types flowing through the coordinator.

use crate::exec::{bounded, Receiver, Sender};

/// Reply channel: a one-shot built on the bounded channel.
pub struct ReplyTo<T> {
    tx: Sender<T>,
}

impl<T> ReplyTo<T> {
    /// Create the (reply-sender, waiter) pair for one request.
    pub fn pair() -> (Self, Receiver<T>) {
        let (tx, rx) = bounded(1);
        (Self { tx }, rx)
    }

    pub fn send(self, value: T) {
        // A dropped waiter is not an error (client gave up).
        let _ = self.tx.send(value);
    }
}

/// One GEMM to execute: C = A·B on the routed artifact.
pub struct GemmRequest {
    pub id: u64,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a: Vec<f32>,
    pub b: Vec<f32>,
    pub reply: ReplyTo<GemmResponse>,
}

#[derive(Debug)]
pub struct GemmResponse {
    pub id: u64,
    pub result: Result<Vec<f32>, String>,
    /// Which artifact served it (observability: the router's decision).
    pub artifact: String,
    /// Fleet device the scheduler placed it on — the serving tier
    /// forwards this on the wire so clients can attribute observed
    /// latency to the right device's tuner cache.
    pub device: usize,
    pub queue_s: f64,
    pub execute_s: f64,
}

/// One MLP inference request: `rows` activations of width `d_in`.
pub struct MlpRequest {
    pub id: u64,
    pub rows: usize,
    pub x: Vec<f32>,
    pub reply: ReplyTo<MlpResponse>,
}

#[derive(Debug)]
pub struct MlpResponse {
    pub id: u64,
    pub result: Result<Vec<f32>, String>,
    /// Batch the request was folded into (batcher observability).
    pub batched_as: usize,
    pub queue_s: f64,
    pub execute_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reply_roundtrip() {
        let (reply, rx) = ReplyTo::pair();
        reply.send(42u32);
        assert_eq!(rx.recv().unwrap(), 42);
    }

    #[test]
    fn dropped_waiter_is_fine() {
        let (reply, rx) = ReplyTo::pair();
        drop(rx);
        reply.send(1u32); // must not panic
    }
}
