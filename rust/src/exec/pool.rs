//! Fixed-size worker pool. Jobs are `FnOnce` closures; shutdown is
//! graceful (drains the queue) and happens on drop.

use super::channel::{bounded, Sender};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `threads` workers sharing a queue of `queue_cap` pending jobs
    /// (senders block beyond that — built-in backpressure).
    pub fn new(threads: usize, queue_cap: usize) -> Self {
        assert!(threads > 0, "thread pool needs at least one worker");
        let (tx, rx) = bounded::<Job>(queue_cap.max(1));
        let workers = (0..threads)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("streamk-worker-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Submit a job; blocks when the queue is full. Returns `false` if the
    /// pool is already shut down.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) -> bool {
        match &self.tx {
            Some(tx) => tx.send(Box::new(job)).is_ok(),
            None => false,
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.tx.as_ref().map_or(0, |tx| tx.len())
    }

    /// Drain the queue and join all workers.
    pub fn shutdown(&mut self) {
        self.tx.take(); // closes the channel; workers exit after draining
        for w in self.workers.drain(..) {
            w.join().expect("worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Scatter `jobs` over a temporary pool of `threads` workers and gather
/// the results in job order — the one scatter/gather loop the offline
/// sweeps share (fleet cache warm-up, `tune --suite`, parallel plan
/// construction). `threads` is clamped to the job count.
pub fn pool_map<T, R, F>(threads: usize, jobs: Vec<T>, f: F) -> Vec<R>
where
    T: Send + 'static,
    R: Send + 'static,
    F: Fn(T) -> R + Send + Sync + 'static,
{
    if jobs.is_empty() {
        return Vec::new();
    }
    let n = jobs.len();
    let threads = threads.clamp(1, n);
    let pool = ThreadPool::new(threads, n);
    let (tx, rx) = super::channel::bounded(n);
    let f = std::sync::Arc::new(f);
    for (i, job) in jobs.into_iter().enumerate() {
        let f = f.clone();
        let tx = tx.clone();
        pool.submit(move || {
            let _ = tx.send((i, f(job)));
        });
    }
    drop(tx);
    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    while let Ok((i, r)) = rx.recv() {
        out[i] = Some(r);
    }
    out.into_iter()
        .map(|slot| slot.expect("every pool job reports"))
        .collect()
}

/// Scatter borrowed `items` over `threads` *scoped* workers, gathering
/// results in item order — the borrowing counterpart of [`pool_map`]
/// for hot paths that must not copy their inputs (the blocked kernel
/// executor fans GEMM work items out over slices of A and B). Each
/// worker gets its own `init()` state (reusable scratch buffers);
/// scheduling is dynamic (atomic work index), so uneven item costs
/// balance. With `threads <= 1` everything runs inline on the caller.
pub fn scope_map_with<T, S, R, FI, F>(
    threads: usize,
    items: &[T],
    init: FI,
    f: F,
) -> Vec<R>
where
    T: Sync,
    R: Send,
    FI: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let threads = threads.clamp(1, n);
    if threads == 1 {
        let mut state = init();
        return items
            .iter()
            .enumerate()
            .map(|(i, t)| f(&mut state, i, t))
            .collect();
    }
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|scope| {
        // Each worker owns its (index, result) list — no shared lock on
        // the completion path; the merge happens once at join time.
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(|| {
                    let mut state = init();
                    let mut got = Vec::new();
                    loop {
                        let i = next.fetch_add(
                            1,
                            std::sync::atomic::Ordering::Relaxed,
                        );
                        if i >= n {
                            break;
                        }
                        got.push((i, f(&mut state, i, &items[i])));
                    }
                    got
                })
            })
            .collect();
        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for h in handles {
            for (i, r) in h.join().expect("scope_map_with worker panicked") {
                out[i] = Some(r);
            }
        }
        out.into_iter()
            .map(|slot| slot.expect("worker filled every slot"))
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4, 16);
            for _ in 0..100 {
                let c = counter.clone();
                assert!(pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }));
            }
            // drop -> shutdown -> drain
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let mut pool = ThreadPool::new(1, 4);
        pool.shutdown();
        assert!(!pool.submit(|| {}));
    }

    #[test]
    fn pool_map_preserves_order_and_runs_everything() {
        let out = pool_map(4, (0..50).collect(), |x: i32| x * 3);
        assert_eq!(out, (0..50).map(|x| x * 3).collect::<Vec<_>>());
        let empty: Vec<i32> = pool_map(4, Vec::new(), |x: i32| x);
        assert!(empty.is_empty());
        // more threads than jobs is fine (clamped)
        assert_eq!(pool_map(16, vec![7], |x: i32| x + 1), vec![8]);
    }

    #[test]
    fn scope_map_with_preserves_order_and_reuses_state() {
        // Borrowed inputs (the whole point), per-worker scratch, dynamic
        // scheduling — results must come back in item order.
        let items: Vec<i64> = (0..200).collect();
        let inits = Arc::new(AtomicUsize::new(0));
        let inits2 = inits.clone();
        let out = scope_map_with(
            4,
            &items,
            move || {
                inits2.fetch_add(1, Ordering::SeqCst);
                Vec::<i64>::new() // per-worker scratch
            },
            |scratch, i, &x| {
                scratch.push(x); // scratch persists across a worker's items
                x * 2 + i as i64
            },
        );
        assert_eq!(
            out,
            (0..200).map(|x| x * 3).collect::<Vec<_>>(),
            "f(x) = 2x + i with x == i"
        );
        assert!(inits.load(Ordering::SeqCst) <= 4, "one init per worker");

        let empty: Vec<i32> = scope_map_with(4, &[] as &[i32], || (), |_, _, &x| x);
        assert!(empty.is_empty());
        // serial path: exactly one init
        let before = inits.load(Ordering::SeqCst);
        let inits3 = inits.clone();
        let one = scope_map_with(
            1,
            &items[..5],
            move || {
                inits3.fetch_add(1, Ordering::SeqCst);
            },
            |_, _, &x| x,
        );
        assert_eq!(one, items[..5].to_vec());
        assert_eq!(inits.load(Ordering::SeqCst), before + 1);
    }

    #[test]
    fn single_worker_is_sequential() {
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        {
            let pool = ThreadPool::new(1, 32);
            for i in 0..20 {
                let order = order.clone();
                pool.submit(move || order.lock().unwrap().push(i));
            }
        }
        assert_eq!(*order.lock().unwrap(), (0..20).collect::<Vec<_>>());
    }
}
