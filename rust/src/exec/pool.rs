//! Fixed-size worker pool. Jobs are `FnOnce` closures; shutdown is
//! graceful (drains the queue) and happens on drop.

use super::channel::{bounded, Sender};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

pub struct ThreadPool {
    tx: Option<Sender<Job>>,
    workers: Vec<JoinHandle<()>>,
}

impl ThreadPool {
    /// Spawn `threads` workers sharing a queue of `queue_cap` pending jobs
    /// (senders block beyond that — built-in backpressure).
    pub fn new(threads: usize, queue_cap: usize) -> Self {
        assert!(threads > 0, "thread pool needs at least one worker");
        let (tx, rx) = bounded::<Job>(queue_cap.max(1));
        let workers = (0..threads)
            .map(|i| {
                let rx = rx.clone();
                std::thread::Builder::new()
                    .name(format!("streamk-worker-{i}"))
                    .spawn(move || {
                        while let Ok(job) = rx.recv() {
                            job();
                        }
                    })
                    .expect("spawn worker")
            })
            .collect();
        Self { tx: Some(tx), workers }
    }

    /// Submit a job; blocks when the queue is full. Returns `false` if the
    /// pool is already shut down.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, job: F) -> bool {
        match &self.tx {
            Some(tx) => tx.send(Box::new(job)).is_ok(),
            None => false,
        }
    }

    pub fn queue_depth(&self) -> usize {
        self.tx.as_ref().map_or(0, |tx| tx.len())
    }

    /// Drain the queue and join all workers.
    pub fn shutdown(&mut self) {
        self.tx.take(); // closes the channel; workers exit after draining
        for w in self.workers.drain(..) {
            w.join().expect("worker panicked");
        }
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn runs_all_jobs() {
        let counter = Arc::new(AtomicUsize::new(0));
        {
            let pool = ThreadPool::new(4, 16);
            for _ in 0..100 {
                let c = counter.clone();
                assert!(pool.submit(move || {
                    c.fetch_add(1, Ordering::SeqCst);
                }));
            }
            // drop -> shutdown -> drain
        }
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn submit_after_shutdown_fails() {
        let mut pool = ThreadPool::new(1, 4);
        pool.shutdown();
        assert!(!pool.submit(|| {}));
    }

    #[test]
    fn single_worker_is_sequential() {
        let order = Arc::new(std::sync::Mutex::new(Vec::new()));
        {
            let pool = ThreadPool::new(1, 32);
            for i in 0..20 {
                let order = order.clone();
                pool.submit(move || order.lock().unwrap().push(i));
            }
        }
        assert_eq!(*order.lock().unwrap(), (0..20).collect::<Vec<_>>());
    }
}
