//! Minimal execution runtime (tokio substitute — DESIGN.md §2).
//!
//! The coordinator's event loop needs: a worker pool, bounded channels
//! with backpressure, cancellation, and monotonic timing. All of it is
//! built on `std::thread` + `std::sync` so the request path has no
//! external-runtime dependency.

mod channel;
mod pool;

pub use channel::{
    bounded, Receiver, RecvError, RecvTimeoutError, SendError, Sender, TryRecvError,
};
pub use pool::{pool_map, scope_map_with, ThreadPool};

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Cooperative cancellation token shared between the coordinator and its
/// workers.
#[derive(Clone, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

/// Monotonic stopwatch used by the metrics and bench layers.
#[derive(Debug, Clone, Copy)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }
}

/// Run `f` over `items` on `threads` scoped workers, preserving order.
/// Panics in workers propagate. Used by benches and the simulator sweep.
pub fn parallel_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    assert!(threads > 0, "parallel_map needs at least one thread");
    let n = items.len();
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let work: Vec<(usize, T)> = items.into_iter().enumerate().collect();
    let queue = std::sync::Mutex::new(work);
    let out = std::sync::Mutex::new(&mut results);

    std::thread::scope(|scope| {
        for _ in 0..threads.min(n.max(1)) {
            scope.spawn(|| loop {
                let item = queue.lock().expect("queue poisoned").pop();
                match item {
                    Some((idx, item)) => {
                        let r = f(item);
                        out.lock().expect("out poisoned")[idx] = Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    results
        .into_iter()
        .map(|r| r.expect("worker filled every slot"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cancel_token_propagates() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t.is_cancelled());
        t2.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn parallel_map_preserves_order() {
        let out = parallel_map((0..100).collect(), 4, |x: i32| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_empty() {
        let out: Vec<i32> = parallel_map(Vec::<i32>::new(), 3, |x| x);
        assert!(out.is_empty());
    }
}
