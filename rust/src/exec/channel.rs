//! Bounded MPMC channel with blocking send (backpressure) built on
//! `Mutex` + `Condvar`. This is the coordinator's request queue: when the
//! queue is full, producers block — the paper's serving analogue of
//! admission control.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};

struct Shared<T> {
    queue: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    capacity: usize,
}

struct State<T> {
    items: VecDeque<T>,
    senders: usize,
    receivers: usize,
}

pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

#[derive(Debug, PartialEq, Eq)]
pub enum RecvError {
    Disconnected,
}

#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    Empty,
    Disconnected,
}

#[derive(Debug, PartialEq, Eq)]
pub enum RecvTimeoutError {
    Timeout,
    Disconnected,
}

/// Create a bounded channel with the given capacity (> 0).
pub fn bounded<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    assert!(capacity > 0, "channel capacity must be positive");
    let shared = Arc::new(Shared {
        queue: Mutex::new(State {
            items: VecDeque::with_capacity(capacity),
            senders: 1,
            receivers: 1,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        capacity,
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Sender<T> {
    /// Blocking send; returns the value if all receivers are gone.
    pub fn send(&self, value: T) -> Result<(), SendError<T>> {
        let mut state = self.shared.queue.lock().expect("channel poisoned");
        loop {
            if state.receivers == 0 {
                return Err(SendError(value));
            }
            if state.items.len() < self.shared.capacity {
                state.items.push_back(value);
                self.shared.not_empty.notify_one();
                return Ok(());
            }
            state = self
                .shared
                .not_full
                .wait(state)
                .expect("channel poisoned");
        }
    }

    /// Non-blocking send; returns the value when the queue is full — the
    /// coordinator uses this to shed load instead of blocking.
    pub fn try_send(&self, value: T) -> Result<(), SendError<T>> {
        let mut state = self.shared.queue.lock().expect("channel poisoned");
        if state.receivers == 0 || state.items.len() >= self.shared.capacity {
            return Err(SendError(value));
        }
        state.items.push_back(value);
        self.shared.not_empty.notify_one();
        Ok(())
    }

    pub fn len(&self) -> usize {
        self.shared.queue.lock().expect("channel poisoned").items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        self.shared.queue.lock().expect("channel poisoned").senders += 1;
        Sender { shared: self.shared.clone() }
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        let mut state = self.shared.queue.lock().expect("channel poisoned");
        state.senders -= 1;
        if state.senders == 0 {
            self.shared.not_empty.notify_all();
        }
    }
}

impl<T> Receiver<T> {
    /// Blocking receive; `Disconnected` once all senders are gone AND the
    /// queue has drained.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut state = self.shared.queue.lock().expect("channel poisoned");
        loop {
            if let Some(item) = state.items.pop_front() {
                self.shared.not_full.notify_one();
                return Ok(item);
            }
            if state.senders == 0 {
                return Err(RecvError::Disconnected);
            }
            state = self
                .shared
                .not_empty
                .wait(state)
                .expect("channel poisoned");
        }
    }

    /// Blocking receive with a deadline — the network serving tier's
    /// per-request deadline primitive. `Timeout` when nothing arrived
    /// within `dur`; `Disconnected` mirrors [`Receiver::recv`].
    pub fn recv_timeout(
        &self,
        dur: std::time::Duration,
    ) -> Result<T, RecvTimeoutError> {
        let deadline = std::time::Instant::now() + dur;
        let mut state = self.shared.queue.lock().expect("channel poisoned");
        loop {
            if let Some(item) = state.items.pop_front() {
                self.shared.not_full.notify_one();
                return Ok(item);
            }
            if state.senders == 0 {
                return Err(RecvTimeoutError::Disconnected);
            }
            let now = std::time::Instant::now();
            if now >= deadline {
                return Err(RecvTimeoutError::Timeout);
            }
            let (guard, res) = self
                .shared
                .not_empty
                .wait_timeout(state, deadline - now)
                .expect("channel poisoned");
            state = guard;
            if res.timed_out() && state.items.is_empty() {
                if state.senders == 0 {
                    return Err(RecvTimeoutError::Disconnected);
                }
                return Err(RecvTimeoutError::Timeout);
            }
        }
    }

    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let mut state = self.shared.queue.lock().expect("channel poisoned");
        if let Some(item) = state.items.pop_front() {
            self.shared.not_full.notify_one();
            return Ok(item);
        }
        if state.senders == 0 {
            Err(TryRecvError::Disconnected)
        } else {
            Err(TryRecvError::Empty)
        }
    }

    /// Drain up to `max` queued items without blocking — the dynamic
    /// batcher's collection primitive.
    pub fn drain_up_to(&self, max: usize) -> Vec<T> {
        let mut state = self.shared.queue.lock().expect("channel poisoned");
        let take = state.items.len().min(max);
        let out: Vec<T> = state.items.drain(..take).collect();
        if !out.is_empty() {
            self.shared.not_full.notify_all();
        }
        out
    }

    pub fn len(&self) -> usize {
        self.shared.queue.lock().expect("channel poisoned").items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        self.shared.queue.lock().expect("channel poisoned").receivers += 1;
        Receiver { shared: self.shared.clone() }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        let mut state = self.shared.queue.lock().expect("channel poisoned");
        state.receivers -= 1;
        if state.receivers == 0 {
            self.shared.not_full.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;
    use std::time::Duration;

    #[test]
    fn fifo_order() {
        let (tx, rx) = bounded(8);
        for i in 0..5 {
            tx.send(i).unwrap();
        }
        assert_eq!((0..5).map(|_| rx.recv().unwrap()).collect::<Vec<_>>(),
                   vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn backpressure_blocks_until_drained() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        assert_eq!(tx.try_send(3), Err(SendError(3)));
        let handle = thread::spawn(move || tx.send(3));
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1);
        handle.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 3);
    }

    #[test]
    fn recv_timeout_times_out_then_delivers() {
        let (tx, rx) = bounded::<i32>(2);
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(10)),
            Err(RecvTimeoutError::Timeout)
        );
        let handle = thread::spawn(move || {
            thread::sleep(Duration::from_millis(20));
            tx.send(9).unwrap();
        });
        assert_eq!(rx.recv_timeout(Duration::from_secs(10)), Ok(9));
        handle.join().unwrap();
        // all senders gone -> Disconnected, not Timeout
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(5)),
            Err(RecvTimeoutError::Disconnected)
        );
    }

    #[test]
    fn disconnect_semantics() {
        let (tx, rx) = bounded::<i32>(4);
        tx.send(7).unwrap();
        drop(tx);
        assert_eq!(rx.recv().unwrap(), 7);
        assert_eq!(rx.recv(), Err(RecvError::Disconnected));

        let (tx, rx) = bounded::<i32>(4);
        drop(rx);
        assert_eq!(tx.send(1), Err(SendError(1)));
    }

    #[test]
    fn drain_up_to_takes_at_most_max() {
        let (tx, rx) = bounded(16);
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        assert_eq!(rx.drain_up_to(4), vec![0, 1, 2, 3]);
        assert_eq!(rx.len(), 6);
        assert_eq!(rx.drain_up_to(100).len(), 6);
        assert!(rx.drain_up_to(3).is_empty());
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = bounded(4);
        let mut senders = Vec::new();
        for s in 0..4 {
            let tx = tx.clone();
            senders.push(thread::spawn(move || {
                for i in 0..50 {
                    tx.send(s * 1000 + i).unwrap();
                }
            }));
        }
        drop(tx);
        let mut receivers = Vec::new();
        for _ in 0..3 {
            let rx = rx.clone();
            receivers.push(thread::spawn(move || {
                let mut got = Vec::new();
                while let Ok(v) = rx.recv() {
                    got.push(v);
                }
                got
            }));
        }
        drop(rx);
        for s in senders {
            s.join().unwrap();
        }
        let mut all: Vec<i32> = receivers
            .into_iter()
            .flat_map(|r| r.join().unwrap())
            .collect();
        all.sort();
        let mut want: Vec<i32> =
            (0..4).flat_map(|s| (0..50).map(move |i| s * 1000 + i)).collect();
        want.sort();
        assert_eq!(all, want);
    }
}
