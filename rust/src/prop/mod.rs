//! Tiny property-testing harness (proptest substitute — DESIGN.md §2).
//!
//! Deterministic SplitMix64 generator + a case runner that, on failure,
//! prints the seed and a one-shot reproduction hint. Shrinking is
//! seed-based: the runner retries the failing case with simpler draws by
//! re-running the property on the recorded sub-seed with halved ranges.

use std::fmt::Debug;

/// SplitMix64 — tiny, fast, solid 64-bit PRNG (public-domain algorithm).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Self { state: seed.wrapping_add(0x9E3779B97F4A7C15) }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "empty range");
        let span = hi - lo + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64();
        }
        lo + self.next_u64() % span
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.range(lo as u64, hi as u64) as usize
    }

    pub fn f64_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f64_unit() as f32
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "choose from empty slice");
        &items[self.usize_in(0, items.len() - 1)]
    }

    /// Standard-normal via Box–Muller (used to fill test matrices).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_unit().max(1e-12);
        let u2 = self.f64_unit();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32).collect()
    }
}

/// Outcome of a single property case.
pub type CaseResult = Result<(), String>;

/// Run `cases` random cases of `property`. Each case gets its own `Rng`
/// derived from `base_seed` so any failure is reproducible in isolation:
/// `check_seed(name, base_seed, failing_case, property)`.
pub fn check<F>(name: &str, cases: usize, property: F)
where
    F: Fn(&mut Rng) -> CaseResult,
{
    check_with_seed(name, 0xC0FFEE, cases, property)
}

pub fn check_with_seed<F>(name: &str, base_seed: u64, cases: usize, property: F)
where
    F: Fn(&mut Rng) -> CaseResult,
{
    for case in 0..cases {
        let seed = case_seed(base_seed, case);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (seed {seed:#x}): {msg}\n\
                 reproduce with prop::check_seed({name:?}, {base_seed:#x}, \
                 {case}, ...)"
            );
        }
    }
}

/// Re-run exactly one failing case (reproduction helper).
pub fn check_seed<F>(name: &str, base_seed: u64, case: usize, property: F)
where
    F: Fn(&mut Rng) -> CaseResult,
{
    let mut rng = Rng::new(case_seed(base_seed, case));
    if let Err(msg) = property(&mut rng) {
        panic!("property {name:?} case {case}: {msg}");
    }
}

fn case_seed(base: u64, case: usize) -> u64 {
    let mut mix = Rng::new(base ^ (case as u64).wrapping_mul(0x5851F42D4C957F2D));
    mix.next_u64()
}

/// assert_eq-style helper that returns Err instead of panicking, so the
/// runner can attach seed context.
pub fn ensure_eq<T: PartialEq + Debug>(a: T, b: T, what: &str) -> CaseResult {
    if a == b {
        Ok(())
    } else {
        Err(format!("{what}: {a:?} != {b:?}"))
    }
}

pub fn ensure(cond: bool, what: impl Into<String>) -> CaseResult {
    if cond {
        Ok(())
    } else {
        Err(what.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Rng::new(42);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_eq!(a, b);
        let c: Vec<u64> = {
            let mut r = Rng::new(43);
            (0..8).map(|_| r.next_u64()).collect()
        };
        assert_ne!(a, c);
    }

    #[test]
    fn range_is_inclusive_and_bounded() {
        let mut r = Rng::new(7);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..2000 {
            let v = r.range(3, 5);
            assert!((3..=5).contains(&v));
            seen_lo |= v == 3;
            seen_hi |= v == 5;
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn f64_unit_in_range() {
        let mut r = Rng::new(9);
        for _ in 0..1000 {
            let v = r.f64_unit();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(11);
        let xs: Vec<f64> = (0..20_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>()
            / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    #[should_panic(expected = "property \"always-fails\"")]
    fn failures_report_seed() {
        check("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn passing_property_is_silent() {
        check("sum-commutes", 50, |rng| {
            let a = rng.usize_in(0, 1000);
            let b = rng.usize_in(0, 1000);
            ensure_eq(a + b, b + a, "commutativity")
        });
    }
}
