//! Fault injection + output validation — the report's two bugs, made
//! reproducible.
//!
//! The report observed (1) the **compute-unit bug**: CK's Stream-K branch
//! corrupted results whenever a sub-maximal CU count was passed, traced
//! as far as the `Block2CTile` mapping but never isolated; and (2) the
//! **medium-matrix bug**: 480×512×512 produced "99% errors" padded or
//! not. This module contains
//!
//! - [`exec`] — a pure-rust executor that runs a Stream-K schedule over
//!   real f32 matrices (a third, independent implementation of the
//!   semantics, cross-checked against naive GEMM and — via the parity
//!   golden file — against the Pallas kernels). Production entries run
//!   on the blocked microkernel layer ([`crate::kernel`]); the
//!   per-element reference ([`execute_flat_ref`]) stays as the
//!   bit-identical oracle;
//! - [`bugs`] — *injectable* recreations of both bug mechanisms;
//! - [`validate`] — the element-error-rate metric the report quotes.

pub mod bugs;
pub mod exec;
pub mod validate;

pub use bugs::{Fault, FaultyExecutor};
pub use exec::{
    execute_flat, execute_flat_ref, execute_schedule, naive_gemm, Matrix,
};
pub use validate::{error_rate, ErrorReport};
