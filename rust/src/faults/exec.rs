//! Reference executor: runs a Stream-K schedule over real f32 data.
//!
//! Two implementations of the Stream-K execution semantics live here:
//!
//! - [`execute_flat_ref`] — the per-element reference: one indexed MAC
//!   per (row, k, col), the masked/clamped edge addressing written out
//!   literally. This is the semantic ground truth the blocked kernel
//!   layer is property-tested against (bit-identical, including NaN/∞
//!   propagation — zero operands are never skipped), and the baseline
//!   `benches/kernel_exec.rs` measures the blocked path's speedup over.
//! - [`execute_flat`] / [`execute_schedule`] — the production entries,
//!   now executed through the blocked packed-tile layer
//!   ([`crate::kernel`]): panel packing, SIMD-laned register-blocked
//!   microkernel, work items parallelized with deterministic
//!   fixup-ordered reduction, and tile-ownership direct-store
//!   streaming (owned tiles write C in place from the workers; only
//!   clamped-edge / multi-writer tiles keep the ordered windowed
//!   path). Numerics are bit-identical to the reference by
//!   construction (and by `kernel::exec`'s property tests).
//!
//! The fault-injection benches drive [`execute_schedule`] with
//! deliberately broken schedules to produce *numeric* corruption; the
//! blocked executor reproduces a broken schedule's corruption exactly,
//! because it executes whatever work items the schedule describes —
//! the ownership analysis counts duplicate writes per tile, so even a
//! corrupted schedule's colliding stores stay in the reference's
//! serial order.

use crate::decomp::{BlockShape, FlatSchedule, GemmShape, StreamKSchedule};
use crate::kernel;

/// Dense row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_fn(rows: usize, cols: usize, f: impl Fn(usize, usize) -> f32) -> Self {
        let mut m = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                m.data[r * cols + c] = f(r, c);
            }
        }
        m
    }

    pub fn random(rows: usize, cols: usize, rng: &mut crate::prop::Rng) -> Self {
        let data = rng.normal_f32_vec(rows * cols);
        Self { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        self.data[r * self.cols + c] = v;
    }
}

/// Naive triple-loop GEMM — the ground truth.
pub fn naive_gemm(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols, b.rows);
    let mut c = Matrix::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for l in 0..a.cols {
            let av = a.at(i, l);
            if av == 0.0 {
                continue;
            }
            for j in 0..b.cols {
                c.data[i * b.cols + j] += av * b.at(l, j);
            }
        }
    }
    c
}

/// Execute a Stream-K schedule faithfully over matrices — phase 1 (per
/// CU, in CU order) then the fixup pass, semantically identical to the
/// two Pallas kernels. Runs on the blocked kernel layer; the
/// fault-injection benches feed this deliberately broken schedules and
/// the corruption reproduces exactly (execution is schedule-driven).
pub fn execute_schedule(
    a: &Matrix,
    b: &Matrix,
    sched: &StreamKSchedule,
) -> Matrix {
    assert_eq!(a.rows, sched.shape.m);
    assert_eq!(b.cols, sched.shape.n);
    assert_eq!(a.cols, sched.shape.k);
    let _s = crate::trace::span2(
        "replay.execute_schedule",
        "m",
        sched.shape.m as u64,
        "n",
        sched.shape.n as u64,
    );
    let flat = FlatSchedule::from_schedule(sched);
    let data = execute_flat(&a.data, &b.data, sched.shape, &flat, sched.block);
    Matrix { rows: a.rows, cols: b.cols, data }
}

// ---------------------------------------------------------------------
// Per-element reference (the bit-identical ground truth)
// ---------------------------------------------------------------------

/// Accumulate `k_len` BK-deep MAC steps of one tile into `acc` over raw
/// row-major slices — clamped-overlap edge addressing identical to the
/// Pallas kernel, and — deliberately — *without* an `av == 0.0` skip:
/// `0.0 * Inf` must stay NaN so non-finite inputs propagate exactly as
/// the PJRT backend would.
#[allow(clippy::too_many_arguments)]
fn accumulate_segment_flat(
    a: &[f32],
    b: &[f32],
    shape: GemmShape,
    flat: &FlatSchedule,
    blk: BlockShape,
    tile: usize,
    k_start: usize,
    k_len: usize,
    acc: &mut [f32],
) {
    let (tm, tn) = flat.grid.tile_rc(tile);
    let r0 = (tm * blk.bm).min(shape.m.saturating_sub(blk.bm));
    let c0 = (tn * blk.bn).min(shape.n.saturating_sub(blk.bn));
    let k_dim = shape.k;
    for j in k_start..k_start + k_len {
        let kg = j * blk.bk;
        let ks = kg.min(k_dim.saturating_sub(blk.bk));
        for r in 0..blk.bm {
            for kk in 0..blk.bk {
                let kcol = ks + kk;
                if kcol < kg || kcol >= k_dim {
                    continue; // the >=-mask of the nopad policy
                }
                let av = a[(r0 + r) * k_dim + kcol];
                let brow = &b[kcol * shape.n..kcol * shape.n + shape.n];
                for cc in 0..blk.bn {
                    acc[r * blk.bn + cc] += av * brow[c0 + cc];
                }
            }
        }
    }
}

fn store_tile_flat(
    c: &mut [f32],
    shape: GemmShape,
    flat: &FlatSchedule,
    blk: BlockShape,
    tile: usize,
    acc: &[f32],
) {
    let (tm, tn) = flat.grid.tile_rc(tile);
    let r0 = (tm * blk.bm).min(shape.m.saturating_sub(blk.bm));
    let c0 = (tn * blk.bn).min(shape.n.saturating_sub(blk.bn));
    for r in 0..blk.bm {
        for cc in 0..blk.bn {
            c[(r0 + r) * shape.n + c0 + cc] = acc[r * blk.bn + cc];
        }
    }
}

/// Per-element reference execution of a flattened schedule: the exact
/// FP semantics the blocked executor must reproduce bit-for-bit.
/// Kept (and exported) as the property-test oracle and the
/// `kernel_exec` bench baseline — do not optimize this.
pub fn execute_flat_ref(
    a: &[f32],
    b: &[f32],
    shape: GemmShape,
    flat: &FlatSchedule,
    blk: BlockShape,
) -> Vec<f32> {
    assert_eq!(a.len(), shape.m * shape.k, "A shape");
    assert_eq!(b.len(), shape.k * shape.n, "B shape");
    let mut c = vec![0.0f32; shape.m * shape.n];
    // partials[cu][slot]
    let mut partials =
        vec![vec![vec![0.0f32; blk.bm * blk.bn]; 2]; flat.p];

    for cu in 0..flat.p {
        for tile in flat.direct_tiles(cu) {
            let mut acc = vec![0.0f32; blk.bm * blk.bn];
            accumulate_segment_flat(
                a,
                b,
                shape,
                flat,
                blk,
                tile,
                0,
                flat.grid.iters_per_tile,
                &mut acc,
            );
            store_tile_flat(&mut c, shape, flat, blk, tile, &acc);
        }
        for seg in flat.cu_segments(cu) {
            let mut acc = vec![0.0f32; blk.bm * blk.bn];
            accumulate_segment_flat(
                a, b, shape, flat, blk, seg.tile, seg.k_start, seg.k_len,
                &mut acc,
            );
            if seg.direct {
                store_tile_flat(&mut c, shape, flat, blk, seg.tile, &acc);
            } else {
                partials[cu][seg.slot] = acc;
            }
        }
    }

    for (i, &tile) in flat.split_tiles.iter().enumerate() {
        let mut acc = vec![0.0f32; blk.bm * blk.bn];
        for contrib in flat.tile_contributors(i) {
            let frag = &partials[contrib.cu][contrib.slot];
            for (dst, src) in acc.iter_mut().zip(frag) {
                *dst += *src;
            }
        }
        store_tile_flat(&mut c, shape, flat, blk, tile, &acc);
    }
    c
}

/// Execute a *flattened* Stream-K schedule over row-major f32 slices —
/// the executor the interpreter runtime drives from the plan cache.
/// Runs on the blocked packed-tile kernel layer ([`crate::kernel`]):
/// bit-identical to [`execute_flat_ref`] (property-tested there),
/// several-fold faster — explicit SIMD lanes, parallel work items,
/// owned tiles streamed into C in place. Zero operands are never
/// skipped, so NaN/∞ inputs propagate exactly as the PJRT backend
/// would.
pub fn execute_flat(
    a: &[f32],
    b: &[f32],
    shape: GemmShape,
    flat: &FlatSchedule,
    blk: BlockShape,
) -> Vec<f32> {
    let _s =
        crate::trace::span2("replay.execute_flat", "cus", flat.p as u64, "k", shape.k as u64);
    kernel::execute_flat_schedule(a, b, shape, flat, blk, kernel::Epilogue::None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{build_schedule, BlockShape, GemmShape};
    use crate::prop;

    fn check(m: usize, n: usize, k: usize, p: usize) {
        let mut rng = prop::Rng::new((m * 31 + n * 7 + k + p) as u64);
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let sched = build_schedule(
            GemmShape::new(m, n, k),
            BlockShape::new(16, 16, 8),
            p,
        )
        .unwrap();
        let got = execute_schedule(&a, &b, &sched);
        let want = naive_gemm(&a, &b);
        for (i, (g, w)) in got.data.iter().zip(&want.data).enumerate() {
            assert!(
                (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                "{m}x{n}x{k} p={p} elem {i}: {g} vs {w}"
            );
        }
    }

    #[test]
    fn matches_naive_on_table1_like_shapes() {
        check(96, 102, 100, 12); // ragged hybrid
        check(3, 9, 9, 120); // Table-1 small
        check(48, 64, 80, 1); // serial
        check(64, 64, 64, 7); // aligned, odd CU count
    }

    #[test]
    fn flat_executor_matches_reference_and_naive() {
        use crate::decomp::FlatSchedule;
        for (m, n, k, p) in [
            (96usize, 102usize, 100usize, 12usize), // ragged hybrid
            (3, 9, 9, 120),
            (48, 64, 80, 1),
            (64, 64, 64, 7),
        ] {
            let mut rng = prop::Rng::new((m + n * 3 + k * 7 + p) as u64);
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let sched = build_schedule(
                GemmShape::new(m, n, k),
                BlockShape::new(16, 16, 8),
                p,
            )
            .unwrap();
            let flat = FlatSchedule::from_schedule(&sched);
            let got = execute_flat(
                &a.data,
                &b.data,
                sched.shape,
                &flat,
                sched.block,
            );
            // blocked == per-element reference, bit for bit
            let reference = execute_flat_ref(
                &a.data,
                &b.data,
                sched.shape,
                &flat,
                sched.block,
            );
            for (i, (g, w)) in got.iter().zip(&reference).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{m}x{n}x{k} p={p} elem {i}: {g} vs {w} (vs reference)"
                );
            }
            let want = naive_gemm(&a, &b);
            for (i, (g, w)) in got.iter().zip(&want.data).enumerate() {
                assert!(
                    (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                    "{m}x{n}x{k} p={p} elem {i}: {g} vs {w}"
                );
            }
        }
    }

    #[test]
    fn flat_executor_propagates_non_finite_inputs() {
        use crate::decomp::FlatSchedule;
        // 0·Inf must stay NaN (the interpreter's PJRT-parity contract);
        // a zero-skip would lose it.
        let m = 8;
        let mut a = Matrix::zeros(m, m);
        a.set(0, 0, f32::INFINITY);
        let b = Matrix::zeros(m, m); // all zeros → Inf * 0 = NaN
        let sched = build_schedule(
            GemmShape::new(m, m, m),
            BlockShape::new(8, 8, 8),
            2,
        )
        .unwrap();
        let flat = FlatSchedule::from_schedule(&sched);
        let got =
            execute_flat(&a.data, &b.data, sched.shape, &flat, sched.block);
        assert!(got[0].is_nan(), "0*Inf must propagate as NaN, got {}", got[0]);
        let reference =
            execute_flat_ref(&a.data, &b.data, sched.shape, &flat, sched.block);
        assert!(reference[0].is_nan(), "reference must agree");
    }

    #[test]
    fn prop_executor_matches_naive() {
        prop::check("schedule executor == naive gemm", 25, |rng| {
            let m = rng.usize_in(1, 80);
            let n = rng.usize_in(1, 80);
            let k = rng.usize_in(1, 80);
            let p = *rng.choose(&[1usize, 3, 16, 120]);
            let a = Matrix::random(m, k, rng);
            let b = Matrix::random(k, n, rng);
            let sched = build_schedule(
                GemmShape::new(m, n, k),
                BlockShape::new(16, 16, 8),
                p,
            )
            .map_err(|e| e.to_string())?;
            let got = execute_schedule(&a, &b, &sched);
            let want = naive_gemm(&a, &b);
            for (g, w) in got.data.iter().zip(&want.data) {
                prop::ensure(
                    (g - w).abs() <= 1e-3 * w.abs().max(1.0),
                    format!("{m}x{n}x{k} p={p}: {g} vs {w}"),
                )?;
            }
            Ok(())
        });
    }
}
