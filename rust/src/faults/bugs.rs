//! Injectable recreations of the report's two CK bugs.
//!
//! Both are *mechanism-level* reconstructions: plausible, minimal code
//! defects that produce exactly the observable the report describes, so
//! the CUBUG/MEDBUG benches can show the symptom and the validator can
//! catch it — and so the tests can prove the *fixed* path (the plain
//! executor) never exhibits it.

use super::exec::{execute_schedule, Matrix};
use crate::decomp::{Contributor, StreamKSchedule};

/// Which defect to inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    /// None — the fixed implementation.
    None,
    /// The compute-unit bug: the Block2CTile mapping is computed with the
    /// *hardware* CU count (120 on the MI200) while the launch uses the
    /// user-requested count. Segments land on the wrong tiles whenever
    /// `cus != hw_cus` — matching the report: default CU count works,
    /// any sub-maximal value corrupts the output.
    CuMapping { hw_cus: usize },
    /// The medium-matrix bug: the fixup pass allocates a fixed two-entry
    /// contributor table per split tile (CK's two-CTA assumption) and
    /// silently drops further contributors. Only shapes whose
    /// (tiles, ipt, P) produce ≥3-way split tiles corrupt — 480×512×512
    /// is such a shape at the CK defaults; most Table-1 shapes are not.
    FixupOverflow,
}

/// Executor wrapper that applies a [`Fault`] to the schedule before
/// running it.
pub struct FaultyExecutor {
    pub fault: Fault,
}

impl FaultyExecutor {
    pub fn new(fault: Fault) -> Self {
        Self { fault }
    }

    /// Run A·B under the injected fault.
    pub fn run(&self, a: &Matrix, b: &Matrix, sched: &StreamKSchedule) -> Matrix {
        match self.fault {
            Fault::None => execute_schedule(a, b, sched),
            Fault::CuMapping { hw_cus } => {
                let broken = inject_cu_mapping_bug(sched, hw_cus);
                execute_schedule(a, b, &broken)
            }
            Fault::FixupOverflow => {
                let broken = inject_fixup_overflow(sched);
                execute_schedule(a, b, &broken)
            }
        }
    }
}

/// Recreate the CU bug: re-map every SK segment's tile through a stride
/// computed with `hw_cus` instead of `sched.p`. Identity when
/// `sched.p == hw_cus` (the report: full-CU runs were fine).
fn inject_cu_mapping_bug(sched: &StreamKSchedule, hw_cus: usize) -> StreamKSchedule {
    let mut broken = sched.clone();
    if sched.p == hw_cus {
        return broken;
    }
    let tiles = sched.grid.num_tiles();
    let remap = |tile: usize| -> usize {
        // CK's Block2CTileMap composes a block id with the launch grid;
        // with the wrong grid stride the affine map walks off the raster.
        (tile * hw_cus / sched.p.max(1)) % tiles
    };
    for segs in &mut broken.segments {
        for seg in segs {
            seg.tile = remap(seg.tile);
        }
    }
    for st in &mut broken.split_tiles {
        st.tile = remap(st.tile);
    }
    broken
}

/// Recreate the medium-matrix bug: truncate every split tile's
/// contributor list to two entries.
fn inject_fixup_overflow(sched: &StreamKSchedule) -> StreamKSchedule {
    let mut broken = sched.clone();
    for st in &mut broken.split_tiles {
        st.contributors.truncate(2);
        let _: &Vec<Contributor> = &st.contributors;
    }
    broken
}

/// Does this schedule trigger the FixupOverflow bug? (≥3-way split tile.)
pub fn shape_triggers_fixup_overflow(sched: &StreamKSchedule) -> bool {
    sched.max_contributors >= 3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{build_schedule, BlockShape, GemmShape};
    use crate::faults::validate::error_rate;
    use crate::faults::exec::naive_gemm;
    use crate::prop;

    fn run_case(
        m: usize,
        n: usize,
        k: usize,
        p: usize,
        block: BlockShape,
        fault: Fault,
    ) -> f64 {
        let mut rng = prop::Rng::new(99);
        let a = Matrix::random(m, k, &mut rng);
        let b = Matrix::random(k, n, &mut rng);
        let sched =
            build_schedule(GemmShape::new(m, n, k), block, p).unwrap();
        let got = FaultyExecutor::new(fault).run(&a, &b, &sched);
        let want = naive_gemm(&a, &b);
        error_rate(&got.data, &want.data, 1e-3).rate
    }

    const BLK: BlockShape = BlockShape { bm: 16, bn: 16, bk: 8 };

    #[test]
    fn cu_bug_clean_at_full_cus() {
        // The report: default (full) CU count works fine.
        let e = run_case(96, 96, 64, 120, BLK, Fault::CuMapping { hw_cus: 120 });
        assert_eq!(e, 0.0);
    }

    #[test]
    fn cu_bug_corrupts_submaximal_cus() {
        // The report: any explicit sub-maximal CU count corrupts.
        let e = run_case(96, 96, 64, 30, BLK, Fault::CuMapping { hw_cus: 120 });
        assert!(e > 0.3, "error rate {e}");
        // and the fixed path is clean at the same CU count
        let fixed = run_case(96, 96, 64, 30, BLK, Fault::None);
        assert_eq!(fixed, 0.0);
    }

    #[test]
    fn fixup_overflow_silent_on_two_way_splits() {
        // A shape whose split tiles all have <= 2 contributors.
        let sched = build_schedule(
            GemmShape::new(96, 96, 64),
            BLK,
            4,
        )
        .unwrap();
        if sched.max_contributors <= 2 {
            let e = run_case(96, 96, 64, 4, BLK, Fault::FixupOverflow);
            assert_eq!(e, 0.0);
        }
    }

    #[test]
    fn fixup_overflow_corrupts_medium_matrix() {
        // The scaled 480x512x512 analogue: blocks scaled 1:8 like the
        // problem, giving deep multi-contributor split tiles.
        let shape = GemmShape::new(60, 64, 64);
        let sched = build_schedule(shape, BlockShape::new(16, 16, 2), 120)
            .unwrap();
        assert!(
            shape_triggers_fixup_overflow(&sched),
            "case must have >=3-way splits (max={})",
            sched.max_contributors
        );
        let e = run_case(60, 64, 64, 120, BlockShape::new(16, 16, 2),
                         Fault::FixupOverflow);
        assert!(e > 0.5, "error rate {e} — the report saw 99%");
        let fixed = run_case(60, 64, 64, 120, BlockShape::new(16, 16, 2),
                             Fault::None);
        assert_eq!(fixed, 0.0);
    }

    #[test]
    fn prop_fixed_path_never_corrupts() {
        prop::check("Fault::None is always clean", 20, |rng| {
            let m = rng.usize_in(1, 60);
            let n = rng.usize_in(1, 60);
            let k = rng.usize_in(1, 60);
            let p = *rng.choose(&[1usize, 13, 120]);
            let e = run_case(m, n, k, p, BLK, Fault::None);
            prop::ensure(e == 0.0, format!("{m}x{n}x{k} p={p}: rate {e}"))
        });
    }
}
