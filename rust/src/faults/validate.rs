//! Output validation — the report's "99% errors" metric.
//!
//! CK's examples validate GEMM output element-wise against a host
//! reference and report the fraction exceeding tolerance; that fraction
//! is what the report quotes for the medium-matrix bug. Same metric here.

/// Element-wise comparison summary.
#[derive(Debug, Clone, PartialEq)]
pub struct ErrorReport {
    pub total: usize,
    pub bad: usize,
    /// Fraction of elements exceeding tolerance — "99% errors" ⇒ 0.99.
    pub rate: f64,
    pub max_abs_err: f64,
    pub max_rel_err: f64,
}

impl ErrorReport {
    /// CK's pass/fail line.
    pub fn passed(&self) -> bool {
        self.bad == 0
    }
}

/// Compare `got` vs `want` with a mixed absolute/relative tolerance:
/// an element fails when `|g - w| > tol · max(|w|, 1)`.
pub fn error_rate(got: &[f32], want: &[f32], tol: f32) -> ErrorReport {
    assert_eq!(got.len(), want.len(), "shape mismatch");
    assert!(tol > 0.0);
    let mut bad = 0usize;
    let mut max_abs = 0.0f64;
    let mut max_rel = 0.0f64;
    for (&g, &w) in got.iter().zip(want) {
        let abs = (g - w).abs() as f64;
        let rel = abs / (w.abs() as f64).max(1.0);
        max_abs = max_abs.max(abs);
        max_rel = max_rel.max(rel);
        if abs > (tol * w.abs().max(1.0)) as f64 {
            bad += 1;
        }
    }
    let total = got.len();
    ErrorReport {
        total,
        bad,
        rate: if total == 0 { 0.0 } else { bad as f64 / total as f64 },
        max_abs_err: max_abs,
        max_rel_err: max_rel,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_passes() {
        let r = error_rate(&[1.0, 2.0, 3.0], &[1.0, 2.0, 3.0], 1e-5);
        assert!(r.passed());
        assert_eq!(r.rate, 0.0);
    }

    #[test]
    fn detects_99_percent_errors() {
        let want = vec![1.0f32; 100];
        let mut got = vec![5.0f32; 100];
        got[0] = 1.0;
        let r = error_rate(&got, &want, 1e-3);
        assert_eq!(r.bad, 99);
        assert!((r.rate - 0.99).abs() < 1e-12);
        assert!(!r.passed());
        assert!((r.max_abs_err - 4.0).abs() < 1e-12);
    }

    #[test]
    fn relative_tolerance_scales_with_magnitude() {
        // 0.1 absolute error on a 1e6 value is fine at 1e-3 rel tol...
        let r = error_rate(&[1e6 + 0.1], &[1e6], 1e-3);
        assert!(r.passed());
        // ...but not on a value of 1.
        let r = error_rate(&[1.1], &[1.0], 1e-3);
        assert!(!r.passed());
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        let _ = error_rate(&[1.0], &[1.0, 2.0], 1e-3);
    }
}
