//! # streamk — Stream-K work-centric GEMM decomposition framework
//!
//! Reproduction of *"Stream-K Optimization and Exploration"* (2024), built on
//! Osama et al.'s Stream-K (PPoPP 2023). Three layers:
//!
//! - **L1** (build-time Python): Pallas GEMM kernels — Stream-K, conventional
//!   tile-based, and Split-K — lowered AOT to HLO text.
//! - **L2** (build-time Python): JAX compute graphs (GEMM + epilogues, MLP)
//!   that call the kernels.
//! - **L3** (this crate): the runtime — partition math ([`decomp`]), a
//!   GPU-occupancy simulator ([`gpu_sim`]), the Block2Time predictive load
//!   balancer ([`predict`]), a sharded plan cache over flattened Stream-K
//!   schedules ([`plan`] — the zero-rebuild serving hot path), a blocked
//!   packed-tile microkernel execution layer ([`kernel`] — how the
//!   functional backend runs those schedules over host data), a
//!   legality-pruned autotuner with a persistent per-shape config cache
//!   ([`tuner`]), a heterogeneous multi-device serving layer ([`fleet`]),
//!   a PJRT artifact runtime ([`runtime`]), the serving coordinator
//!   ([`coordinator`]), and a structured tracing + Block2Time residual
//!   accounting layer ([`trace`]).
//!
//! Python never runs on the request path: `make artifacts` lowers everything
//! once; the rust binary is self-contained afterwards.

pub mod bench;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod decomp;
pub mod exec;
pub mod faults;
pub mod fleet;
pub mod gpu_sim;
pub mod json;
pub mod kernel;
pub mod net;
pub mod plan;
pub mod predict;
pub mod prop;
pub mod runtime;
pub mod trace;
pub mod tuner;
