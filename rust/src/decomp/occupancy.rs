//! Quantization-efficiency / occupancy analysis — Figure 1's arithmetic.
//!
//! A data-parallel launch of `t` tiles on `p` CUs runs in `ceil(t/p)`
//! waves; the last wave is partially filled, idling `p·ceil(t/p) − t`
//! CUs. The report's Figure 1 shows 75% utilization; Stream-K's flat
//! near-100% line is the paper's headline.

use super::{cdiv, BlockShape, GemmShape, TileGrid};

/// Utilization of a pure data-parallel launch: `t / (p·ceil(t/p))`.
pub fn dp_efficiency(num_tiles: usize, p: usize) -> f64 {
    if num_tiles == 0 || p == 0 {
        return 1.0;
    }
    let waves = cdiv(num_tiles, p);
    num_tiles as f64 / (waves * p) as f64
}

/// Utilization of the hybrid Stream-K schedule for the same problem.
pub fn sk_efficiency(shape: GemmShape, block: BlockShape, p: usize) -> f64 {
    match super::build_schedule(shape, block, p) {
        Ok(s) => s.quantization_efficiency_sk(),
        Err(_) => 1.0,
    }
}

/// Per-CU busy ratios for a DP launch — the bar heights of Figure 1.
/// CU `i` executes `ceil((t - i) / p)` tiles.
pub fn dp_cu_load(num_tiles: usize, p: usize) -> Vec<f64> {
    let waves = cdiv(num_tiles.max(1), p.max(1));
    (0..p)
        .map(|i| {
            let tiles_i = if i < num_tiles % p || num_tiles % p == 0 {
                waves
            } else {
                waves - 1
            };
            // When t < p some CUs run zero tiles.
            let tiles_i = if num_tiles <= i { 0 } else { tiles_i };
            tiles_i as f64 / waves as f64
        })
        .collect()
}

/// One row of the FIG1 utilization sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct UtilizationPoint {
    pub shape: GemmShape,
    pub num_tiles: usize,
    pub waves: f64,
    pub dp_efficiency: f64,
    pub sk_efficiency: f64,
}

/// Sweep output-tile counts around multiples of `p` — the sawtooth of
/// the conventional decomposition vs Stream-K's flat line.
pub fn utilization_sweep(
    block: BlockShape,
    p: usize,
    n: usize,
    k: usize,
    m_values: impl IntoIterator<Item = usize>,
) -> Vec<UtilizationPoint> {
    m_values
        .into_iter()
        .map(|m| {
            let shape = GemmShape::new(m, n, k);
            let grid = TileGrid::new(shape, block.effective(shape));
            UtilizationPoint {
                shape,
                num_tiles: grid.num_tiles(),
                waves: grid.num_tiles() as f64 / p as f64,
                dp_efficiency: dp_efficiency(grid.num_tiles(), p),
                sk_efficiency: sk_efficiency(shape, block, p),
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn figure1_example_75_percent() {
        // 3 tiles on 4 CUs -> one wave at 75% occupancy.
        assert!((dp_efficiency(3, 4) - 0.75).abs() < 1e-12);
        // Stream-K on the same problem stays near-perfect.
        let sk = sk_efficiency(
            GemmShape::new(3 * 128, 128, 4096),
            BlockShape::default(),
            4,
        );
        assert!(sk > 0.99, "sk={sk}");
    }

    #[test]
    fn full_waves_are_perfect() {
        assert_eq!(dp_efficiency(240, 120), 1.0);
        assert_eq!(dp_efficiency(120, 120), 1.0);
    }

    #[test]
    fn worst_case_one_extra_tile() {
        // 121 tiles on 120 CUs: 2 waves, ~50.4% utilization.
        let e = dp_efficiency(121, 120);
        assert!((e - 121.0 / 240.0).abs() < 1e-12);
    }

    #[test]
    fn cu_load_shape() {
        let load = dp_cu_load(3, 4);
        assert_eq!(load, vec![1.0, 1.0, 1.0, 0.0]);
        let load = dp_cu_load(6, 4);
        assert_eq!(load, vec![1.0, 1.0, 0.5, 0.5]);
    }

    #[test]
    fn prop_sk_always_at_least_dp() {
        prop::check("sk >= dp efficiency", 80, |rng| {
            let m = rng.usize_in(1, 4000);
            let n = rng.usize_in(1, 2000);
            let k = rng.usize_in(1, 2000);
            let p = rng.usize_in(1, 200);
            let shape = GemmShape::new(m, n, k);
            let block = BlockShape::default();
            let grid = TileGrid::new(shape, block.effective(shape));
            let dp = dp_efficiency(grid.num_tiles(), p);
            let sk = sk_efficiency(shape, block, p);
            prop::ensure(
                sk >= dp - 1e-9,
                format!("sk {sk} < dp {dp} for {shape:?} p={p}"),
            )
        });
    }

    #[test]
    fn sweep_produces_sawtooth() {
        let pts = utilization_sweep(
            BlockShape::default(),
            120,
            4096,
            4096,
            (1..=40).map(|i| i * 128),
        );
        assert_eq!(pts.len(), 40);
        // DP efficiency dips right after each full-wave point...
        // (tiles = 32·i, so the first full-wave point is 480 = 4 waves)
        let full_wave = pts.iter().find(|p| p.num_tiles == 480).unwrap();
        assert_eq!(full_wave.dp_efficiency, 1.0);
        // ...while SK stays near 1 everywhere (±1 MAC-iteration
        // imbalance costs ~5% at the smallest sweep point).
        assert!(pts.iter().all(|p| p.sk_efficiency > 0.9));
        assert!(pts
            .iter()
            .filter(|p| p.num_tiles >= 120)
            .all(|p| p.sk_efficiency > 0.97));
        assert!(pts.iter().any(|p| p.dp_efficiency < 0.9));
    }
}
