//! Conventional data-parallel (tile-per-workgroup) decomposition — the
//! baseline Stream-K displaces. Produces the per-CU work lists the GPU
//! simulator replays.

use super::swizzle::Swizzle;
use super::TileGrid;

/// One unit of CU work: an output tile plus how many BK-deep MAC
/// iterations it runs there (always the full tile depth for DP).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WorkItem {
    pub tile: usize,
    pub k_iters: usize,
    /// True when the result is a partial needing a later reduction.
    pub partial: bool,
}

/// Wave-strided DP assignment: CU `i` runs tiles `i, i+p, i+2p, …` in
/// swizzled raster order. Mirrors how a GPU dispatches a grid of
/// workgroups round-robin across CUs.
pub fn dp_assignment(
    grid: TileGrid,
    p: usize,
    swizzle: Swizzle,
) -> Vec<Vec<WorkItem>> {
    assert!(p > 0);
    let mut cus = vec![Vec::new(); p];
    for t in 0..grid.num_tiles() {
        // raster position t maps to tile id via the swizzle
        let (r, c) = swizzle.tile_rc(grid, t);
        let tile = r * grid.tiles_n + c;
        cus[t % p].push(WorkItem {
            tile,
            k_iters: grid.iters_per_tile,
            partial: false,
        });
    }
    cus
}

/// Number of waves a DP launch needs (`ceil(tiles / p)`).
pub fn dp_waves(grid: TileGrid, p: usize) -> usize {
    super::cdiv(grid.num_tiles(), p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{BlockShape, GemmShape};
    use crate::prop;

    fn grid(tm: usize, tn: usize, ipt: usize) -> TileGrid {
        TileGrid::new(
            GemmShape::new(tm * 128, tn * 128, ipt * 64),
            BlockShape::default(),
        )
    }

    #[test]
    fn strided_assignment() {
        let g = grid(2, 3, 4);
        let cus = dp_assignment(g, 4, Swizzle::RowMajor);
        assert_eq!(cus.len(), 4);
        assert_eq!(cus[0].iter().map(|w| w.tile).collect::<Vec<_>>(), vec![0, 4]);
        assert_eq!(cus[1].iter().map(|w| w.tile).collect::<Vec<_>>(), vec![1, 5]);
        assert_eq!(cus[2].iter().map(|w| w.tile).collect::<Vec<_>>(), vec![2]);
        assert!(cus.iter().flatten().all(|w| w.k_iters == 4 && !w.partial));
    }

    #[test]
    fn prop_every_tile_assigned_once() {
        prop::check("dp assignment covers tiles", 60, |rng| {
            let g = grid(rng.usize_in(1, 30), rng.usize_in(1, 30), 2);
            let p = rng.usize_in(1, 130);
            let sw = *rng.choose(&[
                Swizzle::RowMajor,
                Swizzle::ColMajor,
                Swizzle::GroupedRows(3),
            ]);
            let cus = dp_assignment(g, p, sw);
            let mut seen = vec![false; g.num_tiles()];
            for w in cus.iter().flatten() {
                prop::ensure(!seen[w.tile], format!("tile {} twice", w.tile))?;
                seen[w.tile] = true;
            }
            prop::ensure(seen.iter().all(|&s| s), "tile missing")?;
            // per-CU tile counts differ by at most one (strided round robin)
            let counts: Vec<usize> = cus.iter().map(Vec::len).collect();
            let (mn, mx) =
                (*counts.iter().min().unwrap(), *counts.iter().max().unwrap());
            prop::ensure(mx - mn <= 1, "unbalanced stride")
        });
    }

    #[test]
    fn waves() {
        assert_eq!(dp_waves(grid(2, 3, 1), 4), 2);
        assert_eq!(dp_waves(grid(2, 2, 1), 4), 1);
        assert_eq!(dp_waves(grid(11, 11, 1), 120), 2);
    }
}
