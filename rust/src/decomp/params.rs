//! Kernel-parameter legality — the space the report could not explore.
//!
//! CK's Stream-K kernel has ~15 interdependent template parameters; the
//! report found "the vast majority of block/hyperparameter adjustments"
//! failed to compile, and the one config that did compile (1024 threads,
//! 16×16 per XDL) threw floating-point errors at runtime. This module
//! makes that implicit constraint system *explicit*: a legality predicate
//! over the TPU-adapted parameter space, with human-readable reasons.
//! `cargo bench --bench blocksize_sweep` prints the legality matrix (the
//! BLK experiment).

use super::BlockShape;
use crate::kernel::{RegBlock, Width};

/// Default K-chunk length: how deep a K slice the executor packs and
/// streams per panel pair ([`crate::kernel`] re-exports this as
/// `micro::KC`). Chunking never changes numerics — K still ascends per
/// element — so the axis is purely a locality knob.
pub const KC_DEFAULT: usize = 128;

/// Packed-panel budget for one K chunk: the `BM × KC` A panel plus the
/// `KC × BN` B panel must stay cache-resident while the microkernel
/// streams them (the CPU analogue of the VMEM streaming budget).
pub const PACK_BUDGET_BYTES: usize = 512 * 1024;

/// Full kernel parameter point (TPU adaptation of CK's template params —
/// DESIGN.md §3 maps threadblock/XDL/LDS onto grid/MXU/VMEM).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct KernelParams {
    pub block: BlockShape,
    /// Elements per vector lane pack (CK's kpack / ABlockTransfer widths).
    pub kpack: usize,
    /// MXU tile the inner product maps to (CK's "M/N per XDL").
    pub mxu_m: usize,
    pub mxu_n: usize,
    /// Element width the A/B panels stream at (f32 / bf16 / f16).
    /// Accumulation and C output stay f32 at every width, so this is a
    /// pure precision-vs-bandwidth axis — 16-bit widths halve streamed
    /// panel bytes and double the VMEM headroom.
    pub width: Width,
    /// Register block (MR×NR accumulator tile) the lane kernels run.
    /// Searched per width: f32 is pinned to the baseline block (its
    /// bit-identity contract is frozen), 16-bit widths may take the
    /// wide block.
    pub reg: RegBlock,
    /// Double-buffer the HBM→VMEM stream (doubles VMEM footprint).
    pub double_buffer: bool,
    /// K-chunk length the executor packs panels at (CK's K staging
    /// depth; [`KC_DEFAULT`] unless tuned).
    pub kc: usize,
}

impl KernelParams {
    /// Back-compat constructor speaking bytes-per-element (2 → bf16,
    /// anything else → f32, see [`Width::from_bpe`]).
    pub fn new(block: BlockShape, bytes_per_elem: usize) -> Self {
        Self::new_w(block, Width::from_bpe(bytes_per_elem))
    }

    pub fn new_w(block: BlockShape, width: Width) -> Self {
        Self {
            block,
            kpack: 8,
            mxu_m: 128,
            mxu_n: 128,
            width,
            reg: RegBlock::BASE,
            double_buffer: true,
            kc: KC_DEFAULT,
        }
    }

    /// Streamed bytes per panel element at this point's width.
    pub fn bytes_per_elem(&self) -> usize {
        self.width.bytes()
    }

    /// VMEM bytes the kernel holds resident: A-block + B-block (possibly
    /// double-buffered) + f32 accumulator + two partial slots.
    pub fn vmem_bytes(&self) -> usize {
        let BlockShape { bm, bn, bk } = self.block;
        let stream = (bm * bk + bk * bn) * self.bytes_per_elem();
        let stream = if self.double_buffer { 2 * stream } else { stream };
        let acc = bm * bn * 4;
        let partials = 2 * bm * bn * 4;
        stream + acc + partials
    }

    /// Estimated MXU utilization from tile alignment: how much of each
    /// systolic-array pass is real data.
    pub fn mxu_utilization(&self) -> f64 {
        let fill = |dim: usize, mxu: usize| -> f64 {
            let packed = dim.min(mxu);
            packed as f64 / mxu as f64
        };
        fill(self.block.bm, self.mxu_m) * fill(self.block.bn, self.mxu_n)
    }
}

/// TPU-v4-class budget used by the legality predicate.
pub const VMEM_BUDGET_BYTES: usize = 16 * 1024 * 1024;
/// Sublane granularity for f32 (8) — second-minor dim alignment.
pub const SUBLANE: usize = 8;
/// Lane granularity (128) — minor dim alignment.
pub const LANE: usize = 128;

/// Why a parameter point is illegal. CK surfaces these as opaque template
/// instantiation failures; we name them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Illegal {
    ZeroDim,
    VmemOverflow { need: usize, budget: usize },
    LaneMisaligned { dim: &'static str, value: usize },
    SublaneMisaligned { dim: &'static str, value: usize },
    KpackMisaligned { bk: usize, kpack: usize },
    /// The K-chunk axis must respect the vector pack width.
    KcMisaligned { kc: usize, kpack: usize },
    /// One K chunk's packed A+B panels exceed the cache-residency
    /// budget — the chunk would thrash instead of stream.
    PackOverflow { need: usize, budget: usize },
    MxuUnderfilled { util_pct: usize },
    /// CK's 1024-thread/16×16-XDL failure mode: accumulator rows per MXU
    /// pass exceed what the tile provides, producing the FP errors the
    /// report saw. We reject the combination statically.
    MxuTileMismatch { bm: usize, bn: usize, mxu_m: usize, mxu_n: usize },
    /// Register block not offered at this element width: the wide
    /// accumulator tile exists only for 16-bit lanes (f32 is pinned to
    /// the baseline block — its bit-identity contract is frozen), and
    /// arbitrary MR/NR pairs have no lane kernel at all.
    RegIllegal { mr: usize, nr: usize, width: Width },
}

impl Illegal {
    /// Short stable label for aggregation (legality matrices, tuner
    /// pruning stats). The `Display` impl carries the specifics.
    pub fn label(&self) -> &'static str {
        match self {
            Illegal::ZeroDim => "zero block dimension",
            Illegal::VmemOverflow { .. } => "VMEM overflow",
            Illegal::LaneMisaligned { .. } => {
                "minor dim not lane-aligned (128)"
            }
            Illegal::SublaneMisaligned { .. } => {
                "second-minor dim not sublane-aligned (8)"
            }
            Illegal::KpackMisaligned { .. } => "kpack misaligned",
            Illegal::KcMisaligned { .. } => "KC not kpack-aligned",
            Illegal::PackOverflow { .. } => {
                "packed K-chunk panels overflow the cache budget"
            }
            Illegal::MxuUnderfilled { .. } => {
                "MXU utilization below 25% floor"
            }
            Illegal::MxuTileMismatch { .. } => {
                "block smaller than MXU tile (CK 16x16-per-XDL FP-error mode)"
            }
            Illegal::RegIllegal { .. } => {
                "register block not offered at this element width"
            }
        }
    }
}

impl std::fmt::Display for Illegal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Illegal::ZeroDim => write!(f, "zero block dimension"),
            Illegal::VmemOverflow { need, budget } => {
                write!(f, "VMEM overflow: need {need} B > budget {budget} B")
            }
            Illegal::LaneMisaligned { dim, value } => {
                write!(f, "{dim}={value} not a multiple of {LANE} lanes")
            }
            Illegal::SublaneMisaligned { dim, value } => {
                write!(f, "{dim}={value} not a multiple of {SUBLANE} sublanes")
            }
            Illegal::KpackMisaligned { bk, kpack } => {
                write!(f, "bk={bk} not divisible by kpack={kpack}")
            }
            Illegal::KcMisaligned { kc, kpack } => {
                write!(f, "kc={kc} not divisible by kpack={kpack}")
            }
            Illegal::PackOverflow { need, budget } => write!(
                f,
                "packed K-chunk panels need {need} B > budget {budget} B"
            ),
            Illegal::MxuUnderfilled { util_pct } => {
                write!(f, "MXU utilization {util_pct}% below 25% floor")
            }
            Illegal::MxuTileMismatch { bm, bn, mxu_m, mxu_n } => write!(
                f,
                "block {bm}x{bn} smaller than MXU tile {mxu_m}x{mxu_n} \
                 (CK's 16x16-per-XDL runtime-FP-error mode)"
            ),
            Illegal::RegIllegal { mr, nr, width } => write!(
                f,
                "register block {mr}x{nr} has no {width} lane kernel"
            ),
        }
    }
}

/// The legality predicate: `Ok(())` iff a real-TPU lowering of this point
/// would compile and run. (Interpret-mode accepts anything; this encodes
/// the Mosaic constraints so exploration happens *before* a TPU build.)
pub fn check(p: &KernelParams) -> Result<(), Vec<Illegal>> {
    let mut errs = Vec::new();
    let BlockShape { bm, bn, bk } = p.block;
    if bm == 0 || bn == 0 || bk == 0 || p.kc == 0 {
        errs.push(Illegal::ZeroDim);
        return Err(errs);
    }
    if bn % LANE != 0 {
        errs.push(Illegal::LaneMisaligned { dim: "bn", value: bn });
    }
    if bk % LANE != 0 && bk % p.kpack != 0 {
        errs.push(Illegal::KpackMisaligned { bk, kpack: p.kpack });
    }
    if bm % SUBLANE != 0 {
        errs.push(Illegal::SublaneMisaligned { dim: "bm", value: bm });
    }
    if p.kc % p.kpack != 0 {
        errs.push(Illegal::KcMisaligned { kc: p.kc, kpack: p.kpack });
    }
    if !p.reg.is_legal(p.width) {
        errs.push(Illegal::RegIllegal {
            mr: p.reg.mr,
            nr: p.reg.nr,
            width: p.width,
        });
    }
    let pack_need = (bm * p.kc + p.kc * bn) * p.bytes_per_elem();
    if pack_need > PACK_BUDGET_BYTES {
        errs.push(Illegal::PackOverflow {
            need: pack_need,
            budget: PACK_BUDGET_BYTES,
        });
    }
    let need = p.vmem_bytes();
    if need > VMEM_BUDGET_BYTES {
        errs.push(Illegal::VmemOverflow { need, budget: VMEM_BUDGET_BYTES });
    }
    if bm < p.mxu_m && bn < p.mxu_n && (p.mxu_m > 16 || p.mxu_n > 16) {
        errs.push(Illegal::MxuTileMismatch {
            bm,
            bn,
            mxu_m: p.mxu_m,
            mxu_n: p.mxu_n,
        });
    }
    let util = p.mxu_utilization();
    if util < 0.25 {
        errs.push(Illegal::MxuUnderfilled {
            util_pct: (util * 100.0) as usize,
        });
    }
    if errs.is_empty() {
        Ok(())
    } else {
        Err(errs)
    }
}

/// Enumerate the default exploration grid (the BLK bench's axes).
pub fn exploration_grid() -> Vec<KernelParams> {
    exploration_grid_bpe(4)
}

/// The same grid at an arbitrary element width (bf16 doubles the VMEM
/// headroom, so its legal set is larger) — the tuner's block axes.
pub fn exploration_grid_bpe(bytes_per_elem: usize) -> Vec<KernelParams> {
    exploration_grid_w(Width::from_bpe(bytes_per_elem))
}

/// Width-native grid: block/double-buffer/KC axes crossed with the
/// per-width register-block options ([`RegBlock::options`] — one entry
/// at f32, base + wide at 16-bit).
pub fn exploration_grid_w(width: Width) -> Vec<KernelParams> {
    let mut out = Vec::new();
    for &bm in &[16usize, 32, 64, 128, 256, 512] {
        for &bn in &[16usize, 32, 64, 128, 256, 512] {
            for &bk in &[8usize, 16, 32, 64, 128] {
                for &db in &[false, true] {
                    // KC_DEFAULT first: predicted ranking is stable, so
                    // the default chunk wins cost-model ties.
                    for &kc in &[KC_DEFAULT, 64, 256] {
                        // BASE first, same tie-break convention.
                        for &reg in RegBlock::options(width) {
                            let mut p = KernelParams::new_w(
                                BlockShape::new(bm, bn, bk),
                                width,
                            );
                            p.double_buffer = db;
                            p.kc = kc;
                            p.reg = reg;
                            out.push(p);
                        }
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_legal() {
        let p = KernelParams::new(BlockShape::default(), 4);
        assert_eq!(check(&p), Ok(()));
        assert!(p.vmem_bytes() <= VMEM_BUDGET_BYTES);
        assert_eq!(p.mxu_utilization(), 1.0);
    }

    #[test]
    fn report_1024_thread_16x16_config_rejected() {
        // The config the report got to compile but which threw FP errors:
        // block 16x16 per XDL against a full-size MXU tile.
        let p = KernelParams::new(BlockShape::new(16, 16, 64), 4);
        let errs = check(&p).unwrap_err();
        assert!(
            errs.iter()
                .any(|e| matches!(e, Illegal::MxuTileMismatch { .. })),
            "{errs:?}"
        );
    }

    #[test]
    fn vmem_overflow_detected() {
        let p = KernelParams::new(BlockShape::new(1024, 1024, 512), 4);
        let errs = check(&p).unwrap_err();
        assert!(errs
            .iter()
            .any(|e| matches!(e, Illegal::VmemOverflow { .. })));
    }

    #[test]
    fn misalignment_reasons_are_specific() {
        let p = KernelParams::new(BlockShape::new(100, 100, 60), 4);
        let errs = check(&p).unwrap_err();
        let text: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
        assert!(text.iter().any(|t| t.contains("lanes")), "{text:?}");
        assert!(text.iter().any(|t| t.contains("sublanes")), "{text:?}");
    }

    #[test]
    fn majority_of_grid_is_illegal_like_ck() {
        // The report: "we could not get the vast majority of
        // block/hyperparameter adjustments to compile".
        let grid = exploration_grid();
        let legal = grid.iter().filter(|p| check(p).is_ok()).count();
        assert!(legal * 2 < grid.len(), "{legal}/{} legal", grid.len());
        assert!(legal > 0);
    }

    #[test]
    fn kc_axis_is_legality_pruned() {
        // kpack misalignment is a named reason, not a silent skip
        let mut p = KernelParams::new(BlockShape::default(), 4);
        p.kc = 100;
        let errs = check(&p).unwrap_err();
        assert!(
            errs.iter().any(|e| matches!(e, Illegal::KcMisaligned { .. })),
            "{errs:?}"
        );
        // deep chunks on wide blocks blow the pack budget: 2·512·256·4 B
        let mut p = KernelParams::new(BlockShape::new(512, 512, 64), 4);
        p.kc = 256;
        let errs = check(&p).unwrap_err();
        assert!(
            errs.iter().any(|e| matches!(e, Illegal::PackOverflow { .. })),
            "{errs:?}"
        );
        // the same chunk on default blocks is comfortably legal
        let mut p = KernelParams::new(BlockShape::default(), 4);
        p.kc = 256;
        assert_eq!(check(&p), Ok(()));
        // kc == 0 is a zero dimension
        p.kc = 0;
        assert_eq!(check(&p), Err(vec![Illegal::ZeroDim]));
        // the exploration grid enumerates the axis, default first
        let grid = exploration_grid();
        assert_eq!(grid[0].kc, KC_DEFAULT);
        assert!(grid.iter().any(|p| p.kc == 64));
        assert!(grid.iter().any(|p| p.kc == 256));
    }

    #[test]
    fn reg_block_legality_is_width_gated() {
        // f32 is pinned to the baseline block.
        let mut p = KernelParams::new(BlockShape::default(), 4);
        assert_eq!(p.width, Width::F32);
        p.reg = RegBlock::WIDE;
        let errs = check(&p).unwrap_err();
        assert!(
            errs.iter().any(|e| matches!(e, Illegal::RegIllegal { .. })),
            "{errs:?}"
        );
        // The wide block is legal at 16-bit widths…
        for w in [Width::Bf16, Width::F16] {
            let mut p = KernelParams::new_w(BlockShape::default(), w);
            p.reg = RegBlock::WIDE;
            assert_eq!(check(&p), Ok(()), "{w}");
        }
        // …but an arbitrary MR/NR pair has no lane kernel anywhere.
        let mut p = KernelParams::new_w(BlockShape::default(), Width::Bf16);
        p.reg = RegBlock { mr: 3, nr: 5 };
        assert!(check(&p).is_err());
    }

    #[test]
    fn width_grid_crosses_reg_axis_and_widens_the_legal_set() {
        let f32_grid = exploration_grid_w(Width::F32);
        let bf_grid = exploration_grid_w(Width::Bf16);
        // 16-bit widths add exactly one extra reg option per point.
        assert_eq!(bf_grid.len(), 2 * f32_grid.len());
        assert!(f32_grid.iter().all(|p| p.reg == RegBlock::BASE));
        assert!(bf_grid.iter().any(|p| p.reg == RegBlock::WIDE));
        // Halved element bytes double the VMEM headroom → more legal
        // points, never fewer (reg-illegal points aren't in the grid).
        let legal = |g: &[KernelParams]| {
            g.iter().filter(|p| check(p).is_ok()).count()
        };
        assert!(legal(&bf_grid) > legal(&f32_grid));
        // The bpe spelling is the same grid.
        assert_eq!(exploration_grid_bpe(2), bf_grid);
        assert_eq!(exploration_grid_bpe(4), f32_grid);
        // Default-first tie-break holds on the new axis too.
        assert_eq!(bf_grid[0].reg, RegBlock::BASE);
        assert_eq!(bf_grid[0].kc, KC_DEFAULT);
    }

    #[test]
    fn double_buffer_doubles_stream_footprint() {
        let mut p = KernelParams::new(BlockShape::default(), 4);
        p.double_buffer = false;
        let single = p.vmem_bytes();
        p.double_buffer = true;
        let double = p.vmem_bytes();
        let stream = (128 * 64 + 64 * 128) * 4;
        assert_eq!(double - single, stream);
    }
}
