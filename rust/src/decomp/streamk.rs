//! The hybrid Stream-K schedule — rust twin of
//! `python/compile/partition.py` (kept bit-identical by the parity test).
//!
//! See the python module docstring for the algorithm; briefly: with `t`
//! tiles and `P` CUs, the first `max(t/P - 1, 0)·P` tiles are plain
//! data-parallel waves and the trailing `P + t mod P` tiles have their
//! MAC-iteration space split evenly across all `P` CUs, bounding per-CU
//! partial fragments at 2 and eliminating the final-wave quantization
//! loss.

use super::{BlockShape, GemmShape, TileGrid};

/// A contiguous run of MAC iterations one CU spends inside one tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Segment {
    /// Linear tile id (row-major over the tile grid).
    pub tile: usize,
    /// First k-iteration (in BK units) within the tile.
    pub k_start: usize,
    /// Number of k-iterations.
    pub k_len: usize,
    /// Covers the tile's full K range → direct store, no fixup.
    pub direct: bool,
    /// Partial-buffer slot (0|1) when `!direct`, else unused.
    pub slot: usize,
}

/// One CU's contribution to a split tile.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Contributor {
    pub cu: usize,
    pub slot: usize,
    pub k_start: usize,
    pub k_len: usize,
}

/// A tile whose K range is split across CUs; finished by the fixup pass.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SplitTile {
    pub tile: usize,
    pub contributors: Vec<Contributor>,
}

/// Complete static Stream-K schedule for one GEMM problem.
#[derive(Debug, Clone, PartialEq)]
pub struct StreamKSchedule {
    pub shape: GemmShape,
    pub block: BlockShape,
    /// CU / grid-program count.
    pub p: usize,
    pub grid: TileGrid,
    /// Tiles `[0, dp_tiles)` are data-parallel full waves.
    pub dp_tiles: usize,
    /// Tiles `[dp_tiles, num_tiles)` are stream-k.
    pub sk_tiles: usize,
    pub sk_iters: usize,
    /// Uniform whole tiles per CU in the DP region.
    pub dp_tiles_per_cu: usize,
    /// Per-CU SK iteration range `[start, end)` in global iteration ids.
    pub cu_sk_start: Vec<usize>,
    pub cu_sk_end: Vec<usize>,
    /// Per-CU segments, ordered by iteration.
    pub segments: Vec<Vec<Segment>>,
    /// Tiles needing the fixup pass, ascending tile id.
    pub split_tiles: Vec<SplitTile>,
    pub max_segments: usize,
    pub max_contributors: usize,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleError {
    Degenerate(String),
}

impl std::fmt::Display for ScheduleError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ScheduleError::Degenerate(what) => {
                write!(f, "degenerate problem {what:?}")
            }
        }
    }
}

impl std::error::Error for ScheduleError {}

/// Construct the hybrid Stream-K schedule. Pure and total for all
/// non-degenerate inputs; must stay in lock-step with
/// `partition.build_schedule` in python.
pub fn build_schedule(
    shape: GemmShape,
    block: BlockShape,
    p: usize,
) -> Result<StreamKSchedule, ScheduleError> {
    build_schedule_inner(shape, block, p, None)
}

/// Weighted variant for the Block2Time balancer: the whole iteration
/// space is treated as stream-k (no DP region) and CU `i` receives a
/// share of iterations proportional to `weights[i]` (its predicted
/// speed). `weights` must be positive. Not mirrored in python — the
/// Pallas kernel always uses the even split; this feeds the simulator.
pub fn build_weighted_schedule(
    shape: GemmShape,
    block: BlockShape,
    weights: &[f64],
) -> Result<StreamKSchedule, ScheduleError> {
    if weights.is_empty() || weights.iter().any(|&w| !(w > 0.0)) {
        return Err(ScheduleError::Degenerate(format!(
            "bad weights {weights:?}"
        )));
    }
    build_schedule_inner(shape, block, weights.len(), Some(weights))
}

fn build_schedule_inner(
    shape: GemmShape,
    block: BlockShape,
    p: usize,
    weights: Option<&[f64]>,
) -> Result<StreamKSchedule, ScheduleError> {
    if shape.is_degenerate() || p == 0 {
        return Err(ScheduleError::Degenerate(format!("{shape:?} p={p}")));
    }
    let block = block.effective(shape);
    let grid = TileGrid::new(shape, block);
    let num_tiles = grid.num_tiles();
    let ipt = grid.iters_per_tile;

    let w = if weights.is_some() { 0 } else { num_tiles / p };
    let dp_tiles = w.saturating_sub(1) * p;
    let sk_tiles = num_tiles - dp_tiles;
    let sk_iters = sk_tiles * ipt;
    let dp_tiles_per_cu = dp_tiles / p;

    let base = dp_tiles * ipt;
    let (cu_sk_start, cu_sk_end) = match weights {
        None => (
            (0..p).map(|cu| base + (cu * sk_iters) / p).collect(),
            (0..p).map(|cu| base + ((cu + 1) * sk_iters) / p).collect(),
        ),
        Some(ws) => {
            // Largest-remainder apportionment of sk_iters by weight:
            // deterministic, sums exactly, every boundary monotone.
            let total_w: f64 = ws.iter().sum();
            let mut cuts = Vec::with_capacity(p + 1);
            let mut acc = 0.0;
            cuts.push(0usize);
            for &wi in ws.iter().take(p - 1) {
                acc += wi;
                cuts.push(
                    ((acc / total_w) * sk_iters as f64).round() as usize,
                );
            }
            cuts.push(sk_iters);
            for i in 1..cuts.len() {
                if cuts[i] < cuts[i - 1] {
                    cuts[i] = cuts[i - 1];
                }
            }
            (
                (0..p).map(|cu| base + cuts[cu]).collect::<Vec<_>>(),
                (0..p).map(|cu| base + cuts[cu + 1]).collect::<Vec<_>>(),
            )
        }
    };

    let mut segments: Vec<Vec<Segment>> = Vec::with_capacity(p);
    // tile -> contributors, gathered in CU order then sorted by k_start.
    let mut fragments: Vec<(usize, Contributor)> = Vec::new();
    for cu in 0..p {
        let mut segs = Vec::new();
        let (mut it, end) = (cu_sk_start[cu], cu_sk_end[cu]);
        let mut n_partials = 0usize;
        while it < end {
            let tile = it / ipt;
            let tile_end = (tile + 1) * ipt;
            let seg_end = end.min(tile_end);
            let k_start = it - tile * ipt;
            let k_len = seg_end - it;
            let direct = k_len == ipt;
            let slot = if direct {
                usize::MAX
            } else {
                let s = n_partials;
                n_partials += 1;
                debug_assert!(s <= 1, "hybrid schedule bounds partials at 2/CU");
                fragments.push((
                    tile,
                    Contributor { cu, slot: s, k_start, k_len },
                ));
                s
            };
            segs.push(Segment {
                tile,
                k_start,
                k_len,
                direct,
                slot: if direct { 0 } else { slot },
            });
            it = seg_end;
        }
        segments.push(segs);
    }

    fragments.sort_by_key(|(tile, c)| (*tile, c.k_start));
    let mut split_tiles: Vec<SplitTile> = Vec::new();
    for (tile, c) in fragments {
        match split_tiles.last_mut() {
            Some(st) if st.tile == tile => st.contributors.push(c),
            _ => split_tiles.push(SplitTile { tile, contributors: vec![c] }),
        }
    }
    // Invariant: each split tile's contributors partition [0, ipt).
    for st in &split_tiles {
        let mut cov = 0;
        for c in &st.contributors {
            debug_assert_eq!(c.k_start, cov, "non-contiguous fixup coverage");
            cov += c.k_len;
        }
        debug_assert_eq!(cov, ipt, "fixup does not cover tile {}", st.tile);
    }

    let max_segments = segments.iter().map(Vec::len).max().unwrap_or(0);
    let max_contributors =
        split_tiles.iter().map(|s| s.contributors.len()).max().unwrap_or(0);

    Ok(StreamKSchedule {
        shape,
        block,
        p,
        grid,
        dp_tiles,
        sk_tiles,
        sk_iters,
        dp_tiles_per_cu,
        cu_sk_start,
        cu_sk_end,
        segments,
        split_tiles,
        max_segments,
        max_contributors,
    })
}

impl StreamKSchedule {
    /// DP tiles owned by `cu` (wave-strided assignment).
    pub fn direct_tiles(&self, cu: usize) -> impl Iterator<Item = usize> + '_ {
        (0..self.dp_tiles_per_cu).map(move |wave| wave * self.p + cu)
    }

    /// Total MAC iterations CU `cu` executes (DP quota + SK share).
    pub fn cu_iters(&self, cu: usize) -> usize {
        self.dp_tiles_per_cu * self.grid.iters_per_tile
            + (self.cu_sk_end[cu] - self.cu_sk_start[cu])
    }

    /// Utilization of a pure data-parallel schedule (Figure 1's metric).
    pub fn quantization_efficiency_dp(&self) -> f64 {
        super::occupancy::dp_efficiency(self.grid.num_tiles(), self.p)
    }

    /// Utilization of this hybrid schedule (bounded by ±1 MAC iteration
    /// of imbalance per CU).
    pub fn quantization_efficiency_sk(&self) -> f64 {
        let max_iters =
            (0..self.p).map(|cu| self.cu_iters(cu)).max().unwrap_or(0);
        if max_iters == 0 {
            return 1.0;
        }
        self.grid.total_iters() as f64 / (max_iters * self.p) as f64
    }

    /// Workspace bytes for the partials buffer (P × 2 × BM × BN × f32) —
    /// the fixed-size Stream-K workspace vs Split-K's O(S·M·N).
    pub fn partials_bytes(&self) -> usize {
        self.p * 2 * self.block.bm * self.block.bn * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    fn sched(m: usize, n: usize, k: usize, p: usize) -> StreamKSchedule {
        build_schedule(GemmShape::new(m, n, k), BlockShape::default(), p)
            .expect("valid schedule")
    }

    #[test]
    fn table1_baseline_regimes() {
        let s = sched(3840, 4096, 4096, 120);
        assert_eq!(s.grid.num_tiles(), 960);
        assert_eq!(s.dp_tiles, 840);
        assert_eq!(s.sk_tiles, 120);
        assert_eq!(s.dp_tiles_per_cu, 7);
        // 960 % 120 == 0 and sk split is tile-aligned: no fixup needed.
        assert!(s.split_tiles.is_empty());
        assert!(s.quantization_efficiency_sk() > 0.999);
    }

    #[test]
    fn small_matrix_single_iteration() {
        let s = sched(3, 9, 9, 120);
        assert_eq!(s.grid.num_tiles(), 1);
        assert_eq!(s.grid.iters_per_tile, 1);
        assert_eq!(s.dp_tiles, 0);
        // one CU does the single iteration, the rest idle
        let busy: Vec<usize> =
            (0..120).filter(|&cu| s.cu_iters(cu) > 0).collect();
        assert_eq!(busy.len(), 1);
        assert!(s.split_tiles.is_empty());
    }

    #[test]
    fn ragged_shape_has_fixups() {
        // 64 tiles on 120 CUs: pure-SK regime, shares are not
        // tile-aligned, so fixup tiles must exist.
        let s = sched(1000, 1000, 1000, 120);
        assert!(s.grid.num_tiles() > 0);
        assert!(!s.split_tiles.is_empty());
        // every split tile is in the SK region
        for st in &s.split_tiles {
            assert!(st.tile >= s.dp_tiles);
        }
    }

    #[test]
    fn single_cu_degenerates_to_serial() {
        let s = sched(512, 512, 512, 1);
        assert_eq!(s.cu_iters(0), s.grid.total_iters());
        assert!(s.split_tiles.is_empty()); // one CU never splits a tile
    }

    #[test]
    fn rejects_degenerate() {
        assert!(build_schedule(
            GemmShape::new(0, 1, 1),
            BlockShape::default(),
            4
        )
        .is_err());
        assert!(build_schedule(
            GemmShape::new(1, 1, 1),
            BlockShape::default(),
            0
        )
        .is_err());
    }

    #[test]
    fn weighted_schedule_follows_weights() {
        let shape = GemmShape::new(2048, 2048, 2048);
        let ws = vec![1.0, 1.0, 2.0, 4.0];
        let s = build_weighted_schedule(shape, BlockShape::default(), &ws)
            .unwrap();
        assert_eq!(s.dp_tiles, 0);
        let sizes: Vec<usize> =
            (0..4).map(|cu| s.cu_sk_end[cu] - s.cu_sk_start[cu]).collect();
        assert_eq!(sizes.iter().sum::<usize>(), s.grid.total_iters());
        // CU 3 gets ~4x CU 0's share.
        let r = sizes[3] as f64 / sizes[0] as f64;
        assert!((r - 4.0).abs() < 0.2, "ratio {r}");
        // Still at most 2 partial fragments per CU.
        for segs in &s.segments {
            assert!(segs.iter().filter(|g| !g.direct).count() <= 2);
        }
    }

    #[test]
    fn weighted_rejects_bad_weights() {
        let shape = GemmShape::new(128, 128, 128);
        assert!(build_weighted_schedule(shape, BlockShape::default(), &[])
            .is_err());
        assert!(build_weighted_schedule(
            shape,
            BlockShape::default(),
            &[1.0, 0.0]
        )
        .is_err());
        assert!(build_weighted_schedule(
            shape,
            BlockShape::default(),
            &[1.0, f64::NAN]
        )
        .is_err());
    }

    /// Exhaustive invariants over random problems — the rust twin of
    /// python's `test_schedule_invariants`.
    #[test]
    fn prop_schedule_invariants() {
        prop::check("streamk-schedule-invariants", 120, |rng| {
            let m = rng.usize_in(1, 3000);
            let n = rng.usize_in(1, 3000);
            let k = rng.usize_in(1, 3000);
            let p = *rng.choose(&[1usize, 2, 7, 64, 104, 120, 301]);
            let bm = *rng.choose(&[32usize, 128]);
            let bn = *rng.choose(&[32usize, 128]);
            let bk = *rng.choose(&[16usize, 64]);
            let s = build_schedule(
                GemmShape::new(m, n, k),
                BlockShape::new(bm, bn, bk),
                p,
            )
            .map_err(|e| e.to_string())?;
            let ipt = s.grid.iters_per_tile;

            // Every MAC iteration assigned exactly once.
            let total = s.grid.total_iters();
            let mut owned = vec![false; total];
            let mut claim = |it: usize| -> prop::CaseResult {
                if owned[it] {
                    return Err(format!("iteration {it} double-assigned"));
                }
                owned[it] = true;
                Ok(())
            };
            for cu in 0..p {
                for tile in s.direct_tiles(cu) {
                    for j in 0..ipt {
                        claim(tile * ipt + j)?;
                    }
                }
                for g in &s.segments[cu] {
                    for j in 0..g.k_len {
                        claim(g.tile * ipt + g.k_start + j)?;
                    }
                }
            }
            prop::ensure(
                owned.iter().all(|&o| o),
                "some iteration unassigned",
            )?;

            // Balanced SK split.
            let sizes: Vec<usize> = (0..p)
                .map(|cu| s.cu_sk_end[cu] - s.cu_sk_start[cu])
                .collect();
            let (mn, mx) = (
                *sizes.iter().min().unwrap(),
                *sizes.iter().max().unwrap(),
            );
            prop::ensure(mx - mn <= 1, format!("imbalance {mn}..{mx}"))?;
            prop::ensure_eq(
                sizes.iter().sum::<usize>(),
                s.sk_iters,
                "sk iters total",
            )?;

            // Partial slots bounded at 2 per CU; segments bounded at 4.
            prop::ensure(s.max_segments <= 4, "max_segments > 4")?;
            for segs in &s.segments {
                let partials =
                    segs.iter().filter(|g| !g.direct).count();
                prop::ensure(partials <= 2, "more than 2 partials")?;
            }

            // Split tiles ∪ direct SK tiles == SK region, disjoint.
            let mut kind = vec![0u8; s.grid.num_tiles()]; // 1=direct 2=split
            for segs in &s.segments {
                for g in segs.iter().filter(|g| g.direct) {
                    if kind[g.tile] != 0 {
                        return Err(format!("tile {} double kind", g.tile));
                    }
                    kind[g.tile] = 1;
                }
            }
            for st in &s.split_tiles {
                if kind[st.tile] != 0 {
                    return Err(format!("tile {} double kind", st.tile));
                }
                kind[st.tile] = 2;
                let mut cov = 0;
                for c in &st.contributors {
                    prop::ensure_eq(c.k_start, cov, "contig coverage")?;
                    cov += c.k_len;
                }
                prop::ensure_eq(cov, ipt, "full coverage")?;
            }
            for t in s.dp_tiles..s.grid.num_tiles() {
                prop::ensure(kind[t] != 0, format!("sk tile {t} unhandled"))?;
            }

            // Hybrid never worse than pure DP.
            prop::ensure(
                s.quantization_efficiency_sk()
                    >= s.quantization_efficiency_dp() - 1e-12,
                "hybrid worse than DP",
            )
        });
    }
}
