//! Fixed Split-K decomposition — the second baseline. Each tile's K loop
//! is cut into `splits` balanced chunks; chunk (tile, s) is an
//! independent workgroup, and a reduction pass sums the `splits` partial
//! C buffers.

use super::tile::WorkItem;
use super::TileGrid;

/// Per-CU work list for a Split-K launch of `tiles × splits` workgroups,
/// wave-strided like a real grid dispatch.
pub fn splitk_assignment(
    grid: TileGrid,
    p: usize,
    splits: usize,
) -> Vec<Vec<WorkItem>> {
    assert!(p > 0);
    let splits = splits.clamp(1, grid.iters_per_tile.max(1));
    let ipt = grid.iters_per_tile;
    let mut cus = vec![Vec::new(); p];
    let mut wg = 0usize;
    for tile in 0..grid.num_tiles() {
        for s in 0..splits {
            let k_lo = s * ipt / splits;
            let k_hi = (s + 1) * ipt / splits;
            cus[wg % p].push(WorkItem {
                tile,
                k_iters: k_hi - k_lo,
                partial: splits > 1,
            });
            wg += 1;
        }
    }
    cus
}

/// Extra HBM traffic of the reduction pass, in C-sized buffers: Split-K
/// writes `splits` partial Cs and reads them back once.
pub fn reduction_traffic_factor(splits: usize) -> f64 {
    if splits <= 1 {
        0.0
    } else {
        2.0 * splits as f64
    }
}

/// Effective parallelism: workgroups available vs CUs.
pub fn splitk_efficiency(grid: TileGrid, p: usize, splits: usize) -> f64 {
    let splits = splits.clamp(1, grid.iters_per_tile.max(1));
    super::occupancy::dp_efficiency(grid.num_tiles() * splits, p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{BlockShape, GemmShape};
    use crate::prop;

    fn grid(tm: usize, tn: usize, ipt: usize) -> TileGrid {
        TileGrid::new(
            GemmShape::new(tm * 128, tn * 128, ipt * 64),
            BlockShape::default(),
        )
    }

    #[test]
    fn chunks_partition_k() {
        let g = grid(1, 1, 10);
        let cus = splitk_assignment(g, 3, 4);
        let total: usize =
            cus.iter().flatten().map(|w| w.k_iters).sum();
        assert_eq!(total, 10);
        assert!(cus.iter().flatten().all(|w| w.partial));
    }

    #[test]
    fn splits_clamped_to_depth() {
        let g = grid(2, 2, 2); // only 2 k-iters
        let cus = splitk_assignment(g, 4, 100);
        let per_tile: usize =
            cus.iter().flatten().filter(|w| w.tile == 0).count();
        assert_eq!(per_tile, 2);
    }

    #[test]
    fn split1_equals_dp_shape() {
        let g = grid(3, 3, 4);
        let cus = splitk_assignment(g, 4, 1);
        assert!(cus.iter().flatten().all(|w| !w.partial && w.k_iters == 4));
        assert_eq!(
            cus.iter().flatten().count(),
            g.num_tiles()
        );
    }

    #[test]
    fn prop_splitk_covers_all_iterations() {
        prop::check("splitk covers iter space", 60, |rng| {
            let g = grid(
                rng.usize_in(1, 12),
                rng.usize_in(1, 12),
                rng.usize_in(1, 40),
            );
            let p = rng.usize_in(1, 64);
            let splits = rng.usize_in(1, 12);
            let cus = splitk_assignment(g, p, splits);
            let mut per_tile = vec![0usize; g.num_tiles()];
            for w in cus.iter().flatten() {
                per_tile[w.tile] += w.k_iters;
            }
            prop::ensure(
                per_tile.iter().all(|&it| it == g.iters_per_tile),
                "tile k coverage broken",
            )
        });
    }

    #[test]
    fn efficiency_improves_with_splits_on_small_grids() {
        let g = grid(2, 2, 16); // 4 tiles on 120 CUs: 3.3% DP efficiency
        let e1 = splitk_efficiency(g, 120, 1);
        let e8 = splitk_efficiency(g, 120, 8);
        assert!(e8 > e1 * 5.0, "e1={e1} e8={e8}");
    }
}
