//! Arithmetic-intensity and roofline analysis.
//!
//! The report measured AI = 1337 FLOP/byte for its workload and concluded
//! the kernel is compute-bound on the MI200. This module reproduces that
//! measurement analytically and generalizes it into the roofline model
//! the AI bench sweeps.

use super::GemmShape;

/// FLOPs per byte of minimum HBM traffic for `C = A·B`:
/// `2·M·N·K / (bytes·(M·K + K·N + M·N))`.
pub fn arithmetic_intensity(shape: GemmShape, bytes_per_elem: usize) -> f64 {
    let flops = shape.flops() as f64;
    let bytes = (bytes_per_elem
        * (shape.m * shape.k + shape.k * shape.n + shape.m * shape.n))
        as f64;
    if bytes == 0.0 {
        return 0.0;
    }
    flops / bytes
}

/// Operand-only variant (A and B read once, C ignored) — the convention
/// some rocprof-derived metrics use; reported alongside the full-traffic
/// number by the AI bench.
pub fn operand_intensity(shape: GemmShape, bytes_per_elem: usize) -> f64 {
    let flops = shape.flops() as f64;
    let bytes =
        (bytes_per_elem * (shape.m * shape.k + shape.k * shape.n)) as f64;
    if bytes == 0.0 {
        return 0.0;
    }
    flops / bytes
}

/// Device roofline parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Roofline {
    pub peak_flops: f64,
    pub mem_bw: f64,
}

/// MI250X single-GCD numbers (the report's testbed class):
/// ~45 TFLOP/s fp32-equivalent matrix throughput, 1.6 TB/s HBM.
pub const MI200: Roofline = Roofline { peak_flops: 45.0e12, mem_bw: 1.6e12 };

/// One XLA-CPU core of this testbed (measured empirically by the bench
/// harness; this constant is only the documentation default).
pub const CPU_1CORE: Roofline = Roofline { peak_flops: 5.0e9, mem_bw: 2.0e10 };

impl Roofline {
    /// AI at which the device transitions memory- → compute-bound.
    pub fn ridge_point(&self) -> f64 {
        self.peak_flops / self.mem_bw
    }

    /// Attainable FLOP/s at a given arithmetic intensity.
    pub fn attainable(&self, ai: f64) -> f64 {
        (ai * self.mem_bw).min(self.peak_flops)
    }

    /// Is a kernel with this AI compute-bound on this device?
    pub fn compute_bound(&self, ai: f64) -> bool {
        ai >= self.ridge_point()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_ai_1337() {
        // The report's "arithmetic intensity of 1337": the Table-1
        // baseline shape (3840×4096×4096) at fp16 with full A+B+C
        // traffic gives 1335.65 — within 0.1% of the reported figure.
        let shape = GemmShape::new(3840, 4096, 4096);
        let ai = arithmetic_intensity(shape, 2);
        assert!((ai - 1337.0).abs() / 1337.0 < 0.002, "ai={ai}");
        assert!(operand_intensity(shape, 2) > ai);
    }

    #[test]
    fn square_gemm_intensity_grows_linearly() {
        let ai_1k = arithmetic_intensity(GemmShape::new(1024, 1024, 1024), 4);
        let ai_2k = arithmetic_intensity(GemmShape::new(2048, 2048, 2048), 4);
        assert!((ai_2k / ai_1k - 2.0).abs() < 0.01);
        // n×n×n fp32: AI = 2n³/(4·3n²) = n/6
        assert!((ai_1k - 1024.0 / 6.0).abs() < 1e-9);
    }

    #[test]
    fn mi200_is_compute_bound_for_report_workload() {
        let ai = arithmetic_intensity(GemmShape::new(30840, 4096, 4096), 4);
        assert!(MI200.compute_bound(ai));
        assert_eq!(MI200.attainable(ai), MI200.peak_flops);
    }

    #[test]
    fn tiny_gemm_is_memory_bound() {
        let ai = arithmetic_intensity(GemmShape::new(3, 9, 9), 4);
        assert!(!MI200.compute_bound(ai));
        assert!(MI200.attainable(ai) < MI200.peak_flops);
    }

    #[test]
    fn ridge_point() {
        assert!((MI200.ridge_point() - 28.125).abs() < 1e-9);
    }
}
