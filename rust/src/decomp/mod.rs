//! GEMM work decomposition — the paper's core subject.
//!
//! Three decompositions over the same MAC-iteration space
//! (`tiles × k-iterations`):
//!
//! - [`tile`] — conventional data-parallel: one workgroup per output
//!   tile (Figure 1's quantization-inefficient baseline);
//! - [`splitk`] — fixed K-split: each tile's K loop cut into a constant
//!   number of chunks;
//! - [`streamk`] — the work-centric hybrid: even MAC-iteration split
//!   across CUs with a two-slot partial buffer and a static fixup
//!   schedule. Bit-identical to `python/compile/partition.py`
//!   (enforced by `tests/partition_parity.rs`).
//!
//! [`flat`] re-expresses a built schedule as contiguous CSR-style arenas
//! ([`FlatSchedule`]) — the zero-allocation serving form consumed by the
//! simulator, the plan cache ([`crate::plan`]), and the interpreter
//! runtime.
//!
//! Plus the report's analytical tools: [`occupancy`] (Figure 1),
//! [`intensity`] (the AI=1337 measurement), [`params`] (the block-size
//! legality space CK made impenetrable), and [`swizzle`] (Block2CTile
//! mappings, where the report located the compute-unit bug).

pub mod flat;
pub mod intensity;
pub mod occupancy;
pub mod params;
pub mod splitk;
pub mod streamk;
pub mod swizzle;
pub mod tile;

pub use flat::FlatSchedule;
pub use streamk::{
    build_schedule, build_weighted_schedule, Contributor, Segment, SplitTile,
    StreamKSchedule,
};

/// Ceiling division.
#[inline]
pub fn cdiv(a: usize, b: usize) -> usize {
    a.div_ceil(b)
}

/// GEMM problem shape: `C[m,n] = A[m,k] @ B[k,n]`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct GemmShape {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl GemmShape {
    pub fn new(m: usize, n: usize, k: usize) -> Self {
        Self { m, n, k }
    }

    /// Multiply–accumulate FLOPs (2·M·N·K).
    pub fn flops(&self) -> u64 {
        2 * self.m as u64 * self.n as u64 * self.k as u64
    }

    pub fn is_degenerate(&self) -> bool {
        self.m == 0 || self.n == 0 || self.k == 0
    }
}

/// Kernel tile shape (BM × BN output tile, BK-deep MAC step).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct BlockShape {
    pub bm: usize,
    pub bn: usize,
    pub bk: usize,
}

impl Default for BlockShape {
    /// The single Stream-K configuration per precision (f32): MXU-aligned
    /// 128×128 tile, 64-deep MAC step.
    fn default() -> Self {
        Self { bm: 128, bn: 128, bk: 64 }
    }
}

impl BlockShape {
    pub fn new(bm: usize, bn: usize, bk: usize) -> Self {
        Self { bm, bn, bk }
    }

    /// Shrink to the problem (`dim < block` ⇒ block = dim), mirroring
    /// `kernels/common.py::effective_blocks`.
    pub fn effective(&self, shape: GemmShape) -> BlockShape {
        BlockShape {
            bm: self.bm.min(shape.m.max(1)),
            bn: self.bn.min(shape.n.max(1)),
            bk: self.bk.min(shape.k.max(1)),
        }
    }

    pub fn flops_per_iter(&self) -> u64 {
        2 * self.bm as u64 * self.bn as u64 * self.bk as u64
    }
}

/// Tile grid derived from a shape and block.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileGrid {
    pub tiles_m: usize,
    pub tiles_n: usize,
    pub iters_per_tile: usize,
}

impl TileGrid {
    pub fn new(shape: GemmShape, block: BlockShape) -> Self {
        Self {
            tiles_m: cdiv(shape.m, block.bm),
            tiles_n: cdiv(shape.n, block.bn),
            iters_per_tile: cdiv(shape.k, block.bk),
        }
    }

    pub fn num_tiles(&self) -> usize {
        self.tiles_m * self.tiles_n
    }

    pub fn total_iters(&self) -> usize {
        self.num_tiles() * self.iters_per_tile
    }

    /// Linear tile id → (row, col) under the default row-major mapping.
    pub fn tile_rc(&self, tile: usize) -> (usize, usize) {
        (tile / self.tiles_n, tile % self.tiles_n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cdiv_basics() {
        assert_eq!(cdiv(10, 3), 4);
        assert_eq!(cdiv(9, 3), 3);
        assert_eq!(cdiv(1, 128), 1);
    }

    #[test]
    fn tile_grid_matches_table1_baseline() {
        let g = TileGrid::new(
            GemmShape::new(3840, 4096, 4096),
            BlockShape::default(),
        );
        assert_eq!((g.tiles_m, g.tiles_n), (30, 32));
        assert_eq!(g.num_tiles(), 960);
        assert_eq!(g.iters_per_tile, 64);
        assert_eq!(g.total_iters(), 61_440);
    }

    #[test]
    fn effective_blocks_shrink() {
        let b = BlockShape::default().effective(GemmShape::new(3, 9, 9));
        assert_eq!((b.bm, b.bn, b.bk), (3, 9, 9));
    }

    #[test]
    fn flops() {
        assert_eq!(GemmShape::new(2, 3, 4).flops(), 48);
        assert_eq!(BlockShape::new(2, 3, 4).flops_per_iter(), 48);
    }
}
