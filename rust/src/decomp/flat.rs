//! Flattened (CSR-style) Stream-K schedule — the zero-rebuild serving
//! representation.
//!
//! [`super::StreamKSchedule`] nests its work lists (`Vec<Vec<Segment>>`,
//! `Vec<SplitTile>` each owning a `Vec<Contributor>`), which is the right
//! shape for *construction* but the wrong shape for *serving*: every
//! simulated launch, every tuner measurement, and every fleet placement
//! that replays the schedule walks (and historically rebuilt) a pile of
//! small heap allocations. [`FlatSchedule`] stores the same schedule as
//! four contiguous arenas plus per-CU / per-tile offset arrays, so
//! consumers iterate plain slices and a cached plan can be replayed with
//! zero allocation.
//!
//! The flattening is *bit-identical* to the nested form: every
//! [`WorkItem`], [`Segment`] and [`Contributor`] round-trips exactly
//! (property-tested below), and the per-CU work items reproduce, element
//! for element, the lists `gpu_sim::gemm::simulate_streamk` used to build
//! inline — including the fixup launch's round-robin CU assignment — so
//! simulated timings are unchanged.

use super::streamk::{Contributor, Segment, StreamKSchedule};
use super::tile::WorkItem;
use super::TileGrid;

/// One Stream-K schedule as contiguous arenas + CSR offsets.
#[derive(Debug, Clone, PartialEq)]
pub struct FlatSchedule {
    /// CU / grid-program count.
    pub p: usize,
    pub grid: TileGrid,
    /// Uniform whole tiles per CU in the DP region (wave-strided:
    /// CU `c` owns tiles `c, c+p, …`).
    pub dp_tiles_per_cu: usize,
    /// Phase-1 work items (DP quota then SK segments), grouped by CU.
    pub items: Vec<WorkItem>,
    /// `items[item_offsets[cu]..item_offsets[cu + 1]]` is CU `cu`'s list.
    pub item_offsets: Vec<usize>,
    /// SK segments (with k-ranges — what the executors need), by CU.
    pub segments: Vec<Segment>,
    pub seg_offsets: Vec<usize>,
    /// Fixup-launch work items, grouped by CU (empty ⇒ no fixup launch).
    pub fixup_items: Vec<WorkItem>,
    pub fixup_offsets: Vec<usize>,
    /// Tiles needing the fixup pass, ascending tile id.
    pub split_tiles: Vec<usize>,
    /// Contributors per split tile, in fixup-sum order.
    pub contributors: Vec<Contributor>,
    pub contrib_offsets: Vec<usize>,
}

impl FlatSchedule {
    /// Flatten a nested schedule. Pure restructuring — no work item is
    /// added, dropped, or reordered.
    pub fn from_schedule(s: &StreamKSchedule) -> Self {
        let p = s.p;
        let ipt = s.grid.iters_per_tile;

        // Phase-1 items: exactly the per-CU lists the simulator replays
        // (DP quota first, then the SK segments, in segment order).
        let mut items = Vec::new();
        let mut item_offsets = Vec::with_capacity(p + 1);
        let mut segments = Vec::new();
        let mut seg_offsets = Vec::with_capacity(p + 1);
        item_offsets.push(0);
        seg_offsets.push(0);
        for cu in 0..p {
            for tile in s.direct_tiles(cu) {
                items.push(WorkItem { tile, k_iters: ipt, partial: false });
            }
            for g in &s.segments[cu] {
                items.push(WorkItem {
                    tile: g.tile,
                    k_iters: g.k_len,
                    partial: !g.direct,
                });
                segments.push(*g);
            }
            item_offsets.push(items.len());
            seg_offsets.push(segments.len());
        }

        // Fixup items: split tile `i` lands on CU `i % p` (one store item
        // plus one partial-read item per contributor) — the same
        // round-robin grouping the simulator's fixup launch used, so the
        // per-CU byte-accumulation order is unchanged.
        let mut split_tiles = Vec::with_capacity(s.split_tiles.len());
        let mut contributors = Vec::new();
        let mut contrib_offsets = Vec::with_capacity(s.split_tiles.len() + 1);
        contrib_offsets.push(0);
        let mut fix_nested: Vec<Vec<WorkItem>> = vec![Vec::new(); p];
        for (i, st) in s.split_tiles.iter().enumerate() {
            split_tiles.push(st.tile);
            contributors.extend_from_slice(&st.contributors);
            contrib_offsets.push(contributors.len());
            let cu = i % p;
            fix_nested[cu].push(WorkItem {
                tile: st.tile,
                k_iters: 0,
                partial: false,
            });
            for _ in &st.contributors {
                fix_nested[cu].push(WorkItem {
                    tile: st.tile,
                    k_iters: 0,
                    partial: true,
                });
            }
        }
        let (mut fixup_items, mut fixup_offsets) = (Vec::new(), Vec::new());
        if !split_tiles.is_empty() {
            fixup_offsets.push(0);
            for cu_items in &fix_nested {
                fixup_items.extend_from_slice(cu_items);
                fixup_offsets.push(fixup_items.len());
            }
        }

        Self {
            p,
            grid: s.grid,
            dp_tiles_per_cu: s.dp_tiles_per_cu,
            items,
            item_offsets,
            segments,
            seg_offsets,
            fixup_items,
            fixup_offsets,
            split_tiles,
            contributors,
            contrib_offsets,
        }
    }

    /// Phase-1 work items of one CU.
    #[inline]
    pub fn cu_items(&self, cu: usize) -> &[WorkItem] {
        &self.items[self.item_offsets[cu]..self.item_offsets[cu + 1]]
    }

    /// SK segments of one CU (k-range detail).
    #[inline]
    pub fn cu_segments(&self, cu: usize) -> &[Segment] {
        &self.segments[self.seg_offsets[cu]..self.seg_offsets[cu + 1]]
    }

    /// Fixup-launch items of one CU (empty slice when no fixup launch).
    #[inline]
    pub fn cu_fixup_items(&self, cu: usize) -> &[WorkItem] {
        if self.fixup_offsets.is_empty() {
            return &[];
        }
        &self.fixup_items[self.fixup_offsets[cu]..self.fixup_offsets[cu + 1]]
    }

    /// Contributors of split tile `i` (index into [`Self::split_tiles`]).
    #[inline]
    pub fn tile_contributors(&self, i: usize) -> &[Contributor] {
        &self.contributors[self.contrib_offsets[i]..self.contrib_offsets[i + 1]]
    }

    /// DP tiles owned by `cu` (wave-strided), mirroring
    /// [`StreamKSchedule::direct_tiles`].
    pub fn direct_tiles(&self, cu: usize) -> impl Iterator<Item = usize> + '_ {
        (0..self.dp_tiles_per_cu).map(move |wave| wave * self.p + cu)
    }

    /// Whether a fixup launch exists.
    #[inline]
    pub fn has_fixup(&self) -> bool {
        !self.split_tiles.is_empty()
    }

    /// Total phase-1 work items.
    pub fn num_items(&self) -> usize {
        self.items.len()
    }

    /// Reconstruct the nested per-CU phase-1 work lists (tests; the
    /// round-trip the flattening must survive bit-identically).
    pub fn nested_items(&self) -> Vec<Vec<WorkItem>> {
        (0..self.p).map(|cu| self.cu_items(cu).to_vec()).collect()
    }

    /// Reconstruct the nested per-CU fixup work lists.
    pub fn nested_fixup_items(&self) -> Vec<Vec<WorkItem>> {
        (0..self.p).map(|cu| self.cu_fixup_items(cu).to_vec()).collect()
    }

    /// Reconstruct the nested per-CU segment lists.
    pub fn nested_segments(&self) -> Vec<Vec<Segment>> {
        (0..self.p).map(|cu| self.cu_segments(cu).to_vec()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{build_schedule, BlockShape, GemmShape};
    use crate::prop;

    /// The nested per-CU work list `simulate_streamk` historically built
    /// inline — the reference the flat form must reproduce exactly.
    fn reference_items(s: &StreamKSchedule) -> Vec<Vec<WorkItem>> {
        (0..s.p)
            .map(|cu| {
                let mut items: Vec<WorkItem> = s
                    .direct_tiles(cu)
                    .map(|tile| WorkItem {
                        tile,
                        k_iters: s.grid.iters_per_tile,
                        partial: false,
                    })
                    .collect();
                items.extend(s.segments[cu].iter().map(|g| WorkItem {
                    tile: g.tile,
                    k_iters: g.k_len,
                    partial: !g.direct,
                }));
                items
            })
            .collect()
    }

    fn reference_fixup(s: &StreamKSchedule) -> Vec<Vec<WorkItem>> {
        let mut fix: Vec<Vec<WorkItem>> = vec![Vec::new(); s.p];
        for (i, st) in s.split_tiles.iter().enumerate() {
            fix[i % s.p].push(WorkItem {
                tile: st.tile,
                k_iters: 0,
                partial: false,
            });
            for _ in &st.contributors {
                fix[i % s.p].push(WorkItem {
                    tile: st.tile,
                    k_iters: 0,
                    partial: true,
                });
            }
        }
        fix
    }

    #[test]
    fn flatten_matches_nested_on_known_shapes() {
        for (m, n, k, p) in [
            (3840usize, 4096usize, 4096usize, 120usize), // Table-1 baseline
            (1000, 1000, 1000, 120),                     // ragged, fixups
            (3, 9, 9, 120),                              // tiny
            (512, 512, 512, 1),                          // serial
        ] {
            let s = build_schedule(
                GemmShape::new(m, n, k),
                BlockShape::default(),
                p,
            )
            .unwrap();
            let f = FlatSchedule::from_schedule(&s);
            assert_eq!(f.nested_items(), reference_items(&s));
            assert_eq!(f.nested_segments(), s.segments);
            if s.split_tiles.is_empty() {
                assert!(!f.has_fixup());
                assert!(f.fixup_items.is_empty());
            } else {
                assert_eq!(f.nested_fixup_items(), reference_fixup(&s));
            }
        }
    }

    /// Satellite acceptance: the flat schedule round-trips bit-identically
    /// — every Segment / WorkItem / Contributor equal — over random
    /// problems, blocks and CU counts.
    #[test]
    fn prop_flat_round_trips_bit_identically() {
        prop::check("flat schedule round-trip", 120, |rng| {
            let m = rng.usize_in(1, 3000);
            let n = rng.usize_in(1, 3000);
            let k = rng.usize_in(1, 3000);
            let p = *rng.choose(&[1usize, 2, 7, 64, 104, 120, 301]);
            let bm = *rng.choose(&[32usize, 128]);
            let bn = *rng.choose(&[32usize, 128]);
            let bk = *rng.choose(&[16usize, 64]);
            let s = build_schedule(
                GemmShape::new(m, n, k),
                BlockShape::new(bm, bn, bk),
                p,
            )
            .map_err(|e| e.to_string())?;
            let f = FlatSchedule::from_schedule(&s);

            prop::ensure_eq(f.p, s.p, "p")?;
            prop::ensure_eq(f.dp_tiles_per_cu, s.dp_tiles_per_cu, "dp/cu")?;
            // phase-1 items == the simulator's historical nested lists
            prop::ensure(
                f.nested_items() == reference_items(&s),
                "phase-1 items differ",
            )?;
            // segments round-trip (slice views, then nested)
            for cu in 0..s.p {
                prop::ensure(
                    f.cu_segments(cu) == s.segments[cu].as_slice(),
                    format!("cu {cu} segments differ"),
                )?;
            }
            // split tiles + contributors round-trip
            prop::ensure_eq(
                f.split_tiles.len(),
                s.split_tiles.len(),
                "split tile count",
            )?;
            for (i, st) in s.split_tiles.iter().enumerate() {
                prop::ensure_eq(f.split_tiles[i], st.tile, "split tile id")?;
                prop::ensure(
                    f.tile_contributors(i) == st.contributors.as_slice(),
                    format!("tile {} contributors differ", st.tile),
                )?;
            }
            // fixup grouping == the simulator's historical round-robin
            prop::ensure(
                f.nested_fixup_items() == reference_fixup(&s),
                "fixup items differ",
            )?;
            // offsets are monotone CSR rows covering the arenas
            prop::ensure_eq(f.item_offsets.len(), s.p + 1, "item offsets")?;
            prop::ensure_eq(
                *f.item_offsets.last().unwrap(),
                f.items.len(),
                "item arena covered",
            )?;
            prop::ensure(
                f.item_offsets.windows(2).all(|w| w[0] <= w[1]),
                "item offsets monotone",
            )?;
            prop::ensure(
                f.seg_offsets.windows(2).all(|w| w[0] <= w[1]),
                "seg offsets monotone",
            )
        });
    }

    #[test]
    fn direct_tiles_match_nested() {
        let s = build_schedule(
            GemmShape::new(3840, 4096, 4096),
            BlockShape::default(),
            120,
        )
        .unwrap();
        let f = FlatSchedule::from_schedule(&s);
        for cu in [0usize, 7, 119] {
            assert_eq!(
                f.direct_tiles(cu).collect::<Vec<_>>(),
                s.direct_tiles(cu).collect::<Vec<_>>()
            );
        }
    }
}
