//! Block2CTile mappings — linear tile id → (row, col) grid coordinates.
//!
//! This is exactly the layer where the report located CK's "compute unit
//! bug" (`Block2CTileMap` mis-mapping when a sub-maximal CU count is
//! passed). Each mapping here is a *verified bijection* over the tile
//! grid for every CU count (property-tested below); the deliberately
//! buggy CK-like variant lives in `faults::buggy_block2ctile` for the
//! CUBUG experiment.

use super::TileGrid;

/// Tile-order strategies for DP-region assignment and cache locality.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Swizzle {
    /// tile = r·tiles_n + c (the kernels' native order).
    RowMajor,
    /// tile = c·tiles_m + r.
    ColMajor,
    /// Group `g` consecutive rows; walk columns within the group before
    /// advancing — CUTLASS/CK's "swizzled" raster that keeps concurrent
    /// tiles sharing B-operand columns in cache.
    GroupedRows(usize),
}

impl Swizzle {
    /// Map a linear tile id to (row, col). Total and bijective for any
    /// grid and any `0 <= tile < num_tiles`.
    pub fn tile_rc(&self, grid: TileGrid, tile: usize) -> (usize, usize) {
        let (tm, tn) = (grid.tiles_m, grid.tiles_n);
        debug_assert!(tile < tm * tn);
        match *self {
            Swizzle::RowMajor => (tile / tn, tile % tn),
            Swizzle::ColMajor => (tile % tm, tile / tm),
            Swizzle::GroupedRows(g) => {
                let g = g.clamp(1, tm.max(1));
                let full_group_tiles = g * tn;
                let group = tile / full_group_tiles;
                let rows_before = group * g;
                let rows_here = g.min(tm - rows_before.min(tm));
                let within = tile - group * full_group_tiles;
                let r = rows_before + within % rows_here.max(1);
                let c = within / rows_here.max(1);
                (r, c)
            }
        }
    }

    /// Inverse mapping (used by tests and the simulator's heatmaps).
    pub fn rc_tile(&self, grid: TileGrid, r: usize, c: usize) -> usize {
        let (tm, tn) = (grid.tiles_m, grid.tiles_n);
        debug_assert!(r < tm && c < tn);
        match *self {
            Swizzle::RowMajor => r * tn + c,
            Swizzle::ColMajor => c * tm + r,
            Swizzle::GroupedRows(g) => {
                let g = g.clamp(1, tm.max(1));
                let group = r / g;
                let rows_before = group * g;
                let rows_here = g.min(tm - rows_before);
                group * g * tn + c * rows_here + (r - rows_before)
            }
        }
    }
}

/// Locality score: mean L2-reuse distance proxy — how many distinct
/// B-operand column strips the first `window` tiles touch. Lower is
/// better; used by the blocksize/swizzle ablation bench.
pub fn bcol_working_set(swizzle: Swizzle, grid: TileGrid, window: usize) -> usize {
    let mut seen = std::collections::HashSet::new();
    for t in 0..window.min(grid.num_tiles()) {
        let (_r, c) = swizzle.tile_rc(grid, t);
        seen.insert(c);
    }
    seen.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{BlockShape, GemmShape};
    use crate::prop;

    fn grid(tm: usize, tn: usize) -> TileGrid {
        TileGrid::new(
            GemmShape::new(tm * 128, tn * 128, 64),
            BlockShape::default(),
        )
    }

    #[test]
    fn row_major_is_native_order() {
        let g = grid(3, 4);
        assert_eq!(Swizzle::RowMajor.tile_rc(g, 0), (0, 0));
        assert_eq!(Swizzle::RowMajor.tile_rc(g, 5), (1, 1));
        assert_eq!(Swizzle::RowMajor.tile_rc(g, 11), (2, 3));
    }

    #[test]
    fn grouped_rows_walks_groups_first() {
        let g = grid(4, 3);
        let s = Swizzle::GroupedRows(2);
        let order: Vec<(usize, usize)> =
            (0..12).map(|t| s.tile_rc(g, t)).collect();
        // first group: rows 0..2, column-major within the group
        assert_eq!(&order[..6], &[(0, 0), (1, 0), (0, 1), (1, 1), (0, 2), (1, 2)]);
        assert_eq!(order[6], (2, 0));
    }

    #[test]
    fn prop_all_swizzles_are_bijections() {
        prop::check("swizzle bijection", 100, |rng| {
            let tm = rng.usize_in(1, 40);
            let tn = rng.usize_in(1, 40);
            let g = grid(tm, tn);
            let s = match rng.usize_in(0, 2) {
                0 => Swizzle::RowMajor,
                1 => Swizzle::ColMajor,
                _ => Swizzle::GroupedRows(rng.usize_in(1, 9)),
            };
            let mut seen = vec![false; tm * tn];
            for t in 0..tm * tn {
                let (r, c) = s.tile_rc(g, t);
                prop::ensure(r < tm && c < tn, format!("{s:?} oob {r},{c}"))?;
                let lin = r * tn + c;
                prop::ensure(!seen[lin], format!("{s:?} collides at {r},{c}"))?;
                seen[lin] = true;
                // inverse round-trips
                prop::ensure_eq(s.rc_tile(g, r, c), t, "inverse")?;
            }
            Ok(())
        });
    }

    #[test]
    fn grouped_rows_improves_bcol_locality() {
        let g = grid(16, 16);
        let w = 16; // one wave of 16 CUs
        let row = bcol_working_set(Swizzle::RowMajor, g, w);
        let grouped = bcol_working_set(Swizzle::GroupedRows(4), g, w);
        assert!(grouped < row, "grouped {grouped} !< row {row}");
    }
}
