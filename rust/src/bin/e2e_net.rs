//! `e2e_net` — process-level e2e driver for the TCP serving tier.
//!
//! Spawns REAL `streamk serve --listen` daemons on loopback and drives
//! them through the wire protocol (see [`streamk::net::e2e`] for the
//! individual runs and their gates):
//!
//! ```text
//! e2e_net --smoke                      # 1 daemon + 1 client process
//! e2e_net --kill-one                   # 2 daemons, one SIGKILLed mid-run
//! e2e_net --scenario fault-injection   # live adversarial replay
//! e2e_net --scenario flash-crowd
//! e2e_net                              # all of the above
//! ```
//!
//! The `streamk` binary must already be built in the same profile
//! (`cargo build [--release]`); `STREAMK_BIN` overrides discovery.
//! Exit code 0 only if every selected run passes.

use streamk::net::e2e;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut smoke = args.iter().any(|a| a == "--smoke");
    let mut kill_one = args.iter().any(|a| a == "--kill-one");
    let mut scenarios: Vec<String> = args
        .iter()
        .zip(args.iter().skip(1))
        .filter(|(a, _)| a.as_str() == "--scenario")
        .map(|(_, name)| name.clone())
        .collect();
    // cargo bench forwards `--bench`; ignore it like the other e2e
    // drivers. No selection = run everything.
    let selected = smoke || kill_one || !scenarios.is_empty();
    if !selected {
        smoke = true;
        kill_one = true;
        scenarios =
            vec!["fault-injection".to_string(), "flash-crowd".to_string()];
    }

    let bin = match e2e::find_streamk_bin() {
        Ok(b) => b,
        Err(e) => {
            eprintln!("e2e_net: {e}");
            std::process::exit(2);
        }
    };
    println!("e2e_net: driving {}", bin.display());

    let mut failures = 0usize;
    let mut report = |what: &str, r: Result<String, String>| match r {
        Ok(msg) => println!("PASS {what}: {msg}"),
        Err(e) => {
            failures += 1;
            eprintln!("FAIL {what}: {e}");
        }
    };

    if smoke {
        report("smoke", e2e::run_smoke(&bin));
    }
    if kill_one {
        report("kill-one", e2e::run_kill_one(&bin));
    }
    for name in &scenarios {
        // Live replay executes every GEMM for real; cap the offered
        // load well under the sim-scale request counts.
        report(
            &format!("scenario {name}"),
            e2e::run_scenario_live(&bin, name, 40),
        );
    }
    drop(report);

    if failures > 0 {
        eprintln!("e2e_net: {failures} run(s) FAILED");
        std::process::exit(1);
    }
    println!("e2e_net OK");
}
