//! Plan cache — the zero-rebuild serving hot path.
//!
//! A [`Plan`] is everything derivable from `(shape, block, element
//! width, CU count)` *before* a device or request shows up: the
//! flattened Stream-K schedule ([`crate::decomp::FlatSchedule`]) plus
//! the launch invariants the simulator needs (per-CU MAC flops and
//! iteration counts, total HBM bytes for the phase-1 and fixup
//! launches, MXU fill). With those precomputed, pricing a plan on a
//! concrete device ([`Plan::time_on`]) is an O(CUs) arithmetic loop —
//! no schedule construction, no nested `Vec<Vec<WorkItem>>`, no
//! allocation at all.
//!
//! [`PlanCache`] (see [`cache`]) memoizes plans behind a sharded,
//! LRU-bounded map; [`global`](cache::global) is the process-wide
//! instance shared by the coordinator's fleet scheduler (placement
//! priors), the tuner's top-K measurement loop
//! ([`crate::tuner::measure`]), the interpreter runtime (gemm artifacts
//! execute by walking the cached flat schedule), and the fleet traffic
//! simulator — so a shape that repeats anywhere in the process never
//! re-runs decomposition.
//!
//! Keying note: the issue of device identity resolves cleanly here —
//! a plan depends on the device only through its CU count (per-CU
//! speeds, bandwidth and overheads enter at [`Plan::time_on`] time), so
//! the key is `(GemmShape, effective BlockShape, bytes/elem, cus)` and
//! one cached plan legitimately serves every device with that grid
//! width. That is strictly more sharing than fingerprint-keyed entries
//! with identical contents. The exception is a Block2Time-weighted
//! split, whose work lists *do* depend on per-CU speeds: those keys
//! carry the weight vector, quantized ([`PlanKey::weighted`]) so that
//! jittery speed estimates still collapse onto one plan.

pub mod cache;

pub use cache::{
    global, init_global_with_capacity, load_hwm_capacity, save_hwm,
    warm_parallel, PlanCache, PlanCacheStats, CAPACITY_ENV,
};

use crate::decomp::streamk::ScheduleError;
use crate::decomp::{
    build_schedule, build_weighted_schedule, BlockShape, FlatSchedule,
    GemmShape,
};
use crate::gpu_sim::gemm::{
    item_bytes, item_flops, launch_from_invariants, mxu_fill,
};
use crate::gpu_sim::{Device, LaunchStats, SimResult};
use crate::kernel::{ExecDesc, Width};
use std::sync::{Arc, OnceLock};

/// Fixed-point denominator for quantized per-CU weights: 1/256 relative
/// to the fastest CU. Coarse enough that jittery Block2Time speed
/// estimates collapse onto one key (plan reuse), fine enough that the
/// quantized split's predicted makespan is within ~0.4% of the exact
/// one.
pub const WEIGHT_QUANTUM: u16 = 256;

/// Cache key: exact shape × effective block × element width × CU count,
/// plus — for Block2Time-balanced splits — the per-CU weight vector,
/// quantized to [`WEIGHT_QUANTUM`]ths of the fastest CU so that near-
/// identical speed estimates share one cached plan. The block is
/// normalized through [`BlockShape::effective`] so two requested blocks
/// that shrink to the same kernel share one plan.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub shape: GemmShape,
    pub block: BlockShape,
    /// Element width the A/B panels stream at. Streamed bytes, launch
    /// invariants and the executable descriptor all derive from it, so
    /// a bf16 plan and an f32 plan of the same shape never share an
    /// entry.
    pub width: Width,
    pub cus: usize,
    /// `None` = even Stream-K split; `Some` = weighted split, one
    /// quantized weight per CU (scale-invariant: `2×w` and `w` map to
    /// the same key). A `0` entry marks an invalid — or unrepresentably
    /// small, see [`quantize_weights`] — input weight and makes
    /// [`Plan::build`] fail like `build_weighted_schedule` would.
    pub weights: Option<Arc<[u16]>>,
}

impl PlanKey {
    /// Back-compat constructor speaking bytes-per-element (2 → bf16,
    /// else f32 — [`Width::from_bpe`]).
    pub fn new(
        shape: GemmShape,
        block: BlockShape,
        bytes_per_elem: usize,
        cus: usize,
    ) -> Self {
        Self::new_w(shape, block, Width::from_bpe(bytes_per_elem), cus)
    }

    pub fn new_w(
        shape: GemmShape,
        block: BlockShape,
        width: Width,
        cus: usize,
    ) -> Self {
        Self {
            shape,
            block: block.effective(shape),
            width,
            cus,
            weights: None,
        }
    }

    /// Streamed bytes per panel element at this key's width.
    pub fn bytes_per_elem(&self) -> usize {
        self.width.bytes()
    }

    /// Key for a Block2Time-weighted split: CU count is the weight
    /// count, weights are quantized (and thereby deduplicated).
    pub fn weighted(
        shape: GemmShape,
        block: BlockShape,
        bytes_per_elem: usize,
        weights: &[f64],
    ) -> Self {
        Self {
            shape,
            block: block.effective(shape),
            width: Width::from_bpe(bytes_per_elem),
            cus: weights.len(),
            weights: Some(quantize_weights(weights)),
        }
    }

    /// The dequantized weight factors this key's plan is built with
    /// (`None` for even-split keys).
    pub fn weight_factors(&self) -> Option<Vec<f64>> {
        self.weights.as_ref().map(|q| {
            q.iter().map(|&v| v as f64 / WEIGHT_QUANTUM as f64).collect()
        })
    }
}

/// Scale-invariant fixed-point quantization: weight / max(weights) in
/// 1/256 steps. Non-positive / non-finite inputs map to 0, which
/// [`Plan::build`] rejects exactly like the unquantized builder — and
/// so does a weight too small to represent (one that rounds to zero,
/// i.e. below 1/(2·[`WEIGHT_QUANTUM`]) of the fastest CU): silently
/// flooring it to one quantum would hand an effectively-dead CU up to
/// [`WEIGHT_QUANTUM`]× its true capacity share and gate the whole
/// split on it. Callers with such a skewed estimate should exclude
/// the dead CU (or use the exact, uncached
/// [`crate::predict::balance`]).
fn quantize_weights(ws: &[f64]) -> Arc<[u16]> {
    let maxw = ws
        .iter()
        .cloned()
        .filter(|w| w.is_finite())
        .fold(0.0f64, f64::max);
    ws.iter()
        .map(|&w| {
            if w > 0.0 && w.is_finite() && maxw > 0.0 {
                ((w / maxw) * WEIGHT_QUANTUM as f64)
                    .round()
                    .clamp(0.0, WEIGHT_QUANTUM as f64) as u16
            } else {
                0
            }
        })
        .collect()
}

/// A fully materialized, device-independent execution plan: the
/// flattened schedule plus precomputed launch invariants.
#[derive(Debug, Clone)]
pub struct Plan {
    pub key: PlanKey,
    pub flat: FlatSchedule,
    /// Per-work-item tile descriptors for the blocked microkernel
    /// executor ([`crate::kernel`]), built lazily on first execution
    /// ([`Self::exec`]): the tuner's pricing-only candidate plans never
    /// execute data, so eager construction would double their build
    /// cost and cache footprint for nothing. Once built, the
    /// interpreter runtime replays it with zero descriptor work per
    /// request — same steady state as the eager form.
    exec: OnceLock<ExecDesc>,
    /// MXU systolic-array fill of the (effective) block — constant per
    /// launch, precomputed once.
    pub mxu_fill: f64,
    /// Phase-1 MAC flops per CU (exact integer sums in f64).
    pub cu_flops: Vec<f64>,
    /// Phase-1 BK-deep MAC iterations per CU (drives iter_overhead).
    pub cu_iters: Vec<f64>,
    /// Phase-1 HBM bytes, accumulated in the simulator's item order.
    pub bytes: f64,
    /// Fixup-launch HBM bytes (0.0 when no fixup launch).
    pub fixup_bytes: f64,
    /// Total MAC flops across all CUs (reporting).
    pub flops: f64,
}

impl Plan {
    /// Build the plan for one key: run the decomposition once, flatten
    /// it, and precompute every launch invariant. This is the *only*
    /// place on the serving stack that still constructs a
    /// [`crate::decomp::StreamKSchedule`]; everything downstream reuses
    /// the result through the cache.
    pub fn build(key: PlanKey) -> Result<Self, ScheduleError> {
        let sched = match key.weight_factors() {
            None => build_schedule(key.shape, key.block, key.cus)?,
            // Build with the *quantized* weights, so the key fully
            // determines the plan and every estimate that rounds to the
            // same split shares one cached schedule.
            Some(factors) => {
                build_weighted_schedule(key.shape, key.block, &factors)?
            }
        };
        // build_schedule re-applies `effective`; keep the plan's block
        // identical to the schedule it describes.
        let block = sched.block;
        let flat = FlatSchedule::from_schedule(&sched);
        let bpe = key.bytes_per_elem();

        let mut cu_flops = Vec::with_capacity(key.cus);
        let mut cu_iters = Vec::with_capacity(key.cus);
        let mut bytes = 0.0f64;
        let mut flops = 0.0f64;
        for cu in 0..key.cus {
            let mut f = 0.0f64;
            let mut it = 0usize;
            for item in flat.cu_items(cu) {
                f += item_flops(item, block);
                it += item.k_iters;
                bytes += item_bytes(item, block, bpe);
            }
            flops += f;
            cu_flops.push(f);
            cu_iters.push(it as f64);
        }
        let mut fixup_bytes = 0.0f64;
        for cu in 0..key.cus {
            for item in flat.cu_fixup_items(cu) {
                fixup_bytes += item_bytes(item, block, bpe);
            }
        }

        Ok(Self {
            key: PlanKey { block, ..key },
            flat,
            exec: OnceLock::new(),
            mxu_fill: mxu_fill(block, bpe),
            cu_flops,
            cu_iters,
            bytes,
            fixup_bytes,
            flops,
        })
    }

    /// The executable per-work-item tile descriptors, built on first
    /// use and cached for the plan's lifetime (thread-safe; concurrent
    /// first calls race benignly, one result wins). Pricing paths
    /// ([`Self::time_on`], [`Self::simulate`]) never touch this.
    pub fn exec(&self) -> &ExecDesc {
        self.exec.get_or_init(|| {
            ExecDesc::new(self.key.shape, self.key.block, &self.flat)
                .with_width(self.key.width)
        })
    }

    /// Whether the descriptor has been materialized yet (tests, cache
    /// footprint accounting).
    pub fn exec_built(&self) -> bool {
        self.exec.get().is_some()
    }

    /// Predicted wall time of this plan on `dev` — the allocation-free
    /// hot path. Reproduces `gpu_sim::gemm::simulate_streamk(...).total_s`
    /// up to f64 summation order (per-CU flops are pre-summed; the sums
    /// themselves are exact — integer-valued flop/iteration counts).
    pub fn time_on(&self, dev: &Device) -> f64 {
        assert_eq!(dev.num_cus, self.key.cus, "plan built for other grid");
        self.time_on_prefix(dev)
    }

    /// Like [`Self::time_on`], for a plan whose grid uses only the
    /// first `key.cus` CUs of `dev` (the tuner's sub-grid candidates:
    /// the report's "Compute Units" parameter). Numerically identical
    /// to `time_on(&dev.clone().with_cus(key.cus))` without cloning the
    /// device — [`crate::tuner::measure`] prices every candidate
    /// through this, allocation-free.
    pub fn time_on_prefix(&self, dev: &Device) -> f64 {
        assert!(
            self.key.cus <= dev.num_cus,
            "plan needs {} CUs, device has {}",
            self.key.cus,
            dev.num_cus
        );
        let mut compute_span = 0.0f64;
        for cu in 0..self.key.cus {
            let speed = dev.flops_per_cu * dev.cu_speed[cu] * self.mxu_fill;
            let busy = self.cu_flops[cu] / speed
                + self.cu_iters[cu] * dev.iter_overhead;
            compute_span = compute_span.max(busy);
        }
        let mem_span = self.bytes / dev.hbm_bw;
        let mut total = compute_span.max(mem_span) + dev.launch_overhead;
        if self.flat.has_fixup() {
            // Fixup items carry no MAC work: compute span is zero and
            // the launch is paced by its traffic alone.
            total += self.fixup_bytes / dev.hbm_bw + dev.launch_overhead;
        }
        total
    }

    /// Full per-launch simulation of this plan on `dev` (utilization,
    /// per-CU busy bars) — the reporting path. Runs straight off the
    /// precomputed launch invariants: no walk over work items, no
    /// schedule replay (agrees with the item-walking simulator to f64
    /// summation order).
    pub fn simulate(&self, dev: &Device) -> SimResult {
        assert_eq!(dev.num_cus, self.key.cus, "plan built for other grid");
        let mut launches = vec![launch_from_invariants(
            dev,
            &self.cu_flops,
            &self.cu_iters,
            self.bytes,
            self.mxu_fill,
        )];
        if self.flat.has_fixup() {
            // Fixup items carry no MAC work: zero compute, paced by
            // traffic alone — exactly what replaying the fixup items
            // produces.
            let mem_span = self.fixup_bytes / dev.hbm_bw;
            launches.push(LaunchStats {
                time_s: mem_span + dev.launch_overhead,
                cu_busy: vec![0.0; dev.num_cus],
                bytes: self.fixup_bytes,
                memory_bound: mem_span > 0.0,
            });
        }
        crate::gpu_sim::gemm::finish_launches(dev, self.key.shape, launches)
    }

    /// Workspace bytes for the two-slot partials buffer.
    pub fn partials_bytes(&self) -> usize {
        self.key.cus * 2 * self.key.block.bm * self.key.block.bn * 4
    }
}

/// Descriptor materialization is an execution-side cache, not part of a
/// plan's identity: two plans with equal keys, schedules and launch
/// invariants are equal whether or not either has built its
/// [`ExecDesc`] yet (the manual impl the lazy `OnceLock` field needs —
/// `OnceLock` itself has no `PartialEq`).
impl PartialEq for Plan {
    fn eq(&self, other: &Self) -> bool {
        self.key == other.key
            && self.flat == other.flat
            && self.mxu_fill == other.mxu_fill
            && self.cu_flops == other.cu_flops
            && self.cu_iters == other.cu_iters
            && self.bytes == other.bytes
            && self.fixup_bytes == other.fixup_bytes
            && self.flops == other.flops
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_sim::{simulate_streamk, DeviceKind};

    fn mi200() -> Device {
        Device::preset(DeviceKind::Mi200)
    }

    #[test]
    fn plan_time_matches_full_simulation() {
        let dev = mi200();
        for (m, n, k) in [
            (3840usize, 4096usize, 4096usize),
            (1000, 1000, 1000), // ragged: fixup launch present
            (3, 9, 9),
            (480, 512, 512),
        ] {
            let shape = GemmShape::new(m, n, k);
            let plan = Plan::build(PlanKey::new(
                shape,
                BlockShape::default(),
                4,
                dev.num_cus,
            ))
            .unwrap();
            let sched =
                build_schedule(shape, BlockShape::default(), dev.num_cus)
                    .unwrap();
            let full = simulate_streamk(&dev, &sched, 4);
            let fast = plan.time_on(&dev);
            assert!(
                (fast - full.total_s).abs() <= full.total_s * 1e-9,
                "{m}x{n}x{k}: plan {fast} vs sim {}",
                full.total_s
            );
            // The invariants-based simulation pre-sums per-CU flops, so
            // it agrees with the item-walking replay up to f64
            // summation order.
            let sim = plan.simulate(&dev);
            assert_eq!(sim.launches.len(), full.launches.len());
            assert!(
                (sim.total_s - full.total_s).abs() <= full.total_s * 1e-9,
                "{m}x{n}x{k}: invariant sim {} vs replay {}",
                sim.total_s,
                full.total_s
            );
            assert!(
                (sim.utilization - full.utilization).abs() <= 1e-9,
                "{m}x{n}x{k}: utilization {} vs {}",
                sim.utilization,
                full.utilization
            );
        }
    }

    #[test]
    fn prefix_pricing_matches_truncated_device() {
        let dev = mi200();
        let shape = GemmShape::new(1920, 2000, 2000);
        for cus in [1usize, 30, 120] {
            let plan = Plan::build(PlanKey::new(
                shape,
                BlockShape::default(),
                4,
                cus,
            ))
            .unwrap();
            let via_clone = plan.time_on(&dev.clone().with_cus(cus));
            let via_prefix = plan.time_on_prefix(&dev);
            assert_eq!(
                via_prefix, via_clone,
                "cus={cus}: prefix pricing must match the truncated device"
            );
        }
    }

    #[test]
    fn plans_carry_executable_descriptors() {
        let plan = Plan::build(PlanKey::new(
            GemmShape::new(96, 102, 100),
            BlockShape::new(16, 16, 8),
            4,
            12,
        ))
        .unwrap();
        assert_eq!(plan.exec().jobs.len(), plan.flat.num_items());
        assert_eq!(plan.exec().fixup.len(), plan.flat.split_tiles.len());
        assert_eq!(plan.exec().block, plan.key.block);
        // and they actually execute: quick numeric spot check
        let mut rng = crate::prop::Rng::new(9);
        let a = rng.normal_f32_vec(96 * 100);
        let b = rng.normal_f32_vec(100 * 102);
        let got = crate::kernel::execute(
            &a,
            &b,
            plan.exec(),
            crate::kernel::Epilogue::None,
        );
        let want = crate::faults::execute_flat_ref(
            &a,
            &b,
            plan.key.shape,
            &plan.flat,
            plan.key.block,
        );
        assert_eq!(got, want);
    }

    /// Satellite acceptance: pricing-only plans never pay for a
    /// descriptor — it materializes on first execution and is cached.
    #[test]
    fn exec_desc_is_lazy_and_prices_without_building() {
        let cus = 16;
        let plan = Plan::build(PlanKey::new(
            GemmShape::new(480, 512, 512),
            BlockShape::default(),
            4,
            cus,
        ))
        .unwrap();
        assert!(!plan.exec_built(), "build must not materialize the desc");
        let dev = mi200().with_cus(cus);
        let t = plan.time_on(&dev);
        assert!(t > 0.0);
        let sim = plan.simulate(&dev);
        assert!(sim.total_s > 0.0);
        assert!(
            !plan.exec_built(),
            "pricing and simulation are descriptor-free"
        );
        let first = plan.exec() as *const ExecDesc;
        assert!(plan.exec_built());
        assert_eq!(
            first,
            plan.exec() as *const ExecDesc,
            "descriptor is built once and cached"
        );
        // equality ignores materialization state
        let fresh = Plan::build(PlanKey::new(
            GemmShape::new(480, 512, 512),
            BlockShape::default(),
            4,
            cus,
        ))
        .unwrap();
        assert!(!fresh.exec_built());
        assert_eq!(plan, fresh, "lazy state must not affect plan identity");
    }

    /// Tentpole invariant: a 16-bit plan halves streamed panel bytes in
    /// the launch invariants (Block2Time honesty) and threads the width
    /// into its executable descriptor, while the schedule itself — a
    /// pure index computation — is width-independent.
    #[test]
    fn sixteen_bit_plans_halve_streamed_bytes_and_tag_the_desc() {
        let shape = GemmShape::new(1920, 2000, 2000);
        let blk = BlockShape::default();
        let f32p =
            Plan::build(PlanKey::new_w(shape, blk, Width::F32, 120)).unwrap();
        for w in [Width::Bf16, Width::F16] {
            let p = Plan::build(PlanKey::new_w(shape, blk, w, 120)).unwrap();
            assert_eq!(p.flat, f32p.flat, "schedule is width-independent");
            assert!(
                (p.bytes - f32p.bytes / 2.0).abs() <= f32p.bytes * 1e-12,
                "{w}: {} vs f32 {}",
                p.bytes,
                f32p.bytes
            );
            assert_eq!(p.exec().width, w, "desc carries the key width");
            assert_ne!(p.key, f32p.key, "widths never share a cache entry");
            // Pricing sees the halved traffic: never slower, and the
            // memory span itself strictly shrinks (whether that shows
            // in the total depends on the device's compute/mem balance).
            let dev = mi200();
            assert!(p.time_on(&dev) <= f32p.time_on(&dev));
        }
        assert_eq!(f32p.exec().width, Width::F32);
        assert_eq!(PlanKey::new(shape, blk, 2, 120).width, Width::Bf16);
        assert_eq!(
            PlanKey::new(shape, blk, 2, 120).bytes_per_elem(),
            2
        );
    }

    #[test]
    fn weighted_keys_quantize_scale_invariantly() {
        let shape = GemmShape::new(2048, 2048, 2048);
        let blk = BlockShape::default();
        let a = PlanKey::weighted(shape, blk, 4, &[1.0, 1.0, 2.0, 4.0]);
        let b = PlanKey::weighted(shape, blk, 4, &[0.5, 0.5, 1.0, 2.0]);
        assert_eq!(a, b, "scaled weights share one key");
        let c = PlanKey::weighted(shape, blk, 4, &[1.0, 1.0, 1.0, 1.0]);
        assert_ne!(a, c, "different splits stay distinct");
        // jitter below the quantum collapses onto the same key
        let d = PlanKey::weighted(shape, blk, 4, &[1.0005, 1.0, 2.0, 4.0]);
        assert_eq!(a, d, "sub-quantum jitter must reuse the plan");
        assert_eq!(a.cus, 4);
        assert_eq!(
            a.weight_factors().unwrap(),
            vec![0.25, 0.25, 0.5, 1.0]
        );
    }

    #[test]
    fn weighted_plan_builds_the_quantized_split() {
        let shape = GemmShape::new(2048, 2048, 2048);
        let blk = BlockShape::default();
        let key = PlanKey::weighted(shape, blk, 4, &[1.0, 1.0, 2.0, 4.0]);
        let factors = key.weight_factors().unwrap();
        let plan = Plan::build(key).unwrap();
        let sched =
            crate::decomp::build_weighted_schedule(shape, blk, &factors)
                .unwrap();
        assert_eq!(plan.flat, FlatSchedule::from_schedule(&sched));
        // weighted plans have no DP region: every tile is stream-k
        assert_eq!(plan.flat.dp_tiles_per_cu, 0);
    }

    #[test]
    fn weighted_plan_rejects_bad_weights() {
        let shape = GemmShape::new(128, 128, 128);
        for bad in [
            vec![],
            vec![1.0, 0.0],
            vec![1.0, f64::NAN],
            vec![1.0, f64::INFINITY],
            vec![-1.0, 1.0],
            // unrepresentably skewed: quantizing the 1e-6 CU to one
            // quantum would hand it ~2000x its true share, so the key
            // rejects instead of silently distorting the split
            vec![1.0, 1e-6],
        ] {
            let key =
                PlanKey::weighted(shape, BlockShape::default(), 4, &bad);
            assert!(Plan::build(key).is_err(), "weights {bad:?}");
        }
        // the representable extreme still builds: exactly one quantum
        let key = PlanKey::weighted(
            shape,
            BlockShape::default(),
            4,
            &[1.0, 1.0 / WEIGHT_QUANTUM as f64],
        );
        assert!(Plan::build(key).is_ok());
    }

    #[test]
    fn plan_respects_heterogeneous_cu_speeds() {
        let shape = GemmShape::new(3840, 4096, 4096);
        let plan = Plan::build(PlanKey::new(
            shape,
            BlockShape::default(),
            4,
            120,
        ))
        .unwrap();
        let fast = plan.time_on(&mi200());
        let slow = plan.time_on(&mi200().with_throttled(2, 0.25));
        assert!(slow > fast * 3.0, "throttled {slow} vs {fast}");
    }

    #[test]
    fn key_normalizes_block_to_effective() {
        let shape = GemmShape::new(3, 9, 9);
        let a = PlanKey::new(shape, BlockShape::default(), 4, 8);
        let b = PlanKey::new(shape, BlockShape::new(64, 64, 64), 4, 8);
        assert_eq!(a, b, "both shrink to 3x9x9");
    }

    #[test]
    fn degenerate_key_is_an_error() {
        assert!(Plan::build(PlanKey::new(
            GemmShape::new(0, 4, 4),
            BlockShape::default(),
            4,
            8
        ))
        .is_err());
    }
}
