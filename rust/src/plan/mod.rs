//! Plan cache — the zero-rebuild serving hot path.
//!
//! A [`Plan`] is everything derivable from `(shape, block, element
//! width, CU count)` *before* a device or request shows up: the
//! flattened Stream-K schedule ([`crate::decomp::FlatSchedule`]) plus
//! the launch invariants the simulator needs (per-CU MAC flops and
//! iteration counts, total HBM bytes for the phase-1 and fixup
//! launches, MXU fill). With those precomputed, pricing a plan on a
//! concrete device ([`Plan::time_on`]) is an O(CUs) arithmetic loop —
//! no schedule construction, no nested `Vec<Vec<WorkItem>>`, no
//! allocation at all.
//!
//! [`PlanCache`] (see [`cache`]) memoizes plans behind a sharded,
//! LRU-bounded map; [`global`](cache::global) is the process-wide
//! instance shared by the coordinator's fleet scheduler (placement
//! priors), the tuner's top-K measurement loop
//! ([`crate::tuner::measure`]), the interpreter runtime (gemm artifacts
//! execute by walking the cached flat schedule), and the fleet traffic
//! simulator — so a shape that repeats anywhere in the process never
//! re-runs decomposition.
//!
//! Keying note: the issue of device identity resolves cleanly here —
//! a plan depends on the device only through its CU count (per-CU
//! speeds, bandwidth and overheads enter at [`Plan::time_on`] time), so
//! the key is `(GemmShape, effective BlockShape, bytes/elem, cus)` and
//! one cached plan legitimately serves every device with that grid
//! width. That is strictly more sharing than fingerprint-keyed entries
//! with identical contents.

pub mod cache;

pub use cache::{global, warm_parallel, PlanCache, PlanCacheStats};

use crate::decomp::streamk::ScheduleError;
use crate::decomp::{build_schedule, BlockShape, FlatSchedule, GemmShape};
use crate::gpu_sim::gemm::{item_bytes, item_flops, mxu_fill};
use crate::gpu_sim::{Device, SimResult};

/// Cache key: exact shape × effective block × element width × CU count.
/// The block is normalized through [`BlockShape::effective`] so two
/// requested blocks that shrink to the same kernel share one plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub shape: GemmShape,
    pub block: BlockShape,
    pub bytes_per_elem: usize,
    pub cus: usize,
}

impl PlanKey {
    pub fn new(
        shape: GemmShape,
        block: BlockShape,
        bytes_per_elem: usize,
        cus: usize,
    ) -> Self {
        Self { shape, block: block.effective(shape), bytes_per_elem, cus }
    }
}

/// A fully materialized, device-independent execution plan: the
/// flattened schedule plus precomputed launch invariants.
#[derive(Debug, Clone, PartialEq)]
pub struct Plan {
    pub key: PlanKey,
    pub flat: FlatSchedule,
    /// MXU systolic-array fill of the (effective) block — constant per
    /// launch, precomputed once.
    pub mxu_fill: f64,
    /// Phase-1 MAC flops per CU (exact integer sums in f64).
    pub cu_flops: Vec<f64>,
    /// Phase-1 BK-deep MAC iterations per CU (drives iter_overhead).
    pub cu_iters: Vec<f64>,
    /// Phase-1 HBM bytes, accumulated in the simulator's item order.
    pub bytes: f64,
    /// Fixup-launch HBM bytes (0.0 when no fixup launch).
    pub fixup_bytes: f64,
    /// Total MAC flops across all CUs (reporting).
    pub flops: f64,
}

impl Plan {
    /// Build the plan for one key: run the decomposition once, flatten
    /// it, and precompute every launch invariant. This is the *only*
    /// place on the serving stack that still constructs a
    /// [`crate::decomp::StreamKSchedule`]; everything downstream reuses
    /// the result through the cache.
    pub fn build(key: PlanKey) -> Result<Self, ScheduleError> {
        let sched = build_schedule(key.shape, key.block, key.cus)?;
        // build_schedule re-applies `effective`; keep the plan's block
        // identical to the schedule it describes.
        let block = sched.block;
        let flat = FlatSchedule::from_schedule(&sched);
        let bpe = key.bytes_per_elem;

        let mut cu_flops = Vec::with_capacity(key.cus);
        let mut cu_iters = Vec::with_capacity(key.cus);
        let mut bytes = 0.0f64;
        let mut flops = 0.0f64;
        for cu in 0..key.cus {
            let mut f = 0.0f64;
            let mut it = 0usize;
            for item in flat.cu_items(cu) {
                f += item_flops(item, block);
                it += item.k_iters;
                bytes += item_bytes(item, block, bpe);
            }
            flops += f;
            cu_flops.push(f);
            cu_iters.push(it as f64);
        }
        let mut fixup_bytes = 0.0f64;
        for cu in 0..key.cus {
            for item in flat.cu_fixup_items(cu) {
                fixup_bytes += item_bytes(item, block, bpe);
            }
        }

        Ok(Self {
            key: PlanKey { block, ..key },
            flat,
            mxu_fill: mxu_fill(block, bpe),
            cu_flops,
            cu_iters,
            bytes,
            fixup_bytes,
            flops,
        })
    }

    /// Predicted wall time of this plan on `dev` — the allocation-free
    /// hot path. Reproduces `gpu_sim::gemm::simulate_streamk(...).total_s`
    /// up to f64 summation order (per-CU flops are pre-summed; the sums
    /// themselves are exact — integer-valued flop/iteration counts).
    pub fn time_on(&self, dev: &Device) -> f64 {
        assert_eq!(dev.num_cus, self.key.cus, "plan built for other grid");
        let mut compute_span = 0.0f64;
        for cu in 0..self.key.cus {
            let speed = dev.flops_per_cu * dev.cu_speed[cu] * self.mxu_fill;
            let busy = self.cu_flops[cu] / speed
                + self.cu_iters[cu] * dev.iter_overhead;
            compute_span = compute_span.max(busy);
        }
        let mem_span = self.bytes / dev.hbm_bw;
        let mut total = compute_span.max(mem_span) + dev.launch_overhead;
        if self.flat.has_fixup() {
            // Fixup items carry no MAC work: compute span is zero and
            // the launch is paced by its traffic alone.
            total += self.fixup_bytes / dev.hbm_bw + dev.launch_overhead;
        }
        total
    }

    /// Full per-launch simulation of this plan on `dev` (utilization,
    /// per-CU busy bars) — the reporting path; allocates.
    pub fn simulate(&self, dev: &Device) -> SimResult {
        crate::gpu_sim::simulate_flat(
            dev,
            self.key.shape,
            &self.flat,
            self.key.block,
            self.key.bytes_per_elem,
        )
    }

    /// Workspace bytes for the two-slot partials buffer.
    pub fn partials_bytes(&self) -> usize {
        self.key.cus * 2 * self.key.block.bm * self.key.block.bn * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_sim::{simulate_streamk, DeviceKind};

    fn mi200() -> Device {
        Device::preset(DeviceKind::Mi200)
    }

    #[test]
    fn plan_time_matches_full_simulation() {
        let dev = mi200();
        for (m, n, k) in [
            (3840usize, 4096usize, 4096usize),
            (1000, 1000, 1000), // ragged: fixup launch present
            (3, 9, 9),
            (480, 512, 512),
        ] {
            let shape = GemmShape::new(m, n, k);
            let plan = Plan::build(PlanKey::new(
                shape,
                BlockShape::default(),
                4,
                dev.num_cus,
            ))
            .unwrap();
            let sched =
                build_schedule(shape, BlockShape::default(), dev.num_cus)
                    .unwrap();
            let full = simulate_streamk(&dev, &sched, 4);
            let fast = plan.time_on(&dev);
            assert!(
                (fast - full.total_s).abs() <= full.total_s * 1e-9,
                "{m}x{n}x{k}: plan {fast} vs sim {}",
                full.total_s
            );
            let sim = plan.simulate(&dev);
            assert_eq!(sim.launches.len(), full.launches.len());
            assert_eq!(sim.total_s, full.total_s);
        }
    }

    #[test]
    fn plan_respects_heterogeneous_cu_speeds() {
        let shape = GemmShape::new(3840, 4096, 4096);
        let plan = Plan::build(PlanKey::new(
            shape,
            BlockShape::default(),
            4,
            120,
        ))
        .unwrap();
        let fast = plan.time_on(&mi200());
        let slow = plan.time_on(&mi200().with_throttled(2, 0.25));
        assert!(slow > fast * 3.0, "throttled {slow} vs {fast}");
    }

    #[test]
    fn key_normalizes_block_to_effective() {
        let shape = GemmShape::new(3, 9, 9);
        let a = PlanKey::new(shape, BlockShape::default(), 4, 8);
        let b = PlanKey::new(shape, BlockShape::new(64, 64, 64), 4, 8);
        assert_eq!(a, b, "both shrink to 3x9x9");
    }

    #[test]
    fn degenerate_key_is_an_error() {
        assert!(Plan::build(PlanKey::new(
            GemmShape::new(0, 4, 4),
            BlockShape::default(),
            4,
            8
        ))
        .is_err());
    }
}
