//! Sharded, LRU-bounded plan cache with hit/miss/build-time counters.
//!
//! Shape-keyed plan reuse is the serving hot path's whole point: after
//! first touch, a repeated shape costs one shard lock + one slice scan —
//! no decomposition, no allocation. Sharding (key-hash → shard) keeps
//! the coordinator's worker threads, the background tuner, and the
//! fleet scheduler from serializing on one mutex; each shard is its own
//! MRU-ordered list bounded at `capacity / shards` entries.
//!
//! Counters are lock-free atomics so the metrics snapshot never
//! contends with the request path. [`global`] is the process-wide
//! instance every subsystem shares.

use super::{Plan, PlanKey};
use crate::decomp::streamk::ScheduleError;
use crate::decomp::{BlockShape, GemmShape};
use crate::exec::{pool_map, Stopwatch};
use crate::json::{obj, Value};
use std::collections::hash_map::DefaultHasher;
use std::hash::{Hash, Hasher};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Process-wide cache sizing, derived from the observed distinct-key
/// high-water marks (`hwm_shard_max` in the stats) instead of the old
/// hand-picked total of 2048 over 8 shards: the serving traces this
/// repo ships (the `e2e_serve` coordinator smoke, `streamk fleet`, the
/// tuner's Table-1 sweeps) peak below ~16 distinct keys per shard, so
/// 64 per shard (512 total — a 4× cut from the old default) is 4×
/// headroom over the observed demand. Operators with wider shape mixes
/// can override via `STREAMK_PLAN_CACHE_CAP` (total plans across all
/// shards); the `streamk plan` inspector prints the observed high-water
/// mark and the capacity it recommends.
const GLOBAL_PLANS_PER_SHARD: usize = 64;
const GLOBAL_SHARDS: usize = 8;
/// Environment override for the global cache's total capacity.
pub const CAPACITY_ENV: &str = "STREAMK_PLAN_CACHE_CAP";

/// One shard: MRU-first entries. Linear scan is fine at per-shard sizes
/// (hundreds); the key compare is a handful of integer equalities.
struct Shard {
    entries: Vec<(PlanKey, Arc<Plan>)>,
    /// Distinct-key high-water mark: the most entries this shard ever
    /// demanded at once (measured before eviction, so a saturated shard
    /// reads `capacity + 1` — the "size me up" signal).
    hwm: usize,
}

/// Sharded LRU plan cache. Cheap to share (`Arc<PlanCache>`); all
/// methods take `&self`.
pub struct PlanCache {
    shards: Vec<Mutex<Shard>>,
    per_shard_capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    builds: AtomicU64,
    build_ns: AtomicU64,
    evictions: AtomicU64,
}

/// Point-in-time counter snapshot (serialized into the coordinator
/// metrics and the `streamk serve` / `streamk fleet` reports).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlanCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub builds: u64,
    /// Total wall seconds spent constructing plans (cold path only).
    pub build_time_s: f64,
    pub evictions: u64,
    pub entries: usize,
    pub shards: usize,
    /// Sum of per-shard distinct-key high-water marks — the peak
    /// working set this process has demanded.
    pub hwm_entries: usize,
    /// The busiest shard's high-water mark — what capacity sizing keys
    /// off (shards are hash-balanced, the max bounds them all).
    pub hwm_shard_max: usize,
}

impl PlanCacheStats {
    pub fn lookups(&self) -> u64 {
        self.hits + self.misses
    }

    /// Hit fraction in [0, 1]; 1.0 when no lookups happened yet.
    pub fn hit_rate(&self) -> f64 {
        if self.lookups() == 0 {
            1.0
        } else {
            self.hits as f64 / self.lookups() as f64
        }
    }

    /// A shard hit its bound and evicted: the high-water mark is capped
    /// at `per-shard capacity + 1`, so [`Self::recommended_capacity`]
    /// is only a *lower bound* — raise the capacity and re-measure.
    pub fn saturated(&self) -> bool {
        self.evictions > 0
    }

    /// Capacity this trace's working set asks for: 2× the busiest
    /// shard's high-water mark (headroom for mix drift), rounded up to
    /// a power of two, across all shards — the number an operator (or
    /// the next default) should hand `PlanCache::new` / set in
    /// [`CAPACITY_ENV`]. When [`Self::saturated`] the hwm was clipped
    /// by eviction and this is a lower bound, not the full demand.
    pub fn recommended_capacity(&self) -> usize {
        let per_shard =
            (self.hwm_shard_max.max(1) * 2).next_power_of_two().clamp(8, 4096);
        per_shard * self.shards.max(1)
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("hits", (self.hits as usize).into()),
            ("misses", (self.misses as usize).into()),
            ("hit_rate", self.hit_rate().into()),
            ("builds", (self.builds as usize).into()),
            ("build_time_s", self.build_time_s.into()),
            ("evictions", (self.evictions as usize).into()),
            ("entries", self.entries.into()),
            ("shards", self.shards.into()),
            ("hwm_entries", self.hwm_entries.into()),
            ("hwm_shard_max", self.hwm_shard_max.into()),
            ("recommended_capacity", self.recommended_capacity().into()),
        ])
    }
}

impl PlanCache {
    /// A cache of at most `capacity` plans spread over `shards` shards.
    pub fn new(capacity: usize, shards: usize) -> Self {
        assert!(capacity > 0 && shards > 0, "positive capacity and shards");
        let shards = shards.min(capacity);
        Self {
            shards: (0..shards)
                .map(|_| Mutex::new(Shard { entries: Vec::new(), hwm: 0 }))
                .collect(),
            per_shard_capacity: capacity.div_ceil(shards),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            builds: AtomicU64::new(0),
            build_ns: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    fn shard_for(&self, key: &PlanKey) -> &Mutex<Shard> {
        let mut h = DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// The memoized lookup: a hit returns the shared plan (promoted to
    /// MRU); a miss builds it outside the shard lock, so concurrent
    /// lookups of *other* keys in the same shard proceed while the
    /// decomposition runs. Two threads racing on the same cold key may
    /// both build; the first insert wins and both get equivalent plans
    /// (builds are deterministic).
    pub fn get_or_build(
        &self,
        shape: GemmShape,
        block: BlockShape,
        bytes_per_elem: usize,
        cus: usize,
    ) -> Result<Arc<Plan>, ScheduleError> {
        self.get_or_build_key(PlanKey::new(shape, block, bytes_per_elem, cus))
    }

    /// Width-native spelling of [`Self::get_or_build`] — the tuner's
    /// width axis and the runtime's dtype routing come through here.
    pub fn get_or_build_w(
        &self,
        shape: GemmShape,
        block: BlockShape,
        width: crate::kernel::Width,
        cus: usize,
    ) -> Result<Arc<Plan>, ScheduleError> {
        self.get_or_build_key(PlanKey::new_w(shape, block, width, cus))
    }

    /// Memoized lookup of a Block2Time-weighted split: the per-CU weight
    /// vector is quantized into the key (fixed-point 1/256 of the
    /// fastest CU), so near-identical speed estimates reuse one plan
    /// instead of re-running the weighted decomposition per estimate.
    pub fn get_or_build_weighted(
        &self,
        shape: GemmShape,
        block: BlockShape,
        bytes_per_elem: usize,
        weights: &[f64],
    ) -> Result<Arc<Plan>, ScheduleError> {
        self.get_or_build_key(PlanKey::weighted(
            shape,
            block,
            bytes_per_elem,
            weights,
        ))
    }

    /// Core memoized lookup over a fully-formed key.
    pub fn get_or_build_key(
        &self,
        key: PlanKey,
    ) -> Result<Arc<Plan>, ScheduleError> {
        let shard = self.shard_for(&key);
        {
            let mut s = shard.lock().expect("plan shard");
            if let Some(idx) =
                s.entries.iter().position(|(k, _)| *k == key)
            {
                let entry = s.entries.remove(idx);
                let plan = entry.1.clone();
                s.entries.insert(0, entry);
                self.hits.fetch_add(1, Ordering::Relaxed);
                return Ok(plan);
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);

        let sw = Stopwatch::start();
        let plan = Arc::new(Plan::build(key.clone())?);
        self.builds.fetch_add(1, Ordering::Relaxed);
        self.build_ns.fetch_add(
            (sw.elapsed_secs() * 1e9) as u64,
            Ordering::Relaxed,
        );

        let mut s = shard.lock().expect("plan shard");
        if let Some(idx) = s.entries.iter().position(|(k, _)| *k == key) {
            // lost the build race: the winner's plan is canonical
            let entry = s.entries.remove(idx);
            let winner = entry.1.clone();
            s.entries.insert(0, entry);
            return Ok(winner);
        }
        s.entries.insert(0, (key, plan.clone()));
        // High-water mark before eviction: the shard's true demand.
        s.hwm = s.hwm.max(s.entries.len());
        if s.entries.len() > self.per_shard_capacity {
            s.entries.truncate(self.per_shard_capacity);
            self.evictions.fetch_add(1, Ordering::Relaxed);
        }
        Ok(plan)
    }

    /// Read-only probe (no promotion, no counter movement). Tests and
    /// the `streamk plan` inspector use this to see cache state without
    /// perturbing it.
    pub fn peek(
        &self,
        shape: GemmShape,
        block: BlockShape,
        bytes_per_elem: usize,
        cus: usize,
    ) -> Option<Arc<Plan>> {
        let key = PlanKey::new(shape, block, bytes_per_elem, cus);
        let s = self.shard_for(&key).lock().expect("plan shard");
        s.entries
            .iter()
            .find(|(k, _)| *k == key)
            .map(|(_, p)| p.clone())
    }

    /// Cached plans across all shards.
    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().expect("plan shard").entries.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> PlanCacheStats {
        let (mut entries, mut hwm_entries, mut hwm_shard_max) = (0, 0, 0);
        for shard in &self.shards {
            let s = shard.lock().expect("plan shard");
            entries += s.entries.len();
            hwm_entries += s.hwm;
            hwm_shard_max = hwm_shard_max.max(s.hwm);
        }
        PlanCacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            builds: self.builds.load(Ordering::Relaxed),
            build_time_s: self.build_ns.load(Ordering::Relaxed) as f64 / 1e9,
            evictions: self.evictions.load(Ordering::Relaxed),
            entries,
            shards: self.shards.len(),
            hwm_entries,
            hwm_shard_max,
        }
    }
}

/// Build every missing plan in `keys` concurrently over an
/// [`crate::exec::ThreadPool`] — cold-start warm-up for serving and the
/// benches. Warmed plans arrive fully materialized: the lazily-built
/// [`crate::kernel::ExecDesc`] is forced here so the first request per
/// shape pays neither the decomposition nor the descriptor (laziness
/// only benefits pricing-only paths that never warm). Returns how many
/// plans were built (keys already cached or unbuildable count as 0).
pub fn warm_parallel(
    cache: &Arc<PlanCache>,
    keys: &[PlanKey],
    threads: usize,
) -> usize {
    let before = cache.stats().builds;
    let shared = cache.clone();
    pool_map(threads, keys.to_vec(), move |key: PlanKey| {
        if let Ok(plan) = shared.get_or_build_key(key) {
            let _ = plan.exec();
        }
    });
    (cache.stats().builds - before) as usize
}

static GLOBAL: OnceLock<Arc<PlanCache>> = OnceLock::new();

fn env_capacity() -> Option<usize> {
    std::env::var(CAPACITY_ENV)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&c| c > 0)
}

/// The process-wide plan cache shared by the coordinator, the fleet
/// scheduler, the tuner, and the interpreter runtime. Capacity defaults
/// to the hwm-derived [`GLOBAL_PLANS_PER_SHARD`]`×`[`GLOBAL_SHARDS`];
/// [`CAPACITY_ENV`] overrides the total for wider shape mixes.
pub fn global() -> &'static Arc<PlanCache> {
    GLOBAL.get_or_init(|| {
        let capacity =
            env_capacity().unwrap_or(GLOBAL_PLANS_PER_SHARD * GLOBAL_SHARDS);
        Arc::new(PlanCache::new(capacity, GLOBAL_SHARDS))
    })
}

/// Initialize the process-wide cache with `total` capacity — the
/// `streamk serve` startup path, feeding [`load_hwm_capacity`]'s
/// recommendation in before anything touches [`global`]. Returns the
/// capacity actually applied — [`CAPACITY_ENV`] still wins over
/// `total` when set, so an operator override always beats the
/// persisted observation and the caller can report which source won —
/// or `None` (nothing changed) when the cache was already initialized.
pub fn init_global_with_capacity(total: usize) -> Option<usize> {
    let mut applied = None;
    GLOBAL.get_or_init(|| {
        let capacity = env_capacity().unwrap_or(total.max(GLOBAL_SHARDS));
        applied = Some(capacity);
        Arc::new(PlanCache::new(capacity, GLOBAL_SHARDS))
    });
    applied
}

/// Format version of the persisted hwm file ([`save_hwm`]).
const HWM_VERSION: usize = 1;

/// Persist one run's capacity-sizing observation: the distinct-key
/// high-water marks plus the capacity they recommend. `streamk serve`
/// writes this at shutdown and resizes from it at the next startup —
/// closing the "reported but not applied" gap on
/// [`PlanCacheStats::recommended_capacity`].
pub fn save_hwm(path: &Path, stats: &PlanCacheStats) -> std::io::Result<()> {
    let v = obj(vec![
        ("version", HWM_VERSION.into()),
        ("hwm_entries", stats.hwm_entries.into()),
        ("hwm_shard_max", stats.hwm_shard_max.into()),
        ("shards", stats.shards.into()),
        // A saturated run clipped its hwm at the bound: the
        // recommendation is a lower bound, still worth applying.
        ("saturated", stats.saturated().into()),
        ("recommended_capacity", stats.recommended_capacity().into()),
    ]);
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    std::fs::write(path, crate::json::to_string_pretty(&v))
}

/// Read a persisted hwm file's recommended capacity. `None` when the
/// file is missing, unparseable, from another format version, or
/// carries a degenerate capacity — the caller just falls back to the
/// default sizing (a stale observation must never wedge startup).
pub fn load_hwm_capacity(path: &Path) -> Option<usize> {
    let text = std::fs::read_to_string(path).ok()?;
    let v = crate::json::parse(&text).ok()?;
    if v.u("version").ok()? != HWM_VERSION {
        return None;
    }
    let cap = v.u("recommended_capacity").ok()?;
    (cap > 0).then_some(cap)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(m: usize, cus: usize) -> PlanKey {
        PlanKey::new(GemmShape::new(m, 512, 512), BlockShape::default(), 4, cus)
    }

    #[test]
    fn hit_after_miss_returns_the_same_plan() {
        let cache = PlanCache::new(16, 2);
        let shape = GemmShape::new(480, 512, 512);
        let a = cache
            .get_or_build(shape, BlockShape::default(), 4, 120)
            .unwrap();
        let b = cache
            .get_or_build(shape, BlockShape::default(), 4, 120)
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b), "hit must share the cached plan");
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.builds), (1, 1, 1));
        assert!(s.build_time_s >= 0.0);
        assert_eq!(s.entries, 1);
        assert!(s.hit_rate() > 0.49 && s.hit_rate() < 0.51);
    }

    #[test]
    fn distinct_grids_get_distinct_plans() {
        let cache = PlanCache::new(16, 4);
        let shape = GemmShape::new(1000, 1000, 1000);
        let a = cache
            .get_or_build(shape, BlockShape::default(), 4, 120)
            .unwrap();
        let b = cache
            .get_or_build(shape, BlockShape::default(), 4, 60)
            .unwrap();
        assert_eq!(a.key.cus, 120);
        assert_eq!(b.key.cus, 60);
        assert_eq!(cache.stats().builds, 2);
    }

    /// Satellite acceptance: LRU eviction at the shard bound.
    #[test]
    fn lru_evicts_oldest_within_a_shard() {
        // One shard, capacity 2: the third insert must evict the LRU.
        let cache = PlanCache::new(2, 1);
        let (k1, k2, k3) = (key(128, 8), key(256, 8), key(384, 8));
        for k in [&k1, &k2] {
            cache.get_or_build_key(k.clone()).unwrap();
        }
        // touch k1 so k2 becomes LRU
        cache.get_or_build_key(k1.clone()).unwrap();
        cache.get_or_build_key(k3.clone()).unwrap();
        assert_eq!(cache.len(), 2);
        assert!(cache.peek(k2.shape, k2.block, 4, 8).is_none(), "k2 evicted");
        assert!(cache.peek(k1.shape, k1.block, 4, 8).is_some());
        assert!(cache.peek(k3.shape, k3.block, 4, 8).is_some());
        let s = cache.stats();
        assert_eq!(s.evictions, 1);
        // hwm reads capacity + 1: the shard's demand exceeded capacity
        assert_eq!(s.hwm_shard_max, 3, "hwm measures demand, not residency");
        assert_eq!(s.entries, 2);
    }

    /// Satellite acceptance: the distinct-key high-water mark tracks
    /// peak demand per shard and drives the recommended capacity.
    #[test]
    fn hwm_tracks_peak_demand_and_sizes_capacity() {
        let cache = PlanCache::new(64, 2);
        assert_eq!(cache.stats().hwm_entries, 0);
        for i in 1..=6 {
            cache.get_or_build_key(key(i * 128, 8)).unwrap();
        }
        let s = cache.stats();
        assert_eq!(s.hwm_entries, 6, "no eviction: hwm == resident peak");
        assert!(s.hwm_shard_max >= 3, "2 shards over 6 keys: max >= 3");
        assert_eq!(s.shards, 2);
        // hits never move the hwm
        for i in 1..=6 {
            cache.get_or_build_key(key(i * 128, 8)).unwrap();
        }
        assert_eq!(cache.stats().hwm_entries, 6);
        let rec = s.recommended_capacity();
        assert_eq!(
            rec,
            (s.hwm_shard_max * 2).next_power_of_two() * 2,
            "2x busiest shard, pow2, times shards"
        );
        assert!(rec >= s.hwm_entries, "recommendation covers the demand");
    }

    /// Satellite acceptance: Block2Time-weighted splits get plan reuse
    /// through the quantized weight key.
    #[test]
    fn weighted_splits_share_plans_across_jittered_estimates() {
        let cache = PlanCache::new(16, 2);
        let shape = GemmShape::new(2048, 2048, 2048);
        let blk = BlockShape::default();
        let a = cache
            .get_or_build_weighted(shape, blk, 4, &[0.25, 1.0, 1.0, 1.0])
            .unwrap();
        // a fresh speed estimate, jittered below the quantum + scaled
        let b = cache
            .get_or_build_weighted(shape, blk, 4, &[0.5001, 2.0, 2.0, 2.0])
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b), "jittered estimate must hit");
        let s = cache.stats();
        assert_eq!((s.hits, s.builds), (1, 1));
        // the weighted plan and the even plan for the same shape coexist
        let even = cache.get_or_build(shape, blk, 4, 4).unwrap();
        assert!(!Arc::ptr_eq(&a, &even));
        assert_eq!(cache.len(), 2);
        // and a genuinely different split builds its own plan
        let c = cache
            .get_or_build_weighted(shape, blk, 4, &[1.0, 1.0, 1.0, 1.0])
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &c));
        // weighted split follows the weights: CU 1 gets ~4x CU 0's work
        let w0: f64 = a.cu_iters[0];
        let w1: f64 = a.cu_iters[1];
        assert!(
            (w1 / w0 - 4.0).abs() < 0.3,
            "weighted shares off: {w0} vs {w1}"
        );
    }

    /// Satellite acceptance: one cache shared across threads — every
    /// thread sees the same plan, the key builds once (or, under a
    /// cold-start race, at most once per racer with one canonical
    /// winner), and the steady state is all hits.
    #[test]
    fn cross_thread_sharing_builds_once_and_hits_after() {
        let cache = Arc::new(PlanCache::new(64, 4));
        let shape = GemmShape::new(1920, 2000, 2000);
        // Warm the key so the racing threads measure the *hit* path.
        let canonical = cache
            .get_or_build(shape, BlockShape::default(), 4, 120)
            .unwrap();
        let mut handles = Vec::new();
        for _ in 0..8 {
            let cache = cache.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                for _ in 0..50 {
                    got.push(
                        cache
                            .get_or_build(shape, BlockShape::default(), 4, 120)
                            .unwrap(),
                    );
                }
                got
            }));
        }
        for h in handles {
            for plan in h.join().expect("no panics") {
                assert!(
                    Arc::ptr_eq(&plan, &canonical),
                    "every thread shares the single cached plan"
                );
            }
        }
        let s = cache.stats();
        assert_eq!(s.builds, 1, "warm key never rebuilds");
        assert_eq!(s.hits, 8 * 50);
        assert_eq!(s.entries, 1);
    }

    #[test]
    fn warm_parallel_builds_cold_keys_once() {
        let cache = Arc::new(PlanCache::new(64, 4));
        let keys: Vec<PlanKey> =
            (1..=6).map(|i| key(i * 128, 120)).collect();
        let built = warm_parallel(&cache, &keys, 3);
        assert_eq!(built, 6);
        assert_eq!(cache.len(), 6);
        // warmed plans arrive with the lazy descriptor already forced
        for k in &keys {
            let p = cache.peek(k.shape, k.block, 4, k.cus).unwrap();
            assert!(p.exec_built(), "warm must materialize the desc");
        }
        // second warm is a no-op
        assert_eq!(warm_parallel(&cache, &keys, 3), 0);
    }

    /// Satellite acceptance: the hwm observation round-trips through
    /// disk and yields the capacity `streamk serve` auto-applies.
    #[test]
    fn hwm_file_round_trips_and_rejects_junk() {
        let cache = PlanCache::new(64, 2);
        for i in 1..=6 {
            cache.get_or_build_key(key(i * 128, 8)).unwrap();
        }
        let stats = cache.stats();
        let path = std::env::temp_dir().join(format!(
            "streamk-plan-hwm-{}.json",
            std::process::id()
        ));
        save_hwm(&path, &stats).unwrap();
        assert_eq!(
            load_hwm_capacity(&path),
            Some(stats.recommended_capacity()),
            "round trip must reproduce the recommendation"
        );
        // other format versions and junk come back as None, not errors
        std::fs::write(&path, r#"{"version": 99, "recommended_capacity": 8}"#)
            .unwrap();
        assert_eq!(load_hwm_capacity(&path), None);
        std::fs::write(&path, "not json").unwrap();
        assert_eq!(load_hwm_capacity(&path), None);
        std::fs::remove_file(&path).unwrap();
        assert_eq!(load_hwm_capacity(&path), None, "missing file is a miss");
    }

    #[test]
    fn degenerate_key_errors_without_poisoning_the_cache() {
        let cache = PlanCache::new(8, 1);
        assert!(cache
            .get_or_build(GemmShape::new(0, 1, 1), BlockShape::default(), 4, 8)
            .is_err());
        assert_eq!(cache.len(), 0);
        assert!(cache
            .get_or_build(
                GemmShape::new(64, 64, 64),
                BlockShape::default(),
                4,
                8
            )
            .is_ok());
    }
}
