//! GEMM launch simulation over per-CU work lists.

use super::device::Device;
use crate::decomp::tile::WorkItem;
use crate::decomp::{
    BlockShape, FlatSchedule, GemmShape, StreamKSchedule, TileGrid,
};

/// Timing breakdown of one simulated kernel launch.
#[derive(Debug, Clone, PartialEq)]
pub struct LaunchStats {
    /// Wall time of the launch (seconds), incl. launch overhead.
    pub time_s: f64,
    /// Per-CU busy seconds (compute only).
    pub cu_busy: Vec<f64>,
    /// Total HBM bytes moved.
    pub bytes: f64,
    /// True when HBM bandwidth, not compute, set the pace.
    pub memory_bound: bool,
}

/// Aggregate result over all launches of one GEMM execution.
#[derive(Debug, Clone, PartialEq)]
pub struct SimResult {
    pub shape: GemmShape,
    pub launches: Vec<LaunchStats>,
    pub total_s: f64,
    /// Mean CU utilization during compute launches: busy / (cus × span).
    pub utilization: f64,
    pub tflops: f64,
    pub gbps: f64,
}

/// Per-item HBM traffic: one A block + one B block per MAC iteration,
/// one C tile store per item (partial or final). Public so the plan
/// cache can precompute launch invariants at plan-build time.
pub fn item_bytes(item: &WorkItem, block: BlockShape, bpe: usize) -> f64 {
    let stream =
        item.k_iters * (block.bm * block.bk + block.bk * block.bn) * bpe;
    // Partials are written (and later re-read) in f32.
    let store = block.bm * block.bn * if item.partial { 4 } else { bpe };
    (stream + store) as f64
}

pub fn item_flops(item: &WorkItem, block: BlockShape) -> f64 {
    item.k_iters as f64 * block.flops_per_iter() as f64
}

/// Fraction of each systolic-array pass holding real data — blocks
/// smaller than the MXU tile waste the remainder (the report's
/// 16x16-per-XDL failure is the extreme of this).
pub fn mxu_fill(block: BlockShape, bpe: usize) -> f64 {
    crate::decomp::params::KernelParams::new(block, bpe)
        .mxu_utilization()
        .max(1e-3)
}

/// Simulate one launch of per-CU work lists on `dev`.
///
/// Completion model: compute finishes when the slowest CU finishes its
/// list; the launch additionally cannot beat total traffic / bandwidth
/// (bandwidth wall). Idle CUs contribute idle time — exactly Figure 1's
/// quantization loss.
pub fn simulate_launch(
    dev: &Device,
    work: &[Vec<WorkItem>],
    block: BlockShape,
    bpe: usize,
) -> LaunchStats {
    assert_eq!(work.len(), dev.num_cus, "work list per CU");
    let mut cu_busy = vec![0.0; dev.num_cus];
    let mut bytes = 0.0;
    let fill = mxu_fill(block, bpe);
    for (cu, items) in work.iter().enumerate() {
        let speed = dev.flops_per_cu * dev.cu_speed[cu] * fill;
        for item in items {
            cu_busy[cu] += item_flops(item, block) / speed
                + item.k_iters as f64 * dev.iter_overhead;
            bytes += item_bytes(item, block, bpe);
        }
    }
    let compute_span =
        cu_busy.iter().cloned().fold(0.0f64, f64::max);
    let mem_span = bytes / dev.hbm_bw;
    let memory_bound = mem_span > compute_span;
    LaunchStats {
        time_s: compute_span.max(mem_span) + dev.launch_overhead,
        cu_busy,
        bytes,
        memory_bound,
    }
}

/// Simulate one launch over a flattened (CSR) per-CU work arena —
/// same math as [`simulate_launch`], consuming slices instead of
/// nested Vecs. `offsets` has one row per CU plus the end sentinel.
pub fn simulate_launch_flat(
    dev: &Device,
    items: &[WorkItem],
    offsets: &[usize],
    block: BlockShape,
    bpe: usize,
) -> LaunchStats {
    assert_eq!(offsets.len(), dev.num_cus + 1, "offset row per CU");
    let mut cu_busy = vec![0.0; dev.num_cus];
    let mut bytes = 0.0;
    let fill = mxu_fill(block, bpe);
    for cu in 0..dev.num_cus {
        let speed = dev.flops_per_cu * dev.cu_speed[cu] * fill;
        for item in &items[offsets[cu]..offsets[cu + 1]] {
            cu_busy[cu] += item_flops(item, block) / speed
                + item.k_iters as f64 * dev.iter_overhead;
            bytes += item_bytes(item, block, bpe);
        }
    }
    let compute_span =
        cu_busy.iter().cloned().fold(0.0f64, f64::max);
    let mem_span = bytes / dev.hbm_bw;
    let memory_bound = mem_span > compute_span;
    LaunchStats {
        time_s: compute_span.max(mem_span) + dev.launch_overhead,
        cu_busy,
        bytes,
        memory_bound,
    }
}

/// Launch stats straight from precomputed per-CU invariants — the
/// plan-backed port of [`simulate_launch_flat`]: identical timing
/// model, but the per-item walk happened once at plan-build time
/// ([`crate::plan::Plan`] holds `cu_flops`/`cu_iters`/`bytes`), so the
/// reporting path replays nothing. Agrees with the item-walking replay
/// up to f64 summation order (the invariants are pre-summed).
pub fn launch_from_invariants(
    dev: &Device,
    cu_flops: &[f64],
    cu_iters: &[f64],
    bytes: f64,
    fill: f64,
) -> LaunchStats {
    assert_eq!(cu_flops.len(), dev.num_cus, "flops row per CU");
    assert_eq!(cu_iters.len(), dev.num_cus, "iters row per CU");
    let mut cu_busy = vec![0.0; dev.num_cus];
    for cu in 0..dev.num_cus {
        let speed = dev.flops_per_cu * dev.cu_speed[cu] * fill;
        cu_busy[cu] =
            cu_flops[cu] / speed + cu_iters[cu] * dev.iter_overhead;
    }
    let compute_span = cu_busy.iter().cloned().fold(0.0f64, f64::max);
    let mem_span = bytes / dev.hbm_bw;
    let memory_bound = mem_span > compute_span;
    LaunchStats {
        time_s: compute_span.max(mem_span) + dev.launch_overhead,
        cu_busy,
        bytes,
        memory_bound,
    }
}

/// Aggregate per-launch stats into a [`SimResult`] — public so the
/// plan cache's invariants-based reporting path composes with the same
/// accounting as the item-walking simulators.
pub fn finish_launches(
    dev: &Device,
    shape: GemmShape,
    launches: Vec<LaunchStats>,
) -> SimResult {
    finish(dev, shape, launches)
}

/// Simulate a full Stream-K execution from its flattened schedule:
/// phase-1 launch + (if any split tiles) the fixup launch.
pub fn simulate_flat(
    dev: &Device,
    shape: GemmShape,
    flat: &FlatSchedule,
    block: BlockShape,
    bpe: usize,
) -> SimResult {
    assert_eq!(dev.num_cus, flat.p, "schedule built for different CU count");
    let mut launches = vec![simulate_launch_flat(
        dev,
        &flat.items,
        &flat.item_offsets,
        block,
        bpe,
    )];
    // Fixup: each split tile re-reads its contributors' partials
    // (modeled as `partial` C-tile traffic) and writes the final tile.
    // Tiny traffic-dominated launch.
    if flat.has_fixup() {
        launches.push(simulate_launch_flat(
            dev,
            &flat.fixup_items,
            &flat.fixup_offsets,
            block,
            bpe,
        ));
    }
    finish(dev, shape, launches)
}

/// Simulate a full Stream-K execution: flattens the nested schedule
/// once and replays it through [`simulate_flat`]. Hot paths should
/// cache the [`FlatSchedule`] (see [`crate::plan`]) instead of
/// re-flattening per call.
pub fn simulate_streamk(
    dev: &Device,
    sched: &StreamKSchedule,
    bpe: usize,
) -> SimResult {
    assert_eq!(dev.num_cus, sched.p, "schedule built for different CU count");
    let flat = FlatSchedule::from_schedule(sched);
    simulate_flat(dev, sched.shape, &flat, sched.block, bpe)
}

/// Simulate a data-parallel or split-k execution from its assignment.
/// For split-k (`partial` items present) a reduction launch is appended.
pub fn simulate(
    dev: &Device,
    shape: GemmShape,
    grid: TileGrid,
    work: Vec<Vec<WorkItem>>,
    block: BlockShape,
    bpe: usize,
) -> SimResult {
    let has_partials = work.iter().flatten().any(|w| w.partial);
    let mut launches = vec![simulate_launch(dev, &work, block, bpe)];
    if has_partials {
        // Reduction: read every partial once, write every tile once.
        let mut red_work: Vec<Vec<WorkItem>> = vec![Vec::new(); dev.num_cus];
        for (i, w) in work
            .iter()
            .flatten()
            .filter(|w| w.partial)
            .enumerate()
        {
            red_work[i % dev.num_cus].push(WorkItem {
                tile: w.tile,
                k_iters: 0,
                partial: true,
            });
        }
        for t in 0..grid.num_tiles() {
            red_work[t % dev.num_cus].push(WorkItem {
                tile: t,
                k_iters: 0,
                partial: false,
            });
        }
        launches.push(simulate_launch(dev, &red_work, block, bpe));
    }
    finish(dev, shape, launches)
}

fn finish(dev: &Device, shape: GemmShape, launches: Vec<LaunchStats>) -> SimResult {
    let total_s: f64 = launches.iter().map(|l| l.time_s).sum();
    let busy: f64 = launches
        .iter()
        .map(|l| l.cu_busy.iter().sum::<f64>())
        .sum();
    let span: f64 = launches
        .iter()
        .map(|l| l.time_s - dev.launch_overhead)
        .sum();
    let utilization = if span > 0.0 {
        (busy / (dev.num_cus as f64 * span)).min(1.0)
    } else {
        1.0
    };
    let bytes: f64 = launches.iter().map(|l| l.bytes).sum();
    SimResult {
        shape,
        total_s,
        utilization,
        tflops: shape.flops() as f64 / total_s / 1e12,
        gbps: bytes / total_s / 1e9,
        launches,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::swizzle::Swizzle;
    use crate::decomp::{build_schedule, tile};
    use crate::gpu_sim::device::DeviceKind;

    fn mi200() -> Device {
        Device::preset(DeviceKind::Mi200)
    }

    fn dp_sim(m: usize, n: usize, k: usize, dev: &Device) -> SimResult {
        let shape = GemmShape::new(m, n, k);
        let block = BlockShape::default().effective(shape);
        let grid = TileGrid::new(shape, block);
        let work = tile::dp_assignment(grid, dev.num_cus, Swizzle::RowMajor);
        simulate(dev, shape, grid, work, block, 4)
    }

    fn sk_sim(m: usize, n: usize, k: usize, dev: &Device) -> SimResult {
        let shape = GemmShape::new(m, n, k);
        let s = build_schedule(shape, BlockShape::default(), dev.num_cus)
            .unwrap();
        simulate_streamk(dev, &s, 4)
    }

    #[test]
    fn full_wave_dp_is_fully_utilized() {
        // 960 tiles on 120 CUs = 8 exact waves.
        let r = dp_sim(3840, 4096, 4096, &mi200());
        assert!(r.utilization > 0.99, "{}", r.utilization);
        assert!(r.tflops > 1.0);
    }

    #[test]
    fn partial_wave_dp_loses_utilization() {
        // 961 tiles on 120 CUs: 9th wave has 1 tile.
        let r = dp_sim(3840 + 128, 4096, 4096, &mi200());
        assert!(r.utilization < 0.95, "{}", r.utilization);
        // Stream-K recovers it.
        let sk = sk_sim(3840 + 128, 4096, 4096, &mi200());
        assert!(sk.utilization > 0.98, "{}", sk.utilization);
        assert!(sk.total_s < r.total_s);
    }

    #[test]
    fn streamk_matches_dp_on_aligned_shapes() {
        // When DP has no quantization loss, stream-k shouldn't be
        // meaningfully slower (same work, same traffic + fixup ε).
        let dp = dp_sim(3840, 4096, 4096, &mi200());
        let sk = sk_sim(3840, 4096, 4096, &mi200());
        let ratio = sk.total_s / dp.total_s;
        assert!(ratio < 1.02, "ratio {ratio}");
    }

    #[test]
    fn tiny_gemm_is_fast_and_single_cu() {
        let r = sk_sim(3, 9, 9, &mi200());
        assert!(r.total_s < 1e-3);
        // one MAC iteration: exactly one CU does any work at all
        let busy = r.launches[0].cu_busy.iter().filter(|&&b| b > 0.0).count();
        assert_eq!(busy, 1);
        // device-level roofline still calls this shape memory-bound
        use crate::decomp::intensity;
        let ai = intensity::arithmetic_intensity(GemmShape::new(3, 9, 9), 4);
        assert!(!intensity::MI200.compute_bound(ai));
    }

    #[test]
    fn cu_scaling_monotonic() {
        // More CUs never slows the same problem down.
        let mut last = f64::INFINITY;
        for cus in [1usize, 8, 30, 60, 120] {
            let dev = mi200().with_cus(cus);
            let r = sk_sim(1920, 2000, 2000, &dev);
            assert!(
                r.total_s <= last * 1.0001,
                "cus={cus}: {} > {last}",
                r.total_s
            );
            last = r.total_s;
        }
    }

    #[test]
    fn throttled_device_slows_even_split() {
        let dev = mi200();
        let slow = mi200().with_throttled(2, 0.25);
        let fast = sk_sim(3840, 4096, 4096, &dev);
        let thr = sk_sim(3840, 4096, 4096, &slow);
        // Even split waits on the slowest CU: ~4x slowdown.
        assert!(thr.total_s > fast.total_s * 3.0);
    }

    #[test]
    fn invariant_launch_matches_item_walk() {
        // Pre-summed invariants vs the per-item replay: same model, f64
        // summation order apart.
        let dev = mi200().with_throttled(3, 0.5);
        let s = build_schedule(
            GemmShape::new(1000, 1000, 1000),
            BlockShape::default(),
            dev.num_cus,
        )
        .unwrap();
        let flat = FlatSchedule::from_schedule(&s);
        let walked =
            simulate_launch_flat(&dev, &flat.items, &flat.item_offsets, s.block, 4);
        let fill = mxu_fill(s.block, 4);
        let mut cu_flops = vec![0.0f64; dev.num_cus];
        let mut cu_iters = vec![0.0f64; dev.num_cus];
        let mut bytes = 0.0f64;
        for cu in 0..dev.num_cus {
            for item in flat.cu_items(cu) {
                cu_flops[cu] += item_flops(item, s.block);
                cu_iters[cu] += item.k_iters as f64;
                bytes += item_bytes(item, s.block, 4);
            }
        }
        let fast = launch_from_invariants(&dev, &cu_flops, &cu_iters, bytes, fill);
        assert!(
            (fast.time_s - walked.time_s).abs() <= walked.time_s * 1e-12,
            "{} vs {}",
            fast.time_s,
            walked.time_s
        );
        assert_eq!(fast.memory_bound, walked.memory_bound);
        assert_eq!(fast.bytes, walked.bytes);
        for (a, b) in fast.cu_busy.iter().zip(&walked.cu_busy) {
            assert!((a - b).abs() <= b.abs() * 1e-12 + 1e-18, "{a} vs {b}");
        }
        // and the aggregate accounting path is shared
        let agg = finish_launches(
            &dev,
            GemmShape::new(1000, 1000, 1000),
            vec![fast.clone()],
        );
        assert_eq!(agg.launches.len(), 1);
        assert_eq!(agg.total_s, fast.time_s);
    }

    #[test]
    fn launch_overhead_counted_per_launch() {
        let dev = Device::uniform("t", 4, 1e12, 1e12, 1.0); // 1 s overhead!
        let r = sk_sim(1000, 1000, 1000, &dev);
        assert!(r.total_s > r.launches.len() as f64);
    }
}
