//! Host↔device transfer model — the report's hipMemcpy future-work item.
//!
//! `time(bytes) = base_latency + bytes / bw`, with pinned-memory and
//! chunked-overlap variants. The MEMCPY bench sweeps sizes and prints the
//! latency/bandwidth curve plus the overlap crossover; the real-PJRT
//! counterpart is measured in the same bench for comparison.

/// Transfer link presets (seconds, bytes/s).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Link {
    pub base_latency: f64,
    pub bandwidth: f64,
}

/// PCIe 4.0 x16 pageable-memory host→device (the hipMemcpy default).
pub const PCIE4_PAGEABLE: Link =
    Link { base_latency: 10.0e-6, bandwidth: 12.0e9 };
/// PCIe 4.0 x16 with pinned host memory.
pub const PCIE4_PINNED: Link =
    Link { base_latency: 8.0e-6, bandwidth: 24.0e9 };

impl Link {
    pub fn time(&self, bytes: usize) -> f64 {
        self.base_latency + bytes as f64 / self.bandwidth
    }

    /// Effective bandwidth at a given size (the classic latency-limited
    /// small-transfer curve).
    pub fn effective_bw(&self, bytes: usize) -> f64 {
        bytes as f64 / self.time(bytes)
    }

    /// Chunked transfer overlapped with compute of `compute_s`:
    /// pipeline fill + max(stream, compute) per chunk.
    pub fn overlapped_time(
        &self,
        bytes: usize,
        chunks: usize,
        compute_s: f64,
    ) -> f64 {
        let chunks = chunks.max(1);
        let chunk_bytes = bytes.div_ceil(chunks);
        let chunk_xfer = self.time(chunk_bytes);
        let chunk_compute = compute_s / chunks as f64;
        chunk_xfer + (chunks - 1) as f64 * chunk_xfer.max(chunk_compute)
            + chunk_compute
    }
}

/// GEMM operand bytes that must cross the link once per problem.
pub fn gemm_h2d_bytes(m: usize, n: usize, k: usize, bpe: usize) -> usize {
    (m * k + k * n) * bpe
}

pub fn gemm_d2h_bytes(m: usize, n: usize, bpe: usize) -> usize {
    m * n * bpe
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_dominates_small_transfers() {
        let t_small = PCIE4_PAGEABLE.time(64);
        assert!((t_small - PCIE4_PAGEABLE.base_latency).abs() < 1e-6);
        assert!(PCIE4_PAGEABLE.effective_bw(64) < 0.01 * PCIE4_PAGEABLE.bandwidth);
    }

    #[test]
    fn bandwidth_dominates_large_transfers() {
        let bytes = 1 << 30;
        let eff = PCIE4_PAGEABLE.effective_bw(bytes);
        assert!(eff > 0.99 * PCIE4_PAGEABLE.bandwidth);
    }

    #[test]
    fn pinned_beats_pageable() {
        for bytes in [1usize << 10, 1 << 20, 1 << 28] {
            assert!(PCIE4_PINNED.time(bytes) < PCIE4_PAGEABLE.time(bytes));
        }
    }

    #[test]
    fn overlap_hides_transfer_behind_compute() {
        let bytes = 1 << 26; // 64 MiB, ~5.6 ms on pageable
        let compute = 0.02; // 20 ms of compute
        let serial = PCIE4_PAGEABLE.time(bytes) + compute;
        let overlapped = PCIE4_PAGEABLE.overlapped_time(bytes, 8, compute);
        assert!(overlapped < serial);
        // Can't beat compute alone + one chunk of fill.
        assert!(overlapped > compute);
    }

    #[test]
    fn too_many_chunks_pay_latency() {
        let bytes = 1 << 16; // small transfer
        let few = PCIE4_PAGEABLE.overlapped_time(bytes, 2, 0.0);
        let many = PCIE4_PAGEABLE.overlapped_time(bytes, 64, 0.0);
        assert!(many > few); // 64 latencies vs 2
    }

    #[test]
    fn gemm_traffic() {
        assert_eq!(gemm_h2d_bytes(2, 3, 4, 4), (8 + 12) * 4);
        assert_eq!(gemm_d2h_bytes(2, 3, 4), 24);
    }
}
