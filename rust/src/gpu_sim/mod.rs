//! GPU occupancy/timing simulator — the hardware substitute.
//!
//! The report's experiments ran on an AMD MI200 (120 CUs). We have no
//! MI200; the decomposition phenomena the paper studies (quantization
//! cliffs, padding overhead, CU sweeps, Block2Time balancing) are
//! *schedule* properties, so a two-resource roofline simulator over the
//! per-CU work lists reproduces their shape faithfully (DESIGN.md §2).
//!
//! Model: a kernel launch completes at
//! `max(slowest-CU compute time, total HBM traffic / bandwidth) + launch
//! overhead`; per-CU busy time gives the utilization bars of Figure 1.
//! CUs can be heterogeneous (per-CU speed factors) to exercise the
//! Block2Time predictive balancer.

pub mod device;
pub mod gemm;
pub mod xfer;

pub use device::{Device, DeviceKind};
pub use gemm::{
    finish_launches, launch_from_invariants, simulate, simulate_flat,
    simulate_launch_flat, simulate_streamk, LaunchStats, SimResult,
};
