//! Device models: an MI200-like accelerator and variants.

/// Built-in device presets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeviceKind {
    /// MI250X single die: 110 CUs in hardware; the report's examples used
    /// 120 (MI200-family max), which we keep for fidelity to Table 1.
    Mi200,
    /// MI100: 120 CUs at lower clock/bandwidth.
    Mi100,
}

/// A simulated accelerator.
#[derive(Debug, Clone, PartialEq)]
pub struct Device {
    pub name: String,
    pub num_cus: usize,
    /// Peak MAC-FLOP/s per CU (f32-equivalent matrix throughput).
    pub flops_per_cu: f64,
    /// HBM bandwidth shared by all CUs (bytes/s).
    pub hbm_bw: f64,
    /// Fixed kernel-launch overhead (seconds).
    pub launch_overhead: f64,
    /// Per-MAC-iteration fixed cost (software pipelining, address
    /// generation, LDS/VMEM turnaround): what makes small BK blocks
    /// amortize worse. Zero for idealized custom devices.
    pub iter_overhead: f64,
    /// Per-CU relative speed (1.0 = nominal). Heterogeneity models
    /// thermal throttling / shared-cluster noise; drives Block2Time.
    pub cu_speed: Vec<f64>,
}

impl Device {
    pub fn preset(kind: DeviceKind) -> Self {
        match kind {
            // 45 TFLOP/s fp32 matrix ÷ 120 CUs, 1.6 TB/s, ~6 µs launch,
            // ~150 ns of fixed work per MAC iteration.
            DeviceKind::Mi200 => Self::uniform(
                "mi200", 120, 45.0e12 / 120.0, 1.6e12, 6.0e-6,
            )
            .with_iter_overhead(150.0e-9),
            DeviceKind::Mi100 => Self::uniform(
                "mi100", 120, 23.0e12 / 120.0, 1.2e12, 6.0e-6,
            )
            .with_iter_overhead(180.0e-9),
        }
    }

    pub fn uniform(
        name: &str,
        num_cus: usize,
        flops_per_cu: f64,
        hbm_bw: f64,
        launch_overhead: f64,
    ) -> Self {
        assert!(num_cus > 0);
        Self {
            name: name.to_string(),
            num_cus,
            flops_per_cu,
            hbm_bw,
            launch_overhead,
            iter_overhead: 0.0,
            cu_speed: vec![1.0; num_cus],
        }
    }

    pub fn with_iter_overhead(mut self, seconds: f64) -> Self {
        assert!(seconds >= 0.0);
        self.iter_overhead = seconds;
        self
    }

    /// Restrict to the first `cus` CUs — the report's CLI "Compute Units"
    /// parameter (the one that triggered the CK bug).
    pub fn with_cus(mut self, cus: usize) -> Self {
        assert!(cus > 0 && cus <= self.num_cus, "cus {cus} out of range");
        self.num_cus = cus;
        self.cu_speed.truncate(cus);
        self
    }

    /// Inject heterogeneity: CU `i` runs at `speeds[i]`× nominal.
    pub fn with_speeds(mut self, speeds: Vec<f64>) -> Self {
        assert_eq!(speeds.len(), self.num_cus);
        assert!(speeds.iter().all(|&s| s > 0.0));
        self.cu_speed = speeds;
        self
    }

    /// Deterministic "shared cluster" throttling pattern used by the
    /// Block2Time bench: every `stride`-th CU runs at `factor`× speed.
    pub fn with_throttled(mut self, stride: usize, factor: f64) -> Self {
        assert!(stride > 0 && factor > 0.0);
        for (i, s) in self.cu_speed.iter_mut().enumerate() {
            if i % stride == 0 {
                *s = factor;
            }
        }
        self
    }

    /// Scale every CU's throughput by `factor` — models a binned /
    /// power-capped part of the same family. Distinct fingerprint
    /// (flops enters the fingerprint), so fleet caches never mix the
    /// fast and slow bins.
    pub fn with_flops_scale(mut self, factor: f64) -> Self {
        assert!(factor > 0.0 && factor.is_finite(), "bad scale {factor}");
        self.flops_per_cu *= factor;
        self
    }

    pub fn renamed(mut self, name: &str) -> Self {
        self.name = name.to_string();
        self
    }

    /// Parse one fleet device spec: `<kind>[:<cus>][x<scale>]`, e.g.
    /// `mi200`, `mi100:60`, `mi200x0.5`, `mi200:96x0.75`. Kinds are the
    /// built-in presets.
    pub fn parse_spec(spec: &str) -> Result<Self, String> {
        let (head, scale) = match spec.split_once('x') {
            Some((h, s)) => {
                let f: f64 = s
                    .parse()
                    .map_err(|_| format!("bad speed scale in {spec:?}"))?;
                if !(f > 0.0 && f.is_finite()) {
                    return Err(format!("bad speed scale in {spec:?}"));
                }
                (h, f)
            }
            None => (spec, 1.0),
        };
        let (kind_str, cus) = match head.split_once(':') {
            Some((k, c)) => {
                let n: usize = c
                    .parse()
                    .map_err(|_| format!("bad CU count in {spec:?}"))?;
                (k, Some(n))
            }
            None => (head, None),
        };
        let mut dev = match kind_str {
            "mi200" => Device::preset(DeviceKind::Mi200),
            "mi100" => Device::preset(DeviceKind::Mi100),
            other => {
                return Err(format!(
                    "unknown device kind {other:?} (want mi200|mi100)"
                ))
            }
        };
        if let Some(n) = cus {
            if n == 0 || n > dev.num_cus {
                return Err(format!(
                    "cus {n} out of range 1..={} for {kind_str}",
                    dev.num_cus
                ));
            }
            dev = dev.with_cus(n);
        }
        if scale != 1.0 {
            dev = dev.with_flops_scale(scale);
        }
        Ok(dev)
    }

    /// Parse a comma-separated fleet spec list (`mi200,mi200x0.5,mi100`).
    pub fn parse_fleet_spec(specs: &str) -> Result<Vec<Self>, String> {
        let devices: Vec<Self> = specs
            .split(',')
            .map(str::trim)
            .filter(|s| !s.is_empty())
            .map(Self::parse_spec)
            .collect::<Result<_, _>>()?;
        if devices.is_empty() {
            return Err("empty fleet spec".to_string());
        }
        Ok(devices)
    }

    pub fn peak_flops(&self) -> f64 {
        self.flops_per_cu * self.cu_speed.iter().sum::<f64>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets() {
        let d = Device::preset(DeviceKind::Mi200);
        assert_eq!(d.num_cus, 120);
        assert!((d.peak_flops() - 45.0e12).abs() / 45.0e12 < 1e-12);
    }

    #[test]
    fn with_cus_truncates() {
        let d = Device::preset(DeviceKind::Mi200).with_cus(30);
        assert_eq!(d.num_cus, 30);
        assert_eq!(d.cu_speed.len(), 30);
        assert!((d.peak_flops() - 45.0e12 / 4.0).abs() < 1.0);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn with_cus_rejects_oversubscription() {
        let _ = Device::preset(DeviceKind::Mi200).with_cus(121);
    }

    #[test]
    fn throttling_pattern() {
        let d = Device::uniform("t", 8, 1.0, 1.0, 0.0).with_throttled(4, 0.5);
        assert_eq!(d.cu_speed, vec![0.5, 1.0, 1.0, 1.0, 0.5, 1.0, 1.0, 1.0]);
    }

    #[test]
    fn flops_scale_halves_peak() {
        let d = Device::preset(DeviceKind::Mi200).with_flops_scale(0.5);
        assert!((d.peak_flops() - 22.5e12).abs() / 22.5e12 < 1e-12);
    }

    #[test]
    fn spec_parsing_round_trips_the_fleet_forms() {
        let d = Device::parse_spec("mi200").unwrap();
        assert_eq!((d.name.as_str(), d.num_cus), ("mi200", 120));
        let d = Device::parse_spec("mi100:60").unwrap();
        assert_eq!((d.name.as_str(), d.num_cus), ("mi100", 60));
        let d = Device::parse_spec("mi200x0.5").unwrap();
        assert!((d.peak_flops() - 22.5e12).abs() < 1.0);
        let d = Device::parse_spec("mi200:96x0.75").unwrap();
        assert_eq!(d.num_cus, 96);
        assert!((d.flops_per_cu - 0.75 * 45.0e12 / 120.0).abs() < 1.0);

        for bad in ["", "h100", "mi200:0", "mi200:121", "mi200x0",
                    "mi200xfast", "mi200:many"] {
            assert!(Device::parse_spec(bad).is_err(), "{bad:?}");
        }

        let fleet =
            Device::parse_fleet_spec("mi200, mi200x0.5 ,mi100:60").unwrap();
        assert_eq!(fleet.len(), 3);
        assert!(Device::parse_fleet_spec("  ,").is_err());
    }
}
