//! Two-phase search: Block2Time-predicted ranking, then measured
//! refinement of the top-K under a hard budget.
//!
//! Phase 1 fits a `predict::CostModel` from a handful of probe launches
//! on the simulator (the Block2Time idea: predict runtime from work
//! counts instead of measuring everything) and ranks every legal
//! candidate by predicted time. Phase 2 measures only the top-K on
//! `gpu_sim`, each measurement gated by a budget check — the paper's
//! runs "got stuck" when a bad parameter point ran unbounded; here no
//! point can consume more than its slice, and a budget exhaustion is a
//! *reported outcome*, not a hang.

use super::space::{enumerate, Candidate, PadPolicy, SpaceStats};
use crate::decomp::{cdiv, GemmShape};
use crate::exec::Stopwatch;
use crate::gpu_sim::Device;
use crate::kernel::Width;
use crate::predict::{fit, CostModel};
use std::time::Duration;

/// Hard limits for one tune run.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Budget {
    /// Measured (simulated) candidates at most.
    pub max_measurements: usize,
    /// Wall-clock ceiling for the whole run.
    pub max_time: Duration,
}

impl Default for Budget {
    fn default() -> Self {
        Self { max_measurements: 64, max_time: Duration::from_millis(250) }
    }
}

impl Budget {
    pub fn from_millis(ms: u64) -> Self {
        Self { max_time: Duration::from_millis(ms), ..Self::default() }
    }
}

/// Tuning options.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TuneOptions {
    /// Candidates promoted from predicted ranking to measurement.
    pub top_k: usize,
    pub budget: Budget,
    /// Element width the search runs at. One tune run explores one
    /// width; callers sweeping the axis tune per width and compare
    /// measured times (each width has its own cache key).
    pub width: Width,
    /// Price phase-2 candidates off wall-clock blocked-executor runs
    /// on this host instead of the simulator (`streamk tune
    /// --measure`). The simulator cannot see CPU-locality knobs — `kc`
    /// and the register block price identically there — so real
    /// measurement is what makes those axes discriminating.
    pub measure_cpu: bool,
}

impl Default for TuneOptions {
    fn default() -> Self {
        Self {
            top_k: 8,
            budget: Budget::default(),
            width: Width::F32,
            measure_cpu: false,
        }
    }
}

impl TuneOptions {
    /// Streamed bytes per panel element at the search width.
    pub fn bytes_per_elem(&self) -> usize {
        self.width.bytes()
    }
}

/// The winning configuration for one (shape bucket, device) key.
///
/// `predicted_s` starts as the Block2Time model's estimate and is
/// *refined online*: every measured serving latency folded back through
/// [`crate::tuner::Tuner::observe`] blends it toward reality, so the
/// fleet scheduler's completion estimates tighten as traffic flows.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TunedConfig {
    pub params: crate::decomp::params::KernelParams,
    pub pad: PadPolicy,
    pub cus: usize,
    pub predicted_s: f64,
    pub measured_s: f64,
    /// EWMA of measured request latencies observed while serving
    /// (0.0 until `observed_n > 0`).
    pub observed_s: f64,
    /// How many serving observations have been folded in.
    pub observed_n: u64,
}

/// Everything a tune run did, for observability and the bench tables.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneReport {
    pub shape: GemmShape,
    pub best: TunedConfig,
    /// Simulated time of the default `KernelParams::new` config on the
    /// same device — the baseline the tuner must not lose to.
    pub default_s: f64,
    pub space: SpaceStats,
    /// Candidates actually measured (≤ top_k, ≤ budget).
    pub measured: usize,
    /// Candidates the budget cut before measurement.
    pub skipped_by_budget: usize,
    pub elapsed_s: f64,
    pub budget_exhausted: bool,
}

impl TuneReport {
    pub fn speedup(&self) -> f64 {
        if self.best.measured_s > 0.0 {
            self.default_s / self.best.measured_s
        } else {
            1.0
        }
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TuneError {
    Degenerate(String),
    NoLegalCandidate,
}

impl std::fmt::Display for TuneError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TuneError::Degenerate(what) => {
                write!(f, "cannot tune degenerate problem {what}")
            }
            TuneError::NoLegalCandidate => {
                write!(f, "legality pruning left no candidate to tune")
            }
        }
    }
}

impl std::error::Error for TuneError {}

/// Analytic work counts for one candidate (no schedule materialization —
/// phase 1 must stay cheap enough to rank hundreds of points).
fn work_counts(shape: GemmShape, c: &Candidate) -> (usize, f64) {
    let block = c.params.block.effective(shape);
    let tiles = cdiv(shape.m, block.bm) * cdiv(shape.n, block.bn);
    let ipt = cdiv(shape.k, block.bk);
    let p = c.cus;
    let w = tiles / p;
    let dp_tiles = w.saturating_sub(1) * p;
    let sk_iters = (tiles - dp_tiles) * ipt;
    // Slowest CU under the hybrid split.
    let max_iters = (dp_tiles / p) * ipt + cdiv(sk_iters, p);
    let bytes = (tiles * ipt * (block.bm * block.bk + block.bk * block.bn))
        as f64
        * c.params.bytes_per_elem() as f64
        + (tiles * block.bm * block.bn * c.params.bytes_per_elem()) as f64;
    (max_iters, bytes)
}

/// Physical padding's extra HBM traffic (the Table-1 model): the pad
/// memcpy of A and B plus the inflated streaming reads.
fn pad_penalty_bytes(shape: GemmShape, c: &Candidate) -> f64 {
    if c.pad == PadPolicy::None {
        return 0.0;
    }
    let block = c.params.block.effective(shape);
    let (m, n, k) = (shape.m, shape.n, shape.k);
    let mp = cdiv(m, block.bm) * block.bm;
    let np = cdiv(n, block.bn) * block.bn;
    let kp = cdiv(k, block.bk) * block.bk;
    c.params.bytes_per_elem() as f64
        * ((mp * kp + kp * np) + (mp * kp - m * k) + (kp * np - k * n)) as f64
}

/// MXU-normalized work units: MAC iterations deflated by systolic-array
/// fill, so a 32-wide block "costs" 4× its raw iterations. This is the
/// x axis the Block2Time cost model is fit against.
fn equiv_units(c: &Candidate, shape: GemmShape, max_iters: usize) -> usize {
    let block = c.params.block.effective(shape);
    let mut p = c.params;
    p.block = block;
    let fill = p.mxu_utilization().max(1e-3);
    let flops = max_iters as f64 * block.flops_per_iter() as f64;
    (flops / fill) as usize
}

/// Measure one candidate on the simulator. Returns `None` when the
/// schedule cannot be built (degenerate interplay of block and shape).
///
/// Goes through the process-wide plan cache ([`crate::plan::global`]):
/// the decomposition + flattening runs once per (shape, block, width,
/// grid) key and every later measurement — the tuner's top-K loop, a
/// re-validation probe, every fleet-sim request in that bucket — is an
/// allocation-free replay of the cached [`crate::plan::Plan`].
/// Sub-maximal-grid candidates price through
/// [`crate::plan::Plan::time_on_prefix`], so even they clone nothing.
pub fn measure(
    dev: &Device,
    shape: GemmShape,
    c: &Candidate,
) -> Option<f64> {
    let plan = crate::plan::global()
        .get_or_build_w(shape, c.params.block, c.params.width, c.cus)
        .ok()?;
    let pad_s = pad_penalty_bytes(shape, c) / dev.hbm_bw;
    Some(plan.time_on_prefix(dev) + pad_s)
}

/// Measure one candidate by actually running the blocked executor on
/// this host (`streamk tune --measure`): real packing, real lanes, real
/// caches — wall-clock truth for the axes the simulator is blind to
/// (`kc`, register block, element width). The operand buffers are
/// generated once per tune run and shared across candidates; `kc` and
/// the register block thread through [`crate::kernel::ExecOpts`] so the
/// cached plan descriptor is reused unmodified.
pub fn measure_cpu(
    a: &[f32],
    b: &[f32],
    shape: GemmShape,
    c: &Candidate,
) -> Option<f64> {
    let plan = crate::plan::global()
        .get_or_build_w(shape, c.params.block, c.params.width, c.cus)
        .ok()?;
    let desc = plan.exec();
    let opts = crate::kernel::ExecOpts {
        kc: Some(c.params.kc),
        reg: Some(c.params.reg),
        ..crate::kernel::ExecOpts::auto(desc.macs)
    };
    let sw = Stopwatch::start();
    let out = crate::kernel::execute_opts(
        a,
        b,
        desc,
        crate::kernel::Epilogue::None,
        &opts,
    );
    let t = sw.elapsed_secs();
    std::hint::black_box(&out);
    Some(t)
}

/// Fit the Block2Time cost model from probe launches of the default
/// config at three K depths. Falls back to the analytic roofline slope
/// when the fit is degenerate (e.g. a problem so small every probe
/// collapses to one iteration).
fn probe_cost_model(
    dev: &Device,
    shape: GemmShape,
    width: Width,
) -> CostModel {
    let default = Candidate {
        params: crate::decomp::params::KernelParams::new_w(
            crate::decomp::BlockShape::default(),
            width,
        ),
        pad: PadPolicy::None,
        cus: dev.num_cus,
    };
    let mut samples = Vec::new();
    for scale in [4usize, 2, 1] {
        let probe = GemmShape::new(
            shape.m,
            shape.n,
            (shape.k / scale).max(1),
        );
        let (max_iters, _) = work_counts(probe, &default);
        let x = equiv_units(&default, probe, max_iters);
        if let Some(t) = measure(dev, probe, &default) {
            // Deduct the explicit per-iteration overhead so `a` models
            // pure MXU throughput; ranking adds the overhead back per
            // candidate (it scales with iteration *count*, not flops).
            let y = t - max_iters as f64 * dev.iter_overhead;
            samples.push((x, y.max(0.0)));
        }
    }
    fit(&samples).unwrap_or(CostModel {
        a: 1.0 / (dev.flops_per_cu * dev.num_cus as f64),
        b: dev.launch_overhead,
    })
}

/// Predicted time of one candidate under the fitted cost model, with a
/// bandwidth floor and the padding penalty.
fn predicted(
    model: &CostModel,
    dev: &Device,
    shape: GemmShape,
    c: &Candidate,
) -> f64 {
    let (max_iters, bytes) = work_counts(shape, c);
    let x = equiv_units(c, shape, max_iters);
    let compute = model.predict(x) + max_iters as f64 * dev.iter_overhead;
    let pad_bytes = pad_penalty_bytes(shape, c);
    let mem = (bytes + pad_bytes) / dev.hbm_bw + dev.launch_overhead;
    compute.max(mem)
}

/// Run the full two-phase search for one shape on one device.
///
/// Guarantees, in order: (1) never visits an illegal point; (2) never
/// exceeds `opts.budget` by more than one simulator launch; (3) always
/// returns a config at least as good (by measurement) as the default
/// `KernelParams::new` config when the budget allows ≥ 1 measurement —
/// the default is always ranked into the measured set.
pub fn tune(
    shape: GemmShape,
    dev: &Device,
    opts: &TuneOptions,
) -> Result<TuneReport, TuneError> {
    if shape.is_degenerate() {
        return Err(TuneError::Degenerate(format!("{shape:?}")));
    }
    let sw = Stopwatch::start();
    let (mut candidates, space) =
        enumerate(shape, dev.num_cus, opts.width);
    if candidates.is_empty() {
        return Err(TuneError::NoLegalCandidate);
    }

    // CPU-measure mode: deterministic operand buffers, generated once
    // and shared by every phase-2 run (seeded from the shape so a
    // re-tune of the same problem measures the same data).
    let cpu_operands = opts.measure_cpu.then(|| {
        let seed = 0x7A11_0C10u64
            ^ ((shape.m as u64) << 42)
            ^ ((shape.n as u64) << 21)
            ^ shape.k as u64;
        let mut rng = crate::prop::Rng::new(seed);
        let a = rng.normal_f32_vec(shape.m * shape.k);
        let b = rng.normal_f32_vec(shape.k * shape.n);
        (a, b)
    });
    let run = |c: &Candidate| -> Option<f64> {
        match &cpu_operands {
            Some((a, b)) => measure_cpu(a, b, shape, c),
            None => measure(dev, shape, c),
        }
    };

    // Phase 1: Block2Time-predicted ranking.
    let model = probe_cost_model(dev, shape, opts.width);
    let mut ranked: Vec<(f64, Candidate)> = candidates
        .drain(..)
        .map(|c| (predicted(&model, dev, shape, &c), c))
        .collect();
    ranked.sort_by(|a, b| a.0.total_cmp(&b.0));

    // The default config always competes in phase 2, so "tuned" can
    // never measure worse than the baseline.
    let default_cand = Candidate {
        params: crate::decomp::params::KernelParams::new_w(
            crate::decomp::BlockShape::default(),
            opts.width,
        ),
        pad: PadPolicy::None,
        cus: dev.num_cus,
    };
    let default_s =
        run(&default_cand).ok_or(TuneError::NoLegalCandidate)?;

    // Phase 2: measured refinement of the top-K under the budget.
    //
    // Candidates differing only in `kc` or the register block price
    // *and* measure identically on the simulator (both are
    // CPU-executor locality knobs the cost model cannot see), so
    // simulator measurement promotes one representative per
    // equivalence class — those axes must not crowd distinct block
    // configs out of the top-K budget. CPU measurement *can* tell them
    // apart (that is its whole point), so there the class includes
    // them and every variant competes on wall-clock.
    let class_of = |c: &Candidate| {
        (
            c.params.block.effective(shape),
            c.params.double_buffer,
            c.pad,
            c.cus,
            opts.measure_cpu.then(|| (c.params.kc, c.params.reg)),
        )
    };
    let top_k = opts.top_k.max(1);
    let mut best: Option<TunedConfig> = Some(TunedConfig {
        params: default_cand.params,
        pad: default_cand.pad,
        cus: default_cand.cus,
        predicted_s: predicted(&model, dev, shape, &default_cand),
        measured_s: default_s,
        observed_s: 0.0,
        observed_n: 0,
    });
    let mut measured = 1; // the default baseline above
    let mut skipped = 0;
    let mut exhausted = false;
    let mut seen_classes = std::collections::HashSet::new();
    seen_classes.insert(class_of(&default_cand)); // baseline already measured
    let mut promoted = 0usize;
    for (pred, cand) in ranked.iter() {
        if promoted >= top_k {
            break;
        }
        if !seen_classes.insert(class_of(cand)) {
            continue; // kc twin / default twin: would measure identically
        }
        promoted += 1;
        if measured >= opts.budget.max_measurements
            || sw.elapsed() >= opts.budget.max_time
        {
            exhausted = true;
            skipped += 1;
            continue;
        }
        let Some(t) = run(cand) else { continue };
        measured += 1;
        let better = match &best {
            Some(b) => t < b.measured_s,
            None => true,
        };
        if better {
            best = Some(TunedConfig {
                params: cand.params,
                pad: cand.pad,
                cus: cand.cus,
                predicted_s: *pred,
                measured_s: t,
                observed_s: 0.0,
                observed_n: 0,
            });
        }
    }

    Ok(TuneReport {
        shape,
        best: best.expect("default baseline always present"),
        default_s,
        space,
        measured,
        skipped_by_budget: skipped,
        elapsed_s: sw.elapsed_secs(),
        budget_exhausted: exhausted,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::BlockShape;
    use crate::gpu_sim::DeviceKind;

    fn mi200() -> Device {
        Device::preset(DeviceKind::Mi200)
    }

    #[test]
    fn tuned_never_loses_to_default() {
        let dev = mi200();
        for (m, n, k) in [
            (3840usize, 4096usize, 4096usize),
            (480, 512, 512),
            (1920, 2000, 2000),
            (3, 9, 9),
        ] {
            let r = tune(GemmShape::new(m, n, k), &dev, &TuneOptions::default())
                .unwrap();
            assert!(
                r.best.measured_s <= r.default_s * (1.0 + 1e-9),
                "{m}x{n}x{k}: tuned {} > default {}",
                r.best.measured_s,
                r.default_s
            );
            assert!(check_legal(&r));
        }
    }

    fn check_legal(r: &TuneReport) -> bool {
        crate::decomp::params::check(&r.best.params).is_ok()
    }

    #[test]
    fn finds_strictly_better_config_on_table1_baseline() {
        // bk=128 halves the per-iteration overhead vs the default bk=64;
        // the tuner must find it (or something at least as fast).
        let r = tune(
            GemmShape::new(3840, 4096, 4096),
            &mi200(),
            &TuneOptions::default(),
        )
        .unwrap();
        assert!(
            r.best.measured_s < r.default_s,
            "expected strict win, got {} vs {}",
            r.best.measured_s,
            r.default_s
        );
        assert!(r.speedup() > 1.0);
    }

    #[test]
    fn budget_zero_time_still_terminates_with_default() {
        let opts = TuneOptions {
            budget: Budget {
                max_measurements: 1, // only the default baseline fits
                max_time: Duration::from_millis(0),
            },
            ..TuneOptions::default()
        };
        let r = tune(GemmShape::new(1920, 2000, 2000), &mi200(), &opts)
            .unwrap();
        assert!(r.budget_exhausted);
        assert_eq!(r.measured, 1);
        assert!(r.skipped_by_budget > 0);
        // falls back to the default config — never an illegal or unmeasured one
        assert_eq!(r.best.params.block, BlockShape::default());
        assert_eq!(r.best.measured_s, r.default_s);
    }

    #[test]
    fn budget_bounds_wall_clock() {
        let opts = TuneOptions {
            budget: Budget::from_millis(2000),
            ..TuneOptions::default()
        };
        let sw = Stopwatch::start();
        let r = tune(GemmShape::new(3840, 4096, 4096), &mi200(), &opts)
            .unwrap();
        // generous slack: budget + a couple of simulator launches
        assert!(sw.elapsed_secs() < 10.0, "tune ran {}s", sw.elapsed_secs());
        assert!(r.elapsed_s < 10.0);
    }

    /// Satellite acceptance (`streamk tune --measure`): CPU pricing
    /// runs the real blocked executor, so the kc / register-block
    /// equivalence classes the simulator collapses become separately
    /// measured candidates.
    #[test]
    fn cpu_measure_mode_makes_locality_axes_discriminating() {
        let dev = mi200();
        let shape = GemmShape::new(96, 128, 192); // small: µs-scale runs
        let wide = TuneOptions { top_k: 32, ..TuneOptions::default() };
        let sim = tune(shape, &dev, &wide).unwrap();
        let cpu_opts = TuneOptions {
            top_k: 32,
            measure_cpu: true,
            budget: Budget {
                max_measurements: 64,
                max_time: Duration::from_secs(20),
            },
            ..TuneOptions::default()
        };
        let cpu = tune(shape, &dev, &cpu_opts).unwrap();
        // Finer equivalence classes ⇒ at least as many distinct
        // measurements (kc variants no longer collapse).
        assert!(
            cpu.measured >= sim.measured,
            "cpu measured {} < sim measured {}",
            cpu.measured,
            sim.measured
        );
        assert!(cpu.measured > 1, "CPU mode must measure real candidates");
        assert!(cpu.best.measured_s > 0.0, "wall-clock, not simulated");
        assert!(check_legal(&cpu));
        // The never-loses-to-default guarantee holds on wall-clock too.
        assert!(cpu.best.measured_s <= cpu.default_s * (1.0 + 1e-9));
    }

    /// Width is a tuner axis: a bf16 search returns bf16 params, prices
    /// the halved panel traffic, and never loses to the f32 run on the
    /// same (memory-bound-or-not) problem.
    #[test]
    fn width_axis_tunes_bf16_no_worse_than_f32() {
        let dev = mi200();
        let shape = GemmShape::new(1920, 2000, 2000);
        let f = tune(shape, &dev, &TuneOptions::default()).unwrap();
        let b = tune(
            shape,
            &dev,
            &TuneOptions { width: Width::Bf16, ..TuneOptions::default() },
        )
        .unwrap();
        assert_eq!(b.best.params.width, Width::Bf16);
        assert_eq!(f.best.params.width, Width::F32);
        assert!(check_legal(&b));
        assert!(
            b.best.measured_s <= f.best.measured_s * (1.0 + 1e-9),
            "bf16 {} vs f32 {}",
            b.best.measured_s,
            f.best.measured_s
        );
    }

    #[test]
    fn degenerate_shape_rejected() {
        assert_eq!(
            tune(GemmShape::new(0, 4, 4), &mi200(), &TuneOptions::default()),
            Err(TuneError::Degenerate("GemmShape { m: 0, n: 4, k: 4 }".into()))
        );
    }

    #[test]
    fn report_accounts_for_space_pruning() {
        let r = tune(
            GemmShape::new(480, 512, 512),
            &mi200(),
            &TuneOptions::default(),
        )
        .unwrap();
        assert!(r.space.legal > 0);
        assert!(r.space.illegal_blocks > 0, "{:?}", r.space);
        assert_eq!(r.space.legal + r.space.deduped, r.space.total);
        assert!(r.measured >= 1);
        assert!(r.measured <= TuneOptions::default().top_k + 1);
    }
}
