//! Persistent per-shape tuning cache with an in-memory LRU front.
//!
//! Keyed by `(ShapeBucket, element width, DeviceFingerprint)`;
//! serialized through the
//! in-tree `json` module with an explicit format version — a mismatched
//! version is *rejected*, never reinterpreted, because a stale entry
//! that silently deserializes into the wrong field is exactly the class
//! of corruption the report's CU bug taught us to fear.
//!
//! Entries are no longer immortal (the PR-1 ROADMAP gap): each carries
//! creation/last-use timestamps plus an EWMA of *observed* serving
//! latencies, and [`TuningCache::sweep_stale`] implements the staleness
//! policy — untouched entries age out, and entries whose observed time
//! drifts too far from the cached prediction are flagged for
//! re-validation.

use super::fingerprint::{DeviceFingerprint, ShapeBucket};
use super::search::TunedConfig;
use super::space::PadPolicy;
use crate::decomp::params::{KernelParams, KC_DEFAULT};
use crate::decomp::BlockShape;
use crate::json::{self, obj, Value};
use crate::kernel::{RegBlock, Width};
use std::path::Path;

/// Bump on any change to the entry layout.
/// v2: staleness timestamps + observed-latency EWMA per entry.
pub const CACHE_VERSION: u64 = 2;

/// Seconds since the Unix epoch (0 when the clock is unset/behind).
pub fn now_epoch_s() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

/// When a cache entry stops being trusted.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StalenessPolicy {
    /// Entries untouched (no lookup/insert/observe) longer than this
    /// are aged out of the cache entirely.
    pub max_age_s: u64,
    /// Relative drift |predicted − observed| / observed beyond which an
    /// entry is flagged for re-validation (a fresh tune).
    pub max_drift: f64,
    /// Observations required before drift can flag re-validation — one
    /// noisy sample must not trigger a re-tune.
    pub min_observations: u64,
}

impl Default for StalenessPolicy {
    fn default() -> Self {
        Self {
            max_age_s: 7 * 24 * 3600,
            max_drift: 0.5,
            min_observations: 3,
        }
    }
}

/// What one staleness sweep did.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SweepReport {
    /// Entries dropped because they were untouched past `max_age_s`.
    pub aged_out: usize,
    /// Keys of surviving entries whose observed latency drifted past
    /// `max_drift` — the caller should re-tune these buckets.
    pub drifted: Vec<String>,
    /// Entries kept and within policy.
    pub fresh: usize,
}

#[derive(Debug)]
pub enum CacheError {
    Io { path: String, source: std::io::Error },
    Json(json::JsonError),
    VersionMismatch { found: u64, want: u64 },
    BadEntry(String),
}

impl std::fmt::Display for CacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheError::Io { path, source } => {
                write!(f, "tuner cache {path}: {source}")
            }
            CacheError::Json(e) => write!(f, "tuner cache: {e}"),
            CacheError::VersionMismatch { found, want } => write!(
                f,
                "tuner cache version {found} != {want}; re-tune (the cache \
                 format changed and stale entries are rejected, not guessed)"
            ),
            CacheError::BadEntry(msg) => {
                write!(f, "tuner cache entry: {msg}")
            }
        }
    }
}

impl std::error::Error for CacheError {}

impl From<json::JsonError> for CacheError {
    fn from(e: json::JsonError) -> Self {
        CacheError::Json(e)
    }
}

/// Full cache key: shape bucket × element width × device. The element
/// width matters — bf16 has twice the VMEM headroom and half the
/// traffic of f32, so a config tuned at one width must never be served
/// at another. The device fingerprint stays the suffix (see
/// [`TuningCache::count_for`]).
/// The width segment reuses the historical bytes-per-element spelling
/// ([`Width::cache_tag`]: f32 → `bpe4`, bf16 → `bpe2`), so every
/// pre-width key round-trips unchanged; f16 gets the new `bpe2f16`
/// segment and can never collide with a bf16 entry.
fn composite_key(
    bucket: &ShapeBucket,
    width: Width,
    dev: &DeviceFingerprint,
) -> String {
    format!("{}@bpe{}@{}", bucket.key(), width.cache_tag(), dev.as_str())
}

/// Inverse of [`composite_key`] (used by re-validation, which walks the
/// persisted entries back to tunable buckets).
pub fn split_key(key: &str) -> Option<(ShapeBucket, Width, &str)> {
    let (bucket_str, rest) = key.split_once("@bpe")?;
    let (tag, dev) = rest.split_once('@')?;
    let bucket = ShapeBucket::parse(bucket_str)?;
    let width = Width::parse_cache_tag(tag)?;
    Some((bucket, width, dev))
}

/// One cached config plus its staleness bookkeeping.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheEntry {
    pub cfg: TunedConfig,
    /// Epoch seconds when the entry was (re-)tuned.
    pub created_s: u64,
    /// Epoch seconds of the last lookup/insert/observe.
    pub last_used_s: u64,
}

/// The cache proper: MRU-ordered entries, bounded by `capacity`.
#[derive(Debug, Clone)]
pub struct TuningCache {
    capacity: usize,
    /// Most-recently-used first. Linear scan is fine at serving-cache
    /// sizes (hundreds); the composite key keeps lookups exact.
    entries: Vec<(String, CacheEntry)>,
}

impl TuningCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "cache capacity must be positive");
        Self { capacity, entries: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries keyed to one device fingerprint — a persisted cache can
    /// hold entries for several devices, and a warm-load that matches
    /// none of them is worth warning about.
    pub fn count_for(&self, dev: &DeviceFingerprint) -> usize {
        let suffix = format!("@{}", dev.as_str());
        self.entries.iter().filter(|(k, _)| k.ends_with(&suffix)).count()
    }

    /// (key, config) pairs for one device fingerprint, MRU first —
    /// the re-validation walk.
    pub fn entries_for(
        &self,
        dev: &DeviceFingerprint,
    ) -> Vec<(String, TunedConfig)> {
        let suffix = format!("@{}", dev.as_str());
        self.entries
            .iter()
            .filter(|(k, _)| k.ends_with(&suffix))
            .map(|(k, e)| (k.clone(), e.cfg))
            .collect()
    }

    /// Read-only lookup: no MRU promotion, no timestamp refresh. The
    /// fleet scheduler probes every device's cache on every placement;
    /// only the device that actually serves the request should count
    /// as a touch, or the age-out policy could never fire for
    /// actively-probed buckets on devices that stopped serving them.
    pub fn peek(
        &self,
        bucket: &ShapeBucket,
        width: Width,
        dev: &DeviceFingerprint,
    ) -> Option<TunedConfig> {
        let key = composite_key(bucket, width, dev);
        self.entries.iter().find(|(k, _)| *k == key).map(|(_, e)| e.cfg)
    }

    /// Lookup; a hit is promoted to most-recently-used and touched.
    pub fn get(
        &mut self,
        bucket: &ShapeBucket,
        width: Width,
        dev: &DeviceFingerprint,
    ) -> Option<TunedConfig> {
        let key = composite_key(bucket, width, dev);
        let idx = self.entries.iter().position(|(k, _)| *k == key)?;
        let mut entry = self.entries.remove(idx);
        entry.1.last_used_s = now_epoch_s();
        let cfg = entry.1.cfg;
        self.entries.insert(0, entry);
        Some(cfg)
    }

    /// Insert/overwrite at most-recently-used; evicts the LRU tail.
    pub fn insert(
        &mut self,
        bucket: &ShapeBucket,
        width: Width,
        dev: &DeviceFingerprint,
        cfg: TunedConfig,
    ) {
        let key = composite_key(bucket, width, dev);
        let now = now_epoch_s();
        self.entries.retain(|(k, _)| *k != key);
        self.entries.insert(
            0,
            (key, CacheEntry { cfg, created_s: now, last_used_s: now }),
        );
        self.entries.truncate(self.capacity);
    }

    /// Mutate an entry in place (promoted to MRU and touched). Returns
    /// `false` on a miss. This is the observed-latency update path:
    /// the closure sees the live `TunedConfig`, not a copy.
    pub fn update<F: FnOnce(&mut TunedConfig)>(
        &mut self,
        bucket: &ShapeBucket,
        width: Width,
        dev: &DeviceFingerprint,
        f: F,
    ) -> bool {
        let key = composite_key(bucket, width, dev);
        let Some(idx) = self.entries.iter().position(|(k, _)| *k == key)
        else {
            return false;
        };
        let mut entry = self.entries.remove(idx);
        entry.1.last_used_s = now_epoch_s();
        f(&mut entry.1.cfg);
        self.entries.insert(0, entry);
        true
    }

    /// Apply the staleness policy at time `now_s`: drop entries
    /// untouched past `max_age_s`, and report (but keep) entries whose
    /// observed latency drifted past `max_drift` so the caller can
    /// re-tune them. Entries with too few observations never drift.
    pub fn sweep_stale(
        &mut self,
        now_s: u64,
        policy: &StalenessPolicy,
    ) -> SweepReport {
        let before = self.entries.len();
        self.entries.retain(|(_, e)| {
            now_s.saturating_sub(e.last_used_s) <= policy.max_age_s
        });
        let mut report = SweepReport {
            aged_out: before - self.entries.len(),
            ..SweepReport::default()
        };
        for (key, e) in &self.entries {
            if e.cfg.observed_n >= policy.min_observations
                && entry_drift(&e.cfg)
                    .map(|d| d > policy.max_drift)
                    .unwrap_or(true)
            {
                report.drifted.push(key.clone());
            } else {
                report.fresh += 1;
            }
        }
        report
    }

    /// Merge another cache's entries into this one (skipping keys this
    /// cache already holds, which are assumed fresher). Used by the
    /// fleet to persist every device's per-device cache into one file.
    pub fn absorb(&mut self, other: &TuningCache) {
        for (key, entry) in &other.entries {
            if !self.entries.iter().any(|(k, _)| k == key) {
                self.entries.push((key.clone(), entry.clone()));
            }
        }
        self.entries.truncate(self.capacity);
    }

    pub fn to_json(&self) -> Value {
        let entries: Vec<Value> = self
            .entries
            .iter()
            .map(|(key, e)| {
                let c = &e.cfg;
                obj(vec![
                    ("key", key.as_str().into()),
                    ("bm", c.params.block.bm.into()),
                    ("bn", c.params.block.bn.into()),
                    ("bk", c.params.block.bk.into()),
                    ("kpack", c.params.kpack.into()),
                    ("mxu_m", c.params.mxu_m.into()),
                    ("mxu_n", c.params.mxu_n.into()),
                    ("bytes_per_elem", c.params.bytes_per_elem().into()),
                    ("width", c.params.width.name().into()),
                    ("mr", c.params.reg.mr.into()),
                    ("nr", c.params.reg.nr.into()),
                    ("double_buffer", c.params.double_buffer.into()),
                    ("kc", c.params.kc.into()),
                    ("pad", c.pad.as_str().into()),
                    ("cus", c.cus.into()),
                    ("predicted_s", c.predicted_s.into()),
                    ("measured_s", c.measured_s.into()),
                    ("observed_s", c.observed_s.into()),
                    ("observed_n", (c.observed_n as usize).into()),
                    ("created_s", (e.created_s as usize).into()),
                    ("last_used_s", (e.last_used_s as usize).into()),
                ])
            })
            .collect();
        obj(vec![
            ("version", (CACHE_VERSION as usize).into()),
            ("entries", Value::Arr(entries)),
        ])
    }

    pub fn from_json(v: &Value, capacity: usize) -> Result<Self, CacheError> {
        let found = v.u("version").map_err(CacheError::Json)? as u64;
        if found != CACHE_VERSION {
            return Err(CacheError::VersionMismatch {
                found,
                want: CACHE_VERSION,
            });
        }
        let mut cache = Self::new(capacity);
        let mut parsed = Vec::new();
        for e in v.arr("entries").map_err(CacheError::Json)? {
            let key = e.s("key").map_err(CacheError::Json)?.to_string();
            let pad_str = e.s("pad").map_err(CacheError::Json)?;
            let pad = PadPolicy::parse(pad_str).ok_or_else(|| {
                CacheError::BadEntry(format!("unknown pad policy {pad_str:?}"))
            })?;
            let block = BlockShape::new(
                e.u("bm").map_err(CacheError::Json)?,
                e.u("bn").map_err(CacheError::Json)?,
                e.u("bk").map_err(CacheError::Json)?,
            );
            // The width axis joined in v2's lifetime: entries written
            // before it carry only "bytes_per_elem" (which determines
            // the width — 2 always meant bf16) — a compatible read, not
            // a format break. Newer entries spell the width explicitly
            // so bf16 and f16 (both 2 bytes) stay distinct.
            let bpe = e.u("bytes_per_elem").map_err(CacheError::Json)?;
            let width = e
                .s("width")
                .ok()
                .and_then(Width::parse)
                .unwrap_or(Width::from_bpe(bpe));
            let mut params = KernelParams::new_w(block, width);
            params.kpack = e.u("kpack").map_err(CacheError::Json)?;
            params.mxu_m = e.u("mxu_m").map_err(CacheError::Json)?;
            params.mxu_n = e.u("mxu_n").map_err(CacheError::Json)?;
            params.double_buffer =
                e.b("double_buffer").map_err(CacheError::Json)?;
            // The KC axis joined in v2's lifetime: entries written
            // before it carry no "kc" field and mean the default chunk
            // — a compatible read, not a format break.
            params.kc = e.u("kc").unwrap_or(KC_DEFAULT);
            // Same deal for the per-width register block: absent
            // means the baseline MR×NR.
            params.reg = match (e.u("mr"), e.u("nr")) {
                (Ok(mr), Ok(nr)) => RegBlock { mr, nr },
                _ => RegBlock::BASE,
            };
            let cfg = TunedConfig {
                params,
                pad,
                cus: e.u("cus").map_err(CacheError::Json)?,
                predicted_s: e.f("predicted_s").map_err(CacheError::Json)?,
                measured_s: e.f("measured_s").map_err(CacheError::Json)?,
                observed_s: e.f("observed_s").map_err(CacheError::Json)?,
                observed_n: e.u("observed_n").map_err(CacheError::Json)?
                    as u64,
            };
            let entry = CacheEntry {
                cfg,
                created_s: e.u("created_s").map_err(CacheError::Json)? as u64,
                last_used_s: e.u("last_used_s").map_err(CacheError::Json)?
                    as u64,
            };
            parsed.push((key, entry));
        }
        // File order is MRU-first; inserting via the Vec directly keeps
        // it (an insert() loop would reverse it).
        parsed.truncate(capacity);
        cache.entries = parsed;
        Ok(cache)
    }

    /// Load `path`, or an empty cache when the file does not exist.
    /// A version mismatch or parse failure is an error — the caller
    /// decides whether to discard (serve path) or abort (CLI).
    pub fn load(path: &Path, capacity: usize) -> Result<Self, CacheError> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Self::new(capacity))
            }
            Err(source) => {
                return Err(CacheError::Io {
                    path: path.display().to_string(),
                    source,
                })
            }
        };
        let v = json::parse(&text)?;
        Self::from_json(&v, capacity)
    }

    /// Persist to `path` (pretty JSON, stable ordering).
    pub fn store(&self, path: &Path) -> Result<(), CacheError> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|source| {
                    CacheError::Io {
                        path: path.display().to_string(),
                        source,
                    }
                })?;
            }
        }
        std::fs::write(path, json::to_string_pretty(&self.to_json()))
            .map_err(|source| CacheError::Io {
                path: path.display().to_string(),
                source,
            })
    }
}

/// Relative drift between a cached prediction and the observed EWMA.
/// `None` when the entry has no observations yet; non-finite values
/// (a poisoned entry) come back as `None` from the comparison's point
/// of view — callers treat that as "re-validate".
pub fn entry_drift(cfg: &TunedConfig) -> Option<f64> {
    if cfg.observed_n == 0 {
        return None;
    }
    if !(cfg.observed_s.is_finite()
        && cfg.observed_s > 0.0
        && cfg.predicted_s.is_finite())
    {
        return None;
    }
    Some((cfg.predicted_s - cfg.observed_s).abs() / cfg.observed_s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::GemmShape;
    use std::path::PathBuf;

    fn fp() -> DeviceFingerprint {
        DeviceFingerprint("test-cu120-gf375-bw1600-lo6.0-io150".into())
    }

    fn cfg(bm: usize, measured: f64) -> TunedConfig {
        TunedConfig {
            params: KernelParams::new(BlockShape::new(bm, 128, 64), 4),
            pad: PadPolicy::None,
            cus: 120,
            predicted_s: measured * 0.9,
            measured_s: measured,
            observed_s: 0.0,
            observed_n: 0,
        }
    }

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "streamk-tuner-cache-{tag}-{}.json",
            std::process::id()
        ))
    }

    #[test]
    fn lru_front_evicts_oldest() {
        let mut c = TuningCache::new(2);
        let (b1, b2, b3) = (
            ShapeBucket::of(GemmShape::new(100, 100, 100)),
            ShapeBucket::of(GemmShape::new(1000, 1000, 1000)),
            ShapeBucket::of(GemmShape::new(4000, 4000, 4000)),
        );
        c.insert(&b1, Width::F32, &fp(), cfg(128, 1.0));
        c.insert(&b2, Width::F32, &fp(), cfg(256, 2.0));
        // touch b1 so b2 becomes LRU
        assert!(c.get(&b1, Width::F32, &fp()).is_some());
        c.insert(&b3, Width::F32, &fp(), cfg(64, 3.0));
        assert_eq!(c.len(), 2);
        assert!(c.get(&b2, Width::F32, &fp()).is_none(), "b2 must be evicted");
        assert!(c.get(&b1, Width::F32, &fp()).is_some());
        assert!(c.get(&b3, Width::F32, &fp()).is_some());
    }

    #[test]
    fn same_bucket_different_device_are_distinct() {
        let mut c = TuningCache::new(8);
        let b = ShapeBucket::of(GemmShape::new(512, 512, 512));
        let other = DeviceFingerprint("mi100-cu120".into());
        c.insert(&b, Width::F32, &fp(), cfg(128, 1.0));
        assert!(c.get(&b, Width::F32, &other).is_none());
        c.insert(&b, Width::F32, &other, cfg(256, 2.0));
        assert_eq!(c.get(&b, Width::F32, &fp()).unwrap().params.block.bm, 128);
        assert_eq!(c.get(&b, Width::F32, &other).unwrap().params.block.bm, 256);
    }

    #[test]
    fn same_bucket_different_dtype_are_distinct() {
        // A config tuned at bf16 (bpe=2) must never be served for f32
        // lookups — the legal set and traffic model differ.
        let mut c = TuningCache::new(8);
        let b = ShapeBucket::of(GemmShape::new(512, 512, 512));
        c.insert(&b, Width::Bf16, &fp(), cfg(256, 1.0));
        assert!(c.get(&b, Width::F32, &fp()).is_none());
        c.insert(&b, Width::F32, &fp(), cfg(128, 2.0));
        assert_eq!(c.get(&b, Width::Bf16, &fp()).unwrap().params.block.bm, 256);
        assert_eq!(c.get(&b, Width::F32, &fp()).unwrap().params.block.bm, 128);
    }

    #[test]
    fn round_trip_through_disk() {
        let mut c = TuningCache::new(8);
        let b1 = ShapeBucket::of(GemmShape::new(3840, 4096, 4096));
        let b2 = ShapeBucket::of(GemmShape::new(480, 512, 512));
        let mut special = cfg(256, 1.5e-3);
        special.pad = PadPolicy::Physical;
        special.params.double_buffer = false;
        special.params.kc = 64;
        special.cus = 60;
        special.observed_s = 1.4e-3;
        special.observed_n = 5;
        c.insert(&b1, Width::F32, &fp(), cfg(128, 2.5e-3));
        c.insert(&b2, Width::F32, &fp(), special);

        let path = tmpfile("roundtrip");
        c.store(&path).unwrap();
        let mut back = TuningCache::load(&path, 8).unwrap();
        assert_eq!(back.len(), 2);
        // b2 was inserted last → MRU, survives as-is with every field
        let got = back.get(&b2, Width::F32, &fp()).unwrap();
        assert_eq!(got, special);
        let got1 = back.get(&b1, Width::F32, &fp()).unwrap();
        assert_eq!(got1.params.block.bm, 128);
        assert!((got1.measured_s - 2.5e-3).abs() < 1e-12);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn version_mismatch_rejected() {
        let path = tmpfile("version");
        std::fs::write(
            &path,
            r#"{"version": 999, "entries": []}"#,
        )
        .unwrap();
        let err = TuningCache::load(&path, 4).unwrap_err();
        assert!(matches!(
            err,
            CacheError::VersionMismatch { found: 999, want: CACHE_VERSION }
        ));
        assert!(err.to_string().contains("re-tune"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn v1_cache_rejected_not_guessed() {
        // The PR-1 format had no staleness fields; a v1 file must be
        // rejected by version, never partially parsed.
        let path = tmpfile("v1");
        std::fs::write(
            &path,
            r#"{"version": 1, "entries": []}"#,
        )
        .unwrap();
        let err = TuningCache::load(&path, 4).unwrap_err();
        assert!(matches!(
            err,
            CacheError::VersionMismatch { found: 1, want: CACHE_VERSION }
        ));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_is_empty_cache() {
        let c = TuningCache::load(
            Path::new("/definitely/not/here/cache.json"),
            4,
        );
        // nonexistent *file* in an existing tempdir → empty; here the
        // parent also doesn't exist, which still surfaces as NotFound
        assert!(c.unwrap().is_empty());
    }

    #[test]
    fn bad_entry_rejected_with_reason() {
        let path = tmpfile("bad-entry");
        std::fs::write(
            &path,
            r#"{"version": 2, "entries": [{"key": "k", "bm": 128, "bn": 128,
               "bk": 64, "kpack": 8, "mxu_m": 128, "mxu_n": 128,
               "bytes_per_elem": 4, "double_buffer": true,
               "pad": "diagonal", "cus": 120,
               "predicted_s": 0.1, "measured_s": 0.1, "observed_s": 0.0,
               "observed_n": 0, "created_s": 1, "last_used_s": 1}]}"#,
        )
        .unwrap();
        let err = TuningCache::load(&path, 4).unwrap_err();
        assert!(err.to_string().contains("diagonal"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn entry_without_kc_loads_with_the_default() {
        // v2 files written before the KC axis carry no "kc" field —
        // they must load (same version, compatible format), meaning
        // the default chunk length.
        let path = tmpfile("no-kc");
        std::fs::write(
            &path,
            r#"{"version": 2, "entries": [{
               "key": "512x512x512@bpe4@test-cu120-gf375-bw1600-lo6.0-io150",
               "bm": 128, "bn": 128, "bk": 64, "kpack": 8,
               "mxu_m": 128, "mxu_n": 128, "bytes_per_elem": 4,
               "double_buffer": true, "pad": "none", "cus": 120,
               "predicted_s": 0.1, "measured_s": 0.1, "observed_s": 0.0,
               "observed_n": 0, "created_s": 1, "last_used_s": 1}]}"#,
        )
        .unwrap();
        let mut back = TuningCache::load(&path, 4).unwrap();
        let b = ShapeBucket::of(GemmShape::new(512, 512, 512));
        let got = back.get(&b, Width::F32, &fp()).expect("pre-KC entry must load");
        assert_eq!(got.params.kc, KC_DEFAULT);
        // pre-width fields default the same way: bpe determines the
        // width, the register block falls back to the baseline
        assert_eq!(got.params.width, Width::F32);
        assert_eq!(got.params.reg, RegBlock::BASE);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn pre_width_bf16_entry_loads_at_bf16_with_base_reg() {
        // Entries written when "bytes_per_elem": 2 was the only 16-bit
        // spelling must come back as bf16 (2 always meant bf16) and
        // answer bf16 lookups under the unchanged `@bpe2@` key.
        let path = tmpfile("pre-width");
        std::fs::write(
            &path,
            r#"{"version": 2, "entries": [{
               "key": "512x512x512@bpe2@test-cu120-gf375-bw1600-lo6.0-io150",
               "bm": 128, "bn": 128, "bk": 64, "kpack": 8,
               "mxu_m": 128, "mxu_n": 128, "bytes_per_elem": 2,
               "double_buffer": true, "pad": "none", "cus": 120,
               "predicted_s": 0.1, "measured_s": 0.1, "observed_s": 0.0,
               "observed_n": 0, "created_s": 1, "last_used_s": 1}]}"#,
        )
        .unwrap();
        let mut back = TuningCache::load(&path, 4).unwrap();
        let b = ShapeBucket::of(GemmShape::new(512, 512, 512));
        let got =
            back.get(&b, Width::Bf16, &fp()).expect("pre-width entry loads");
        assert_eq!(got.params.width, Width::Bf16);
        assert_eq!(got.params.bytes_per_elem(), 2);
        assert_eq!(got.params.reg, RegBlock::BASE);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn width_and_reg_round_trip_through_disk() {
        let mut c = TuningCache::new(8);
        let b = ShapeBucket::of(GemmShape::new(512, 512, 512));
        let mut wide = cfg(128, 1.0e-3);
        wide.params =
            KernelParams::new_w(BlockShape::new(128, 128, 64), Width::F16);
        wide.params.reg = RegBlock::WIDE;
        c.insert(&b, Width::F16, &fp(), wide);
        let path = tmpfile("width-reg");
        c.store(&path).unwrap();
        let mut back = TuningCache::load(&path, 8).unwrap();
        let got = back.get(&b, Width::F16, &fp()).unwrap();
        assert_eq!(got.params.width, Width::F16);
        assert_eq!(got.params.reg, RegBlock::WIDE);
        // the f16 key segment is distinct from bf16's despite equal bpe
        assert!(back.get(&b, Width::Bf16, &fp()).is_none());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_respects_capacity() {
        let mut c = TuningCache::new(16);
        for i in 1..=10usize {
            let b = ShapeBucket::of(GemmShape::new(i * 128, 128, 128));
            c.insert(&b, Width::F32, &fp(), cfg(128, i as f64));
        }
        let path = tmpfile("capacity");
        c.store(&path).unwrap();
        let back = TuningCache::load(&path, 3).unwrap();
        assert_eq!(back.len(), 3);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn key_splits_back_into_parts() {
        let b = ShapeBucket::of(GemmShape::new(480, 512, 512));
        for w in Width::all() {
            let key = composite_key(&b, w, &fp());
            let (bucket, width, dev) = split_key(&key).unwrap();
            assert_eq!(bucket, b);
            assert_eq!(width, w);
            assert_eq!(dev, fp().as_str());
        }
        // pre-width keys spell the f32/bf16 segments identically, so
        // old persisted keys parse unchanged
        assert!(composite_key(&b, Width::F32, &fp()).contains("@bpe4@"));
        assert!(composite_key(&b, Width::Bf16, &fp()).contains("@bpe2@"));
        assert!(split_key("garbage").is_none());
        assert!(split_key("1x2x3@bpeX@dev").is_none());
    }

    #[test]
    fn peek_does_not_promote_or_touch() {
        let mut c = TuningCache::new(2);
        let (b1, b2, b3) = (
            ShapeBucket::of(GemmShape::new(100, 100, 100)),
            ShapeBucket::of(GemmShape::new(1000, 1000, 1000)),
            ShapeBucket::of(GemmShape::new(4000, 4000, 4000)),
        );
        c.insert(&b1, Width::F32, &fp(), cfg(128, 1.0));
        c.insert(&b2, Width::F32, &fp(), cfg(256, 2.0));
        // peeking the LRU entry must NOT rescue it from eviction
        assert_eq!(c.peek(&b1, Width::F32, &fp()).unwrap().params.block.bm, 128);
        c.insert(&b3, Width::F32, &fp(), cfg(64, 3.0));
        assert!(c.peek(&b1, Width::F32, &fp()).is_none(), "b1 stayed LRU");
        assert!(c.peek(&b2, Width::F32, &fp()).is_some());
    }

    #[test]
    fn update_mutates_in_place_and_touches() {
        let mut c = TuningCache::new(4);
        let b = ShapeBucket::of(GemmShape::new(512, 512, 512));
        c.insert(&b, Width::F32, &fp(), cfg(128, 1.0));
        assert!(c.update(&b, Width::F32, &fp(), |cfg| {
            cfg.observed_s = 0.8;
            cfg.observed_n = 1;
        }));
        let got = c.get(&b, Width::F32, &fp()).unwrap();
        assert_eq!(got.observed_n, 1);
        assert!((got.observed_s - 0.8).abs() < 1e-12);
        // miss → false, nothing inserted
        let other = ShapeBucket::of(GemmShape::new(4000, 4000, 4000));
        assert!(!c.update(&other, Width::F32, &fp(), |_| unreachable!()));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn sweep_ages_out_untouched_entries() {
        let mut c = TuningCache::new(8);
        let b1 = ShapeBucket::of(GemmShape::new(512, 512, 512));
        let b2 = ShapeBucket::of(GemmShape::new(4000, 4000, 4000));
        c.insert(&b1, Width::F32, &fp(), cfg(128, 1.0));
        c.insert(&b2, Width::F32, &fp(), cfg(256, 2.0));
        let policy = StalenessPolicy { max_age_s: 100, ..Default::default() };
        // "now" far in the future: everything ages out
        let report = c.sweep_stale(now_epoch_s() + 1000, &policy);
        assert_eq!(report.aged_out, 2);
        assert!(c.is_empty());
    }

    #[test]
    fn sweep_flags_drifted_entries_but_keeps_them() {
        let mut c = TuningCache::new(8);
        let b = ShapeBucket::of(GemmShape::new(512, 512, 512));
        let mut drifty = cfg(128, 1.0e-3);
        drifty.predicted_s = 1.0e-3;
        drifty.observed_s = 3.0e-3; // 67% off
        drifty.observed_n = 5;
        c.insert(&b, Width::F32, &fp(), drifty);
        let fresh_b = ShapeBucket::of(GemmShape::new(4000, 4000, 4000));
        let mut ok = cfg(256, 2.0e-3);
        ok.predicted_s = 2.0e-3;
        ok.observed_s = 2.1e-3;
        ok.observed_n = 5;
        c.insert(&fresh_b, Width::F32, &fp(), ok);

        let report = c.sweep_stale(now_epoch_s(), &StalenessPolicy::default());
        assert_eq!(report.aged_out, 0);
        assert_eq!(report.drifted.len(), 1);
        assert!(report.drifted[0].starts_with("512x512x512@"));
        assert_eq!(report.fresh, 1);
        assert_eq!(c.len(), 2, "drifted entries are kept for re-tune");
    }

    #[test]
    fn sweep_needs_min_observations_before_drift() {
        let mut c = TuningCache::new(8);
        let b = ShapeBucket::of(GemmShape::new(512, 512, 512));
        let mut noisy = cfg(128, 1.0e-3);
        noisy.observed_s = 9.0e-3;
        noisy.observed_n = 1; // below min_observations
        c.insert(&b, Width::F32, &fp(), noisy);
        let report = c.sweep_stale(now_epoch_s(), &StalenessPolicy::default());
        assert!(report.drifted.is_empty());
        assert_eq!(report.fresh, 1);
    }

    #[test]
    fn absorb_merges_disjoint_entries() {
        let mut a = TuningCache::new(8);
        let mut b = TuningCache::new(8);
        let bucket = ShapeBucket::of(GemmShape::new(512, 512, 512));
        let other_dev = DeviceFingerprint("mi100-cu60".into());
        a.insert(&bucket, Width::F32, &fp(), cfg(128, 1.0));
        b.insert(&bucket, Width::F32, &other_dev, cfg(256, 2.0));
        // overlapping key: a's copy wins
        b.insert(&bucket, Width::F32, &fp(), cfg(64, 9.0));
        a.absorb(&b);
        assert_eq!(a.len(), 2);
        assert_eq!(a.get(&bucket, Width::F32, &fp()).unwrap().params.block.bm, 128);
        assert_eq!(
            a.get(&bucket, Width::F32, &other_dev).unwrap().params.block.bm,
            256
        );
    }

    #[test]
    fn entry_drift_semantics() {
        let mut c = cfg(128, 1.0e-3);
        assert_eq!(entry_drift(&c), None, "no observations yet");
        c.predicted_s = 1.0e-3;
        c.observed_s = 2.0e-3;
        c.observed_n = 4;
        assert!((entry_drift(&c).unwrap() - 0.5).abs() < 1e-12);
        c.predicted_s = f64::NAN;
        assert_eq!(entry_drift(&c), None, "poisoned prediction");
    }
}
