//! Persistent per-shape tuning cache with an in-memory LRU front.
//!
//! Keyed by `(ShapeBucket, bytes_per_elem, DeviceFingerprint)`;
//! serialized through the
//! in-tree `json` module with an explicit format version — a mismatched
//! version is *rejected*, never reinterpreted, because a stale entry
//! that silently deserializes into the wrong field is exactly the class
//! of corruption the report's CU bug taught us to fear.

use super::fingerprint::{DeviceFingerprint, ShapeBucket};
use super::search::TunedConfig;
use super::space::PadPolicy;
use crate::decomp::params::KernelParams;
use crate::decomp::BlockShape;
use crate::json::{self, obj, Value};
use std::path::Path;

/// Bump on any change to the entry layout.
pub const CACHE_VERSION: u64 = 1;

#[derive(Debug)]
pub enum CacheError {
    Io { path: String, source: std::io::Error },
    Json(json::JsonError),
    VersionMismatch { found: u64, want: u64 },
    BadEntry(String),
}

impl std::fmt::Display for CacheError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CacheError::Io { path, source } => {
                write!(f, "tuner cache {path}: {source}")
            }
            CacheError::Json(e) => write!(f, "tuner cache: {e}"),
            CacheError::VersionMismatch { found, want } => write!(
                f,
                "tuner cache version {found} != {want}; re-tune (the cache \
                 format changed and stale entries are rejected, not guessed)"
            ),
            CacheError::BadEntry(msg) => {
                write!(f, "tuner cache entry: {msg}")
            }
        }
    }
}

impl std::error::Error for CacheError {}

impl From<json::JsonError> for CacheError {
    fn from(e: json::JsonError) -> Self {
        CacheError::Json(e)
    }
}

/// Full cache key: shape bucket × element width × device. The element
/// width matters — bf16 has twice the VMEM headroom and half the
/// traffic of f32, so a config tuned at one width must never be served
/// at another. The device fingerprint stays the suffix (see
/// [`TuningCache::count_for`]).
fn composite_key(
    bucket: &ShapeBucket,
    bytes_per_elem: usize,
    dev: &DeviceFingerprint,
) -> String {
    format!("{}@bpe{}@{}", bucket.key(), bytes_per_elem, dev.as_str())
}

/// The cache proper: MRU-ordered entries, bounded by `capacity`.
#[derive(Debug, Clone)]
pub struct TuningCache {
    capacity: usize,
    /// Most-recently-used first. Linear scan is fine at serving-cache
    /// sizes (hundreds); the composite key keeps lookups exact.
    entries: Vec<(String, TunedConfig)>,
}

impl TuningCache {
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0, "cache capacity must be positive");
        Self { capacity, entries: Vec::new() }
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Entries keyed to one device fingerprint — a persisted cache can
    /// hold entries for several devices, and a warm-load that matches
    /// none of them is worth warning about.
    pub fn count_for(&self, dev: &DeviceFingerprint) -> usize {
        let suffix = format!("@{}", dev.as_str());
        self.entries.iter().filter(|(k, _)| k.ends_with(&suffix)).count()
    }

    /// Lookup; a hit is promoted to most-recently-used.
    pub fn get(
        &mut self,
        bucket: &ShapeBucket,
        bytes_per_elem: usize,
        dev: &DeviceFingerprint,
    ) -> Option<TunedConfig> {
        let key = composite_key(bucket, bytes_per_elem, dev);
        let idx = self.entries.iter().position(|(k, _)| *k == key)?;
        let entry = self.entries.remove(idx);
        let cfg = entry.1;
        self.entries.insert(0, entry);
        Some(cfg)
    }

    /// Insert/overwrite at most-recently-used; evicts the LRU tail.
    pub fn insert(
        &mut self,
        bucket: &ShapeBucket,
        bytes_per_elem: usize,
        dev: &DeviceFingerprint,
        cfg: TunedConfig,
    ) {
        let key = composite_key(bucket, bytes_per_elem, dev);
        self.entries.retain(|(k, _)| *k != key);
        self.entries.insert(0, (key, cfg));
        self.entries.truncate(self.capacity);
    }

    pub fn to_json(&self) -> Value {
        let entries: Vec<Value> = self
            .entries
            .iter()
            .map(|(key, c)| {
                obj(vec![
                    ("key", key.as_str().into()),
                    ("bm", c.params.block.bm.into()),
                    ("bn", c.params.block.bn.into()),
                    ("bk", c.params.block.bk.into()),
                    ("kpack", c.params.kpack.into()),
                    ("mxu_m", c.params.mxu_m.into()),
                    ("mxu_n", c.params.mxu_n.into()),
                    ("bytes_per_elem", c.params.bytes_per_elem.into()),
                    ("double_buffer", c.params.double_buffer.into()),
                    ("pad", c.pad.as_str().into()),
                    ("cus", c.cus.into()),
                    ("predicted_s", c.predicted_s.into()),
                    ("measured_s", c.measured_s.into()),
                ])
            })
            .collect();
        obj(vec![
            ("version", (CACHE_VERSION as usize).into()),
            ("entries", Value::Arr(entries)),
        ])
    }

    pub fn from_json(v: &Value, capacity: usize) -> Result<Self, CacheError> {
        let found = v.u("version").map_err(CacheError::Json)? as u64;
        if found != CACHE_VERSION {
            return Err(CacheError::VersionMismatch {
                found,
                want: CACHE_VERSION,
            });
        }
        let mut cache = Self::new(capacity);
        let mut parsed = Vec::new();
        for e in v.arr("entries").map_err(CacheError::Json)? {
            let key = e.s("key").map_err(CacheError::Json)?.to_string();
            let pad_str = e.s("pad").map_err(CacheError::Json)?;
            let pad = PadPolicy::parse(pad_str).ok_or_else(|| {
                CacheError::BadEntry(format!("unknown pad policy {pad_str:?}"))
            })?;
            let block = BlockShape::new(
                e.u("bm").map_err(CacheError::Json)?,
                e.u("bn").map_err(CacheError::Json)?,
                e.u("bk").map_err(CacheError::Json)?,
            );
            let mut params = KernelParams::new(
                block,
                e.u("bytes_per_elem").map_err(CacheError::Json)?,
            );
            params.kpack = e.u("kpack").map_err(CacheError::Json)?;
            params.mxu_m = e.u("mxu_m").map_err(CacheError::Json)?;
            params.mxu_n = e.u("mxu_n").map_err(CacheError::Json)?;
            params.double_buffer =
                e.b("double_buffer").map_err(CacheError::Json)?;
            let cfg = TunedConfig {
                params,
                pad,
                cus: e.u("cus").map_err(CacheError::Json)?,
                predicted_s: e.f("predicted_s").map_err(CacheError::Json)?,
                measured_s: e.f("measured_s").map_err(CacheError::Json)?,
            };
            parsed.push((key, cfg));
        }
        // File order is MRU-first; inserting via the Vec directly keeps
        // it (an insert() loop would reverse it).
        parsed.truncate(capacity);
        cache.entries = parsed;
        Ok(cache)
    }

    /// Load `path`, or an empty cache when the file does not exist.
    /// A version mismatch or parse failure is an error — the caller
    /// decides whether to discard (serve path) or abort (CLI).
    pub fn load(path: &Path, capacity: usize) -> Result<Self, CacheError> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                return Ok(Self::new(capacity))
            }
            Err(source) => {
                return Err(CacheError::Io {
                    path: path.display().to_string(),
                    source,
                })
            }
        };
        let v = json::parse(&text)?;
        Self::from_json(&v, capacity)
    }

    /// Persist to `path` (pretty JSON, stable ordering).
    pub fn store(&self, path: &Path) -> Result<(), CacheError> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).map_err(|source| {
                    CacheError::Io {
                        path: path.display().to_string(),
                        source,
                    }
                })?;
            }
        }
        std::fs::write(path, json::to_string_pretty(&self.to_json()))
            .map_err(|source| CacheError::Io {
                path: path.display().to_string(),
                source,
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::GemmShape;
    use std::path::PathBuf;

    fn fp() -> DeviceFingerprint {
        DeviceFingerprint("test-cu120-gf375-bw1600-lo6.0-io150".into())
    }

    fn cfg(bm: usize, measured: f64) -> TunedConfig {
        TunedConfig {
            params: KernelParams::new(BlockShape::new(bm, 128, 64), 4),
            pad: PadPolicy::None,
            cus: 120,
            predicted_s: measured * 0.9,
            measured_s: measured,
        }
    }

    fn tmpfile(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "streamk-tuner-cache-{tag}-{}.json",
            std::process::id()
        ))
    }

    #[test]
    fn lru_front_evicts_oldest() {
        let mut c = TuningCache::new(2);
        let (b1, b2, b3) = (
            ShapeBucket::of(GemmShape::new(100, 100, 100)),
            ShapeBucket::of(GemmShape::new(1000, 1000, 1000)),
            ShapeBucket::of(GemmShape::new(4000, 4000, 4000)),
        );
        c.insert(&b1, 4, &fp(), cfg(128, 1.0));
        c.insert(&b2, 4, &fp(), cfg(256, 2.0));
        // touch b1 so b2 becomes LRU
        assert!(c.get(&b1, 4, &fp()).is_some());
        c.insert(&b3, 4, &fp(), cfg(64, 3.0));
        assert_eq!(c.len(), 2);
        assert!(c.get(&b2, 4, &fp()).is_none(), "b2 must be evicted");
        assert!(c.get(&b1, 4, &fp()).is_some());
        assert!(c.get(&b3, 4, &fp()).is_some());
    }

    #[test]
    fn same_bucket_different_device_are_distinct() {
        let mut c = TuningCache::new(8);
        let b = ShapeBucket::of(GemmShape::new(512, 512, 512));
        let other = DeviceFingerprint("mi100-cu120".into());
        c.insert(&b, 4, &fp(), cfg(128, 1.0));
        assert!(c.get(&b, 4, &other).is_none());
        c.insert(&b, 4, &other, cfg(256, 2.0));
        assert_eq!(c.get(&b, 4, &fp()).unwrap().params.block.bm, 128);
        assert_eq!(c.get(&b, 4, &other).unwrap().params.block.bm, 256);
    }

    #[test]
    fn same_bucket_different_dtype_are_distinct() {
        // A config tuned at bf16 (bpe=2) must never be served for f32
        // lookups — the legal set and traffic model differ.
        let mut c = TuningCache::new(8);
        let b = ShapeBucket::of(GemmShape::new(512, 512, 512));
        c.insert(&b, 2, &fp(), cfg(256, 1.0));
        assert!(c.get(&b, 4, &fp()).is_none());
        c.insert(&b, 4, &fp(), cfg(128, 2.0));
        assert_eq!(c.get(&b, 2, &fp()).unwrap().params.block.bm, 256);
        assert_eq!(c.get(&b, 4, &fp()).unwrap().params.block.bm, 128);
    }

    #[test]
    fn round_trip_through_disk() {
        let mut c = TuningCache::new(8);
        let b1 = ShapeBucket::of(GemmShape::new(3840, 4096, 4096));
        let b2 = ShapeBucket::of(GemmShape::new(480, 512, 512));
        let mut special = cfg(256, 1.5e-3);
        special.pad = PadPolicy::Physical;
        special.params.double_buffer = false;
        special.cus = 60;
        c.insert(&b1, 4, &fp(), cfg(128, 2.5e-3));
        c.insert(&b2, 4, &fp(), special);

        let path = tmpfile("roundtrip");
        c.store(&path).unwrap();
        let mut back = TuningCache::load(&path, 8).unwrap();
        assert_eq!(back.len(), 2);
        // b2 was inserted last → MRU, survives as-is with every field
        let got = back.get(&b2, 4, &fp()).unwrap();
        assert_eq!(got, special);
        let got1 = back.get(&b1, 4, &fp()).unwrap();
        assert_eq!(got1.params.block.bm, 128);
        assert!((got1.measured_s - 2.5e-3).abs() < 1e-12);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn version_mismatch_rejected() {
        let path = tmpfile("version");
        std::fs::write(
            &path,
            r#"{"version": 999, "entries": []}"#,
        )
        .unwrap();
        let err = TuningCache::load(&path, 4).unwrap_err();
        assert!(matches!(
            err,
            CacheError::VersionMismatch { found: 999, want: CACHE_VERSION }
        ));
        assert!(err.to_string().contains("re-tune"));
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn missing_file_is_empty_cache() {
        let c = TuningCache::load(
            Path::new("/definitely/not/here/cache.json"),
            4,
        );
        // nonexistent *file* in an existing tempdir → empty; here the
        // parent also doesn't exist, which still surfaces as NotFound
        assert!(c.unwrap().is_empty());
    }

    #[test]
    fn bad_entry_rejected_with_reason() {
        let path = tmpfile("bad-entry");
        std::fs::write(
            &path,
            r#"{"version": 1, "entries": [{"key": "k", "bm": 128, "bn": 128,
               "bk": 64, "kpack": 8, "mxu_m": 128, "mxu_n": 128,
               "bytes_per_elem": 4, "double_buffer": true,
               "pad": "diagonal", "cus": 120,
               "predicted_s": 0.1, "measured_s": 0.1}]}"#,
        )
        .unwrap();
        let err = TuningCache::load(&path, 4).unwrap_err();
        assert!(err.to_string().contains("diagonal"), "{err}");
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn load_respects_capacity() {
        let mut c = TuningCache::new(16);
        for i in 1..=10usize {
            let b = ShapeBucket::of(GemmShape::new(i * 128, 128, 128));
            c.insert(&b, 4, &fp(), cfg(128, i as f64));
        }
        let path = tmpfile("capacity");
        c.store(&path).unwrap();
        let back = TuningCache::load(&path, 3).unwrap();
        assert_eq!(back.len(), 3);
        std::fs::remove_file(&path).unwrap();
    }
}
