//! The tuning search space, legality-pruned up front.
//!
//! The report probed CK's ~15 interdependent template parameters by hand
//! until the build broke ("we could not get the vast majority … to
//! compile"). Here the space is explicit — `KernelParams` block axes ×
//! padding policy × grid size — and every point is screened by
//! `decomp::params::check` *before* anything is built or measured, so
//! illegal points are never visited and every rejection carries a named
//! reason.

use crate::decomp::occupancy::dp_efficiency;
use crate::decomp::params::{check, exploration_grid_w, KernelParams};
use crate::decomp::{GemmShape, TileGrid};
use crate::kernel::Width;
use std::collections::BTreeMap;

/// Artifact padding policy, as a typed axis (the router's "none" /
/// "physical" strings).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PadPolicy {
    None,
    Physical,
}

impl PadPolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            PadPolicy::None => "none",
            PadPolicy::Physical => "physical",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "none" => Some(PadPolicy::None),
            "physical" => Some(PadPolicy::Physical),
            _ => None,
        }
    }
}

/// One legal point of the search space.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Candidate {
    pub params: KernelParams,
    pub pad: PadPolicy,
    /// Grid size: how many CUs the schedule is built for.
    pub cus: usize,
}

/// What the up-front pruning removed, by named reason.
///
/// Two levels of accounting: *block points* (distinct `KernelParams`,
/// where legality lives) and *candidates* (legal blocks × pad × grid
/// variants, where dedup lives). Invariants:
/// `illegal_blocks + legal_blocks == block_points` and
/// `legal + deduped == total`.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SpaceStats {
    /// Distinct `KernelParams` grid points enumerated.
    pub block_points: usize,
    /// Block points the legality predicate rejected (counted once per
    /// block, however many reasons it carries).
    pub illegal_blocks: usize,
    /// Rejection counts keyed by `Illegal::label()`, once per block
    /// point per reason (a block can carry several reasons, so these
    /// sum to ≥ `illegal_blocks`).
    pub pruned: BTreeMap<&'static str, usize>,
    /// Candidate points: legal blocks × pad × grid-size variants.
    pub total: usize,
    /// Candidates that survived effective-block dedup.
    pub legal: usize,
    /// Candidates dropped because their *effective* block (after
    /// shrinking to the problem) duplicates an earlier candidate —
    /// booked separately so the legality table is not blamed for
    /// dedup collapse.
    pub deduped: usize,
}

/// Grid-size axis, occupancy-guided (the report's CLI "Compute Units"
/// parameter — the one that triggered the CK bug — is worth tuning
/// because small problems can prefer fewer CUs to fewer fixup
/// fragments). Instead of naive halvings from the device CU count,
/// candidates come from the tile count of *this* problem at *this*
/// block:
///
/// - the full device (Stream-K's home turf — near-perfect occupancy by
///   construction);
/// - `min(tiles, dev_cus)` — never launch more CUs than output tiles,
///   the pure idle-CU cap;
/// - the largest grid ≤ that cap with the best data-parallel wave
///   efficiency ([`dp_efficiency`]): full waves mean zero fixup
///   fragments, which is exactly where small problems win.
fn grid_sizes(tiles: usize, dev_cus: usize) -> Vec<usize> {
    let cap = tiles.clamp(1, dev_cus);
    let mut best = (0.0f64, 1usize);
    for c in 1..=cap {
        let e = dp_efficiency(tiles, c);
        // ties go to the larger grid: same occupancy, more parallelism
        if e >= best.0 {
            best = (e, c);
        }
    }
    let mut out = vec![dev_cus, cap, best.1];
    out.retain({
        let mut seen = std::collections::HashSet::new();
        move |c| seen.insert(*c)
    });
    out
}

/// Enumerate the legality-pruned candidate list for one problem.
///
/// Block points whose effective block (after shrinking to the problem)
/// is identical are deduplicated so tiny shapes don't measure the same
/// point dozens of times.
pub fn enumerate(
    shape: GemmShape,
    dev_cus: usize,
    width: Width,
) -> (Vec<Candidate>, SpaceStats) {
    let mut stats = SpaceStats::default();
    let mut out = Vec::new();
    let mut seen = std::collections::HashSet::new();
    for params in exploration_grid_w(width) {
        // Legality depends only on the block parameters: check once per
        // grid point, count each rejection reason once per grid point.
        stats.block_points += 1;
        if let Err(errs) = check(&params) {
            stats.illegal_blocks += 1;
            for e in errs {
                *stats.pruned.entry(e.label()).or_default() += 1;
            }
            continue;
        }
        // Grid candidates depend on the tile count, which depends on
        // the (effective) block — occupancy guidance is per block point.
        let eff_block = params.block.effective(shape);
        let tiles = TileGrid::new(shape, eff_block).num_tiles();
        for pad in [PadPolicy::None, PadPolicy::Physical] {
            for &cus in &grid_sizes(tiles, dev_cus) {
                stats.total += 1;
                if seen.insert((
                    eff_block,
                    params.double_buffer,
                    params.kc,
                    params.width,
                    params.reg,
                    pad,
                    cus,
                )) {
                    stats.legal += 1;
                    out.push(Candidate { params, pad, cus });
                } else {
                    stats.deduped += 1;
                }
            }
        }
    }
    (out, stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::BlockShape;

    #[test]
    fn pruning_removes_the_majority_like_ck() {
        let (cands, stats) =
            enumerate(GemmShape::new(3840, 4096, 4096), 120, Width::F32);
        assert!(stats.block_points > 0);
        assert!(!cands.is_empty());
        // the report: "the vast majority … fail to compile" — of the
        // *block* space, which is where legality lives
        assert!(
            stats.illegal_blocks * 2 > stats.block_points,
            "{stats:?}"
        );
        // every named rejection reason accounts for at least one block
        assert!(!stats.pruned.is_empty());
        // reasons are counted once per block, so no reason can exceed
        // the number of illegal blocks
        for (reason, n) in &stats.pruned {
            assert!(*n <= stats.illegal_blocks, "{reason}: {n}");
        }
        // the candidate books balance
        assert_eq!(stats.legal + stats.deduped, stats.total, "{stats:?}");
        // grid candidates are occupancy-guided and per-block (1..=3 of
        // them), so the totals are bounded, not fixed
        let legal_blocks = stats.block_points - stats.illegal_blocks;
        assert!(stats.total >= legal_blocks * 2, "{stats:?}");
        assert!(stats.total <= legal_blocks * 6, "{stats:?}");
        assert_eq!(stats.legal, cands.len());
        // no illegal point survives
        for c in &cands {
            assert!(check(&c.params).is_ok());
        }
    }

    #[test]
    fn dedup_is_booked_separately_from_legality() {
        // Tiny shape: nearly every legal candidate collapses by dedup;
        // the gap must show up in `deduped`, not be blamed on legality.
        let (_, stats) = enumerate(GemmShape::new(3, 9, 9), 120, Width::F32);
        assert!(stats.deduped > 0, "{stats:?}");
        assert_eq!(stats.legal + stats.deduped, stats.total);
        // the big shape has no dedup at all (all effective blocks distinct)
        let (_, big) = enumerate(GemmShape::new(3840, 4096, 4096), 120, Width::F32);
        assert_eq!(big.deduped, 0, "{big:?}");
    }

    #[test]
    fn kc_axis_survives_pruning_and_dedup() {
        let (cands, _) = enumerate(GemmShape::new(3840, 4096, 4096), 120, Width::F32);
        let kcs: std::collections::BTreeSet<usize> =
            cands.iter().map(|c| c.params.kc).collect();
        assert!(
            kcs.len() >= 2,
            "the KC axis must survive effective-block dedup: {kcs:?}"
        );
        // every surviving chunk length is kpack-aligned and within the
        // pack budget (the legality predicate ran on all of them)
        for c in &cands {
            assert_eq!(c.params.kc % c.params.kpack, 0, "{c:?}");
        }
    }

    #[test]
    fn reg_axis_survives_only_at_sixteen_bit_widths() {
        use crate::kernel::RegBlock;
        let shape = GemmShape::new(3840, 4096, 4096);
        let (f32c, _) = enumerate(shape, 120, Width::F32);
        assert!(f32c.iter().all(|c| c.params.reg == RegBlock::BASE));
        let (bfc, _) = enumerate(shape, 120, Width::Bf16);
        let regs: std::collections::BTreeSet<_> =
            bfc.iter().map(|c| c.params.reg).collect();
        assert!(
            regs.contains(&RegBlock::BASE) && regs.contains(&RegBlock::WIDE),
            "the per-width reg axis must survive dedup: {regs:?}"
        );
        // Every candidate carries the width it was enumerated at.
        assert!(bfc.iter().all(|c| c.params.width == Width::Bf16));
        // Halved bytes widen the legal set (more VMEM headroom) and the
        // reg axis doubles the candidate list on top.
        assert!(bfc.len() > f32c.len(), "{} vs {}", bfc.len(), f32c.len());
    }

    #[test]
    fn report_16x16_config_is_never_visited() {
        let (cands, _) = enumerate(GemmShape::new(3840, 4096, 4096), 120, Width::F32);
        assert!(cands
            .iter()
            .all(|c| c.params.block != BlockShape::new(16, 16, 64)));
    }

    #[test]
    fn tiny_shape_deduplicates_effective_blocks() {
        let tiny = GemmShape::new(3, 9, 9);
        let big = GemmShape::new(3840, 4096, 4096);
        let (t, _) = enumerate(tiny, 120, Width::F32);
        let (b, _) = enumerate(big, 120, Width::F32);
        // every legal block shrinks to (3,9,9): far fewer distinct points
        assert!(t.len() < b.len(), "{} vs {}", t.len(), b.len());
    }

    #[test]
    fn grid_axis_is_occupancy_guided() {
        // 960 tiles on a 120-CU device: 8 exact waves — the full device
        // is already the occupancy optimum, one candidate suffices.
        assert_eq!(grid_sizes(960, 120), vec![120]);
        // 3 tiles: cap at the tile count (no idle CUs); 3 CUs is also
        // the best full-wave grid.
        assert_eq!(grid_sizes(3, 120), vec![120, 3]);
        // 961 tiles: 961 = 31², so 31 CUs runs perfectly full waves
        // where the naive 120-CU launch idles 119 CUs in the last wave.
        assert_eq!(grid_sizes(961, 120), vec![120, 31]);
        // degenerate corners
        assert_eq!(grid_sizes(1, 1), vec![1]);
        assert_eq!(grid_sizes(0, 120), vec![120, 1]);
        // every candidate is launchable: within [1, dev_cus]
        for tiles in [1usize, 7, 31, 120, 960, 961, 5000] {
            for c in grid_sizes(tiles, 120) {
                assert!((1..=120).contains(&c), "tiles={tiles} c={c}");
            }
        }
    }

    #[test]
    fn occupancy_guided_grid_beats_naive_halving_on_awkward_tiles() {
        // The case halving can't reach: 961 tiles. Naive halvings
        // {120, 60, 30} all leave a ragged last wave; the occupancy
        // scan finds the divisor grid.
        use crate::decomp::occupancy::dp_efficiency;
        let best = *grid_sizes(961, 120).last().unwrap();
        assert!(dp_efficiency(961, best) > 0.999, "best={best}");
        for naive in [120usize, 60, 30] {
            assert!(dp_efficiency(961, naive) < 0.98, "naive={naive}");
        }
    }

    #[test]
    fn pad_policy_round_trips() {
        for p in [PadPolicy::None, PadPolicy::Physical] {
            assert_eq!(PadPolicy::parse(p.as_str()), Some(p));
        }
        assert_eq!(PadPolicy::parse("maybe"), None);
    }
}
