//! Autotuner — the systematic exploration the report could not run.
//!
//! The paper's parameter study ended with "adjusting the block size and
//! parameters led to the process getting stuck, indicating a need for
//! further tuning". This subsystem is that further tuning, built from
//! the two prerequisites the repo already had:
//!
//! - [`space`] — the `KernelParams` × padding × grid-size search space,
//!   pruned up front by `decomp::params::check` so illegal points are
//!   *never visited* (CK surfaced them as opaque template failures; we
//!   name them and skip them);
//! - [`search`] — two-phase search: Block2Time-predicted ranking
//!   ([`crate::predict`]) of the legal candidates, then measured
//!   refinement of the top-K on [`crate::gpu_sim`], under a hard
//!   iteration/time budget so no configuration can ever "get stuck";
//! - [`cache`] — a persistent, versioned tuning cache keyed by
//!   ([`ShapeBucket`], [`DeviceFingerprint`]) with an in-memory LRU
//!   front, serialized through the in-tree `json` module;
//! - [`fingerprint`] — the cache keys.
//!
//! The serving coordinator consults a shared [`Tuner`] per incoming
//! GEMM shape (hit → tuned routing policy, miss → defaults + a
//! background tune), and `streamk tune` warms the cache offline.
//! `cargo bench --bench tuner_gain` demonstrates tuned-vs-default
//! speedups across the Table-1 shape suite.

pub mod cache;
pub mod fingerprint;
pub mod search;
pub mod space;

pub use cache::{CacheError, TuningCache, CACHE_VERSION};
pub use fingerprint::{DeviceFingerprint, ShapeBucket};
pub use search::{
    measure, tune, Budget, TuneError, TuneOptions, TuneReport, TunedConfig,
};
pub use space::{enumerate, Candidate, PadPolicy, SpaceStats};

use crate::decomp::GemmShape;
use crate::gpu_sim::Device;
use std::path::Path;
use std::sync::Mutex;

/// The paper's Table-1 shape suite — the canonical tuning/bench targets
/// (baseline, small, large uneven, medium).
pub const TABLE1_SUITE: &[(usize, usize, usize)] = &[
    (3840, 4096, 4096),
    (3, 9, 9),
    (1920, 2000, 2000),
    (480, 512, 512),
];

/// Thread-safe tuner handle: the cache plus the device it tunes for.
/// This is what the coordinator shares between the router (lookups) and
/// the background tune-on-miss worker (inserts).
pub struct Tuner {
    dev: Device,
    opts: TuneOptions,
    fingerprint: DeviceFingerprint,
    capacity: usize,
    cache: Mutex<TuningCache>,
}

impl Tuner {
    pub fn new(dev: Device, opts: TuneOptions, capacity: usize) -> Self {
        let fingerprint = DeviceFingerprint::of(&dev);
        Self {
            dev,
            opts,
            fingerprint,
            capacity,
            cache: Mutex::new(TuningCache::new(capacity)),
        }
    }

    pub fn device(&self) -> &Device {
        &self.dev
    }

    pub fn options(&self) -> &TuneOptions {
        &self.opts
    }

    pub fn len(&self) -> usize {
        self.cache.lock().expect("tuner cache").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cached entries usable by *this* tuner (matching its device
    /// fingerprint). A loaded cache with `len() > 0` but
    /// `matching_entries() == 0` was tuned for a different device.
    pub fn matching_entries(&self) -> usize {
        self.cache
            .lock()
            .expect("tuner cache")
            .count_for(&self.fingerprint)
    }

    /// Cache lookup for a shape (bucketed, at this tuner's element
    /// width). `None` is a miss.
    pub fn lookup(&self, shape: GemmShape) -> Option<TunedConfig> {
        let bucket = ShapeBucket::of(shape);
        self.cache.lock().expect("tuner cache").get(
            &bucket,
            self.opts.bytes_per_elem,
            &self.fingerprint,
        )
    }

    /// Tune the shape's bucket (at its representative, so the result is
    /// valid for everything that maps there) and insert the winner.
    /// The cache lock is NOT held during the search — lookups proceed
    /// concurrently while a tune runs.
    pub fn tune_and_insert(
        &self,
        shape: GemmShape,
    ) -> Result<TuneReport, TuneError> {
        let bucket = ShapeBucket::of(shape);
        let report = tune(bucket.representative(), &self.dev, &self.opts)?;
        self.cache.lock().expect("tuner cache").insert(
            &bucket,
            self.opts.bytes_per_elem,
            &self.fingerprint,
            report.best,
        );
        Ok(report)
    }

    /// Replace the in-memory cache with the persisted one at `path`
    /// (bounded by the capacity this tuner was built with). Version
    /// mismatches come back as errors; the caller chooses between
    /// discarding (serve path warms from empty) and aborting.
    pub fn load_cache(&self, path: &Path) -> Result<usize, CacheError> {
        let loaded = TuningCache::load(path, self.capacity)?;
        let n = loaded.len();
        *self.cache.lock().expect("tuner cache") = loaded;
        Ok(n)
    }

    pub fn store_cache(&self, path: &Path) -> Result<(), CacheError> {
        self.cache.lock().expect("tuner cache").store(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_sim::DeviceKind;

    fn tuner() -> Tuner {
        Tuner::new(
            Device::preset(DeviceKind::Mi200),
            TuneOptions::default(),
            8,
        )
    }

    #[test]
    fn miss_then_tune_then_hit() {
        let t = tuner();
        let shape = GemmShape::new(480, 512, 512);
        assert!(t.lookup(shape).is_none());
        let report = t.tune_and_insert(shape).unwrap();
        let hit = t.lookup(shape).expect("tuned shape must hit");
        assert_eq!(hit, report.best);
        // a different shape in the same pow2 bucket also hits
        let neighbor = GemmShape::new(400, 500, 300);
        assert!(t.lookup(neighbor).is_some());
        // a different bucket still misses
        assert!(t.lookup(GemmShape::new(4000, 4000, 4000)).is_none());
    }

    #[test]
    fn persist_and_reload_via_handle() {
        let t = tuner();
        let shape = GemmShape::new(1920, 2000, 2000);
        t.tune_and_insert(shape).unwrap();
        let path = std::env::temp_dir().join(format!(
            "streamk-tuner-handle-{}.json",
            std::process::id()
        ));
        t.store_cache(&path).unwrap();

        let fresh = tuner();
        assert!(fresh.lookup(shape).is_none());
        let n = fresh.load_cache(&path).unwrap();
        assert_eq!(n, 1);
        assert!(fresh.lookup(shape).is_some());
        std::fs::remove_file(&path).unwrap();
    }
}
