//! Autotuner — the systematic exploration the report could not run.
//!
//! The paper's parameter study ended with "adjusting the block size and
//! parameters led to the process getting stuck, indicating a need for
//! further tuning". This subsystem is that further tuning, built from
//! the two prerequisites the repo already had:
//!
//! - [`space`] — the `KernelParams` × padding × grid-size search space,
//!   pruned up front by `decomp::params::check` so illegal points are
//!   *never visited* (CK surfaced them as opaque template failures; we
//!   name them and skip them); grid-size candidates are occupancy-guided
//!   ([`crate::decomp::occupancy`]), not naive halvings;
//! - [`search`] — two-phase search: Block2Time-predicted ranking
//!   ([`crate::predict`]) of the legal candidates, then measured
//!   refinement of the top-K on [`crate::gpu_sim`], under a hard
//!   iteration/time budget so no configuration can ever "get stuck";
//! - [`cache`] — a persistent, versioned tuning cache keyed by
//!   ([`ShapeBucket`], [`DeviceFingerprint`]) with an in-memory LRU
//!   front and a staleness policy (age-out + drift re-validation),
//!   serialized through the in-tree `json` module;
//! - [`fingerprint`] — the cache keys.
//!
//! The serving coordinator consults one [`Tuner`] per fleet device
//! (hit → tuned routing policy, miss → defaults + a background tune),
//! and `streamk tune` warms or re-validates the cache offline. The
//! online half of the Block2Time loop is [`Tuner::observe`]: measured
//! serving latencies are folded back into the cached predictions, so
//! the fleet scheduler's completion estimates tighten as traffic flows.
//! `cargo bench --bench tuner_gain` demonstrates tuned-vs-default
//! speedups; `cargo bench --bench fleet_throughput` demonstrates the
//! cross-device loop.

pub mod cache;
pub mod fingerprint;
pub mod search;
pub mod space;

pub use cache::{
    entry_drift, now_epoch_s, CacheError, StalenessPolicy, SweepReport,
    TuningCache, CACHE_VERSION,
};
pub use fingerprint::{DeviceFingerprint, ShapeBucket};
pub use search::{
    measure, tune, Budget, TuneError, TuneOptions, TuneReport, TunedConfig,
};
pub use space::{enumerate, Candidate, PadPolicy, SpaceStats};

use crate::decomp::GemmShape;
use crate::exec::pool_map;
use crate::gpu_sim::Device;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// The paper's Table-1 shape suite — the canonical tuning/bench targets
/// (baseline, small, large uneven, medium).
pub const TABLE1_SUITE: &[(usize, usize, usize)] = &[
    (3840, 4096, 4096),
    (3, 9, 9),
    (1920, 2000, 2000),
    (480, 512, 512),
];

/// Default EWMA weight of one new serving observation in `observed_s`.
const OBSERVE_ALPHA: f64 = 0.3;
/// Default prediction blend: how far one observation pulls the cached
/// prediction toward the measured latency — the online Block2Time
/// re-tuning step. Geometric: after k same-valued observations the
/// prediction error shrinks by (1 − PREDICT_BLEND)^k.
const PREDICT_BLEND: f64 = 0.25;

/// The two online-feedback smoothing constants, made configurable
/// (settings key / env override) instead of hard-coded: the observation
/// EWMA weight and the prediction blend used by [`Tuner::observe`].
/// Both live in (0, 1]; higher chases regime changes faster, lower
/// rejects noise harder.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BlendConfig {
    /// EWMA weight of one new serving observation in `observed_s`.
    pub observe_alpha: f64,
    /// How far one observation pulls the cached prediction toward the
    /// measured latency.
    pub predict_blend: f64,
}

impl Default for BlendConfig {
    fn default() -> Self {
        Self { observe_alpha: OBSERVE_ALPHA, predict_blend: PREDICT_BLEND }
    }
}

fn env_unit_fraction(key: &str) -> Option<f64> {
    std::env::var(key)
        .ok()?
        .trim()
        .parse::<f64>()
        .ok()
        .filter(|v| v.is_finite() && *v > 0.0 && *v <= 1.0)
}

impl BlendConfig {
    /// Defaults overridden by `STREAMK_OBSERVE_ALPHA` /
    /// `STREAMK_PREDICT_BLEND` (each a fraction in (0, 1]; malformed or
    /// out-of-range values are ignored, never panicked on).
    pub fn from_env() -> Self {
        let mut c = Self::default();
        if let Some(v) = env_unit_fraction("STREAMK_OBSERVE_ALPHA") {
            c.observe_alpha = v;
        }
        if let Some(v) = env_unit_fraction("STREAMK_PREDICT_BLEND") {
            c.predict_blend = v;
        }
        c
    }

    pub fn is_valid(&self) -> bool {
        let ok = |v: f64| v.is_finite() && v > 0.0 && v <= 1.0;
        ok(self.observe_alpha) && ok(self.predict_blend)
    }

    /// Least-squares estimate of the smoothing constants from recorded
    /// scenario traces: one measured-latency series per (device,
    /// bucket). Picks the coefficient minimizing the summed one-step-
    /// ahead squared prediction error of the EWMA across all series
    /// (see [`fit_ewma_alpha`]); both constants smooth the same signal
    /// toward measured latency, so the fitted tracking coefficient
    /// applies to each. `None` when no series has ≥ 3 finite samples.
    pub fn fit(series: &[Vec<f64>]) -> Option<Self> {
        let alpha = fit_ewma_alpha_many(series)?;
        Some(Self { observe_alpha: alpha, predict_blend: alpha })
    }
}

/// Least-squares fit of a single EWMA smoothing coefficient to one
/// recorded series: the α in (0, 1] minimizing
/// Σₜ (EWMA_{t−1}(α) − xₜ)² — i.e. the best one-step-ahead tracker of
/// the measured latencies. Evaluated on a fine grid (the objective is
/// cheap and not guaranteed convex across regime changes). Returns
/// `None` for fewer than 3 finite samples.
pub fn fit_ewma_alpha(series: &[f64]) -> Option<f64> {
    fit_ewma_alpha_many(std::slice::from_ref(&series.to_vec()))
}

fn fit_ewma_alpha_many(series: &[Vec<f64>]) -> Option<f64> {
    let cleaned: Vec<Vec<f64>> = series
        .iter()
        .map(|s| {
            s.iter().copied().filter(|v| v.is_finite() && *v > 0.0).collect()
        })
        .filter(|s: &Vec<f64>| s.len() >= 3)
        .collect();
    if cleaned.is_empty() {
        return None;
    }
    let sse = |alpha: f64| -> f64 {
        let mut total = 0.0;
        for s in &cleaned {
            let mut ewma = s[0];
            for &x in &s[1..] {
                let err = ewma - x;
                total += err * err;
                ewma = (1.0 - alpha) * ewma + alpha * x;
            }
        }
        total
    };
    let mut best = (f64::INFINITY, OBSERVE_ALPHA);
    for step in 1..=100 {
        let alpha = step as f64 / 100.0;
        let e = sse(alpha);
        if e < best.0 {
            best = (e, alpha);
        }
    }
    Some(best.1)
}

/// Outcome of folding one measured serving latency into the cache.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Observation {
    /// Measurement was NaN/∞/non-positive — discarded before it could
    /// poison the entry (a clock glitch must not steer placement).
    Rejected,
    /// No cache entry for this shape bucket (nothing to refine).
    NoEntry,
    /// Entry updated; `drift` is the relative gap between the cached
    /// prediction and this measurement, *before* the update.
    Updated { drift: f64 },
    /// Drift exceeded the staleness policy after enough observations —
    /// the caller should re-tune this bucket.
    Drifted { drift: f64 },
}

/// What one offline re-validation pass (`streamk tune --revalidate`) did.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RevalidateReport {
    /// Entries dropped by the age-out half of the staleness policy.
    pub aged_out: usize,
    /// Entries probed against a fresh measurement.
    pub checked: usize,
    /// Entries whose fresh probe drifted past policy → re-tuned.
    pub retuned: usize,
    /// Entries within policy; their `measured_s` was refreshed.
    pub refreshed: usize,
    /// Entries skipped (other element width, unparseable key, or a
    /// re-tune failure).
    pub skipped: usize,
}

/// Thread-safe tuner handle: the cache plus the device it tunes for.
/// This is what the coordinator shares between the router (lookups) and
/// the background tune-on-miss worker (inserts) — one per fleet device.
pub struct Tuner {
    dev: Device,
    opts: TuneOptions,
    staleness: StalenessPolicy,
    blend: BlendConfig,
    fingerprint: DeviceFingerprint,
    capacity: usize,
    cache: Mutex<TuningCache>,
}

impl Tuner {
    pub fn new(dev: Device, opts: TuneOptions, capacity: usize) -> Self {
        let fingerprint = DeviceFingerprint::of(&dev);
        Self {
            dev,
            opts,
            staleness: StalenessPolicy::default(),
            blend: BlendConfig::from_env(),
            fingerprint,
            capacity,
            cache: Mutex::new(TuningCache::new(capacity)),
        }
    }

    /// Override the staleness policy (age-out horizon, drift threshold).
    pub fn with_staleness(mut self, policy: StalenessPolicy) -> Self {
        self.staleness = policy;
        self
    }

    /// Override the feedback smoothing constants (ignores invalid
    /// configs, keeping the current one — a bad settings file must not
    /// freeze or explode the feedback loop).
    pub fn with_blend(mut self, blend: BlendConfig) -> Self {
        if blend.is_valid() {
            self.blend = blend;
        }
        self
    }

    pub fn blend(&self) -> BlendConfig {
        self.blend
    }

    pub fn device(&self) -> &Device {
        &self.dev
    }

    pub fn options(&self) -> &TuneOptions {
        &self.opts
    }

    pub fn staleness(&self) -> &StalenessPolicy {
        &self.staleness
    }

    pub fn fingerprint(&self) -> &DeviceFingerprint {
        &self.fingerprint
    }

    pub fn len(&self) -> usize {
        self.cache.lock().expect("tuner cache").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cached entries usable by *this* tuner (matching its device
    /// fingerprint). A loaded cache with `len() > 0` but
    /// `matching_entries() == 0` was tuned for a different device.
    pub fn matching_entries(&self) -> usize {
        self.cache
            .lock()
            .expect("tuner cache")
            .count_for(&self.fingerprint)
    }

    /// Cache lookup for a shape (bucketed, at this tuner's element
    /// width). `None` is a miss.
    pub fn lookup(&self, shape: GemmShape) -> Option<TunedConfig> {
        let bucket = ShapeBucket::of(shape);
        self.cache.lock().expect("tuner cache").get(
            &bucket,
            self.opts.width,
            &self.fingerprint,
        )
    }

    /// Read-only lookup: no MRU promotion, no last-used refresh. The
    /// fleet scheduler uses this to price a shape on every device
    /// without marking entries as "in use" on devices that never serve
    /// the request (which would defeat age-out).
    pub fn peek(&self, shape: GemmShape) -> Option<TunedConfig> {
        let bucket = ShapeBucket::of(shape);
        self.cache.lock().expect("tuner cache").peek(
            &bucket,
            self.opts.width,
            &self.fingerprint,
        )
    }

    /// Tune the shape's bucket (at its representative, so the result is
    /// valid for everything that maps there) and insert the winner.
    /// The cache lock is NOT held during the search — lookups proceed
    /// concurrently while a tune runs.
    pub fn tune_and_insert(
        &self,
        shape: GemmShape,
    ) -> Result<TuneReport, TuneError> {
        let bucket = ShapeBucket::of(shape);
        let report = tune(bucket.representative(), &self.dev, &self.opts)?;
        self.cache.lock().expect("tuner cache").insert(
            &bucket,
            self.opts.width,
            &self.fingerprint,
            report.best,
        );
        Ok(report)
    }

    /// Re-tune a drifted bucket while carrying the serving
    /// observations over. The fresh search picks the *config* (params,
    /// pad, grid), but the *prediction* keeps the online-learned
    /// latency: the search's simulated estimate lives in simulator
    /// units that need not agree with measured serving latency, so
    /// restoring it would make the very next observation drift again —
    /// an endless re-tune cycle. With the observation EWMA carried
    /// over, drift after a re-validation is small by construction and
    /// the loop converges.
    pub fn retune_keeping_observations(
        &self,
        shape: GemmShape,
    ) -> Result<TuneReport, TuneError> {
        let bucket = ShapeBucket::of(shape);
        let previous = self.cache.lock().expect("tuner cache").peek(
            &bucket,
            self.opts.width,
            &self.fingerprint,
        );
        let report = self.tune_and_insert(shape)?;
        if let Some(old) = previous {
            if old.observed_n > 0
                && old.observed_s.is_finite()
                && old.observed_s > 0.0
            {
                self.cache.lock().expect("tuner cache").update(
                    &bucket,
                    self.opts.width,
                    &self.fingerprint,
                    |cfg| {
                        cfg.observed_s = old.observed_s;
                        cfg.observed_n = old.observed_n;
                        cfg.predicted_s = old.observed_s;
                    },
                );
            }
        }
        Ok(report)
    }

    /// Insert a configuration directly (fleet cache transplants, tests).
    pub fn insert_config(&self, shape: GemmShape, cfg: TunedConfig) {
        let bucket = ShapeBucket::of(shape);
        self.cache.lock().expect("tuner cache").insert(
            &bucket,
            self.opts.width,
            &self.fingerprint,
            cfg,
        );
    }

    /// Fold one *measured* serving latency for `shape` back into the
    /// cache — the online half of the Block2Time loop. Updates the
    /// observation EWMA and blends the cached prediction toward the
    /// measurement; reports [`Observation::Drifted`] when the staleness
    /// policy says the entry needs a full re-tune.
    pub fn observe(&self, shape: GemmShape, measured_s: f64) -> Observation {
        if !(measured_s.is_finite() && measured_s > 0.0) {
            return Observation::Rejected;
        }
        let bucket = ShapeBucket::of(shape);
        let mut drift = f64::INFINITY;
        let mut observations = 0u64;
        let updated = self.cache.lock().expect("tuner cache").update(
            &bucket,
            self.opts.width,
            &self.fingerprint,
            |cfg| {
                drift = if cfg.predicted_s.is_finite() && cfg.predicted_s > 0.0
                {
                    (cfg.predicted_s - measured_s).abs() / measured_s
                } else {
                    f64::INFINITY // poisoned prediction: maximal drift
                };
                cfg.observed_n += 1;
                cfg.observed_s = if cfg.observed_n == 1
                    || !cfg.observed_s.is_finite()
                {
                    measured_s
                } else {
                    (1.0 - self.blend.observe_alpha) * cfg.observed_s
                        + self.blend.observe_alpha * measured_s
                };
                cfg.predicted_s =
                    if cfg.predicted_s.is_finite() && cfg.predicted_s > 0.0 {
                        (1.0 - self.blend.predict_blend) * cfg.predicted_s
                            + self.blend.predict_blend * measured_s
                    } else {
                        measured_s
                    };
                observations = cfg.observed_n;
            },
        );
        if !updated {
            return Observation::NoEntry;
        }
        if observations >= self.staleness.min_observations
            && drift > self.staleness.max_drift
        {
            Observation::Drifted { drift }
        } else {
            Observation::Updated { drift }
        }
    }

    /// Apply the age-out half of the staleness policy now and report
    /// which surviving entries have drifted (by observation EWMA).
    pub fn sweep_stale(&self) -> SweepReport {
        self.cache
            .lock()
            .expect("tuner cache")
            .sweep_stale(now_epoch_s(), &self.staleness)
    }

    /// Offline re-validation (`streamk tune --revalidate`): age out
    /// untouched entries, then probe every surviving entry of this
    /// device with a fresh measurement; entries whose stored
    /// `measured_s` drifted past policy are re-tuned, the rest get
    /// their measurement refreshed. Never holds the cache lock across
    /// a probe or a tune.
    pub fn revalidate(&self) -> RevalidateReport {
        let mut report = RevalidateReport::default();
        let entries = {
            let mut cache = self.cache.lock().expect("tuner cache");
            report.aged_out =
                cache.sweep_stale(now_epoch_s(), &self.staleness).aged_out;
            cache.entries_for(&self.fingerprint)
        };
        for (key, cfg) in entries {
            let Some((bucket, width, _)) = cache::split_key(&key) else {
                report.skipped += 1;
                continue;
            };
            if width != self.opts.width {
                report.skipped += 1;
                continue;
            }
            report.checked += 1;
            let cand =
                Candidate { params: cfg.params, pad: cfg.pad, cus: cfg.cus };
            let fresh = measure(&self.dev, bucket.representative(), &cand);
            let stale = match fresh {
                Some(t)
                    if cfg.measured_s.is_finite() && cfg.measured_s > 0.0 =>
                {
                    (t - cfg.measured_s).abs() / cfg.measured_s
                        > self.staleness.max_drift
                }
                // unmeasurable config or poisoned entry: re-tune
                _ => true,
            };
            if stale {
                match self.tune_and_insert(bucket.representative()) {
                    Ok(_) => report.retuned += 1,
                    Err(_) => report.skipped += 1,
                }
            } else {
                let t = fresh.expect("non-stale implies a fresh probe");
                self.cache.lock().expect("tuner cache").update(
                    &bucket,
                    width,
                    &self.fingerprint,
                    |c| c.measured_s = t,
                );
                report.refreshed += 1;
            }
        }
        report
    }

    /// A copy of the current cache contents (the fleet merges these for
    /// single-file persistence).
    pub fn cache_snapshot(&self) -> TuningCache {
        self.cache.lock().expect("tuner cache").clone()
    }

    /// Replace the in-memory cache with the persisted one at `path`
    /// (bounded by the capacity this tuner was built with). Version
    /// mismatches come back as errors; the caller chooses between
    /// discarding (serve path warms from empty) and aborting.
    pub fn load_cache(&self, path: &Path) -> Result<usize, CacheError> {
        let loaded = TuningCache::load(path, self.capacity)?;
        let n = loaded.len();
        *self.cache.lock().expect("tuner cache") = loaded;
        Ok(n)
    }

    pub fn store_cache(&self, path: &Path) -> Result<(), CacheError> {
        self.cache.lock().expect("tuner cache").store(path)
    }
}

/// Tune several shapes concurrently over an [`crate::exec::ThreadPool`]
/// — the offline sweep path (`streamk tune --suite`, bench warm-ups).
/// Each job runs the full two-phase search; all of them share the
/// process-wide plan cache, so candidate grids that repeat across
/// shapes measure against already-flattened schedules. Results come
/// back in input order; the cache sees the same inserts as a serial
/// sweep (order of insertion may differ, contents do not).
pub fn tune_many(
    tuner: &Arc<Tuner>,
    shapes: &[GemmShape],
    threads: usize,
) -> Vec<(GemmShape, Result<TuneReport, TuneError>)> {
    let tuner = tuner.clone();
    pool_map(threads, shapes.to_vec(), move |shape| {
        (shape, tuner.tune_and_insert(shape))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_sim::DeviceKind;

    fn tuner() -> Tuner {
        Tuner::new(
            Device::preset(DeviceKind::Mi200),
            TuneOptions::default(),
            8,
        )
    }

    #[test]
    fn miss_then_tune_then_hit() {
        let t = tuner();
        let shape = GemmShape::new(480, 512, 512);
        assert!(t.lookup(shape).is_none());
        let report = t.tune_and_insert(shape).unwrap();
        let hit = t.lookup(shape).expect("tuned shape must hit");
        assert_eq!(hit, report.best);
        // a different shape in the same pow2 bucket also hits
        let neighbor = GemmShape::new(400, 500, 300);
        assert!(t.lookup(neighbor).is_some());
        // a different bucket still misses
        assert!(t.lookup(GemmShape::new(4000, 4000, 4000)).is_none());
    }

    #[test]
    fn persist_and_reload_via_handle() {
        let t = tuner();
        let shape = GemmShape::new(1920, 2000, 2000);
        t.tune_and_insert(shape).unwrap();
        let path = std::env::temp_dir().join(format!(
            "streamk-tuner-handle-{}.json",
            std::process::id()
        ));
        t.store_cache(&path).unwrap();

        let fresh = tuner();
        assert!(fresh.lookup(shape).is_none());
        let n = fresh.load_cache(&path).unwrap();
        assert_eq!(n, 1);
        assert!(fresh.lookup(shape).is_some());
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn observe_without_entry_reports_no_entry() {
        let t = tuner();
        assert_eq!(
            t.observe(GemmShape::new(480, 512, 512), 1.0e-3),
            Observation::NoEntry
        );
    }

    #[test]
    fn observe_rejects_poisoned_measurements() {
        let t = tuner();
        let shape = GemmShape::new(480, 512, 512);
        t.tune_and_insert(shape).unwrap();
        let before = t.lookup(shape).unwrap();
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -1.0] {
            assert_eq!(t.observe(shape, bad), Observation::Rejected);
        }
        let after = t.lookup(shape).unwrap();
        assert_eq!(after.observed_n, 0, "rejected samples never land");
        assert_eq!(after.predicted_s, before.predicted_s);
    }

    #[test]
    fn observations_tighten_the_prediction() {
        let t = tuner();
        let shape = GemmShape::new(1920, 2000, 2000);
        t.tune_and_insert(shape).unwrap();
        let p0 = t.lookup(shape).unwrap().predicted_s;
        // serve a constant "real" latency 40% above the prediction
        let real = p0 * 1.4;
        let mut last_drift = f64::INFINITY;
        for i in 1..=6u64 {
            match t.observe(shape, real) {
                Observation::Updated { drift } => {
                    assert!(
                        drift < last_drift,
                        "drift must shrink: {drift} vs {last_drift}"
                    );
                    last_drift = drift;
                }
                other => panic!("observation {i}: unexpected {other:?}"),
            }
        }
        let cfg = t.lookup(shape).unwrap();
        assert_eq!(cfg.observed_n, 6);
        assert!((cfg.observed_s - real).abs() / real < 0.05);
        // prediction converged toward reality
        assert!((cfg.predicted_s - real).abs() < (p0 - real).abs());
    }

    #[test]
    fn heavy_drift_flags_revalidation_after_min_observations() {
        let t = tuner().with_staleness(StalenessPolicy {
            max_drift: 0.5,
            min_observations: 2,
            ..Default::default()
        });
        let shape = GemmShape::new(480, 512, 512);
        t.tune_and_insert(shape).unwrap();
        let p0 = t.lookup(shape).unwrap().predicted_s;
        let real = p0 * 10.0; // 90% off
        // first observation: under min_observations, never flags
        assert!(matches!(
            t.observe(shape, real),
            Observation::Updated { .. }
        ));
        // second observation crosses min_observations while the blended
        // prediction is still 67% off → flagged for re-tune
        assert!(matches!(
            t.observe(shape, real),
            Observation::Drifted { drift } if drift > 0.5
        ));
    }

    #[test]
    fn retune_after_drift_converges_instead_of_cycling() {
        // Serving latencies live in different units than the
        // simulator's estimate (wall-clock vs modeled seconds). A
        // plain re-tune would restore the simulated prediction and
        // drift again on the very next observation; the
        // observation-carrying re-tune must come back within policy.
        let t = tuner().with_staleness(StalenessPolicy {
            max_drift: 0.5,
            min_observations: 2,
            ..Default::default()
        });
        let shape = GemmShape::new(480, 512, 512);
        t.tune_and_insert(shape).unwrap();
        let real = t.lookup(shape).unwrap().predicted_s * 1e4; // other units
        assert!(matches!(
            t.observe(shape, real),
            Observation::Updated { .. }
        ));
        assert!(matches!(
            t.observe(shape, real),
            Observation::Drifted { .. }
        ));
        t.retune_keeping_observations(shape).unwrap();
        let cfg = t.lookup(shape).unwrap();
        assert_eq!(cfg.observed_n, 2, "observations survive the re-tune");
        // prediction now sits at the observed latency, so the next
        // observation is within policy — the cycle is broken
        assert!(matches!(
            t.observe(shape, real),
            Observation::Updated { drift } if drift < 0.5
        ));
    }

    #[test]
    fn peek_is_read_only() {
        let t = tuner();
        let shape = GemmShape::new(480, 512, 512);
        assert!(t.peek(shape).is_none());
        t.tune_and_insert(shape).unwrap();
        assert_eq!(t.peek(shape), t.lookup(shape));
    }

    #[test]
    fn revalidate_retunes_entries_that_drifted_from_fresh_probe() {
        let t = tuner();
        let shape = GemmShape::new(1920, 2000, 2000);
        t.tune_and_insert(shape).unwrap();
        let good = t.lookup(shape).unwrap();

        // Poison the stored measurement (as if the device changed under
        // us): revalidate must catch it against the fresh probe.
        let mut poisoned = good;
        poisoned.measured_s = good.measured_s * 100.0;
        t.insert_config(shape, poisoned);

        let report = t.revalidate();
        assert_eq!(report.checked, 1);
        assert_eq!(report.retuned, 1);
        assert_eq!(report.refreshed, 0);
        let back = t.lookup(shape).unwrap();
        assert!(
            (back.measured_s - good.measured_s).abs()
                < good.measured_s * 0.5,
            "re-tune restored a sane measurement: {} vs {}",
            back.measured_s,
            good.measured_s
        );

        // a second pass finds nothing to do but a refresh
        let report = t.revalidate();
        assert_eq!(report.checked, 1);
        assert_eq!(report.retuned, 0);
        assert_eq!(report.refreshed, 1);
    }

    #[test]
    fn tune_many_matches_serial_tuning() {
        let parallel = Arc::new(tuner());
        let shapes: Vec<GemmShape> = TABLE1_SUITE
            .iter()
            .map(|&(m, n, k)| GemmShape::new(m, n, k))
            .collect();
        let results = tune_many(&parallel, &shapes, 4);
        assert_eq!(results.len(), shapes.len());
        for ((shape, result), want) in results.iter().zip(&shapes) {
            assert_eq!(shape, want, "input order preserved");
            let report = result.as_ref().expect("suite shapes tune");
            assert!(report.best.measured_s > 0.0);
            assert!(
                parallel.lookup(*shape).is_some(),
                "{shape:?} must land in the cache"
            );
        }
    }

    #[test]
    fn blend_config_overrides_the_smoothing_constants() {
        let defaults = BlendConfig::default();
        assert_eq!(defaults.observe_alpha, 0.3);
        assert_eq!(defaults.predict_blend, 0.25);
        assert!(defaults.is_valid());
        assert!(!BlendConfig { observe_alpha: 0.0, ..defaults }.is_valid());
        assert!(
            !BlendConfig { predict_blend: f64::NAN, ..defaults }.is_valid()
        );
        assert!(!BlendConfig { observe_alpha: 1.5, ..defaults }.is_valid());

        // predict_blend = 1.0: one observation snaps the prediction to
        // the measurement exactly.
        let t = tuner().with_blend(BlendConfig {
            observe_alpha: 1.0,
            predict_blend: 1.0,
        });
        let shape = GemmShape::new(480, 512, 512);
        t.tune_and_insert(shape).unwrap();
        let real = t.lookup(shape).unwrap().predicted_s * 1.4;
        t.observe(shape, real);
        let cfg = t.lookup(shape).unwrap();
        assert!((cfg.predicted_s - real).abs() < 1e-15);
        assert!((cfg.observed_s - real).abs() < 1e-15);

        // an invalid override is ignored, not installed
        let t = tuner().with_blend(BlendConfig {
            observe_alpha: -1.0,
            predict_blend: 0.5,
        });
        assert_eq!(t.blend(), BlendConfig::default());
    }

    #[test]
    fn fit_ewma_alpha_tracks_the_series_dynamics() {
        // A step change held for many samples rewards fast tracking.
        let mut step = vec![1.0; 5];
        step.extend(std::iter::repeat(4.0).take(40));
        let fast = fit_ewma_alpha(&step).unwrap();
        assert!(fast > 0.5, "step series wants a fast alpha: {fast}");

        // Alternating noise around a fixed mean rewards heavy smoothing.
        let noisy: Vec<f64> = (0..60)
            .map(|i| if i % 2 == 0 { 0.5 } else { 1.5 })
            .collect();
        let slow = fit_ewma_alpha(&noisy).unwrap();
        assert!(slow < fast, "noise wants a slower alpha: {slow} vs {fast}");

        // Degenerate inputs: too short, or nothing finite.
        assert!(fit_ewma_alpha(&[1.0, 2.0]).is_none());
        assert!(fit_ewma_alpha(&[f64::NAN, -1.0, 0.0, f64::INFINITY])
            .is_none());

        // The multi-series fit returns a valid config and applies the
        // same coefficient to both constants.
        let cfg =
            BlendConfig::fit(&[step.clone(), noisy.clone()]).unwrap();
        assert!(cfg.is_valid());
        assert_eq!(cfg.observe_alpha, cfg.predict_blend);
    }

    #[test]
    fn snapshot_is_a_copy() {
        let t = tuner();
        t.tune_and_insert(GemmShape::new(480, 512, 512)).unwrap();
        let snap = t.cache_snapshot();
        assert_eq!(snap.len(), 1);
        t.tune_and_insert(GemmShape::new(4000, 4000, 4000)).unwrap();
        assert_eq!(snap.len(), 1, "snapshot must not alias the live cache");
    }
}
