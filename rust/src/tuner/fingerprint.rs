//! Cache keys: shape buckets and device fingerprints.
//!
//! A tuned configuration transfers between problems that land in the
//! same performance regime, not just between identical shapes — so the
//! cache keys a power-of-two bucket of the GEMM shape. The device half
//! of the key captures everything the simulator's timing depends on;
//! two devices with the same fingerprint are interchangeable for tuning
//! purposes.

use crate::decomp::GemmShape;
use crate::gpu_sim::Device;

fn ceil_pow2(x: usize) -> usize {
    x.max(1).next_power_of_two()
}

/// Power-of-two bucketed GEMM shape — the shape half of the cache key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ShapeBucket {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl ShapeBucket {
    pub fn of(shape: GemmShape) -> Self {
        Self {
            m: ceil_pow2(shape.m),
            n: ceil_pow2(shape.n),
            k: ceil_pow2(shape.k),
        }
    }

    /// Stable text form used in the persistent cache file.
    pub fn key(&self) -> String {
        format!("{}x{}x{}", self.m, self.n, self.k)
    }

    pub fn parse(text: &str) -> Option<Self> {
        let mut it = text.split('x');
        let m = it.next()?.parse().ok()?;
        let n = it.next()?.parse().ok()?;
        let k = it.next()?.parse().ok()?;
        if it.next().is_some() {
            return None;
        }
        Some(Self { m, n, k })
    }

    /// A representative shape for tuning this bucket: the bucket's upper
    /// corner (the worst case the tuned config must still win on).
    pub fn representative(&self) -> GemmShape {
        GemmShape::new(self.m, self.n, self.k)
    }
}

/// Everything the simulated timing depends on, folded into a stable
/// string. Heterogeneity (per-CU speeds) is intentionally excluded: it
/// is transient (thermal / shared-cluster noise) and handled online by
/// the Block2Time balancer, not by the persistent cache.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct DeviceFingerprint(pub String);

impl DeviceFingerprint {
    pub fn of(dev: &Device) -> Self {
        Self(format!(
            "{}-cu{}-gf{:.0}-bw{:.0}-lo{:.1}-io{:.0}",
            dev.name,
            dev.num_cus,
            dev.flops_per_cu / 1e9,
            dev.hbm_bw / 1e9,
            dev.launch_overhead * 1e6,
            dev.iter_overhead * 1e9,
        ))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_sim::DeviceKind;

    #[test]
    fn buckets_round_up_to_pow2() {
        let b = ShapeBucket::of(GemmShape::new(3840, 4096, 4096));
        assert_eq!((b.m, b.n, b.k), (4096, 4096, 4096));
        let b = ShapeBucket::of(GemmShape::new(3, 9, 9));
        assert_eq!((b.m, b.n, b.k), (4, 16, 16));
        // exact powers stay put; zero clamps to 1
        let b = ShapeBucket::of(GemmShape::new(128, 1, 0));
        assert_eq!((b.m, b.n, b.k), (128, 1, 1));
    }

    #[test]
    fn nearby_shapes_share_a_bucket() {
        let a = ShapeBucket::of(GemmShape::new(1920, 2000, 2000));
        let b = ShapeBucket::of(GemmShape::new(2048, 1100, 1500));
        assert_eq!(a, b);
    }

    #[test]
    fn key_round_trips() {
        let b = ShapeBucket::of(GemmShape::new(480, 512, 512));
        assert_eq!(ShapeBucket::parse(&b.key()), Some(b));
        assert_eq!(ShapeBucket::parse("1x2"), None);
        assert_eq!(ShapeBucket::parse("1x2x3x4"), None);
        assert_eq!(ShapeBucket::parse("axbxc"), None);
    }

    #[test]
    fn fingerprint_distinguishes_devices_not_noise() {
        let mi200 = Device::preset(DeviceKind::Mi200);
        let mi100 = Device::preset(DeviceKind::Mi100);
        assert_ne!(DeviceFingerprint::of(&mi200), DeviceFingerprint::of(&mi100));
        assert_ne!(
            DeviceFingerprint::of(&mi200),
            DeviceFingerprint::of(&mi200.clone().with_cus(60))
        );
        // throttling (transient heterogeneity) does NOT change the key
        let throttled = mi200.clone().with_throttled(2, 0.5);
        assert_eq!(
            DeviceFingerprint::of(&mi200),
            DeviceFingerprint::of(&throttled)
        );
    }
}
