//! Block2Time — predictive per-CU runtime modeling and load balancing.
//!
//! The report's main future-work item: "utilizing Block2Time's predictive
//! modeling capabilities, we hope to enhance the accuracy of runtime
//! predictions and optimize the load balancing … across multiple and
//! various hardware configurations." Implemented here:
//!
//! 1. [`CostModel`] — least-squares fit of `time = a·iters + b` per work
//!    unit from observed (iters, seconds) samples;
//! 2. [`SpeedEstimator`] — per-CU relative speed from repeated
//!    equal-work probes (robust to noise via median);
//! 3. [`balance`] — a weighted Stream-K schedule whose per-CU shares are
//!    proportional to predicted speed, replacing the even split.
//!
//! `cargo bench --bench block2time` compares even vs predicted splits on
//! heterogeneous simulated devices (the B2T experiment).

use crate::decomp::{build_weighted_schedule, BlockShape, GemmShape, StreamKSchedule};

/// Linear per-CU cost model: `seconds = a · mac_iters + b`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostModel {
    /// Seconds per MAC iteration.
    pub a: f64,
    /// Fixed per-launch overhead seconds.
    pub b: f64,
}

impl CostModel {
    pub fn predict(&self, iters: usize) -> f64 {
        self.a * iters as f64 + self.b
    }
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FitError {
    Underdetermined(usize),
    NonFinite,
    /// A sample contained a NaN/∞ observation (clock glitch, dead CU);
    /// rejected up-front so the OLS sums never silently poison.
    NonFiniteSample { index: usize },
}

impl std::fmt::Display for FitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FitError::Underdetermined(n) => {
                write!(f, "need at least two samples with distinct x, got {n}")
            }
            FitError::NonFinite => {
                write!(f, "fit produced non-finite coefficients")
            }
            FitError::NonFiniteSample { index } => {
                write!(f, "sample {index} is NaN or infinite")
            }
        }
    }
}

impl std::error::Error for FitError {}

/// Ordinary least squares on (iters, seconds) samples.
pub fn fit(samples: &[(usize, f64)]) -> Result<CostModel, FitError> {
    let n = samples.len();
    if n < 2 {
        return Err(FitError::Underdetermined(n));
    }
    if let Some(index) =
        samples.iter().position(|&(_, y)| !y.is_finite())
    {
        return Err(FitError::NonFiniteSample { index });
    }
    let xs: Vec<f64> = samples.iter().map(|&(x, _)| x as f64).collect();
    let ys: Vec<f64> = samples.iter().map(|&(_, y)| y).collect();
    let mx = xs.iter().sum::<f64>() / n as f64;
    let my = ys.iter().sum::<f64>() / n as f64;
    let sxx: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    if sxx == 0.0 {
        return Err(FitError::Underdetermined(n));
    }
    let sxy: f64 = xs
        .iter()
        .zip(&ys)
        .map(|(x, y)| (x - mx) * (y - my))
        .sum();
    let a = sxy / sxx;
    let b = my - a * mx;
    if !a.is_finite() || !b.is_finite() {
        return Err(FitError::NonFinite);
    }
    Ok(CostModel { a, b })
}

/// Per-CU speed estimation from equal-work probe timings.
#[derive(Debug, Clone, Default)]
pub struct SpeedEstimator {
    /// Per CU: observed seconds for one probe unit of work.
    observations: Vec<Vec<f64>>,
}

impl SpeedEstimator {
    pub fn new(num_cus: usize) -> Self {
        Self { observations: vec![Vec::new(); num_cus] }
    }

    pub fn record(&mut self, cu: usize, seconds: f64) {
        assert!(seconds > 0.0, "non-positive probe time");
        self.observations[cu].push(seconds);
    }

    /// Median probe time per CU (None until every CU has a sample).
    pub fn median_times(&self) -> Option<Vec<f64>> {
        self.observations
            .iter()
            .map(|obs| {
                if obs.is_empty() {
                    return None;
                }
                let mut v = obs.clone();
                v.sort_by(|a, b| a.partial_cmp(b).expect("finite times"));
                Some(v[v.len() / 2])
            })
            .collect()
    }

    /// Relative speeds (1.0 = fastest CU), suitable for [`balance`].
    pub fn speeds(&self) -> Option<Vec<f64>> {
        let times = self.median_times()?;
        let fastest = times.iter().cloned().fold(f64::INFINITY, f64::min);
        Some(times.iter().map(|t| fastest / t).collect())
    }
}

/// Build the Block2Time-balanced schedule: per-CU share ∝ speed.
pub fn balance(
    shape: GemmShape,
    block: BlockShape,
    speeds: &[f64],
) -> Result<StreamKSchedule, crate::decomp::streamk::ScheduleError> {
    build_weighted_schedule(shape, block, speeds)
}

/// The cached counterpart of [`balance`]: fetch (or build, once per
/// quantized split) the weighted plan from the process-wide plan cache.
/// Speed vectors are quantized to 1/256 of the fastest CU inside the
/// key ([`crate::plan::PlanKey::weighted`]), so the jittery estimates a
/// [`SpeedEstimator`] refines over time collapse onto one reusable
/// plan instead of re-running the weighted decomposition per dispatch.
/// A speed below 1/512 of the fastest CU is unrepresentable in the
/// quantized key and comes back as an error (flooring it would hand a
/// near-dead CU up to 256× its true share): exclude such a CU, or use
/// the exact, uncached [`balance`].
pub fn balance_plan(
    shape: GemmShape,
    block: BlockShape,
    speeds: &[f64],
    bytes_per_elem: usize,
) -> Result<
    std::sync::Arc<crate::plan::Plan>,
    crate::decomp::streamk::ScheduleError,
> {
    crate::plan::global().get_or_build_weighted(
        shape,
        block,
        bytes_per_elem,
        speeds,
    )
}

/// Predicted makespan of a schedule on CUs with the given per-iteration
/// cost and speeds — used to pick even vs balanced at dispatch time.
pub fn predicted_makespan(
    sched: &StreamKSchedule,
    model: CostModel,
    speeds: &[f64],
) -> f64 {
    (0..sched.p)
        .map(|cu| model.predict(sched.cu_iters(cu)) / speeds[cu])
        .fold(0.0, f64::max)
}

/// [`predicted_makespan`] over a cached plan's precomputed per-CU
/// iteration counts (the counts are exact integers stored in f64) —
/// the [`balance_plan`] counterpart, so dispatch never needs the
/// nested schedule just to price it.
pub fn predicted_makespan_plan(
    plan: &crate::plan::Plan,
    model: CostModel,
    speeds: &[f64],
) -> f64 {
    (0..plan.key.cus)
        .map(|cu| model.predict(plan.cu_iters[cu] as usize) / speeds[cu])
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop;

    #[test]
    fn fit_recovers_exact_line() {
        let m = fit(&[(10, 1.2), (20, 2.2), (30, 3.2)]).unwrap();
        assert!((m.a - 0.1).abs() < 1e-9);
        assert!((m.b - 0.2).abs() < 1e-9);
        assert!((m.predict(50) - 5.2).abs() < 1e-9);
    }

    #[test]
    fn fit_rejects_degenerate() {
        assert_eq!(fit(&[]), Err(FitError::Underdetermined(0)));
        assert_eq!(fit(&[(5, 1.0)]), Err(FitError::Underdetermined(1)));
        assert_eq!(
            fit(&[(5, 1.0), (5, 2.0)]),
            Err(FitError::Underdetermined(2))
        );
    }

    #[test]
    fn fit_rejects_all_equal_x() {
        // Vertical line: infinitely many slopes fit. Must not return a
        // model (and must not divide by zero).
        let samples: Vec<(usize, f64)> =
            (0..10).map(|i| (100, 1.0 + i as f64)).collect();
        assert_eq!(fit(&samples), Err(FitError::Underdetermined(10)));
    }

    #[test]
    fn fit_rejects_non_finite_samples() {
        assert_eq!(
            fit(&[(10, 1.0), (20, f64::NAN), (30, 3.0)]),
            Err(FitError::NonFiniteSample { index: 1 })
        );
        assert_eq!(
            fit(&[(10, f64::INFINITY), (20, 2.0)]),
            Err(FitError::NonFiniteSample { index: 0 })
        );
        assert_eq!(
            fit(&[(10, 1.0), (20, f64::NEG_INFINITY)]),
            Err(FitError::NonFiniteSample { index: 1 })
        );
        // error text is actionable
        let e = fit(&[(1, f64::NAN), (2, 1.0)]).unwrap_err();
        assert!(e.to_string().contains("sample 0"));
    }

    #[test]
    fn prop_fit_tolerates_noise() {
        prop::check("ols noise", 30, |rng| {
            let a = rng.f64_unit() * 1e-3 + 1e-6;
            let b = rng.f64_unit() * 1e-2;
            let samples: Vec<(usize, f64)> = (1..=40)
                .map(|i| {
                    let x = i * 100;
                    let noise = 1.0 + 0.01 * rng.normal();
                    (x, (a * x as f64 + b) * noise)
                })
                .collect();
            let m = fit(&samples).map_err(|e| e.to_string())?;
            prop::ensure(
                (m.a - a).abs() / a < 0.1,
                format!("a {} vs {a}", m.a),
            )
        });
    }

    #[test]
    fn speed_estimator_uses_median() {
        let mut est = SpeedEstimator::new(2);
        for t in [1.0, 1.0, 9.0] {
            est.record(0, t); // one outlier
        }
        for t in [2.0, 2.0, 2.0] {
            est.record(1, t);
        }
        let speeds = est.speeds().unwrap();
        assert!((speeds[0] - 1.0).abs() < 1e-9);
        assert!((speeds[1] - 0.5).abs() < 1e-9);
    }

    #[test]
    fn speed_estimator_incomplete() {
        let est = SpeedEstimator::new(3);
        assert!(est.speeds().is_none());
    }

    #[test]
    fn balanced_schedule_beats_even_on_heterogeneous_cus() {
        use crate::decomp::build_schedule;
        let shape = GemmShape::new(2048, 2048, 2048);
        let block = BlockShape::default();
        // 4 CUs, one throttled to quarter speed.
        let speeds = vec![0.25, 1.0, 1.0, 1.0];
        let model = CostModel { a: 1e-6, b: 0.0 };
        let even = build_schedule(shape, block, 4).unwrap();
        let bal = balance(shape, block, &speeds).unwrap();
        let t_even = predicted_makespan(&even, model, &speeds);
        let t_bal = predicted_makespan(&bal, model, &speeds);
        assert!(
            t_bal < t_even * 0.45,
            "balanced {t_bal} vs even {t_even}"
        );
    }

    #[test]
    fn plan_makespan_agrees_with_schedule_makespan() {
        let shape = GemmShape::new(1024, 1024, 1024);
        let block = BlockShape::default();
        let speeds = vec![0.5, 1.0, 1.0, 1.0];
        let model = CostModel { a: 1e-6, b: 0.0 };
        let plan = balance_plan(shape, block, &speeds, 4).unwrap();
        // the same quantized split, priced through the nested schedule
        let factors = plan.key.weight_factors().unwrap();
        let sched = balance(shape, block, &factors).unwrap();
        assert_eq!(
            predicted_makespan_plan(&plan, model, &speeds),
            predicted_makespan(&sched, model, &speeds),
            "plan- and schedule-based makespans must agree exactly"
        );
    }

    #[test]
    fn balance_plan_reuses_quantized_splits() {
        // Two dispatches with estimates that differ below the quantum
        // must share one cached plan (global cache: assert per-key and
        // Arc identity only — other tests touch other keys).
        let shape = GemmShape::new(1536, 1536, 1536);
        let block = BlockShape::default();
        let a =
            balance_plan(shape, block, &[0.25, 1.0, 1.0, 1.0], 4).unwrap();
        let b = balance_plan(shape, block, &[0.2501, 1.0003, 1.0, 1.0], 4)
            .unwrap();
        assert!(
            std::sync::Arc::ptr_eq(&a, &b),
            "jittered estimate must reuse the cached plan"
        );
        // the plan is the quantized weighted schedule
        let factors = a.key.weight_factors().expect("weighted key");
        let sched = balance(shape, block, &factors).unwrap();
        assert_eq!(
            a.flat,
            crate::decomp::FlatSchedule::from_schedule(&sched)
        );
        // bad speeds still fail like the uncached builder
        assert!(balance_plan(shape, block, &[1.0, f64::NAN], 4).is_err());
        assert!(balance_plan(shape, block, &[], 4).is_err());
    }

    #[test]
    fn balanced_ties_even_on_homogeneous_cus() {
        use crate::decomp::build_schedule;
        let shape = GemmShape::new(1024, 1024, 1024);
        let block = BlockShape::default();
        let speeds = vec![1.0; 8];
        let model = CostModel { a: 1e-6, b: 0.0 };
        let even = build_schedule(shape, block, 8).unwrap();
        let bal = balance(shape, block, &speeds).unwrap();
        let t_even = predicted_makespan(&even, model, &speeds);
        let t_bal = predicted_makespan(&bal, model, &speeds);
        assert!((t_bal - t_even).abs() / t_even < 0.05);
    }
}
