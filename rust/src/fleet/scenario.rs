//! Adversarial-scenario runner — churn, skew, degradation, and
//! serving-time fault injection as deterministic, SLO-gated runs.
//!
//! The scenario DSL lives in [`crate::bench::workload`] (arrival curve
//! × drifting shape mix × scripted fleet events); this module executes
//! one [`Scenario`] against a simulated [`Fleet`] and reports what the
//! CI gates assert on:
//!
//! - **Open-loop with churn** — arrivals come from the (calibrated,
//!   absolute) rate curve; devices join mid-run ([`Fleet::add_device`],
//!   warm-seeded via [`Fleet::transfer_cache`] when asked), leave
//!   mid-flight (their queued work is requeued, never lost), decay to a
//!   fraction of their speed (the drift re-tune loop has to chase), or
//!   start corrupting results ([`crate::faults::Fault`]).
//! - **Spot-check validation** — every completed request is validated
//!   by re-running a small canary GEMM through the device's (possibly
//!   faulted) executor against ground truth, with *two* schedules
//!   (full-CU and sub-maximal) so each of the report's bug mechanisms
//!   trips at least one. A failed check counts the fault, requeues the
//!   request on another device, and quarantines the device after
//!   repeated hits — a wrong result is never served.
//! - **Conservation** — every offered request terminates exactly once:
//!   served, shed at admission, or dropped (unbuildable / attempts
//!   exhausted / no active device). [`ScenarioReport::conserved`] is a
//!   structural invariant the property tests and bench gates check.
//!
//! Everything is deterministic per scenario seed: arrivals, shape
//! draws, canary data, and the simulated execution times
//! ([`crate::tuner::measure`] on the owning device, divided by the
//! device's current degradation speed).

use super::registry::Fleet;
use super::sim::{tuned_candidate, warm};
use crate::bench::workload::{FleetAction, Scenario};
use crate::coordinator::slo;
use crate::coordinator::{Breach, Metrics};
use crate::decomp::{build_schedule, BlockShape, GemmShape, StreamKSchedule};
use crate::faults::{error_rate, naive_gemm, Fault, FaultyExecutor, Matrix};
use crate::gpu_sim::Device;
use crate::json::{obj, Value};
use crate::prop::Rng;
use crate::trace::residual::device_key;
use crate::trace::ResidualSnapshot;
use crate::tuner::{
    measure, Budget, Observation, ShapeBucket, StalenessPolicy, TuneOptions,
};
use std::cmp::Reverse;
use std::collections::{BTreeMap, BinaryHeap, VecDeque};
use std::sync::OnceLock;

/// Spot-check failures on one device before it is quarantined.
const QUARANTINE_HITS: u32 = 3;
/// Placement attempts per request before it is dropped (first try +
/// fault re-placements).
const MAX_ATTEMPTS: u32 = 4;
/// Consecutive tuner-cache hits a joiner needs to count as converged.
const JOIN_STREAK: u32 = 3;
/// Consecutive within-drift-policy completions the degraded device
/// needs before the re-tune loop counts as recovered.
const RECOVERY_STREAK: u32 = 5;
/// Closed-loop requests used to calibrate the fleet's service rate.
const CALIBRATION_REQUESTS: usize = 40;

/// Knobs for one scenario run.
#[derive(Debug, Clone, Default)]
pub struct ScenarioRunOptions {
    /// Override the scenario's offered request count (bench `--test`
    /// smoke mode shrinks, stress runs grow).
    pub requests: Option<usize>,
    /// Force every `Join { warm: true }` to join cold instead — the
    /// control arm of the warm-vs-cold convergence comparison.
    pub cold_joins: bool,
}

/// One mid-run joiner's convergence story.
#[derive(Debug, Clone)]
pub struct JoinerReport {
    pub device: usize,
    pub name: String,
    /// Whether the joiner was warm-seeded via cache transfer.
    pub warm: bool,
    /// Entries transplanted into the joiner's cache at join time.
    pub seeded: usize,
    /// Requests served by the joiner until its first
    /// [`JOIN_STREAK`]-long run of consecutive tuner-cache hits
    /// (`None` = never converged within the run).
    pub requests_to_converge: Option<u64>,
    pub served: u64,
}

/// Everything one scenario run produced — counters first (the CI
/// gates), then the latency/residual detail.
#[derive(Debug, Clone)]
pub struct ScenarioReport {
    pub name: String,
    /// Offered requests (after any [`ScenarioRunOptions::requests`]
    /// override).
    pub requests: usize,
    pub served: u64,
    pub shed: u64,
    pub dropped: u64,
    /// Re-placements (fault detections + device-leave evacuations).
    pub requeued: u64,
    /// Spot-check failures — every one re-placed, never served.
    pub faults_detected: u64,
    /// Served results whose device had an active fault the spot check
    /// missed. Structurally zero for the catalogue faults; the bench
    /// gate asserts it.
    pub wrong_results: u64,
    /// Devices deactivated after repeated spot-check failures.
    pub quarantined: u64,
    /// Scripted device departures.
    pub leaves: u64,
    pub joins: Vec<JoinerReport>,
    /// Drift-triggered observation-keeping re-tunes.
    pub revalidations: u64,
    /// Inline tunes for shapes missing from the placed device's cache.
    pub tunes_on_miss: u64,
    /// Completion time of the last served request (simulated seconds).
    pub makespan_s: f64,
    pub total_flops: f64,
    pub latency_p50_ms: f64,
    pub latency_p99_ms: f64,
    pub queue_delay_mean_s: f64,
    /// Seconds from the first Degrade event until the degraded device
    /// logged [`RECOVERY_STREAK`] consecutive within-policy
    /// completions (`None` = no Degrade event, or never recovered).
    pub retune_convergence_s: Option<f64>,
    pub residuals: Vec<ResidualSnapshot>,
    /// SLO breaches over the final metrics snapshot (empty = pass).
    pub breaches: Vec<Breach>,
    /// The admission bound the run used (from the scenario).
    pub final_bound: usize,
    /// Measured execution times per `dev{i}|bucket` key, in completion
    /// order — the trace [`crate::tuner::BlendConfig::fit`] consumes.
    pub measured_series: Vec<(String, Vec<f64>)>,
}

impl ScenarioReport {
    /// Shed fraction of offered load; 0.0 (not NaN) when nothing was
    /// offered, so SLO arithmetic downstream stays finite.
    pub fn shed_rate(&self) -> f64 {
        if self.requests > 0 {
            self.shed as f64 / self.requests as f64
        } else {
            0.0
        }
    }

    /// Served TFLOP/s at the makespan; 0.0 (not NaN/∞) when nothing
    /// completed.
    pub fn throughput_tflops(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.total_flops / self.makespan_s / 1e12
        } else {
            0.0
        }
    }

    /// Every offered request terminated exactly once: served, shed, or
    /// dropped. Requeues move a request, they never duplicate it.
    pub fn conserved(&self) -> bool {
        self.served + self.shed + self.dropped == self.requests as u64
    }

    pub fn to_json(&self) -> Value {
        obj(vec![
            ("scenario", self.name.as_str().into()),
            ("requests", self.requests.into()),
            ("served", (self.served as usize).into()),
            ("shed", (self.shed as usize).into()),
            ("dropped", (self.dropped as usize).into()),
            ("requeued", (self.requeued as usize).into()),
            ("faults_detected", (self.faults_detected as usize).into()),
            ("wrong_results", (self.wrong_results as usize).into()),
            ("quarantined", (self.quarantined as usize).into()),
            ("leaves", (self.leaves as usize).into()),
            ("revalidations", (self.revalidations as usize).into()),
            ("tunes_on_miss", (self.tunes_on_miss as usize).into()),
            ("shed_rate", self.shed_rate().into()),
            ("makespan_s", self.makespan_s.into()),
            ("throughput_tflops", self.throughput_tflops().into()),
            ("latency_p50_ms", self.latency_p50_ms.into()),
            ("latency_p99_ms", self.latency_p99_ms.into()),
            ("queue_delay_mean_s", self.queue_delay_mean_s.into()),
            (
                "retune_convergence_s",
                match self.retune_convergence_s {
                    Some(s) => s.into(),
                    None => Value::Null,
                },
            ),
            ("conserved", self.conserved().into()),
            (
                "joins",
                Value::Arr(
                    self.joins
                        .iter()
                        .map(|j| {
                            obj(vec![
                                ("device", j.device.into()),
                                ("name", j.name.as_str().into()),
                                ("warm", j.warm.into()),
                                ("seeded", j.seeded.into()),
                                (
                                    "requests_to_converge",
                                    match j.requests_to_converge {
                                        Some(n) => (n as usize).into(),
                                        None => Value::Null,
                                    },
                                ),
                                ("served", (j.served as usize).into()),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "breaches",
                Value::Arr(
                    self.breaches
                        .iter()
                        .map(|b| {
                            obj(vec![
                                ("rule", b.rule.as_str().into()),
                                ("value", b.value.into()),
                                ("limit", b.limit.into()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// One-line human form for `streamk fleet --scenario`.
    pub fn summary(&self) -> String {
        format!(
            "{}: {}/{} served | shed {:.1}% | dropped {} | requeued {} | \
             faults {} (wrong {}) | p99 {:.2} ms | breaches {}",
            self.name,
            self.served,
            self.requests,
            self.shed_rate() * 100.0,
            self.dropped,
            self.requeued,
            self.faults_detected,
            self.wrong_results,
            self.latency_p99_ms,
            self.breaches.len(),
        )
    }
}

// ---------------------------------------------------------------------
// Spot-check canary
// ---------------------------------------------------------------------

struct CanaryKit {
    a: Matrix,
    b: Matrix,
    want: Matrix,
    /// Full-CU and sub-maximal schedules of the same shape: the fixup
    /// overflow (≥3-way split tiles) and a CU-mapping mismatch against
    /// *any* `hw_cus` each corrupt at least one of the two.
    scheds: Vec<StreamKSchedule>,
}

static CANARY: OnceLock<CanaryKit> = OnceLock::new();

fn canary() -> &'static CanaryKit {
    CANARY.get_or_init(|| {
        let shape = GemmShape::new(60, 64, 64);
        let blk = BlockShape::new(16, 16, 2);
        let mut rng = Rng::new(0xCA_4A_11);
        let a = Matrix::random(60, 64, &mut rng);
        let b = Matrix::random(64, 64, &mut rng);
        let want = naive_gemm(&a, &b);
        let scheds = vec![
            build_schedule(shape, blk, 120).expect("canary schedule p=120"),
            build_schedule(shape, blk, 30).expect("canary schedule p=30"),
        ];
        CanaryKit { a, b, want, scheds }
    })
}

/// Run the canary GEMMs through an executor carrying `fault` and
/// compare against ground truth. `true` = output is bit-clean on both
/// schedules (the device's results can be trusted).
fn spot_check(fault: Fault) -> bool {
    let kit = canary();
    let exec = FaultyExecutor::new(fault);
    kit.scheds.iter().all(|s| {
        let got = exec.run(&kit.a, &kit.b, s);
        error_rate(&got.data, &kit.want.data, 1e-3).rate == 0.0
    })
}

// ---------------------------------------------------------------------
// Event-driven runner
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
struct Request {
    at_s: f64,
    shape: GemmShape,
    /// Placement attempts consumed by fault re-placements (a device
    /// *leaving* evacuates without charging the request).
    attempts: u32,
    /// Re-placements avoid the device that just failed the request.
    last_device: Option<usize>,
    /// Requeued work was already admitted once — it bypasses the
    /// admission bound instead of risking a double shed.
    redelivery: bool,
}

#[derive(Debug, Clone)]
enum Work {
    Arrive(Request),
    Event(FleetAction),
}

/// Heap slot ordered by (time, insertion seq) — the seq tiebreak keeps
/// the run deterministic and processes scripted events before arrivals
/// that land on the same instant.
struct Slot {
    t: f64,
    seq: u64,
    work: Work,
}

impl PartialEq for Slot {
    fn eq(&self, other: &Self) -> bool {
        self.t.total_cmp(&other.t).is_eq() && self.seq == other.seq
    }
}
impl Eq for Slot {}
impl PartialOrd for Slot {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Slot {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&other.t).then(self.seq.cmp(&other.seq))
    }
}

#[derive(Debug, Clone)]
struct Pending {
    req: Request,
    start_s: f64,
    done_s: f64,
    pred: Option<f64>,
    exec_s: f64,
    cache_hit: bool,
}

struct Runner {
    fleet: Fleet,
    sc: Scenario,
    cold_joins: bool,
    heap: BinaryHeap<Reverse<Slot>>,
    seq: u64,
    /// Per-device absolute time the device next comes free.
    free: Vec<f64>,
    /// Degradation multiplier on service speed (1.0 = nominal).
    speed: Vec<f64>,
    faults: Vec<Fault>,
    fault_hits: Vec<u32>,
    pending: Vec<VecDeque<Pending>>,
    metrics: Metrics,
    series: BTreeMap<String, Vec<f64>>,
    joins: Vec<JoinerReport>,
    join_streaks: BTreeMap<usize, u32>,
    served: u64,
    shed: u64,
    dropped: u64,
    requeued: u64,
    faults_detected: u64,
    wrong_results: u64,
    quarantined: u64,
    leaves: u64,
    revalidations: u64,
    tunes_on_miss: u64,
    makespan_s: f64,
    total_flops: f64,
    degraded: Option<usize>,
    degrade_at: Option<f64>,
    degrade_streak: u32,
    retune_convergence_s: Option<f64>,
}

impl Runner {
    fn new(fleet: Fleet, sc: Scenario, cold_joins: bool) -> Self {
        let n = fleet.len();
        Self {
            fleet,
            sc,
            cold_joins,
            heap: BinaryHeap::new(),
            seq: 0,
            free: vec![0.0; n],
            speed: vec![1.0; n],
            faults: vec![Fault::None; n],
            fault_hits: vec![0; n],
            pending: (0..n).map(|_| VecDeque::new()).collect(),
            metrics: Metrics::new(),
            series: BTreeMap::new(),
            joins: Vec::new(),
            join_streaks: BTreeMap::new(),
            served: 0,
            shed: 0,
            dropped: 0,
            requeued: 0,
            faults_detected: 0,
            wrong_results: 0,
            quarantined: 0,
            leaves: 0,
            revalidations: 0,
            tunes_on_miss: 0,
            makespan_s: 0.0,
            total_flops: 0.0,
            degraded: None,
            degrade_at: None,
            degrade_streak: 0,
            retune_convergence_s: None,
        }
    }

    fn push(&mut self, t: f64, work: Work) {
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Slot { t, seq, work }));
    }

    /// Closed-loop calibration: greedily place a short burst on the
    /// warmed fleet to learn its aggregate service rate, so the
    /// scenario's *relative* curve (base 1.0 = capacity) can be made
    /// absolute. Run before any events, on the founding fleet.
    fn calibrate(&self) -> f64 {
        let mut rng = Rng::new(self.sc.seed ^ 0xCA11_B8A7E);
        let mut busy = vec![0.0f64; self.fleet.len()];
        let mut served = 0usize;
        for i in 0..CALIBRATION_REQUESTS {
            let shape = self.sc.mix.sample(&mut rng, i);
            let idx = (0..self.fleet.len())
                .min_by(|&x, &y| {
                    let sx = busy[x]
                        + self.fleet.predict_exec(x, shape).unwrap_or(0.0);
                    let sy = busy[y]
                        + self.fleet.predict_exec(y, shape).unwrap_or(0.0);
                    sx.total_cmp(&sy)
                })
                .expect("non-empty fleet");
            let cand = tuned_candidate(&self.fleet, idx, shape);
            if let Some(e) =
                measure(self.fleet.device(idx).device(), shape, &cand)
            {
                busy[idx] += e;
                served += 1;
            }
        }
        let makespan = busy.iter().cloned().fold(0.0f64, f64::max);
        if makespan > 0.0 && served > 0 {
            served as f64 / makespan
        } else {
            1.0
        }
    }

    fn run(mut self) -> ScenarioReport {
        let cal_rate = self.calibrate();
        let n = self.sc.requests;
        // Nominal span: n arrivals at the curve's base fraction of the
        // calibrated capacity. Mod times in the catalogue are fractions
        // of this span.
        let span = n as f64 / (self.sc.curve.base * cal_rate).max(1e-12);
        let curve = self.sc.curve.scaled(cal_rate, span);
        let arrivals = curve.gen_times(self.sc.seed, n);
        let span_end = arrivals.last().copied().unwrap_or(0.0);
        // Events are anchored to the *generated* trace (a flash crowd
        // compresses arrivals, so the nominal span overshoots).
        for ev in self.sc.events.clone() {
            let t = ev.at.clamp(0.0, 1.0) * span_end;
            self.push(t, Work::Event(ev.action));
        }
        let mut shape_rng = Rng::new(self.sc.seed ^ 0x5AFE_C0DE);
        for (i, &t) in arrivals.iter().enumerate() {
            let shape = self.sc.mix.sample(&mut shape_rng, i);
            self.push(
                t,
                Work::Arrive(Request {
                    at_s: t,
                    shape,
                    attempts: 0,
                    last_device: None,
                    redelivery: false,
                }),
            );
        }

        // Global time order across three streams: completions, scripted
        // events, arrivals. Completions at time T commit before any
        // same-T heap work, so admission sees an up-to-date queue and
        // fault requeues re-enter after the device freed the slot.
        loop {
            let next_heap = self.heap.peek().map(|Reverse(s)| s.t);
            let next_done = self.earliest_done();
            match (next_heap, next_done) {
                (None, None) => break,
                (ht, Some((idx, d)))
                    if ht.map_or(true, |ht| d <= ht) =>
                {
                    self.commit_head(idx);
                }
                _ => {
                    let Reverse(slot) =
                        self.heap.pop().expect("heap non-empty");
                    match slot.work {
                        Work::Arrive(req) => self.place(req, slot.t),
                        Work::Event(action) => {
                            self.apply_event(action, slot.t)
                        }
                    }
                }
            }
        }
        self.finish()
    }

    /// The globally earliest uncommitted completion. Per-device queues
    /// complete in push order (service is FIFO per device), so only
    /// queue heads need scanning.
    fn earliest_done(&self) -> Option<(usize, f64)> {
        self.pending
            .iter()
            .enumerate()
            .filter_map(|(i, q)| q.front().map(|p| (i, p.done_s)))
            .min_by(|a, b| a.1.total_cmp(&b.1))
    }

    fn place(&mut self, req: Request, now: f64) {
        if !req.redelivery {
            self.metrics.on_submit();
        }
        let mut cands = self.fleet.active_indices();
        if cands.is_empty() {
            self.dropped += 1;
            self.metrics.on_fail();
            return;
        }
        if let Some(last) = req.last_device {
            if cands.len() > 1 {
                cands.retain(|&d| d != last);
            }
        }
        let shape = req.shape;
        let best = cands
            .iter()
            .copied()
            .min_by(|&x, &y| {
                let sx = self.free[x].max(now)
                    + self.fleet.predict_exec(x, shape).unwrap_or(0.0);
                let sy = self.free[y].max(now)
                    + self.fleet.predict_exec(y, shape).unwrap_or(0.0);
                sx.total_cmp(&sy)
            })
            .expect("candidates non-empty");
        if !req.redelivery
            && self.sc.max_queue > 0
            && self.pending[best].len() >= self.sc.max_queue
        {
            self.shed += 1;
            self.metrics.on_shed();
            return;
        }
        let tuner = &self.fleet.device(best).tuner;
        let cache_hit = tuner.lookup(shape).is_some();
        if !cache_hit {
            self.tunes_on_miss += 1;
            let _ = tuner.tune_and_insert(shape);
        }
        let pred = self.fleet.predict_exec(best, shape);
        let cand = tuned_candidate(&self.fleet, best, shape);
        let Some(base) =
            measure(self.fleet.device(best).device(), shape, &cand)
        else {
            self.dropped += 1;
            self.metrics.on_fail();
            return;
        };
        let exec_s = base / self.speed[best].max(1e-12);
        let start_s = self.free[best].max(now);
        let done_s = start_s + exec_s;
        self.free[best] = done_s;
        self.pending[best].push_back(Pending {
            req,
            start_s,
            done_s,
            pred,
            exec_s,
            cache_hit,
        });
    }

    fn apply_event(&mut self, action: FleetAction, t: f64) {
        match action {
            FleetAction::Leave { device }
                if device < self.fleet.len() =>
            {
                self.fleet.set_active(device, false);
                self.leaves += 1;
                // Evacuate in-flight work (completions ≤ t already
                // committed). The device failed, not the request, so
                // no attempt is charged.
                let inflight: Vec<Pending> =
                    self.pending[device].drain(..).collect();
                self.free[device] = t;
                for p in inflight {
                    self.requeued += 1;
                    let mut req = p.req;
                    req.last_device = Some(device);
                    req.redelivery = true;
                    self.push(t, Work::Arrive(req));
                }
            }
            FleetAction::Leave { .. } => {}
            FleetAction::Join { spec, warm } => {
                let Ok(dev) = Device::parse_spec(&spec) else {
                    return;
                };
                let idx = self.fleet.add_device(dev);
                self.free.push(t);
                self.speed.push(1.0);
                self.faults.push(Fault::None);
                self.fault_hits.push(0);
                self.pending.push(VecDeque::new());
                let warm = warm && !self.cold_joins;
                let seeded = if warm {
                    self.fleet.transfer_cache(idx)
                } else {
                    0
                };
                self.join_streaks.insert(idx, 0);
                self.joins.push(JoinerReport {
                    device: idx,
                    name: self.fleet.device(idx).name.clone(),
                    warm,
                    seeded,
                    requests_to_converge: None,
                    served: 0,
                });
            }
            FleetAction::Degrade { device, factor } => {
                if device < self.speed.len()
                    && factor.is_finite()
                    && factor > 0.0
                {
                    self.speed[device] *= factor;
                    if self.degrade_at.is_none() {
                        self.degraded = Some(device);
                        self.degrade_at = Some(t);
                    }
                }
            }
            FleetAction::Inject { device, fault } => {
                if device < self.faults.len() {
                    self.faults[device] = fault;
                }
            }
        }
    }

    fn commit_head(&mut self, idx: usize) {
        let p = self.pending[idx].pop_front().expect("queue head");
        self.makespan_s = self.makespan_s.max(p.done_s);
        let fault = self.faults[idx];
        if spot_check(fault) {
            if fault != Fault::None {
                // An active fault slipped past both canaries — the
                // result cannot be trusted and the bench gate treats
                // any non-zero count as a hard failure.
                self.wrong_results += 1;
            }
            self.serve(idx, p);
        } else {
            self.faults_detected += 1;
            self.fault_hits[idx] += 1;
            if self.fault_hits[idx] >= QUARANTINE_HITS
                && self.fleet.is_active(idx)
            {
                self.fleet.set_active(idx, false);
                self.quarantined += 1;
            }
            let mut req = p.req;
            req.attempts += 1;
            req.last_device = Some(idx);
            req.redelivery = true;
            if req.attempts >= MAX_ATTEMPTS {
                self.dropped += 1;
                self.metrics.on_fail();
            } else {
                self.requeued += 1;
                self.push(p.done_s, Work::Arrive(req));
            }
        }
    }

    fn serve(&mut self, idx: usize, p: Pending) {
        let shape = p.req.shape;
        self.served += 1;
        self.total_flops += shape.flops() as f64;
        let queue_s = (p.start_s - p.req.at_s).max(0.0);
        self.metrics.on_complete(queue_s, p.exec_s, shape.flops());
        let key = device_key(idx, &ShapeBucket::of(shape).key());
        self.metrics.on_residual(&key, p.pred, p.exec_s);
        self.series.entry(key).or_default().push(p.exec_s);
        let ape = p.pred.map(|pr| (pr - p.exec_s).abs() / p.exec_s);
        if let Observation::Drifted { .. } =
            self.fleet.observe(idx, shape, p.exec_s)
        {
            self.revalidations += 1;
            let _ = self
                .fleet
                .device(idx)
                .tuner
                .retune_keeping_observations(shape);
        }
        // Slow-node recovery clock: consecutive within-policy
        // completions on the degraded device, measured from the first
        // Degrade event.
        if let (Some(d), Some(t0)) = (self.degraded, self.degrade_at) {
            if idx == d
                && p.done_s >= t0
                && self.retune_convergence_s.is_none()
            {
                let max_drift =
                    self.fleet.device(idx).tuner.staleness().max_drift;
                if ape.map_or(false, |a| a <= max_drift) {
                    self.degrade_streak += 1;
                } else {
                    self.degrade_streak = 0;
                }
                if self.degrade_streak >= RECOVERY_STREAK {
                    self.retune_convergence_s = Some(p.done_s - t0);
                }
            }
        }
        // Joiner convergence: consecutive tuner-cache hits.
        if let Some(j) = self.joins.iter_mut().find(|j| j.device == idx) {
            j.served += 1;
            let streak = self.join_streaks.entry(idx).or_insert(0);
            if p.cache_hit {
                *streak += 1;
            } else {
                *streak = 0;
            }
            if *streak >= JOIN_STREAK && j.requests_to_converge.is_none() {
                j.requests_to_converge = Some(j.served);
            }
        }
    }

    fn finish(self) -> ScenarioReport {
        let snapshot = self.metrics.snapshot();
        let rules =
            slo::parse_rules(self.sc.slo).expect("catalogue SLO parses");
        let breaches = slo::evaluate(&rules, &snapshot, None);
        ScenarioReport {
            name: self.sc.name.to_string(),
            requests: self.sc.requests,
            served: self.served,
            shed: self.shed,
            dropped: self.dropped,
            requeued: self.requeued,
            faults_detected: self.faults_detected,
            wrong_results: self.wrong_results,
            quarantined: self.quarantined,
            leaves: self.leaves,
            joins: self.joins,
            revalidations: self.revalidations,
            tunes_on_miss: self.tunes_on_miss,
            makespan_s: self.makespan_s,
            total_flops: self.total_flops,
            latency_p50_ms: snapshot.e2e.quantile_us(0.50) / 1e3,
            latency_p99_ms: snapshot.e2e.quantile_us(0.99) / 1e3,
            queue_delay_mean_s: snapshot.queue.mean_us() / 1e6,
            retune_convergence_s: self.retune_convergence_s,
            residuals: snapshot.residuals,
            breaches,
            final_bound: self.sc.max_queue,
            measured_series: self.series.into_iter().collect(),
        }
    }
}

/// Run one scenario end to end on a fresh fleet built from its spec.
/// Deterministic per (scenario, options).
pub fn run_scenario(
    sc: &Scenario,
    opts: &ScenarioRunOptions,
) -> ScenarioReport {
    let sc = match opts.requests {
        Some(n) => sc.clone().with_requests(n),
        None => sc.clone(),
    };
    let devices = Device::parse_fleet_spec(sc.fleet_spec)
        .expect("scenario fleet spec parses");
    let fleet = Fleet::new(
        devices,
        TuneOptions {
            top_k: 4,
            budget: Budget::from_millis(40),
            ..TuneOptions::default()
        },
        StalenessPolicy::default(),
        64,
    );
    warm(&fleet, &sc.mix.shapes());
    Runner::new(fleet, sc, opts.cold_joins).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench::workload::{
        scenario, DriftingMix, FleetEvent, RateCurve,
    };
    use crate::prop;

    fn shrunk(name: &str, n: usize) -> ScenarioReport {
        let sc = scenario(name).expect("catalogue scenario");
        run_scenario(
            &sc,
            &ScenarioRunOptions { requests: Some(n), cold_joins: false },
        )
    }

    #[test]
    fn canary_catches_every_catalogue_fault() {
        assert!(spot_check(Fault::None), "fixed path must be clean");
        // Sub-maximal hw_cus corrupts the full-CU canary schedule.
        assert!(!spot_check(Fault::CuMapping { hw_cus: 30 }));
        // Full-CU hw_cus is identity on p=120 but corrupts p=30 — the
        // second canary exists exactly for this case.
        assert!(!spot_check(Fault::CuMapping { hw_cus: 120 }));
        // The canary shape has ≥3-way split tiles at p=120.
        assert!(!spot_check(Fault::FixupOverflow));
    }

    #[test]
    fn scenario_runs_are_deterministic() {
        let a = shrunk("flash-crowd", 60);
        let b = shrunk("flash-crowd", 60);
        assert_eq!(a.served, b.served);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.makespan_s, b.makespan_s);
        assert!(a.conserved(), "{a:?}");
        assert!(a.served > 0);
        assert!(a.shed_rate().is_finite());
        assert!(a.throughput_tflops().is_finite());
    }

    #[test]
    fn fault_injection_detects_and_never_serves_wrong_results() {
        let r = shrunk("fault-injection", 100);
        assert!(r.conserved(), "{r:?}");
        assert!(r.faults_detected > 0, "faults must trip the spot check");
        assert_eq!(r.wrong_results, 0, "a wrong result was served: {r:?}");
        assert!(r.quarantined >= 1, "repeat offenders must be benched");
        assert!(r.requeued > 0, "detected faults must re-place the work");
        assert!(r.served > 0, "healthy devices must absorb the load");
    }

    #[test]
    fn device_churn_requeues_inflight_and_joiner_serves() {
        let r = shrunk("device-churn", 120);
        assert!(r.conserved(), "{r:?}");
        assert_eq!(r.leaves, 1);
        assert_eq!(r.joins.len(), 1);
        let j = &r.joins[0];
        assert!(j.warm && j.seeded > 0, "join must seed via transfer");
        assert!(j.served > 0, "joiner must take traffic");
        assert_eq!(
            j.requests_to_converge,
            Some(u64::from(JOIN_STREAK)),
            "a fully warm-seeded joiner hits the cache from request 1"
        );
    }

    #[test]
    fn warm_joiner_converges_before_cold() {
        let sc = scenario("device-churn").unwrap();
        let warm = run_scenario(
            &sc,
            &ScenarioRunOptions { requests: Some(120), cold_joins: false },
        );
        let cold = run_scenario(
            &sc,
            &ScenarioRunOptions { requests: Some(120), cold_joins: true },
        );
        let w = warm.joins[0]
            .requests_to_converge
            .expect("warm joiner converges");
        // The cold joiner's first request is necessarily a cache miss,
        // so its streak cannot complete before request JOIN_STREAK + 1.
        match cold.joins[0].requests_to_converge {
            Some(c) => assert!(w < c, "warm {w} must beat cold {c}"),
            None => {} // never converged: warm wins by definition
        }
        assert_eq!(cold.joins[0].seeded, 0);
        assert!(cold.tunes_on_miss > warm.tunes_on_miss);
    }

    #[test]
    fn slow_node_recovery_clock_runs() {
        let r = shrunk("slow-node", 140);
        assert!(r.conserved(), "{r:?}");
        assert!(
            r.retune_convergence_s.is_some(),
            "drift re-tunes must chase the degraded device: {r:?}"
        );
        assert!(r.revalidations > 0, "degradation must trip drift");
    }

    #[test]
    fn prop_leave_conserves_every_request() {
        // Random leave instants and seeds: no request is ever lost or
        // duplicated across the evacuation/requeue path.
        prop::check("device-leave conservation", 4, |rng| {
            let at = 0.1 + 0.8 * rng.f64_unit();
            let device = rng.usize_in(0, 3);
            let sc = Scenario {
                name: "prop-leave",
                about: "conservation probe",
                seed: rng.next_u64() | 1,
                requests: 40,
                curve: RateCurve::constant(0.6),
                mix: DriftingMix::new(
                    crate::fleet::sim::ShapeMix::skewed_default().shapes(),
                    1.0,
                    13,
                ),
                events: vec![FleetEvent {
                    at,
                    action: FleetAction::Leave { device },
                }],
                fleet_spec: "mi200,mi200x0.5,mi100,mi100:60",
                max_queue: 4,
                slo: "shed<=1.0",
            };
            let r = run_scenario(&sc, &ScenarioRunOptions::default());
            prop::ensure(
                r.conserved(),
                format!(
                    "leave@{at:.2} dev{device}: served {} + shed {} + \
                     dropped {} != {}",
                    r.served, r.shed, r.dropped, r.requests
                ),
            )?;
            prop::ensure(r.leaves == 1, "leave must fire".into())
        });
    }

    #[test]
    fn zero_request_report_stays_finite() {
        let sc = scenario("drifting-hotset").unwrap();
        let r = run_scenario(
            &sc,
            &ScenarioRunOptions { requests: Some(1), cold_joins: false },
        );
        assert!(r.conserved());
        assert!(r.shed_rate().is_finite());
        assert!(r.throughput_tflops().is_finite());
        // And the report serializes.
        let j = r.to_json();
        assert_eq!(j.s("scenario").unwrap(), "drifting-hotset");
        assert!(j.f("shed_rate").unwrap().is_finite());
    }
}
