//! Heterogeneous fleet — multi-device scheduling between the
//! coordinator and the per-device engines.
//!
//! The paper frames the Stream-K decomposition as hardware-dependent
//! and names Block2Time's promise as "enhancing runtime predictions and
//! optimizing load balancing … across multiple and various hardware
//! configurations". PR 1 closed that loop *offline* for one device;
//! this subsystem closes it *online* across a fleet:
//!
//! ```text
//!                 ┌────────────────── fleet ──────────────────┐
//! client → queue →│ scheduler: argmin_d (in-flight_d + pred_d)│
//!                 │   pred_d = per-device tuner cache         │
//!                 │            (Block2Time, refined online)   │
//!                 │            → roofline prior → least-loaded│
//!                 └─────┬──────────────┬──────────────┬───────┘
//!                   device 0       device 1  …    device N-1
//!                   (engine +      (engine +       (engine +
//!                    tuner cache)   tuner cache)    tuner cache)
//!                       └──── measured latency ──────┘
//!                              ↓ observe()
//!                   blend prediction toward reality;
//!                   drift > policy → background re-tune;
//!                   untouched entries age out
//! ```
//!
//! - [`registry`] — the device registry: N simulated devices with
//!   distinct fingerprints (CU count, per-CU speed, HBM bandwidth — the
//!   `gpu_sim` heterogeneity hooks), each owning its own
//!   [`crate::tuner::Tuner`] cache;
//! - [`scheduler`] — cost-aware placement: lowest Block2Time-predicted
//!   completion time given current per-device predicted work-in-flight,
//!   falling back to least-loaded when no prediction exists; poisoned
//!   (NaN/∞) predictions are quarantined, never crash placement;
//! - [`feedback`] — the online re-tuning loop: measured request
//!   latencies fold back into the owning device's cache
//!   ([`crate::tuner::Tuner::observe`]), with staleness handling
//!   (drift → re-validate, untouched → age out);
//! - [`sim`] — deterministic fleet traffic simulation shared by
//!   `streamk fleet` and `cargo bench --bench fleet_throughput`
//!   (Block2Time-guided placement vs round-robin on a skewed mix);
//! - [`scenario`] — the adversarial-scenario runner: named
//!   [`crate::bench::workload::Scenario`]s (flash crowds, drifting hot
//!   sets, device churn, slow-node decay, serving-time fault
//!   injection) executed open-loop with spot-check validation and
//!   SLO-gated reports (`cargo bench --bench scenarios`,
//!   `streamk fleet --scenario <name>`).

pub mod feedback;
pub mod registry;
pub mod scenario;
pub mod scheduler;
pub mod sim;

pub use registry::{demo_fleet_devices, Fleet, FleetDevice};
pub use scenario::{
    run_scenario, JoinerReport, ScenarioReport, ScenarioRunOptions,
};
pub use scheduler::Placement;
pub use sim::{
    admits, gen_open_trace, gen_trace, run_trace, run_trace_open,
    run_trace_open_adaptive, run_trace_open_bounded, warm, OpenReport,
    PlacementPolicy, ShapeMix, SimReport, TimedRequest,
};
