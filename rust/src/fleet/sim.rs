//! Deterministic fleet traffic simulation — the harness behind
//! `streamk fleet` and `cargo bench --bench fleet_throughput`.
//!
//! A trace of GEMM requests (skewed shape mix, seeded) is placed on the
//! fleet under a policy (Block2Time-guided vs round-robin), each
//! request's execution time is *measured* on the owning simulated
//! device ([`crate::tuner::measure`], using that device's tuned config
//! when cached), and — when feedback is on — folded back through the
//! online re-tuning loop. The report captures everything the bench
//! tables and acceptance checks need: makespan, per-device load, and
//! the per-entry predicted-vs-measured drift series that demonstrates
//! the loop tightening.

use super::registry::Fleet;
use super::scheduler::Placement;
use crate::bench::workload::{Arrival, SizeMix};
use crate::decomp::params::KernelParams;
use crate::decomp::{BlockShape, GemmShape};
use crate::exec::pool_map;
use crate::prop::Rng;
use crate::trace::{self, ResidualSnapshot, ResidualTracker};
use crate::tuner::{
    measure, Candidate, Observation, PadPolicy, ShapeBucket, Tuner,
};
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

/// Weighted GEMM shape classes — the request-size mix.
#[derive(Debug, Clone)]
pub struct ShapeMix(pub Vec<(GemmShape, f64)>);

impl ShapeMix {
    /// The skewed serving mix: mostly small/medium shapes, a heavy
    /// tail of large ones. None sits on its pow2 bucket representative,
    /// so cached predictions start visibly off and the feedback loop
    /// has real drift to close.
    pub fn skewed_default() -> Self {
        ShapeMix(vec![
            (GemmShape::new(480, 512, 512), 0.45),
            (GemmShape::new(1920, 2000, 2000), 0.30),
            (GemmShape::new(960, 1024, 1024), 0.15),
            (GemmShape::new(3840, 4096, 4096), 0.10),
        ])
    }

    /// The distinct shapes in the mix (cache-warming targets).
    pub fn shapes(&self) -> Vec<GemmShape> {
        self.0.iter().map(|&(s, _)| s).collect()
    }

    /// Draw one shape by weight (public: the open-loop trace generator
    /// composes this with `bench::workload` arrival processes).
    pub fn sample(&self, rng: &mut Rng) -> GemmShape {
        let total: f64 = self.0.iter().map(|(_, w)| w).sum();
        let mut u = rng.f64_unit() * total;
        for &(shape, w) in &self.0 {
            if u < w {
                return shape;
            }
            u -= w;
        }
        self.0.last().expect("non-empty mix").0
    }
}

/// Generate a deterministic trace of `n` requests from the mix.
pub fn gen_trace(seed: u64, n: usize, mix: &ShapeMix) -> Vec<GemmShape> {
    assert!(!mix.0.is_empty(), "empty shape mix");
    let mut rng = Rng::new(seed);
    (0..n).map(|_| mix.sample(&mut rng)).collect()
}

/// How requests are assigned to devices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// The fleet scheduler: lowest Block2Time-predicted completion.
    Block2Time,
    /// The baseline: device `i % N` for request `i`.
    RoundRobin,
}

/// Drift of one cache entry over the run: the relative gap between the
/// cached prediction and each successive measurement on that device.
#[derive(Debug, Clone, PartialEq)]
pub struct DriftSeries {
    pub device: usize,
    pub bucket: String,
    pub drifts: Vec<f64>,
}

/// Everything one simulated traffic run produced.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub policy: PlacementPolicy,
    pub requests: usize,
    /// Completion time of the most-loaded device (closed-loop burst).
    pub makespan_s: f64,
    pub total_flops: f64,
    pub tflops: f64,
    pub device_busy_s: Vec<f64>,
    pub device_requests: Vec<u64>,
    /// Placements that took the least-loaded fallback path.
    pub fallback_placements: u64,
    /// Buckets re-tuned because observations drifted past policy.
    pub revalidations: u64,
    /// Per-(device, bucket) drift trajectories (feedback runs only).
    pub drift: Vec<DriftSeries>,
    /// Block2Time residual stats per shape bucket: the scheduler's
    /// placement prediction vs. the measured simulator time. Empty
    /// under round-robin (no prediction is made).
    pub residuals: Vec<ResidualSnapshot>,
}

impl SimReport {
    /// Fleet throughput in TFLOP/s at the makespan.
    pub fn throughput_tflops(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.total_flops / self.makespan_s / 1e12
        } else {
            0.0
        }
    }
}

/// Warm every device's cache for every distinct bucket in `shapes`.
/// The (device × bucket) tune jobs are independent, so they fan out
/// over an [`crate::exec::ThreadPool`] — a 4-device fleet warms in
/// roughly one tune's wall time per bucket instead of `devices ×
/// buckets`. Every job shares the process-wide plan cache, so repeated
/// candidate grids across devices measure against already-flattened
/// schedules. Returns the number of tunes performed.
pub fn warm(fleet: &Fleet, shapes: &[GemmShape]) -> usize {
    let mut jobs: Vec<(Arc<Tuner>, GemmShape)> = Vec::new();
    for d in fleet.devices() {
        let mut seen = Vec::new();
        for &shape in shapes {
            let bucket = ShapeBucket::of(shape);
            if seen.contains(&bucket) {
                continue;
            }
            seen.push(bucket);
            jobs.push((d.tuner.clone(), shape));
        }
    }
    pool_map(4, jobs, |(tuner, shape)| {
        tuner.tune_and_insert(shape).is_ok()
    })
    .into_iter()
    .filter(|&ok| ok)
    .count()
}

/// The execution config both replay loops share: the device's tuned
/// config when cached, else the one-config-per-precision default —
/// the same rule for every policy, so comparisons isolate *placement*.
/// `pub(super)` so the scenario runner executes requests identically.
pub(super) fn tuned_candidate(
    fleet: &Fleet,
    idx: usize,
    shape: GemmShape,
) -> Candidate {
    match fleet.device(idx).tuner.lookup(shape) {
        Some(cfg) => Candidate {
            params: cfg.params,
            pad: cfg.pad,
            cus: cfg.cus,
        },
        None => Candidate {
            params: KernelParams::new_w(
                BlockShape::default(),
                fleet.width(),
            ),
            pad: PadPolicy::None,
            cus: fleet.device(idx).device().num_cus,
        },
    }
}

/// Run one closed-loop trace (a burst: every request outstanding at
/// once) under `policy`. Execution times are measured per request on
/// the placed device's simulator; with `feedback` on, each measurement
/// is folded back into the owning cache and drift-flagged buckets are
/// re-tuned inline.
pub fn run_trace(
    fleet: &Fleet,
    trace: &[GemmShape],
    policy: PlacementPolicy,
    feedback: bool,
) -> SimReport {
    let n = fleet.len();
    let mut busy = vec![0.0f64; n];
    let mut counts = vec![0u64; n];
    let mut total_flops = 0.0f64;
    let mut fallbacks = 0u64;
    let mut revalidations = 0u64;
    let mut drift_map: BTreeMap<(usize, String), Vec<f64>> = BTreeMap::new();
    let mut placements: Vec<Placement> = Vec::with_capacity(trace.len());
    let mut residuals = ResidualTracker::new();

    for (i, &shape) in trace.iter().enumerate() {
        let placement = match policy {
            PlacementPolicy::Block2Time => fleet.place_gemm(shape),
            PlacementPolicy::RoundRobin => Placement {
                device: i % n,
                predicted_s: None,
                fallback: false,
            },
        };
        if placement.fallback {
            fallbacks += 1;
        }
        let idx = placement.device;
        let fdev = fleet.device(idx);
        let cand = tuned_candidate(fleet, idx, shape);
        if policy == PlacementPolicy::Block2Time {
            placements.push(placement);
        }
        let Some(exec_s) = measure(fdev.device(), shape, &cand) else {
            continue; // unbuildable schedule: request dropped
        };
        if let Some(pred) = placement.predicted_s {
            // Multi-device fleets key residuals per device: a slow
            // outlier's mispredictions must not average away inside
            // the shape bucket shared with faster devices.
            let key = ShapeBucket::of(shape).key();
            let key = if n > 1 {
                trace::residual::device_key(idx, &key)
            } else {
                key
            };
            residuals.observe(&key, pred, exec_s);
        }
        busy[idx] += exec_s;
        counts[idx] += 1;
        total_flops += shape.flops() as f64;

        if feedback {
            match fleet.observe(idx, shape, exec_s) {
                Observation::Updated { drift } => {
                    drift_map
                        .entry((idx, ShapeBucket::of(shape).key()))
                        .or_default()
                        .push(drift);
                }
                Observation::Drifted { drift } => {
                    drift_map
                        .entry((idx, ShapeBucket::of(shape).key()))
                        .or_default()
                        .push(drift);
                    revalidations += 1;
                    // observation-carrying re-tune: refreshes the
                    // config without resetting the learned latency
                    let _ = fdev.tuner.retune_keeping_observations(shape);
                }
                Observation::NoEntry | Observation::Rejected => {}
            }
        }
    }
    // Drain the scheduler accounting so back-to-back runs on the same
    // fleet start clean.
    for p in &placements {
        fleet.complete(p);
    }

    let makespan_s = busy.iter().cloned().fold(0.0f64, f64::max);
    SimReport {
        policy,
        requests: trace.len(),
        makespan_s,
        total_flops,
        tflops: if makespan_s > 0.0 {
            total_flops / makespan_s / 1e12
        } else {
            0.0
        },
        device_busy_s: busy,
        device_requests: counts,
        fallback_placements: fallbacks,
        revalidations,
        drift: drift_map
            .into_iter()
            .map(|((device, bucket), drifts)| DriftSeries {
                device,
                bucket,
                drifts,
            })
            .collect(),
        residuals: residuals.snapshot(),
    }
}

// ---------------------------------------------------------------------
// Open-loop traffic (timed arrivals → queueing delay is visible)
// ---------------------------------------------------------------------

/// A timed request: arrival offset (seconds from trace start) + shape.
pub type TimedRequest = (f64, GemmShape);

/// Generate a deterministic *open-loop* trace: arrival times from a
/// [`bench::workload::Arrival`](crate::bench::workload::Arrival) process,
/// shapes from the weighted mix. Closed-loop arrivals all land at t=0.
pub fn gen_open_trace(
    seed: u64,
    n: usize,
    mix: &ShapeMix,
    arrival: Arrival,
) -> Vec<TimedRequest> {
    assert!(!mix.0.is_empty(), "empty shape mix");
    // The workload module owns the arrival process; one unit-row mix
    // strips its size dimension, leaving pure timestamps.
    let times =
        crate::bench::workload::generate(seed, n, arrival, &SizeMix(vec![(1, 1.0)]));
    let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);
    times
        .into_iter()
        .map(|e| (e.at_s, mix.sample(&mut rng)))
        .collect()
}

/// Everything one open-loop run produced. Unlike the closed-loop
/// [`SimReport`], the makespan here includes *queueing*: a request that
/// arrives while its device is busy waits, and that wait is reported —
/// as is the shed count when an admission bound is set.
#[derive(Debug, Clone)]
pub struct OpenReport {
    pub policy: PlacementPolicy,
    pub requests: usize,
    /// Completion time of the last request (from trace start).
    pub makespan_s: f64,
    pub total_flops: f64,
    pub device_busy_s: Vec<f64>,
    pub device_requests: Vec<u64>,
    /// Mean seconds requests spent queued before starting.
    pub queue_delay_mean_s: f64,
    /// 95th-percentile queueing delay.
    pub queue_delay_p95_s: f64,
    /// Requests rejected by the queue-depth admission bound
    /// (0 when the run is unbounded).
    pub shed: u64,
    /// Requests dropped because no schedule could be built for their
    /// shape (distinct from shedding — these never reached a queue).
    /// Invariant: `served + shed + dropped == requests`.
    pub dropped: u64,
}

impl OpenReport {
    pub fn throughput_tflops(&self) -> f64 {
        if self.makespan_s > 0.0 {
            self.total_flops / self.makespan_s / 1e12
        } else {
            0.0
        }
    }

    /// Fraction of offered requests shed by the admission bound.
    pub fn shed_rate(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.shed as f64 / self.requests as f64
        }
    }
}

/// Replay a timed trace as an event simulation with no admission bound
/// — see [`run_trace_open_bounded`].
pub fn run_trace_open(
    fleet: &Fleet,
    trace: &[TimedRequest],
    policy: PlacementPolicy,
    feedback: bool,
) -> OpenReport {
    run_trace_open_bounded(fleet, trace, policy, feedback, 0)
}

/// Replay a timed trace as an event simulation: each request arrives at
/// its timestamp, is placed (earliest predicted completion under
/// Block2Time — current backlog + [`Fleet::predict_exec`] — or `i % n`
/// round-robin), queues until its device frees up, then runs for its
/// *measured* simulator time. With `feedback` on, measurements fold
/// back through the online re-tuning loop exactly as in the closed
/// loop.
///
/// `max_queue` is the open-loop shedding knob (`streamk fleet
/// --open-rate --max-queue`): when > 0, a request whose placed device
/// already has that many requests outstanding (running + waiting) at
/// its arrival instant is rejected instead of queued, and the shed
/// count/rate is reported next to the queue-delay stats. 0 means admit
/// everything (identical to the unbounded replay).
/// The admission predicate shared by the open-loop fleet simulator and
/// the TCP serving tier (`net::server`): with `bound == 0` everything
/// is admitted; otherwise a request is admitted only while fewer than
/// `bound` requests are outstanding. Keeping sim and daemon on one
/// predicate means the simulated shed behaviour *is* the live SHED
/// behaviour.
pub fn admits(outstanding: usize, bound: usize) -> bool {
    bound == 0 || outstanding < bound
}

pub fn run_trace_open_bounded(
    fleet: &Fleet,
    trace: &[TimedRequest],
    policy: PlacementPolicy,
    feedback: bool,
    max_queue: usize,
) -> OpenReport {
    // An infinite shed ceiling never adapts: identical to the fixed
    // bound.
    run_trace_open_adaptive(
        fleet,
        trace,
        policy,
        feedback,
        max_queue,
        f64::INFINITY,
    )
    .0
}

/// Arrivals per adaptation window of the SLO-coupled admission bound.
const ADAPT_WINDOW: usize = 32;

/// [`run_trace_open_bounded`] with an *adaptive* admission bound: the
/// shed rate is evaluated over windows of [`ADAPT_WINDOW`] arrivals,
/// and a window whose rate exceeds `shed_ceiling` tightens the bound to
/// ¾ of its current value (floor 1) — the fleet-sim realization of the
/// SLO watchdog's `shed<=X` rule (each tightening emits `slo.breach` /
/// `slo.adapt` trace events). Tightening trades more shedding at
/// admission for shorter queues: under sustained overload the tail
/// latency of *admitted* requests is what the SLO protects. A
/// `max_queue` of 0 (unbounded) never adapts — there is no bound to
/// tighten. Returns the report and the final bound.
pub fn run_trace_open_adaptive(
    fleet: &Fleet,
    trace: &[TimedRequest],
    policy: PlacementPolicy,
    feedback: bool,
    max_queue: usize,
    shed_ceiling: f64,
) -> (OpenReport, usize) {
    let n = fleet.len();
    let mut bound = max_queue;
    let mut window_shed = 0u64;
    let mut window_n = 0usize;
    let mut free = vec![0.0f64; n];
    let mut busy = vec![0.0f64; n];
    let mut counts = vec![0u64; n];
    // Per-device completion times of admitted-but-unfinished requests:
    // the queue depth the admission bound inspects.
    let mut outstanding: Vec<VecDeque<f64>> =
        (0..n).map(|_| VecDeque::new()).collect();
    let mut delays: Vec<f64> = Vec::with_capacity(trace.len());
    let mut total_flops = 0.0f64;
    let mut makespan = 0.0f64;
    let mut shed = 0u64;
    let mut dropped = 0u64;

    for (i, &(at_s, shape)) in trace.iter().enumerate() {
        let idx = match policy {
            PlacementPolicy::RoundRobin => i % n,
            PlacementPolicy::Block2Time => {
                // earliest predicted completion given each device's
                // simulated backlog; least-backlogged fallback when no
                // device has a usable prediction
                let mut best: Option<(f64, usize)> = None;
                for d in 0..n {
                    let Some(pred) = fleet.predict_exec(d, shape) else {
                        continue;
                    };
                    let fin = free[d].max(at_s) + pred;
                    if fin.is_finite()
                        && best.map_or(true, |(b, _)| fin < b)
                    {
                        best = Some((fin, d));
                    }
                }
                match best {
                    Some((_, d)) => d,
                    None => {
                        let mut least = 0;
                        for d in 1..n {
                            if free[d] < free[least] {
                                least = d;
                            }
                        }
                        least
                    }
                }
            }
        };
        // Admission control: drop requests that arrive while the placed
        // device already holds `bound` outstanding requests.
        let q = &mut outstanding[idx];
        while q.front().is_some_and(|&done| done <= at_s) {
            q.pop_front();
        }
        let this_shed = !admits(q.len(), bound);
        if this_shed {
            shed += 1;
            window_shed += 1;
        }
        window_n += 1;
        if window_n >= ADAPT_WINDOW {
            let rate = window_shed as f64 / window_n as f64;
            if bound > 0 && rate > shed_ceiling {
                drop(trace::span1("slo.breach", "pm", (rate * 1e3) as u64));
                bound = (bound * 3 / 4).max(1);
                drop(trace::span1("slo.adapt", "bound", bound as u64));
            }
            window_shed = 0;
            window_n = 0;
        }
        if this_shed {
            continue;
        }
        let cand = tuned_candidate(fleet, idx, shape);
        let Some(exec_s) = measure(fleet.device(idx).device(), shape, &cand)
        else {
            dropped += 1; // unbuildable schedule: request dropped
            continue;
        };
        let start = free[idx].max(at_s);
        delays.push(start - at_s);
        free[idx] = start + exec_s;
        outstanding[idx].push_back(free[idx]);
        makespan = makespan.max(free[idx]);
        busy[idx] += exec_s;
        counts[idx] += 1;
        total_flops += shape.flops() as f64;
        if feedback {
            if let Observation::Drifted { .. } =
                fleet.observe(idx, shape, exec_s)
            {
                let _ = fleet
                    .device(idx)
                    .tuner
                    .retune_keeping_observations(shape);
            }
        }
    }

    delays.sort_by(|a, b| a.total_cmp(b));
    let mean = if delays.is_empty() {
        0.0
    } else {
        delays.iter().sum::<f64>() / delays.len() as f64
    };
    let p95 = if delays.is_empty() {
        0.0
    } else {
        let idx = ((delays.len() as f64 * 0.95).ceil() as usize)
            .clamp(1, delays.len())
            - 1;
        delays[idx]
    };
    (
        OpenReport {
            policy,
            requests: trace.len(),
            makespan_s: makespan,
            total_flops,
            device_busy_s: busy,
            device_requests: counts,
            queue_delay_mean_s: mean,
            queue_delay_p95_s: p95,
            shed,
            dropped,
        },
        bound,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::registry::demo_fleet_devices;
    use crate::tuner::{Budget, StalenessPolicy, TuneOptions};

    fn quick_fleet() -> Fleet {
        let opts = TuneOptions {
            top_k: 4,
            budget: Budget::from_millis(50),
            ..TuneOptions::default()
        };
        // High drift threshold: unit tests exercise the blending, the
        // revalidation path is covered in tuner::tests.
        let staleness =
            StalenessPolicy { max_drift: 10.0, ..Default::default() };
        Fleet::new(demo_fleet_devices(), opts, staleness, 64)
    }

    #[test]
    fn trace_is_deterministic_per_seed() {
        let mix = ShapeMix::skewed_default();
        assert_eq!(gen_trace(7, 40, &mix), gen_trace(7, 40, &mix));
        assert_ne!(gen_trace(7, 40, &mix), gen_trace(8, 40, &mix));
    }

    #[test]
    fn skewed_mix_weights_respected() {
        let mix = ShapeMix::skewed_default();
        let trace = gen_trace(3, 2000, &mix);
        let small = trace
            .iter()
            .filter(|s| **s == GemmShape::new(480, 512, 512))
            .count() as f64
            / 2000.0;
        assert!((small - 0.45).abs() < 0.05, "P(small) = {small}");
    }

    #[test]
    fn fleet_placement_beats_round_robin_on_heterogeneous_fleet() {
        let fleet = quick_fleet();
        let mix = ShapeMix::skewed_default();
        warm(&fleet, &mix.shapes());
        let trace = gen_trace(42, 80, &mix);
        let rr = run_trace(&fleet, &trace, PlacementPolicy::RoundRobin, false);
        let b2t = run_trace(&fleet, &trace, PlacementPolicy::Block2Time, false);
        assert_eq!(rr.requests, b2t.requests);
        assert!(
            b2t.makespan_s < rr.makespan_s * 0.95,
            "fleet {} vs rr {}",
            b2t.makespan_s,
            rr.makespan_s
        );
        // every device participated under both policies
        assert!(b2t.device_requests.iter().all(|&c| c > 0));
        assert!(rr.device_requests.iter().all(|&c| c > 0));
        // residual accounting: Block2Time predicted every placement, so
        // every bucket in the mix has finite stats; round-robin made no
        // predictions and must report none
        assert!(!b2t.residuals.is_empty());
        assert!(b2t
            .residuals
            .iter()
            .all(|r| r.count > 0 && r.ewma_bias.is_finite() && r.p95_ape.is_finite()));
        assert!(rr.residuals.is_empty());
    }

    #[test]
    fn feedback_tightens_drift_over_the_run() {
        let fleet = quick_fleet();
        let mix = ShapeMix::skewed_default();
        warm(&fleet, &mix.shapes());
        let trace = gen_trace(7, 120, &mix);
        let report =
            run_trace(&fleet, &trace, PlacementPolicy::Block2Time, true);
        let best = report
            .drift
            .iter()
            .filter(|s| s.drifts.len() >= 3)
            .max_by(|a, b| a.drifts[0].total_cmp(&b.drifts[0]))
            .expect("at least one repeated (device, bucket) series");
        let (first, last) =
            (best.drifts[0], *best.drifts.last().unwrap());
        assert!(
            last < first,
            "feedback must tighten drift: {first} -> {last} ({best:?})"
        );
    }

    #[test]
    fn open_trace_is_deterministic_and_time_ordered() {
        let mix = ShapeMix::skewed_default();
        let a = gen_open_trace(7, 50, &mix, Arrival::Poisson { rate: 100.0 });
        let b = gen_open_trace(7, 50, &mix, Arrival::Poisson { rate: 100.0 });
        assert_eq!(a, b);
        assert_ne!(
            a,
            gen_open_trace(8, 50, &mix, Arrival::Poisson { rate: 100.0 })
        );
        for w in a.windows(2) {
            assert!(w[1].0 >= w[0].0, "arrivals must be non-decreasing");
        }
    }

    #[test]
    fn trickle_arrivals_have_no_queueing_delay() {
        let fleet = quick_fleet();
        let mix = ShapeMix::skewed_default();
        warm(&fleet, &mix.shapes());
        // One request per simulated minute: every device idles between
        // arrivals, so queueing delay must vanish and the makespan is
        // paced by the arrival process, not the fleet.
        let trace =
            gen_open_trace(5, 12, &mix, Arrival::Poisson { rate: 1.0 / 60.0 });
        let r =
            run_trace_open(&fleet, &trace, PlacementPolicy::Block2Time, false);
        assert_eq!(r.requests, 12);
        assert!(
            r.queue_delay_p95_s < 1e-9,
            "idle fleet must not queue: p95 {}",
            r.queue_delay_p95_s
        );
        assert!(r.makespan_s >= trace.last().unwrap().0);
    }

    #[test]
    fn open_loop_surfaces_queueing_that_placement_reduces() {
        let fleet = quick_fleet();
        let mix = ShapeMix::skewed_default();
        warm(&fleet, &mix.shapes());
        // Offered load at 2× what round-robin sustains on this skewed
        // fleet: rr's queues grow throughout the run, while
        // completion-time placement drains strictly faster.
        let closed = run_trace(
            &fleet,
            &gen_trace(42, 60, &mix),
            PlacementPolicy::RoundRobin,
            false,
        );
        let rate = 2.0 * 60.0 / closed.makespan_s;
        let trace = gen_open_trace(9, 120, &mix, Arrival::Poisson { rate });
        let rr =
            run_trace_open(&fleet, &trace, PlacementPolicy::RoundRobin, false);
        let b2t =
            run_trace_open(&fleet, &trace, PlacementPolicy::Block2Time, false);
        assert_eq!(rr.requests, b2t.requests);
        assert!(
            b2t.makespan_s < rr.makespan_s,
            "placement must shorten the open-loop makespan: {} vs {}",
            b2t.makespan_s,
            rr.makespan_s
        );
        assert!(
            b2t.queue_delay_mean_s < rr.queue_delay_mean_s,
            "placement must cut queueing: {} vs {}",
            b2t.queue_delay_mean_s,
            rr.queue_delay_mean_s
        );
        // round-robin at this rate visibly queues — the delay the
        // closed-loop report could never show
        assert!(rr.queue_delay_p95_s > 0.0);
    }

    #[test]
    fn admission_bound_sheds_overload_and_caps_queueing() {
        let fleet = quick_fleet();
        let mix = ShapeMix::skewed_default();
        warm(&fleet, &mix.shapes());
        // Same overload construction as the queueing test: 2x what
        // round-robin sustains.
        let closed = run_trace(
            &fleet,
            &gen_trace(42, 60, &mix),
            PlacementPolicy::RoundRobin,
            false,
        );
        let rate = 2.0 * 60.0 / closed.makespan_s;
        let trace = gen_open_trace(9, 120, &mix, Arrival::Poisson { rate });
        let unbounded = run_trace_open_bounded(
            &fleet,
            &trace,
            PlacementPolicy::RoundRobin,
            false,
            0,
        );
        let bounded = run_trace_open_bounded(
            &fleet,
            &trace,
            PlacementPolicy::RoundRobin,
            false,
            2,
        );
        assert_eq!(unbounded.shed, 0, "max_queue 0 admits everything");
        assert!(bounded.shed > 0, "overload against depth 2 must shed");
        assert!(
            bounded.shed_rate() > 0.0 && bounded.shed_rate() < 1.0,
            "rate {}",
            bounded.shed_rate()
        );
        assert_eq!(
            (bounded.shed
                + bounded.dropped
                + bounded.device_requests.iter().sum::<u64>())
                as usize,
            trace.len(),
            "every request is served, shed, or dropped"
        );
        assert_eq!(bounded.dropped, 0, "mix shapes all build");
        // shedding is what bounds the tail: admitted requests wait at
        // most (depth-1) service times instead of the unbounded backlog
        assert!(
            bounded.queue_delay_p95_s < unbounded.queue_delay_p95_s,
            "bounded p95 {} vs unbounded {}",
            bounded.queue_delay_p95_s,
            unbounded.queue_delay_p95_s
        );
    }

    #[test]
    fn adaptive_bound_tightens_under_sustained_overload() {
        let fleet = quick_fleet();
        let mix = ShapeMix::skewed_default();
        warm(&fleet, &mix.shapes());
        // Same overload construction as the shedding test: 2x what
        // round-robin sustains, long enough for several adapt windows.
        let closed = run_trace(
            &fleet,
            &gen_trace(42, 60, &mix),
            PlacementPolicy::RoundRobin,
            false,
        );
        let rate = 2.0 * 60.0 / closed.makespan_s;
        let trace = gen_open_trace(9, 200, &mix, Arrival::Poisson { rate });
        let (report, bound) = run_trace_open_adaptive(
            &fleet,
            &trace,
            PlacementPolicy::RoundRobin,
            false,
            8,
            0.01, // any real shedding breaches and tightens
        );
        assert!(report.shed > 0, "overload must shed");
        assert!(
            bound < 8,
            "sustained shed breach must tighten the bound: {bound}"
        );
        assert!(bound >= 1, "the bound never collapses to zero");
        assert_eq!(
            (report.shed
                + report.dropped
                + report.device_requests.iter().sum::<u64>())
                as usize,
            trace.len(),
            "every request is served, shed, or dropped"
        );
        // an infinite ceiling is exactly the fixed bound
        let (fixed, same) = run_trace_open_adaptive(
            &fleet,
            &trace,
            PlacementPolicy::RoundRobin,
            false,
            8,
            f64::INFINITY,
        );
        assert_eq!(same, 8);
        assert_eq!(
            fixed.shed,
            run_trace_open_bounded(
                &fleet,
                &trace,
                PlacementPolicy::RoundRobin,
                false,
                8,
            )
            .shed
        );
        // unbounded runs have nothing to tighten even at ceiling 0
        let (unbounded, still_zero) = run_trace_open_adaptive(
            &fleet,
            &trace,
            PlacementPolicy::RoundRobin,
            false,
            0,
            0.0,
        );
        assert_eq!(still_zero, 0);
        assert_eq!(unbounded.shed, 0);
    }

    #[test]
    fn trickle_arrivals_shed_nothing_even_when_bounded() {
        let fleet = quick_fleet();
        let mix = ShapeMix::skewed_default();
        warm(&fleet, &mix.shapes());
        let trace =
            gen_open_trace(5, 12, &mix, Arrival::Poisson { rate: 1.0 / 60.0 });
        let r = run_trace_open_bounded(
            &fleet,
            &trace,
            PlacementPolicy::Block2Time,
            false,
            1,
        );
        assert_eq!(r.shed, 0, "idle fleet must admit every trickle request");
        assert_eq!(r.shed_rate(), 0.0);
        assert_eq!(r.requests, 12);
    }

    #[test]
    fn scheduler_state_drains_between_runs() {
        let fleet = quick_fleet();
        let mix = ShapeMix::skewed_default();
        let trace = gen_trace(1, 30, &mix);
        run_trace(&fleet, &trace, PlacementPolicy::Block2Time, false);
        for d in fleet.devices() {
            assert_eq!(d.queue_depth(), 0);
            assert_eq!(d.in_flight_s(), 0.0);
        }
    }
}
