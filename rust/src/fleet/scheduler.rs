//! Cost-aware placement: which device should run this request?
//!
//! Completion estimate per device = predicted work already in flight
//! there + the Block2Time-predicted execution time of this request on
//! that device. The execution prediction comes, in order, from:
//!
//! 1. the device's tuner cache (offline-tuned, *refined online* by the
//!    feedback loop — the freshest signal);
//! 2. a plan-backed simulated prior: the default one-config-per-precision
//!    kernel's cached [`crate::plan::Plan`] priced on this device — a
//!    cold device competes with a quantization-aware estimate, and the
//!    shared plan cache means a repeated shape never re-runs
//!    decomposition (first touch builds, every later placement replays);
//! 3. an analytic roofline (`max(flops/peak, bytes/bw) + launch
//!    overhead`) — defense in depth only: for every non-degenerate
//!    shape on a sanely constructed [`Device`] the plan prior exists
//!    and is finite, so this tier is reached only if a hand-built
//!    device carries pathological parameters (e.g. zero/∞ bandwidth)
//!    that poison the simulated estimate;
//! 4. nothing — when the shape is degenerate, placement falls back to
//!    least-loaded by queue depth.
//!
//! Poisoned numbers never propagate: a NaN/∞ cached prediction is
//! skipped in favor of the prior, a non-finite score disqualifies the
//! candidate, and non-finite in-flight accounting self-heals to zero.

use super::registry::Fleet;
use crate::decomp::GemmShape;
use crate::gpu_sim::Device;

/// One placement decision. Hand it back to [`Fleet::complete`] when the
/// request finishes so the in-flight accounting drains.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Placement {
    pub device: usize,
    /// The execution-time prediction the decision was based on
    /// (`None` on the least-loaded fallback path).
    pub predicted_s: Option<f64>,
    /// True when no device had a usable prediction and the scheduler
    /// fell back to least-loaded.
    pub fallback: bool,
}

/// Roofline prior: the two-resource bound the simulator itself obeys.
fn roofline(dev: &Device, shape: GemmShape, bpe: usize) -> Option<f64> {
    if shape.is_degenerate() {
        return None;
    }
    let flops = shape.flops() as f64;
    let bytes =
        ((shape.m * shape.k + shape.k * shape.n + shape.m * shape.n) * bpe)
            as f64;
    let t = (flops / dev.peak_flops()).max(bytes / dev.hbm_bw)
        + dev.launch_overhead;
    (t.is_finite() && t > 0.0).then_some(t)
}

impl Fleet {
    /// Block2Time-predicted execution seconds of `shape` on device
    /// `idx`: cached (online-refined) prediction when present and
    /// finite, then the plan-backed simulated prior, then the analytic
    /// roofline, `None` when nothing is usable.
    pub fn predict_exec(&self, idx: usize, shape: GemmShape) -> Option<f64> {
        if shape.is_degenerate() {
            return None;
        }
        let d = self.device(idx);
        // peek, not lookup: pricing a shape on every device must not
        // mark entries as "in use" on devices that never serve it
        // (that would defeat the age-out half of the staleness policy)
        if let Some(cfg) = d.tuner.peek(shape) {
            if cfg.predicted_s.is_finite() && cfg.predicted_s > 0.0 {
                return Some(cfg.predicted_s);
            }
            // poisoned entry: quarantine, fall through to the prior
        }
        // Plan-backed prior: the default kernel's flattened schedule,
        // memoized process-wide — untuned buckets are priced by the
        // same model the simulator measures with, and the hot path
        // never rebuilds a schedule for a shape it has seen.
        let dev = d.device();
        if let Ok(plan) = crate::plan::global().get_or_build_w(
            shape,
            crate::decomp::BlockShape::default(),
            self.width(),
            dev.num_cus,
        ) {
            let t = plan.time_on(dev);
            if t.is_finite() && t > 0.0 {
                return Some(t);
            }
        }
        // Defensive only — see tier 3 in the module docs: unreachable
        // unless a hand-built Device's parameters poison the plan time.
        roofline(dev, shape, self.bytes_per_elem())
    }

    /// Place one GEMM: lowest predicted completion time, least-loaded
    /// fallback. Always returns a valid device index; never panics on
    /// poisoned predictions.
    pub fn place_gemm(&self, shape: GemmShape) -> Placement {
        let _s =
            crate::trace::span1("fleet.place", "devices", self.len() as u64);
        let mut best: Option<(f64, usize, f64)> = None; // (score, idx, pred)
        for idx in 0..self.len() {
            if !self.device(idx).is_active() {
                continue; // churned-out member: never place there
            }
            let Some(pred) = self.predict_exec(idx, shape) else {
                continue;
            };
            let score = self.device(idx).in_flight_s() + pred;
            if !score.is_finite() {
                continue;
            }
            let better = match &best {
                Some((s, _, _)) => score < *s,
                None => true,
            };
            if better {
                best = Some((score, idx, pred));
            }
        }
        let placement = match best {
            Some((_, idx, pred)) => {
                Placement { device: idx, predicted_s: Some(pred), fallback: false }
            }
            None => Placement {
                device: self.least_loaded(),
                predicted_s: None,
                fallback: true,
            },
        };
        let mut q = self
            .device(placement.device)
            .queue
            .lock()
            .expect("fleet queue");
        q.depth += 1;
        if let Some(pred) = placement.predicted_s {
            q.in_flight_s += pred;
        }
        if !q.in_flight_s.is_finite() {
            q.in_flight_s = 0.0; // self-heal poisoned accounting
        }
        placement
    }

    /// Drain one placement's contribution to the queue accounting.
    pub fn complete(&self, placement: &Placement) {
        let mut q = self
            .device(placement.device)
            .queue
            .lock()
            .expect("fleet queue");
        q.depth = q.depth.saturating_sub(1);
        if let Some(pred) = placement.predicted_s {
            q.in_flight_s -= pred;
        }
        if !(q.in_flight_s.is_finite() && q.in_flight_s > 0.0) {
            q.in_flight_s = 0.0;
        }
        if q.depth == 0 {
            // no outstanding work: cancel accumulated rounding residue
            q.in_flight_s = 0.0;
        }
    }

    /// The least-loaded *active* device: fewest outstanding requests,
    /// ties by predicted in-flight seconds (non-finite treated as
    /// saturated), then by index for determinism. Falls back to
    /// device 0 only in the pathological all-inactive fleet (placement
    /// must return *some* index; the caller sees every device refusing
    /// work through its own queue accounting).
    fn least_loaded(&self) -> usize {
        let mut best = 0usize;
        let mut best_key = (usize::MAX, f64::INFINITY);
        for (idx, d) in self.devices().iter().enumerate() {
            if !d.is_active() {
                continue;
            }
            let q = d.queue.lock().expect("fleet queue");
            let inflight =
                if q.in_flight_s.is_finite() { q.in_flight_s } else { f64::INFINITY };
            let key = (q.depth, inflight);
            if key.0 < best_key.0
                || (key.0 == best_key.0 && key.1 < best_key.1)
            {
                best_key = key;
                best = idx;
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fleet::registry::Fleet;
    use crate::gpu_sim::Device;
    use crate::prop;
    use crate::tuner::TuneOptions;

    /// Two MI200-class devices with a generous HBM (1000× nominal) so
    /// the plan-backed prior stays *compute*-bound: the 2×-work
    /// property below is a statement about compute scaling, and the
    /// simulated prior — unlike the old whole-problem roofline —
    /// correctly charges Stream-K's per-iteration block re-streaming,
    /// which would make a stock MI200 bandwidth-bound here.
    fn two_device_fleet(speed_ratio: f64) -> Fleet {
        Fleet::from_devices(
            vec![
                Device::uniform(
                    "fast",
                    120,
                    speed_ratio * 45.0e12 / 120.0,
                    1.6e15,
                    6.0e-6,
                ),
                Device::uniform("base", 120, 45.0e12 / 120.0, 1.6e15, 6.0e-6),
            ],
            TuneOptions::default(),
        )
    }

    #[test]
    fn twice_as_fast_device_gets_about_twice_the_work() {
        // Property: under uniform traffic of a compute-bound shape, a
        // 2× device should end up with ~2× the placements — the greedy
        // completion-time rule equalizes predicted finish times.
        prop::check("2x device gets ~2x work", 10, |rng| {
            let fleet = two_device_fleet(2.0);
            let m = rng.usize_in(1500, 2500);
            let shape = GemmShape::new(m, 2048, 2048);
            let mut counts = [0usize; 2];
            let mut placements = Vec::new();
            for _ in 0..300 {
                let p = fleet.place_gemm(shape);
                counts[p.device] += 1;
                placements.push(p);
            }
            for p in &placements {
                fleet.complete(p);
            }
            let ratio = counts[0] as f64 / counts[1].max(1) as f64;
            prop::ensure(
                (1.6..=2.4).contains(&ratio),
                format!("placement ratio {ratio} ({counts:?})"),
            )
        });
    }

    #[test]
    fn equal_devices_split_evenly() {
        let fleet = two_device_fleet(1.0);
        let shape = GemmShape::new(1024, 1024, 1024);
        let mut counts = [0usize; 2];
        for _ in 0..100 {
            counts[fleet.place_gemm(shape).device] += 1;
        }
        assert!(
            counts[0].abs_diff(counts[1]) <= 2,
            "near-even split expected: {counts:?}"
        );
    }

    #[test]
    fn poisoned_cached_prediction_never_crashes_or_starves_placement() {
        let fleet = two_device_fleet(1.0);
        let shape = GemmShape::new(512, 512, 512);
        // Poison device 0's cache entry for this bucket with NaN / ∞.
        let report = fleet.device(0).tuner.tune_and_insert(shape).unwrap();
        for poison in [f64::NAN, f64::INFINITY, -1.0] {
            let mut bad = report.best;
            bad.predicted_s = poison;
            fleet.device(0).tuner.insert_config(shape, bad);
            let mut counts = [0usize; 2];
            let mut placements = Vec::new();
            for _ in 0..50 {
                let p = fleet.place_gemm(shape);
                assert!(p.device < fleet.len());
                counts[p.device] += 1;
                placements.push(p);
            }
            for p in &placements {
                fleet.complete(p);
            }
            // the poisoned device falls back to the plan-backed prior
            // and still takes a fair share — no blackhole, no starvation
            assert!(counts[0] > 5 && counts[1] > 5, "{poison}: {counts:?}");
        }
    }

    #[test]
    fn degenerate_shape_falls_back_to_least_loaded() {
        let fleet = two_device_fleet(1.0);
        // load device 0 with one outstanding request
        let busy = fleet.place_gemm(GemmShape::new(1024, 1024, 1024));
        assert_eq!(busy.device, 0, "first placement is deterministic");
        let p = fleet.place_gemm(GemmShape::new(0, 4, 4));
        assert!(p.fallback);
        assert_eq!(p.predicted_s, None);
        assert_eq!(p.device, 1, "least-loaded device takes the fallback");
        fleet.complete(&busy);
        fleet.complete(&p);
        assert_eq!(fleet.device(0).queue_depth(), 0);
        assert!(fleet.device(0).in_flight_s() == 0.0);
    }

    #[test]
    fn cached_prediction_beats_the_prior_when_present() {
        let fleet = two_device_fleet(1.0);
        let shape = GemmShape::new(1920, 2000, 2000);
        fleet.device(0).tuner.tune_and_insert(shape).unwrap();
        let cached = fleet.predict_exec(0, shape).unwrap();
        let prior = fleet.predict_exec(1, shape).unwrap();
        let exact =
            fleet.device(0).tuner.lookup(shape).unwrap().predicted_s;
        assert_eq!(cached, exact, "cache entry must drive the estimate");
        assert!(prior > 0.0 && prior.is_finite());
    }

    #[test]
    fn inactive_devices_never_receive_placements() {
        let fleet = two_device_fleet(1.0);
        let shape = GemmShape::new(1024, 1024, 1024);
        fleet.set_active(0, false);
        let mut placements = Vec::new();
        for _ in 0..20 {
            let p = fleet.place_gemm(shape);
            assert_eq!(p.device, 1, "only the active device may serve");
            placements.push(p);
        }
        // the degenerate-shape fallback also respects the flag
        let p = fleet.place_gemm(GemmShape::new(0, 4, 4));
        assert!(p.fallback);
        assert_eq!(p.device, 1);
        placements.push(p);
        for p in &placements {
            fleet.complete(p);
        }
        // rejoin: both serve again
        fleet.set_active(0, true);
        let mut counts = [0usize; 2];
        for _ in 0..40 {
            counts[fleet.place_gemm(shape).device] += 1;
        }
        assert!(counts[0] > 0 && counts[1] > 0, "{counts:?}");
    }

    #[test]
    fn completion_drains_accounting() {
        let fleet = two_device_fleet(1.0);
        let shape = GemmShape::new(1024, 1024, 1024);
        let ps: Vec<Placement> =
            (0..10).map(|_| fleet.place_gemm(shape)).collect();
        let depth: usize =
            (0..2).map(|i| fleet.device(i).queue_depth()).sum();
        assert_eq!(depth, 10);
        for p in &ps {
            fleet.complete(p);
        }
        for i in 0..2 {
            assert_eq!(fleet.device(i).queue_depth(), 0);
            assert_eq!(fleet.device(i).in_flight_s(), 0.0);
        }
    }
}
