//! The online re-tuning feedback loop — measured serving latencies
//! flow back into the owning device's tuner cache.
//!
//! This is the full Block2Time loop the ROADMAP asked for: offline
//! `tune` seeds the per-device predictions, the scheduler spends them,
//! and every *measured* completion refines them ([`Tuner::observe`]
//! blends the cached prediction toward reality). The staleness policy
//! rides along: entries whose measurements drift past the policy come
//! back as [`Observation::Drifted`] so the caller can schedule a full
//! re-tune, and entries nothing touches age out on the next sweep.

use super::registry::Fleet;
use crate::decomp::GemmShape;
use crate::tuner::{Observation, SweepReport};

impl Fleet {
    /// Fold one measured request latency for `shape` into device
    /// `idx`'s cache. Non-finite measurements are rejected inside
    /// [`crate::tuner::Tuner::observe`]; a [`Observation::Drifted`]
    /// return is the caller's cue to re-tune that bucket on that
    /// device (the coordinator enqueues a background re-tune, the
    /// simulator re-tunes inline).
    pub fn observe(
        &self,
        idx: usize,
        shape: GemmShape,
        measured_s: f64,
    ) -> Observation {
        self.device(idx).tuner.observe(shape, measured_s)
    }

    /// [`Fleet::observe`] driven by the *measured* Block2Time residual:
    /// alongside folding `measured_s` into the cache, compare it against
    /// the prediction the scheduler actually placed with
    /// (`predicted_s`, which may come from the plan-backed prior when
    /// the bucket is untuned). A cold bucket whose prior is off by more
    /// than the drift policy now reports [`Observation::Drifted`] too —
    /// previously such requests came back [`Observation::NoEntry`] and
    /// the mis-prediction persisted until a cache entry existed.
    pub fn observe_residual(
        &self,
        idx: usize,
        shape: GemmShape,
        predicted_s: Option<f64>,
        measured_s: f64,
    ) -> Observation {
        let obs = self.observe(idx, shape, measured_s);
        if let (Observation::NoEntry, Some(pred)) = (&obs, predicted_s) {
            if measured_s.is_finite()
                && measured_s > 0.0
                && pred.is_finite()
                && pred > 0.0
            {
                let drift = (pred - measured_s).abs() / measured_s;
                let policy = self.device(idx).tuner.staleness();
                if drift.is_finite() && drift > policy.max_drift {
                    return Observation::Drifted { drift };
                }
            }
        }
        obs
    }

    /// Apply the staleness policy (age-out + drift flags) to every
    /// device's cache; one report per device, in registry order.
    pub fn sweep_stale(&self) -> Vec<SweepReport> {
        self.devices().iter().map(|d| d.tuner.sweep_stale()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu_sim::{Device, DeviceKind};
    use crate::tuner::TuneOptions;

    fn fleet() -> Fleet {
        Fleet::from_devices(
            vec![
                Device::preset(DeviceKind::Mi200),
                Device::preset(DeviceKind::Mi100),
            ],
            TuneOptions::default(),
        )
    }

    #[test]
    fn observation_lands_in_the_owning_device_only() {
        let f = fleet();
        let shape = GemmShape::new(480, 512, 512);
        f.device(0).tuner.tune_and_insert(shape).unwrap();
        f.device(1).tuner.tune_and_insert(shape).unwrap();
        let before_other = f.device(1).tuner.lookup(shape).unwrap();

        let real = f.device(0).tuner.lookup(shape).unwrap().predicted_s * 1.3;
        assert!(matches!(
            f.observe(0, shape, real),
            Observation::Updated { .. }
        ));
        let owner = f.device(0).tuner.lookup(shape).unwrap();
        assert_eq!(owner.observed_n, 1);
        let other = f.device(1).tuner.lookup(shape).unwrap();
        assert_eq!(other.observed_n, 0);
        assert_eq!(other.predicted_s, before_other.predicted_s);
    }

    #[test]
    fn observe_without_entry_is_a_no_op() {
        let f = fleet();
        assert_eq!(
            f.observe(1, GemmShape::new(480, 512, 512), 1e-3),
            Observation::NoEntry
        );
    }

    #[test]
    fn measured_residual_drives_drift_even_without_a_cache_entry() {
        let f = fleet();
        let shape = GemmShape::new(480, 512, 512);
        // Cold bucket + a scheduler prediction 10× off the measurement:
        // the residual path must flag drift where plain observe cannot.
        let measured = 1e-3;
        let obs = f.observe_residual(0, shape, Some(10.0 * measured), measured);
        assert!(
            matches!(obs, Observation::Drifted { drift } if drift > 5.0),
            "10x residual on a cold bucket must report Drifted, got {obs:?}"
        );
        // A prediction within policy stays NoEntry (nothing to re-tune
        // beyond the miss-tune already queued by the serving path).
        let obs = f.observe_residual(0, shape, Some(1.1 * measured), measured);
        assert_eq!(obs, Observation::NoEntry);
        // No prediction at all (fallback placement) degrades to observe.
        let obs = f.observe_residual(0, shape, None, measured);
        assert_eq!(obs, Observation::NoEntry);
        // With a live entry the tuner's own drift logic owns the verdict.
        f.device(0).tuner.tune_and_insert(shape).unwrap();
        let pred = f.device(0).tuner.peek(shape).unwrap().predicted_s;
        assert!(matches!(
            f.observe_residual(0, shape, Some(pred), pred),
            Observation::Updated { .. }
        ));
    }

    #[test]
    fn sweep_reports_per_device() {
        let f = fleet();
        f.device(0)
            .tuner
            .tune_and_insert(GemmShape::new(480, 512, 512))
            .unwrap();
        let reports = f.sweep_stale();
        assert_eq!(reports.len(), 2);
        assert_eq!(reports[0].fresh, 1);
        assert_eq!(reports[1].fresh, 0);
    }
}
