//! The device registry: who is in the fleet, and each member's
//! per-device tuner cache.
//!
//! Every registered device gets its own [`Tuner`] (and therefore its
//! own [`crate::tuner::DeviceFingerprint`]-keyed cache slice): a config
//! tuned for the 120-CU MI200 must never steer the binned 60-CU MI100,
//! which is exactly the multi-device gap the PR-1 ROADMAP named. All
//! per-device caches persist into *one* file — entries carry the
//! fingerprint in their key, so a merged file warm-loads correctly on
//! any fleet member.

use crate::gpu_sim::{Device, DeviceKind};
use crate::tuner::{
    CacheError, StalenessPolicy, TuneOptions, Tuner, TuningCache,
};
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Scheduler bookkeeping for one device (see `scheduler.rs`).
#[derive(Debug, Default)]
pub(super) struct QueueState {
    /// Predicted seconds of placed-but-not-completed work.
    pub in_flight_s: f64,
    /// Placed-but-not-completed request count (the least-loaded
    /// fallback's load signal — robust even when predictions are
    /// unavailable or poisoned).
    pub depth: usize,
}

/// One fleet member: a simulated device plus its private tuner cache
/// and scheduler queue state.
pub struct FleetDevice {
    pub id: usize,
    /// Display name (`mi200#0`); the cache key uses the fingerprint,
    /// not this.
    pub name: String,
    pub tuner: Arc<Tuner>,
    pub(super) queue: Mutex<QueueState>,
}

impl FleetDevice {
    pub fn device(&self) -> &Device {
        self.tuner.device()
    }

    /// Predicted seconds of work currently placed on this device.
    pub fn in_flight_s(&self) -> f64 {
        self.queue.lock().expect("fleet queue").in_flight_s
    }

    /// Requests currently placed on this device.
    pub fn queue_depth(&self) -> usize {
        self.queue.lock().expect("fleet queue").depth
    }
}

/// The fleet: device registry + (via `scheduler`/`feedback` impls)
/// placement and the online re-tuning loop.
pub struct Fleet {
    devices: Vec<FleetDevice>,
    bytes_per_elem: usize,
}

impl Fleet {
    /// Register `devices` as the fleet, each with its own tuner cache
    /// of `cache_capacity` entries under the given staleness policy.
    pub fn new(
        devices: Vec<Device>,
        opts: TuneOptions,
        staleness: StalenessPolicy,
        cache_capacity: usize,
    ) -> Self {
        assert!(!devices.is_empty(), "a fleet needs at least one device");
        let devices = devices
            .into_iter()
            .enumerate()
            .map(|(id, dev)| {
                let name = format!("{}#{id}", dev.name);
                FleetDevice {
                    id,
                    name,
                    tuner: Arc::new(
                        Tuner::new(dev, opts, cache_capacity)
                            .with_staleness(staleness),
                    ),
                    queue: Mutex::new(QueueState::default()),
                }
            })
            .collect();
        Self { devices, bytes_per_elem: opts.bytes_per_elem }
    }

    /// Convenience constructor with the default staleness policy.
    pub fn from_devices(devices: Vec<Device>, opts: TuneOptions) -> Self {
        Self::new(devices, opts, StalenessPolicy::default(), 256)
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn device(&self, idx: usize) -> &FleetDevice {
        &self.devices[idx]
    }

    pub fn devices(&self) -> &[FleetDevice] {
        &self.devices
    }

    pub fn bytes_per_elem(&self) -> usize {
        self.bytes_per_elem
    }

    /// Warm every device's cache from one merged file. Each tuner loads
    /// the full file and serves only the entries matching its own
    /// fingerprint. Returns (usable entries across the fleet, total
    /// entries in the file).
    pub fn load_cache(&self, path: &Path) -> Result<(usize, usize), CacheError> {
        let mut usable = 0;
        let mut total = 0;
        for d in &self.devices {
            total = d.tuner.load_cache(path)?;
            usable += d.tuner.matching_entries();
        }
        Ok((usable, total))
    }

    /// Persist every device's cache into one merged file. Devices that
    /// share a fingerprint (identical hardware) share entries; the
    /// lower-id device's copy wins, which is fine — same hardware,
    /// interchangeable configs.
    pub fn store_cache(&self, path: &Path) -> Result<(), CacheError> {
        let capacity = self
            .devices
            .iter()
            .map(|d| d.tuner.len())
            .sum::<usize>()
            .max(1);
        let mut merged = TuningCache::new(capacity);
        for d in &self.devices {
            merged.absorb(&d.tuner.cache_snapshot());
        }
        merged.store(path)
    }
}

/// The 4-device heterogeneous demo fleet used by `streamk fleet` and
/// the `fleet_throughput` bench: a full MI200, a power-binned MI200 at
/// half throughput, a full MI100, and a 60-CU MI100 — four distinct
/// fingerprints spanning a ~4× speed range.
pub fn demo_fleet_devices() -> Vec<Device> {
    vec![
        Device::preset(DeviceKind::Mi200),
        Device::preset(DeviceKind::Mi200)
            .with_flops_scale(0.5)
            .renamed("mi200b"),
        Device::preset(DeviceKind::Mi100),
        Device::preset(DeviceKind::Mi100).with_cus(60).renamed("mi100h"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::GemmShape;
    use crate::tuner::DeviceFingerprint;

    fn fleet() -> Fleet {
        Fleet::from_devices(demo_fleet_devices(), TuneOptions::default())
    }

    #[test]
    fn demo_fleet_has_distinct_fingerprints() {
        let f = fleet();
        assert_eq!(f.len(), 4);
        let mut prints: Vec<String> = f
            .devices()
            .iter()
            .map(|d| DeviceFingerprint::of(d.device()).as_str().to_string())
            .collect();
        prints.sort();
        prints.dedup();
        assert_eq!(prints.len(), 4, "fingerprints must be distinct");
    }

    #[test]
    fn per_device_caches_are_isolated() {
        let f = fleet();
        let shape = GemmShape::new(480, 512, 512);
        f.device(0).tuner.tune_and_insert(shape).unwrap();
        assert!(f.device(0).tuner.lookup(shape).is_some());
        for idx in 1..f.len() {
            assert!(
                f.device(idx).tuner.lookup(shape).is_none(),
                "device {idx} must not see device 0's entries"
            );
        }
    }

    #[test]
    fn merged_cache_round_trips_across_the_fleet() {
        let f = fleet();
        let shape = GemmShape::new(480, 512, 512);
        // two devices tune the same bucket: entries differ per device
        f.device(0).tuner.tune_and_insert(shape).unwrap();
        f.device(2).tuner.tune_and_insert(shape).unwrap();
        let path = std::env::temp_dir().join(format!(
            "streamk-fleet-cache-{}.json",
            std::process::id()
        ));
        f.store_cache(&path).unwrap();

        let fresh = fleet();
        let (usable, total) = fresh.load_cache(&path).unwrap();
        assert_eq!(total, 2);
        assert_eq!(usable, 2);
        assert!(fresh.device(0).tuner.lookup(shape).is_some());
        assert!(fresh.device(1).tuner.lookup(shape).is_none());
        assert!(fresh.device(2).tuner.lookup(shape).is_some());
        std::fs::remove_file(&path).unwrap();
    }
}
