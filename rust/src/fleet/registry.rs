//! The device registry: who is in the fleet, and each member's
//! per-device tuner cache.
//!
//! Every registered device gets its own [`Tuner`] (and therefore its
//! own [`crate::tuner::DeviceFingerprint`]-keyed cache slice): a config
//! tuned for the 120-CU MI200 must never steer the binned 60-CU MI100,
//! which is exactly the multi-device gap the PR-1 ROADMAP named. All
//! per-device caches persist into *one* file — entries carry the
//! fingerprint in their key, so a merged file warm-loads correctly on
//! any fleet member.

use crate::gpu_sim::{Device, DeviceKind};
use crate::tuner::{
    cache::split_key, BlendConfig, CacheError, StalenessPolicy, TuneOptions,
    Tuner, TuningCache,
};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// Scheduler bookkeeping for one device (see `scheduler.rs`).
#[derive(Debug, Default)]
pub(super) struct QueueState {
    /// Predicted seconds of placed-but-not-completed work.
    pub in_flight_s: f64,
    /// Placed-but-not-completed request count (the least-loaded
    /// fallback's load signal — robust even when predictions are
    /// unavailable or poisoned).
    pub depth: usize,
}

/// One fleet member: a simulated device plus its private tuner cache
/// and scheduler queue state.
pub struct FleetDevice {
    pub id: usize,
    /// Display name (`mi200#0`); the cache key uses the fingerprint,
    /// not this.
    pub name: String,
    pub tuner: Arc<Tuner>,
    pub(super) queue: Mutex<QueueState>,
    /// Churn flag: inactive devices stay registered (stable indices,
    /// cache retained for a possible rejoin) but the scheduler never
    /// places on them.
    active: AtomicBool,
}

impl FleetDevice {
    pub fn device(&self) -> &Device {
        self.tuner.device()
    }

    /// Is this device currently accepting placements?
    pub fn is_active(&self) -> bool {
        self.active.load(Ordering::Relaxed)
    }

    /// Predicted seconds of work currently placed on this device.
    pub fn in_flight_s(&self) -> f64 {
        self.queue.lock().expect("fleet queue").in_flight_s
    }

    /// Requests currently placed on this device.
    pub fn queue_depth(&self) -> usize {
        self.queue.lock().expect("fleet queue").depth
    }
}

/// The fleet: device registry + (via `scheduler`/`feedback` impls)
/// placement and the online re-tuning loop.
pub struct Fleet {
    devices: Vec<FleetDevice>,
    width: crate::kernel::Width,
    // Construction parameters, retained so devices joining later
    // ([`Fleet::add_device`]) get tuners built exactly like the
    // original members'.
    opts: TuneOptions,
    staleness: StalenessPolicy,
    cache_capacity: usize,
    blend: BlendConfig,
}

impl Fleet {
    /// Register `devices` as the fleet, each with its own tuner cache
    /// of `cache_capacity` entries under the given staleness policy.
    pub fn new(
        devices: Vec<Device>,
        opts: TuneOptions,
        staleness: StalenessPolicy,
        cache_capacity: usize,
    ) -> Self {
        Self::new_with_blend(
            devices,
            opts,
            staleness,
            cache_capacity,
            BlendConfig::from_env(),
        )
    }

    /// [`Fleet::new`] with explicit feedback smoothing constants (the
    /// serve path threads `config::Settings` values through here).
    pub fn new_with_blend(
        devices: Vec<Device>,
        opts: TuneOptions,
        staleness: StalenessPolicy,
        cache_capacity: usize,
        blend: BlendConfig,
    ) -> Self {
        assert!(!devices.is_empty(), "a fleet needs at least one device");
        let mut fleet = Self {
            devices: Vec::new(),
            width: opts.width,
            opts,
            staleness,
            cache_capacity,
            blend,
        };
        for dev in devices {
            fleet.add_device(dev);
        }
        fleet
    }

    /// Convenience constructor with the default staleness policy.
    pub fn from_devices(devices: Vec<Device>, opts: TuneOptions) -> Self {
        Self::new(devices, opts, StalenessPolicy::default(), 256)
    }

    /// A device joins the fleet mid-flight: it is appended (indices of
    /// existing members never move), gets a fresh tuner built with the
    /// same options/staleness/blend as the founding members, and starts
    /// active with a cold cache. Returns its index; see
    /// [`Fleet::transfer_cache`] for warm-seeding the joiner.
    pub fn add_device(&mut self, dev: Device) -> usize {
        let id = self.devices.len();
        let name = format!("{}#{id}", dev.name);
        self.devices.push(FleetDevice {
            id,
            name,
            tuner: Arc::new(
                Tuner::new(dev, self.opts, self.cache_capacity)
                    .with_staleness(self.staleness)
                    .with_blend(self.blend),
            ),
            queue: Mutex::new(QueueState::default()),
            active: AtomicBool::new(true),
        });
        id
    }

    /// Mark a device active/inactive. Leaving is a soft-remove: the
    /// entry (and its tuner cache) stays registered under a stable
    /// index so in-flight bookkeeping and a later rejoin both work;
    /// the scheduler simply stops placing there.
    pub fn set_active(&self, idx: usize, active: bool) {
        self.devices[idx].active.store(active, Ordering::Relaxed);
    }

    pub fn is_active(&self, idx: usize) -> bool {
        self.devices[idx].is_active()
    }

    /// Indices of the currently active members.
    pub fn active_indices(&self) -> Vec<usize> {
        (0..self.len()).filter(|&i| self.is_active(i)).collect()
    }

    /// Number of currently active members.
    pub fn active_len(&self) -> usize {
        self.devices.iter().filter(|d| d.is_active()).count()
    }

    /// Cross-device cache transfer: seed `joiner`'s tuner cache from
    /// the *nearest* existing member — the active device (with a
    /// non-empty cache) whose peak FLOPS is closest in log-ratio —
    /// scaling every donor time by the donor:joiner peak-flops ratio
    /// (time ∝ 1/throughput to first order). Transplanted entries keep
    /// the donor's config but reset the observation EWMA: they are
    /// estimates, and the online loop must re-learn reality on the new
    /// silicon. Grid CU counts are clamped to the joiner's hardware.
    /// Returns the number of entries seeded (0 when no donor exists).
    pub fn transfer_cache(&self, joiner: usize) -> usize {
        let jtuner = &self.device(joiner).tuner;
        let jdev = jtuner.device();
        let jpeak = jdev.peak_flops();
        if !(jpeak.is_finite() && jpeak > 0.0) {
            return 0;
        }
        let mut donor: Option<(f64, usize)> = None;
        for idx in 0..self.len() {
            if idx == joiner || !self.is_active(idx) {
                continue;
            }
            let d = self.device(idx);
            if d.tuner.matching_entries() == 0 {
                continue;
            }
            let peak = d.device().peak_flops();
            if !(peak.is_finite() && peak > 0.0) {
                continue;
            }
            let dist = (peak / jpeak).ln().abs();
            if donor.map_or(true, |(best, _)| dist < best) {
                donor = Some((dist, idx));
            }
        }
        let Some((_, didx)) = donor else {
            return 0;
        };
        let dtuner = &self.device(didx).tuner;
        let dpeak = dtuner.device().peak_flops();
        let scale = dpeak / jpeak; // donor faster → joiner times grow
        let snapshot = dtuner.cache_snapshot();
        let mut seeded = 0;
        for (key, mut cfg) in snapshot.entries_for(dtuner.fingerprint()) {
            let Some((bucket, width, _)) = split_key(&key) else {
                continue;
            };
            if width != self.width {
                continue;
            }
            cfg.predicted_s *= scale;
            cfg.measured_s *= scale;
            cfg.observed_s = 0.0;
            cfg.observed_n = 0;
            cfg.cus = cfg.cus.min(jdev.num_cus).max(1);
            jtuner.insert_config(bucket.representative(), cfg);
            seeded += 1;
        }
        seeded
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn device(&self, idx: usize) -> &FleetDevice {
        &self.devices[idx]
    }

    pub fn devices(&self) -> &[FleetDevice] {
        &self.devices
    }

    pub fn bytes_per_elem(&self) -> usize {
        self.width.bytes()
    }

    /// The element width this fleet tunes and serves at.
    pub fn width(&self) -> crate::kernel::Width {
        self.width
    }

    /// Warm every device's cache from one merged file. Each tuner loads
    /// the full file and serves only the entries matching its own
    /// fingerprint. Returns (usable entries across the fleet, total
    /// entries in the file).
    pub fn load_cache(&self, path: &Path) -> Result<(usize, usize), CacheError> {
        let mut usable = 0;
        let mut total = 0;
        for d in &self.devices {
            total = d.tuner.load_cache(path)?;
            usable += d.tuner.matching_entries();
        }
        Ok((usable, total))
    }

    /// Persist every device's cache into one merged file. Devices that
    /// share a fingerprint (identical hardware) share entries; the
    /// lower-id device's copy wins, which is fine — same hardware,
    /// interchangeable configs.
    pub fn store_cache(&self, path: &Path) -> Result<(), CacheError> {
        let capacity = self
            .devices
            .iter()
            .map(|d| d.tuner.len())
            .sum::<usize>()
            .max(1);
        let mut merged = TuningCache::new(capacity);
        for d in &self.devices {
            merged.absorb(&d.tuner.cache_snapshot());
        }
        merged.store(path)
    }
}

/// The 4-device heterogeneous demo fleet used by `streamk fleet` and
/// the `fleet_throughput` bench: a full MI200, a power-binned MI200 at
/// half throughput, a full MI100, and a 60-CU MI100 — four distinct
/// fingerprints spanning a ~4× speed range.
pub fn demo_fleet_devices() -> Vec<Device> {
    vec![
        Device::preset(DeviceKind::Mi200),
        Device::preset(DeviceKind::Mi200)
            .with_flops_scale(0.5)
            .renamed("mi200b"),
        Device::preset(DeviceKind::Mi100),
        Device::preset(DeviceKind::Mi100).with_cus(60).renamed("mi100h"),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::GemmShape;
    use crate::tuner::DeviceFingerprint;

    fn fleet() -> Fleet {
        Fleet::from_devices(demo_fleet_devices(), TuneOptions::default())
    }

    #[test]
    fn demo_fleet_has_distinct_fingerprints() {
        let f = fleet();
        assert_eq!(f.len(), 4);
        let mut prints: Vec<String> = f
            .devices()
            .iter()
            .map(|d| DeviceFingerprint::of(d.device()).as_str().to_string())
            .collect();
        prints.sort();
        prints.dedup();
        assert_eq!(prints.len(), 4, "fingerprints must be distinct");
    }

    #[test]
    fn per_device_caches_are_isolated() {
        let f = fleet();
        let shape = GemmShape::new(480, 512, 512);
        f.device(0).tuner.tune_and_insert(shape).unwrap();
        assert!(f.device(0).tuner.lookup(shape).is_some());
        for idx in 1..f.len() {
            assert!(
                f.device(idx).tuner.lookup(shape).is_none(),
                "device {idx} must not see device 0's entries"
            );
        }
    }

    #[test]
    fn join_and_leave_preserve_indices_and_flags() {
        let mut f = fleet();
        assert_eq!(f.active_len(), 4);
        assert!(f.devices().iter().all(|d| d.is_active()));

        // leave: soft-remove under a stable index
        f.set_active(1, false);
        assert!(!f.is_active(1));
        assert_eq!(f.active_len(), 3);
        assert_eq!(f.active_indices(), vec![0, 2, 3]);
        assert_eq!(f.len(), 4, "departed devices stay registered");
        assert_eq!(f.device(1).name, "mi200b#1", "index stability");

        // join: appended, active, same tuner parameters
        let idx =
            f.add_device(Device::preset(DeviceKind::Mi200).renamed("late"));
        assert_eq!(idx, 4);
        assert!(f.is_active(idx));
        assert_eq!(f.device(idx).name, "late#4");
        assert_eq!(
            f.device(idx).tuner.options(),
            f.device(0).tuner.options()
        );
        assert_eq!(
            f.device(idx).tuner.staleness(),
            f.device(0).tuner.staleness()
        );
        assert_eq!(f.device(idx).tuner.blend(), f.device(0).tuner.blend());

        // rejoin: the flag flips back, cache intact
        f.set_active(1, true);
        assert_eq!(f.active_len(), 5);
    }

    #[test]
    fn cache_transfer_seeds_joiner_from_nearest_donor_scaled() {
        let mut f = fleet();
        let shape = GemmShape::new(1920, 2000, 2000);
        // two potential donors at different speeds, both tuned
        f.device(0).tuner.tune_and_insert(shape).unwrap(); // mi200 (full)
        f.device(1).tuner.tune_and_insert(shape).unwrap(); // mi200 × 0.5
        let donor_full = f.device(0).tuner.lookup(shape).unwrap();

        // joiner is another full-speed mi200: device 0 is the nearest
        // donor (identical peak), so entries land unscaled
        let idx =
            f.add_device(Device::preset(DeviceKind::Mi200).renamed("twin"));
        let seeded = f.transfer_cache(idx);
        assert_eq!(seeded, 1);
        let got = f.device(idx).tuner.lookup(shape).unwrap();
        assert!((got.predicted_s - donor_full.predicted_s).abs() < 1e-12);
        assert_eq!(got.observed_n, 0, "transplants reset observations");
        assert_eq!(got.observed_s, 0.0);

        // a half-speed joiner picks the half-speed donor; had it picked
        // the full-speed one, the scale would still make times larger.
        let half = Device::preset(DeviceKind::Mi200)
            .with_flops_scale(0.5)
            .renamed("halfling");
        let half_peak = half.peak_flops();
        let hidx = f.add_device(half);
        let seeded = f.transfer_cache(hidx);
        assert_eq!(seeded, 1);
        let donor_half = f.device(1).tuner.lookup(shape).unwrap();
        let got = f.device(hidx).tuner.lookup(shape).unwrap();
        let expect = donor_half.predicted_s
            * (f.device(1).device().peak_flops() / half_peak);
        assert!(
            (got.predicted_s - expect).abs() < expect * 1e-9,
            "scaled transfer: {} vs {expect}",
            got.predicted_s
        );

        // no donors → nothing to seed
        let lonely = Fleet::from_devices(
            vec![Device::preset(DeviceKind::Mi100)],
            TuneOptions::default(),
        );
        assert_eq!(lonely.transfer_cache(0), 0);
    }

    #[test]
    fn merged_cache_round_trips_across_the_fleet() {
        let f = fleet();
        let shape = GemmShape::new(480, 512, 512);
        // two devices tune the same bucket: entries differ per device
        f.device(0).tuner.tune_and_insert(shape).unwrap();
        f.device(2).tuner.tune_and_insert(shape).unwrap();
        let path = std::env::temp_dir().join(format!(
            "streamk-fleet-cache-{}.json",
            std::process::id()
        ));
        f.store_cache(&path).unwrap();

        let fresh = fleet();
        let (usable, total) = fresh.load_cache(&path).unwrap();
        assert_eq!(total, 2);
        assert_eq!(usable, 2);
        assert!(fresh.device(0).tuner.lookup(shape).is_some());
        assert!(fresh.device(1).tuner.lookup(shape).is_none());
        assert!(fresh.device(2).tuner.lookup(shape).is_some());
        std::fs::remove_file(&path).unwrap();
    }
}
