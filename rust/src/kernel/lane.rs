//! Explicit SIMD lane backends for the microkernel's `MR × NR`
//! register block.
//!
//! The PR-4 microkernel was scalar Rust the compiler auto-vectorized
//! against the x86_64 *baseline* ISA (SSE2, 4-wide); this module makes
//! the lanes explicit: a stable-Rust `std::arch` AVX2 path (8-wide, the
//! full NR in one register), an SSE2 path (two 4-wide halves), and the
//! scalar register block everything else falls back to. The backend is
//! picked once per process by runtime feature detection
//! ([`active`]), overridable with the `STREAMK_KERNEL_LANES`
//! environment variable (`avx2` / `sse2` / `scalar`; anything else, or
//! an unavailable backend, falls back to detection).
//!
//! **Bit-identity is the contract, not a best effort.** Every backend
//! computes, per output element, the *same* FP sequence as the
//! per-element reference executor: K ascending, one `mul` then one
//! `add` per (element, k) pair with the intermediate product rounded to
//! f32. Vectorizing is safe because the lanes run across the N
//! (column) dimension — different output elements sit in different
//! lanes, and `_mm*_mul_ps`/`_mm*_add_ps` are IEEE-exact per lane,
//! identical to the scalar `mulss`/`addss` sequence (including NaN/∞
//! propagation: `0 · ∞` produces the same quiet NaN scalar math does,
//! and zero operands are never skipped). FMA (`_mm*_fmadd_ps`) is
//! deliberately never used: it contracts the mul+add into one rounding,
//! which would break bit-identity with the reference oracle.

//!
//! **16-bit widths.** The widening kernels ([`micro_block_w`]) stream
//! bf16/f16 panels packed by [`super::pack`] and convert in registers:
//! bf16 widens with a 16-bit left shift (`_mm256_cvtepu16_epi32` +
//! `_mm256_slli_epi32`), f16 with `_mm256_cvtph_ps` when `f16c` is
//! detected and a bit-identical software conversion otherwise.
//! Accumulation stays f32 mul-then-add, so per-width bit-identity holds
//! against the per-element oracle run over quantized inputs.

use super::width::Width;
use std::sync::OnceLock;

/// Register block rows of the microkernel.
pub(crate) const MR: usize = 4;
/// Register block columns (one AVX2 lane, or two SSE2 lanes, of f32).
pub(crate) const NR: usize = 8;
/// Widest supported register-block column count (16-bit lanes only).
pub(crate) const NR_WIDE: usize = 16;

/// A searched `MR × NR` register-block shape. The f32 path is pinned to
/// the PR-5 `4×8` block (its bit-identity baseline); 16-bit widths may
/// additionally run the `4×16` block — halving the panel element size
/// frees enough register pressure for two B vectors per row — searched
/// as a tuner axis ([`RegBlock::options`]). Column grouping never
/// changes per-element FP order (lanes run across N), so `reg` is a
/// pure performance knob: every legal block is bit-identical.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct RegBlock {
    pub mr: usize,
    pub nr: usize,
}

impl RegBlock {
    /// The PR-5 baseline block, legal at every width.
    pub const BASE: RegBlock = RegBlock { mr: MR, nr: NR };
    /// The wide block for 16-bit lanes.
    pub const WIDE: RegBlock = RegBlock { mr: MR, nr: NR_WIDE };

    /// Blocks the tuner may search at `width`.
    pub fn options(width: Width) -> &'static [RegBlock] {
        match width {
            Width::F32 => &[RegBlock::BASE],
            Width::Bf16 | Width::F16 => &[RegBlock::BASE, RegBlock::WIDE],
        }
    }

    pub fn is_legal(self, width: Width) -> bool {
        RegBlock::options(width).contains(&self)
    }

    pub fn label(self) -> String {
        format!("{}x{}", self.mr, self.nr)
    }

    pub fn parse(s: &str) -> Option<RegBlock> {
        let (m, n) = s.split_once('x')?;
        Some(RegBlock { mr: m.parse().ok()?, nr: n.parse().ok()? })
    }
}

impl Default for RegBlock {
    fn default() -> Self {
        RegBlock::BASE
    }
}

/// Whether the hardware f16 widen (`_mm256_cvtph_ps`) is usable: both
/// `f16c` and `avx2` detected. The software fallback is bit-identical,
/// so this only gates tuner exploration and lane selection, never
/// correctness.
pub fn f16c_available() -> bool {
    static F16C: OnceLock<bool> = OnceLock::new();
    *F16C.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            std::is_x86_feature_detected!("f16c")
                && std::is_x86_feature_detected!("avx2")
        }
        #[cfg(not(target_arch = "x86_64"))]
        {
            false
        }
    })
}

/// Environment override for the lane backend (`avx2`/`sse2`/`scalar`).
pub const LANES_ENV: &str = "STREAMK_KERNEL_LANES";

/// One microkernel lane implementation. Non-x86_64 targets only ever
/// *run* `Scalar`; the other variants still parse/print there so cache
/// files and CLI output stay portable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneBackend {
    /// Scalar register block (the PR-4 microkernel, auto-vectorized at
    /// whatever the build's baseline ISA allows).
    Scalar,
    /// Two 4-wide `__m128` lanes per register-block row.
    Sse2,
    /// One 8-wide `__m256` lane per register-block row.
    Avx2,
}

impl LaneBackend {
    pub fn name(self) -> &'static str {
        match self {
            LaneBackend::Scalar => "scalar",
            LaneBackend::Sse2 => "sse2",
            LaneBackend::Avx2 => "avx2",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "scalar" => Some(LaneBackend::Scalar),
            "sse2" => Some(LaneBackend::Sse2),
            "avx2" => Some(LaneBackend::Avx2),
            _ => None,
        }
    }
}

/// Backends that can actually execute on this machine, scalar first.
pub fn available() -> Vec<LaneBackend> {
    let mut v = vec![LaneBackend::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        v.push(LaneBackend::Sse2); // baseline ISA on x86_64
        if std::is_x86_feature_detected!("avx2") {
            v.push(LaneBackend::Avx2);
        }
    }
    v
}

/// Best detected backend (no environment consultation).
fn detect() -> LaneBackend {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return LaneBackend::Avx2;
        }
        return LaneBackend::Sse2;
    }
    #[allow(unreachable_code)]
    LaneBackend::Scalar
}

/// The process-wide lane backend: `STREAMK_KERNEL_LANES` if it names an
/// available backend, otherwise runtime detection. Resolved once and
/// cached (the dispatcher reads this per `block_update` call).
pub fn active() -> LaneBackend {
    static ACTIVE: OnceLock<LaneBackend> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var(LANES_ENV) {
        Ok(v) => match LaneBackend::parse(v.trim()) {
            Some(b) if available().contains(&b) => b,
            _ => detect(),
        },
        Err(_) => detect(),
    })
}

/// Downgrade a backend this machine cannot run to `Scalar` (same bits,
/// slower lanes) — hoisted out of the per-block hot path by
/// [`super::micro::block_update_with`], which resolves once per panel
/// instead of once per `MR × NR` register block.
pub(crate) fn resolve(backend: LaneBackend) -> LaneBackend {
    match backend {
        LaneBackend::Scalar => LaneBackend::Scalar,
        #[cfg(target_arch = "x86_64")]
        LaneBackend::Sse2 => LaneBackend::Sse2,
        #[cfg(target_arch = "x86_64")]
        LaneBackend::Avx2 => {
            if std::is_x86_feature_detected!("avx2") {
                LaneBackend::Avx2
            } else {
                LaneBackend::Scalar
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => LaneBackend::Scalar,
    }
}

/// One `MR × NR` register block:
/// `acc[(r0+i)·bn + c0 + j] += Σ_kk a_rows[i][kk] · bp[kk·bn + c0 + j]`
/// — K strictly ascending, separate mul-then-add per (element, k), so
/// every backend is bit-identical to the scalar reference.
///
/// Callers guarantee `a_rows[i].len() == kv`, `bp.len() >= kv * bn`,
/// `c0 + NR <= bn`, and `acc.len() >= (r0 + MR) * bn` (the contract
/// [`super::micro::block_update_with`] establishes). A backend the
/// machine cannot run (an explicit `Avx2` request on non-AVX2 hardware)
/// silently degrades to the scalar block — same bits, slower lanes.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn micro_block(
    backend: LaneBackend,
    a_rows: &[&[f32]; MR],
    bp: &[f32],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    match backend {
        LaneBackend::Scalar => {
            micro_block_scalar(a_rows, bp, bn, kv, r0, c0, acc)
        }
        #[cfg(target_arch = "x86_64")]
        LaneBackend::Sse2 => unsafe {
            // SSE2 is part of the x86_64 baseline: always runnable.
            micro_block_sse2(a_rows, bp, bn, kv, r0, c0, acc)
        },
        #[cfg(target_arch = "x86_64")]
        LaneBackend::Avx2 => {
            if std::is_x86_feature_detected!("avx2") {
                unsafe { micro_block_avx2(a_rows, bp, bn, kv, r0, c0, acc) }
            } else {
                micro_block_scalar(a_rows, bp, bn, kv, r0, c0, acc)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => micro_block_scalar(a_rows, bp, bn, kv, r0, c0, acc),
    }
}

/// The scalar register block (PR-4's microkernel, unchanged): load
/// accumulators once, stream the K slice, store once.
#[allow(clippy::too_many_arguments)]
fn micro_block_scalar(
    a_rows: &[&[f32]; MR],
    bp: &[f32],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    let mut reg = [[0.0f32; NR]; MR];
    for (i, regs) in reg.iter_mut().enumerate() {
        let at = (r0 + i) * bn + c0;
        regs.copy_from_slice(&acc[at..at + NR]);
    }
    for kk in 0..kv {
        let brow = &bp[kk * bn + c0..][..NR];
        for i in 0..MR {
            let av = a_rows[i][kk];
            for j in 0..NR {
                reg[i][j] += av * brow[j];
            }
        }
    }
    for (i, regs) in reg.iter().enumerate() {
        let at = (r0 + i) * bn + c0;
        acc[at..at + NR].copy_from_slice(regs);
    }
}

/// AVX2: the whole NR-wide row in one `__m256`. Safety: caller upholds
/// the [`micro_block`] bounds contract and AVX2 is detected.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn micro_block_avx2(
    a_rows: &[&[f32]; MR],
    bp: &[f32],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    use std::arch::x86_64::*;
    debug_assert!(c0 + NR <= bn && acc.len() >= (r0 + MR) * bn);
    debug_assert!(bp.len() >= kv * bn);
    let base = acc.as_mut_ptr();
    let bptr = bp.as_ptr();
    let mut reg = [_mm256_setzero_ps(); MR];
    for (i, r) in reg.iter_mut().enumerate() {
        *r = _mm256_loadu_ps(base.add((r0 + i) * bn + c0));
    }
    for kk in 0..kv {
        let brow = _mm256_loadu_ps(bptr.add(kk * bn + c0));
        for (i, r) in reg.iter_mut().enumerate() {
            let av = _mm256_set1_ps(*a_rows[i].get_unchecked(kk));
            // mul then add — never _mm256_fmadd_ps, which would contract
            // the two roundings and break bit-identity with the oracle
            *r = _mm256_add_ps(*r, _mm256_mul_ps(av, brow));
        }
    }
    for (i, r) in reg.iter().enumerate() {
        _mm256_storeu_ps(base.add((r0 + i) * bn + c0), *r);
    }
}

/// SSE2: two 4-wide halves per row. Safety: caller upholds the
/// [`micro_block`] bounds contract (SSE2 is always present on x86_64).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
#[allow(clippy::too_many_arguments)]
unsafe fn micro_block_sse2(
    a_rows: &[&[f32]; MR],
    bp: &[f32],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    use std::arch::x86_64::*;
    debug_assert!(c0 + NR <= bn && acc.len() >= (r0 + MR) * bn);
    debug_assert!(bp.len() >= kv * bn);
    let base = acc.as_mut_ptr();
    let bptr = bp.as_ptr();
    let mut lo = [_mm_setzero_ps(); MR];
    let mut hi = [_mm_setzero_ps(); MR];
    for i in 0..MR {
        let p = base.add((r0 + i) * bn + c0);
        lo[i] = _mm_loadu_ps(p);
        hi[i] = _mm_loadu_ps(p.add(4));
    }
    for kk in 0..kv {
        let bl = _mm_loadu_ps(bptr.add(kk * bn + c0));
        let bh = _mm_loadu_ps(bptr.add(kk * bn + c0 + 4));
        for i in 0..MR {
            let av = _mm_set1_ps(*a_rows[i].get_unchecked(kk));
            // mul then add — never FMA (see the AVX2 block)
            lo[i] = _mm_add_ps(lo[i], _mm_mul_ps(av, bl));
            hi[i] = _mm_add_ps(hi[i], _mm_mul_ps(av, bh));
        }
    }
    for i in 0..MR {
        let p = base.add((r0 + i) * bn + c0);
        _mm_storeu_ps(p, lo[i]);
        _mm_storeu_ps(p.add(4), hi[i]);
    }
}

/// One widening `MR × nr` register block over 16-bit panels:
/// `acc[(r0+i)·bn + c0 + j] += Σ_kk widen(a_rows[i][kk]) · widen(bp[kk·bn + c0 + j])`
/// — K strictly ascending, separate mul-then-add per (element, k).
/// Widening is an exact per-element conversion (hardware and software
/// paths agree bit-for-bit, including NaN quieting), so every backend
/// and both block widths are bit-identical to the scalar widening
/// block, which in turn matches the per-element oracle over quantized
/// inputs.
///
/// Callers guarantee `width != F32`, `nr ∈ {8, 16}`, `c0 + nr <= bn`,
/// and the same bounds contract as [`micro_block`]. The B row is
/// widened once per k and reused across all MR rows (same value as
/// widening per use — `widen` is pure — but ~MR× fewer conversions).
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn micro_block_w(
    backend: LaneBackend,
    width: Width,
    nr: usize,
    a_rows: &[&[u16]; MR],
    bp: &[u16],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    debug_assert!(width != Width::F32, "f32 panels use micro_block");
    debug_assert!(nr == NR || nr == NR_WIDE);
    match backend {
        LaneBackend::Scalar => {
            micro_block_w_scalar(width, nr, a_rows, bp, bn, kv, r0, c0, acc)
        }
        #[cfg(target_arch = "x86_64")]
        LaneBackend::Sse2 => match width {
            Width::Bf16 => unsafe {
                micro_block_w_sse2_bf16(nr / NR, a_rows, bp, bn, kv, r0, c0, acc)
            },
            // No SSE2 f16 widen in hardware; the software-widened scalar
            // block computes the identical bits.
            _ => micro_block_w_scalar(width, nr, a_rows, bp, bn, kv, r0, c0, acc),
        },
        #[cfg(target_arch = "x86_64")]
        LaneBackend::Avx2 => {
            if !std::is_x86_feature_detected!("avx2") {
                return micro_block_w_scalar(
                    width, nr, a_rows, bp, bn, kv, r0, c0, acc,
                );
            }
            match width {
                Width::Bf16 => unsafe {
                    micro_block_w_avx2_bf16(nr / NR, a_rows, bp, bn, kv, r0, c0, acc)
                },
                Width::F16 if f16c_available() => unsafe {
                    micro_block_w_avx2_f16(nr / NR, a_rows, bp, bn, kv, r0, c0, acc)
                },
                _ => micro_block_w_scalar(width, nr, a_rows, bp, bn, kv, r0, c0, acc),
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => micro_block_w_scalar(width, nr, a_rows, bp, bn, kv, r0, c0, acc),
    }
}

/// Scalar widening register block — the reference every SIMD widening
/// lane must match bitwise, at either block width.
#[allow(clippy::too_many_arguments)]
fn micro_block_w_scalar(
    width: Width,
    nr: usize,
    a_rows: &[&[u16]; MR],
    bp: &[u16],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    let mut reg = [[0.0f32; NR_WIDE]; MR];
    for (i, regs) in reg.iter_mut().enumerate() {
        let at = (r0 + i) * bn + c0;
        regs[..nr].copy_from_slice(&acc[at..at + nr]);
    }
    let mut bw = [0.0f32; NR_WIDE];
    for kk in 0..kv {
        let brow = &bp[kk * bn + c0..][..nr];
        for (w, &h) in bw[..nr].iter_mut().zip(brow) {
            *w = width.widen(h);
        }
        for i in 0..MR {
            let av = width.widen(a_rows[i][kk]);
            for j in 0..nr {
                reg[i][j] += av * bw[j];
            }
        }
    }
    for (i, regs) in reg.iter().enumerate() {
        let at = (r0 + i) * bn + c0;
        acc[at..at + nr].copy_from_slice(&regs[..nr]);
    }
}

/// AVX2 bf16: widen each 8-wide B group with zero-extend + 16-bit left
/// shift; broadcast A via the scalar shift-widen. Safety: caller
/// upholds the [`micro_block_w`] bounds contract and AVX2 is detected.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn micro_block_w_avx2_bf16(
    nb: usize,
    a_rows: &[&[u16]; MR],
    bp: &[u16],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    use std::arch::x86_64::*;
    debug_assert!(nb >= 1 && nb <= 2);
    debug_assert!(c0 + nb * NR <= bn && acc.len() >= (r0 + MR) * bn);
    debug_assert!(bp.len() >= kv * bn);
    let base = acc.as_mut_ptr();
    let bptr = bp.as_ptr();
    let mut reg = [[_mm256_setzero_ps(); 2]; MR];
    for (i, row) in reg.iter_mut().enumerate() {
        for (jb, r) in row[..nb].iter_mut().enumerate() {
            *r = _mm256_loadu_ps(base.add((r0 + i) * bn + c0 + jb * NR));
        }
    }
    for kk in 0..kv {
        let mut brow = [_mm256_setzero_ps(); 2];
        for (jb, b) in brow[..nb].iter_mut().enumerate() {
            let raw = _mm_loadu_si128(
                bptr.add(kk * bn + c0 + jb * NR) as *const __m128i
            );
            *b = _mm256_castsi256_ps(_mm256_slli_epi32(
                _mm256_cvtepu16_epi32(raw),
                16,
            ));
        }
        for (i, row) in reg.iter_mut().enumerate() {
            let h = *a_rows[i].get_unchecked(kk);
            let av = _mm256_set1_ps(f32::from_bits((h as u32) << 16));
            for (jb, r) in row[..nb].iter_mut().enumerate() {
                // mul then add — never FMA (see micro_block_avx2)
                *r = _mm256_add_ps(*r, _mm256_mul_ps(av, brow[jb]));
            }
        }
    }
    for (i, row) in reg.iter().enumerate() {
        for (jb, r) in row[..nb].iter().enumerate() {
            _mm256_storeu_ps(base.add((r0 + i) * bn + c0 + jb * NR), *r);
        }
    }
}

/// AVX2 + F16C: widen each 8-wide B group with `_mm256_cvtph_ps`;
/// broadcast A via the (bit-identical) software widen. Safety: caller
/// upholds the [`micro_block_w`] bounds contract; AVX2 and F16C are
/// detected.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2,f16c")]
#[allow(clippy::too_many_arguments)]
unsafe fn micro_block_w_avx2_f16(
    nb: usize,
    a_rows: &[&[u16]; MR],
    bp: &[u16],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    use std::arch::x86_64::*;
    debug_assert!(nb >= 1 && nb <= 2);
    debug_assert!(c0 + nb * NR <= bn && acc.len() >= (r0 + MR) * bn);
    debug_assert!(bp.len() >= kv * bn);
    let base = acc.as_mut_ptr();
    let bptr = bp.as_ptr();
    let mut reg = [[_mm256_setzero_ps(); 2]; MR];
    for (i, row) in reg.iter_mut().enumerate() {
        for (jb, r) in row[..nb].iter_mut().enumerate() {
            *r = _mm256_loadu_ps(base.add((r0 + i) * bn + c0 + jb * NR));
        }
    }
    for kk in 0..kv {
        let mut brow = [_mm256_setzero_ps(); 2];
        for (jb, b) in brow[..nb].iter_mut().enumerate() {
            let raw = _mm_loadu_si128(
                bptr.add(kk * bn + c0 + jb * NR) as *const __m128i
            );
            *b = _mm256_cvtph_ps(raw);
        }
        for (i, row) in reg.iter_mut().enumerate() {
            let h = *a_rows[i].get_unchecked(kk);
            let av = _mm256_set1_ps(super::width::f16_to_f32(h));
            for (jb, r) in row[..nb].iter_mut().enumerate() {
                // mul then add — never FMA (see micro_block_avx2)
                *r = _mm256_add_ps(*r, _mm256_mul_ps(av, brow[jb]));
            }
        }
    }
    for (i, row) in reg.iter().enumerate() {
        for (jb, r) in row[..nb].iter().enumerate() {
            _mm256_storeu_ps(base.add((r0 + i) * bn + c0 + jb * NR), *r);
        }
    }
}

/// SSE2 bf16: widen each 8-wide B group into two 4-wide halves with
/// `unpacklo/hi(0, v)` (interleaving zeros below each u16 *is* the
/// 16-bit left shift). Safety: caller upholds the [`micro_block_w`]
/// bounds contract (SSE2 is always present on x86_64).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
#[allow(clippy::too_many_arguments)]
unsafe fn micro_block_w_sse2_bf16(
    nb: usize,
    a_rows: &[&[u16]; MR],
    bp: &[u16],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    use std::arch::x86_64::*;
    debug_assert!(nb >= 1 && nb <= 2);
    debug_assert!(c0 + nb * NR <= bn && acc.len() >= (r0 + MR) * bn);
    debug_assert!(bp.len() >= kv * bn);
    let base = acc.as_mut_ptr();
    let bptr = bp.as_ptr();
    let mut reg = [[_mm_setzero_ps(); 4]; MR];
    for (i, row) in reg.iter_mut().enumerate() {
        for jb in 0..nb {
            let p = base.add((r0 + i) * bn + c0 + jb * NR);
            row[2 * jb] = _mm_loadu_ps(p);
            row[2 * jb + 1] = _mm_loadu_ps(p.add(4));
        }
    }
    let zero = _mm_setzero_si128();
    for kk in 0..kv {
        let mut brow = [_mm_setzero_ps(); 4];
        for jb in 0..nb {
            let raw = _mm_loadu_si128(
                bptr.add(kk * bn + c0 + jb * NR) as *const __m128i
            );
            brow[2 * jb] = _mm_castsi128_ps(_mm_unpacklo_epi16(zero, raw));
            brow[2 * jb + 1] = _mm_castsi128_ps(_mm_unpackhi_epi16(zero, raw));
        }
        for (i, row) in reg.iter_mut().enumerate() {
            let h = *a_rows[i].get_unchecked(kk);
            let av = _mm_set1_ps(f32::from_bits((h as u32) << 16));
            for (h4, r) in row[..2 * nb].iter_mut().enumerate() {
                // mul then add — never FMA (see the AVX2 block)
                *r = _mm_add_ps(*r, _mm_mul_ps(av, brow[h4]));
            }
        }
    }
    for (i, row) in reg.iter().enumerate() {
        for jb in 0..nb {
            let p = base.add((r0 + i) * bn + c0 + jb * NR);
            _mm_storeu_ps(p, row[2 * jb]);
            _mm_storeu_ps(p.add(4), row[2 * jb + 1]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for b in [LaneBackend::Scalar, LaneBackend::Sse2, LaneBackend::Avx2] {
            assert_eq!(LaneBackend::parse(b.name()), Some(b));
        }
        assert_eq!(LaneBackend::parse("neon"), None);
    }

    #[test]
    fn scalar_is_always_available_and_active_is_runnable() {
        let avail = available();
        assert!(avail.contains(&LaneBackend::Scalar));
        assert!(
            avail.contains(&active()),
            "active backend {:?} must be runnable here",
            active()
        );
        #[cfg(target_arch = "x86_64")]
        assert!(avail.contains(&LaneBackend::Sse2), "sse2 is baseline");
    }

    #[test]
    fn every_backend_matches_scalar_bitwise_on_one_block() {
        // One MR×NR block with non-finite values seeded: the lanes must
        // reproduce the scalar block exactly, bit for bit.
        let kv = 9;
        let bn = NR + 3; // misaligned panel width exercises unaligned loads
        let mut a = vec![0.0f32; MR * kv];
        for (i, v) in a.iter_mut().enumerate() {
            *v = (i as f32).sin();
        }
        a[3] = f32::INFINITY;
        a[kv + 1] = f32::NAN;
        let mut bp = vec![0.0f32; kv * bn];
        for (i, v) in bp.iter_mut().enumerate() {
            *v = (i as f32 * 0.7).cos();
        }
        bp[2 * bn] = 0.0; // 0 · Inf inside the block
        let a_rows: [&[f32]; MR] = [
            &a[0..kv],
            &a[kv..2 * kv],
            &a[2 * kv..3 * kv],
            &a[3 * kv..4 * kv],
        ];
        let mut want = vec![0.1f32; MR * bn];
        micro_block_scalar(&a_rows, &bp, bn, kv, 0, 0, &mut want);
        for backend in available() {
            let mut got = vec![0.1f32; MR * bn];
            micro_block(backend, &a_rows, &bp, bn, kv, 0, 0, &mut got);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{backend:?} elem {i}: {g} vs {w}"
                );
            }
        }
    }

    /// 16-bit panel with every special class seeded: ∞, quiet NaN,
    /// *signaling* NaN (hardware and software widens must both quieten
    /// it identically), subnormals, and a 0·∞ pair inside the block.
    fn seeded_panel_u16(width: Width, n: usize, seed: u64) -> Vec<u16> {
        let mut rng = crate::prop::Rng::new(seed);
        let mut v: Vec<u16> = (0..n)
            .map(|_| width.narrow(rng.f32_in(-4.0, 4.0)))
            .collect();
        let (inf, qnan, snan, sub) = match width {
            Width::F16 => (0x7C00, 0x7E01, 0x7C01, 0x0001),
            _ => (0x7F80, 0xFFC1, 0x7F81, 0x0001),
        };
        if n >= 8 {
            v[1] = inf;
            v[3] = qnan;
            v[5] = snan;
            v[6] = sub;
            v[7] = 0;
        }
        v
    }

    #[test]
    fn widening_backends_match_scalar_bitwise_per_width_and_block() {
        for width in [Width::Bf16, Width::F16] {
            for nr in [NR, NR_WIDE] {
                let kv = 9;
                let bn = nr + 3;
                let a = seeded_panel_u16(width, MR * kv, 0xA11CE);
                let mut bp = seeded_panel_u16(width, kv * bn, 0xB0B);
                bp[bn + 1] = 0; // column hit by A's ∞ row → 0 · ∞
                let a_rows: [&[u16]; MR] = [
                    &a[0..kv],
                    &a[kv..2 * kv],
                    &a[2 * kv..3 * kv],
                    &a[3 * kv..4 * kv],
                ];
                let mut want = vec![0.1f32; MR * bn];
                micro_block_w_scalar(width, nr, &a_rows, &bp, bn, kv, 0, 0, &mut want);
                for backend in available() {
                    let mut got = vec![0.1f32; MR * bn];
                    micro_block_w(backend, width, nr, &a_rows, &bp, bn, kv, 0, 0, &mut got);
                    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                        assert_eq!(
                            g.to_bits(),
                            w.to_bits(),
                            "{backend:?}/{width}/nr={nr} elem {i}: {g} vs {w}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn wide_block_is_bit_identical_to_two_base_blocks() {
        // Column grouping must not change per-element FP order: one
        // 4×16 call equals two adjacent 4×8 calls, at every backend.
        for width in [Width::Bf16, Width::F16] {
            let kv = 7;
            let bn = NR_WIDE + 1;
            let a = seeded_panel_u16(width, MR * kv, 0xCAFE);
            let bp = seeded_panel_u16(width, kv * bn, 0xD00D);
            let a_rows: [&[u16]; MR] = [
                &a[0..kv],
                &a[kv..2 * kv],
                &a[2 * kv..3 * kv],
                &a[3 * kv..4 * kv],
            ];
            for backend in available() {
                let mut wide = vec![0.25f32; MR * bn];
                micro_block_w(backend, width, NR_WIDE, &a_rows, &bp, bn, kv, 0, 0, &mut wide);
                let mut base = vec![0.25f32; MR * bn];
                micro_block_w(backend, width, NR, &a_rows, &bp, bn, kv, 0, 0, &mut base);
                micro_block_w(backend, width, NR, &a_rows, &bp, bn, kv, 0, NR, &mut base);
                for (i, (g, w)) in wide.iter().zip(&base).enumerate() {
                    assert_eq!(g.to_bits(), w.to_bits(), "{backend:?}/{width} elem {i}");
                }
            }
        }
    }

    #[test]
    fn reg_block_legality_and_labels() {
        assert_eq!(RegBlock::options(Width::F32), &[RegBlock::BASE]);
        assert_eq!(
            RegBlock::options(Width::Bf16),
            &[RegBlock::BASE, RegBlock::WIDE]
        );
        assert!(RegBlock::WIDE.is_legal(Width::F16));
        assert!(!RegBlock::WIDE.is_legal(Width::F32));
        assert!(!RegBlock { mr: 6, nr: 8 }.is_legal(Width::Bf16));
        for r in [RegBlock::BASE, RegBlock::WIDE] {
            assert_eq!(RegBlock::parse(&r.label()), Some(r));
        }
        assert_eq!(RegBlock::parse("4x"), None);
        assert_eq!(RegBlock::default(), RegBlock::BASE);
    }
}
