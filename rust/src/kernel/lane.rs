//! Explicit SIMD lane backends for the microkernel's `MR × NR`
//! register block.
//!
//! The PR-4 microkernel was scalar Rust the compiler auto-vectorized
//! against the x86_64 *baseline* ISA (SSE2, 4-wide); this module makes
//! the lanes explicit: a stable-Rust `std::arch` AVX2 path (8-wide, the
//! full NR in one register), an SSE2 path (two 4-wide halves), and the
//! scalar register block everything else falls back to. The backend is
//! picked once per process by runtime feature detection
//! ([`active`]), overridable with the `STREAMK_KERNEL_LANES`
//! environment variable (`avx2` / `sse2` / `scalar`; anything else, or
//! an unavailable backend, falls back to detection).
//!
//! **Bit-identity is the contract, not a best effort.** Every backend
//! computes, per output element, the *same* FP sequence as the
//! per-element reference executor: K ascending, one `mul` then one
//! `add` per (element, k) pair with the intermediate product rounded to
//! f32. Vectorizing is safe because the lanes run across the N
//! (column) dimension — different output elements sit in different
//! lanes, and `_mm*_mul_ps`/`_mm*_add_ps` are IEEE-exact per lane,
//! identical to the scalar `mulss`/`addss` sequence (including NaN/∞
//! propagation: `0 · ∞` produces the same quiet NaN scalar math does,
//! and zero operands are never skipped). FMA (`_mm*_fmadd_ps`) is
//! deliberately never used: it contracts the mul+add into one rounding,
//! which would break bit-identity with the reference oracle.

use std::sync::OnceLock;

/// Register block rows of the microkernel.
pub(crate) const MR: usize = 4;
/// Register block columns (one AVX2 lane, or two SSE2 lanes, of f32).
pub(crate) const NR: usize = 8;

/// Environment override for the lane backend (`avx2`/`sse2`/`scalar`).
pub const LANES_ENV: &str = "STREAMK_KERNEL_LANES";

/// One microkernel lane implementation. Non-x86_64 targets only ever
/// *run* `Scalar`; the other variants still parse/print there so cache
/// files and CLI output stay portable.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LaneBackend {
    /// Scalar register block (the PR-4 microkernel, auto-vectorized at
    /// whatever the build's baseline ISA allows).
    Scalar,
    /// Two 4-wide `__m128` lanes per register-block row.
    Sse2,
    /// One 8-wide `__m256` lane per register-block row.
    Avx2,
}

impl LaneBackend {
    pub fn name(self) -> &'static str {
        match self {
            LaneBackend::Scalar => "scalar",
            LaneBackend::Sse2 => "sse2",
            LaneBackend::Avx2 => "avx2",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "scalar" => Some(LaneBackend::Scalar),
            "sse2" => Some(LaneBackend::Sse2),
            "avx2" => Some(LaneBackend::Avx2),
            _ => None,
        }
    }
}

/// Backends that can actually execute on this machine, scalar first.
pub fn available() -> Vec<LaneBackend> {
    let mut v = vec![LaneBackend::Scalar];
    #[cfg(target_arch = "x86_64")]
    {
        v.push(LaneBackend::Sse2); // baseline ISA on x86_64
        if std::is_x86_feature_detected!("avx2") {
            v.push(LaneBackend::Avx2);
        }
    }
    v
}

/// Best detected backend (no environment consultation).
fn detect() -> LaneBackend {
    #[cfg(target_arch = "x86_64")]
    {
        if std::is_x86_feature_detected!("avx2") {
            return LaneBackend::Avx2;
        }
        return LaneBackend::Sse2;
    }
    #[allow(unreachable_code)]
    LaneBackend::Scalar
}

/// The process-wide lane backend: `STREAMK_KERNEL_LANES` if it names an
/// available backend, otherwise runtime detection. Resolved once and
/// cached (the dispatcher reads this per `block_update` call).
pub fn active() -> LaneBackend {
    static ACTIVE: OnceLock<LaneBackend> = OnceLock::new();
    *ACTIVE.get_or_init(|| match std::env::var(LANES_ENV) {
        Ok(v) => match LaneBackend::parse(v.trim()) {
            Some(b) if available().contains(&b) => b,
            _ => detect(),
        },
        Err(_) => detect(),
    })
}

/// Downgrade a backend this machine cannot run to `Scalar` (same bits,
/// slower lanes) — hoisted out of the per-block hot path by
/// [`super::micro::block_update_with`], which resolves once per panel
/// instead of once per `MR × NR` register block.
pub(crate) fn resolve(backend: LaneBackend) -> LaneBackend {
    match backend {
        LaneBackend::Scalar => LaneBackend::Scalar,
        #[cfg(target_arch = "x86_64")]
        LaneBackend::Sse2 => LaneBackend::Sse2,
        #[cfg(target_arch = "x86_64")]
        LaneBackend::Avx2 => {
            if std::is_x86_feature_detected!("avx2") {
                LaneBackend::Avx2
            } else {
                LaneBackend::Scalar
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => LaneBackend::Scalar,
    }
}

/// One `MR × NR` register block:
/// `acc[(r0+i)·bn + c0 + j] += Σ_kk a_rows[i][kk] · bp[kk·bn + c0 + j]`
/// — K strictly ascending, separate mul-then-add per (element, k), so
/// every backend is bit-identical to the scalar reference.
///
/// Callers guarantee `a_rows[i].len() == kv`, `bp.len() >= kv * bn`,
/// `c0 + NR <= bn`, and `acc.len() >= (r0 + MR) * bn` (the contract
/// [`super::micro::block_update_with`] establishes). A backend the
/// machine cannot run (an explicit `Avx2` request on non-AVX2 hardware)
/// silently degrades to the scalar block — same bits, slower lanes.
#[inline]
#[allow(clippy::too_many_arguments)]
pub(crate) fn micro_block(
    backend: LaneBackend,
    a_rows: &[&[f32]; MR],
    bp: &[f32],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    match backend {
        LaneBackend::Scalar => {
            micro_block_scalar(a_rows, bp, bn, kv, r0, c0, acc)
        }
        #[cfg(target_arch = "x86_64")]
        LaneBackend::Sse2 => unsafe {
            // SSE2 is part of the x86_64 baseline: always runnable.
            micro_block_sse2(a_rows, bp, bn, kv, r0, c0, acc)
        },
        #[cfg(target_arch = "x86_64")]
        LaneBackend::Avx2 => {
            if std::is_x86_feature_detected!("avx2") {
                unsafe { micro_block_avx2(a_rows, bp, bn, kv, r0, c0, acc) }
            } else {
                micro_block_scalar(a_rows, bp, bn, kv, r0, c0, acc)
            }
        }
        #[cfg(not(target_arch = "x86_64"))]
        _ => micro_block_scalar(a_rows, bp, bn, kv, r0, c0, acc),
    }
}

/// The scalar register block (PR-4's microkernel, unchanged): load
/// accumulators once, stream the K slice, store once.
#[allow(clippy::too_many_arguments)]
fn micro_block_scalar(
    a_rows: &[&[f32]; MR],
    bp: &[f32],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    let mut reg = [[0.0f32; NR]; MR];
    for (i, regs) in reg.iter_mut().enumerate() {
        let at = (r0 + i) * bn + c0;
        regs.copy_from_slice(&acc[at..at + NR]);
    }
    for kk in 0..kv {
        let brow = &bp[kk * bn + c0..][..NR];
        for i in 0..MR {
            let av = a_rows[i][kk];
            for j in 0..NR {
                reg[i][j] += av * brow[j];
            }
        }
    }
    for (i, regs) in reg.iter().enumerate() {
        let at = (r0 + i) * bn + c0;
        acc[at..at + NR].copy_from_slice(regs);
    }
}

/// AVX2: the whole NR-wide row in one `__m256`. Safety: caller upholds
/// the [`micro_block`] bounds contract and AVX2 is detected.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
#[allow(clippy::too_many_arguments)]
unsafe fn micro_block_avx2(
    a_rows: &[&[f32]; MR],
    bp: &[f32],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    use std::arch::x86_64::*;
    debug_assert!(c0 + NR <= bn && acc.len() >= (r0 + MR) * bn);
    debug_assert!(bp.len() >= kv * bn);
    let base = acc.as_mut_ptr();
    let bptr = bp.as_ptr();
    let mut reg = [_mm256_setzero_ps(); MR];
    for (i, r) in reg.iter_mut().enumerate() {
        *r = _mm256_loadu_ps(base.add((r0 + i) * bn + c0));
    }
    for kk in 0..kv {
        let brow = _mm256_loadu_ps(bptr.add(kk * bn + c0));
        for (i, r) in reg.iter_mut().enumerate() {
            let av = _mm256_set1_ps(*a_rows[i].get_unchecked(kk));
            // mul then add — never _mm256_fmadd_ps, which would contract
            // the two roundings and break bit-identity with the oracle
            *r = _mm256_add_ps(*r, _mm256_mul_ps(av, brow));
        }
    }
    for (i, r) in reg.iter().enumerate() {
        _mm256_storeu_ps(base.add((r0 + i) * bn + c0), *r);
    }
}

/// SSE2: two 4-wide halves per row. Safety: caller upholds the
/// [`micro_block`] bounds contract (SSE2 is always present on x86_64).
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "sse2")]
#[allow(clippy::too_many_arguments)]
unsafe fn micro_block_sse2(
    a_rows: &[&[f32]; MR],
    bp: &[f32],
    bn: usize,
    kv: usize,
    r0: usize,
    c0: usize,
    acc: &mut [f32],
) {
    use std::arch::x86_64::*;
    debug_assert!(c0 + NR <= bn && acc.len() >= (r0 + MR) * bn);
    debug_assert!(bp.len() >= kv * bn);
    let base = acc.as_mut_ptr();
    let bptr = bp.as_ptr();
    let mut lo = [_mm_setzero_ps(); MR];
    let mut hi = [_mm_setzero_ps(); MR];
    for i in 0..MR {
        let p = base.add((r0 + i) * bn + c0);
        lo[i] = _mm_loadu_ps(p);
        hi[i] = _mm_loadu_ps(p.add(4));
    }
    for kk in 0..kv {
        let bl = _mm_loadu_ps(bptr.add(kk * bn + c0));
        let bh = _mm_loadu_ps(bptr.add(kk * bn + c0 + 4));
        for i in 0..MR {
            let av = _mm_set1_ps(*a_rows[i].get_unchecked(kk));
            // mul then add — never FMA (see the AVX2 block)
            lo[i] = _mm_add_ps(lo[i], _mm_mul_ps(av, bl));
            hi[i] = _mm_add_ps(hi[i], _mm_mul_ps(av, bh));
        }
    }
    for i in 0..MR {
        let p = base.add((r0 + i) * bn + c0);
        _mm_storeu_ps(p, lo[i]);
        _mm_storeu_ps(p.add(4), hi[i]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for b in [LaneBackend::Scalar, LaneBackend::Sse2, LaneBackend::Avx2] {
            assert_eq!(LaneBackend::parse(b.name()), Some(b));
        }
        assert_eq!(LaneBackend::parse("neon"), None);
    }

    #[test]
    fn scalar_is_always_available_and_active_is_runnable() {
        let avail = available();
        assert!(avail.contains(&LaneBackend::Scalar));
        assert!(
            avail.contains(&active()),
            "active backend {:?} must be runnable here",
            active()
        );
        #[cfg(target_arch = "x86_64")]
        assert!(avail.contains(&LaneBackend::Sse2), "sse2 is baseline");
    }

    #[test]
    fn every_backend_matches_scalar_bitwise_on_one_block() {
        // One MR×NR block with non-finite values seeded: the lanes must
        // reproduce the scalar block exactly, bit for bit.
        let kv = 9;
        let bn = NR + 3; // misaligned panel width exercises unaligned loads
        let mut a = vec![0.0f32; MR * kv];
        for (i, v) in a.iter_mut().enumerate() {
            *v = (i as f32).sin();
        }
        a[3] = f32::INFINITY;
        a[kv + 1] = f32::NAN;
        let mut bp = vec![0.0f32; kv * bn];
        for (i, v) in bp.iter_mut().enumerate() {
            *v = (i as f32 * 0.7).cos();
        }
        bp[2 * bn] = 0.0; // 0 · Inf inside the block
        let a_rows: [&[f32]; MR] = [
            &a[0..kv],
            &a[kv..2 * kv],
            &a[2 * kv..3 * kv],
            &a[3 * kv..4 * kv],
        ];
        let mut want = vec![0.1f32; MR * bn];
        micro_block_scalar(&a_rows, &bp, bn, kv, 0, 0, &mut want);
        for backend in available() {
            let mut got = vec![0.1f32; MR * bn];
            micro_block(backend, &a_rows, &bp, bn, kv, 0, 0, &mut got);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{backend:?} elem {i}: {g} vs {w}"
                );
            }
        }
    }
}
