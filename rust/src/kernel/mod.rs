//! Blocked microkernel execution layer — how the functional backend
//! actually runs a Stream-K schedule over host data.
//!
//! The interpreter runtime, the fault-injection executors and the
//! kernel-equivalence bench all execute schedules on the CPU. The
//! original per-element executors indexed `A[(r0+r)*k + kcol]` /
//! `B[kcol*n + c0+cc]` once per MAC, which is several-fold off what a
//! blocked CPU GEMM does. This layer executes a [`FlatSchedule`] the way
//! the paper decomposes it — fixed-size block tiles streamed over the K
//! dimension — with the classic packed-buffer structure of BLIS-style
//! CPU GEMM (Huang et al., 2016):
//!
//! - [`pack`] — row-slice panel packing: the A panel (`BM × kc`) and the
//!   B panel (`kc × BN`) of one tile's K-slice are copied into
//!   contiguous scratch, so the inner loops walk unit-stride memory; at
//!   16-bit widths ([`width::Width`]) the packer narrows on the copy
//!   (convert-on-pack), halving streamed panel bytes;
//! - [`lane`] — explicit SIMD lane backends for the register block: a
//!   stable-Rust `std::arch` AVX2/SSE2 path picked by runtime feature
//!   detection (`STREAMK_KERNEL_LANES` overrides), scalar everywhere
//!   else — separate mul-then-add per lane element, never FMA, so every
//!   backend is bit-identical to the scalar reference;
//! - [`micro`] — a cache-sized, register-blocked f32 microkernel
//!   (`MR × NR` accumulators) that streams the packed panels in strictly
//!   ascending K order, so every output element sees the *exact* FP
//!   addition sequence of the per-element reference — bit-identical
//!   numerics, including NaN/∞ propagation (zero operands are never
//!   skipped);
//! - [`exec`] — per-work-item dispatch: [`exec::ExecDesc`] precomputes
//!   one tile descriptor per [`FlatSchedule`] work item (clamped tile
//!   origins, contiguous valid-K ranges, partial-slot routing, and the
//!   tile-ownership class of every store). Owned tiles — unclamped,
//!   single-writer, the common aligned case — stream their finished
//!   accumulators straight into C from the compute workers (no staging
//!   arena, no ordered drain); the rest compute in parallel over
//!   [`crate::exec::scope_map_with`], store in the reference's serial
//!   order, and sum fixup contributors in k-ascending contributor
//!   order — deterministic for every thread count and dispatcher mode.
//!
//! The [`Epilogue`] hook fuses the artifact epilogue (relu / tanh-gelu)
//! into the accumulate-into-C store, so the interpreter runtime does not
//! re-walk C after a fused gemm.
//!
//! Consumers: [`crate::faults::execute_flat`] (interpreter runtime),
//! [`crate::faults::execute_schedule`] (fault-injection replay),
//! [`crate::runtime`]'s interpreter backend (Stream-K gemm artifacts and
//! the MLP matmuls via [`matmul`]), and `benches/kernel_exec.rs`.

pub mod exec;
pub mod lane;
pub mod micro;
pub mod pack;
pub mod width;

pub use exec::{
    execute, execute_opts, execute_threads, matmul, Dest, ExecDesc,
    ExecOpts, TileJob,
};
pub use lane::{f16c_available, LaneBackend, RegBlock, LANES_ENV};
pub use pack::PackBuf;
pub use width::Width;

use crate::decomp::FlatSchedule;

/// Below this many MAC-FLOPs the dispatcher stays single-threaded —
/// scoped-thread spawn (~tens of µs) would dominate tiny problems.
const PARALLEL_MIN_MACS: u64 = 1 << 23;

/// Worker cap: the executor shares the machine with the coordinator's
/// worker threads and the test harness; past 8 lanes the packed panels
/// start fighting over shared cache anyway.
const MAX_THREADS: usize = 8;

/// Pick the worker count for `macs` MAC-FLOPs of schedule work.
pub(crate) fn default_threads(macs: u64) -> usize {
    if macs < PARALLEL_MIN_MACS {
        return 1;
    }
    std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1)
        .min(MAX_THREADS)
}

/// Elementwise epilogue fused into the accumulate-into-C store. Applied
/// exactly once per output element (at the direct store or the fixup
/// store — never to a partial), so fusing is bit-identical to a separate
/// post-pass over C.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Epilogue {
    #[default]
    None,
    Relu,
    /// jax.nn.gelu(approximate=True) — the tanh approximation the MLP
    /// graph lowers (`python/compile/model.py`).
    Gelu,
}

impl Epilogue {
    /// Map an artifact-manifest epilogue name; `None` for unsupported
    /// names (the runtime turns that into its typed backend error).
    pub fn parse(name: &str) -> Option<Self> {
        match name {
            "" | "none" => Some(Self::None),
            "relu" => Some(Self::Relu),
            "gelu" => Some(Self::Gelu),
            _ => None,
        }
    }

    #[inline]
    pub fn apply(self, v: f32) -> f32 {
        match self {
            Self::None => v,
            Self::Relu => v.max(0.0),
            Self::Gelu => gelu(v),
        }
    }

    /// Apply in place over a full buffer (the unfused fallback path).
    pub fn apply_slice(self, c: &mut [f32]) {
        if self != Self::None {
            for v in c {
                *v = self.apply(*v);
            }
        }
    }
}

/// The tanh-approximate gelu, computed in f64 exactly as the original
/// interpreter backend did (bit-compatible with the PJRT lowering).
pub fn gelu(x: f32) -> f32 {
    let x = x as f64;
    let inner =
        (2.0 / std::f64::consts::PI).sqrt() * (x + 0.044715 * x * x * x);
    (0.5 * x * (1.0 + inner.tanh())) as f32
}

/// Convenience: descriptor + blocked execution for a flat schedule in
/// one call (callers that replay repeatedly should cache the
/// [`ExecDesc`] — [`crate::plan::Plan`] does).
pub fn execute_flat_schedule(
    a: &[f32],
    b: &[f32],
    shape: crate::decomp::GemmShape,
    flat: &FlatSchedule,
    block: crate::decomp::BlockShape,
    epilogue: Epilogue,
) -> Vec<f32> {
    let desc = ExecDesc::new(shape, block, flat);
    execute(a, b, &desc, epilogue)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn epilogue_parsing_matches_manifest_names() {
        assert_eq!(Epilogue::parse(""), Some(Epilogue::None));
        assert_eq!(Epilogue::parse("none"), Some(Epilogue::None));
        assert_eq!(Epilogue::parse("relu"), Some(Epilogue::Relu));
        assert_eq!(Epilogue::parse("gelu"), Some(Epilogue::Gelu));
        assert_eq!(Epilogue::parse("swish"), None);
    }

    #[test]
    fn epilogue_apply_matches_slice_apply() {
        let vals = [-2.5f32, -0.0, 0.0, 0.7, 10.0, f32::NAN];
        for ep in [Epilogue::None, Epilogue::Relu, Epilogue::Gelu] {
            let mut buf = vals.to_vec();
            ep.apply_slice(&mut buf);
            for (&v, &got) in vals.iter().zip(&buf) {
                let want = ep.apply(v);
                assert!(
                    want.to_bits() == got.to_bits(),
                    "{ep:?}({v}): {want} vs {got}"
                );
            }
        }
    }

    #[test]
    fn gelu_limits() {
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
        assert_eq!(gelu(0.0), 0.0);
    }

    #[test]
    fn thread_heuristic_keeps_small_problems_serial() {
        assert_eq!(default_threads(0), 1);
        assert_eq!(default_threads(1 << 20), 1);
        let big = default_threads(1 << 30);
        assert!(big >= 1 && big <= MAX_THREADS);
    }
}
