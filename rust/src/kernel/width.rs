//! Element widths for the streamed operand panels.
//!
//! The kernel streams A/B panels at one of three widths — `f32` (the
//! PR-5 baseline), `bf16`, or `f16` — and always accumulates in `f32`.
//! C output stays `f32` at every width. 16-bit panels are produced by
//! *convert-on-pack* ([`super::pack::pack_a16`]): the packer narrows
//! each source element once with round-to-nearest-even, and the lane
//! kernels widen in registers per use. Widening is exact (every 16-bit
//! value is representable in `f32`), so the per-element oracle for a
//! 16-bit width is simply the f32 oracle run over *quantized* inputs
//! (`widen(narrow(x))` per element) — same values, same ascending-K
//! mul-then-add order, bit-identical results.
//!
//! NaN handling: both narrows quiet NaNs (set the quiet bit, keep the
//! sign and the top payload bits). This guarantees packed panels never
//! contain a signaling NaN, so the hardware f16 widen
//! (`_mm256_cvtph_ps`, which quiets sNaNs) and the software widen
//! (payload passthrough) agree bit-for-bit on everything the kernel
//! can ever see.

/// Element width of the streamed A/B panels.
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Width {
    /// 32-bit float panels — the PR-5 baseline path, bit-identical to it.
    #[default]
    F32,
    /// bfloat16: top 16 bits of f32. Widen is a 16-bit left shift.
    Bf16,
    /// IEEE binary16. Widen uses `_mm256_cvtph_ps` when `f16c` is
    /// detected, a bit-identical software conversion otherwise.
    F16,
}

impl Width {
    /// Bytes per streamed panel element. C output is always 4 (f32).
    pub fn bytes(self) -> usize {
        match self {
            Width::F32 => 4,
            Width::Bf16 | Width::F16 => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Width::F32 => "f32",
            Width::Bf16 => "bf16",
            Width::F16 => "f16",
        }
    }

    pub fn parse(s: &str) -> Option<Width> {
        match s {
            "f32" | "fp32" => Some(Width::F32),
            "bf16" | "bfloat16" => Some(Width::Bf16),
            "f16" | "fp16" | "half" => Some(Width::F16),
            _ => None,
        }
    }

    /// Back-compat derivation for pre-width cache entries and APIs that
    /// still speak bytes-per-element: 2 bytes always meant bf16 before
    /// f16 existed, anything else is the f32 baseline.
    pub fn from_bpe(bytes_per_elem: usize) -> Width {
        match bytes_per_elem {
            2 => Width::Bf16,
            _ => Width::F32,
        }
    }

    /// Segment used in tuner-cache composite keys. `4` and `2` are the
    /// historical bpe segments (f32 / bf16 entries round-trip
    /// unchanged); f16 gets a new segment so it never collides.
    pub fn cache_tag(self) -> &'static str {
        match self {
            Width::F32 => "4",
            Width::Bf16 => "2",
            Width::F16 => "2f16",
        }
    }

    pub fn parse_cache_tag(s: &str) -> Option<Width> {
        match s {
            "4" => Some(Width::F32),
            "2" => Some(Width::Bf16),
            "2f16" => Some(Width::F16),
            _ => None,
        }
    }

    pub fn all() -> [Width; 3] {
        [Width::F32, Width::Bf16, Width::F16]
    }

    /// Widths the tuner explores on this host, pruned by CPU feature
    /// detection: f16 is only offered when the `f16c` widen is in
    /// hardware (the scalar fallback stays *correct* everywhere, but a
    /// software-widened f16 lane is never a tuning win).
    pub fn tunable() -> Vec<Width> {
        let mut w = vec![Width::F32, Width::Bf16];
        if super::lane::f16c_available() {
            w.push(Width::F16);
        }
        w
    }

    /// Narrow one f32 to this width's bit pattern (RNE, NaNs quieted).
    /// `F32` is identity on the bottom 16 bits' discard — callers never
    /// narrow on the f32 path; this exists so oracles can be generic.
    pub fn narrow(self, x: f32) -> u16 {
        match self {
            Width::F32 => unreachable!("f32 panels are never narrowed"),
            Width::Bf16 => f32_to_bf16(x),
            Width::F16 => f32_to_f16(x),
        }
    }

    /// Widen one packed element back to f32 (exact).
    pub fn widen(self, h: u16) -> f32 {
        match self {
            Width::F32 => unreachable!("f32 panels are never widened"),
            Width::Bf16 => bf16_to_f32(h),
            Width::F16 => f16_to_f32(h),
        }
    }

    /// `widen(narrow(x))` — the value the kernel actually multiplies
    /// with when streaming at this width. Identity for `F32`.
    pub fn quantize(self, x: f32) -> f32 {
        match self {
            Width::F32 => x,
            _ => self.widen(self.narrow(x)),
        }
    }

    /// Quantize a whole matrix: the per-width oracle input. Running the
    /// per-element f32 reference over `quantize_slice`d operands *is*
    /// the pack→widen→accumulate reference, because narrow∘widen is a
    /// pure per-element function applied exactly once per element.
    pub fn quantize_slice(self, xs: &[f32]) -> Vec<f32> {
        match self {
            Width::F32 => xs.to_vec(),
            _ => xs.iter().map(|&x| self.quantize(x)).collect(),
        }
    }
}

impl std::fmt::Display for Width {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// bf16 → f32: exact, a 16-bit left shift.
#[inline(always)]
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// f32 → bf16 with round-to-nearest-even on bit 16. NaNs are quieted
/// (quiet bit set, sign + top payload preserved) so rounding can never
/// turn a NaN payload into ∞.
#[inline(always)]
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    (bits.wrapping_add(round) >> 16) as u16
}

/// f16 → f32: exact. Subnormals are renormalized; Inf/NaN payloads are
/// carried left-aligned into the f32 mantissa with the quiet bit set,
/// matching what `VCVTPH2PS` produces for every packed value the
/// kernel can see (pack-narrowed NaNs are already quiet).
#[inline(always)]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    let bits = if exp == 0x1F {
        let mut m = man << 13;
        if man != 0 {
            m |= 0x0040_0000;
        }
        sign | 0x7F80_0000 | m
    } else if exp == 0 {
        if man == 0 {
            sign
        } else {
            let mut e32: u32 = 113; // 127 - 15 + 1
            let mut m = man;
            while m & 0x0400 == 0 {
                m <<= 1;
                e32 -= 1;
            }
            sign | (e32 << 23) | ((m & 0x03FF) << 13)
        }
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// f32 → f16 with round-to-nearest-even, overflow to ±∞, gradual
/// underflow through f16 subnormals, NaNs quieted with the top 9
/// payload bits preserved.
#[inline(always)]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        if man == 0 {
            return sign | 0x7C00; // ±∞
        }
        return sign | 0x7E00 | ((man >> 13) as u16 & 0x01FF); // quiet NaN
    }
    let e = exp - 112; // f16-biased exponent
    if e >= 0x1F {
        return sign | 0x7C00; // overflow → ±∞
    }
    if e <= 0 {
        if e < -10 {
            return sign; // below half the smallest subnormal → ±0
        }
        let m = man | 0x0080_0000;
        let shift = (14 - e) as u32;
        let mut hm = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && hm & 1 == 1) {
            hm += 1; // carry into exp 1 (== smallest normal) is correct
        }
        return sign | hm as u16;
    }
    let mut h = ((e as u32) << 10) | (man >> 13);
    let rem = man & 0x1FFF;
    if rem > 0x1000 || (rem == 0x1000 && h & 1 == 1) {
        h += 1; // mantissa carry may bump the exponent, up to ∞ — correct RNE
    }
    sign | h as u16
}

#[cfg(test)]
mod tests {
    use super::*;

    fn is_f16_snan(h: u16) -> bool {
        (h >> 10) & 0x1F == 0x1F && h & 0x03FF != 0 && h & 0x0200 == 0
    }

    fn is_bf16_snan(h: u16) -> bool {
        (h >> 7) & 0xFF == 0xFF && h & 0x7F != 0 && h & 0x0040 == 0
    }

    #[test]
    fn bf16_round_trips_every_bit_pattern() {
        for h in 0..=u16::MAX {
            let back = f32_to_bf16(bf16_to_f32(h));
            if is_bf16_snan(h) {
                assert_eq!(back, h | 0x0040, "sNaN {h:#06x} must quieten only");
            } else {
                assert_eq!(back, h, "bf16 {h:#06x} must round-trip exactly");
            }
        }
    }

    #[test]
    fn f16_round_trips_every_bit_pattern() {
        for h in 0..=u16::MAX {
            let wide = f16_to_f32(h);
            let back = f32_to_f16(wide);
            if is_f16_snan(h) {
                assert!(wide.is_nan() && back & 0x0200 != 0, "sNaN {h:#06x} quietens");
                assert_eq!(back & !0x0200, h & !0x0200, "payload preserved");
            } else {
                assert_eq!(back, h, "f16 {h:#06x} must round-trip exactly");
            }
        }
    }

    #[test]
    fn narrow_rounds_to_nearest_even() {
        // Exactly halfway between two bf16 values: 1.0 + 2^-9 has bit 16
        // set and nothing below — ties to the even neighbour (1.0).
        let tie = f32::from_bits(0x3F80_8000);
        assert_eq!(f32_to_bf16(tie), 0x3F80);
        // Just above the tie rounds up.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F80_8001)), 0x3F81);
        // Odd mantissa ties round up to even.
        assert_eq!(f32_to_bf16(f32::from_bits(0x3F81_8000)), 0x3F82);

        // f16: 1.0 + 2^-11 is halfway, ties to even (1.0).
        assert_eq!(f32_to_f16(f32::from_bits(0x3F80_1000)), 0x3C00);
        assert_eq!(f32_to_f16(f32::from_bits(0x3F80_1001)), 0x3C01);
        assert_eq!(f32_to_f16(f32::from_bits(0x3F80_3000)), 0x3C02);
    }

    #[test]
    fn narrow_handles_overflow_underflow_and_specials() {
        assert_eq!(f32_to_f16(1.0e9), 0x7C00);
        assert_eq!(f32_to_f16(-1.0e9), 0xFC00);
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7C00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xFC00);
        assert_eq!(f32_to_f16(0.0), 0x0000);
        assert_eq!(f32_to_f16(-0.0), 0x8000);
        // Largest f32 rounds to bf16 ∞ (it sits above the bf16 max).
        assert_eq!(f32_to_bf16(f32::MAX), 0x7F80);
        assert_eq!(f32_to_bf16(f32::INFINITY), 0x7F80);
        // Smallest f16 subnormal survives; half of it ties to zero.
        assert_eq!(f16_to_f32(0x0001), 2.0f32.powi(-24));
        assert_eq!(f32_to_f16(2.0f32.powi(-24)), 0x0001);
        assert_eq!(f32_to_f16(2.0f32.powi(-25)), 0x0000);
        assert_eq!(f32_to_f16(2.0f32.powi(-25) * 1.5), 0x0001);
        // NaNs stay NaN and come out quiet.
        let q = f32_to_f16(f32::NAN);
        assert!(f16_to_f32(q).is_nan() && q & 0x0200 != 0);
        let qb = f32_to_bf16(f32::NAN);
        assert!(bf16_to_f32(qb).is_nan() && qb & 0x0040 != 0);
        // An f32 sNaN whose payload lives below bf16's 7 kept bits must
        // not collapse to ∞ — quieting guarantees a NaN comes back.
        let snan = f32::from_bits(0x7F80_0001);
        assert!(bf16_to_f32(f32_to_bf16(snan)).is_nan());
        assert!(f16_to_f32(f32_to_f16(snan)).is_nan());
    }

    #[test]
    fn quantize_is_idempotent_per_width() {
        let mut rng = crate::prop::Rng::new(0x5eed_11);
        for w in [Width::Bf16, Width::F16] {
            for _ in 0..2000 {
                let x = (rng.normal() as f32) * 10.0f32.powi(rng.usize_in(0, 12) as i32 - 6);
                let q = w.quantize(x);
                let qq = w.quantize(q);
                assert_eq!(q.to_bits(), qq.to_bits(), "{w} quantize must be idempotent");
            }
            for x in [0.0, -0.0, f32::INFINITY, f32::NEG_INFINITY, 1e-40, -1e-42] {
                let q = w.quantize(x);
                assert_eq!(q.to_bits(), w.quantize(q).to_bits());
            }
            assert!(w.quantize(f32::NAN).is_nan());
        }
    }

    #[test]
    fn names_tags_and_bpe_round_trip() {
        for w in Width::all() {
            assert_eq!(Width::parse(w.name()), Some(w));
            assert_eq!(Width::parse_cache_tag(w.cache_tag()), Some(w));
        }
        assert_eq!(Width::from_bpe(4), Width::F32);
        assert_eq!(Width::from_bpe(2), Width::Bf16);
        assert_eq!(Width::F32.bytes(), 4);
        assert_eq!(Width::Bf16.bytes(), 2);
        assert_eq!(Width::F16.bytes(), 2);
        assert_eq!(Width::parse("half"), Some(Width::F16));
        assert_eq!(Width::parse("i8"), None);
        let t = Width::tunable();
        assert!(t.contains(&Width::F32) && t.contains(&Width::Bf16));
    }
}
