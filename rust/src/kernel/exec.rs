//! Per-work-item dispatch of a flattened Stream-K schedule over host
//! data, plus the blocked dense [`matmul`] the MLP interpreter path
//! uses.
//!
//! [`ExecDesc`] is the precomputed form of "what does each
//! [`FlatSchedule`] work item touch": clamped tile origins, the
//! contiguous valid-K column range (the per-element executor's
//! `>=`-mask plus edge clamp collapse to one `[kc0, kc1)` interval per
//! segment), partial-slot routing, and the fixup contributor → work-item
//! index arena. Plans cache it ([`crate::plan::Plan::exec`]) so the
//! serving hot path never recomputes a descriptor.
//!
//! Execution is three deterministic passes:
//!
//! 1. **compute** — every work item accumulates its tile slice into a
//!    private accumulator via pack + microkernel; items are independent,
//!    so they fan out over [`crate::exec::scope_map_with`] (each
//!    worker reuses one [`PackBuf`]). Results are identical for every
//!    thread count because nothing is shared.
//! 2. **store** — direct stores are applied *in the reference's serial
//!    order* (CU-major: DP quota, then segments). Clamped edge tiles
//!    overlap their neighbours, so store order is part of the
//!    bit-identical contract and is never raced.
//! 3. **fixup** — split tiles sum their contributors in k-ascending
//!    contributor order (the deterministic fixup-ordered reduction),
//!    then store.
//!
//! The [`Epilogue`] hook runs inside the stores of passes 2–3, exactly
//! once per output element.

use super::micro::{block_update, KC};
use super::pack::{pack_a, pack_b, PackBuf};
use super::{default_threads, Epilogue};
use crate::decomp::{BlockShape, FlatSchedule, GemmShape};
use crate::exec::scope_map_with;

/// Where one work item's accumulator goes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dest {
    /// Full-K coverage: store straight into C (direct tile / segment).
    Store,
    /// Partial K segment: becomes partial buffer `(cu, slot)`, summed by
    /// the fixup pass.
    Partial { cu: usize, slot: usize },
}

/// One work item, fully resolved: which C tile, which A/B slices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileJob {
    pub tile: usize,
    /// Clamped tile origin rows/cols (the kernel's edge addressing:
    /// `min(tm·BM, M−BM)`).
    pub r0: usize,
    pub c0: usize,
    /// Contiguous valid K columns `[kc0, kc1)` — the union of the
    /// segment's BK-deep steps after the nopad `>=`-mask.
    pub kc0: usize,
    pub kc1: usize,
    pub dest: Dest,
}

/// One fixup tile: origin plus its contributor range in
/// [`ExecDesc::sources`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixupTile {
    pub tile: usize,
    pub r0: usize,
    pub c0: usize,
    pub src_start: usize,
    pub src_end: usize,
}

/// Precomputed per-work-item tile descriptors for one flat schedule —
/// everything the dispatcher needs, allocation-free at execute time
/// (modulo the per-item accumulators the reference also allocated).
#[derive(Debug, Clone, PartialEq)]
pub struct ExecDesc {
    pub shape: GemmShape,
    pub block: BlockShape,
    /// Phase-1 work items in the reference's serial store order
    /// (CU-major; per CU: DP quota then SK segments).
    pub jobs: Vec<TileJob>,
    /// Split tiles in ascending tile order (the fixup pass order).
    pub fixup: Vec<FixupTile>,
    /// Contributor → phase-1 job index, in fixup-sum order.
    pub sources: Vec<usize>,
    /// Total MAC-FLOPs (drives the parallel/serial heuristic).
    pub macs: u64,
}

impl ExecDesc {
    /// Resolve every work item of `flat` against `shape`/`block`.
    /// `block` must be the (effective) block the schedule was built
    /// with — the same contract as the per-element executor.
    pub fn new(shape: GemmShape, block: BlockShape, flat: &FlatSchedule) -> Self {
        let (m, n, k) = (shape.m, shape.n, shape.k);
        let (bm, bn, bk) = (block.bm, block.bn, block.bk);
        let ipt = flat.grid.iters_per_tile;
        let origin = |tile: usize| -> (usize, usize) {
            let (tm, tn) = flat.grid.tile_rc(tile);
            (
                (tm * bm).min(m.saturating_sub(bm)),
                (tn * bn).min(n.saturating_sub(bn)),
            )
        };

        let mut jobs = Vec::with_capacity(flat.num_items());
        // (cu, slot) → phase-1 job index; the reference's two-slot
        // partial buffer, as indices (last write wins, like the buffer).
        let mut partial_job = vec![usize::MAX; flat.p * 2];
        let mut macs = 0u64;
        for cu in 0..flat.p {
            for tile in flat.direct_tiles(cu) {
                let (r0, c0) = origin(tile);
                let kc1 = k.min(ipt * bk);
                macs += 2 * (bm * bn * kc1) as u64;
                jobs.push(TileJob { tile, r0, c0, kc0: 0, kc1, dest: Dest::Store });
            }
            for seg in flat.cu_segments(cu) {
                let (r0, c0) = origin(seg.tile);
                // Clamp both ends: a (deliberately broken) schedule may
                // carry a segment past K — the per-element reference
                // masks every column of it out, i.e. an empty range.
                let kc0 = (seg.k_start * bk).min(k);
                let kc1 = k.min((seg.k_start + seg.k_len) * bk).max(kc0);
                let dest = if seg.direct {
                    Dest::Store
                } else {
                    partial_job[cu * 2 + seg.slot] = jobs.len();
                    Dest::Partial { cu, slot: seg.slot }
                };
                macs += 2 * (bm * bn * (kc1 - kc0)) as u64;
                jobs.push(TileJob { tile: seg.tile, r0, c0, kc0, kc1, dest });
            }
        }

        let mut fixup = Vec::with_capacity(flat.split_tiles.len());
        let mut sources = Vec::new();
        for (i, &tile) in flat.split_tiles.iter().enumerate() {
            let (r0, c0) = origin(tile);
            let src_start = sources.len();
            for cb in flat.tile_contributors(i) {
                // usize::MAX marks a contributor whose (cu, slot) no
                // partial segment wrote — possible only in broken
                // (fault-injected) schedules. The reference reads the
                // zero-initialized partials buffer there (a no-op add);
                // the dispatcher skips the sentinel to match.
                sources.push(partial_job[cb.cu * 2 + cb.slot]);
            }
            fixup.push(FixupTile {
                tile,
                r0,
                c0,
                src_start,
                src_end: sources.len(),
            });
        }

        Self { shape, block, jobs, fixup, sources, macs }
    }
}

/// Execute a described schedule over row-major f32 slices; worker count
/// chosen from the problem size. See [`execute_threads`].
pub fn execute(
    a: &[f32],
    b: &[f32],
    desc: &ExecDesc,
    epilogue: Epilogue,
) -> Vec<f32> {
    execute_threads(a, b, desc, epilogue, default_threads(desc.macs))
}

/// How many work items are computed in parallel before their direct
/// stores drain — bounds the transient accumulator memory at
/// `WINDOW × BM × BN` f32 (8 MiB at the 128-wide default blocks)
/// instead of one accumulator per work item for the whole run.
const WINDOW: usize = 128;

/// Execute with an explicit worker count (benches / determinism tests).
/// Output is bit-identical for every `threads` value.
pub fn execute_threads(
    a: &[f32],
    b: &[f32],
    desc: &ExecDesc,
    epilogue: Epilogue,
    threads: usize,
) -> Vec<f32> {
    let GemmShape { m, n, k } = desc.shape;
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    let (bm, bn) = (desc.block.bm, desc.block.bn);
    let mut c = vec![0.0f32; m * n];
    // Partial-segment accumulators (the reference's two-slot-per-CU
    // buffer), kept alive until the fixup pass; direct accumulators
    // drain window by window.
    let mut partial_accs: Vec<Option<Vec<f32>>> = vec![None; desc.jobs.len()];

    // Passes 1+2, windowed: compute a window of independent work items
    // in parallel, then apply its stores in the reference's serial
    // order. Windows ascend in job order, so the overall store order is
    // exactly the reference's.
    let mut start = 0;
    while start < desc.jobs.len() {
        let end = (start + WINDOW).min(desc.jobs.len());
        let accs: Vec<Vec<f32>> = scope_map_with(
            threads,
            &desc.jobs[start..end],
            PackBuf::new,
            |buf, _, job| compute_job(a, b, k, n, bm, bn, job, buf),
        );
        for (off, acc) in accs.into_iter().enumerate() {
            let job = &desc.jobs[start + off];
            match job.dest {
                Dest::Store => store_tile(
                    &mut c, n, job.r0, job.c0, bm, bn, &acc, epilogue,
                ),
                Dest::Partial { .. } => {
                    partial_accs[start + off] = Some(acc);
                }
            }
        }
        start = end;
    }

    // Pass 3: fixup-ordered reduction of partial K segments.
    let mut facc = vec![0.0f32; bm * bn];
    for ft in &desc.fixup {
        facc.iter_mut().for_each(|v| *v = 0.0);
        for &src in &desc.sources[ft.src_start..ft.src_end] {
            if src == usize::MAX {
                continue; // unwritten partial slot == all-zero buffer
            }
            let Some(frag) = partial_accs[src].as_ref() else {
                continue; // ditto: slot declared but never produced
            };
            for (d, s) in facc.iter_mut().zip(frag) {
                *d += *s;
            }
        }
        store_tile(&mut c, n, ft.r0, ft.c0, bm, bn, &facc, epilogue);
    }
    c
}

/// Accumulate one work item: stream its K range in cache-sized chunks
/// through pack + microkernel. K chunks ascend, so per-element FP order
/// matches the reference exactly.
#[allow(clippy::too_many_arguments)]
fn compute_job(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    bm: usize,
    bn: usize,
    job: &TileJob,
    buf: &mut PackBuf,
) -> Vec<f32> {
    let mut acc = vec![0.0f32; bm * bn];
    let mut kc = job.kc0;
    while kc < job.kc1 {
        let kv = KC.min(job.kc1 - kc);
        pack_a(&mut buf.a, a, k, job.r0, bm, kc, kv);
        pack_b(&mut buf.b, b, n, job.c0, bn, kc, kv);
        block_update(&buf.a, &buf.b, bm, bn, kv, &mut acc);
        kc += kv;
    }
    acc
}

/// Store one `bm × bn` accumulator into C at its clamped origin, with
/// the epilogue fused in.
#[allow(clippy::too_many_arguments)]
fn store_tile(
    c: &mut [f32],
    n: usize,
    r0: usize,
    c0: usize,
    bm: usize,
    bn: usize,
    acc: &[f32],
    epilogue: Epilogue,
) {
    for r in 0..bm {
        let at = (r0 + r) * n + c0;
        let row = &mut c[at..at + bn];
        let src = &acc[r * bn..(r + 1) * bn];
        if epilogue == Epilogue::None {
            row.copy_from_slice(src);
        } else {
            for (d, &s) in row.iter_mut().zip(src) {
                *d = epilogue.apply(s);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Dense blocked matmul — the interpreter's plain-gemm / MLP path
// ---------------------------------------------------------------------

/// Row-major `C[m,n] = A[m,k] · B[k,n]` through the same K-chunked
/// microkernel, parallel over row panels. Bit-identical to the naive
/// triple loop *without* zero-skip (K ascends per element; `0·Inf`
/// stays NaN), independent of thread count. Workers accumulate straight
/// into disjoint row panels of the one output buffer — no per-panel
/// staging, no final gather copy.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    let mut c = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    // Rows per panel: big enough to amortize dispatch, small enough to
    // split MLP batches across workers.
    const RB: usize = 32;
    let threads =
        default_threads(2 * (m * n) as u64 * k as u64).min(m.div_ceil(RB));
    if threads <= 1 {
        let mut buf = PackBuf::new();
        for (i, panel) in c.chunks_mut(RB * n).enumerate() {
            matmul_panel(a, b, k, n, i * RB, panel, &mut buf);
        }
        return c;
    }
    // Round-robin the row panels over scoped workers: panels are
    // uniform, so static assignment balances and every worker writes
    // its own disjoint slices of C.
    let mut per_worker: Vec<Vec<(usize, &mut [f32])>> =
        (0..threads).map(|_| Vec::new()).collect();
    for (i, panel) in c.chunks_mut(RB * n).enumerate() {
        per_worker[i % threads].push((i * RB, panel));
    }
    std::thread::scope(|scope| {
        for work in per_worker {
            scope.spawn(move || {
                let mut buf = PackBuf::new();
                for (r0, panel) in work {
                    matmul_panel(a, b, k, n, r0, panel, &mut buf);
                }
            });
        }
    });
    c
}

/// Accumulate one row panel of C (`out` holds `out.len() / n` rows
/// starting at row `r0`, zero-initialized) in ascending K chunks.
fn matmul_panel(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    r0: usize,
    out: &mut [f32],
    buf: &mut PackBuf,
) {
    let rows = out.len() / n;
    let mut kc = 0;
    while kc < k {
        let kv = KC.min(k - kc);
        pack_a(&mut buf.a, a, k, r0, rows, kc, kv);
        // B rows are already contiguous at full width: no pack.
        block_update(&buf.a, &b[kc * n..(kc + kv) * n], rows, n, kv, out);
        kc += kv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{build_schedule, BlockShape, GemmShape};
    use crate::faults::{execute_flat_ref, Matrix};
    use crate::prop;

    fn bits_equal(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "{what}: elem {i}: {g} vs {w}"
            );
        }
    }

    fn flat_of(
        m: usize,
        n: usize,
        k: usize,
        p: usize,
        block: BlockShape,
    ) -> (GemmShape, crate::decomp::FlatSchedule, BlockShape) {
        let shape = GemmShape::new(m, n, k);
        let s = build_schedule(shape, block, p).unwrap();
        (shape, crate::decomp::FlatSchedule::from_schedule(&s), s.block)
    }

    #[test]
    fn blocked_matches_reference_bitwise_on_fixed_shapes() {
        for (m, n, k, p) in [
            (96usize, 102usize, 100usize, 12usize), // ragged hybrid
            (3, 9, 9, 120),                         // tiny, idle CUs
            (48, 64, 80, 1),                        // serial
            (64, 64, 64, 7),                        // aligned, odd CUs
            (60, 64, 64, 120),                      // deep multi-way splits
        ] {
            let mut rng = prop::Rng::new((m * 5 + n + k * 3 + p) as u64);
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let (shape, flat, block) =
                flat_of(m, n, k, p, BlockShape::new(16, 16, 8));
            let want = execute_flat_ref(&a.data, &b.data, shape, &flat, block);
            let desc = ExecDesc::new(shape, block, &flat);
            for threads in [1usize, 4] {
                let got = execute_threads(
                    &a.data,
                    &b.data,
                    &desc,
                    Epilogue::None,
                    threads,
                );
                bits_equal(
                    &got,
                    &want,
                    &format!("{m}x{n}x{k} p={p} threads={threads}"),
                );
            }
        }
    }

    /// Satellite acceptance: blocked execution is bit-identical to the
    /// per-element reference over random shapes/blocks/CU counts with
    /// NaN/∞ inputs and fixup-segment reduction exercised.
    #[test]
    fn prop_blocked_bit_identical_including_non_finite() {
        prop::check("blocked == per-element reference (bitwise)", 40, |rng| {
            let m = rng.usize_in(1, 150);
            let n = rng.usize_in(1, 150);
            let k = rng.usize_in(1, 150);
            let p = *rng.choose(&[1usize, 3, 16, 120]);
            let bm = *rng.choose(&[8usize, 16, 33]);
            let bn = *rng.choose(&[8usize, 16, 33]);
            let bk = *rng.choose(&[2usize, 8, 16]);
            let mut a = Matrix::random(m, k, rng);
            let b = Matrix::random(k, n, rng);
            // Seed non-finite values: NaN propagation is part of the
            // contract (no zero-skip anywhere).
            for _ in 0..rng.usize_in(0, 4) {
                let at = rng.usize_in(0, m * k - 1);
                a.data[at] =
                    *rng.choose(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
            }
            let (shape, flat, block) =
                flat_of(m, n, k, p, BlockShape::new(bm, bn, bk));
            let want =
                execute_flat_ref(&a.data, &b.data, shape, &flat, block);
            let desc = ExecDesc::new(shape, block, &flat);
            let threads = *rng.choose(&[1usize, 2, 5]);
            let got = execute_threads(
                &a.data,
                &b.data,
                &desc,
                Epilogue::None,
                threads,
            );
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                if g.to_bits() != w.to_bits() {
                    return Err(format!(
                        "{m}x{n}x{k} p={p} block {bm}x{bn}x{bk} \
                         threads={threads} elem {i}: {g:?} vs {w:?}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn fixup_reduction_is_contributor_ordered() {
        // 60x64x64 with a 16x16x2 block on 120 CUs has >= 3-way split
        // tiles (the medium-matrix-bug regime): the fixup sum order is
        // observable in FP, so bit-equality proves the reduction runs
        // in contributor order.
        let (shape, flat, block) =
            flat_of(60, 64, 64, 120, BlockShape::new(16, 16, 2));
        assert!(
            flat.contributors.len() >= 3,
            "case must exercise multi-way fixups"
        );
        let mut rng = prop::Rng::new(123);
        let a = Matrix::random(60, 64, &mut rng);
        let b = Matrix::random(64, 64, &mut rng);
        let want = execute_flat_ref(&a.data, &b.data, shape, &flat, block);
        let desc = ExecDesc::new(shape, block, &flat);
        for threads in [1usize, 3, 8] {
            let got =
                execute_threads(&a.data, &b.data, &desc, Epilogue::None, threads);
            bits_equal(&got, &want, &format!("threads={threads}"));
        }
    }

    #[test]
    fn epilogue_fuses_at_store_only() {
        // relu at the store == relu over the final C; partials must not
        // be clamped before the fixup sum (negative partials + positive
        // partials can produce positive finals).
        let (shape, flat, block) =
            flat_of(60, 64, 64, 120, BlockShape::new(16, 16, 2));
        let mut rng = prop::Rng::new(5);
        let a = Matrix::random(60, 64, &mut rng);
        let b = Matrix::random(64, 64, &mut rng);
        let desc = ExecDesc::new(shape, block, &flat);
        let plain = execute(&a.data, &b.data, &desc, Epilogue::None);
        let fused = execute(&a.data, &b.data, &desc, Epilogue::Relu);
        let mut post = plain;
        Epilogue::Relu.apply_slice(&mut post);
        bits_equal(&fused, &post, "fused relu");
        assert!(fused.iter().any(|&v| v > 0.0), "case must be non-trivial");
    }

    #[test]
    fn descriptor_k_ranges_cover_the_mask_exactly() {
        // Ragged K: 100 with bk=8 -> last step holds 4 valid columns.
        let (shape, flat, block) =
            flat_of(96, 102, 100, 12, BlockShape::new(16, 16, 8));
        let desc = ExecDesc::new(shape, block, &flat);
        assert_eq!(desc.shape, shape);
        for job in &desc.jobs {
            assert!(job.kc0 < job.kc1, "empty K range");
            assert!(job.kc1 <= shape.k, "mask violated: {job:?}");
            assert!(job.r0 + block.bm <= shape.m);
            assert!(job.c0 + block.bn <= shape.n);
        }
        // every partial referenced by the fixup arena resolves
        for &src in &desc.sources {
            assert!(matches!(desc.jobs[src].dest, Dest::Partial { .. }));
        }
        assert!(desc.macs > 0);
    }

    #[test]
    fn matmul_matches_naive_order_bitwise() {
        let mut rng = prop::Rng::new(11);
        for (m, k, n) in [(1usize, 1usize, 1usize), (5, 7, 3), (33, 40, 65)] {
            let a = rng.normal_f32_vec(m * k);
            let b = rng.normal_f32_vec(k * n);
            // naive k-ascending reference, no zero-skip
            let mut want = vec![0.0f32; m * n];
            for i in 0..m {
                for l in 0..k {
                    let av = a[i * k + l];
                    for j in 0..n {
                        want[i * n + j] += av * b[l * n + j];
                    }
                }
            }
            let got = matmul(&a, &b, m, k, n);
            bits_equal(&got, &want, &format!("{m}x{k}x{n}"));
        }
    }

    #[test]
    fn matmul_propagates_non_finite() {
        let a = vec![f32::INFINITY, 0.0];
        let b = vec![0.0, 0.0]; // 1x2 @ 2x1: Inf*0 + 0*0 = NaN
        let got = matmul(&a, &b, 1, 2, 1);
        assert!(got[0].is_nan());
        assert!(matmul(&[], &[], 0, 0, 4).is_empty());
        assert_eq!(matmul(&[], &[], 2, 0, 2), vec![0.0; 4]);
    }
}
