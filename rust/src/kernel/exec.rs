//! Per-work-item dispatch of a flattened Stream-K schedule over host
//! data, plus the blocked dense [`matmul`] the MLP interpreter path
//! uses.
//!
//! [`ExecDesc`] is the precomputed form of "what does each
//! [`FlatSchedule`] work item touch": clamped tile origins, the
//! contiguous valid-K column range (the per-element executor's
//! `>=`-mask plus edge clamp collapse to one `[kc0, kc1)` interval per
//! segment), partial-slot routing, the fixup contributor → work-item
//! index arena — and the **tile-ownership class** of every store. Plans
//! cache it lazily ([`crate::plan::Plan::exec`]) so the serving hot
//! path never recomputes a descriptor.
//!
//! ## Ownership: who may stream and who must stay ordered
//!
//! A store job *owns* its output tile when no other store in the whole
//! run touches any element of its `BM × BN` region: the tile is written
//! exactly once (one direct store, no fixup on the same tile id — also
//! true under fault-injected duplicate writes, which are counted), and
//! it is not involved in clamped-edge overlap (when a dimension is
//! ragged, the *last* tile row/column is clamped back onto the
//! *second-to-last* one, so both stay out of the owned class). Owned
//! tiles are the common aligned case — on grid-aligned Table-1 shapes
//! that is every direct store.
//!
//! Execution is then:
//!
//! 0. **direct-store streaming** — owned work items compute *and store*
//!    in the worker threads: each worker reuses one accumulator + one
//!    [`PackBuf`] and writes its finished tile straight into C (the
//!    region is exclusively its own, so no ordering and no staging
//!    arena exist for these items). Because each owned element is
//!    written exactly once in the whole run, when it is written cannot
//!    change the final bits.
//! 1. **compute** — the remaining work items accumulate into private
//!    accumulators via pack + microkernel, windowed so at most
//!    `WINDOW × BM × BN` transient floats are in flight.
//! 2. **store** — their direct stores apply *in the reference's serial
//!    order* (CU-major: DP quota, then segments). Clamped edge tiles
//!    overlap their neighbours, so store order is part of the
//!    bit-identical contract here and is never raced.
//! 3. **fixup** — split tiles sum their contributors in k-ascending
//!    contributor order (the deterministic fixup-ordered reduction),
//!    then store.
//!
//! The [`Epilogue`] hook runs inside the stores of passes 0 and 2–3,
//! exactly once per output element. The microkernel lanes
//! ([`super::lane`]) and the dispatcher mode are selectable through
//! [`ExecOpts`]; the bench pins the PR-4 configuration (scalar lanes,
//! everything windowed) as its baseline.

use super::lane::{self, LaneBackend, RegBlock};
use super::micro::{block_update_w, block_update_with, KC};
use super::pack::{pack_a, pack_a16, pack_b, pack_b16, PackBuf};
use super::width::Width;
use super::{default_threads, Epilogue};
use crate::decomp::{BlockShape, FlatSchedule, GemmShape};
use crate::exec::scope_map_with;
use crate::trace;
use std::sync::atomic::Ordering;
use std::time::Instant;

/// Where one work item's accumulator goes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dest {
    /// Full-K coverage: store straight into C (direct tile / segment).
    Store,
    /// Partial K segment: becomes partial buffer `(cu, slot)`, summed by
    /// the fixup pass.
    Partial { cu: usize, slot: usize },
}

/// One work item, fully resolved: which C tile, which A/B slices.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TileJob {
    pub tile: usize,
    /// Clamped tile origin rows/cols (the kernel's edge addressing:
    /// `min(tm·BM, M−BM)`).
    pub r0: usize,
    pub c0: usize,
    /// Contiguous valid K columns `[kc0, kc1)` — the union of the
    /// segment's BK-deep steps after the nopad `>=`-mask.
    pub kc0: usize,
    pub kc1: usize,
    pub dest: Dest,
    /// Tile-ownership class: `true` when this store is the *only* write
    /// into its C region for the whole run (unclamped, overlap-free,
    /// single-writer), so the dispatcher may stream it in place from
    /// the worker thread. Always `false` for [`Dest::Partial`].
    pub owned: bool,
}

/// One fixup tile: origin plus its contributor range in
/// [`ExecDesc::sources`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FixupTile {
    pub tile: usize,
    pub r0: usize,
    pub c0: usize,
    pub src_start: usize,
    pub src_end: usize,
}

/// Precomputed per-work-item tile descriptors for one flat schedule —
/// everything the dispatcher needs, allocation-free at execute time
/// (modulo the per-item accumulators the reference also allocated).
#[derive(Debug, Clone, PartialEq)]
pub struct ExecDesc {
    pub shape: GemmShape,
    pub block: BlockShape,
    /// K-chunk length the dispatcher packs panels at
    /// ([`crate::decomp::params::KC_DEFAULT`] unless overridden via
    /// [`Self::with_kc`]). Chunk boundaries never change numerics.
    pub kc: usize,
    /// Element width the A/B panels stream at ([`Width::F32`] unless
    /// overridden via [`Self::with_width`] — [`crate::plan::Plan::exec`]
    /// threads its key's width here). 16-bit widths pack through the
    /// convert-on-pack path and widen in registers; accumulation and C
    /// stay f32, and per-width results are bit-identical to the
    /// per-element oracle over quantized inputs.
    pub width: Width,
    /// Phase-1 work items in the reference's serial store order
    /// (CU-major; per CU: DP quota then SK segments).
    pub jobs: Vec<TileJob>,
    /// Split tiles in ascending tile order (the fixup pass order).
    pub fixup: Vec<FixupTile>,
    /// Contributor → phase-1 job index, in fixup-sum order.
    pub sources: Vec<usize>,
    /// Total MAC-FLOPs (drives the parallel/serial heuristic).
    pub macs: u64,
}

impl ExecDesc {
    /// Resolve every work item of `flat` against `shape`/`block`.
    /// `block` must be the (effective) block the schedule was built
    /// with — the same contract as the per-element executor.
    pub fn new(shape: GemmShape, block: BlockShape, flat: &FlatSchedule) -> Self {
        let (m, n, k) = (shape.m, shape.n, shape.k);
        let (bm, bn, bk) = (block.bm, block.bn, block.bk);
        let ipt = flat.grid.iters_per_tile;
        let origin = |tile: usize| -> (usize, usize) {
            let (tm, tn) = flat.grid.tile_rc(tile);
            (
                (tm * bm).min(m.saturating_sub(bm)),
                (tn * bn).min(n.saturating_sub(bn)),
            )
        };

        let mut jobs = Vec::with_capacity(flat.num_items());
        // (cu, slot) → phase-1 job index; the reference's two-slot
        // partial buffer, as indices (last write wins, like the buffer).
        let mut partial_job = vec![usize::MAX; flat.p * 2];
        let mut macs = 0u64;
        for cu in 0..flat.p {
            for tile in flat.direct_tiles(cu) {
                let (r0, c0) = origin(tile);
                let kc1 = k.min(ipt * bk);
                macs += 2 * (bm * bn * kc1) as u64;
                jobs.push(TileJob {
                    tile,
                    r0,
                    c0,
                    kc0: 0,
                    kc1,
                    dest: Dest::Store,
                    owned: false,
                });
            }
            for seg in flat.cu_segments(cu) {
                let (r0, c0) = origin(seg.tile);
                // Clamp both ends: a (deliberately broken) schedule may
                // carry a segment past K — the per-element reference
                // masks every column of it out, i.e. an empty range.
                let kc0 = (seg.k_start * bk).min(k);
                let kc1 = k.min((seg.k_start + seg.k_len) * bk).max(kc0);
                let dest = if seg.direct {
                    Dest::Store
                } else {
                    partial_job[cu * 2 + seg.slot] = jobs.len();
                    Dest::Partial { cu, slot: seg.slot }
                };
                macs += 2 * (bm * bn * (kc1 - kc0)) as u64;
                jobs.push(TileJob {
                    tile: seg.tile,
                    r0,
                    c0,
                    kc0,
                    kc1,
                    dest,
                    owned: false,
                });
            }
        }

        let mut fixup = Vec::with_capacity(flat.split_tiles.len());
        let mut sources = Vec::new();
        for (i, &tile) in flat.split_tiles.iter().enumerate() {
            let (r0, c0) = origin(tile);
            let src_start = sources.len();
            for cb in flat.tile_contributors(i) {
                // usize::MAX marks a contributor whose (cu, slot) no
                // partial segment wrote — possible only in broken
                // (fault-injected) schedules. The reference reads the
                // zero-initialized partials buffer there (a no-op add);
                // the dispatcher skips the sentinel to match.
                sources.push(partial_job[cb.cu * 2 + cb.slot]);
            }
            fixup.push(FixupTile {
                tile,
                r0,
                c0,
                src_start,
                src_end: sources.len(),
            });
        }

        // Tile-ownership analysis. A store may stream in place iff its
        // region is written exactly once in the whole run:
        // - single-writer by tile id (duplicate direct stores or a
        //   fixup on the same tile — both possible in fault-injected
        //   schedules — keep the ordered path);
        // - no clamped-edge overlap: when a dimension is ragged the
        //   last tile row/col is clamped back over the second-to-last,
        //   so both stay ordered. Tiles outside the grid (broken
        //   schedules) are never owned.
        let grid = flat.grid;
        let mut store_writes = vec![0u8; grid.num_tiles()];
        // An out-of-grid tile id (broken schedules) clamps onto the
        // last in-grid row's region via `origin`, so its write is
        // booked against that aliased tile — otherwise the aliased
        // tile could stream while the corrupt store races it.
        let count_tile = |tile: usize| -> Option<usize> {
            if grid.num_tiles() == 0 {
                return None;
            }
            if tile < grid.num_tiles() {
                return Some(tile);
            }
            let (tm, tn) = grid.tile_rc(tile);
            Some(tm.min(grid.tiles_m - 1) * grid.tiles_n + tn)
        };
        for job in &jobs {
            if matches!(job.dest, Dest::Store) {
                if let Some(t) = count_tile(job.tile) {
                    store_writes[t] = store_writes[t].saturating_add(1);
                }
            }
        }
        for &tile in &flat.split_tiles {
            if let Some(t) = count_tile(tile) {
                store_writes[t] = store_writes[t].saturating_add(1);
            }
        }
        let rows_ragged = grid.tiles_m * bm != m;
        let cols_ragged = grid.tiles_n * bn != n;
        for job in &mut jobs {
            if !matches!(job.dest, Dest::Store) {
                continue;
            }
            let (tm, tn) = grid.tile_rc(job.tile);
            let row_safe = !rows_ragged || tm + 2 < grid.tiles_m;
            let col_safe = !cols_ragged || tn + 2 < grid.tiles_n;
            let single =
                store_writes.get(job.tile).is_some_and(|&w| w == 1);
            job.owned = single && row_safe && col_safe;
        }

        Self {
            shape,
            block,
            kc: KC,
            width: Width::F32,
            jobs,
            fixup,
            sources,
            macs,
        }
    }

    /// Override the K-chunk length (the tuner's KC axis); clamped to ≥1.
    pub fn with_kc(mut self, kc: usize) -> Self {
        self.kc = kc.max(1);
        self
    }

    /// Override the panel element width (the tuner's width axis).
    pub fn with_width(mut self, width: Width) -> Self {
        self.width = width;
        self
    }

    /// Per-class work-item counts:
    /// `(owned direct-store, ordered store, partial)`.
    pub fn class_counts(&self) -> (usize, usize, usize) {
        let owned = self.jobs.iter().filter(|j| j.owned).count();
        let partial = self
            .jobs
            .iter()
            .filter(|j| matches!(j.dest, Dest::Partial { .. }))
            .count();
        (owned, self.jobs.len() - owned - partial, partial)
    }
}

/// Dispatcher knobs. Production paths use [`ExecOpts::auto`] (detected
/// SIMD lanes, direct-store streaming on); the bench pins the PR-4
/// configuration (scalar lanes, everything windowed) as its baseline,
/// and the identity tests sweep both axes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecOpts {
    /// Microkernel lane backend.
    pub backend: LaneBackend,
    /// Stream owned tiles straight into C from the compute workers
    /// (`false` ⇒ every store goes through the windowed ordered path).
    pub direct_store: bool,
    pub threads: usize,
    /// Per-call K-chunk override — the serving path threads the
    /// tuner-cached `kc` here so a shared (plan-cache) descriptor can
    /// execute at a tuned chunk length without being cloned. `None`
    /// uses [`ExecDesc::kc`]. Chunk length never changes output bits
    /// (`kc_chunking_never_changes_bits`).
    pub kc: Option<usize>,
    /// Register-block override for 16-bit widths (the tuner's per-width
    /// MR/NR axis; [`RegBlock::BASE`] when `None`). Ignored on the f32
    /// path, which is pinned to the PR-5 `4×8` block. Like `kc`, the
    /// block shape never changes output bits.
    pub reg: Option<RegBlock>,
}

impl ExecOpts {
    /// The serving configuration for `macs` MAC-FLOPs of work.
    pub fn auto(macs: u64) -> Self {
        Self {
            backend: lane::active(),
            direct_store: true,
            threads: default_threads(macs),
            kc: None,
            reg: None,
        }
    }
}

/// Execute a described schedule over row-major f32 slices; worker count
/// chosen from the problem size. See [`execute_opts`].
pub fn execute(
    a: &[f32],
    b: &[f32],
    desc: &ExecDesc,
    epilogue: Epilogue,
) -> Vec<f32> {
    execute_opts(a, b, desc, epilogue, &ExecOpts::auto(desc.macs))
}

/// How many non-owned work items are computed in parallel before their
/// ordered stores drain — bounds the transient accumulator memory at
/// `WINDOW × BM × BN` f32 (8 MiB at the 128-wide default blocks)
/// instead of one accumulator per work item for the whole run. Owned
/// items never enter the window: they stream through per-worker scratch.
const WINDOW: usize = 128;

/// Execute with an explicit worker count (benches / determinism tests).
/// Output is bit-identical for every `threads` value.
pub fn execute_threads(
    a: &[f32],
    b: &[f32],
    desc: &ExecDesc,
    epilogue: Epilogue,
    threads: usize,
) -> Vec<f32> {
    execute_opts(
        a,
        b,
        desc,
        epilogue,
        &ExecOpts { threads, ..ExecOpts::auto(desc.macs) },
    )
}

/// Raw C base pointer shared by the owned-store workers. Safety rests
/// on the ownership analysis: every owned job writes a disjoint region
/// of C, and no reference to C is alive while the workers run.
#[derive(Clone, Copy)]
struct SyncPtr(*mut f32);
unsafe impl Send for SyncPtr {}
unsafe impl Sync for SyncPtr {}

/// Per-worker state of the streaming pass: pack scratch plus one
/// reusable accumulator (no per-job allocation).
#[derive(Default)]
struct OwnedState {
    buf: PackBuf,
    acc: Vec<f32>,
}

/// Execute with explicit dispatcher options. Output is bit-identical
/// across every `(backend, direct_store, threads)` combination.
pub fn execute_opts(
    a: &[f32],
    b: &[f32],
    desc: &ExecDesc,
    epilogue: Epilogue,
    opts: &ExecOpts,
) -> Vec<f32> {
    let GemmShape { m, n, k } = desc.shape;
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    let (bm, bn) = (desc.block.bm, desc.block.bn);
    let threads = opts.threads.max(1);
    let backend = opts.backend;
    let kc = opts.kc.unwrap_or(desc.kc).max(1);
    let width = desc.width;
    let reg = opts.reg.unwrap_or(RegBlock::BASE);
    let mut c = vec![0.0f32; m * n];
    // Partial-segment accumulators (the reference's two-slot-per-CU
    // buffer), indexed by original job id, kept alive until the fixup
    // pass; non-owned direct accumulators drain window by window.
    let mut partial_accs: Vec<Option<Vec<f32>>> = vec![None; desc.jobs.len()];

    // Roofline attribution: disabled is one relaxed load plus `Option`
    // branches (held to the same ≤1% gate as the span hook); enabled,
    // workers bump the shared counters and the dispatching thread times
    // each pass — the passes run sequentially here, so their sum is the
    // accounted share of the dispatch wall time.
    let prof = trace::profile::enabled();
    let counters = prof.then(trace::profile::DispatchCounters::default);
    let ctr = counters.as_ref();
    let mut times = trace::profile::PassTimes::default();
    let t_all = prof.then(Instant::now);

    // Pass 0: owned tiles stream straight into C from the workers — no
    // staging arena, no ordered drain. Each owned element is written
    // exactly once in the whole run, so timing cannot change the bits.
    let t_pass = prof.then(Instant::now);
    if opts.direct_store {
        let owned: Vec<usize> =
            (0..desc.jobs.len()).filter(|&i| desc.jobs[i].owned).collect();
        if !owned.is_empty() {
            let _sp = trace::span2(
                "kernel.direct_store",
                "jobs",
                owned.len() as u64,
                "threads",
                threads as u64,
            );
            let cbase = SyncPtr(c.as_mut_ptr());
            scope_map_with(
                threads,
                &owned,
                OwnedState::default,
                move |st, _, &ji| {
                    let job = &desc.jobs[ji];
                    let _sj = trace::span2(
                        "kernel.accumulate",
                        "tile",
                        job.tile as u64,
                        "job",
                        ji as u64,
                    );
                    st.acc.clear();
                    st.acc.resize(bm * bn, 0.0);
                    accumulate_job(
                        a, b, k, n, bm, bn, kc, width, reg, backend, job,
                        &mut st.buf, &mut st.acc, ctr,
                    );
                    unsafe {
                        store_owned(
                            cbase.0, n, job.r0, job.c0, bm, bn, &st.acc,
                            epilogue,
                        );
                    }
                    if let Some(c) = ctr {
                        c.store_bytes.fetch_add(
                            (bm * bn * 4) as u64,
                            Ordering::Relaxed,
                        );
                    }
                },
            );
        }
    }
    if let Some(t) = t_pass {
        times.direct_ns += t.elapsed().as_nanos() as u64;
    }

    // Passes 1+2, windowed over the remaining jobs: compute a window of
    // independent work items in parallel, then apply its stores in the
    // reference's serial order. Windows ascend in job order, so the
    // ordered stores land exactly as the reference's (removing the
    // owned, order-free items from the sequence cannot change it).
    let rest: Vec<usize> = (0..desc.jobs.len())
        .filter(|&i| !(opts.direct_store && desc.jobs[i].owned))
        .collect();
    let mut start = 0;
    while start < rest.len() {
        let end = (start + WINDOW).min(rest.len());
        let t_pass = prof.then(Instant::now);
        let accs: Vec<Vec<f32>> = {
            let _sp = trace::span2(
                "kernel.windowed",
                "start",
                start as u64,
                "len",
                (end - start) as u64,
            );
            scope_map_with(
                threads,
                &rest[start..end],
                PackBuf::new,
                |buf, _, &ji| {
                    let job = &desc.jobs[ji];
                    // partial segments carry their CU id; plain stores
                    // are identified by job index
                    let _sj = match job.dest {
                        Dest::Partial { cu, .. } => trace::span2(
                            "kernel.accumulate",
                            "tile",
                            job.tile as u64,
                            "cu",
                            cu as u64,
                        ),
                        Dest::Store => trace::span2(
                            "kernel.accumulate",
                            "tile",
                            job.tile as u64,
                            "job",
                            ji as u64,
                        ),
                    };
                    let mut acc = vec![0.0f32; bm * bn];
                    accumulate_job(
                        a, b, k, n, bm, bn, kc, width, reg, backend, job, buf,
                        &mut acc, ctr,
                    );
                    acc
                },
            )
        };
        if let Some(t) = t_pass {
            times.windowed_ns += t.elapsed().as_nanos() as u64;
        }
        let t_pass = prof.then(Instant::now);
        let _ss = trace::span2(
            "kernel.store",
            "start",
            start as u64,
            "len",
            (end - start) as u64,
        );
        for (off, acc) in accs.into_iter().enumerate() {
            let ji = rest[start + off];
            let job = &desc.jobs[ji];
            match job.dest {
                Dest::Store => {
                    store_tile(
                        &mut c, n, job.r0, job.c0, bm, bn, &acc, epilogue,
                    );
                    if let Some(ct) = ctr {
                        ct.store_bytes.fetch_add(
                            (bm * bn * 4) as u64,
                            Ordering::Relaxed,
                        );
                    }
                }
                Dest::Partial { .. } => {
                    partial_accs[ji] = Some(acc);
                }
            }
        }
        drop(_ss);
        if let Some(t) = t_pass {
            times.store_ns += t.elapsed().as_nanos() as u64;
        }
        start = end;
    }

    // Pass 3: fixup-ordered reduction of partial K segments.
    let t_pass = prof.then(Instant::now);
    let _sf = trace::span2(
        "kernel.fixup",
        "tiles",
        desc.fixup.len() as u64,
        "contributors",
        desc.sources.len() as u64,
    );
    let mut facc = vec![0.0f32; bm * bn];
    for ft in &desc.fixup {
        facc.iter_mut().for_each(|v| *v = 0.0);
        for &src in &desc.sources[ft.src_start..ft.src_end] {
            if src == usize::MAX {
                continue; // unwritten partial slot == all-zero buffer
            }
            let Some(frag) = partial_accs[src].as_ref() else {
                continue; // ditto: slot declared but never produced
            };
            for (d, s) in facc.iter_mut().zip(frag) {
                *d += *s;
            }
        }
        store_tile(&mut c, n, ft.r0, ft.c0, bm, bn, &facc, epilogue);
        if let Some(ct) = ctr {
            ct.store_bytes
                .fetch_add((bm * bn * 4) as u64, Ordering::Relaxed);
        }
    }
    if let Some(t) = t_pass {
        times.fixup_ns += t.elapsed().as_nanos() as u64;
    }
    if let Some(counters) = counters.as_ref() {
        trace::profile::record_dispatch(
            desc.shape,
            desc.width,
            desc.class_counts(),
            desc.fixup.len(),
            counters,
            &times,
            t_all.expect("profiler epoch").elapsed().as_nanos() as u64,
        );
    }
    c
}

/// Accumulate one work item into `acc` (zero-initialized by the
/// caller): stream its K range in `kc`-deep chunks through pack +
/// microkernel. K chunks ascend, so per-element FP order matches the
/// reference exactly regardless of the chunk length. At 16-bit widths
/// the chunks go through convert-on-pack + the widening microkernel.
/// When the attribution profiler is on, `ctr` receives this job's
/// exact flop and packed-byte counts (at the *descriptor's* width —
/// streamed panel bytes halve at 16 bits) plus the time spent packing.
#[allow(clippy::too_many_arguments)]
fn accumulate_job(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    bm: usize,
    bn: usize,
    kc: usize,
    width: Width,
    reg: RegBlock,
    backend: LaneBackend,
    job: &TileJob,
    buf: &mut PackBuf,
    acc: &mut [f32],
    ctr: Option<&trace::profile::DispatchCounters>,
) {
    if let Some(c) = ctr {
        let kspan = job.kc1 - job.kc0;
        c.flops
            .fetch_add(2 * (bm * bn * kspan) as u64, Ordering::Relaxed);
        // Width-exact pack accounting: 2 bytes/elem at bf16/f16, 4 at
        // f32 — never a hardcoded 4 (C stores stay ×4; C is f32 at
        // every width).
        c.pack_bytes.fetch_add(
            ((bm + bn) * kspan * width.bytes()) as u64,
            Ordering::Relaxed,
        );
    }
    let mut kcur = job.kc0;
    while kcur < job.kc1 {
        let kv = kc.max(1).min(job.kc1 - kcur);
        {
            let t = ctr.map(|_| Instant::now());
            let _sp = trace::span2(
                "kernel.pack",
                "tile",
                job.tile as u64,
                "kv",
                kv as u64,
            );
            if width == Width::F32 {
                pack_a(&mut buf.a, a, k, job.r0, bm, kcur, kv);
                pack_b(&mut buf.b, b, n, job.c0, bn, kcur, kv);
            } else {
                pack_a16(&mut buf.a16, width, a, k, job.r0, bm, kcur, kv);
                pack_b16(&mut buf.b16, width, b, n, job.c0, bn, kcur, kv);
            }
            if let (Some(c), Some(t)) = (ctr, t) {
                c.pack_ns.fetch_add(
                    t.elapsed().as_nanos() as u64,
                    Ordering::Relaxed,
                );
            }
        }
        if width == Width::F32 {
            block_update_with(backend, &buf.a, &buf.b, bm, bn, kv, acc);
        } else {
            block_update_w(
                backend, width, reg, &buf.a16, &buf.b16, bm, bn, kv, acc,
            );
        }
        kcur += kv;
    }
}

/// Store one `bm × bn` accumulator into C at its clamped origin, with
/// the epilogue fused in.
#[allow(clippy::too_many_arguments)]
fn store_tile(
    c: &mut [f32],
    n: usize,
    r0: usize,
    c0: usize,
    bm: usize,
    bn: usize,
    acc: &[f32],
    epilogue: Epilogue,
) {
    for r in 0..bm {
        let at = (r0 + r) * n + c0;
        let row = &mut c[at..at + bn];
        let src = &acc[r * bn..(r + 1) * bn];
        if epilogue == Epilogue::None {
            row.copy_from_slice(src);
        } else {
            for (d, &s) in row.iter_mut().zip(src) {
                *d = epilogue.apply(s);
            }
        }
    }
}

/// Store one owned accumulator straight into C through the shared base
/// pointer, epilogue fused. Safety: the caller guarantees the `bm × bn`
/// region at `(r0, c0)` lies inside C and is written by no other job
/// (the ownership analysis), so rows touch memory no other thread
/// writes.
#[allow(clippy::too_many_arguments)]
unsafe fn store_owned(
    c: *mut f32,
    n: usize,
    r0: usize,
    c0: usize,
    bm: usize,
    bn: usize,
    acc: &[f32],
    epilogue: Epilogue,
) {
    for r in 0..bm {
        let dst = c.add((r0 + r) * n + c0);
        let src = &acc[r * bn..(r + 1) * bn];
        if epilogue == Epilogue::None {
            std::ptr::copy_nonoverlapping(src.as_ptr(), dst, bn);
        } else {
            for (j, &s) in src.iter().enumerate() {
                *dst.add(j) = epilogue.apply(s);
            }
        }
    }
}

// ---------------------------------------------------------------------
// Dense blocked matmul — the interpreter's plain-gemm / MLP path
// ---------------------------------------------------------------------

/// Row-major `C[m,n] = A[m,k] · B[k,n]` through the same K-chunked
/// microkernel, parallel over row panels. Bit-identical to the naive
/// triple loop *without* zero-skip (K ascends per element; `0·Inf`
/// stays NaN), independent of thread count. Workers accumulate straight
/// into disjoint row panels of the one output buffer — no per-panel
/// staging, no final gather copy.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A shape");
    assert_eq!(b.len(), k * n, "B shape");
    let mut c = vec![0.0f32; m * n];
    if m == 0 || n == 0 || k == 0 {
        return c;
    }
    // Rows per panel: big enough to amortize dispatch, small enough to
    // split MLP batches across workers.
    const RB: usize = 32;
    let threads =
        default_threads(2 * (m * n) as u64 * k as u64).min(m.div_ceil(RB));
    if threads <= 1 {
        let mut buf = PackBuf::new();
        for (i, panel) in c.chunks_mut(RB * n).enumerate() {
            matmul_panel(a, b, k, n, i * RB, panel, &mut buf);
        }
        return c;
    }
    // Round-robin the row panels over scoped workers: panels are
    // uniform, so static assignment balances and every worker writes
    // its own disjoint slices of C.
    let mut per_worker: Vec<Vec<(usize, &mut [f32])>> =
        (0..threads).map(|_| Vec::new()).collect();
    for (i, panel) in c.chunks_mut(RB * n).enumerate() {
        per_worker[i % threads].push((i * RB, panel));
    }
    std::thread::scope(|scope| {
        for work in per_worker {
            scope.spawn(move || {
                let mut buf = PackBuf::new();
                for (r0, panel) in work {
                    matmul_panel(a, b, k, n, r0, panel, &mut buf);
                }
            });
        }
    });
    c
}

/// Accumulate one row panel of C (`out` holds `out.len() / n` rows
/// starting at row `r0`, zero-initialized) in ascending K chunks.
fn matmul_panel(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    r0: usize,
    out: &mut [f32],
    buf: &mut PackBuf,
) {
    let rows = out.len() / n;
    let mut kc = 0;
    while kc < k {
        let kv = KC.min(k - kc);
        pack_a(&mut buf.a, a, k, r0, rows, kc, kv);
        // B rows are already contiguous at full width: no pack.
        super::micro::block_update(
            &buf.a,
            &b[kc * n..(kc + kv) * n],
            rows,
            n,
            kv,
            out,
        );
        kc += kv;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::decomp::{build_schedule, BlockShape, GemmShape};
    use crate::faults::{execute_flat_ref, Matrix};
    use crate::prop;

    fn bits_equal(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert_eq!(
                g.to_bits(),
                w.to_bits(),
                "{what}: elem {i}: {g} vs {w}"
            );
        }
    }

    fn flat_of(
        m: usize,
        n: usize,
        k: usize,
        p: usize,
        block: BlockShape,
    ) -> (GemmShape, crate::decomp::FlatSchedule, BlockShape) {
        let shape = GemmShape::new(m, n, k);
        let s = build_schedule(shape, block, p).unwrap();
        (shape, crate::decomp::FlatSchedule::from_schedule(&s), s.block)
    }

    #[test]
    fn blocked_matches_reference_bitwise_on_fixed_shapes() {
        for (m, n, k, p) in [
            (96usize, 102usize, 100usize, 12usize), // ragged hybrid
            (3, 9, 9, 120),                         // tiny, idle CUs
            (48, 64, 80, 1),                        // serial
            (64, 64, 64, 7),                        // aligned, odd CUs
            (60, 64, 64, 120),                      // deep multi-way splits
        ] {
            let mut rng = prop::Rng::new((m * 5 + n + k * 3 + p) as u64);
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let (shape, flat, block) =
                flat_of(m, n, k, p, BlockShape::new(16, 16, 8));
            let want = execute_flat_ref(&a.data, &b.data, shape, &flat, block);
            let desc = ExecDesc::new(shape, block, &flat);
            for threads in [1usize, 4] {
                let got = execute_threads(
                    &a.data,
                    &b.data,
                    &desc,
                    Epilogue::None,
                    threads,
                );
                bits_equal(
                    &got,
                    &want,
                    &format!("{m}x{n}x{k} p={p} threads={threads}"),
                );
            }
        }
    }

    /// Satellite acceptance: blocked execution is bit-identical to the
    /// per-element reference over random shapes/blocks/CU counts with
    /// NaN/∞ inputs and fixup-segment reduction exercised.
    #[test]
    fn prop_blocked_bit_identical_including_non_finite() {
        prop::check("blocked == per-element reference (bitwise)", 40, |rng| {
            let m = rng.usize_in(1, 150);
            let n = rng.usize_in(1, 150);
            let k = rng.usize_in(1, 150);
            let p = *rng.choose(&[1usize, 3, 16, 120]);
            let bm = *rng.choose(&[8usize, 16, 33]);
            let bn = *rng.choose(&[8usize, 16, 33]);
            let bk = *rng.choose(&[2usize, 8, 16]);
            let mut a = Matrix::random(m, k, rng);
            let b = Matrix::random(k, n, rng);
            // Seed non-finite values: NaN propagation is part of the
            // contract (no zero-skip anywhere).
            for _ in 0..rng.usize_in(0, 4) {
                let at = rng.usize_in(0, m * k - 1);
                a.data[at] =
                    *rng.choose(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
            }
            let (shape, flat, block) =
                flat_of(m, n, k, p, BlockShape::new(bm, bn, bk));
            let want =
                execute_flat_ref(&a.data, &b.data, shape, &flat, block);
            let desc = ExecDesc::new(shape, block, &flat);
            let threads = *rng.choose(&[1usize, 2, 5]);
            let got = execute_threads(
                &a.data,
                &b.data,
                &desc,
                Epilogue::None,
                threads,
            );
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                if g.to_bits() != w.to_bits() {
                    return Err(format!(
                        "{m}x{n}x{k} p={p} block {bm}x{bn}x{bk} \
                         threads={threads} elem {i}: {g:?} vs {w:?}"
                    ));
                }
            }
            Ok(())
        });
    }

    /// Satellite acceptance: the direct-store streaming dispatcher is
    /// bit-identical to the all-windowed one (and both to the
    /// reference) on random mixed-ownership grids — ragged edges,
    /// fixups, NaN/∞ — across every runnable lane backend.
    #[test]
    fn prop_direct_store_matches_windowed_on_mixed_grids() {
        prop::check("direct-store == windowed (bitwise)", 25, |rng| {
            let m = rng.usize_in(20, 150);
            let n = rng.usize_in(20, 150);
            let k = rng.usize_in(1, 100);
            let p = *rng.choose(&[1usize, 3, 16, 120]);
            let bm = *rng.choose(&[8usize, 16]);
            let bn = *rng.choose(&[8usize, 16]);
            let bk = *rng.choose(&[2usize, 8]);
            let mut a = Matrix::random(m, k, rng);
            let b = Matrix::random(k, n, rng);
            for _ in 0..rng.usize_in(0, 3) {
                let at = rng.usize_in(0, m * k - 1);
                a.data[at] =
                    *rng.choose(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
            }
            let (shape, flat, block) =
                flat_of(m, n, k, p, BlockShape::new(bm, bn, bk));
            let desc = ExecDesc::new(shape, block, &flat);
            let want =
                execute_flat_ref(&a.data, &b.data, shape, &flat, block);
            let threads = *rng.choose(&[1usize, 4]);
            for backend in lane::available() {
                for direct_store in [false, true] {
                    let got = execute_opts(
                        &a.data,
                        &b.data,
                        &desc,
                        Epilogue::None,
                        &ExecOpts {
                            backend,
                            direct_store,
                            threads,
                            kc: None,
                            reg: None,
                        },
                    );
                    for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                        if g.to_bits() != w.to_bits() {
                            return Err(format!(
                                "{m}x{n}x{k} p={p} {backend:?} \
                                 direct={direct_store} elem {i}: \
                                 {g:?} vs {w:?}"
                            ));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn ownership_classifies_aligned_and_edge_tiles() {
        // Grid-aligned problem: every direct store is owned, nothing
        // takes the ordered path.
        let (shape, flat, block) =
            flat_of(64, 64, 64, 7, BlockShape::new(16, 16, 8));
        let desc = ExecDesc::new(shape, block, &flat);
        let (owned, ordered, partial) = desc.class_counts();
        assert_eq!(ordered, 0, "aligned grid must stream every store");
        assert!(owned > 0);
        assert!(partial > 0, "case must exercise fixups too");
        for job in &desc.jobs {
            match job.dest {
                Dest::Store => assert!(job.owned, "{job:?}"),
                Dest::Partial { .. } => assert!(!job.owned, "{job:?}"),
            }
        }
        assert_eq!(owned + ordered + partial, desc.jobs.len());

        // Ragged columns: the clamped last tile-column overlaps the
        // second-to-last, so stores in both stay ordered; interior
        // columns still stream.
        let (shape, flat, block) =
            flat_of(96, 102, 100, 12, BlockShape::new(16, 16, 8));
        let desc = ExecDesc::new(shape, block, &flat);
        let (owned, ordered, _) = desc.class_counts();
        assert!(owned > 0, "interior tiles must stream");
        assert!(ordered > 0, "clamped-edge tiles must stay ordered");
        let tiles_n = flat.grid.tiles_n;
        for job in &desc.jobs {
            if !matches!(job.dest, Dest::Store) {
                continue;
            }
            let (_, tn) = flat.grid.tile_rc(job.tile);
            if tn + 2 >= tiles_n {
                assert!(!job.owned, "edge-overlap tile streamed: {job:?}");
            }
        }
    }

    #[test]
    fn duplicate_tile_writes_are_never_owned() {
        // Fault-injected schedules can write one tile many times (the
        // CU-bug remap); the ownership analysis must keep every such
        // store ordered and the dispatcher must reproduce the broken
        // schedule's corruption exactly, for every thread count.
        let (shape, flat, block) =
            flat_of(64, 64, 64, 7, BlockShape::new(16, 16, 8));
        let mut broken = flat.clone();
        for seg in &mut broken.segments {
            seg.tile = 0; // collide every SK segment onto the DP tile 0
        }
        let desc = ExecDesc::new(shape, block, &broken);
        let mut colliding = 0;
        for job in &desc.jobs {
            if job.tile == 0 && matches!(job.dest, Dest::Store) {
                assert!(!job.owned, "multi-writer tile streamed: {job:?}");
                colliding += 1;
            }
        }
        assert!(colliding >= 2, "case must actually collide stores");
        // untouched aligned single-writer tiles still stream
        assert!(desc.jobs.iter().any(|j| j.owned));

        let mut rng = prop::Rng::new(31);
        let a = Matrix::random(64, 64, &mut rng);
        let b = Matrix::random(64, 64, &mut rng);
        let want =
            execute_flat_ref(&a.data, &b.data, shape, &broken, block);
        for threads in [1usize, 4] {
            let got = execute_threads(
                &a.data,
                &b.data,
                &desc,
                Epilogue::None,
                threads,
            );
            bits_equal(&got, &want, &format!("broken threads={threads}"));
        }

        // Out-of-grid corruption: tile 18 of a 4x4 grid clamps onto
        // tile (3,2)'s region, so that aliased in-grid tile must not
        // stream either, and execution still matches the reference.
        let mut oob = flat.clone();
        oob.segments[0].tile = flat.grid.num_tiles() + 2;
        let desc = ExecDesc::new(shape, block, &oob);
        let aliased = (flat.grid.tiles_m - 1) * flat.grid.tiles_n + 2;
        for job in &desc.jobs {
            if job.tile == aliased || job.tile >= flat.grid.num_tiles() {
                assert!(!job.owned, "aliased/out-of-grid streamed: {job:?}");
            }
        }
        let want = execute_flat_ref(&a.data, &b.data, shape, &oob, block);
        for threads in [1usize, 4] {
            let got = execute_threads(
                &a.data,
                &b.data,
                &desc,
                Epilogue::None,
                threads,
            );
            bits_equal(&got, &want, &format!("oob threads={threads}"));
        }
    }

    #[test]
    fn fixup_reduction_is_contributor_ordered() {
        // 60x64x64 with a 16x16x2 block on 120 CUs has >= 3-way split
        // tiles (the medium-matrix-bug regime): the fixup sum order is
        // observable in FP, so bit-equality proves the reduction runs
        // in contributor order.
        let (shape, flat, block) =
            flat_of(60, 64, 64, 120, BlockShape::new(16, 16, 2));
        assert!(
            flat.contributors.len() >= 3,
            "case must exercise multi-way fixups"
        );
        let mut rng = prop::Rng::new(123);
        let a = Matrix::random(60, 64, &mut rng);
        let b = Matrix::random(64, 64, &mut rng);
        let want = execute_flat_ref(&a.data, &b.data, shape, &flat, block);
        let desc = ExecDesc::new(shape, block, &flat);
        for threads in [1usize, 3, 8] {
            let got =
                execute_threads(&a.data, &b.data, &desc, Epilogue::None, threads);
            bits_equal(&got, &want, &format!("threads={threads}"));
        }
    }

    #[test]
    fn epilogue_fuses_at_store_only() {
        // relu at the store == relu over the final C; partials must not
        // be clamped before the fixup sum (negative partials + positive
        // partials can produce positive finals).
        let (shape, flat, block) =
            flat_of(60, 64, 64, 120, BlockShape::new(16, 16, 2));
        let mut rng = prop::Rng::new(5);
        let a = Matrix::random(60, 64, &mut rng);
        let b = Matrix::random(64, 64, &mut rng);
        let desc = ExecDesc::new(shape, block, &flat);
        let plain = execute(&a.data, &b.data, &desc, Epilogue::None);
        let fused = execute(&a.data, &b.data, &desc, Epilogue::Relu);
        let mut post = plain;
        Epilogue::Relu.apply_slice(&mut post);
        bits_equal(&fused, &post, "fused relu");
        assert!(fused.iter().any(|&v| v > 0.0), "case must be non-trivial");
    }

    #[test]
    fn kc_chunking_never_changes_bits() {
        // The K-chunk length is a locality knob only: odd chunk lengths
        // must reproduce the default bits exactly.
        let (shape, flat, block) =
            flat_of(96, 102, 100, 12, BlockShape::new(16, 16, 8));
        let mut rng = prop::Rng::new(77);
        let a = Matrix::random(96, 100, &mut rng);
        let b = Matrix::random(100, 102, &mut rng);
        let want = execute(
            &a.data,
            &b.data,
            &ExecDesc::new(shape, block, &flat),
            Epilogue::None,
        );
        for kc in [1usize, 7, 64, 256, 10_000] {
            let desc = ExecDesc::new(shape, block, &flat).with_kc(kc);
            let got = execute(&a.data, &b.data, &desc, Epilogue::None);
            bits_equal(&got, &want, &format!("kc={kc}"));
        }
        // the per-call override (the serving path's tuned-KC hook) is
        // equivalent to baking the same kc into the descriptor
        let desc = ExecDesc::new(shape, block, &flat);
        for kc in [1usize, 7, 256] {
            let got = execute_opts(
                &a.data,
                &b.data,
                &desc,
                Epilogue::None,
                &ExecOpts { kc: Some(kc), ..ExecOpts::auto(desc.macs) },
            );
            bits_equal(&got, &want, &format!("opts kc={kc}"));
        }
    }

    /// Tentpole acceptance: a 16-bit descriptor is bit-identical to the
    /// per-element oracle over *quantized* inputs (the pack → widen →
    /// accumulate reference), across backends, dispatcher modes, and
    /// both register blocks — the f32 oracle generalizes per width
    /// instead of being weakened.
    #[test]
    fn prop_sixteen_bit_widths_match_quantized_oracle_bitwise() {
        prop::check("16-bit widths == quantized oracle (bitwise)", 12, |rng| {
            let width = *rng.choose(&[Width::Bf16, Width::F16]);
            let m = rng.usize_in(20, 120);
            let n = rng.usize_in(20, 120);
            let k = rng.usize_in(1, 90);
            let p = *rng.choose(&[1usize, 3, 16]);
            let mut a = Matrix::random(m, k, rng);
            let b = Matrix::random(k, n, rng);
            for _ in 0..rng.usize_in(0, 3) {
                let at = rng.usize_in(0, m * k - 1);
                a.data[at] = *rng.choose(&[
                    f32::NAN,
                    f32::INFINITY,
                    f32::NEG_INFINITY,
                    1.0e-41,
                ]);
            }
            let (shape, flat, block) =
                flat_of(m, n, k, p, BlockShape::new(16, 16, 8));
            let desc = ExecDesc::new(shape, block, &flat).with_width(width);
            let aq = width.quantize_slice(&a.data);
            let bq = width.quantize_slice(&b.data);
            let want = execute_flat_ref(&aq, &bq, shape, &flat, block);
            let threads = *rng.choose(&[1usize, 4]);
            for backend in lane::available() {
                for direct_store in [false, true] {
                    for reg in [None, Some(RegBlock::WIDE)] {
                        let got = execute_opts(
                            &a.data,
                            &b.data,
                            &desc,
                            Epilogue::None,
                            &ExecOpts {
                                backend,
                                direct_store,
                                threads,
                                kc: None,
                                reg,
                            },
                        );
                        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                            if g.to_bits() != w.to_bits() {
                                return Err(format!(
                                    "{m}x{n}x{k} p={p} {width} {backend:?} \
                                     direct={direct_store} reg={reg:?} \
                                     elem {i}: {g:?} vs {w:?}"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn width_is_a_pure_precision_knob_kc_and_reg_never_change_bits() {
        // Same descriptor, every (kc, reg) combination: identical bits
        // per width. And the f32 descriptor ignores `reg` entirely.
        let (shape, flat, block) =
            flat_of(96, 102, 100, 12, BlockShape::new(16, 16, 8));
        let mut rng = prop::Rng::new(404);
        let a = Matrix::random(96, 100, &mut rng);
        let b = Matrix::random(100, 102, &mut rng);
        for width in [Width::Bf16, Width::F16] {
            let desc = ExecDesc::new(shape, block, &flat).with_width(width);
            let want = execute(&a.data, &b.data, &desc, Epilogue::None);
            for kc in [1usize, 7, 256] {
                for reg in [RegBlock::BASE, RegBlock::WIDE] {
                    let got = execute_opts(
                        &a.data,
                        &b.data,
                        &desc,
                        Epilogue::None,
                        &ExecOpts {
                            kc: Some(kc),
                            reg: Some(reg),
                            ..ExecOpts::auto(desc.macs)
                        },
                    );
                    bits_equal(
                        &got,
                        &want,
                        &format!("{width} kc={kc} reg={}", reg.label()),
                    );
                }
            }
        }
        let f32_desc = ExecDesc::new(shape, block, &flat);
        let want = execute(&a.data, &b.data, &f32_desc, Epilogue::None);
        let got = execute_opts(
            &a.data,
            &b.data,
            &f32_desc,
            Epilogue::None,
            &ExecOpts {
                reg: Some(RegBlock::WIDE),
                ..ExecOpts::auto(f32_desc.macs)
            },
        );
        bits_equal(&got, &want, "f32 ignores reg");
    }

    #[test]
    fn descriptor_k_ranges_cover_the_mask_exactly() {
        // Ragged K: 100 with bk=8 -> last step holds 4 valid columns.
        let (shape, flat, block) =
            flat_of(96, 102, 100, 12, BlockShape::new(16, 16, 8));
        let desc = ExecDesc::new(shape, block, &flat);
        assert_eq!(desc.shape, shape);
        for job in &desc.jobs {
            assert!(job.kc0 < job.kc1, "empty K range");
            assert!(job.kc1 <= shape.k, "mask violated: {job:?}");
            assert!(job.r0 + block.bm <= shape.m);
            assert!(job.c0 + block.bn <= shape.n);
        }
        // every partial referenced by the fixup arena resolves
        for &src in &desc.sources {
            assert!(matches!(desc.jobs[src].dest, Dest::Partial { .. }));
        }
        assert!(desc.macs > 0);
    }

    #[test]
    fn matmul_matches_naive_order_bitwise() {
        let mut rng = prop::Rng::new(11);
        for (m, k, n) in [(1usize, 1usize, 1usize), (5, 7, 3), (33, 40, 65)] {
            let a = rng.normal_f32_vec(m * k);
            let b = rng.normal_f32_vec(k * n);
            // naive k-ascending reference, no zero-skip
            let mut want = vec![0.0f32; m * n];
            for i in 0..m {
                for l in 0..k {
                    let av = a[i * k + l];
                    for j in 0..n {
                        want[i * n + j] += av * b[l * n + j];
                    }
                }
            }
            let got = matmul(&a, &b, m, k, n);
            bits_equal(&got, &want, &format!("{m}x{k}x{n}"));
        }
    }

    #[test]
    fn matmul_propagates_non_finite() {
        let a = vec![f32::INFINITY, 0.0];
        let b = vec![0.0, 0.0]; // 1x2 @ 2x1: Inf*0 + 0*0 = NaN
        let got = matmul(&a, &b, 1, 2, 1);
        assert!(got[0].is_nan());
        assert!(matmul(&[], &[], 0, 0, 4).is_empty());
        assert_eq!(matmul(&[], &[], 2, 0, 2), vec![0.0; 4]);
    }

    /// The attribution counters are *exact*, not sampled: one profiled
    /// dispatch books precisely the descriptor's MAC-FLOPs, the packed
    /// panel bytes, one store per output tile, and the per-class tile
    /// counts. (Shape chosen so its pow2 bucket collides with no other
    /// test that executes concurrently without the trace lock.)
    #[test]
    fn profiler_counters_are_exact_per_dispatch() {
        let _g = crate::trace::test_lock();
        let (shape, flat, block) =
            flat_of(320, 320, 320, 7, BlockShape::new(16, 16, 8));
        let desc = ExecDesc::new(shape, block, &flat);
        let mut rng = prop::Rng::new(2024);
        let a = Matrix::random(320, 320, &mut rng);
        let b = Matrix::random(320, 320, &mut rng);

        trace::profile::set_enabled(true);
        let _ = trace::profile::drain();
        let got = execute_threads(&a.data, &b.data, &desc, Epilogue::None, 4);
        trace::profile::set_enabled(false);
        let profiles = trace::profile::drain();

        let key = crate::tuner::ShapeBucket::of(shape).key();
        let p = profiles
            .iter()
            .find(|p| p.bucket == key)
            .expect("profiled bucket present");
        assert_eq!(p.dispatches, 1);
        // flops match the descriptor's MAC count exactly
        assert_eq!(p.flops, desc.macs);
        // aligned covering schedule: every output element stored once
        assert_eq!(p.store_bytes, (320 * 320 * 4) as u64);
        // pack traffic: (bm + bn) · K-span · 4 bytes summed over jobs
        let want_pack: u64 = desc
            .jobs
            .iter()
            .map(|j| ((block.bm + block.bn) * (j.kc1 - j.kc0) * 4) as u64)
            .sum();
        assert_eq!(p.pack_bytes, want_pack);
        // per-class tile counts mirror the descriptor
        let (owned, ordered, partial) = desc.class_counts();
        assert_eq!(
            (p.owned, p.ordered, p.partial),
            (owned as u64, ordered as u64, partial as u64)
        );
        assert_eq!(p.fixup_tiles, desc.fixup.len() as u64);
        assert!(p.total_ns > 0);
        assert!(p.achieved_gflops() > 0.0);
        // the four sequential passes account for (nearly) all of the
        // dispatch wall time — the release bench gates this at 95%
        assert!(p.accounted() > 0.8, "accounted {}", p.accounted());
        assert!(p.accounted() <= 1.05, "accounted {}", p.accounted());

        // the profiled run still produces the reference bits
        let want = execute_flat_ref(&a.data, &b.data, shape, &flat, block);
        bits_equal(&got, &want, "profiled run");

        // all-windowed dispatch books identical byte/flop totals
        trace::profile::set_enabled(true);
        let _ = trace::profile::drain();
        let _ = execute_opts(
            &a.data,
            &b.data,
            &desc,
            Epilogue::None,
            &ExecOpts {
                direct_store: false,
                threads: 2,
                ..ExecOpts::auto(desc.macs)
            },
        );
        trace::profile::set_enabled(false);
        let profiles = trace::profile::drain();
        let w = profiles.iter().find(|p| p.bucket == key).unwrap();
        assert_eq!(w.flops, desc.macs);
        assert_eq!(w.store_bytes, (320 * 320 * 4) as u64);
        assert_eq!(w.pack_bytes, want_pack);
        // nothing streams: direct pass is (near) empty, windowed busy
        assert!(w.windowed_ns > 0);
    }

    /// Satellite acceptance: profiled byte accounting takes the width
    /// from the descriptor — a bf16 dispatch books *half* the f32 pack
    /// bytes, full f32 store bytes (C stays f32), and lands in a
    /// width-suffixed bucket so per-width GB/s never mix.
    #[test]
    fn profiler_pack_bytes_follow_descriptor_width() {
        let _g = crate::trace::test_lock();
        let (shape, flat, block) =
            flat_of(320, 320, 320, 7, BlockShape::new(16, 16, 8));
        let mut rng = prop::Rng::new(909);
        let a = Matrix::random(320, 320, &mut rng);
        let b = Matrix::random(320, 320, &mut rng);
        let f32_pack: u64 = {
            let desc = ExecDesc::new(shape, block, &flat);
            desc.jobs
                .iter()
                .map(|j| ((block.bm + block.bn) * (j.kc1 - j.kc0) * 4) as u64)
                .sum()
        };
        for width in [Width::Bf16, Width::F16] {
            let desc = ExecDesc::new(shape, block, &flat).with_width(width);
            trace::profile::set_enabled(true);
            let _ = trace::profile::drain();
            let _ = execute_threads(&a.data, &b.data, &desc, Epilogue::None, 2);
            trace::profile::set_enabled(false);
            let profiles = trace::profile::drain();
            let key = trace::profile::width_key(
                &crate::tuner::ShapeBucket::of(shape).key(),
                width,
            );
            let p = profiles
                .iter()
                .find(|p| p.bucket == key)
                .expect("width-suffixed bucket present");
            assert_eq!(p.pack_bytes, f32_pack / 2, "{width}: panel bytes halve");
            assert_eq!(p.store_bytes, (320 * 320 * 4) as u64, "C stays f32");
            assert_eq!(p.width(), width);
        }
    }

    /// Satellite property: attribution survives interleaved dispatches
    /// from independent `exec::pool` workers — each dispatching thread
    /// times its own passes, so per-bucket pass sums stay within
    /// tolerance of the booked wall time and counters stay exact.
    #[test]
    fn profiler_attribution_holds_under_interleaved_pool_dispatch() {
        let _g = crate::trace::test_lock();
        let (shape, flat, block) =
            flat_of(288, 288, 96, 5, BlockShape::new(16, 16, 8));
        let desc = ExecDesc::new(shape, block, &flat);
        let macs = desc.macs;

        trace::profile::set_enabled(true);
        let _ = trace::profile::drain();
        let runs = 4usize;
        let outs = crate::exec::pool_map(runs, (0..runs).collect(), {
            move |seed: usize| {
                let s = GemmShape::new(288, 288, 96);
                let schedule = crate::decomp::build_schedule(
                    s,
                    BlockShape::new(16, 16, 8),
                    5,
                )
                .unwrap();
                let flat =
                    crate::decomp::FlatSchedule::from_schedule(&schedule);
                let desc = ExecDesc::new(s, schedule.block, &flat);
                let mut rng = prop::Rng::new(seed as u64 + 7);
                let a = Matrix::random(288, 96, &mut rng);
                let b = Matrix::random(96, 288, &mut rng);
                execute_threads(&a.data, &b.data, &desc, Epilogue::None, 2)
                    .len()
            }
        });
        trace::profile::set_enabled(false);
        let profiles = trace::profile::drain();
        assert!(outs.iter().all(|&l| l == 288 * 288));

        let key = crate::tuner::ShapeBucket::of(shape).key();
        let p = profiles
            .iter()
            .find(|p| p.bucket == key)
            .expect("interleaved bucket present");
        assert_eq!(p.dispatches, runs as u64);
        assert_eq!(p.flops, macs * runs as u64);
        assert_eq!(p.store_bytes, (288 * 288 * 4 * runs) as u64);
        // pass times are sub-intervals of each dispatch's wall time:
        // their sum can never meaningfully exceed it, and on real work
        // it covers most of it even with worker interleaving
        assert!(p.accounted() <= 1.05, "accounted {}", p.accounted());
        assert!(p.accounted() > 0.5, "accounted {}", p.accounted());
        assert!(p.total_ns > 0);
    }
}
