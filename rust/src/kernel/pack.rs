//! Row-slice panel packing into contiguous scratch buffers.
//!
//! The microkernel lanes want unit-stride operands: the A panel as
//! `rows × kc` (row-major, one contiguous K slice per tile row) and the
//! B panel as `kc × cols` (one contiguous BN-wide row per K column).
//! At f32 packing is a pure copy — values are untouched, so it cannot
//! perturb the bit-identical numerics contract. At 16-bit widths
//! packing is *convert-on-pack* ([`pack_a16`]/[`pack_b16`]): each
//! source element is narrowed exactly once (RNE, NaNs quieted — see
//! [`super::width`]), halving the streamed panel bytes; the widening
//! lane kernels convert back in registers. The buffers are reused
//! across K chunks and across work items by each dispatcher worker
//! ([`PackBuf`]; the direct-store streaming pass additionally reuses
//! one accumulator per worker), so the steady-state hot path allocates
//! nothing.

use super::width::Width;

/// Per-worker packing scratch: one A panel + one B panel per element
/// width, grown once to the high-water panel size and reused for every
/// subsequent chunk. Only the pair matching the dispatch width is
/// touched, so mixed-width traffic through one worker stays cheap.
#[derive(Debug, Default)]
pub struct PackBuf {
    pub(crate) a: Vec<f32>,
    pub(crate) b: Vec<f32>,
    pub(crate) a16: Vec<u16>,
    pub(crate) b16: Vec<u16>,
}

impl PackBuf {
    pub fn new() -> Self {
        Self::default()
    }
}

/// Pack `rows` rows of `a` (row stride `stride`), columns
/// `[kc0, kc0 + kv)`, into `buf` as a contiguous `rows × kv` panel.
pub(crate) fn pack_a(
    buf: &mut Vec<f32>,
    a: &[f32],
    stride: usize,
    r0: usize,
    rows: usize,
    kc0: usize,
    kv: usize,
) {
    buf.clear();
    buf.reserve(rows * kv);
    for r in 0..rows {
        let src = &a[(r0 + r) * stride + kc0..][..kv];
        buf.extend_from_slice(src);
    }
}

/// Pack `kv` rows of `b` (row stride `stride`), columns
/// `[c0, c0 + cols)`, into `buf` as a contiguous `kv × cols` panel.
pub(crate) fn pack_b(
    buf: &mut Vec<f32>,
    b: &[f32],
    stride: usize,
    c0: usize,
    cols: usize,
    kc0: usize,
    kv: usize,
) {
    buf.clear();
    buf.reserve(kv * cols);
    for kk in 0..kv {
        let src = &b[(kc0 + kk) * stride + c0..][..cols];
        buf.extend_from_slice(src);
    }
}

/// Convert-on-pack variant of [`pack_a`]: narrow each element of the
/// `rows × kv` A panel to `width` (bf16/f16) while copying.
pub(crate) fn pack_a16(
    buf: &mut Vec<u16>,
    width: Width,
    a: &[f32],
    stride: usize,
    r0: usize,
    rows: usize,
    kc0: usize,
    kv: usize,
) {
    buf.clear();
    buf.reserve(rows * kv);
    for r in 0..rows {
        let src = &a[(r0 + r) * stride + kc0..][..kv];
        buf.extend(src.iter().map(|&x| width.narrow(x)));
    }
}

/// Convert-on-pack variant of [`pack_b`]: narrow each element of the
/// `kv × cols` B panel to `width` while copying.
pub(crate) fn pack_b16(
    buf: &mut Vec<u16>,
    width: Width,
    b: &[f32],
    stride: usize,
    c0: usize,
    cols: usize,
    kc0: usize,
    kv: usize,
) {
    buf.clear();
    buf.reserve(kv * cols);
    for kk in 0..kv {
        let src = &b[(kc0 + kk) * stride + c0..][..cols];
        buf.extend(src.iter().map(|&x| width.narrow(x)));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a_panel_is_row_major_slice_copy() {
        // 3x4 matrix, pack rows 1..3, cols 1..3
        let a: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let mut buf = Vec::new();
        pack_a(&mut buf, &a, 4, 1, 2, 1, 2);
        assert_eq!(buf, vec![5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn b_panel_is_k_major_slice_copy() {
        // 4x3 matrix, pack k rows 2..4, cols 0..2
        let b: Vec<f32> = (0..12).map(|v| v as f32).collect();
        let mut buf = Vec::new();
        pack_b(&mut buf, &b, 3, 0, 2, 2, 2);
        assert_eq!(buf, vec![6.0, 7.0, 9.0, 10.0]);
    }

    #[test]
    fn buffers_are_reused_without_stale_tails() {
        let a: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut pb = PackBuf::new();
        pack_a(&mut pb.a, &a, 4, 0, 4, 0, 4);
        assert_eq!(pb.a.len(), 16);
        pack_a(&mut pb.a, &a, 4, 0, 1, 0, 2);
        assert_eq!(pb.a, vec![0.0, 1.0]);
    }

    #[test]
    fn sixteen_bit_pack_narrows_each_element_exactly_once() {
        let a: Vec<f32> = vec![1.0, 1.0009765625, -2.5, f32::NAN, 3.0e38, 1.0e-40];
        let mut pb = PackBuf::new();
        for w in [Width::Bf16, Width::F16] {
            pack_a16(&mut pb.a16, w, &a, 3, 0, 2, 0, 3);
            let want: Vec<u16> = a.iter().map(|&x| w.narrow(x)).collect();
            assert_eq!(pb.a16, want, "{w}: pack must equal per-element narrow");
            pack_b16(&mut pb.b16, w, &a, 3, 1, 2, 0, 2);
            assert_eq!(pb.b16, vec![w.narrow(a[1]), w.narrow(a[2]), w.narrow(a[4]), w.narrow(a[5])]);
        }
        // Reuse shrinks without stale tails, same as the f32 path.
        pack_a16(&mut pb.a16, Width::Bf16, &a, 3, 0, 1, 0, 1);
        assert_eq!(pb.a16, vec![Width::Bf16.narrow(1.0)]);
    }
}
