//! The cache-sized f32 microkernel.
//!
//! [`block_update`] computes `acc[r, c] += Σ_kk ap[r, kk] · bp[kk, c]`
//! over packed panels, walking K in strictly ascending order with one
//! separate mul-then-add per (element, k) pair — the exact FP sequence
//! of the per-element reference executor, so results are bit-identical
//! (including NaN/∞ propagation: zero operands are never skipped).
//!
//! The speed comes from register blocking plus explicit SIMD lanes
//! ([`super::lane`]): the `MR × NR` inner kernel keeps a 4×8
//! accumulator block in registers across the whole K slice (the
//! reference re-loads and re-stores every accumulator element once per
//! MAC), and on x86_64 the NR lane runs as one AVX2 register (or two
//! SSE2 registers) of IEEE-exact mul+add — never FMA, which would
//! contract the two roundings and break the bit-identity contract.
//! Edges that do not fill an `MR × NR` block fall back to a scalar dot
//! loop with the same K order.

use super::lane::{self, LaneBackend, RegBlock, MR, NR};
use super::width::Width;

/// Default K-chunk length: panels of `BM × KC` + `KC × BN` f32 stay
/// cache-resident (≤ 64 KiB each at the 128-wide default blocks). The
/// tuner can override per config ([`crate::decomp::params::KernelParams::kc`]);
/// chunking never changes numerics (K still ascends per element).
pub use crate::decomp::params::KC_DEFAULT as KC;

/// `acc (bm × bn) += ap (bm × kv, row-major) · bp (kv × bn, row-major)`
/// on the process-wide lane backend ([`lane::active`]).
///
/// `bp` may be a view of a wider row-major matrix only when its row
/// stride equals `bn` (the dispatcher packs panels; [`super::matmul`]
/// passes full-width B rows directly).
pub fn block_update(
    ap: &[f32],
    bp: &[f32],
    bm: usize,
    bn: usize,
    kv: usize,
    acc: &mut [f32],
) {
    block_update_with(lane::active(), ap, bp, bm, bn, kv, acc)
}

/// [`block_update`] on an explicit lane backend — the bit-identity
/// property tests and the `kernel_exec` bench pin backends through
/// this; production paths go through [`block_update`] /
/// [`super::exec::ExecOpts`].
pub fn block_update_with(
    backend: LaneBackend,
    ap: &[f32],
    bp: &[f32],
    bm: usize,
    bn: usize,
    kv: usize,
    acc: &mut [f32],
) {
    debug_assert!(ap.len() >= bm * kv, "A panel short");
    debug_assert!(bp.len() >= kv * bn, "B panel short");
    debug_assert!(acc.len() >= bm * bn, "acc short");
    if kv == 0 || bm == 0 || bn == 0 {
        return;
    }
    // Downgrade an unrunnable backend once per panel, not once per
    // register block inside the hot loop.
    let backend = lane::resolve(backend);
    let mut r0 = 0;
    while r0 + MR <= bm {
        let a_rows: [&[f32]; MR] = [
            &ap[r0 * kv..][..kv],
            &ap[(r0 + 1) * kv..][..kv],
            &ap[(r0 + 2) * kv..][..kv],
            &ap[(r0 + 3) * kv..][..kv],
        ];
        let mut c0 = 0;
        while c0 + NR <= bn {
            lane::micro_block(backend, &a_rows, bp, bn, kv, r0, c0, acc);
            c0 += NR;
        }
        for r in r0..r0 + MR {
            for c in c0..bn {
                edge_dot(ap, bp, bn, kv, r, c, acc);
            }
        }
        r0 += MR;
    }
    for r in r0..bm {
        for c in 0..bn {
            edge_dot(ap, bp, bn, kv, r, c, acc);
        }
    }
}

/// 16-bit variant of [`block_update_with`]: panels hold pack-narrowed
/// `width` elements ([`super::pack::pack_a16`]); lanes widen in
/// registers and accumulate f32. `reg` picks the register-block shape
/// ([`RegBlock::options`]); column grouping never changes per-element
/// FP order, so every legal `reg` is bit-identical to the per-element
/// oracle over quantized inputs.
#[allow(clippy::too_many_arguments)]
pub fn block_update_w(
    backend: LaneBackend,
    width: Width,
    reg: RegBlock,
    ap: &[u16],
    bp: &[u16],
    bm: usize,
    bn: usize,
    kv: usize,
    acc: &mut [f32],
) {
    debug_assert!(ap.len() >= bm * kv, "A panel short");
    debug_assert!(bp.len() >= kv * bn, "B panel short");
    debug_assert!(acc.len() >= bm * bn, "acc short");
    if kv == 0 || bm == 0 || bn == 0 {
        return;
    }
    let backend = lane::resolve(backend);
    let nr = if reg.is_legal(width) { reg.nr } else { NR };
    let mut r0 = 0;
    while r0 + MR <= bm {
        let a_rows: [&[u16]; MR] = [
            &ap[r0 * kv..][..kv],
            &ap[(r0 + 1) * kv..][..kv],
            &ap[(r0 + 2) * kv..][..kv],
            &ap[(r0 + 3) * kv..][..kv],
        ];
        let mut c0 = 0;
        while c0 + nr <= bn {
            lane::micro_block_w(backend, width, nr, &a_rows, bp, bn, kv, r0, c0, acc);
            c0 += nr;
        }
        // A base-width block still fits in the wide-reg column edge.
        if nr > NR && c0 + NR <= bn {
            lane::micro_block_w(backend, width, NR, &a_rows, bp, bn, kv, r0, c0, acc);
            c0 += NR;
        }
        for r in r0..r0 + MR {
            for c in c0..bn {
                edge_dot_w(width, ap, bp, bn, kv, r, c, acc);
            }
        }
        r0 += MR;
    }
    for r in r0..bm {
        for c in 0..bn {
            edge_dot_w(width, ap, bp, bn, kv, r, c, acc);
        }
    }
}

/// Scalar fallback for one edge element — identical K order (and
/// identical on every backend, so edges never break lane bit-identity).
#[inline]
fn edge_dot(
    ap: &[f32],
    bp: &[f32],
    bn: usize,
    kv: usize,
    r: usize,
    c: usize,
    acc: &mut [f32],
) {
    let arow = &ap[r * kv..][..kv];
    let mut s = acc[r * bn + c];
    for (kk, &av) in arow.iter().enumerate() {
        s += av * bp[kk * bn + c];
    }
    acc[r * bn + c] = s;
}

/// 16-bit edge element: widen both operands, then the same mul-then-add
/// K order as [`edge_dot`].
#[inline]
fn edge_dot_w(
    width: Width,
    ap: &[u16],
    bp: &[u16],
    bn: usize,
    kv: usize,
    r: usize,
    c: usize,
    acc: &mut [f32],
) {
    let arow = &ap[r * kv..][..kv];
    let mut s = acc[r * bn + c];
    for (kk, &ah) in arow.iter().enumerate() {
        s += width.widen(ah) * width.widen(bp[kk * bn + c]);
    }
    acc[r * bn + c] = s;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prop::Rng;

    /// The per-element reference order: for each element, K ascending,
    /// one sequential add per MAC.
    fn reference(
        ap: &[f32],
        bp: &[f32],
        bm: usize,
        bn: usize,
        kv: usize,
        acc: &mut [f32],
    ) {
        for r in 0..bm {
            for kk in 0..kv {
                let av = ap[r * kv + kk];
                for c in 0..bn {
                    acc[r * bn + c] += av * bp[kk * bn + c];
                }
            }
        }
    }

    #[test]
    fn bit_identical_to_reference_over_odd_shapes() {
        let mut rng = Rng::new(7);
        for (bm, bn, kv) in [
            (1usize, 1usize, 1usize),
            (4, 8, 16),   // exact register blocks
            (5, 9, 3),    // edges in both dimensions
            (16, 16, 8),  // the faults-test block
            (7, 130, 33), // wide with a 2-col edge
            (12, 8, 0),   // empty K slice: no-op
        ] {
            let ap = rng.normal_f32_vec(bm * kv);
            let bp = rng.normal_f32_vec(kv * bn);
            let mut want = rng.normal_f32_vec(bm * bn); // nonzero start
            let mut got = want.clone();
            reference(&ap, &bp, bm, bn, kv, &mut want);
            block_update(&ap, &bp, bm, bn, kv, &mut got);
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w.to_bits(),
                    "{bm}x{bn}x{kv} elem {i}: {g} vs {w}"
                );
            }
        }
    }

    /// Satellite acceptance: every runnable lane backend is
    /// bit-identical to the per-element reference over odd shapes with
    /// seeded NaN/∞ (forced through `block_update_with`, independent of
    /// the process-wide backend).
    #[test]
    fn prop_every_lane_backend_matches_reference_bitwise() {
        crate::prop::check("lane backends == reference (bitwise)", 30, |rng| {
            let bm = rng.usize_in(1, 24);
            let bn = rng.usize_in(1, 40);
            let kv = rng.usize_in(1, 48);
            let mut ap = rng.normal_f32_vec(bm * kv);
            let bp = rng.normal_f32_vec(kv * bn);
            for _ in 0..rng.usize_in(0, 3) {
                let at = rng.usize_in(0, bm * kv - 1);
                ap[at] =
                    *rng.choose(&[f32::NAN, f32::INFINITY, f32::NEG_INFINITY]);
            }
            let start = rng.normal_f32_vec(bm * bn);
            let mut want = start.clone();
            reference(&ap, &bp, bm, bn, kv, &mut want);
            for backend in lane::available() {
                let mut got = start.clone();
                block_update_with(backend, &ap, &bp, bm, bn, kv, &mut got);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    if g.to_bits() != w.to_bits() {
                        return Err(format!(
                            "{backend:?} {bm}x{bn}x{kv} elem {i}: {g:?} vs {w:?}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// Per-width oracle: widen each panel element, then the identical
    /// per-element K-ascending order as `reference`. Equivalently: the
    /// f32 reference over quantized inputs.
    fn reference_w(
        width: Width,
        ap: &[u16],
        bp: &[u16],
        bm: usize,
        bn: usize,
        kv: usize,
        acc: &mut [f32],
    ) {
        for r in 0..bm {
            for kk in 0..kv {
                let av = width.widen(ap[r * kv + kk]);
                for c in 0..bn {
                    acc[r * bn + c] += av * width.widen(bp[kk * bn + c]);
                }
            }
        }
    }

    /// Tentpole acceptance: every backend × 16-bit width × register
    /// block is bit-identical to the per-width per-element oracle over
    /// odd shapes with seeded NaN/∞/subnormals — and identical to the
    /// f32 path run over quantized operands, which ties the widening
    /// kernels back to the existing f32 oracle machinery.
    #[test]
    fn prop_widening_backends_match_per_width_reference_bitwise() {
        crate::prop::check("widening lanes == oracle (bitwise)", 24, |rng| {
            let width = *rng.choose(&[Width::Bf16, Width::F16]);
            let reg = *rng.choose(RegBlock::options(width));
            let bm = rng.usize_in(1, 24);
            let bn = rng.usize_in(1, 40);
            let kv = rng.usize_in(1, 48);
            let mut af = rng.normal_f32_vec(bm * kv);
            for _ in 0..rng.usize_in(0, 3) {
                let at = rng.usize_in(0, bm * kv - 1);
                af[at] = *rng.choose(&[
                    f32::NAN,
                    f32::INFINITY,
                    f32::NEG_INFINITY,
                    1.0e-41, // f32 subnormal after narrowing
                ]);
            }
            let bf = rng.normal_f32_vec(kv * bn);
            let ap: Vec<u16> = af.iter().map(|&x| width.narrow(x)).collect();
            let bp: Vec<u16> = bf.iter().map(|&x| width.narrow(x)).collect();
            let start = rng.normal_f32_vec(bm * bn);
            let mut want = start.clone();
            reference_w(width, &ap, &bp, bm, bn, kv, &mut want);
            // The same bits must fall out of the f32 kernel over
            // quantized operands (narrow∘widen per element).
            let aq = width.quantize_slice(&af);
            let bq = width.quantize_slice(&bf);
            let mut via_f32 = start.clone();
            block_update(&aq, &bq, bm, bn, kv, &mut via_f32);
            for backend in lane::available() {
                let mut got = start.clone();
                block_update_w(backend, width, reg, &ap, &bp, bm, bn, kv, &mut got);
                for i in 0..bm * bn {
                    if got[i].to_bits() != want[i].to_bits() {
                        return Err(format!(
                            "{backend:?}/{width}/{} {bm}x{bn}x{kv} elem {i}: {:?} vs {:?}",
                            reg.label(), got[i], want[i]
                        ));
                    }
                    if got[i].to_bits() != via_f32[i].to_bits() {
                        return Err(format!(
                            "{backend:?}/{width} disagrees with f32-over-quantized at {i}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn no_zero_skip_nan_propagates() {
        // Inf * 0 must produce NaN inside the register block and at the
        // scalar edge alike — on every runnable backend.
        let bm = 5;
        let bn = 9;
        let kv = 2;
        let mut ap = vec![0.0f32; bm * kv];
        ap[0] = f32::INFINITY; // row 0 (register block)
        ap[4 * kv] = f32::INFINITY; // row 4 (scalar edge row)
        let bp = vec![0.0f32; kv * bn];
        for backend in lane::available() {
            let mut acc = vec![0.0f32; bm * bn];
            block_update_with(backend, &ap, &bp, bm, bn, kv, &mut acc);
            assert!(acc[0].is_nan(), "{backend:?}: register path lost 0*Inf");
            assert!(
                acc[4 * bn + 8].is_nan(),
                "{backend:?}: edge path lost 0*Inf"
            );
            assert_eq!(acc[bn], 0.0, "{backend:?}: untouched rows stay zero");
        }
    }
}
