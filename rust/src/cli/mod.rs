//! Declarative command-line parser (clap substitute — DESIGN.md §2).
//!
//! Supports subcommands, `--flag`, `--key value`, `--key=value`, and
//! positional arguments, with generated `--help` text. Used by the
//! `streamk` binary, every example, and every bench harness.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Specification of one option.
#[derive(Debug, Clone)]
pub struct Opt {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

impl Opt {
    pub fn flag(name: &'static str, help: &'static str) -> Self {
        Self { name, takes_value: false, default: None, help }
    }

    pub fn value(
        name: &'static str,
        default: Option<&'static str>,
        help: &'static str,
    ) -> Self {
        Self { name, takes_value: true, default, help }
    }
}

/// A parsed command line.
#[derive(Debug, Default, PartialEq)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CliError {
    Unknown(String),
    MissingValue(String),
    Invalid { name: String, value: String, msg: String },
    Help,
}

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CliError::Unknown(name) => {
                write!(f, "unknown option --{name} (try --help)")
            }
            CliError::MissingValue(name) => {
                write!(f, "option --{name} requires a value")
            }
            CliError::Invalid { name, value, msg } => {
                write!(f, "invalid value {value:?} for --{name}: {msg}")
            }
            CliError::Help => write!(f, "help requested"),
        }
    }
}

impl std::error::Error for CliError {}

/// Command definition: name + options; renders its own usage text.
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<Opt>,
    pub examples: Vec<&'static str>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, opts: Vec::new(), examples: Vec::new() }
    }

    pub fn opt(mut self, o: Opt) -> Self {
        self.opts.push(o);
        self
    }

    /// Add a quickstart line rendered under `examples:` in `--help`.
    pub fn example(mut self, line: &'static str) -> Self {
        self.examples.push(line);
        self
    }

    pub fn usage(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(s, "{} — {}\n", self.name, self.about);
        let _ = writeln!(s, "options:");
        for o in &self.opts {
            let arg = if o.takes_value { format!("--{} <v>", o.name) } else { format!("--{}", o.name) };
            let def = o
                .default
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            let _ = writeln!(s, "  {arg:<26} {}{def}", o.help);
        }
        if !self.examples.is_empty() {
            let _ = writeln!(s, "\nexamples:");
            for ex in &self.examples {
                let _ = writeln!(s, "  {ex}");
            }
        }
        s
    }

    /// Parse an argv slice (without the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for o in &self.opts {
            if let (true, Some(d)) = (o.takes_value, o.default) {
                args.values.insert(o.name.to_string(), d.to_string());
            }
        }
        let mut it = argv.iter().peekable();
        while let Some(raw) = it.next() {
            if raw == "--help" || raw == "-h" {
                return Err(CliError::Help);
            }
            if let Some(body) = raw.strip_prefix("--") {
                let (name, inline) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError::Unknown(name.clone()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    args.values.insert(name, v);
                } else {
                    if inline.is_some() {
                        return Err(CliError::Invalid {
                            name: name.clone(),
                            value: inline.unwrap(),
                            msg: "flag does not take a value".into(),
                        });
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(raw.clone());
            }
        }
        Ok(args)
    }

    /// Parse `std::env::args()`, printing usage and exiting on `--help`
    /// or error. Convenience wrapper for binaries.
    pub fn parse_or_exit(&self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        match self.parse(&argv) {
            Ok(a) => a,
            Err(CliError::Help) => {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            Err(e) => {
                eprintln!("error: {e}\n\n{}", self.usage());
                std::process::exit(2);
            }
        }
    }
}

impl Args {
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> &str {
        self.get(name)
            .unwrap_or_else(|| panic!("option --{name} has no value/default"))
    }

    pub fn usize(&self, name: &str) -> Result<usize, CliError> {
        let v = self.str(name);
        v.parse().map_err(|_| CliError::Invalid {
            name: name.into(),
            value: v.into(),
            msg: "expected unsigned integer".into(),
        })
    }

    pub fn f64(&self, name: &str) -> Result<f64, CliError> {
        let v = self.str(name);
        v.parse().map_err(|_| CliError::Invalid {
            name: name.into(),
            value: v.into(),
            msg: "expected number".into(),
        })
    }

    /// Comma-separated usize list, e.g. `--cus 1,30,120`.
    pub fn usize_list(&self, name: &str) -> Result<Vec<usize>, CliError> {
        self.str(name)
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| {
                s.trim().parse().map_err(|_| CliError::Invalid {
                    name: name.into(),
                    value: s.into(),
                    msg: "expected comma-separated unsigned integers".into(),
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cmd() -> Command {
        Command::new("t", "test")
            .opt(Opt::value("n", Some("4"), "count"))
            .opt(Opt::flag("verbose", "chatty"))
            .opt(Opt::value("name", None, "a name"))
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = cmd().parse(&argv(&[])).unwrap();
        assert_eq!(a.usize("n").unwrap(), 4);
        assert!(!a.flag("verbose"));

        let a = cmd().parse(&argv(&["--n", "9", "--verbose"])).unwrap();
        assert_eq!(a.usize("n").unwrap(), 9);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_positionals() {
        let a = cmd().parse(&argv(&["--n=12", "pos1", "pos2"])).unwrap();
        assert_eq!(a.usize("n").unwrap(), 12);
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn errors() {
        assert_eq!(
            cmd().parse(&argv(&["--bogus"])),
            Err(CliError::Unknown("bogus".into()))
        );
        assert_eq!(
            cmd().parse(&argv(&["--name"])),
            Err(CliError::MissingValue("name".into()))
        );
        assert!(matches!(
            cmd().parse(&argv(&["--n", "x"])).unwrap().usize("n"),
            Err(CliError::Invalid { .. })
        ));
        assert_eq!(cmd().parse(&argv(&["--help"])), Err(CliError::Help));
    }

    #[test]
    fn usize_list() {
        let c = Command::new("t", "t").opt(Opt::value("cus", Some("1,2,3"), ""));
        let a = c.parse(&argv(&[])).unwrap();
        assert_eq!(a.usize_list("cus").unwrap(), vec![1, 2, 3]);
        let a = c.parse(&argv(&["--cus", "10, 20"])).unwrap();
        assert_eq!(a.usize_list("cus").unwrap(), vec![10, 20]);
    }

    #[test]
    fn usage_mentions_options() {
        let u = cmd().usage();
        assert!(u.contains("--n"));
        assert!(u.contains("--verbose"));
        assert!(!u.contains("examples:"));
    }

    #[test]
    fn usage_renders_examples_section() {
        let u = Command::new("t", "test")
            .opt(Opt::value("n", Some("4"), "count"))
            .example("t --n 9")
            .example("t --n 9 --out x.json")
            .usage();
        assert!(u.contains("examples:"));
        assert!(u.contains("  t --n 9\n"));
        assert!(u.contains("  t --n 9 --out x.json\n"));
    }
}
